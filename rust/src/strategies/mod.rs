//! Fine-tuning strategies: HiFT plus every baseline the paper compares
//! against (Appendix C).
//!
//! | strategy | kind | trainable set | grad artifact(s) |
//! |---|---|---|---|
//! | [`hift::Hift`] | the paper | one layer group per step, rotating | `grad_base_u{i}` per unit |
//! | FPFT | standard | everything, every step | `grad_base_full` |
//! | BitFit | selection PEFT | biases + LN params | `grad_base_bitfit` |
//! | LoRA / IA3 / Prefix | addition/reparam PEFT | adapters only | `grad_<v>_adapter` |
//! | LP (linear probe) | selection | head unit only | `grad_base_u{n-1}` |
//! | LOMO (sim) | fused-SGD | everything, no optimizer state | `grad_base_full` + SGD |
//! | [`mezo::Mezo`] | zeroth-order | everything, two forwards, no grads | `fwd_base` ×2 |
//!
//! All implement [`FineTuneStrategy`]; the trainer is strategy-agnostic.

pub mod hift;
pub mod mezo;
pub mod subset;

pub use hift::{Hift, HiftCfg};
pub use mezo::Mezo;
pub use subset::SubsetTune;

use std::time::Duration;

use anyhow::Result;

use crate::backend::{Batch, ExecBackend, Manifest};
use crate::coordinator::lr::LrSchedule;
use crate::coordinator::strategy::UpdateStrategy;
use crate::optim::{OffloadLedger, OptimCfg, OptimKind};
use crate::tensor::{Tensor, TensorSet};

/// Per-step outcome every strategy reports.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub loss: f32,
    pub ncorrect: f32,
    pub weight_sum: f32,
    pub lr: f32,
    /// Parameters that received an update this step (the paper's
    /// "#Trainable Parameters" axis).
    pub trainable_params: usize,
    /// Backend execute wallclock within the step.
    pub exec_time: Duration,
}

/// A fine-tuning algorithm: owns its optimizer/LR policy, updates params
/// in place given gradients (or forward passes) from an execution backend.
pub trait FineTuneStrategy {
    fn name(&self) -> &str;

    /// Which model variant's parameters/artifacts it trains on.
    fn variant(&self) -> &str;

    /// The eval forward artifact for this strategy.
    fn fwd_artifact(&self) -> String {
        format!("fwd_{}", self.variant())
    }

    /// One training step: compute gradients via `be`, update `params`.
    fn step(&mut self, be: &mut dyn ExecBackend, params: &mut TensorSet, batch: &Batch)
        -> Result<StepStats>;

    /// Peak per-step trainable parameter count seen so far.
    fn peak_trainable_params(&self) -> usize;

    /// The host↔device optimizer-state paging ledger, if the strategy
    /// offloads (HiFT does; baselines keep state resident).
    fn ledger(&self) -> Option<&OffloadLedger> {
        None
    }

    /// Total optimizer-state bytes currently held (device + host).
    fn optimizer_state_bytes(&self) -> usize;

    /// Advance internal schedules (step/sweep counters, HiFT's rotating
    /// queue) as if `steps_done` training steps had already run — the
    /// resume half of the checkpoint workflow.  Call at most once, on a
    /// freshly built strategy, before any [`FineTuneStrategy::step`];
    /// optimizer moments are restored separately via
    /// [`FineTuneStrategy::import_opt_state`].
    fn fast_forward(&mut self, steps_done: u64);

    /// Schedule index persisted in checkpoints: HiFT reports its delayed-LR
    /// sweep counter (§3.1); per-step strategies report their step count.
    /// Resume cross-checks this against the fast-forwarded schedule.
    fn sweeps_done(&self) -> u64;

    /// Optimizer state to persist in a checkpoint (moments etc.), keyed
    /// `"{param idx}.{field}"`; empty for stateless optimizers.
    fn export_opt_state(&self) -> Vec<(String, Tensor)>;

    /// Restore state captured by [`FineTuneStrategy::export_opt_state`].
    /// `params` is the parameter set the resumed run will train — imported
    /// buffers are validated against its tensor geometry, so a
    /// size-mismatched checkpoint fails here with context instead of
    /// panicking inside the first fused update.
    fn import_opt_state(&mut self, state: &[(String, Tensor)], params: &TensorSet) -> Result<()>;
}

/// Everything needed to construct any strategy by name (CLI/bench entry).
#[derive(Debug, Clone)]
pub struct StrategySpec {
    pub name: String,
    pub optim: OptimKind,
    pub lr: f32,
    pub warmup: usize,
    pub total: usize,
    /// HiFT's m (ignored by baselines).
    pub m: usize,
    /// HiFT's order (ignored by baselines).
    pub order: UpdateStrategy,
    pub seed: u64,
}

impl StrategySpec {
    pub fn new(name: &str, optim: OptimKind, lr: f32, total: usize) -> Self {
        StrategySpec {
            name: name.to_string(),
            optim,
            lr,
            warmup: 0,
            total,
            m: 1,
            order: UpdateStrategy::Bottom2Up,
            seed: 0,
        }
    }

    pub fn schedule(&self) -> LrSchedule {
        LrSchedule::Linear { lr: self.lr, warmup: self.warmup, total: self.total.max(1) * 2 }
    }

    /// Build the strategy. Names: `hift`, `fpft`, `lora`, `ia3`, `prefix`,
    /// `bitfit`, `lp`, `lomo`, `mezo`, `mezo-adam`.
    pub fn build(&self, manifest: &Manifest) -> Result<Box<dyn FineTuneStrategy>> {
        let ocfg = OptimCfg::new(self.optim);
        let sched = self.schedule();
        Ok(match self.name.as_str() {
            "hift" => Box::new(Hift::new(
                HiftCfg { m: self.m, order: self.order, schedule: sched, optim: ocfg },
                manifest,
            )?),
            "fpft" => Box::new(SubsetTune::fpft(manifest, ocfg, sched)?),
            "bitfit" => Box::new(SubsetTune::bitfit(manifest, ocfg, sched)?),
            "lora" => Box::new(SubsetTune::adapter(manifest, "lora", ocfg, sched)?),
            "ia3" => Box::new(SubsetTune::adapter(manifest, "ia3", ocfg, sched)?),
            "prefix" => Box::new(SubsetTune::adapter(manifest, "prefix", ocfg, sched)?),
            "lp" => Box::new(SubsetTune::linear_probe(manifest, ocfg, sched)?),
            "lomo" => Box::new(SubsetTune::lomo(manifest, sched)?),
            "mezo" => Box::new(Mezo::new(manifest, OptimCfg::new(OptimKind::Sgd), sched, self.seed)?),
            "mezo-adam" => {
                Box::new(Mezo::new(manifest, OptimCfg::new(OptimKind::AdamW), sched, self.seed)?)
            }
            other => anyhow::bail!("unknown strategy {other:?}"),
        })
    }
}

/// All buildable strategy names (bench sweeps iterate this).
pub const STRATEGY_NAMES: [&str; 10] =
    ["hift", "fpft", "lora", "ia3", "prefix", "bitfit", "lp", "lomo", "mezo", "mezo-adam"];

/// Map a grad artifact's gradient outputs to parameter indices in `variant`.
pub(crate) fn grad_param_indices(
    manifest: &Manifest,
    artifact: &str,
    variant: &str,
) -> Result<Vec<usize>> {
    let info = manifest.artifact(artifact)?;
    let vinfo = manifest.variant(variant)?;
    info.outputs[2..]
        .iter()
        .map(|name| {
            vinfo
                .params
                .iter()
                .position(|p| &p.name == name)
                .ok_or_else(|| anyhow::anyhow!("grad output {name} not a {variant} param"))
        })
        .collect()
}
