//! MeZO (Malladi et al., 2023): zeroth-order SPSA fine-tuning.
//!
//! Two forward passes per step, no gradients, no activation storage:
//!
//! ```text
//! z ~ N(0, I)   (regenerated from the step seed, never stored)
//! g̃ = [L(θ + εz) − L(θ − εz)] / 2ε        (a scalar)
//! θ ← θ − η · g̃ · z
//! ```
//!
//! The in-place ±ε walk and seed-regenerated `z` reproduce the paper's
//! memory story: parameter memory only.  `mezo-adam` feeds `g̃·z` into
//! AdamW instead of raw SGD (the MeZO-Adam row of Table 1).
//!
//! The quality gap the HiFT paper emphasizes (zeroth-order ≪ first-order,
//! Tables 1–2) emerges naturally — `bench_table1` reproduces the ordering.

use anyhow::Result;

use super::{FineTuneStrategy, StepStats};
use crate::backend::{Batch, ExecBackend, Manifest};
use crate::coordinator::lr::LrSchedule;
use crate::optim::{self, OptimCfg, OptimKind, Optimizer};
use crate::rng::Pcg32;
use crate::tensor::{Tensor, TensorSet};

pub struct Mezo {
    name: String,
    eps: f32,
    schedule: LrSchedule,
    step: u64,
    seed: u64,
    optimizer: Box<dyn Optimizer>,
    grad_clip: f32,
    n_params: usize,
    total_params: usize,
}

impl Mezo {
    pub fn new(manifest: &Manifest, ocfg: OptimCfg, schedule: LrSchedule, seed: u64) -> Result<Self> {
        let vinfo = manifest.variant("base")?;
        let name = match ocfg.kind {
            OptimKind::Sgd => "mezo".to_string(),
            k => format!("mezo-{}", k.name().to_ascii_lowercase()),
        };
        Ok(Mezo {
            name,
            eps: 1e-3,
            schedule,
            step: 0,
            seed,
            optimizer: optim::build(ocfg, vinfo.params.len()),
            grad_clip: 0.0, // SPSA pseudo-grads are already tiny; no clip
            n_params: vinfo.params.len(),
            total_params: vinfo.total_params(),
        })
    }

    /// Walk every parameter by `scale * z(step_seed)` in place, streaming
    /// `z` from the RNG (never materialized beyond one tensor's worth).
    fn perturb(&self, params: &mut TensorSet, step_seed: u64, scale: f32) {
        for i in 0..params.len() {
            let mut rng = Pcg32::new(step_seed, i as u64 + 1);
            let t = params.tensor_mut(i); // bump version: device cache must refresh
            for x in t.data.iter_mut() {
                *x += scale * rng.normal();
            }
        }
    }
}

impl FineTuneStrategy for Mezo {
    fn name(&self) -> &str {
        &self.name
    }

    fn variant(&self) -> &str {
        "base"
    }

    fn step(
        &mut self,
        be: &mut dyn ExecBackend,
        params: &mut TensorSet,
        batch: &Batch,
    ) -> Result<StepStats> {
        if be.offload().enabled {
            // MeZO's ±εz walks mutate every parameter *outside* the backend
            // walk; a paging tier that evicts masters between executions
            // would silently drop the perturbations.  Refuse loudly.
            anyhow::bail!(
                "MeZO mutates parameters outside the backend walk and cannot run \
                 with host offload ({}); use --offload none",
                be.offload().name()
            );
        }
        let lr = self.schedule.at(self.step as usize);
        let step_seed = self.seed ^ (0x9E37 + self.step).wrapping_mul(0x2545F4914F6CDD1D);
        self.step += 1;

        // L(θ + εz), L(θ − εz), restore — three in-place walks.
        self.perturb(params, step_seed, self.eps);
        let out_p = be.run("fwd_base", params, batch)?;
        self.perturb(params, step_seed, -2.0 * self.eps);
        let out_m = be.run("fwd_base", params, batch)?;
        self.perturb(params, step_seed, self.eps);

        let proj = (out_p.loss - out_m.loss) / (2.0 * self.eps);

        // θ ← optimizer(θ, g̃·z) with z regenerated per tensor.
        for i in 0..self.n_params {
            let mut rng = Pcg32::new(step_seed, i as u64 + 1);
            let t = params.tensor_mut(i);
            let mut g = Tensor::zeros(&t.shape);
            for x in g.data.iter_mut() {
                *x = proj * rng.normal();
            }
            if self.grad_clip > 0.0 {
                optim::clip_grad(&mut g, self.grad_clip);
            }
            self.optimizer.update(i, t, &g, lr);
        }

        Ok(StepStats {
            loss: 0.5 * (out_p.loss + out_m.loss),
            ncorrect: out_p.ncorrect,
            weight_sum: batch.weights.iter().sum(),
            lr,
            trainable_params: self.total_params,
            exec_time: out_p.exec_time + out_m.exec_time,
        })
    }

    fn peak_trainable_params(&self) -> usize {
        self.total_params
    }

    fn optimizer_state_bytes(&self) -> usize {
        self.optimizer.total_state_bytes()
    }

    fn fast_forward(&mut self, steps_done: u64) {
        // Perturbation seeds derive from the absolute step index, so a
        // resumed run regenerates the same z sequence.
        self.step = steps_done;
    }

    fn sweeps_done(&self) -> u64 {
        self.step
    }

    fn export_opt_state(&self) -> Vec<(String, Tensor)> {
        self.optimizer.export_state()
    }

    fn import_opt_state(&mut self, state: &[(String, Tensor)], params: &TensorSet) -> Result<()> {
        self.optimizer.import_state(state, params)
    }
}
