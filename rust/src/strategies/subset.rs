//! Fixed-subset baselines: one grad artifact, the same trainable set every
//! step.  Instantiations cover the paper's comparison grid:
//!
//! * **FPFT** — `grad_base_full`, AdamW/SGD/…: the standard full fine-tune.
//! * **BitFit** (Zaken et al., 2022) — biases + LN parameters only.
//! * **LoRA / IA3 / Prefix** — adapter parameters of the corresponding
//!   model variant only (base weights stay frozen *inputs*).
//! * **LP** — linear probe: the head unit only.
//! * **LOMO (sim)** — full gradients + stateless SGD fused into the
//!   backward walk (no optimizer state ever exists; only one tensor's
//!   gradient is live at a time — the analogue of Lv et al., 2023).
//!
//! All of these now run on the streamed seam: one
//! [`crate::backend::ExecBackend::run_streamed`] call per step with a
//! [`FusedApply`] sink, so the update of each tensor happens the moment
//! its gradient is emitted and no per-step `Vec<Tensor>` of gradients is
//! ever allocated.  Because optimizer updates are per-tensor, the final
//! parameters are bit-identical to the old collect-then-update loop.

use anyhow::Result;

use super::{grad_param_indices, FineTuneStrategy, StepStats};
use crate::backend::{Batch, ExecBackend, Manifest};
use crate::coordinator::lr::LrSchedule;
use crate::optim::{self, FusedApply, LossScaler, NonFinitePolicy, OptimCfg, OptimKind, Optimizer};
use crate::tensor::TensorSet;

/// A baseline that always trains the same parameter subset.
pub struct SubsetTune {
    name: String,
    variant: String,
    artifact: String,
    /// Parameter index (into the variant's param list) per grad output.
    param_idxs: Vec<usize>,
    optimizer: Box<dyn Optimizer>,
    grad_clip: f32,
    schedule: LrSchedule,
    step: u64,
    /// The subset's parameter-element count (known from the manifest at
    /// build time — the trainable *set* is fixed, so a step that skips a
    /// non-finite tensor's update still reports the full set).
    trainable: usize,
    /// Dynamic loss scaler, engaged lazily when the backend runs at f16.
    scaler: Option<LossScaler>,
}

impl SubsetTune {
    fn build(
        manifest: &Manifest,
        name: &str,
        variant: &str,
        artifact: &str,
        ocfg: OptimCfg,
        schedule: LrSchedule,
    ) -> Result<Self> {
        let param_idxs = grad_param_indices(manifest, artifact, variant)?;
        let vinfo = manifest.variant(variant)?;
        let n_params = vinfo.params.len();
        let trainable: usize = param_idxs.iter().map(|&i| vinfo.params[i].size).sum();
        Ok(SubsetTune {
            name: name.to_string(),
            variant: variant.to_string(),
            artifact: artifact.to_string(),
            param_idxs,
            optimizer: optim::build(ocfg, n_params),
            grad_clip: ocfg.grad_clip,
            schedule,
            step: 0,
            trainable,
            scaler: None,
        })
    }

    /// Standard full-parameter fine-tuning.
    pub fn fpft(m: &Manifest, o: OptimCfg, s: LrSchedule) -> Result<Self> {
        Self::build(m, &format!("fpft({})", o.kind.name()), "base", "grad_base_full", o, s)
    }

    /// BitFit: bias/LN subset.
    pub fn bitfit(m: &Manifest, o: OptimCfg, s: LrSchedule) -> Result<Self> {
        Self::build(m, "bitfit", "base", "grad_base_bitfit", o, s)
    }

    /// LoRA / IA3 / Prefix adapters.
    pub fn adapter(m: &Manifest, variant: &str, o: OptimCfg, s: LrSchedule) -> Result<Self> {
        Self::build(m, variant, variant, &format!("grad_{variant}_adapter"), o, s)
    }

    /// Linear probe: head unit only.
    pub fn linear_probe(m: &Manifest, o: OptimCfg, s: LrSchedule) -> Result<Self> {
        let head = m.n_units - 1;
        Self::build(m, "lp", "base", &format!("grad_base_u{head}"), o, s)
    }

    /// LOMO-style fused SGD (full grads, zero optimizer state).
    pub fn lomo(m: &Manifest, s: LrSchedule) -> Result<Self> {
        let o = OptimCfg::new(OptimKind::Sgd);
        Self::build(m, "lomo", "base", "grad_base_full", o, s)
    }

    pub fn artifact(&self) -> &str {
        &self.artifact
    }
}

impl FineTuneStrategy for SubsetTune {
    fn name(&self) -> &str {
        &self.name
    }

    fn variant(&self) -> &str {
        &self.variant
    }

    fn step(
        &mut self,
        be: &mut dyn ExecBackend,
        params: &mut TensorSet,
        batch: &Batch,
    ) -> Result<StepStats> {
        let lr = self.schedule.at(self.step as usize);
        self.step += 1;
        // f16 compute: lazy scaler + per-step scale install (see Hift).
        let scaling = LossScaler::prepare_step(&mut self.scaler, be);
        let (out, updated, nonfinite, skipped) = {
            let mut sink = FusedApply::new(
                &mut *self.optimizer,
                None,
                &self.param_idxs,
                self.grad_clip,
                lr,
            )
            .non_finite(if scaling {
                NonFinitePolicy::SkipStep
            } else {
                NonFinitePolicy::SkipTensor
            });
            let out = be.run_streamed(&self.artifact, params, batch, &mut sink)?;
            (out, sink.updated_elems, sink.nonfinite_grads, sink.step_skipped)
        };
        LossScaler::finish_step(&mut self.scaler, be, nonfinite, skipped);
        debug_assert!(
            skipped || nonfinite > 0 || updated == self.trainable,
            "healthy step updated {updated} of {} subset elements",
            self.trainable
        );
        Ok(StepStats {
            loss: out.loss,
            ncorrect: out.ncorrect,
            weight_sum: batch.weights.iter().sum(),
            lr,
            trainable_params: self.trainable,
            exec_time: out.exec_time,
        })
    }

    fn peak_trainable_params(&self) -> usize {
        self.trainable
    }

    fn optimizer_state_bytes(&self) -> usize {
        self.optimizer.total_state_bytes()
    }

    fn fast_forward(&mut self, steps_done: u64) {
        self.step = steps_done;
    }

    fn sweeps_done(&self) -> u64 {
        self.step
    }

    fn export_opt_state(&self) -> Vec<(String, crate::tensor::Tensor)> {
        self.optimizer.export_state()
    }

    fn import_opt_state(
        &mut self,
        state: &[(String, crate::tensor::Tensor)],
        params: &TensorSet,
    ) -> Result<()> {
        self.optimizer.import_state(state, params)
    }
}
