//! HiFT itself (Algorithm 1) as a [`FineTuneStrategy`].
//!
//! Per training step:
//!   a) all parameters are conceptually frozen;
//!   c/d) the scheduler pops the next group of `m` layer units from the
//!        rotating queue and requeues them at the tail;
//!   e/f/g) the group's per-unit gradient artifacts are the *only* ones
//!        executed — XLA never materializes any other gradient, which is
//!        the memory contribution;
//!   h) forward+backward run fused in the artifact;
//!   i) optimizer state for exactly this group is paged host→device
//!        (ledger-tracked — the #Sta communication column of Tables 8–12);
//!   g') parameters update in place; gradients are dropped immediately;
//!   k) state pages back device→host;
//!   LR advances only at sweep boundaries (delayed LR, §3.1).
//!
//! For `m > 1` all unit gradients of the group are computed *before* any
//! update, so the group updates jointly at the same parameter point —
//! matching Eq. (2)'s single argmin over the whole group mask βᵢ.

use anyhow::Result;

use super::{FineTuneStrategy, StepStats};
use crate::backend::{unit_artifact, Batch, ExecBackend, Manifest};
use crate::coordinator::lr::LrSchedule;
use crate::coordinator::scheduler::{HiftScheduler, SchedulerCfg};
use crate::coordinator::strategy::UpdateStrategy;
use crate::optim::{self, OffloadLedger, OptimCfg, Optimizer};
use crate::tensor::TensorSet;

/// HiFT hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct HiftCfg {
    /// Layers per group (paper's m; m=1 in most paper experiments).
    pub m: usize,
    /// Update order S.
    pub order: UpdateStrategy,
    /// Underlying LR schedule (advanced per sweep).
    pub schedule: LrSchedule,
    pub optim: OptimCfg,
}

/// The HiFT strategy state.
pub struct Hift {
    cfg: HiftCfg,
    scheduler: HiftScheduler,
    optimizer: Box<dyn Optimizer>,
    ledger: OffloadLedger,
    /// Parameter indices per layer unit.
    unit_params: Vec<Vec<usize>>,
    /// Per-unit parameter element counts.
    unit_sizes: Vec<usize>,
    peak_trainable: usize,
    name: String,
}

impl Hift {
    pub fn new(cfg: HiftCfg, manifest: &Manifest) -> Result<Self> {
        let vinfo = manifest.variant("base")?;
        let n_units = manifest.n_units;
        let unit_params: Vec<Vec<usize>> = (0..n_units).map(|u| vinfo.unit_indices(u)).collect();
        let unit_sizes: Vec<usize> = unit_params
            .iter()
            .map(|idxs| idxs.iter().map(|&i| vinfo.params[i].size).sum())
            .collect();
        let scheduler = HiftScheduler::new(
            SchedulerCfg { m: cfg.m, strategy: cfg.order, schedule: cfg.schedule },
            n_units,
        );
        let optimizer = optim::build(cfg.optim, vinfo.params.len());
        let name = format!("hift(m={},{},{})", cfg.m, cfg.order.name(), cfg.optim.kind.name());
        Ok(Hift {
            cfg,
            scheduler,
            optimizer,
            ledger: OffloadLedger::new(),
            unit_params,
            unit_sizes,
            peak_trainable: 0,
            name,
        })
    }

    /// Steps per sweep (k).
    pub fn k(&self) -> usize {
        self.scheduler.k()
    }

    pub fn scheduler(&self) -> &HiftScheduler {
        &self.scheduler
    }
}

impl FineTuneStrategy for Hift {
    fn name(&self) -> &str {
        &self.name
    }

    fn variant(&self) -> &str {
        "base"
    }

    fn step(
        &mut self,
        be: &mut dyn ExecBackend,
        params: &mut TensorSet,
        batch: &Batch,
    ) -> Result<StepStats> {
        let plan = self.scheduler.next();

        // Phase 1 — gradients for every unit in the group, at the *current*
        // parameter point (no update interleaving).
        let mut exec_time = std::time::Duration::ZERO;
        let mut loss = 0.0f32;
        let mut ncorrect = 0.0f32;
        let mut grads: Vec<(usize, crate::tensor::Tensor)> = Vec::new();
        for (gi, &u) in plan.units.iter().enumerate() {
            let out = be.run(&unit_artifact(u), params, batch)?;
            exec_time += out.exec_time;
            if gi == 0 {
                loss = out.loss;
                ncorrect = out.ncorrect;
            }
            for (slot, g) in self.unit_params[u].iter().zip(out.grads) {
                grads.push((*slot, g));
            }
        }

        // Phase 2 — page in exactly this group's optimizer state, update,
        // page out (Algorithm 1 steps i, g', k).
        let mut trainable = 0usize;
        for (idx, mut g) in grads {
            optim::clip_grad(&mut g, self.cfg.optim.grad_clip);
            let pre = self.optimizer.state_bytes(idx) as u64;
            self.ledger.page_in(pre);
            let p = params.tensor_mut(idx);
            trainable += p.numel();
            self.optimizer.update(idx, p, &g, plan.lr);
            let post = self.optimizer.state_bytes(idx) as u64;
            self.ledger.alloc_on_device(post.saturating_sub(pre));
            self.ledger.page_out(post);
            // gradient dropped here — "Clear gradients" (step g)
        }
        self.peak_trainable = self.peak_trainable.max(trainable);
        debug_assert_eq!(
            trainable,
            plan.units.iter().map(|&u| self.unit_sizes[u]).sum::<usize>()
        );

        let weight_sum: f32 = batch.weights.iter().sum();
        Ok(StepStats {
            loss,
            ncorrect,
            weight_sum,
            lr: plan.lr,
            trainable_params: trainable,
            exec_time,
        })
    }

    fn peak_trainable_params(&self) -> usize {
        self.peak_trainable
    }

    fn ledger(&self) -> Option<&OffloadLedger> {
        Some(&self.ledger)
    }

    fn optimizer_state_bytes(&self) -> usize {
        self.optimizer.total_state_bytes()
    }
}
