//! HiFT itself (Algorithm 1) as a [`FineTuneStrategy`], on the streamed
//! gradient seam.
//!
//! Per training step:
//!   a) all parameters are conceptually frozen;
//!   c/d) the scheduler pops the next group of `m` layer units from the
//!        rotating queue and requeues them at the tail;
//!   e/f/g) only the group's gradients are ever formed — the backend runs
//!        **one** forward + one multi-unit truncated backward
//!        ([`crate::backend::ExecBackend::run_group_streamed`]), so XLA /
//!        the native walk never materializes any other gradient, which is
//!        the memory contribution;
//!   h/i/g'/k) backward and optimizer fuse: each unit tensor's gradient is
//!        streamed into a [`FusedApply`] sink that clips, pages exactly
//!        that tensor's optimizer state host→device (ledger-tracked — the
//!        #Sta communication column of Tables 8–12), updates in place,
//!        pages back out and drops the gradient immediately.  Peak
//!        gradient residency is one tensor, not the group sum;
//!   LR advances only at sweep boundaries (delayed LR, §3.1).
//!
//! For `m > 1` all unit gradients are still taken at the *same* parameter
//! point — they come from a single backward pass whose activations were
//! cached before any update, and the walk never re-reads a tensor after
//! emitting its gradient — so the group updates jointly, matching
//! Eq. (2)'s single argmin over the whole group mask βᵢ, bit-identically
//! to the old collect-then-update path (asserted in `tests/streaming.rs`).
//!
//! Set `HIFT_PIPELINE=1` (or build via [`Hift::pipelined`]) to double-
//! buffer the fusion: gradient *i*'s optimizer update runs concurrently
//! with the backward chunk producing gradient *i+1*
//! ([`crate::optim::PipelinedApply`]; fixed order, bit-identical results).

use anyhow::Result;

use super::{FineTuneStrategy, StepStats};
use crate::backend::{Batch, ExecBackend, Manifest};
use crate::coordinator::lr::LrSchedule;
use crate::coordinator::scheduler::{HiftScheduler, SchedulerCfg};
use crate::coordinator::strategy::UpdateStrategy;
use crate::optim::{
    self, FusedApply, LossScaler, NonFinitePolicy, OffloadLedger, OptimCfg, Optimizer,
    PipelinedApply,
};
use crate::tensor::TensorSet;

/// HiFT hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct HiftCfg {
    /// Layers per group (paper's m; m=1 in most paper experiments).
    pub m: usize,
    /// Update order S.
    pub order: UpdateStrategy,
    /// Underlying LR schedule (advanced per sweep).
    pub schedule: LrSchedule,
    pub optim: OptimCfg,
}

/// The HiFT strategy state.
pub struct Hift {
    cfg: HiftCfg,
    scheduler: HiftScheduler,
    /// `None` only while a pipelined step has the optimizer checked out
    /// into the update worker.
    optimizer: Option<Box<dyn Optimizer>>,
    ledger: OffloadLedger,
    /// Parameter indices per layer unit.
    unit_params: Vec<Vec<usize>>,
    /// Per-unit parameter element counts.
    unit_sizes: Vec<usize>,
    peak_trainable: usize,
    pipeline: bool,
    /// Dynamic loss scaler, engaged lazily when the backend runs at f16
    /// ([`crate::backend::Precision::needs_loss_scaling`]); `None` under
    /// f32/bf16 compute.
    scaler: Option<LossScaler>,
    name: String,
}

impl Hift {
    /// Build with the double-buffered update pipeline taken from the
    /// `HIFT_PIPELINE` env var (`1` = on).
    pub fn new(cfg: HiftCfg, manifest: &Manifest) -> Result<Self> {
        let pipeline = std::env::var("HIFT_PIPELINE").map(|v| v == "1").unwrap_or(false);
        Self::pipelined(cfg, manifest, pipeline)
    }

    /// Build with the update pipeline explicitly on or off.
    pub fn pipelined(cfg: HiftCfg, manifest: &Manifest, pipeline: bool) -> Result<Self> {
        let vinfo = manifest.variant("base")?;
        let n_units = manifest.n_units;
        let unit_params: Vec<Vec<usize>> = (0..n_units).map(|u| vinfo.unit_indices(u)).collect();
        let unit_sizes: Vec<usize> = unit_params
            .iter()
            .map(|idxs| idxs.iter().map(|&i| vinfo.params[i].size).sum())
            .collect();
        let scheduler = HiftScheduler::new(
            SchedulerCfg { m: cfg.m, strategy: cfg.order, schedule: cfg.schedule },
            n_units,
        );
        let optimizer = optim::build(cfg.optim, vinfo.params.len());
        let name = format!("hift(m={},{},{})", cfg.m, cfg.order.name(), cfg.optim.kind.name());
        Ok(Hift {
            cfg,
            scheduler,
            optimizer: Some(optimizer),
            ledger: OffloadLedger::new(),
            unit_params,
            unit_sizes,
            peak_trainable: 0,
            pipeline,
            scaler: None,
            name,
        })
    }

    /// Steps per sweep (k).
    pub fn k(&self) -> usize {
        self.scheduler.k()
    }

    pub fn scheduler(&self) -> &HiftScheduler {
        &self.scheduler
    }
}

impl FineTuneStrategy for Hift {
    fn name(&self) -> &str {
        &self.name
    }

    fn variant(&self) -> &str {
        "base"
    }

    fn step(
        &mut self,
        be: &mut dyn ExecBackend,
        params: &mut TensorSet,
        batch: &Batch,
    ) -> Result<StepStats> {
        let plan = self.scheduler.next();
        // Stage the *next* group before this step's compute starts — the
        // scheduler's queue already knows it.  The paging tier posts its
        // page-ins (decompression overlaps this step's compute) and keeps
        // the staged units resident across the end-of-run eviction, so the
        // next step begins with its active group already in the arena:
        // cross-step double-buffering.  No-op when the backend has no
        // paging tier; coalesced with the walk's own one-unit-ahead
        // prefetch (no duplicate transfers).
        be.prefetch_units(&self.scheduler.peek_next());
        // f16 compute: engage the dynamic loss scaler lazily (the backend's
        // precision is only known here) and install this step's scale
        // before the run seeds its backward.
        let scaling = LossScaler::prepare_step(&mut self.scaler, be);
        // Gradient slot order = concatenation of the group's unit parameter
        // lists — the contract of `run_group_streamed`.
        let slot_param: Vec<usize> =
            plan.units.iter().flat_map(|&u| self.unit_params[u].iter().copied()).collect();
        let planned: usize = plan.units.iter().map(|&u| self.unit_sizes[u]).sum();

        // The pipelined sink cannot drop a step atomically (its worker
        // applies updates as they stream), so loss-scaled f16 runs fall
        // back to the serial fused sink in skip-step mode.
        let (out, trainable, nonfinite, skipped) = if self.pipeline && !scaling {
            let Some(opt) = self.optimizer.take() else {
                anyhow::bail!("HiFT optimizer was lost by a previous failed pipelined step");
            };
            let mut sink = PipelinedApply::new(
                opt,
                Some(&mut self.ledger),
                slot_param,
                self.cfg.optim.grad_clip,
                plan.lr,
            );
            let run = be.run_group_streamed(&plan.units, params, batch, &mut sink);
            let trainable = sink.updated_elems;
            let nonfinite = sink.nonfinite_grads;
            match run {
                Ok(out) => {
                    self.optimizer = Some(sink.into_optimizer()?);
                    (out, trainable, nonfinite, false)
                }
                Err(e) => {
                    // Best-effort recovery: drain the worker, restore any
                    // checked-out tensor into `params`, and put the
                    // optimizer back so the strategy stays usable.
                    let _ = sink.finish(params);
                    if let Ok(opt) = sink.into_optimizer() {
                        self.optimizer = Some(opt);
                    }
                    return Err(e);
                }
            }
        } else {
            let Some(opt) = self.optimizer.as_mut() else {
                anyhow::bail!("HiFT optimizer was lost by a previous failed pipelined step");
            };
            let mut sink = FusedApply::new(
                &mut **opt,
                Some(&mut self.ledger),
                &slot_param,
                self.cfg.optim.grad_clip,
                plan.lr,
            )
            .non_finite(if scaling {
                NonFinitePolicy::SkipStep
            } else {
                NonFinitePolicy::SkipTensor
            });
            let out = be.run_group_streamed(&plan.units, params, batch, &mut sink)?;
            (out, sink.updated_elems, sink.nonfinite_grads, sink.step_skipped)
        };
        LossScaler::finish_step(&mut self.scaler, be, nonfinite, skipped);
        self.peak_trainable = self.peak_trainable.max(planned);
        debug_assert!(
            skipped || nonfinite > 0 || trainable == planned,
            "healthy step updated {trainable} of {planned} planned elements"
        );

        let weight_sum: f32 = batch.weights.iter().sum();
        Ok(StepStats {
            loss: out.loss,
            ncorrect: out.ncorrect,
            weight_sum,
            lr: plan.lr,
            // The step's trainable *set* (the paper's axis) — on a scaler
            // skip-step the set was planned even though no element moved.
            trainable_params: planned,
            exec_time: out.exec_time,
        })
    }

    fn peak_trainable_params(&self) -> usize {
        self.peak_trainable
    }

    fn ledger(&self) -> Option<&OffloadLedger> {
        Some(&self.ledger)
    }

    fn optimizer_state_bytes(&self) -> usize {
        self.optimizer.as_ref().map(|o| o.total_state_bytes()).unwrap_or(0)
    }

    fn fast_forward(&mut self, steps_done: u64) {
        self.scheduler.fast_forward(steps_done);
    }

    fn sweeps_done(&self) -> u64 {
        self.scheduler.sweep() as u64
    }

    fn export_opt_state(&self) -> Vec<(String, crate::tensor::Tensor)> {
        self.optimizer.as_ref().map(|o| o.export_state()).unwrap_or_default()
    }

    fn import_opt_state(
        &mut self,
        state: &[(String, crate::tensor::Tensor)],
        params: &TensorSet,
    ) -> Result<()> {
        match self.optimizer.as_mut() {
            Some(o) => o.import_state(state, params),
            None => anyhow::bail!("HiFT optimizer is checked out by a pipelined step"),
        }
    }
}
