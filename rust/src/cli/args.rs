//! Tiny `--flag value` / `--flag=value` argument parser.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Parsed flags + positional arguments.
#[derive(Debug, Default)]
pub struct Args {
    flags: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `--key value`, `--key=value`, and bare positionals.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    // boolean flag
                    out.flags.insert(stripped.to_string(), "1".to_string());
                }
            } else if a.starts_with('-') && a.len() > 1 {
                bail!("short flags not supported: {a}");
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_num(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&["--steps", "200", "--lr=0.001", "table1"]);
        assert_eq!(a.get("steps"), Some("200"));
        assert_eq!(a.get_num("lr"), Some(0.001));
        assert_eq!(a.positional, vec!["table1"]);
    }

    #[test]
    fn boolean_flags() {
        let a = parse(&["--quick", "--out", "x.json"]);
        assert!(a.has("quick"));
        assert_eq!(a.get("out"), Some("x.json"));
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse(&["--min-lr", "-0.5"]);
        // "-0.5" starts with '-' so it's treated as the next token only if
        // it doesn't match "--"; our parser treats it as a value.
        assert_eq!(a.get_num("min-lr"), Some(-0.5));
    }

    #[test]
    fn missing_keys_are_none() {
        let a = parse(&[]);
        assert_eq!(a.get("x"), None);
        assert_eq!(a.get_num("x"), None);
    }
}
