//! The `hift` command-line launcher (hand-rolled parsing — no clap in the
//! offline vendor set).
//!
//! By default every command runs on the **native CPU backend** (no
//! artifacts, no Python): `--preset tiny|small|base|e2e|e2e100m` picks the
//! geometry.  Passing `--artifacts DIR` selects the PJRT engine instead
//! (requires building with `--features pjrt`).
//!
//! ```text
//! hift train  [--preset tiny | --artifacts DIR] --strategy hift --task motif4
//!             [--steps 200] [--optim adamw] [--lr 4e-3] [--warmup 0] [--m 1]
//!             [--order b2u] [--seed 0] [--eval-every 50] [--log-every 10]
//!             [--out runs/run.json] [--act-ckpt none|sqrt|every_k(K)]
//!             [--precision f32|bf16|f16] [--kernels naive|blocked|simd]
//!             [--offload host|none] [--offload-compress none|f16] [--prefetch 1|0]
//!             [--workers N] [--save-ckpt DIR] [--save-every N] [--resume DIR]
//! hift eval   [--preset tiny | --artifacts DIR] [--variant base] --task motif4
//!             [--seed 0] [--precision f32|bf16|f16] [--kernels naive|blocked|simd]
//!             [--offload host|none] [--workers N]
//! hift memory-report [--model llama-7b] [--batch 8] [--seq 512] [--m 1]
//!             [--precision f32|bf16|f16]
//! hift info   [--preset tiny | --artifacts DIR] [--seed 0]
//! hift bench  <table1|table2|table3|table4|table5|mtbench|fig3|fig4|fig5|fig6
//!              |tables8_12|appendix_b|act_ckpt|offload|precision|kernels|parallel
//!              |evalmatrix|all>
//!             [--preset P] [--artifacts DIR] [--act-ckpt P] [--precision P]
//!             [--kernels K] [--offload host] [--workers N]
//! hift evalmatrix [--preset P] [--artifacts DIR] [--precision P] [--kernels K]
//!             [--workers N]   (alias for `hift bench evalmatrix`)
//! hift plancheck [--preset tiny] [--steps N] [--out runs/plancheck.json]
//!             [--inject none|drop-evict|evict-pinned|prefetch-pinned
//!              |swap-emits|hoard-grads]
//! ```
//!
//! `docs/CLI.md` documents every flag and `HIFT_*` environment variable;
//! `hift help` prints the same inventory.
//!
//! Checkpoint/resume: `--save-ckpt DIR --save-every N` writes a crash-safe
//! checkpoint (params + optimizer moments + step/sweep counters) every N
//! steps; `--resume DIR` continues a killed run **bit-identically** — same
//! batches, same sweep-aligned delayed-LR position, same optimizer state.
//!
//! Host paging: `--offload host` physically moves inactive groups'
//! parameter masters to a host pool and pages them back on demand
//! (optimizer state stays in the optimizer and is ledger-accounted per
//! fused update, not pooled); `--offload-compress f16` stores the masters
//! lossy at half size; `--prefetch 0` disables the async double buffer
//! (synchronous paging — the `bench offload` baseline).  Lossless paged
//! runs are bit-identical to resident runs.

mod args;

pub use args::Args;

use anyhow::{bail, Context, Result};

use crate::backend::{build_backend, ActCkpt, ExecBackend, KernelKind, OffloadCfg, Precision};
use crate::bench::{exhibits, Bench};
use crate::coordinator::strategy::UpdateStrategy;
use crate::coordinator::trainer::{self, CkptOpts, TrainCfg};
use crate::data::{build_task, TaskGeom, TASK_NAMES};
use crate::memmodel::{account, account_prec, by_name, Dtype, Method, Workload, GIB, MIB};
use crate::optim::OptimKind;
use crate::ser::emit_pretty;
use crate::strategies::{StrategySpec, STRATEGY_NAMES};
use crate::tensor::checkpoint;

const USAGE: &str = "usage: hift <train|eval|memory-report|info|bench|evalmatrix|plancheck> [flags]
  backends: --preset tiny|small|base|e2e|e2e100m (native CPU, default)
            --artifacts DIR (PJRT; needs the `pjrt` cargo feature)

  train  --strategy hift|fpft|lora|ia3|prefix|bitfit|lp|lomo|mezo|mezo-adam
         --task TASK --steps N --optim adamw|sgd|sgdm|adagrad|adafactor
         --lr F --warmup N --m M --order b2u|t2d|ran --seed N
         --eval-every N --log-every N --out FILE.json
         --act-ckpt none|sqrt|every_k(K) --precision f32|bf16|f16
         --kernels naive|blocked|simd
         --offload host|none --offload-compress none|f16 --prefetch 1|0
         --workers N --save-ckpt DIR --save-every N --resume DIR
  eval   --variant base|lora|ia3|prefix --task TASK --seed N
         --precision f32|bf16|f16 --kernels naive|blocked|simd
         --offload host|none --workers N
  memory-report --model NAME --batch N --seq N --m M --precision f32|bf16|f16
  info   (prints manifest, variants, artifacts, strategies, tasks)
  bench  table1|table2|table3|table4|table5|mtbench|fig3|fig4|fig5|fig6
         |tables8_12|appendix_b|act_ckpt|offload|precision|kernels|parallel
         |evalmatrix|all
         (flags --preset/--artifacts/--act-ckpt/--precision/--kernels/
          --offload*/--workers set the HIFT_* env)
  evalmatrix  every strategy x every task family on the current preset;
         writes the runs/evalmatrix.json scoreboard (alias for
         `hift bench evalmatrix`; same flags as bench)
  plancheck  statically verify the full config lattice (strategy x m x
         act-ckpt x offload x prefetch x precision x workers): derive
         every step plan symbolically and prove the residency/ordering
         invariants; writes the runs/plancheck.json proof artifact
         (--steps N overrides the 2-sweep default; --inject KIND seeds a
         deliberate violation the verifier must catch)

  env: HIFT_PRESET HIFT_ARTIFACTS HIFT_SEED HIFT_ACT_CKPT HIFT_PRECISION
       HIFT_KERNELS HIFT_OFFLOAD HIFT_OFFLOAD_COMPRESS HIFT_PREFETCH
       HIFT_WORKERS HIFT_PIPELINE HIFT_THREADS HIFT_QUICK HIFT_OUT
       (full inventory: docs/CLI.md)";

/// Binary entrypoint.
pub fn main_entry() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "memory-report" => cmd_memory_report(&args),
        "info" => cmd_info(&args),
        "bench" => cmd_bench(&args),
        "evalmatrix" => cmd_evalmatrix(&args),
        "plancheck" => cmd_plancheck(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn geom(be: &dyn ExecBackend) -> TaskGeom {
    let c = &be.manifest().config;
    TaskGeom::new(c.vocab, c.batch, c.seq_len)
}

fn backend_from(a: &Args, seed: u64) -> Result<Box<dyn ExecBackend>> {
    build_backend(a.get("artifacts"), a.get("preset"), seed)
}

/// Offload config: env (`HIFT_OFFLOAD*`, `HIFT_PREFETCH`) overridden by the
/// `--offload` / `--offload-compress` / `--prefetch` flags.
fn offload_from(a: &Args) -> Result<OffloadCfg> {
    OffloadCfg::from_env()?.with_flags(
        a.get("offload"),
        a.get("offload-compress"),
        a.get("prefetch"),
    )
}

/// Count flags where `0` is always a configuration error, never a value:
/// `--workers 0` would mean no execution, `--m 0` an empty group, and
/// `--save-every 0` used to be silently conflated with "flag unset".
/// Rejected here — before any backend is built — with the flag named, and
/// non-numeric values rejected too instead of being dropped on the floor.
fn reject_zero_count(a: &Args, flag: &str, why: &str) -> Result<()> {
    let Some(raw) = a.get(flag) else { return Ok(()) };
    let n = a
        .get_num(flag)
        .with_context(|| format!("--{flag} wants a number, got {raw:?}"))?;
    if n < 1.0 {
        bail!("--{flag} must be >= 1 ({why}); got {raw}");
    }
    Ok(())
}

fn cmd_train(a: &Args) -> Result<()> {
    reject_zero_count(a, "workers", "1 = the plain serial walk")?;
    reject_zero_count(a, "m", "one unit per step is the finest schedule")?;
    reject_zero_count(a, "save-every", "omit the flag to disable periodic checkpoints")?;
    let strategy_name = a.get("strategy").unwrap_or("hift");
    let task_name = a.get("task").unwrap_or("motif4");
    let steps: u64 = a.get_num("steps").unwrap_or(200.0) as u64;
    let seed: u64 = a.get_num("seed").unwrap_or(0.0) as u64;

    let mut be = backend_from(a, seed)?;
    if let Some(p) = a.get("act-ckpt") {
        be.set_act_ckpt(ActCkpt::parse(p)?)?;
    }
    if let Some(p) = a.get("precision") {
        be.set_precision(Precision::parse(p)?)?;
    }
    if let Some(p) = a.get("kernels") {
        be.set_kernels(KernelKind::parse(p)?)?;
    }
    let offload = offload_from(a)?;
    if offload.enabled {
        if strategy_name.starts_with("mezo") {
            // Fail fast (Mezo::step also guards): MeZO perturbs parameters
            // outside the backend walk, which a paging tier cannot see.
            bail!(
                "--strategy {strategy_name} cannot run with --offload host: MeZO mutates \
                 parameters outside the backend walk; use --offload none"
            );
        }
        be.set_offload(offload)?;
    }
    if let Some(w) = a.get_num("workers") {
        be.set_workers(w as usize)?;
    }
    let optim = OptimKind::parse(a.get("optim").unwrap_or("adamw"))
        .context("bad --optim (adamw|sgd|sgdm|adagrad|adafactor)")?;
    let mut spec = StrategySpec::new(strategy_name, optim, a.get_num("lr").unwrap_or(4e-3) as f32,
                                     steps as usize);
    spec.m = a.get_num("m").unwrap_or(1.0) as usize;
    spec.order = UpdateStrategy::parse(a.get("order").unwrap_or("b2u"), seed)
        .context("bad --order (b2u|t2d|ran)")?;
    spec.warmup = a.get_num("warmup").unwrap_or(0.0) as usize;
    spec.seed = seed;

    let mut strategy = spec.build(be.manifest())?;
    let mut params = be.load_params(strategy.variant())?;
    let mut task = build_task(task_name, geom(be.as_ref()), seed)?;

    let mut ckpt_opts = CkptOpts {
        save_dir: a.get("save-ckpt").map(std::path::PathBuf::from),
        save_every: a.get_num("save-every").unwrap_or(0.0) as u64,
        ..Default::default()
    };
    if let Some(dir) = a.get("resume") {
        let ck = checkpoint::load(dir).with_context(|| format!("loading checkpoint {dir}"))?;
        // A precision switch mid-run would silently change the loss
        // surface, the drift profile and the scaler state — reject it.
        Precision::check_resume(ck.meta.precision.as_deref(), be.precision())
            .with_context(|| format!("resuming checkpoint {dir}"))?;
        if ck.meta.strategy != strategy.name() {
            bail!(
                "checkpoint {dir} was written by strategy {:?} but this run is configured as \
                 {:?}; resuming would desync the sweep-aligned LR schedule",
                ck.meta.strategy,
                strategy.name()
            );
        }
        if ck.meta.task != task.name() {
            bail!("checkpoint task {:?} != requested task {:?}", ck.meta.task, task.name());
        }
        if ck.params.names != params.names {
            bail!(
                "checkpoint parameter inventory ({} tensors) does not match the {:?} variant \
                 ({} tensors)",
                ck.params.names.len(),
                strategy.variant(),
                params.names.len()
            );
        }
        for (i, t) in ck.params.tensors.iter().enumerate() {
            if t.shape != params.tensors[i].shape {
                bail!(
                    "checkpoint tensor {:?} has shape {:?}, expected {:?} — wrong preset?",
                    ck.params.names[i],
                    t.shape,
                    params.tensors[i].shape
                );
            }
        }
        strategy.import_opt_state(&ck.opt_state, &params)?;
        ckpt_opts.start_step = ck.meta.step;
        // Schema-1 checkpoints carry no sweep index: skip the cross-check
        // rather than falsely rejecting them as "configuration changed".
        ckpt_opts.expect_sweep = ck.meta.sweep;
        params = ck.params;
        eprintln!("resuming from {dir}: step {} (sweep {:?})", ck.meta.step, ck.meta.sweep);
    }

    eprintln!(
        "training {} on {} for {steps} steps ({} params, platform {})",
        strategy.name(),
        task.name(),
        params.total_params(),
        be.platform()
    );
    let rec = trainer::train_ckpt(
        be.as_mut(),
        strategy.as_mut(),
        &mut params,
        task.as_mut(),
        TrainCfg {
            steps,
            eval_every: a.get_num("eval-every").unwrap_or(0.0) as u64,
            log_every: a.get_num("log-every").unwrap_or(10.0) as u64,
        },
        &ckpt_opts,
    )?;
    println!("{}", emit_pretty(&rec.to_json()));
    if let Some(out) = a.get("out") {
        if let Some(dir) = std::path::Path::new(out).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(out, emit_pretty(&rec.to_json()))?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn cmd_eval(a: &Args) -> Result<()> {
    reject_zero_count(a, "workers", "1 = the plain serial walk")?;
    let variant = a.get("variant").unwrap_or("base");
    let task_name = a.get("task").unwrap_or("motif4");
    let seed = a.get_num("seed").unwrap_or(0.0) as u64;
    let mut be = backend_from(a, seed)?;
    if let Some(p) = a.get("precision") {
        be.set_precision(Precision::parse(p)?)?;
    }
    if let Some(p) = a.get("kernels") {
        be.set_kernels(KernelKind::parse(p)?)?;
    }
    let offload = offload_from(a)?;
    if offload.enabled {
        be.set_offload(offload)?;
    }
    if let Some(w) = a.get_num("workers") {
        be.set_workers(w as usize)?;
    }
    let mut params = be.load_params(variant)?;
    let task = build_task(task_name, geom(be.as_ref()), seed)?;
    let ev = trainer::evaluate(
        be.as_mut(),
        &format!("fwd_{variant}"),
        &mut params,
        task.eval_batches(),
    )?;
    println!("task={task_name} variant={variant} acc={:.4} loss={:.4}", ev.acc, ev.loss);
    Ok(())
}

fn cmd_memory_report(a: &Args) -> Result<()> {
    let w = Workload {
        batch: a.get_num("batch").unwrap_or(8.0) as usize,
        seq: a.get_num("seq").unwrap_or(512.0) as usize,
    };
    let m = a.get_num("m").unwrap_or(1.0) as usize;
    // Compute precision column: with --precision bf16|f16 the table gains
    // Res/Tot columns at the halved activation term (the compute-precision
    // analogue of the paper's mixed-precision residual discussion).
    let prec = Precision::parse(a.get("precision").unwrap_or("f32"))?;
    let models: Vec<String> = match a.get("model") {
        Some(one) => vec![one.to_string()],
        None => crate::memmodel::zoo().iter().map(|z| z.name.clone()).collect(),
    };
    for name in models {
        let arch = by_name(&name).with_context(|| format!("unknown model {name}"))?;
        println!(
            "\n{name}: {:.2}M params, {} units, peak group (m={m}) {:.2}M ({:.2}%)",
            arch.total_params() as f64 / 1e6,
            arch.n_units(),
            arch.peak_group_params(m) as f64 / 1e6,
            arch.peak_group_params(m) as f64 / arch.total_params() as f64 * 100.0,
        );
        let mut header = format!(
            "  {:<10} {:<8} {:<5} {:>10} {:>10} {:>12} {:>10} {:>9} {:>9} {:>9}",
            "optim", "dtype", "ftype", "#Para(MiB)", "#Gra(MiB)", "#GraStr(MiB)", "#Sta(MiB)",
            "PGS(GiB)", "Res(GiB)", "Tot(GiB)"
        );
        if prec != Precision::F32 {
            header.push_str(&format!(
                " {:>12} {:>12}",
                format!("Res@{}(GiB)", prec.name()),
                format!("Tot@{}(GiB)", prec.name())
            ));
        }
        println!("{header}");
        for opt in OptimKind::ALL {
            for (dt, meth) in [
                (Dtype::Fp32, Method::Fpft),
                (Dtype::Fp32, Method::Hift { m }),
                (Dtype::Mixed, Method::Fpft),
                (Dtype::Mixed, Method::Hift { m }),
                (Dtype::MixedHi, Method::Hift { m }),
            ] {
                let r = account(&arch, opt, dt, meth, w);
                let f = match meth {
                    Method::Fpft => "FPFT",
                    _ => "HiFT",
                };
                let mut line = format!(
                    "  {:<10} {:<8} {:<5} {:>10.2} {:>10.2} {:>12.2} {:>10.2} {:>9.2} {:>9.2} {:>9.2}",
                    opt.name(),
                    dt.name(),
                    f,
                    r.para / MIB,
                    r.gra / MIB,
                    r.gra_streamed / MIB,
                    r.sta / MIB,
                    r.pgs / GIB,
                    r.residual / GIB,
                    r.total / GIB
                );
                if prec != Precision::F32 {
                    let rp = account_prec(&arch, opt, dt, meth, w, ActCkpt::None, prec);
                    line.push_str(&format!(
                        " {:>12.2} {:>12.2}",
                        rp.residual / GIB,
                        rp.total / GIB
                    ));
                }
                println!("{line}");
            }
        }
    }
    Ok(())
}

fn cmd_info(a: &Args) -> Result<()> {
    let be = backend_from(a, a.get_num("seed").unwrap_or(0.0) as u64)?;
    let m = be.manifest();
    println!("backend:  {} ({})", be.name(), be.platform());
    println!("preset:   {} (kernels={}, seed={})", m.preset, m.kernels, m.seed);
    let c = &m.config;
    println!(
        "model:    vocab={} d={} L={} H={} ff={} seq={} batch={} ({} units)",
        c.vocab, c.d_model, c.n_layers, c.n_heads, c.d_ff, c.seq_len, c.batch, m.n_units
    );
    for (name, v) in m.variants.iter() {
        println!("variant {name}: {} tensors, {:.3}M params", v.params.len(),
                 v.total_params() as f64 / 1e6);
    }
    println!("artifacts ({}):", m.artifacts.len());
    for art in &m.artifacts {
        println!("  {:<24} {} inputs -> {} outputs", art.name, art.inputs.len(), art.outputs.len());
    }
    println!("strategies: {STRATEGY_NAMES:?}");
    println!("tasks:      {TASK_NAMES:?}");
    Ok(())
}

/// Forward the bench-relevant flags into the `HIFT_*` env, which
/// [`Bench::from_env`] (and the backend it builds) reads.
fn bench_env_from_flags(a: &Args) {
    if let Some(dir) = a.get("artifacts") {
        std::env::set_var("HIFT_ARTIFACTS", dir);
    }
    if let Some(preset) = a.get("preset") {
        std::env::set_var("HIFT_PRESET", preset);
        if a.get("artifacts").is_none() {
            // An explicit --preset means the native backend: don't let an
            // inherited HIFT_ARTIFACTS silently override it.
            std::env::remove_var("HIFT_ARTIFACTS");
        }
    }
    if let Some(p) = a.get("act-ckpt") {
        std::env::set_var("HIFT_ACT_CKPT", p);
    }
    if let Some(p) = a.get("precision") {
        std::env::set_var("HIFT_PRECISION", p);
    }
    if let Some(p) = a.get("kernels") {
        std::env::set_var("HIFT_KERNELS", p);
    }
    if let Some(p) = a.get("offload") {
        std::env::set_var("HIFT_OFFLOAD", p);
    }
    if let Some(p) = a.get("offload-compress") {
        std::env::set_var("HIFT_OFFLOAD_COMPRESS", p);
    }
    if let Some(p) = a.get("prefetch") {
        std::env::set_var("HIFT_PREFETCH", p);
    }
    if let Some(p) = a.get("workers") {
        std::env::set_var("HIFT_WORKERS", p);
    }
}

/// `hift evalmatrix` — the strategy × task-family scoreboard, promoted to a
/// top-level command (alias for `hift bench evalmatrix`).
fn cmd_evalmatrix(a: &Args) -> Result<()> {
    bench_env_from_flags(a);
    let mut b = Bench::from_env()?;
    exhibits::evalmatrix(&mut b)
}

fn cmd_bench(a: &Args) -> Result<()> {
    let which = a.positional.first().map(String::as_str).unwrap_or("all");
    bench_env_from_flags(a);
    let mut b = Bench::from_env()?;
    let run = |b: &mut Bench, name: &str| -> Result<()> {
        match name {
            "table1" => exhibits::table1(b),
            "table2" => exhibits::table2(b),
            "table3" => exhibits::table3(b),
            "table4" => exhibits::table4(b),
            "table5" => exhibits::table5(b),
            "mtbench" | "fig2" | "table7" => exhibits::mtbench(b),
            "fig3" => exhibits::fig3(b),
            "fig4" => exhibits::fig4(b),
            "fig5" => exhibits::fig5(b),
            "fig6" => exhibits::fig6(b),
            "tables8_12" => exhibits::tables8_12(b),
            "appendix_b" => exhibits::appendix_b(b),
            "act_ckpt" | "actckpt" => exhibits::act_ckpt(b),
            "offload" => exhibits::offload(b),
            "precision" => exhibits::precision(b),
            "kernels" => exhibits::kernels(b),
            "parallel" => exhibits::parallel(b),
            "evalmatrix" => exhibits::evalmatrix(b),
            other => bail!("unknown exhibit {other:?}"),
        }
    };
    if which == "all" {
        for name in ["tables8_12", "fig6", "appendix_b", "act_ckpt", "offload", "precision",
                     "kernels", "parallel", "evalmatrix", "table5", "fig3", "fig4", "table3",
                     "table4", "mtbench", "table2", "table1", "fig5"] {
            run(&mut b, name)?;
        }
        Ok(())
    } else {
        run(&mut b, which)
    }
}

/// `hift plancheck` — statically verify the full configuration lattice and
/// write the machine-readable proof artifact.  Exits non-zero if any valid
/// point violates a rule (or any mutually-exclusive point is not rejected),
/// which is what makes `cargo xtask plancheck` a CI gate.
fn cmd_plancheck(a: &Args) -> Result<()> {
    let seed = a.get_num("seed").unwrap_or(0.0) as u64;
    let be = backend_from(a, seed)?;
    let inject = match a.get("inject") {
        Some(s) => crate::plancheck::Inject::parse(s)?,
        None => crate::plancheck::Inject::None,
    };
    reject_zero_count(a, "steps", "a plan needs at least one step")?;
    let steps = a.get_num("steps").map(|n| n as u64);
    let report = crate::plancheck::check_lattice(be.manifest(), inject, steps)?;
    let out = a.get("out").unwrap_or("runs/plancheck.json");
    if let Some(dir) = std::path::Path::new(out).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(out, emit_pretty(&crate::plancheck::report_json(&report)))?;
    let total_checks: u64 = report.checks.values().sum();
    eprintln!(
        "plancheck [{}] inject={}: {} configs ({} verified, {} rejected-invalid, {} failed), \
         {} rule checks across {} rules; wrote {out}",
        report.preset,
        report.inject.name(),
        report.points.len(),
        report.verified,
        report.rejected,
        report.failed,
        total_checks,
        report.checks.len(),
    );
    if !report.ok() {
        for p in report.points.iter().filter(|p| !p.violations.is_empty()).take(5) {
            for v in p.violations.iter().take(3) {
                eprintln!("  {} step {}: [{}] {}", p.point.name(), v.step, v.rule, v.detail);
            }
        }
        bail!("plancheck failed: {} of {} configs violated a rule", report.failed,
              report.points.len());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn workers_zero_is_rejected_by_name() {
        let err = cmd_train(&args(&["--workers", "0"])).unwrap_err().to_string();
        assert!(err.contains("--workers"), "error must name the flag: {err}");
        assert!(err.contains(">= 1"), "error must state the bound: {err}");
        let err = cmd_eval(&args(&["--workers", "0"])).unwrap_err().to_string();
        assert!(err.contains("--workers"), "eval too: {err}");
    }

    #[test]
    fn m_zero_is_rejected_by_name() {
        let err = cmd_train(&args(&["--m", "0"])).unwrap_err().to_string();
        assert!(err.contains("--m"), "error must name the flag: {err}");
        assert!(err.contains(">= 1"), "error must state the bound: {err}");
    }

    #[test]
    fn save_every_zero_is_rejected_by_name() {
        let err = cmd_train(&args(&["--save-every", "0"])).unwrap_err().to_string();
        assert!(err.contains("--save-every"), "error must name the flag: {err}");
        assert!(err.contains("omit the flag"), "error must point at the fix: {err}");
    }

    #[test]
    fn non_numeric_counts_are_rejected_not_ignored() {
        let err = cmd_train(&args(&["--workers", "two"])).unwrap_err().to_string();
        assert!(err.contains("--workers"), "error must name the flag: {err}");
        assert!(err.contains("number"), "error must say what it wants: {err}");
    }

    #[test]
    fn unset_counts_still_default() {
        // The guard only fires on explicit values; the defaults (workers 1,
        // m 1, save-every disabled) are untouched.
        assert!(reject_zero_count(&args(&[]), "workers", "x").is_ok());
        assert!(reject_zero_count(&args(&["--workers", "4"]), "workers", "x").is_ok());
    }
}
