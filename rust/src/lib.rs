//! # hift — Hierarchical Full-Parameter Fine-Tuning (EMNLP 2024) in Rust
//!
//! A reproduction of *HiFT: A Hierarchical Full Parameter Fine-Tuning
//! Strategy* (Liu et al., EMNLP 2024) with a **pluggable execution
//! backend**:
//!
//! * **native (default)** — a pure-Rust decoder-only transformer LM with
//!   hand-written forward + backward ([`backend::model`]), organized into
//!   the same per-layer-unit gradient artifacts the manifest names
//!   (`grad_base_u{i}`, `grad_base_full`, `fwd_base`, …).  The whole
//!   training loop — HiFT, every baseline, the trainer, all bench
//!   harnesses — builds, tests and runs offline with zero external
//!   dependencies: `cargo run --example quickstart`.
//! * **pjrt (feature `pjrt`)** — the three-layer XLA path: Pallas kernels
//!   (`python/compile/kernels/`) lowered into per-unit HLO artifacts
//!   (`make artifacts`), loaded and executed through the PJRT C API
//!   ([`runtime`]).  Python never runs on the training path.
//!
//! Both engines implement [`backend::ExecBackend`]; strategies, trainer,
//! benches and CLI take `&mut dyn ExecBackend`, so the coordinator code is
//! identical either way — which is itself the paper's point: HiFT only
//! needs per-group gradients, not a particular autodiff substrate.
//!
//! The seam is **streamed**: the primitive operation is
//! `run_streamed(artifact, params, batch, &mut dyn GradSink)` — the
//! backward walk emits each parameter gradient the moment it is final, and
//! the strategy's sink ([`optim::FusedApply`], optionally double-buffered
//! by [`optim::PipelinedApply`]) clips, pages optimizer state, updates in
//! place and drops it.  Peak gradient residency is one tensor instead of
//! the active group's sum, and HiFT groups (m>1) run as a single forward +
//! multi-unit backward instead of one pass per unit.  `run` (collected
//! `Vec<Tensor>`) survives as a provided method for forward-only and MeZO
//! paths.
//!
//! With gradients streamed, activations dominate the remaining footprint:
//! [`backend::ActCkpt`] (`--act-ckpt none|sqrt|every_k(K)`) turns on
//! **recompute-on-backward activation checkpointing** — the forward
//! retains only layer-boundary residual streams and the backward rebuilds
//! each layer's internals just before emitting its gradients, bit-identical
//! to the cached path, with the tradeoff tracked as
//! `peak_act_resident_bytes` / `recompute_flops` in
//! [`backend::RuntimeStats`].  Long runs are crash-safe:
//! [`tensor::checkpoint`] persists params + optimizer state + the
//! step/sweep schedule position, and `hift train --resume DIR` continues a
//! killed run bit-identically (delayed-LR sweep alignment included).
//!
//! The paper's headline residency claim is **enforced** by the host
//! paging tier ([`tensor::paged`], `--offload host`): inactive groups'
//! parameter masters physically leave the arena into a host pool
//! (optionally f16-compressed) and return on demand, double-buffered by a
//! background prefetch worker so transfers hide behind compute — lossless
//! paged runs are bit-identical to resident runs, and
//! `peak_param_resident_bytes` is measured from real evictions, not
//! modeled.
//!
//! Compute is **precision-selectable** (`--precision f32|bf16|f16`,
//! [`tensor::half`]): forward activations, backward intermediates and the
//! hot loops run at the chosen width — with retained activation caches
//! physically stored as 16-bit words — while parameter masters and
//! optimizer state stay f32.  f16 backward runs under dynamic loss
//! scaling ([`optim::LossScaler`]) with atomic skip-step on overflow; a
//! non-finite gradient can never reach the optimizer in any mode
//! (the [`optim::FusedApply`] safety net).  `--precision f32` remains
//! bit-identical to the historical path.
//!
//! Deeper docs: `docs/ARCHITECTURE.md` (layering + contracts),
//! `docs/CONTRACTS.md` (machine-checked invariants: lints + runtime
//! assertions), `docs/PAPER_MAP.md` (paper exhibit → harness map),
//! `docs/CLI.md` (flags + `HIFT_*` env inventory).
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`ser`] | minimal JSON (no serde in the offline vendor set) |
//! | [`rng`] | deterministic PCG RNG (MeZO perturbations, shuffles) |
//! | [`tensor`] | flat f32 tensors, crash-safe checkpoint save/load (`tensor::checkpoint`), shared f16/bf16 codecs + precision-tagged buffers (`tensor::half`), host paging tier with async double-buffered prefetch (`tensor::paged`) |
//! | [`backend`] | the streamed execution seam: `ExecBackend`, `GradSink`, `ActCkpt` recompute policies, `Precision` compute modes, manifest, native CPU model, the cache-blocked/SIMD kernel layer (`backend::kernels`), thread-budgeted parallel helpers |
//! | [`runtime`] | PJRT client, artifact registry, executable cache (`pjrt` feature; streams via post-execute drain) |
//! | [`optim`] | AdamW / SGD / SGDM / Adagrad / Adafactor + paging ledger + fused/pipelined update sinks + the f16 dynamic loss scaler |
//! | [`coordinator`] | HiFT itself: queue, strategies, grouping, delayed LR, trainer (+ checkpoint/resume loop) |
//! | [`strategies`] | FPFT, LoRA, IA3, prefix, BitFit, LP, MeZO, LOMO, … |
//! | [`memmodel`] | analytic GPU-memory accounting (Tables 5, 8–12, Fig. 6) incl. streamed-gradient residency |
//! | [`data`] | synthetic tasks standing in for GLUE/E2E/GSM8K |
//! | [`metrics`] | loss/accuracy/throughput trackers |
//! | [`bench`] | table/figure harnesses shared by `cargo bench` targets |
//! | [`proptest`] | minimal property-testing harness (offline substitute) |
//! | [`contracts`] | runtime contract checks (`contracts` feature / `HIFT_CHECK`): emission order, ledger conservation, lease balance — the dynamic half of `cargo xtask lint` (see `docs/CONTRACTS.md`) |
//! | [`plancheck`] | static schedule & memory-model verifier: derives every config's full step plan symbolically and proves the residency/ordering claims over the whole lattice (`hift plancheck`, `cargo xtask plancheck`) |

// Portable SIMD is still nightly-gated; the `simd` cargo feature opts in
// (see `backend::kernels` — scalar blocked kernels compile without it).
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod backend;
pub mod bench;
pub mod cli;
pub mod contracts;
pub mod coordinator;
pub mod data;
pub mod memmodel;
pub mod metrics;
pub mod optim;
pub mod plancheck;
pub mod proptest;
pub mod rng;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod ser;
pub mod strategies;
pub mod tensor;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
