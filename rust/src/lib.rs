//! # hift — Hierarchical Full-Parameter Fine-Tuning (EMNLP 2024) in Rust+XLA
//!
//! A three-layer reproduction of *HiFT: A Hierarchical Full Parameter
//! Fine-Tuning Strategy* (Liu et al., EMNLP 2024):
//!
//! * **L1** — Pallas kernels (flash attention, fused cross-entropy,
//!   layernorm), authored in `python/compile/kernels/` and lowered into the
//!   model's HLO at build time.
//! * **L2** — a JAX transformer LM (`python/compile/model.py`) lowered once
//!   per layer-unit to HLO-text artifacts (`make artifacts`).
//! * **L3** — this crate: the HiFT coordinator (Algorithm 1 of the paper),
//!   the baselines it is compared against, the optimizers with host↔device
//!   state paging, the analytic device-memory model that regenerates the
//!   paper's memory tables, and the benchmark harnesses for every table and
//!   figure in the evaluation.
//!
//! Python never runs on the training path: the Rust binary loads the
//! AOT-compiled artifacts through the PJRT C API (`xla` crate) and owns the
//! training loop, optimizer math, batching and metrics.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`ser`] | minimal JSON (no serde in the offline vendor set) |
//! | [`rng`] | deterministic PCG RNG (MeZO perturbations, shuffles) |
//! | [`tensor`] | flat f32 tensors + the math optimizers need |
//! | [`runtime`] | PJRT client, artifact registry, executable cache |
//! | [`optim`] | AdamW / SGD / SGDM / Adagrad / Adafactor + paging ledger |
//! | [`coordinator`] | HiFT itself: queue, strategies, grouping, delayed LR, trainer |
//! | [`strategies`] | FPFT, LoRA, IA3, prefix, BitFit, LP, MeZO, LOMO, … |
//! | [`memmodel`] | analytic GPU-memory accounting (Tables 5, 8–12, Fig. 6) |
//! | [`data`] | synthetic tasks standing in for GLUE/E2E/GSM8K |
//! | [`metrics`] | loss/accuracy/throughput trackers |
//! | [`bench`] | table/figure harnesses shared by `cargo bench` targets |
//! | [`proptest`] | minimal property-testing harness (offline substitute) |

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod memmodel;
pub mod metrics;
pub mod optim;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod ser;
pub mod strategies;
pub mod tensor;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
