//! Runtime contract checks (`--features contracts`, toggled by `HIFT_CHECK`).
//!
//! The static half of every invariant lives in `tools/hift-lint`
//! (`cargo xtask lint`); this module is the dynamic half — assertions that
//! fire while a real step runs.  `docs/CONTRACTS.md` maps each lint to the
//! check here that backs it.
//!
//! Three seams are covered:
//!
//! * **GradSink emission order** ([`EmitChecker`]): the streamed backward
//!   must emit every expected gradient exactly once, walking layer units
//!   strictly head→embedding and each unit's parameters in manifest order —
//!   the property that makes group sweeps and kill+resume bit-identical.
//! * **OffloadLedger conservation** (`OffloadLedger::check_conservation`,
//!   in `optim`): bytes paged in plus bytes allocated on-device equal bytes
//!   paged out plus bytes still resident.
//! * **ThreadBudget lease balance** (underflow asserts in the `Lease` /
//!   `WorkerSlot` drops in `backend::par`).
//!
//! Everything here compiles unconditionally (the types are pure logic and
//! unit-tested without the feature); only the *call sites* are gated, via
//! [`enabled`], so the default build pays nothing.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::OnceLock;

use anyhow::{bail, ensure, Context, Result};

use crate::backend::manifest::VariantInfo;

/// True when the `contracts` feature is compiled in and `HIFT_CHECK` is not
/// `"0"` (the feature defaults to on once compiled; set `HIFT_CHECK=0` to
/// silence it without rebuilding).
pub fn enabled() -> bool {
    if !cfg!(feature = "contracts") {
        return false;
    }
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| std::env::var("HIFT_CHECK").map(|v| v != "0").unwrap_or(true))
}

/// Validates a streamed-backward emission sequence against the manifest.
///
/// Built from the artifact's name→slot map; [`EmitChecker::observe`] is
/// called once per emitted gradient and [`EmitChecker::finalize`] once the
/// walk ends.  The enforced contract:
///
/// * every slot is emitted exactly once, under its manifest name;
/// * within a layer unit, slots are contiguous and ascending (unit
///   parameters are contiguous in manifest order, and slot maps preserve
///   relative order);
/// * across units the walk is strictly descending (head = `L+1` first,
///   embedding = `0` last) and a closed unit is never re-entered;
/// * adapter parameters (unit `-1`) are exempt from the ordering rules —
///   their updates are whole-tensor and order-independent — but still
///   checked for duplicates, names, and coverage.
pub struct EmitChecker {
    /// Slot → (expected name, layer unit).
    expected: Vec<(String, i64)>,
    seen: Vec<bool>,
    /// Last non-adapter emission: (slot, unit).
    last: Option<(usize, i64)>,
    closed: BTreeSet<i64>,
    /// First (minimum) slot of each non-adapter unit.
    unit_min: BTreeMap<i64, usize>,
}

impl EmitChecker {
    pub fn new(vinfo: &VariantInfo, slots: &HashMap<String, usize>) -> Result<EmitChecker> {
        let mut expected: Vec<Option<(String, i64)>> = vec![None; slots.len()];
        for (name, &slot) in slots {
            let unit = vinfo
                .params
                .iter()
                .find(|p| &p.name == name)
                .map(|p| p.unit)
                .with_context(|| format!("slot map names {name:?}, absent from the manifest"))?;
            ensure!(slot < expected.len(), "slot {slot} out of range for {} gradients", expected.len());
            ensure!(expected[slot].is_none(), "slot {slot} assigned twice in the slot map");
            expected[slot] = Some((name.clone(), unit));
        }
        let expected: Vec<(String, i64)> = expected
            .into_iter()
            .map(|e| e.context("slot map leaves a gap"))
            .collect::<Result<_>>()?;
        let mut unit_min = BTreeMap::new();
        for (slot, (_, unit)) in expected.iter().enumerate() {
            if *unit >= 0 {
                unit_min.entry(*unit).or_insert(slot);
            }
        }
        let seen = vec![false; expected.len()];
        Ok(EmitChecker { expected, seen, last: None, closed: BTreeSet::new(), unit_min })
    }

    pub fn observe(&mut self, slot: usize, name: &str) -> Result<()> {
        ensure!(
            slot < self.expected.len(),
            "emitted slot {slot} out of range ({} expected)",
            self.expected.len()
        );
        let (exp_name, unit) = &self.expected[slot];
        let unit = *unit;
        ensure!(
            exp_name == name,
            "slot {slot} emitted as {name:?}, manifest says {exp_name:?}"
        );
        ensure!(!self.seen[slot], "gradient {name:?} (slot {slot}) emitted twice");
        self.seen[slot] = true;
        if unit < 0 {
            return Ok(()); // adapter: no ordering constraints
        }
        match self.last {
            Some((last_slot, last_unit)) if last_unit == unit => {
                ensure!(
                    slot == last_slot + 1,
                    "within-unit emission out of manifest order: unit {unit} jumped slot {last_slot} -> {slot}"
                );
            }
            Some((_, last_unit)) => {
                ensure!(
                    !self.closed.contains(&unit),
                    "unit {unit} re-entered after it was closed"
                );
                ensure!(
                    unit < last_unit,
                    "unit walk not strictly descending: unit {last_unit} then unit {unit}"
                );
                self.closed.insert(last_unit);
                self.enter_unit(unit, slot)?;
            }
            None => {
                self.enter_unit(unit, slot)?;
            }
        }
        self.last = Some((slot, unit));
        Ok(())
    }

    fn enter_unit(&self, unit: i64, slot: usize) -> Result<()> {
        let min = self.unit_min[&unit];
        if slot != min {
            bail!("unit {unit} entered mid-block at slot {slot} (its first slot is {min})");
        }
        Ok(())
    }

    /// Coverage check after the walk: every expected gradient was emitted.
    pub fn finalize(&self) -> Result<()> {
        for (slot, seen) in self.seen.iter().enumerate() {
            ensure!(
                *seen,
                "gradient {:?} (slot {slot}) never emitted",
                self.expected[slot].0
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::manifest::ParamInfo;

    fn pinfo(name: &str, unit: i64) -> ParamInfo {
        ParamInfo { name: name.into(), shape: vec![1], unit, bitfit: false, offset: 0, size: 1 }
    }

    /// Two-unit variant plus one adapter param; slots in manifest order.
    fn fixture() -> (VariantInfo, HashMap<String, usize>) {
        let vinfo = VariantInfo {
            params: vec![
                pinfo("emb.w", 0),
                pinfo("head.w", 1),
                pinfo("head.b", 1),
                pinfo("head.g", 1),
                pinfo("lora.a", -1),
            ],
            n_base_params: 4,
        };
        let slots: HashMap<String, usize> = [
            ("emb.w".to_string(), 0usize),
            ("head.w".to_string(), 1),
            ("head.b".to_string(), 2),
            ("head.g".to_string(), 3),
            ("lora.a".to_string(), 4),
        ]
        .into_iter()
        .collect();
        (vinfo, slots)
    }

    #[test]
    fn descending_walk_passes() {
        let (vinfo, slots) = fixture();
        let mut c = EmitChecker::new(&vinfo, &slots).unwrap();
        // head unit (1) first, then embedding (0); adapter anywhere.
        c.observe(4, "lora.a").unwrap();
        c.observe(1, "head.w").unwrap();
        c.observe(2, "head.b").unwrap();
        c.observe(3, "head.g").unwrap();
        c.observe(0, "emb.w").unwrap();
        c.finalize().unwrap();
    }

    #[test]
    fn duplicate_emission_is_caught() {
        let (vinfo, slots) = fixture();
        let mut c = EmitChecker::new(&vinfo, &slots).unwrap();
        c.observe(1, "head.w").unwrap();
        let err = c.observe(1, "head.w").unwrap_err();
        assert!(err.to_string().contains("twice"), "{err}");
    }

    #[test]
    fn ascending_unit_walk_is_caught() {
        let (vinfo, slots) = fixture();
        let mut c = EmitChecker::new(&vinfo, &slots).unwrap();
        c.observe(0, "emb.w").unwrap();
        let err = c.observe(1, "head.w").unwrap_err();
        assert!(err.to_string().contains("descending"), "{err}");
    }

    #[test]
    fn within_unit_jump_is_caught() {
        let (vinfo, slots) = fixture();
        let mut c = EmitChecker::new(&vinfo, &slots).unwrap();
        c.observe(1, "head.w").unwrap();
        let err = c.observe(3, "head.g").unwrap_err();
        assert!(err.to_string().contains("manifest order"), "{err}");
    }

    #[test]
    fn closed_unit_reentry_is_caught() {
        let (vinfo, slots) = fixture();
        let mut c = EmitChecker::new(&vinfo, &slots).unwrap();
        c.observe(1, "head.w").unwrap();
        c.observe(2, "head.b").unwrap();
        c.observe(3, "head.g").unwrap();
        c.observe(0, "emb.w").unwrap();
        // Unit 1 closed when the walk moved to unit 0; head.w also dups.
        let err = c.observe(1, "head.w").unwrap_err();
        assert!(err.to_string().contains("twice") || err.to_string().contains("re-entered"), "{err}");
    }

    #[test]
    fn mid_block_entry_is_caught() {
        let (vinfo, slots) = fixture();
        let mut c = EmitChecker::new(&vinfo, &slots).unwrap();
        let err = c.observe(2, "head.b").unwrap_err();
        assert!(err.to_string().contains("mid-block"), "{err}");
    }

    #[test]
    fn wrong_name_and_missing_coverage_are_caught() {
        let (vinfo, slots) = fixture();
        let mut c = EmitChecker::new(&vinfo, &slots).unwrap();
        assert!(c.observe(1, "emb.w").is_err());
        c.observe(1, "head.w").unwrap();
        let err = c.finalize().unwrap_err();
        assert!(err.to_string().contains("never emitted"), "{err}");
    }

    #[test]
    fn unknown_slot_name_rejected_at_build() {
        let (vinfo, mut slots) = fixture();
        slots.insert("ghost".into(), 5);
        assert!(EmitChecker::new(&vinfo, &slots).is_err());
    }
}
