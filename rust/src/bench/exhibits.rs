//! One harness per paper exhibit (see DESIGN.md §4 for the mapping).
//!
//! Training-based exhibits (Tables 1–4, Fig. 2/3/4/5, the speed half of
//! Table 5) run the real three-layer stack on synthetic stand-in tasks;
//! accounting-based exhibits (the memory half of Table 5, Tables 8–12,
//! Fig. 6) come from [`crate::memmodel`] over the paper's architectures.

use anyhow::Result;

use super::{acc_cell, default_spec, print_table, Bench};
use crate::backend::kernels::{self, KernelKind};
use crate::backend::par;
use crate::backend::{ActCkpt, Compression, ExecBackend, OffloadCfg, Precision};
use crate::coordinator::strategy::UpdateStrategy;
use crate::data::templates::MATRIX_FAMILIES;
use crate::memmodel::{
    account, account_ckpt, account_prec, by_name, native_probs_bytes, paged_host_bound,
    paged_param_bound, workers_overhead, Dtype, Method, Workload, GIB, MIB,
};
use crate::optim::OptimKind;
use crate::ser::Value;
use crate::strategies::STRATEGY_NAMES;

/// Table 1 — few-shot prompt-style comparison: gradient-free (MeZO family)
/// vs gradient-based (FPFT/LoRA/prefix/HiFT), at two data scales
/// (paper Num=16 / Num=512 ⇒ short / long training budgets here).
pub fn table1(b: &mut Bench) -> Result<()> {
    let tasks = ["motif2", "motif4", "motif8"];
    let seeds: &[u64] = if b.quick { &[1] } else { &[1, 2] };
    let mut json_rows = Vec::new();
    for (num, steps) in [(16u64, b.steps(64)), (512u64, b.steps(360))] {
        let mut rows = Vec::new();
        // zero-shot row
        let mut zrow = vec!["Zero-shot".to_string()];
        for t in tasks {
            zrow.push(format!("{:.1}", b.zero_shot(t, 1)? * 100.0));
        }
        rows.push(zrow);
        for strat in ["lp", "mezo", "mezo-adam", "fpft", "lora", "prefix", "hift"] {
            let mut row = vec![strat.to_string()];
            for t in tasks {
                let spec = default_spec(strat, steps);
                let (m, s, recs) = b.run_avg(&spec, t, steps, seeds)?;
                row.push(acc_cell(m, s));
                json_rows.push(Value::obj(vec![
                    ("num", (num as usize).into()),
                    ("strategy", strat.into()),
                    ("task", t.into()),
                    ("acc_mean", m.into()),
                    ("acc_std", s.into()),
                    ("final_loss", recs[0].losses.tail_mean(8).into()),
                ]));
            }
            rows.push(row);
        }
        let mut headers = vec!["method"];
        headers.extend(tasks);
        print_table(&format!("Table 1 analogue — few-shot (Num={num}, {steps} steps)"), &headers, &rows);
    }
    b.save("table1", &Value::Arr(json_rows))
}

/// Table 2 — task-type sweep (classification / generation / reasoning):
/// HiFT should win or tie the majority of columns.
pub fn table2(b: &mut Bench) -> Result<()> {
    let tasks = ["motif2", "motif8", "motif16", "copy", "sort", "modsum"];
    let steps = b.steps(360);
    let seeds: &[u64] = &[1]; // paper's Table 2 reports point estimates
    let mut rows = Vec::new();
    let mut json = Vec::new();
    let mut zrow = vec!["Zero-shot".to_string()];
    for t in tasks {
        zrow.push(format!("{:.1}", b.zero_shot(t, 1)? * 100.0));
    }
    rows.push(zrow);
    let mut best: Vec<(f64, String)> = vec![(0.0, String::new()); tasks.len()];
    for strat in ["lp", "mezo", "fpft", "lora", "ia3", "prefix", "hift"] {
        let mut row = vec![strat.to_string()];
        for (ti, t) in tasks.iter().enumerate() {
            let spec = default_spec(strat, steps);
            let (m, s, _) = b.run_avg(&spec, t, steps, seeds)?;
            row.push(acc_cell(m, s));
            if m > best[ti].0 {
                best[ti] = (m, strat.to_string());
            }
            json.push(Value::obj(vec![
                ("strategy", strat.into()),
                ("task", (*t).into()),
                ("acc_mean", m.into()),
                ("acc_std", s.into()),
            ]));
        }
        rows.push(row);
    }
    // Equal-steps HiFT updates each unit only steps/k times; the paper's
    // regime (fine-tuning pretrained models to saturation) is closer to
    // equal per-parameter updates, so also report HiFT at k× steps.
    {
        let k = b.rt.manifest().n_units as u64;
        let mut row = vec!["hift(eq)".to_string()];
        for (ti, t) in tasks.iter().enumerate() {
            let spec = default_spec("hift", steps * k);
            let (m, s, _) = b.run_avg(&spec, t, steps * k, seeds)?;
            row.push(acc_cell(m, s));
            if m > best[ti].0 {
                best[ti] = (m, "hift(eq)".to_string());
            }
            json.push(Value::obj(vec![
                ("strategy", "hift(eq)".into()),
                ("task", (*t).into()),
                ("acc_mean", m.into()),
            ]));
        }
        rows.push(row);
    }
    let mut headers = vec!["method"];
    headers.extend(tasks);
    print_table(&format!("Table 2 analogue — task sweep ({steps} steps; hift(eq) = k×)"), &headers, &rows);
    let hift_wins = best.iter().filter(|(_, s)| s.starts_with("hift")).count();
    println!("best-per-task: {:?}  (hift wins {hift_wins}/{})", best, tasks.len());
    b.save("table2", &Value::Arr(json))
}

/// Table 3 — generation (E2E-NLG stand-ins): FPFT vs LoRA vs HiFT token
/// accuracy on copy/sort.
pub fn table3(b: &mut Bench) -> Result<()> {
    let tasks = ["copy", "sort"];
    let steps = b.steps(360);
    let seeds: &[u64] = if b.quick { &[1] } else { &[1, 2] };
    let mut rows = Vec::new();
    let mut json = Vec::new();
    for strat in ["fpft", "lora", "prefix", "hift"] {
        let mut row = vec![strat.to_string()];
        for t in tasks {
            let spec = default_spec(strat, steps);
            let (m, s, _) = b.run_avg(&spec, t, steps, seeds)?;
            row.push(acc_cell(m, s));
            json.push(Value::obj(vec![
                ("strategy", strat.into()),
                ("task", t.into()),
                ("acc_mean", m.into()),
            ]));
        }
        rows.push(row);
    }
    print_table(
        &format!("Table 3 analogue — generation token-accuracy ({steps} steps)"),
        &["method", "copy", "sort"],
        &rows,
    );
    b.save("table3", &Value::Arr(json))
}

/// Table 4 — "hard" compositional tasks: full-parameter methods (FPFT,
/// HiFT) should beat LoRA clearly (the paper's capacity argument).
pub fn table4(b: &mut Bench) -> Result<()> {
    let tasks = ["modsum", "modsum6", "sort"];
    let steps = b.steps(400);
    let seeds: &[u64] = if b.quick { &[1] } else { &[1, 2] };
    let mut rows = Vec::new();
    let mut json = Vec::new();
    let mut acc = std::collections::HashMap::new();
    for strat in ["fpft", "lora", "hift"] {
        let mut row = vec![strat.to_string()];
        for t in tasks {
            let spec = default_spec(strat, steps);
            let (m, s, _) = b.run_avg(&spec, t, steps, seeds)?;
            row.push(acc_cell(m, s));
            acc.insert((strat, t), m);
            json.push(Value::obj(vec![
                ("strategy", strat.into()),
                ("task", t.into()),
                ("acc_mean", m.into()),
            ]));
        }
        rows.push(row);
    }
    print_table(
        &format!("Table 4 analogue — hard tasks ({steps} steps)"),
        &["method", "modsum", "modsum6", "sort"],
        &rows,
    );
    let lora_losses = tasks
        .iter()
        .filter(|t| acc[&("hift", **t)] >= acc[&("lora", **t)] - 0.02)
        .count();
    println!("hift >= lora on {lora_losses}/{} hard tasks (paper: full-param wins)", tasks.len());
    b.save("table4", &Value::Arr(json))
}

/// Figure 2 / Table 7 — instruction-tuning proxy: per-category accuracy on
/// the multi-task instruct mixture.
pub fn mtbench(b: &mut Bench) -> Result<()> {
    use crate::coordinator::trainer::{evaluate, train, TrainCfg};
    use crate::data::InstructTask;
    let steps = b.steps(360);
    let cats = ["classify", "copy", "reason"];
    let mut rows = Vec::new();
    let mut json = Vec::new();
    for strat in ["fpft", "lora", "prefix", "hift"] {
        let spec = default_spec(strat, steps);
        let mut strategy = spec.build(b.rt.manifest())?;
        let mut params = b.rt.load_params(strategy.variant())?;
        let mut task = InstructTask::new(b.geom(), 1);
        train(b.rt.as_mut(), strategy.as_mut(), &mut params, &mut task,
              TrainCfg { steps, eval_every: 0, log_every: 0 })?;
        let fwd = strategy.fwd_artifact();
        let mut row = vec![strat.to_string()];
        let mut sum = 0.0;
        for c in 0..cats.len() {
            let ev = evaluate(b.rt.as_mut(), &fwd, &mut params, &task.eval_category(c))?;
            row.push(format!("{:.1}", ev.acc * 100.0));
            sum += ev.acc;
            json.push(Value::obj(vec![
                ("strategy", strat.into()),
                ("category", cats[c].into()),
                ("acc", ev.acc.into()),
            ]));
        }
        row.push(format!("{:.1}", sum / cats.len() as f64 * 100.0));
        rows.push(row);
    }
    print_table(
        &format!("Figure 2 / Table 7 analogue — instruction FT per category ({steps} steps)"),
        &["method", "classify", "copy", "reason", "AVG"],
        &rows,
    );
    b.save("mtbench", &Value::Arr(json))
}

/// Figure 3 — HiFT loss curves on four datasets (m=1): smooth, stable
/// convergence under the delayed-LR schedule.
pub fn fig3(b: &mut Bench) -> Result<()> {
    let tasks = ["markovlm", "motif4", "copy", "modsum"];
    let steps = b.steps(320);
    let mut json = Vec::new();
    for t in tasks {
        let spec = default_spec("hift", steps);
        let rec = b.run_one(&spec, t, steps, 1)?;
        let slope = rec.losses.slope();
        println!("\n--- Figure 3: HiFT loss on {t} (slope {slope:+.5}/step) ---");
        for (i, v) in rec.losses.downsample(16) {
            let bar = "#".repeat((v * 12.0).min(80.0) as usize);
            println!("  step {i:>4}  loss {v:7.4}  {bar}");
        }
        assert!(slope < 0.0, "{t}: HiFT loss must trend down (slope {slope})");
        json.push(Value::obj(vec![("task", t.into()), ("record", rec.to_json())]));
    }
    b.save("fig3", &Value::Arr(json))
}

/// Figure 4 — left: update-order ablation (B2U/T2D/RAN); right: group-size
/// ablation (m).  Both axes should be ~flat.
pub fn fig4(b: &mut Bench) -> Result<()> {
    let steps = b.steps(320);
    let seeds: &[u64] = if b.quick { &[1] } else { &[1, 2] };
    let tasks = ["motif4", "copy"];
    // left: strategies
    let mut rows = Vec::new();
    let mut json = Vec::new();
    for (label, order) in [
        ("B2U", UpdateStrategy::Bottom2Up),
        ("T2D", UpdateStrategy::Top2Down),
        ("RAN", UpdateStrategy::Random { seed: 7 }),
    ] {
        let mut row = vec![label.to_string()];
        for t in tasks {
            let mut spec = default_spec("hift", steps);
            spec.order = order;
            let (m, s, _) = b.run_avg(&spec, t, steps, seeds)?;
            row.push(acc_cell(m, s));
            json.push(Value::obj(vec![
                ("axis", "order".into()),
                ("setting", label.into()),
                ("task", t.into()),
                ("acc_mean", m.into()),
            ]));
        }
        rows.push(row);
    }
    print_table("Figure 4 (left) — update order ablation", &["order", "motif4", "copy"], &rows);

    // right: grouping m (tiny model has n_layers+2 units)
    let n_units = b.rt.manifest().n_units;
    let mut rows = Vec::new();
    for m in [1usize, 2, n_units.div_ceil(2), n_units] {
        let mut row = vec![format!("m={m}")];
        for t in tasks {
            let mut spec = default_spec("hift", steps);
            spec.m = m;
            let (mean, s, _) = b.run_avg(&spec, t, steps, seeds)?;
            row.push(acc_cell(mean, s));
            json.push(Value::obj(vec![
                ("axis", "m".into()),
                ("setting", m.into()),
                ("task", t.into()),
                ("acc_mean", mean.into()),
            ]));
        }
        rows.push(row);
    }
    print_table("Figure 4 (right) — group size ablation", &["m", "motif4", "copy"], &rows);
    b.save("fig4", &Value::Arr(json))
}

/// Figure 5 — the no-prompt GLUE-style grid: FPFT vs HiFT(3 orders) vs
/// PEFT (BitFit/LoRA/IA3/prefix) across eight tasks.
pub fn fig5(b: &mut Bench) -> Result<()> {
    let tasks =
        ["motif2", "motif4", "motif8", "motif16", "copy", "sort", "modsum", "markovlm"];
    let steps = b.steps(320);
    let seeds: &[u64] = &[1];
    let mut rows = Vec::new();
    let mut json = Vec::new();
    let configs: Vec<(String, crate::strategies::StrategySpec)> = vec![
        ("FPFT".into(), default_spec("fpft", steps)),
        ("HiFT-B2U".into(), default_spec("hift", steps)),
        ("HiFT-T2D".into(), {
            let mut s = default_spec("hift", steps);
            s.order = UpdateStrategy::Top2Down;
            s
        }),
        ("HiFT-RAN".into(), {
            let mut s = default_spec("hift", steps);
            s.order = UpdateStrategy::Random { seed: 7 };
            s
        }),
        ("BitFit".into(), default_spec("bitfit", steps)),
        ("LoRA".into(), default_spec("lora", steps)),
        ("IA3".into(), default_spec("ia3", steps)),
        ("Prefix".into(), default_spec("prefix", steps)),
    ];
    for (label, spec) in configs {
        let mut row = vec![label.clone()];
        for t in tasks {
            let (m, _, _) = b.run_avg(&spec, t, steps, seeds)?;
            row.push(format!("{:.1}", m * 100.0));
            json.push(Value::obj(vec![
                ("method", label.as_str().into()),
                ("task", t.into()),
                ("acc", m.into()),
            ]));
        }
        rows.push(row);
    }
    let mut headers = vec!["method"];
    headers.extend(tasks);
    print_table(&format!("Figure 5 analogue — 8-task grid ({steps} steps)"), &headers, &rows);
    b.save("fig5", &Value::Arr(json))
}

/// Figure 6 — (a–d) memory pies for LLaMA-7B under FPFT/HiFT × fp32/mixed;
/// (e) peak-trainable fraction vs model size.
pub fn fig6(b: &Bench) -> Result<()> {
    let a = by_name("llama-7b").unwrap();
    let w = Workload { batch: 6, seq: 512 };
    let mut rows = Vec::new();
    let mut json = Vec::new();
    for (label, dtype, method, policy) in [
        ("(a) fp32 FPFT", Dtype::Fp32, Method::Fpft, ActCkpt::None),
        ("(b) fp32 HiFT", Dtype::Fp32, Method::Hift { m: 1 }, ActCkpt::None),
        ("(c) mixed FPFT", Dtype::Mixed, Method::Fpft, ActCkpt::None),
        ("(d) mixed HiFT", Dtype::Mixed, Method::Hift { m: 1 }, ActCkpt::None),
        ("(e) fp32 HiFT+ckpt(sqrt)", Dtype::Fp32, Method::Hift { m: 1 }, ActCkpt::Sqrt),
    ] {
        let r = account_ckpt(&a, OptimKind::AdamW, dtype, method, w, policy);
        let pct = |x: f64| format!("{:.1}%", x / r.total * 100.0);
        rows.push(vec![
            label.to_string(),
            pct(r.para),
            pct(r.gra),
            pct(r.sta),
            pct(r.residual),
            format!("{:.1} GiB", r.total / GIB),
        ]);
        json.push(Value::obj(vec![
            ("panel", label.into()),
            ("para", r.para.into()),
            ("gra", r.gra.into()),
            ("sta", r.sta.into()),
            ("residual", r.residual.into()),
            ("act_ckpt", r.act_ckpt.into()),
            ("total", r.total.into()),
        ]));
    }
    print_table(
        "Figure 6 (a–e) — LLaMA-7B memory composition (AdamW; (e) = recompute-on-backward)",
        &["panel", "params", "grads", "optim state", "residual", "total"],
        &rows,
    );

    let mut rows = Vec::new();
    for name in ["opt-125m", "roberta-large", "opt-1.3b", "gpt-neo-2.7b", "llama-7b", "opt-13b", "llama-13b"] {
        let a = by_name(name).unwrap();
        let frac = a.peak_group_params(1) as f64 / a.total_params() as f64 * 100.0;
        rows.push(vec![
            name.to_string(),
            format!("{:.1}M", a.total_params() as f64 / 1e6),
            format!("{:.2}%", frac),
        ]);
        json.push(Value::obj(vec![
            ("model", name.into()),
            ("total_params", a.total_params().into()),
            ("peak_frac_pct", frac.into()),
        ]));
    }
    print_table(
        "Figure 6 (e) — peak trainable fraction vs model size (m=1)",
        &["model", "params", "peak trainable %"],
        &rows,
    );
    b.save("fig6", &Value::Arr(json))
}

/// Tables 8–12 — the full per-optimizer memory grid over the paper's five
/// profiled models.
pub fn tables8_12(b: &Bench) -> Result<()> {
    let mut json = Vec::new();
    for (name, batch) in [
        ("roberta-base", 8usize),
        ("roberta-large", 8),
        ("gpt2-large", 8),
        ("gpt-neo-2.7b", 8),
        ("llama-7b", 6),
    ] {
        let a = by_name(name).unwrap();
        let w = Workload { batch, seq: 512 };
        let mut rows = Vec::new();
        for opt in OptimKind::ALL {
            for (dtype, method) in [
                (Dtype::Fp32, Method::Fpft),
                (Dtype::Fp32, Method::Hift { m: 1 }),
                (Dtype::Mixed, Method::Fpft),
                (Dtype::Mixed, Method::Hift { m: 1 }),
                (Dtype::MixedHi, Method::Hift { m: 1 }),
            ] {
                let r = account(&a, opt, dtype, method, w);
                let ftype = match method {
                    Method::Fpft => "FPFT",
                    Method::Hift { .. } => "HiFT",
                    Method::Peft { .. } => "PEFT",
                };
                rows.push(vec![
                    opt.name().to_string(),
                    dtype.name().to_string(),
                    ftype.to_string(),
                    format!("{:.2}M", r.trainable as f64 / 1e6),
                    format!("{:.2}", r.para / MIB),
                    format!("{:.2}", r.gra / MIB),
                    format!("{:.2}", r.sta / MIB),
                    format!("{:.2}", r.pgs / GIB),
                    format!("{:.2}", r.residual / GIB),
                    format!("{:.2}", r.total / GIB),
                ]);
                json.push(Value::obj(vec![
                    ("model", name.into()),
                    ("optimizer", opt.name().into()),
                    ("dtype", dtype.name().into()),
                    ("ftype", ftype.into()),
                    ("trainable", r.trainable.into()),
                    ("para_mib", (r.para / MIB).into()),
                    ("gra_mib", (r.gra / MIB).into()),
                    ("sta_mib", (r.sta / MIB).into()),
                    ("pgs_gib", (r.pgs / GIB).into()),
                    ("residual_gib", (r.residual / GIB).into()),
                    ("total_gib", (r.total / GIB).into()),
                ]));
            }
        }
        print_table(
            &format!("Tables 8–12 analogue — {name} (b={batch}, s=512)"),
            &["optim", "dtype", "ftype", "#Train", "#Para(MiB)", "#Gra(MiB)", "#Sta(MiB)",
              "#PGS(GiB)", "Residual(GiB)", "Total(GiB)"],
            &rows,
        );
    }
    b.save("tables8_12", &Value::Arr(json))
}

/// Table 5 — memory (paper architectures, analytic) and speed (our stack,
/// measured steps/s) for FPFT / LoRA / IA3 / Prefix / HiFT × AdamW / SGD.
pub fn table5(b: &mut Bench) -> Result<()> {
    // --- memory half (analytic, RoBERTa-base/large + LLaMA-7B, b=8 s=512) ---
    let mut rows = Vec::new();
    let mut json = Vec::new();
    let w = Workload { batch: 8, seq: 512 };
    for model in ["roberta-base", "roberta-large", "llama-7b"] {
        let a = by_name(model).unwrap();
        // LoRA r=8 on q,v; IA3; prefix 128 virtual tokens — paper's setups.
        let lora_params = 4 * a.n_layers * a.d_model * 8;
        let ia3_params = a.n_layers * (2 * a.d_model + a.d_ff);
        let prefix_params = 128 * a.d_model;
        for opt in [OptimKind::AdamW, OptimKind::Sgd] {
            for (label, dtype, method, policy) in [
                ("FPFT", Dtype::Mixed, Method::Fpft, ActCkpt::None),
                ("LoRA(r=8)", Dtype::Mixed, Method::Peft { adapter_params: lora_params },
                 ActCkpt::None),
                ("IA3", Dtype::Mixed, Method::Peft { adapter_params: ia3_params }, ActCkpt::None),
                ("Prefix", Dtype::Mixed, Method::Peft { adapter_params: prefix_params },
                 ActCkpt::None),
                ("HiFT", Dtype::MixedHi, Method::Hift { m: 1 }, ActCkpt::None),
                ("HiFT+ckpt", Dtype::MixedHi, Method::Hift { m: 1 }, ActCkpt::Sqrt),
            ] {
                let r = account_ckpt(&a, opt, dtype, method, w, policy);
                let total = r.total / GIB;
                let oom = model == "llama-7b" && label == "FPFT";
                rows.push(vec![
                    model.to_string(),
                    opt.name().to_string(),
                    label.to_string(),
                    format!("{:.2}", r.act_ckpt_gib()),
                    if oom { "OOM(>80G)".into() } else { format!("{total:.2}") },
                ]);
                json.push(Value::obj(vec![
                    ("model", model.into()),
                    ("optimizer", opt.name().into()),
                    ("method", label.into()),
                    ("act_ckpt_gib", r.act_ckpt_gib().into()),
                    ("memory_gib", total.into()),
                ]));
            }
        }
    }
    print_table(
        "Table 5 analogue (memory, mixed precision; act = activation/act_ckpt term)",
        &["model", "optim", "method", "act(GiB)", "Memory(GiB)"],
        &rows,
    );

    // --- speed half (measured on our stack) ---
    let steps = b.steps(100);
    let mut rows = Vec::new();
    for opt in [OptimKind::AdamW, OptimKind::Sgd] {
        for strat in ["fpft", "lora", "ia3", "prefix", "hift"] {
            let mut spec = default_spec(strat, steps);
            spec.optim = opt;
            // Warm the executable cache so one-time XLA compiles don't
            // pollute the steps/s measurement (HiFT touches one artifact
            // per unit — warm a full sweep plus slack).
            let warm = b.rt.manifest().n_units as u64 + 2;
            let _ = b.run_one(&spec, "markovlm", warm, 1)?;
            let rec = b.run_one(&spec, "markovlm", steps, 1)?;
            let lookups = rec.backend.cache_hits + rec.backend.cache_misses;
            let hit_rate = if lookups > 0 {
                rec.backend.cache_hits as f64 / lookups as f64
            } else {
                0.0
            };
            rows.push(vec![
                opt.name().to_string(),
                strat.to_string(),
                format!("{:.2}", rec.steps_per_sec),
                format!("{:.1}", rec.exec_secs / rec.wall_secs * 100.0),
                format!("{:.1}", hit_rate * 100.0),
                format!("{:.1}", rec.backend.peak_grad_resident_bytes as f64 / 1024.0),
            ]);
            json.push(Value::obj(vec![
                ("optimizer", opt.name().into()),
                ("method", strat.into()),
                ("steps_per_sec", rec.steps_per_sec.into()),
                ("exec_frac", (rec.exec_secs / rec.wall_secs).into()),
                ("upload_cache_hit_rate", hit_rate.into()),
                (
                    "peak_grad_resident_bytes",
                    (rec.backend.peak_grad_resident_bytes as usize).into(),
                ),
            ]));
        }
    }
    print_table(
        &format!("Table 5 analogue (speed on this substrate, {steps} steps)"),
        &["optim", "method", "steps/s", "XLA-exec %", "upload-cache hit %", "peak grad KiB"],
        &rows,
    );
    b.save("table5", &Value::Arr(json))
}

/// Activation-checkpointing tradeoff exhibit: measured HiFT runs on this
/// substrate under `none` / `every_k(2)` / `sqrt` (peak activation-cache
/// residency vs recompute work vs steps/s, loss bit-identical across
/// policies), plus the analytic `act_ckpt` residual column at paper scale.
pub fn act_ckpt(b: &mut Bench) -> Result<()> {
    let steps = b.steps(60);
    let mut rows = Vec::new();
    let mut json = Vec::new();
    let mut final_losses: Vec<f64> = Vec::new();
    for policy in [ActCkpt::None, ActCkpt::EveryK(2), ActCkpt::Sqrt] {
        b.rt.set_act_ckpt(policy)?;
        let spec = default_spec("hift", steps);
        let rec = b.run_one(&spec, "markovlm", steps, 1)?;
        final_losses.push(rec.losses.tail_mean(8));
        rows.push(vec![
            policy.name(),
            format!("{:.1}", rec.backend.peak_act_resident_bytes as f64 / 1024.0),
            rec.backend.recompute_layers.to_string(),
            format!("{:.2}", rec.backend.recompute_flops as f64 / 1e6),
            format!("{:.2}", rec.steps_per_sec),
            format!("{:.4}", rec.losses.tail_mean(8)),
        ]);
        json.push(Value::obj(vec![
            ("policy", policy.name().as_str().into()),
            ("peak_act_resident_bytes", (rec.backend.peak_act_resident_bytes as usize).into()),
            ("recompute_layers", (rec.backend.recompute_layers as usize).into()),
            ("recompute_flops", (rec.backend.recompute_flops as usize).into()),
            ("steps_per_sec", rec.steps_per_sec.into()),
            ("final_train_loss", rec.losses.tail_mean(8).into()),
        ]));
    }
    b.rt.set_act_ckpt(ActCkpt::None)?;
    assert!(
        final_losses.iter().all(|&l| l == final_losses[0]),
        "recompute must not change the loss curve: {final_losses:?}"
    );
    print_table(
        &format!("Activation checkpointing — memory vs recompute (HiFT, {steps} steps)"),
        &["policy", "peak act KiB", "recompute layers", "recompute MFLOP", "steps/s",
          "final loss"],
        &rows,
    );

    // Analytic half at paper scale: the act_ckpt residual term.
    let w = Workload { batch: 8, seq: 512 };
    let mut rows = Vec::new();
    for model in ["roberta-large", "llama-7b"] {
        let a = by_name(model).unwrap();
        for policy in [ActCkpt::None, ActCkpt::EveryK(2), ActCkpt::Sqrt] {
            let r = account_ckpt(&a, OptimKind::AdamW, Dtype::Fp32, Method::Hift { m: 1 }, w, policy);
            rows.push(vec![
                model.to_string(),
                policy.name(),
                format!("{:.2}", r.act_ckpt_gib()),
                format!("{:.2}", r.residual_gib()),
                format!("{:.2}", r.total_gib()),
            ]);
            json.push(Value::obj(vec![
                ("model", model.into()),
                ("policy", policy.name().as_str().into()),
                ("act_ckpt_gib", r.act_ckpt_gib().into()),
                ("residual_gib", r.residual_gib().into()),
                ("total_gib", r.total_gib().into()),
            ]));
        }
    }
    print_table(
        "Activation checkpointing — analytic act_ckpt term (fp32 HiFT m=1, b=8 s=512)",
        &["model", "policy", "act_ckpt(GiB)", "Residual(GiB)", "Total(GiB)"],
        &rows,
    );
    b.save("act_ckpt", &Value::Arr(json))
}

/// Host-paging exhibit (`hift bench offload`): measured HiFT stepping under
/// the real paging tier — resident vs synchronous paging vs double-buffered
/// prefetch (and the f16 lossy host store) across group sizes m — plus the
/// enforced residency peaks and, at paper scale, the analytic paged bounds.
/// Lossless paged runs must reproduce the resident loss bit-for-bit;
/// prefetch should beat synchronous paging wherever transfers are material
/// (m ≥ 2 makes the per-step paged volume big enough to matter).
pub fn offload(b: &mut Bench) -> Result<()> {
    let steps = b.steps(48);
    let n_units = b.rt.manifest().n_units;
    // Native-preset structural bound from the manifest's real unit sizes
    // (the same source tests/offload.rs uses, so they cannot drift).
    let unit_bytes = b.rt.manifest().unit_param_bytes("base")?;
    let max_unit = unit_bytes.iter().copied().max().unwrap_or(0);
    let group_bytes = |m: usize| -> u64 {
        unit_bytes.chunks(m).map(|c| c.iter().sum::<u64>()).max().unwrap_or(0)
    };

    let mut rows = Vec::new();
    let mut json = Vec::new();
    let mut ms: Vec<usize> = vec![1, 2];
    let half = n_units.div_ceil(2);
    if half > 2 {
        ms.push(half);
    }
    let modes: [(&str, OffloadCfg); 4] = [
        ("resident", OffloadCfg::default()),
        (
            "host sync",
            OffloadCfg { enabled: true, compress: Compression::Lossless, prefetch: false },
        ),
        ("host prefetch", OffloadCfg::host()),
        (
            "host f16",
            OffloadCfg { enabled: true, compress: Compression::F16, prefetch: true },
        ),
    ];
    for &m in &ms {
        let mut resident_loss = f64::NAN;
        let mut sync_sps = 0.0f64;
        let mut prefetch_sps = 0.0f64;
        for (label, cfg) in modes {
            b.rt.set_offload(cfg)?;
            let mut spec = default_spec("hift", steps);
            spec.m = m;
            let rec = b.run_one(&spec, "markovlm", steps, 1)?;
            let final_loss = rec.losses.tail_mean(8);
            match label {
                "resident" => resident_loss = final_loss,
                "host sync" => sync_sps = rec.steps_per_sec,
                "host prefetch" => prefetch_sps = rec.steps_per_sec,
                _ => {}
            }
            if cfg.enabled && cfg.compress == Compression::Lossless {
                assert!(
                    final_loss == resident_loss,
                    "m={m} {label}: lossless paged loss {final_loss} != resident {resident_loss}"
                );
            }
            let bk = &rec.backend;
            // Sync paging holds group + one walk unit; prefetch staging
            // adds the next group ("one group + one prefetch buffer").
            let bound = if cfg.enabled && cfg.prefetch {
                2 * group_bytes(m) + max_unit
            } else {
                group_bytes(m) + max_unit
            };
            rows.push(vec![
                format!("m={m}"),
                label.to_string(),
                format!("{:.2}", rec.steps_per_sec),
                format!("{:.1}", bk.peak_param_resident_bytes as f64 / 1024.0),
                if cfg.enabled { format!("{:.1}", bound as f64 / 1024.0) } else { "-".into() },
                format!("{:.1}", bk.peak_host_pool_bytes as f64 / 1024.0),
                bk.offload_page_ins.to_string(),
                bk.prefetch_hits.to_string(),
                format!("{:.2}", bk.prefetch_stall_nanos as f64 / 1e6),
                format!("{:.4}", final_loss),
            ]);
            json.push(Value::obj(vec![
                ("m", m.into()),
                ("mode", label.into()),
                ("steps_per_sec", rec.steps_per_sec.into()),
                ("peak_param_resident_bytes", (bk.peak_param_resident_bytes as usize).into()),
                ("bound_bytes", (bound as usize).into()),
                ("peak_prefetch_buffer_bytes", (bk.peak_prefetch_buffer_bytes as usize).into()),
                ("peak_host_pool_bytes", (bk.peak_host_pool_bytes as usize).into()),
                ("page_ins", (bk.offload_page_ins as usize).into()),
                ("page_outs", (bk.offload_page_outs as usize).into()),
                ("prefetch_hits", (bk.prefetch_hits as usize).into()),
                ("prefetch_misses", (bk.prefetch_misses as usize).into()),
                ("prefetch_stall_ms", (bk.prefetch_stall_nanos as f64 / 1e6).into()),
                ("final_train_loss", final_loss.into()),
            ]));
        }
        println!(
            "  m={m}: prefetched stepping {:.2}x vs synchronous paging ({:.2} vs {:.2} steps/s)",
            if sync_sps > 0.0 { prefetch_sps / sync_sps } else { f64::NAN },
            prefetch_sps,
            sync_sps
        );
    }
    b.rt.set_offload(OffloadCfg::default())?;
    print_table(
        &format!(
            "Offload — measured paging tier (HiFT, {steps} steps; bound: sync = group + walk \
             unit, prefetch = 2 groups + walk unit)"
        ),
        &["m", "mode", "steps/s", "peak param KiB", "bound KiB", "peak host KiB", "page-ins",
          "pf hits", "stall ms", "final loss"],
        &rows,
    );

    // Analytic half at paper scale: what the enforced bound buys on the
    // real architectures (vs keeping every master resident).
    let mut rows = Vec::new();
    for model in ["roberta-large", "llama-7b"] {
        let a = by_name(model).unwrap();
        for m in [1usize, 2, 4] {
            let bound = paged_param_bound(&a, m, 2);
            let host = paged_host_bound(&a, m, false);
            let host16 = paged_host_bound(&a, m, true);
            rows.push(vec![
                model.to_string(),
                format!("m={m}"),
                format!("{:.2}", bound / GIB),
                format!("{:.2}", 4.0 * a.total_params() as f64 / GIB),
                format!("{:.2}", host / GIB),
                format!("{:.2}", host16 / GIB),
            ]);
            json.push(Value::obj(vec![
                ("model", model.into()),
                ("m", m.into()),
                ("paged_param_bound_bytes", bound.into()),
                ("resident_bytes", (4.0 * a.total_params() as f64).into()),
                ("host_bound_bytes", host.into()),
                ("host_bound_f16_bytes", host16.into()),
            ]));
        }
    }
    print_table(
        "Offload — analytic paged bounds at paper scale (f32 masters, 2 transfer slots)",
        &["model", "m", "device bound(GiB)", "all-resident(GiB)", "host tier(GiB)",
          "host f16(GiB)"],
        &rows,
    );
    b.save("offload", &Value::Arr(json))
}

/// Mixed-precision exhibit (`hift bench precision`): measured f32 vs bf16
/// vs f16 HiFT training — throughput, peak retained-activation residency
/// (physically halved by the 16-bit storage), parameter h2d traffic
/// (half-width working copies), final-loss drift against the f32 reference
/// and the f16 dynamic loss scaler's trajectory — plus the analytic
/// halved-activation panel at paper scale.  The f32 row *is* the
/// historical baseline (bit-identical path); the half rows must stay
/// inside the documented drift band (rel. final-loss drift < 25% on the
/// tiny presets) while cutting measured peak activation bytes to ≤ 0.7×.
pub fn precision(b: &mut Bench) -> Result<()> {
    let steps = b.steps(48);
    let mut rows = Vec::new();
    let mut json = Vec::new();
    let mut f32_loss = f64::NAN;
    let mut f32_act = 0u64;
    for prec in [Precision::F32, Precision::Bf16, Precision::F16] {
        b.rt.set_precision(prec)?;
        let spec = default_spec("hift", steps);
        let rec = b.run_one(&spec, "markovlm", steps, 1)?;
        let final_loss = rec.losses.tail_mean(8);
        let bk = &rec.backend;
        if prec == Precision::F32 {
            f32_loss = final_loss;
            f32_act = bk.peak_act_resident_bytes;
        } else {
            assert!(final_loss.is_finite(), "{}: final loss went non-finite", prec.name());
            let drift = (final_loss - f32_loss).abs() / f32_loss.abs().max(1e-9);
            assert!(
                drift < 0.25,
                "{}: final-loss drift {drift:.3} outside the documented band \
                 ({final_loss:.4} vs f32 {f32_loss:.4})",
                prec.name()
            );
            assert!(
                bk.peak_act_resident_bytes * 10 <= f32_act * 7,
                "{}: peak activation bytes {} not meaningfully below f32's {f32_act}",
                prec.name(),
                bk.peak_act_resident_bytes
            );
        }
        rows.push(vec![
            prec.name().to_string(),
            format!("{:.2}", rec.steps_per_sec),
            format!("{:.1}", bk.peak_act_resident_bytes as f64 / 1024.0),
            format!("{:.1}", bk.h2d_bytes as f64 / 1024.0),
            format!("{:.4}", final_loss),
            format!("{:.3}", rec.final_eval.acc),
            if bk.loss_scale > 0.0 { format!("{:.0}", bk.loss_scale) } else { "-".into() },
            bk.nonfinite_grad_steps.to_string(),
            bk.loss_scale_backoffs.to_string(),
        ]);
        json.push(Value::obj(vec![
            ("precision", prec.name().into()),
            ("steps_per_sec", rec.steps_per_sec.into()),
            ("peak_act_resident_bytes", (bk.peak_act_resident_bytes as usize).into()),
            ("h2d_bytes", (bk.h2d_bytes as usize).into()),
            ("final_train_loss", final_loss.into()),
            ("final_eval_acc", rec.final_eval.acc.into()),
            ("final_eval_loss", rec.final_eval.loss.into()),
            ("loss_scale", bk.loss_scale.into()),
            ("nonfinite_grad_tensors", (bk.nonfinite_grad_tensors as usize).into()),
            ("nonfinite_grad_steps", (bk.nonfinite_grad_steps as usize).into()),
            ("loss_scale_growths", (bk.loss_scale_growths as usize).into()),
            ("loss_scale_backoffs", (bk.loss_scale_backoffs as usize).into()),
        ]));
    }
    b.rt.set_precision(Precision::F32)?;
    print_table(
        &format!("Compute precision — measured f32/bf16/f16 (HiFT, {steps} steps)"),
        &["precision", "steps/s", "peak act KiB", "h2d KiB", "final loss", "eval acc",
          "loss scale", "skipped", "backoffs"],
        &rows,
    );

    // Analytic half at paper scale: the halved activation term (and its
    // composition with recompute checkpointing).
    let w = Workload { batch: 8, seq: 512 };
    let mut rows = Vec::new();
    for model in ["roberta-large", "llama-7b"] {
        let a = by_name(model).unwrap();
        for policy in [ActCkpt::None, ActCkpt::Sqrt] {
            for prec in [Precision::F32, Precision::Bf16] {
                let r = account_prec(
                    &a,
                    OptimKind::AdamW,
                    Dtype::Fp32,
                    Method::Hift { m: 1 },
                    w,
                    policy,
                    prec,
                );
                rows.push(vec![
                    model.to_string(),
                    policy.name(),
                    prec.name().to_string(),
                    format!("{:.2}", r.act_ckpt_gib()),
                    format!("{:.2}", r.residual_gib()),
                    format!("{:.2}", r.total_gib()),
                ]);
                json.push(Value::obj(vec![
                    ("model", model.into()),
                    ("policy", policy.name().as_str().into()),
                    ("precision", prec.name().into()),
                    ("act_gib", r.act_ckpt_gib().into()),
                    ("residual_gib", r.residual_gib().into()),
                    ("total_gib", r.total_gib().into()),
                ]));
            }
        }
    }
    print_table(
        "Compute precision — analytic halved-activation term (HiFT m=1, b=8 s=512; \
         bf16 ≡ f16 storage width)",
        &["model", "ckpt policy", "precision", "act(GiB)", "Residual(GiB)", "Total(GiB)"],
        &rows,
    );
    b.save("precision", &Value::Arr(json))
}

/// Kernel layer — three panels: raw GEMM throughput per kernel kind
/// (naive reference vs cache-blocked vs blocked+SIMD) with bit-identity
/// checked across kinds; an end-to-end per-kind training run (losses must
/// be bit-identical — the schedule changes, the bits don't); and the fused
/// streaming-softmax attention's measured activation saving, which must
/// equal the analytic `L·B·H·T²` probs term *exactly* under
/// [`ActCkpt::None`].
pub fn kernels(b: &mut Bench) -> Result<()> {
    let mut json = Vec::new();

    // Panel 1 — raw GEMM GFLOP/s (C += A·B).  The naive kind is the
    // strided dot-form reference the identity tests pin bits against; the
    // blocked/SIMD kinds must reproduce those bits while going faster.
    let shapes: &[(usize, usize, usize)] =
        if b.quick { &[(48, 64, 80)] } else { &[(128, 128, 128), (256, 256, 256), (96, 384, 160)] };
    let reps: u32 = if b.quick { 2 } else { 6 };
    let kinds: &[KernelKind] = if kernels::simd_available() {
        &[KernelKind::Naive, KernelKind::Blocked, KernelKind::Simd]
    } else {
        &[KernelKind::Naive, KernelKind::Blocked]
    };
    let mut rows = Vec::new();
    for &(m, k, n) in shapes {
        let a: Vec<f32> =
            (0..m * k).map(|i| ((i * 37 + 11) % 101) as f32 / 101.0 - 0.5).collect();
        let bm: Vec<f32> =
            (0..k * n).map(|i| ((i * 53 + 29) % 97) as f32 / 97.0 - 0.5).collect();
        let mut ref_bits: Option<Vec<u32>> = None;
        let mut naive_gf = 0.0f64;
        for &kind in kinds {
            let mut c = vec![0.0f32; m * n];
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                c.iter_mut().for_each(|x| *x = 0.0);
                kernels::matmul_with(kind, &a, &bm, &mut c, m, k, n);
            }
            let secs = t0.elapsed().as_secs_f64().max(1e-9);
            let gflops = 2.0 * (m * k * n) as f64 * reps as f64 / secs / 1e9;
            let bits: Vec<u32> = c.iter().map(|x| x.to_bits()).collect();
            match &ref_bits {
                None => ref_bits = Some(bits),
                Some(r) => assert_eq!(
                    r, &bits,
                    "{} GEMM diverges bitwise from naive on {m}x{k}x{n}",
                    kind.name()
                ),
            }
            if kind == KernelKind::Naive {
                naive_gf = gflops;
            }
            // The headline perf claim, checked on the default bench shape
            // (big enough that tiling/SIMD dominate fixed overheads).
            if kind == KernelKind::Simd && !b.quick && m * k * n >= 128 * 128 * 128 {
                assert!(
                    gflops >= 3.0 * naive_gf,
                    "blocked+SIMD GEMM must be >= 3x naive on {m}x{k}x{n}: \
                     {gflops:.2} vs {naive_gf:.2} GFLOP/s"
                );
            }
            rows.push(vec![
                format!("{m}x{k}x{n}"),
                kind.name().to_string(),
                format!("{gflops:.2}"),
                format!("{:.2}", gflops / naive_gf.max(1e-12)),
            ]);
            json.push(Value::obj(vec![
                ("panel", "gemm".into()),
                ("shape", format!("{m}x{k}x{n}").into()),
                ("kind", kind.name().into()),
                ("gflops", gflops.into()),
                ("speedup_vs_naive", (gflops / naive_gf.max(1e-12)).into()),
            ]));
        }
    }
    print_table(
        &format!(
            "Kernel layer — raw GEMM throughput (bit-identical across kinds; simd {})",
            if kernels::simd_available() { "on" } else { "off (feature not built)" }
        ),
        &["shape", "kind", "GFLOP/s", "vs naive"],
        &rows,
    );

    // Panels 2+3 — end-to-end per kernel kind: same seeds, same bits,
    // different schedule; the fused kinds never materialize the
    // [B*H, T*T] probs cache, and under `none` checkpointing the measured
    // peak-act delta is exactly that buffer.
    b.rt.set_act_ckpt(ActCkpt::None)?;
    b.rt.set_precision(Precision::F32)?;
    let steps = b.steps(24);
    let mut rows = Vec::new();
    let mut naive_loss = f64::NAN;
    let mut naive_peak = 0u64;
    let mut blocked_peak = 0u64;
    for &kind in kinds {
        b.rt.set_kernels(kind)?;
        let spec = default_spec("hift", steps);
        let rec = b.run_one(&spec, "markovlm", steps, 1)?;
        let loss = rec.losses.tail_mean(8);
        let bk = &rec.backend;
        if kind == KernelKind::Naive {
            naive_loss = loss;
            naive_peak = bk.peak_act_resident_bytes;
        } else {
            assert!(
                loss == naive_loss,
                "{}: final loss {loss} != naive {naive_loss} — kernel kinds must be bit-identical",
                kind.name()
            );
            if kind == KernelKind::Blocked {
                blocked_peak = bk.peak_act_resident_bytes;
            }
        }
        rows.push(vec![
            kind.name().to_string(),
            format!("{:.2}", rec.steps_per_sec),
            format!("{:.2}", bk.kernel_gflops()),
            format!("{:.1}", bk.peak_act_resident_bytes as f64 / 1024.0),
            format!("{loss:.4}"),
        ]);
        json.push(Value::obj(vec![
            ("panel", "e2e".into()),
            ("kind", kind.name().into()),
            ("steps_per_sec", rec.steps_per_sec.into()),
            ("kernel_gflops", bk.kernel_gflops().into()),
            ("kernel_flops", (bk.kernel_flops as usize).into()),
            ("peak_act_resident_bytes", (bk.peak_act_resident_bytes as usize).into()),
            ("final_train_loss", loss.into()),
        ]));
    }
    b.rt.set_kernels(KernelKind::default())?;
    let c = b.rt.manifest().config.clone();
    let probs = native_probs_bytes(c.n_layers, c.batch, c.n_heads, c.seq_len, Precision::F32);
    let delta = naive_peak - blocked_peak;
    assert_eq!(
        delta, probs,
        "fused attention's measured peak-act saving must equal the removed \
         L*B*H*T^2 probs term ({naive_peak} - {blocked_peak} vs {probs})"
    );
    rows.push(vec![
        "(probs saved)".into(),
        "-".into(),
        "-".into(),
        format!("{:.1}", delta as f64 / 1024.0),
        "-".into(),
    ]);
    json.push(Value::obj(vec![
        ("panel", "fused_attn".into()),
        ("measured_saving_bytes", (delta as usize).into()),
        ("analytic_probs_bytes", (probs as usize).into()),
    ]));
    print_table(
        &format!("Kernel layer — end-to-end per kind (HiFT, {steps} steps, ckpt none)"),
        &["kind", "steps/s", "kernel GFLOP/s", "peak act KiB", "final loss"],
        &rows,
    );
    b.save("kernels", &Value::Arr(json))
}

/// Data-parallel sharded execution (`hift bench parallel`): measured step
/// throughput vs worker count N, with the determinism contract checked on
/// every multi-worker run — the loss curve, the final eval, the measured
/// kernel flop total, and `peak_grad_resident_bytes` must all be
/// bit-identical to (resp. exactly equal to) the N=1 serial walk.  The
/// reducer folds per-batch-row partials with the same fixed balanced tree
/// the serial path uses, so the split is invisible in the bits; the emit
/// seam still sees exactly one tensor per site, so grad residency never
/// grows with N.  In full mode on a multi-core host the N=2 run must also
/// clear a ≥ 1.7× step-throughput gate; on a single-core host (or under
/// `HIFT_QUICK`) the measured ratio is reported but not gated, since
/// worker replicas can't overlap without a second core.
pub fn parallel(b: &mut Bench) -> Result<()> {
    let steps = b.steps(32);
    let host_threads = par::max_threads();
    let counts: &[usize] = if b.quick { &[1, 2] } else { &[1, 2, 4] };
    let mut rows = Vec::new();
    let mut json = Vec::new();
    let mut base_sps = f64::NAN;
    let mut base_losses: Vec<f64> = Vec::new();
    let mut base_eval = (f64::NAN, f64::NAN);
    let mut base_grad_peak = 0u64;
    let mut base_flops = 0u64;
    for &n in counts {
        b.rt.set_workers(n)?;
        let spec = default_spec("hift", steps);
        let rec = b.run_one(&spec, "markovlm", steps, 1)?;
        let bk = &rec.backend;
        let speedup;
        if n == 1 {
            base_sps = rec.steps_per_sec;
            base_losses = rec.losses.values.clone();
            base_eval = (rec.final_eval.loss, rec.final_eval.acc);
            base_grad_peak = bk.peak_grad_resident_bytes;
            base_flops = bk.kernel_flops;
            speedup = 1.0;
        } else {
            assert!(
                rec.losses.values == base_losses,
                "workers={n}: loss curve diverged from serial — the sharded walk \
                 must be bit-identical"
            );
            assert!(
                rec.final_eval.loss == base_eval.0 && rec.final_eval.acc == base_eval.1,
                "workers={n}: final eval ({}, {}) != serial ({}, {})",
                rec.final_eval.loss,
                rec.final_eval.acc,
                base_eval.0,
                base_eval.1
            );
            assert_eq!(
                bk.peak_grad_resident_bytes, base_grad_peak,
                "workers={n}: peak grad residency must stay at max-single-tensor"
            );
            assert_eq!(
                bk.kernel_flops, base_flops,
                "workers={n}: measured kernel flop total must equal serial exactly \
                 (same math, different schedule)"
            );
            speedup = rec.steps_per_sec / base_sps.max(1e-12);
            if n == 2 && !b.quick && host_threads >= 2 {
                assert!(
                    speedup >= 1.7,
                    "workers=2 must reach >= 1.7x serial step throughput on a \
                     multi-core host: {:.2} vs {:.2} steps/s ({speedup:.2}x)",
                    rec.steps_per_sec,
                    base_sps
                );
            }
        }
        rows.push(vec![
            n.to_string(),
            format!("{:.2}", rec.steps_per_sec),
            format!("{speedup:.2}"),
            format!("{:.1}", bk.peak_grad_resident_bytes as f64 / 1024.0),
            format!("{:.2}", bk.kernel_gflops()),
            format!("{:.4}", rec.losses.tail_mean(8)),
            format!("{:.3}", rec.final_eval.acc),
        ]);
        json.push(Value::obj(vec![
            ("workers", n.into()),
            ("steps_per_sec", rec.steps_per_sec.into()),
            ("speedup_vs_serial", speedup.into()),
            ("peak_grad_resident_bytes", (bk.peak_grad_resident_bytes as usize).into()),
            ("peak_act_resident_bytes", (bk.peak_act_resident_bytes as usize).into()),
            ("kernel_flops", (bk.kernel_flops as usize).into()),
            ("kernel_gflops", bk.kernel_gflops().into()),
            ("final_train_loss", rec.losses.tail_mean(8).into()),
            ("final_eval_acc", rec.final_eval.acc.into()),
            ("speedup_gated", (n == 2 && !b.quick && host_threads >= 2).into()),
        ]));
    }
    b.rt.set_workers(1)?;
    print_table(
        &format!(
            "Data-parallel workers — measured scaling (HiFT, {steps} steps, \
             host threads {host_threads}{})",
            if b.quick || host_threads < 2 { "; speedup gate skipped" } else { "" }
        ),
        &[
            "workers",
            "steps/s",
            "vs serial",
            "peak grad KiB",
            "kernel GFLOP/s",
            "final loss",
            "eval acc",
        ],
        &rows,
    );

    // Analytic panel — the worker-replica overhead term at paper scale:
    // one shared read-only snapshot (4·P, independent of N) plus the
    // reducer's transient per-row partial buffers.  A step function of
    // "topology on", not a multiple of N.
    let w = Workload { batch: 8, seq: 512 };
    let mut rows = Vec::new();
    for model in ["roberta-large", "llama-7b"] {
        let a = by_name(model).unwrap();
        for n in [1usize, 2, 4, 8] {
            let o = workers_overhead(&a, w, n);
            rows.push(vec![model.to_string(), n.to_string(), format!("{:.3}", o / GIB)]);
            json.push(Value::obj(vec![
                ("panel", "overhead".into()),
                ("model", model.into()),
                ("workers", n.into()),
                ("overhead_bytes", (o as usize).into()),
            ]));
        }
    }
    print_table(
        "Data-parallel workers — analytic replica overhead (b=8 s=512; flat in N)",
        &["model", "workers", "overhead(GiB)"],
        &rows,
    );
    b.save("parallel", &Value::Arr(json))
}

/// Strategy × task-family eval matrix over the forge templates (ISSUE 9):
/// every [`STRATEGY_NAMES`] strategy trains on every
/// [`MATRIX_FAMILIES`] stream at the current preset, and the scoreboard JSON
/// records per-cell quality (final loss / eval acc), residency peaks, kernel
/// throughput, and the stream's diversity / dedup statistics — the
/// MeZO-motivated "rankings flip across task families" regression surface.
pub fn evalmatrix(b: &mut Bench) -> Result<()> {
    let steps = b.steps(32);
    let seed = 1u64;
    let mut rows = Vec::new();
    let mut cells = Vec::new();
    for strat in STRATEGY_NAMES {
        let spec = default_spec(strat, steps);
        let mut row = vec![strat.to_string()];
        for fam in MATRIX_FAMILIES {
            let rec = b.run_one(&spec, fam, steps, seed)?;
            let d = rec.diversity.as_ref().ok_or_else(|| {
                anyhow::anyhow!("forge stream for {fam} recorded no diversity stats")
            })?;
            row.push(format!("{:.2}", rec.final_eval.acc));
            cells.push(Value::obj(vec![
                ("strategy", strat.into()),
                ("task", fam.into()),
                ("steps", (steps as usize).into()),
                ("final_eval_acc", rec.final_eval.acc.into()),
                ("final_eval_loss", rec.final_eval.loss.into()),
                ("final_train_loss", rec.losses.tail_mean(8).into()),
                (
                    "peak_grad_resident_bytes",
                    (rec.backend.peak_grad_resident_bytes as usize).into(),
                ),
                ("peak_act_resident_bytes", (rec.backend.peak_act_resident_bytes as usize).into()),
                ("kernel_gflops", rec.backend.kernel_gflops().into()),
                ("diversity", d.to_json()),
            ]));
        }
        rows.push(row);
    }
    let mut headers: Vec<&str> = vec!["strategy"];
    headers.extend(MATRIX_FAMILIES);
    print_table("Eval matrix — final eval accuracy per strategy × task family", &headers, &rows);
    let board = Value::obj(vec![
        ("schema", "evalmatrix/1".into()),
        ("preset", b.rt.manifest().preset.as_str().into()),
        ("steps", (steps as usize).into()),
        ("strategies", STRATEGY_NAMES.to_vec().into()),
        ("families", MATRIX_FAMILIES.to_vec().into()),
        ("cells", Value::Arr(cells)),
    ]);
    b.save("evalmatrix", &board)
}

/// Appendix-B sanity print: closed-form ratio vs k.
pub fn appendix_b(b: &Bench) -> Result<()> {
    let mut rows = Vec::new();
    for k in [1usize, 2, 4, 8, 14, 26, 34, 42] {
        rows.push(vec![
            k.to_string(),
            format!("{:.3}", crate::memmodel::appendix_b_ratio(k)),
            format!("{:.1}%", (1.0 - crate::memmodel::appendix_b_ratio(k)) * 100.0),
        ]);
    }
    print_table(
        "Appendix B — ζ_hift/ζ_fpft = (k+3)/4k (AdamW, params+grads+state)",
        &["k", "ratio", "savings"],
        &rows,
    );
    let _ = b;
    Ok(())
}
