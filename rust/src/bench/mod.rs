//! Benchmark harnesses — one function per table/figure of the paper's
//! evaluation (DESIGN.md §4 maps each exhibit to its function).
//!
//! Every harness prints the paper-shaped table to stdout and writes the
//! underlying [`RunRecord`]s as JSON under `runs/`.  Absolute numbers come
//! from *our* substrate (small transformers on CPU-PJRT, synthetic tasks,
//! the analytic memory model); what must match the paper is the **shape**:
//! who wins, roughly by how much, where the crossovers are.
//!
//! Env knobs (full inventory: `docs/CLI.md`):
//! * `HIFT_ARTIFACTS` — artifact dir (selects the PJRT backend; needs the
//!   `pjrt` cargo feature).  Unset ⇒ the native CPU backend.
//! * `HIFT_PRESET`    — native-backend geometry (default `tiny`)
//! * `HIFT_ACT_CKPT`  — activation-checkpoint policy (`none|sqrt|every_k(K)`)
//! * `HIFT_OFFLOAD` / `HIFT_OFFLOAD_COMPRESS` / `HIFT_PREFETCH` — host
//!   paging tier (`host|none`, `none|f16`, `1|0`)
//! * `HIFT_QUICK=1`   — trim steps/seeds for smoke runs
//! * `HIFT_OUT`       — output dir for JSON records (default `runs`)

pub mod exhibits;

use std::path::PathBuf;

use anyhow::Result;

use crate::backend::{self, ExecBackend};
use crate::coordinator::trainer::{self, RunRecord, TrainCfg};
use crate::data::{build_task, TaskGeom};
use crate::metrics::Series;
use crate::optim::OptimKind;
use crate::ser::{emit_pretty, Value};
use crate::strategies::StrategySpec;

/// Shared bench context: one backend (compile/upload caches persist across
/// runs), output dir, quick-mode flag.
pub struct Bench {
    pub rt: Box<dyn ExecBackend>,
    pub out_dir: PathBuf,
    pub quick: bool,
}

impl Bench {
    /// Construct from env (see module docs).
    pub fn from_env() -> Result<Self> {
        let out_dir = PathBuf::from(std::env::var("HIFT_OUT").unwrap_or_else(|_| "runs".to_string()));
        std::fs::create_dir_all(&out_dir)?;
        let quick = std::env::var("HIFT_QUICK").map(|v| v == "1").unwrap_or(false);
        Ok(Bench { rt: backend::from_env()?, out_dir, quick })
    }

    pub fn geom(&self) -> TaskGeom {
        let c = &self.rt.manifest().config;
        TaskGeom::new(c.vocab, c.batch, c.seq_len)
    }

    /// Scale a step budget down in quick mode.
    pub fn steps(&self, full: u64) -> u64 {
        if self.quick {
            (full / 8).max(4)
        } else {
            full
        }
    }

    /// Train one (strategy, task, seed) combination.
    pub fn run_one(
        &mut self,
        spec: &StrategySpec,
        task_name: &str,
        steps: u64,
        seed: u64,
    ) -> Result<RunRecord> {
        let mut spec = spec.clone();
        spec.seed = seed;
        spec.total = steps as usize;
        let mut strategy = spec.build(self.rt.manifest())?;
        let mut params = self.rt.load_params(strategy.variant())?;
        let mut task = build_task(task_name, self.geom(), seed)?;
        trainer::train(
            self.rt.as_mut(),
            strategy.as_mut(),
            &mut params,
            task.as_mut(),
            TrainCfg { steps, eval_every: 0, log_every: 0 },
        )
    }

    /// Mean ± std of final eval accuracy over seeds.
    pub fn run_avg(
        &mut self,
        spec: &StrategySpec,
        task: &str,
        steps: u64,
        seeds: &[u64],
    ) -> Result<(f64, f64, Vec<RunRecord>)> {
        let mut accs = Series::new("acc");
        let mut recs = Vec::new();
        for &seed in seeds {
            let r = self.run_one(spec, task, steps, seed)?;
            accs.push(r.final_eval.acc);
            recs.push(r);
        }
        Ok((accs.mean(), accs.std(), recs))
    }

    /// Zero-shot (untrained) accuracy on a task.
    pub fn zero_shot(&mut self, task_name: &str, seed: u64) -> Result<f64> {
        let mut params = self.rt.load_params("base")?;
        let task = build_task(task_name, self.geom(), seed)?;
        let ev =
            trainer::evaluate(self.rt.as_mut(), "fwd_base", &mut params, task.eval_batches())?;
        // With offload on, evaluation parks this throwaway set's masters in
        // the host pool; flush before dropping it so the pool never holds
        // the only copy of a dead set (which would block later mode
        // switches).
        self.rt.flush_offload(&mut params)?;
        Ok(ev.acc)
    }

    /// Persist a JSON exhibit record.
    pub fn save(&self, name: &str, value: &Value) -> Result<()> {
        let path = self.out_dir.join(format!("{name}.json"));
        std::fs::write(&path, emit_pretty(value))?;
        eprintln!("  [saved {}]", path.display());
        Ok(())
    }
}

/// Default per-strategy hyperparameters at tiny/small scale — the analogue
/// of the paper's per-method LR grids (Table 6).
pub fn default_spec(strategy: &str, steps: u64) -> StrategySpec {
    let (optim, lr) = match strategy {
        "hift" | "fpft" | "lomo" => (OptimKind::AdamW, 4e-3),
        "lora" | "ia3" | "prefix" | "bitfit" | "lp" => (OptimKind::AdamW, 1.5e-2),
        // SPSA pseudo-gradients have norm ∝ √N·proj — tiny LRs, like the
        // paper's MeZO grids (1e-6/1e-7 at 13B scale).
        "mezo" => (OptimKind::Sgd, 3e-4),
        "mezo-adam" => (OptimKind::AdamW, 3e-4),
        _ => (OptimKind::AdamW, 4e-3),
    };
    StrategySpec::new(strategy, optim, lr, steps as usize)
}

// ---------------------------------------------------------------------------
// Table rendering
// ---------------------------------------------------------------------------

/// Print an aligned text table (the paper-row format used by all benches).
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
        }
        println!("{}", s.trim_end());
    };
    line(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>());
    for row in rows {
        line(row);
    }
}

/// `mean (std)` accuracy cell in the paper's percent format.
pub fn acc_cell(mean: f64, std: f64) -> String {
    format!("{:.1} ({:.1})", mean * 100.0, std * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_specs_cover_all_strategies() {
        for name in crate::strategies::STRATEGY_NAMES {
            let s = default_spec(name, 100);
            assert_eq!(s.name, name);
            assert!(s.lr > 0.0);
        }
    }

    #[test]
    fn acc_cell_formats_like_paper() {
        assert_eq!(acc_cell(0.919, 0.018), "91.9 (1.8)");
    }
}
