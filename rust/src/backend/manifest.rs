//! Typed view of `artifacts/<preset>/manifest.json` (written by `aot.py`).
//!
//! The manifest is the only contract between the Python compile path and the
//! Rust training path: model geometry, the ordered parameter list with
//! byte offsets into `params.bin`, layer-unit assignments, and the artifact
//! inventory with exact input/output orderings.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::ser::Value;

/// Model geometry (mirrors `ModelConfig` in `python/compile/model.py`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub lora_rank: usize,
    pub lora_alpha: f64,
    pub n_prefix: usize,
}

impl ModelCfg {
    /// Layer units: embeddings + blocks + head (paper §F).
    pub fn n_units(&self) -> usize {
        self.n_layers + 2
    }

    fn from_json(v: &Value) -> Result<Self> {
        let req = |k: &str| -> Result<usize> {
            v.get(k).as_usize().with_context(|| format!("config.{k} missing"))
        };
        Ok(ModelCfg {
            name: v.get("name").as_str().unwrap_or("?").to_string(),
            vocab: req("vocab")?,
            d_model: req("d_model")?,
            n_layers: req("n_layers")?,
            n_heads: req("n_heads")?,
            d_ff: req("d_ff")?,
            seq_len: req("seq_len")?,
            batch: req("batch")?,
            lora_rank: req("lora_rank")?,
            lora_alpha: v.get("lora_alpha").as_f64().unwrap_or(8.0),
            n_prefix: req("n_prefix")?,
        })
    }
}

/// One named parameter tensor: shape, layer unit, offset into the .bin file.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    /// Layer-unit index; `-1` marks PEFT adapter parameters.
    pub unit: i64,
    pub bitfit: bool,
    pub offset: usize,
    pub size: usize,
}

/// A model variant (base / lora / ia3 / prefix) = its full parameter list.
#[derive(Debug, Clone)]
pub struct VariantInfo {
    pub params: Vec<ParamInfo>,
    pub n_base_params: usize,
}

impl VariantInfo {
    /// Indices of parameters belonging to layer unit `u`.
    pub fn unit_indices(&self, u: usize) -> Vec<usize> {
        self.params.iter().enumerate().filter(|(_, p)| p.unit == u as i64).map(|(i, _)| i).collect()
    }

    /// Indices of adapter parameters (unit == -1).
    pub fn adapter_indices(&self) -> Vec<usize> {
        self.params.iter().enumerate().filter(|(_, p)| p.unit == -1).map(|(i, _)| i).collect()
    }

    pub fn bitfit_indices(&self) -> Vec<usize> {
        self.params.iter().enumerate().filter(|(_, p)| p.bitfit).map(|(i, _)| i).collect()
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.size).sum()
    }
}

/// One lowered HLO artifact with its input/output name orderings.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub path: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub kernels: String,
    pub seed: u64,
    pub config: ModelCfg,
    pub n_units: usize,
    /// Keyed by variant name; BTreeMap so any iteration (CLI listings,
    /// synth checks) is deterministic — see docs/CONTRACTS.md (D2).
    pub variants: BTreeMap<String, VariantInfo>,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let v = crate::ser::parse(text).context("manifest.json parse")?;
        if v.get("schema").as_usize() != Some(1) {
            bail!("unsupported manifest schema {:?}", v.get("schema"));
        }
        let config = ModelCfg::from_json(v.get("config"))?;
        let mut variants = BTreeMap::new();
        if let Some(obj) = v.get("variants").as_obj() {
            for (name, vv) in obj.iter() {
                let params = vv
                    .get("params")
                    .as_arr()
                    .context("variant.params")?
                    .iter()
                    .map(parse_param)
                    .collect::<Result<Vec<_>>>()?;
                let n_base_params =
                    vv.get("n_base_params").as_usize().context("n_base_params")?;
                variants.insert(name.clone(), VariantInfo { params, n_base_params });
            }
        }
        let artifacts = v
            .get("artifacts")
            .as_arr()
            .context("artifacts")?
            .iter()
            .map(|a| {
                Ok(ArtifactInfo {
                    name: a.get("name").as_str().context("artifact.name")?.to_string(),
                    path: a.get("path").as_str().context("artifact.path")?.to_string(),
                    inputs: str_arr(a.get("inputs"))?,
                    outputs: str_arr(a.get("outputs"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            preset: v.get("preset").as_str().unwrap_or("?").to_string(),
            kernels: v.get("kernels").as_str().unwrap_or("?").to_string(),
            seed: v.get("seed").as_i64().unwrap_or(0) as u64,
            n_units: v.get("n_units").as_usize().context("n_units")?,
            config,
            variants,
            artifacts,
        })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.iter().map(|a| &a.name).collect::<Vec<_>>()))
    }

    /// f32 bytes of each layer unit's parameters for `variant` (index =
    /// unit id; adapters, unit −1, excluded) — the single source for the
    /// paging tier's residency bounds (bench exhibit and tests derive
    /// "group + walk unit" from this, so they cannot desynchronize).
    pub fn unit_param_bytes(&self, variant: &str) -> Result<Vec<u64>> {
        let vinfo = self.variant(variant)?;
        let mut out = vec![0u64; self.n_units];
        for p in &vinfo.params {
            if p.unit >= 0 {
                out[p.unit as usize] += p.size as u64 * 4;
            }
        }
        Ok(out)
    }

    pub fn variant(&self, name: &str) -> Result<&VariantInfo> {
        self.variants
            .get(name)
            .with_context(|| format!("variant {name:?} not in manifest"))
    }
}

fn parse_param(v: &Value) -> Result<ParamInfo> {
    Ok(ParamInfo {
        name: v.get("name").as_str().context("param.name")?.to_string(),
        shape: v
            .get("shape")
            .as_arr()
            .context("param.shape")?
            .iter()
            .map(|d| d.as_usize().context("dim"))
            .collect::<Result<Vec<_>>>()?,
        unit: v.get("unit").as_i64().context("param.unit")?,
        bitfit: v.get("bitfit").as_bool().unwrap_or(false),
        offset: v.get("offset").as_usize().context("param.offset")?,
        size: v.get("size").as_usize().context("param.size")?,
    })
}

fn str_arr(v: &Value) -> Result<Vec<String>> {
    Ok(v.as_arr()
        .context("string array")?
        .iter()
        .filter_map(|s| s.as_str().map(str::to_string))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "schema": 1, "preset": "t", "kernels": "pallas", "seed": 0,
      "config": {"name":"t","vocab":8,"d_model":4,"n_layers":1,"n_heads":1,
                 "d_ff":8,"seq_len":4,"batch":2,"lora_rank":2,"lora_alpha":8.0,"n_prefix":2},
      "n_units": 3,
      "variants": {"base": {"n_base_params": 2, "params": [
         {"name":"tok_emb","shape":[8,4],"unit":0,"bitfit":false,"offset":0,"size":32},
         {"name":"head.b","shape":[8],"unit":2,"bitfit":true,"offset":128,"size":8}]}},
      "artifacts": [{"name":"fwd_base","path":"fwd_base.hlo.txt",
                     "inputs":["tok_emb","head.b","tokens","targets","weights"],
                     "outputs":["loss","ncorrect"]}]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config.vocab, 8);
        assert_eq!(m.n_units, 3);
        let v = m.variant("base").unwrap();
        assert_eq!(v.params.len(), 2);
        assert_eq!(v.unit_indices(0), vec![0]);
        assert_eq!(v.bitfit_indices(), vec![1]);
        assert_eq!(v.total_params(), 40);
        let a = m.artifact("fwd_base").unwrap();
        assert_eq!(a.inputs.len(), 5);
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.variant("nope").is_err());
    }

    #[test]
    fn rejects_wrong_schema() {
        let bad = SAMPLE.replace("\"schema\": 1", "\"schema\": 9");
        assert!(Manifest::parse(&bad).is_err());
    }
}
