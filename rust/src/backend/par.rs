//! `std::thread` chunking helpers for the native backend's hot loops.
//!
//! Everything here is deterministic regardless of thread count: work is
//! split into disjoint output regions and every output element is produced
//! by a sequential reduction in a fixed order, so a run with
//! `HIFT_THREADS=1` is bit-identical to one with 32 threads — which the
//! equivalence tests rely on.
//!
//! Small inputs fall back to the serial path (spawning threads costs more
//! than a few thousand flops), so the tiny test models pay no overhead.

use std::sync::OnceLock;

/// Minimum flops of per-thread work before a loop is split across threads.
const MIN_FLOPS: usize = 1 << 17;

/// Minimum elements per thread for flat elementwise loops.
const MIN_ELEMS: usize = 1 << 16;

/// Worker count: `HIFT_THREADS` env override, else the machine's parallelism.
pub fn max_threads() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        if let Ok(v) = std::env::var("HIFT_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Split `data` into row-aligned chunks (`row_len` elements per row) and run
/// `f(first_row, chunk)` on each chunk, using up to [`max_threads`] scoped
/// threads.  Runs serially when fewer than `min_rows` rows per thread would
/// be available.
pub fn par_rows<F>(data: &mut [f32], row_len: usize, min_rows: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(row_len > 0 && data.len() % row_len == 0, "data not row-aligned");
    let rows = data.len() / row_len;
    if rows == 0 {
        return;
    }
    let threads = max_threads().min(rows.div_ceil(min_rows.max(1)));
    if threads <= 1 {
        f(0, data);
        return;
    }
    let per = rows.div_ceil(threads);
    std::thread::scope(|s| {
        for (ci, chunk) in data.chunks_mut(per * row_len).enumerate() {
            let f = &f;
            s.spawn(move || f(ci * per, chunk));
        }
    });
}

/// `c += a @ b` for row-major `a: [M,K]`, `b: [K,N]`, `c: [M,N]`, parallel
/// over rows of `c`.
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "matmul: a");
    assert_eq!(b.len(), k * n, "matmul: b");
    assert_eq!(c.len(), m * n, "matmul: c");
    let min_rows = MIN_FLOPS.div_ceil((k * n).max(1));
    par_rows(c, n, min_rows, |r0, cc| {
        for (ri, crow) in cc.chunks_mut(n).enumerate() {
            let i = r0 + ri;
            let arow = &a[i * k..(i + 1) * k];
            for (kk, &aik) in arow.iter().enumerate() {
                if aik != 0.0 {
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (cj, &bj) in crow.iter_mut().zip(brow.iter()) {
                        *cj += aik * bj;
                    }
                }
            }
        }
    });
}

/// `c += aᵀ @ b` for `a: [M,K]`, `b: [M,N]`, `c: [K,N]` — the weight-grad
/// shape (`dW = Xᵀ dY`), parallel over rows of `c`.
pub fn matmul_at(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "matmul_at: a");
    assert_eq!(b.len(), m * n, "matmul_at: b");
    assert_eq!(c.len(), k * n, "matmul_at: c");
    let min_rows = MIN_FLOPS.div_ceil((m * n).max(1));
    par_rows(c, n, min_rows, |r0, cc| {
        for (ri, crow) in cc.chunks_mut(n).enumerate() {
            let kk = r0 + ri;
            for i in 0..m {
                let aik = a[i * k + kk];
                if aik != 0.0 {
                    let brow = &b[i * n..(i + 1) * n];
                    for (cj, &bj) in crow.iter_mut().zip(brow.iter()) {
                        *cj += aik * bj;
                    }
                }
            }
        }
    });
}

/// `c += a @ bᵀ` for `a: [M,K]`, `b: [N,K]`, `c: [M,N]` — the input-grad
/// shape (`dX = dY Wᵀ`), parallel over rows of `c`.
pub fn matmul_bt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "matmul_bt: a");
    assert_eq!(b.len(), n * k, "matmul_bt: b");
    assert_eq!(c.len(), m * n, "matmul_bt: c");
    let min_rows = MIN_FLOPS.div_ceil((k * n).max(1));
    par_rows(c, n, min_rows, |r0, cc| {
        for (ri, crow) in cc.chunks_mut(n).enumerate() {
            let i = r0 + ri;
            let arow = &a[i * k..(i + 1) * k];
            for (j, cj) in crow.iter_mut().enumerate() {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (x, y) in arow.iter().zip(brow.iter()) {
                    acc += x * y;
                }
                *cj += acc;
            }
        }
    });
}

/// Process `n` independent items across threads, where item `i` owns the
/// disjoint slices `a[i*a_item..][..a_item]` and `b[i*b_item..][..b_item]`.
pub fn par_items2<F>(a: &mut [f32], a_item: usize, b: &mut [f32], b_item: usize, f: F)
where
    F: Fn(usize, &mut [f32], &mut [f32]) + Sync,
{
    assert!(a_item > 0 && b_item > 0);
    let n = a.len() / a_item;
    assert_eq!(a.len(), n * a_item, "par_items2: a not item-aligned");
    assert_eq!(b.len(), n * b_item, "par_items2: b item count mismatch");
    if n == 0 {
        return;
    }
    let threads = max_threads().min(n).min((a.len() + b.len()).div_ceil(MIN_ELEMS));
    if threads <= 1 {
        for (i, (ai, bi)) in a.chunks_mut(a_item).zip(b.chunks_mut(b_item)).enumerate() {
            f(i, ai, bi);
        }
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (g, (ac, bc)) in a.chunks_mut(per * a_item).zip(b.chunks_mut(per * b_item)).enumerate()
        {
            let f = &f;
            s.spawn(move || {
                for (j, (ai, bi)) in ac.chunks_mut(a_item).zip(bc.chunks_mut(b_item)).enumerate() {
                    f(g * per + j, ai, bi);
                }
            });
        }
    });
}

/// Three-output variant of [`par_items2`] (attention backward needs dq/dk/dv).
pub fn par_items3<F>(
    a: &mut [f32],
    a_item: usize,
    b: &mut [f32],
    b_item: usize,
    c: &mut [f32],
    c_item: usize,
    f: F,
) where
    F: Fn(usize, &mut [f32], &mut [f32], &mut [f32]) + Sync,
{
    assert!(a_item > 0 && b_item > 0 && c_item > 0);
    let n = a.len() / a_item;
    assert_eq!(a.len(), n * a_item, "par_items3: a not item-aligned");
    assert_eq!(b.len(), n * b_item, "par_items3: b item count mismatch");
    assert_eq!(c.len(), n * c_item, "par_items3: c item count mismatch");
    if n == 0 {
        return;
    }
    let work = a.len() + b.len() + c.len();
    let threads = max_threads().min(n).min(work.div_ceil(MIN_ELEMS));
    if threads <= 1 {
        for (i, ((ai, bi), ci)) in
            a.chunks_mut(a_item).zip(b.chunks_mut(b_item)).zip(c.chunks_mut(c_item)).enumerate()
        {
            f(i, ai, bi, ci);
        }
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (g, ((ac, bc), cc)) in a
            .chunks_mut(per * a_item)
            .zip(b.chunks_mut(per * b_item))
            .zip(c.chunks_mut(per * c_item))
            .enumerate()
        {
            let f = &f;
            s.spawn(move || {
                for (j, ((ai, bi), ci)) in
                    ac.chunks_mut(a_item).zip(bc.chunks_mut(b_item)).zip(cc.chunks_mut(c_item)).enumerate()
                {
                    f(g * per + j, ai, bi, ci);
                }
            });
        }
    });
}

/// Elementwise `f(&mut p[i], g[i])` chunked across threads (SGD-style).
pub fn par_apply2<F>(p: &mut [f32], g: &[f32], f: F)
where
    F: Fn(&mut f32, f32) + Sync,
{
    assert_eq!(p.len(), g.len());
    let n = p.len();
    let threads = max_threads().min(n.div_ceil(MIN_ELEMS));
    if threads <= 1 {
        for (pi, &gi) in p.iter_mut().zip(g.iter()) {
            f(pi, gi);
        }
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (pc, gc) in p.chunks_mut(per).zip(g.chunks(per)) {
            let f = &f;
            s.spawn(move || {
                for (pi, &gi) in pc.iter_mut().zip(gc.iter()) {
                    f(pi, gi);
                }
            });
        }
    });
}

/// Elementwise `f(&mut p[i], &mut s[i], g[i])` (one state buffer: SGDM, Adagrad).
pub fn par_apply3<F>(p: &mut [f32], st: &mut [f32], g: &[f32], f: F)
where
    F: Fn(&mut f32, &mut f32, f32) + Sync,
{
    assert_eq!(p.len(), g.len());
    assert_eq!(p.len(), st.len());
    let n = p.len();
    let threads = max_threads().min(n.div_ceil(MIN_ELEMS));
    if threads <= 1 {
        for i in 0..n {
            f(&mut p[i], &mut st[i], g[i]);
        }
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        for ((pc, sc), gc) in p.chunks_mut(per).zip(st.chunks_mut(per)).zip(g.chunks(per)) {
            let f = &f;
            s.spawn(move || {
                for i in 0..pc.len() {
                    f(&mut pc[i], &mut sc[i], gc[i]);
                }
            });
        }
    });
}

/// Elementwise `f(&mut p[i], &mut m[i], &mut v[i], g[i])` (AdamW).
pub fn par_apply4<F>(p: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], f: F)
where
    F: Fn(&mut f32, &mut f32, &mut f32, f32) + Sync,
{
    assert_eq!(p.len(), g.len());
    assert_eq!(p.len(), m.len());
    assert_eq!(p.len(), v.len());
    let n = p.len();
    let threads = max_threads().min(n.div_ceil(MIN_ELEMS));
    if threads <= 1 {
        for i in 0..n {
            f(&mut p[i], &mut m[i], &mut v[i], g[i]);
        }
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (((pc, mc), vc), gc) in
            p.chunks_mut(per).zip(m.chunks_mut(per)).zip(v.chunks_mut(per)).zip(g.chunks(per))
        {
            let f = &f;
            s.spawn(move || {
                for i in 0..pc.len() {
                    f(&mut pc[i], &mut mc[i], &mut vc[i], gc[i]);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    fn seq(n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|i| ((i * 7 + 3) % 11) as f32 * scale - 0.4).collect()
    }

    #[test]
    fn matmul_matches_naive() {
        let (m, k, n) = (7, 5, 9);
        let a = seq(m * k, 0.1);
        let b = seq(k * n, 0.2);
        let mut c = vec![0.0; m * n];
        matmul(&a, &b, &mut c, m, k, n);
        let want = naive_matmul(&a, &b, m, k, n);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_at_is_transposed_a() {
        let (m, k, n) = (6, 4, 5);
        let a = seq(m * k, 0.3);
        let b = seq(m * n, 0.1);
        // aT: [K,M]
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let want = naive_matmul(&at, &b, k, m, n);
        let mut c = vec![0.0; k * n];
        matmul_at(&a, &b, &mut c, m, k, n);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_bt_is_transposed_b() {
        let (m, k, n) = (3, 6, 4);
        let a = seq(m * k, 0.2);
        let b = seq(n * k, 0.3); // [N,K]
        let mut bt = vec![0.0; k * n];
        for i in 0..n {
            for j in 0..k {
                bt[j * n + i] = b[i * k + j];
            }
        }
        let want = naive_matmul(&a, &bt, m, k, n);
        let mut c = vec![0.0; m * n];
        matmul_bt(&a, &b, &mut c, m, k, n);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn par_rows_covers_every_row_once() {
        let mut data = vec![0.0f32; 13 * 4];
        par_rows(&mut data, 4, 1, |r0, chunk| {
            for (ri, row) in chunk.chunks_mut(4).enumerate() {
                for x in row.iter_mut() {
                    *x += (r0 + ri) as f32;
                }
            }
        });
        for (r, row) in data.chunks(4).enumerate() {
            assert!(row.iter().all(|&x| x == r as f32), "row {r}");
        }
    }

    #[test]
    fn par_items_assign_disjoint_slices() {
        let mut a = vec![0.0f32; 6 * 3];
        let mut b = vec![0.0f32; 6 * 2];
        par_items2(&mut a, 3, &mut b, 2, |i, ai, bi| {
            ai.fill(i as f32);
            bi.fill(-(i as f32));
        });
        for (i, chunk) in a.chunks(3).enumerate() {
            assert!(chunk.iter().all(|&x| x == i as f32));
        }
        for (i, chunk) in b.chunks(2).enumerate() {
            assert!(chunk.iter().all(|&x| x == -(i as f32)));
        }
    }

    #[test]
    fn par_apply_updates_every_element() {
        let mut p = vec![1.0f32; 100];
        let g: Vec<f32> = (0..100).map(|i| i as f32).collect();
        par_apply2(&mut p, &g, |pi, gi| *pi += gi);
        for (i, x) in p.iter().enumerate() {
            assert_eq!(*x, 1.0 + i as f32);
        }
    }
}
