//! `std::thread` chunking helpers and the shared thread budget for the
//! native backend's hot loops.
//!
//! Everything here is deterministic regardless of thread count: work is
//! split into disjoint output regions and every output element is produced
//! by a sequential reduction in a fixed order, so a run with
//! `HIFT_THREADS=1` is bit-identical to one with 32 threads — which the
//! equivalence tests rely on.
//!
//! Small inputs fall back to the serial path (spawning threads costs more
//! than a few thousand flops), so the tiny test models pay no overhead.
//! [`par_rows`] derives its serial cutoff from the caller-supplied
//! per-row cost rather than a fixed row count, so cheap rows (tiny GELU
//! chunks) and expensive rows (wide GEMM panels) both land near the same
//! flops-per-spawn break-even point.
//!
//! ## The thread budget
//!
//! All helpers draw spawned threads from one process-wide
//! [`ThreadBudget`] capped at [`max_threads`] (`HIFT_THREADS` env).
//! Long-lived worker threads — the pipelined optimizer's update thread —
//! [`register_worker`] themselves against the same budget, so when an
//! optimizer update runs concurrently with the backward walk the two
//! sides *share* the cap instead of each assuming they own the machine
//! (the oversubscription bug this replaces).  Leasing is lock-free and
//! never blocks: a caller always keeps at least its own thread, so the
//! worst contention outcome is a serial loop, never a stall.  The budget
//! changes only *how many* threads split the work, and chunk boundaries
//! are data-independent per call site — never correctness or bits within
//! one call (each output element's reduction order is fixed regardless).
//!
//! The GEMM entry points ([`matmul`], [`matmul_at`], [`matmul_bt`]) are
//! thin wrappers routing to the active [`super::kernels`] schedule
//! (naive reference, cache-blocked, or blocked+SIMD — all bit-identical
//! in f32; see the kernel module's reduction-order guarantee).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use super::kernels;

/// Minimum flops of per-thread work before a loop is split across threads.
const MIN_FLOPS: usize = 1 << 17;

/// Minimum elements per thread for flat elementwise loops.
const MIN_ELEMS: usize = 1 << 16;

/// Worker count: `HIFT_THREADS` env override, else the machine's parallelism.
pub fn max_threads() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        if let Ok(v) = std::env::var("HIFT_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// A shared cap on concurrently running threads.  `in_flight` counts
/// threads beyond the callers' own: lease extras plus registered workers.
pub struct ThreadBudget {
    cap: usize,
    in_flight: AtomicUsize,
}

impl ThreadBudget {
    pub const fn new(cap: usize) -> Self {
        ThreadBudget { cap, in_flight: AtomicUsize::new(0) }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Extra threads (lease grants + registered workers) currently charged
    /// against the budget.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// Try to reserve up to `want` concurrent threads (including the
    /// calling thread, which is always granted).  Never blocks: under
    /// contention the grant shrinks, bottoming out at 1 (serial).  The
    /// reservation is released when the [`Lease`] drops.
    pub fn lease(&self, want: usize) -> Lease<'_> {
        let want = want.max(1);
        let mut cur = self.in_flight.load(Ordering::Relaxed);
        loop {
            // The caller occupies one slot itself; extras come from what's
            // left after every other lease/worker in flight.
            let avail = self.cap.saturating_sub(1 + cur);
            let extra = (want - 1).min(avail);
            match self.in_flight.compare_exchange_weak(
                cur,
                cur + extra,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Lease { budget: self, extra },
                Err(seen) => cur = seen,
            }
        }
    }

    /// Charge one long-lived worker thread against the budget until the
    /// returned guard drops (the pipelined optimizer's update thread).
    pub fn register_worker(&self) -> WorkerSlot<'_> {
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        WorkerSlot { budget: self }
    }
}

/// A temporary thread reservation; see [`ThreadBudget::lease`].
pub struct Lease<'a> {
    budget: &'a ThreadBudget,
    extra: usize,
}

impl Lease<'_> {
    /// Total threads this lease allows, calling thread included (≥ 1).
    pub fn granted(&self) -> usize {
        1 + self.extra
    }
}

impl Drop for Lease<'_> {
    fn drop(&mut self) {
        if self.extra > 0 {
            let prev = self.budget.in_flight.fetch_sub(self.extra, Ordering::Relaxed);
            // Contracts (HIFT_CHECK): a release larger than what is in
            // flight means some lease was double-released or never charged
            // — the budget would wrap and oversubscribe every later grant.
            if crate::contracts::enabled() {
                assert!(
                    prev >= self.extra,
                    "ThreadBudget lease imbalance: releasing {} with only {prev} in flight",
                    self.extra
                );
            }
        }
    }
}

/// RAII registration of a long-lived worker thread; see
/// [`ThreadBudget::register_worker`].
pub struct WorkerSlot<'a> {
    budget: &'a ThreadBudget,
}

impl Drop for WorkerSlot<'_> {
    fn drop(&mut self) {
        let prev = self.budget.in_flight.fetch_sub(1, Ordering::Relaxed);
        // Contracts (HIFT_CHECK): same wrap hazard as the Lease drop.
        if crate::contracts::enabled() {
            assert!(prev >= 1, "ThreadBudget worker slot released with nothing in flight");
        }
    }
}

/// The process-wide budget every helper in this module draws from,
/// capped at [`max_threads`].
fn budget() -> &'static ThreadBudget {
    static B: OnceLock<ThreadBudget> = OnceLock::new();
    B.get_or_init(|| ThreadBudget::new(max_threads()))
}

/// Register a long-lived worker thread against the process-wide budget.
/// Call on the *spawning* thread and move the guard into the worker, so
/// the slot is charged before the worker's first instruction.
pub fn register_worker() -> WorkerSlot<'static> {
    budget().register_worker()
}

/// Extra threads currently charged against the process-wide budget
/// (observability for the oversubscription regression tests).
pub fn budget_in_flight() -> usize {
    budget().in_flight()
}

/// Split `data` into row-aligned chunks (`row_len` elements per row) and run
/// `f(first_row, chunk)` on each chunk, using threads leased from the shared
/// budget.  `row_cost` is the approximate flops (or elements touched) per
/// row; rows are grouped so each thread gets at least ~[`MIN_FLOPS`] of
/// work, and anything cheaper runs serially on the calling thread.
pub fn par_rows<F>(data: &mut [f32], row_len: usize, row_cost: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(row_len > 0 && data.len() % row_len == 0, "data not row-aligned");
    let rows = data.len() / row_len;
    if rows == 0 {
        return;
    }
    let min_rows = MIN_FLOPS.div_ceil(row_cost.max(1)).max(1);
    let want = max_threads().min(rows.div_ceil(min_rows));
    if want <= 1 {
        f(0, data);
        return;
    }
    let lease = budget().lease(want);
    let threads = lease.granted();
    if threads <= 1 {
        f(0, data);
        return;
    }
    let per = rows.div_ceil(threads);
    std::thread::scope(|s| {
        for (ci, chunk) in data.chunks_mut(per * row_len).enumerate() {
            let f = &f;
            s.spawn(move || f(ci * per, chunk));
        }
    });
}

/// `c += a @ b` for row-major `a: [M,K]`, `b: [K,N]`, `c: [M,N]` under the
/// active kernel schedule (see [`super::kernels`]).
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    kernels::matmul_with(kernels::kind(), a, b, c, m, k, n);
}

/// `c += aᵀ @ b` for `a: [M,K]`, `b: [M,N]`, `c: [K,N]` — the weight-grad
/// shape (`dW = Xᵀ dY`) — under the active kernel schedule.
pub fn matmul_at(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    kernels::matmul_at_with(kernels::kind(), a, b, c, m, k, n);
}

/// `c += a @ bᵀ` for `a: [M,K]`, `b: [N,K]`, `c: [M,N]` — the input-grad
/// shape (`dX = dY Wᵀ`) — under the active kernel schedule.
pub fn matmul_bt(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    kernels::matmul_bt_with(kernels::kind(), a, b, c, m, k, n);
}

/// Process `n` independent items across threads, where item `i` owns the
/// disjoint slices `a[i*a_item..][..a_item]` and `b[i*b_item..][..b_item]`.
pub fn par_items2<F>(a: &mut [f32], a_item: usize, b: &mut [f32], b_item: usize, f: F)
where
    F: Fn(usize, &mut [f32], &mut [f32]) + Sync,
{
    assert!(a_item > 0 && b_item > 0);
    let n = a.len() / a_item;
    assert_eq!(a.len(), n * a_item, "par_items2: a not item-aligned");
    assert_eq!(b.len(), n * b_item, "par_items2: b item count mismatch");
    if n == 0 {
        return;
    }
    let want = max_threads().min(n).min((a.len() + b.len()).div_ceil(MIN_ELEMS));
    let lease = if want > 1 { Some(budget().lease(want)) } else { None };
    let threads = lease.as_ref().map_or(1, Lease::granted);
    if threads <= 1 {
        for (i, (ai, bi)) in a.chunks_mut(a_item).zip(b.chunks_mut(b_item)).enumerate() {
            f(i, ai, bi);
        }
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (g, (ac, bc)) in a.chunks_mut(per * a_item).zip(b.chunks_mut(per * b_item)).enumerate()
        {
            let f = &f;
            s.spawn(move || {
                for (j, (ai, bi)) in ac.chunks_mut(a_item).zip(bc.chunks_mut(b_item)).enumerate() {
                    f(g * per + j, ai, bi);
                }
            });
        }
    });
}

/// Three-output variant of [`par_items2`] (attention backward needs dq/dk/dv).
pub fn par_items3<F>(
    a: &mut [f32],
    a_item: usize,
    b: &mut [f32],
    b_item: usize,
    c: &mut [f32],
    c_item: usize,
    f: F,
) where
    F: Fn(usize, &mut [f32], &mut [f32], &mut [f32]) + Sync,
{
    assert!(a_item > 0 && b_item > 0 && c_item > 0);
    let n = a.len() / a_item;
    assert_eq!(a.len(), n * a_item, "par_items3: a not item-aligned");
    assert_eq!(b.len(), n * b_item, "par_items3: b item count mismatch");
    assert_eq!(c.len(), n * c_item, "par_items3: c item count mismatch");
    if n == 0 {
        return;
    }
    let work = a.len() + b.len() + c.len();
    let want = max_threads().min(n).min(work.div_ceil(MIN_ELEMS));
    let lease = if want > 1 { Some(budget().lease(want)) } else { None };
    let threads = lease.as_ref().map_or(1, Lease::granted);
    if threads <= 1 {
        for (i, ((ai, bi), ci)) in
            a.chunks_mut(a_item).zip(b.chunks_mut(b_item)).zip(c.chunks_mut(c_item)).enumerate()
        {
            f(i, ai, bi, ci);
        }
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (g, ((ac, bc), cc)) in a
            .chunks_mut(per * a_item)
            .zip(b.chunks_mut(per * b_item))
            .zip(c.chunks_mut(per * c_item))
            .enumerate()
        {
            let f = &f;
            s.spawn(move || {
                for (j, ((ai, bi), ci)) in
                    ac.chunks_mut(a_item).zip(bc.chunks_mut(b_item)).zip(cc.chunks_mut(c_item)).enumerate()
                {
                    f(g * per + j, ai, bi, ci);
                }
            });
        }
    });
}

/// Elementwise `f(&mut p[i], g[i])` chunked across threads (SGD-style).
pub fn par_apply2<F>(p: &mut [f32], g: &[f32], f: F)
where
    F: Fn(&mut f32, f32) + Sync,
{
    assert_eq!(p.len(), g.len());
    let n = p.len();
    let want = max_threads().min(n.div_ceil(MIN_ELEMS));
    let lease = if want > 1 { Some(budget().lease(want)) } else { None };
    let threads = lease.as_ref().map_or(1, Lease::granted);
    if threads <= 1 {
        for (pi, &gi) in p.iter_mut().zip(g.iter()) {
            f(pi, gi);
        }
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (pc, gc) in p.chunks_mut(per).zip(g.chunks(per)) {
            let f = &f;
            s.spawn(move || {
                for (pi, &gi) in pc.iter_mut().zip(gc.iter()) {
                    f(pi, gi);
                }
            });
        }
    });
}

/// Elementwise `f(&mut p[i], &mut s[i], g[i])` (one state buffer: SGDM, Adagrad).
pub fn par_apply3<F>(p: &mut [f32], st: &mut [f32], g: &[f32], f: F)
where
    F: Fn(&mut f32, &mut f32, f32) + Sync,
{
    assert_eq!(p.len(), g.len());
    assert_eq!(p.len(), st.len());
    let n = p.len();
    let want = max_threads().min(n.div_ceil(MIN_ELEMS));
    let lease = if want > 1 { Some(budget().lease(want)) } else { None };
    let threads = lease.as_ref().map_or(1, Lease::granted);
    if threads <= 1 {
        for i in 0..n {
            f(&mut p[i], &mut st[i], g[i]);
        }
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        for ((pc, sc), gc) in p.chunks_mut(per).zip(st.chunks_mut(per)).zip(g.chunks(per)) {
            let f = &f;
            s.spawn(move || {
                for i in 0..pc.len() {
                    f(&mut pc[i], &mut sc[i], gc[i]);
                }
            });
        }
    });
}

/// Elementwise `f(&mut p[i], &mut m[i], &mut v[i], g[i])` (AdamW).
pub fn par_apply4<F>(p: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], f: F)
where
    F: Fn(&mut f32, &mut f32, &mut f32, f32) + Sync,
{
    assert_eq!(p.len(), g.len());
    assert_eq!(p.len(), m.len());
    assert_eq!(p.len(), v.len());
    let n = p.len();
    let want = max_threads().min(n.div_ceil(MIN_ELEMS));
    let lease = if want > 1 { Some(budget().lease(want)) } else { None };
    let threads = lease.as_ref().map_or(1, Lease::granted);
    if threads <= 1 {
        for i in 0..n {
            f(&mut p[i], &mut m[i], &mut v[i], g[i]);
        }
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (((pc, mc), vc), gc) in
            p.chunks_mut(per).zip(m.chunks_mut(per)).zip(v.chunks_mut(per)).zip(g.chunks(per))
        {
            let f = &f;
            s.spawn(move || {
                for i in 0..pc.len() {
                    f(&mut pc[i], &mut mc[i], &mut vc[i], gc[i]);
                }
            });
        }
    });
}

/// Chunked variant of [`par_apply4`]: `f` receives whole equal-length
/// sub-slices instead of single elements, so callers can run vectorized
/// kernels over each chunk (the AdamW update path).
pub fn par_chunks4<F>(p: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], f: F)
where
    F: Fn(&mut [f32], &mut [f32], &mut [f32], &[f32]) + Sync,
{
    assert_eq!(p.len(), g.len());
    assert_eq!(p.len(), m.len());
    assert_eq!(p.len(), v.len());
    let n = p.len();
    if n == 0 {
        return;
    }
    let want = max_threads().min(n.div_ceil(MIN_ELEMS));
    let lease = if want > 1 { Some(budget().lease(want)) } else { None };
    let threads = lease.as_ref().map_or(1, Lease::granted);
    if threads <= 1 {
        f(p, m, v, g);
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (((pc, mc), vc), gc) in
            p.chunks_mut(per).zip(m.chunks_mut(per)).zip(v.chunks_mut(per)).zip(g.chunks(per))
        {
            let f = &f;
            s.spawn(move || f(pc, mc, vc, gc));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    fn seq(n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|i| ((i * 7 + 3) % 11) as f32 * scale - 0.4).collect()
    }

    #[test]
    fn matmul_matches_naive() {
        let (m, k, n) = (7, 5, 9);
        let a = seq(m * k, 0.1);
        let b = seq(k * n, 0.2);
        let mut c = vec![0.0; m * n];
        matmul(&a, &b, &mut c, m, k, n);
        let want = naive_matmul(&a, &b, m, k, n);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_at_is_transposed_a() {
        let (m, k, n) = (6, 4, 5);
        let a = seq(m * k, 0.3);
        let b = seq(m * n, 0.1);
        // aT: [K,M]
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let want = naive_matmul(&at, &b, k, m, n);
        let mut c = vec![0.0; k * n];
        matmul_at(&a, &b, &mut c, m, k, n);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn matmul_bt_is_transposed_b() {
        let (m, k, n) = (3, 6, 4);
        let a = seq(m * k, 0.2);
        let b = seq(n * k, 0.3); // [N,K]
        let mut bt = vec![0.0; k * n];
        for i in 0..n {
            for j in 0..k {
                bt[j * n + i] = b[i * k + j];
            }
        }
        let want = naive_matmul(&a, &bt, m, k, n);
        let mut c = vec![0.0; m * n];
        matmul_bt(&a, &b, &mut c, m, k, n);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn par_rows_covers_every_row_once() {
        let mut data = vec![0.0f32; 13 * 4];
        // row_cost = MIN_FLOPS makes min_rows 1, the old many-thread split.
        par_rows(&mut data, 4, MIN_FLOPS, |r0, chunk| {
            for (ri, row) in chunk.chunks_mut(4).enumerate() {
                for x in row.iter_mut() {
                    *x += (r0 + ri) as f32;
                }
            }
        });
        for (r, row) in data.chunks(4).enumerate() {
            assert!(row.iter().all(|&x| x == r as f32), "row {r}");
        }
    }

    #[test]
    fn par_rows_cheap_rows_take_serial_fast_path() {
        use std::sync::Mutex;
        // 8 rows × cost 8 flops ≪ MIN_FLOPS: must be exactly one serial
        // call spanning the whole buffer, regardless of HIFT_THREADS.
        let calls = Mutex::new(Vec::new());
        let mut data = vec![0.0f32; 8 * 4];
        par_rows(&mut data, 4, 8, |r0, chunk| {
            calls.lock().unwrap().push((r0, chunk.len()));
        });
        assert_eq!(*calls.lock().unwrap(), vec![(0, 32)]);
    }

    #[test]
    fn thread_budget_grants_within_cap() {
        let b = ThreadBudget::new(4);
        let l1 = b.lease(4);
        assert_eq!(l1.granted(), 4, "caller + 3 extras fit the cap");
        assert_eq!(b.in_flight(), 3);
        let l2 = b.lease(4);
        assert_eq!(l2.granted(), 1, "budget exhausted: caller thread only");
        drop(l2);
        drop(l1);
        assert_eq!(b.in_flight(), 0, "drops release the reservation");
        let l3 = b.lease(2);
        assert_eq!(l3.granted(), 2);
    }

    #[test]
    fn registered_worker_shrinks_leases() {
        let b = ThreadBudget::new(4);
        let w = b.register_worker();
        assert_eq!(b.in_flight(), 1);
        let l = b.lease(8);
        // cap 4 − worker 1 − caller 1 = 2 extras.
        assert_eq!(l.granted(), 3);
        drop(l);
        drop(w);
        assert_eq!(b.in_flight(), 0);
    }

    #[test]
    fn lease_always_grants_the_calling_thread() {
        let b = ThreadBudget::new(1);
        let w = b.register_worker();
        let l = b.lease(16);
        assert_eq!(l.granted(), 1, "even a saturated budget grants the caller");
        drop(l);
        drop(w);
    }

    /// Mutation test for the lease-balance contract: hand-build guards that
    /// release more than was ever charged — the double-release / spurious
    /// worker-exit mutants — and assert the drop guards kill them by name.
    #[test]
    #[cfg(feature = "contracts")]
    fn unbalanced_release_is_caught() {
        if !crate::contracts::enabled() {
            return; // HIFT_CHECK=0 disarms the drop guards
        }
        let panic_message = |f: Box<dyn FnOnce() + Send>| -> String {
            let p = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
                .expect_err("the unbalanced release must not pass");
            p.downcast_ref::<String>()
                .cloned()
                .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_default()
        };
        let fresh = ThreadBudget::new(8);
        let msg = panic_message(Box::new(move || {
            drop(Lease { budget: &fresh, extra: 3 });
        }));
        assert!(msg.contains("ThreadBudget lease imbalance"), "{msg}");
        let fresh = ThreadBudget::new(8);
        let msg = panic_message(Box::new(move || {
            drop(WorkerSlot { budget: &fresh });
        }));
        assert!(msg.contains("worker slot released with nothing in flight"), "{msg}");
    }

    #[test]
    fn par_items_assign_disjoint_slices() {
        let mut a = vec![0.0f32; 6 * 3];
        let mut b = vec![0.0f32; 6 * 2];
        par_items2(&mut a, 3, &mut b, 2, |i, ai, bi| {
            ai.fill(i as f32);
            bi.fill(-(i as f32));
        });
        for (i, chunk) in a.chunks(3).enumerate() {
            assert!(chunk.iter().all(|&x| x == i as f32));
        }
        for (i, chunk) in b.chunks(2).enumerate() {
            assert!(chunk.iter().all(|&x| x == -(i as f32)));
        }
    }

    #[test]
    fn par_apply_updates_every_element() {
        let mut p = vec![1.0f32; 100];
        let g: Vec<f32> = (0..100).map(|i| i as f32).collect();
        par_apply2(&mut p, &g, |pi, gi| *pi += gi);
        for (i, x) in p.iter().enumerate() {
            assert_eq!(*x, 1.0 + i as f32);
        }
    }

    #[test]
    fn par_chunks4_covers_every_element() {
        let n = 100;
        let mut p = vec![0.0f32; n];
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        let g: Vec<f32> = (0..n).map(|i| i as f32).collect();
        par_chunks4(&mut p, &mut m, &mut v, &g, |pc, mc, vc, gc| {
            for i in 0..pc.len() {
                pc[i] += gc[i];
                mc[i] += 1.0;
                vc[i] += 2.0;
            }
        });
        for i in 0..n {
            assert_eq!(p[i], i as f32);
            assert_eq!(m[i], 1.0);
            assert_eq!(v[i], 2.0);
        }
    }
}
