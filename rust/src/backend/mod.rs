//! Pluggable execution backends and the streamed-gradient seam.
//!
//! HiFT is backend-independent: the coordinator only needs, per step, the
//! loss/metrics and the *active group's* gradients for a named artifact
//! (paper §1).  This module owns that seam:
//!
//! * [`ExecBackend`] — the trait every engine implements.  The primitive
//!   operation is [`ExecBackend::run_streamed`]: execute an artifact
//!   against a [`crate::tensor::TensorSet`] + [`Batch`] and *stream* each
//!   gradient into a [`GradSink`] the moment it is final, instead of
//!   collecting the whole group into a `Vec<Tensor>`.  [`ExecBackend::run`]
//!   is a provided method that collects the stream back into the classic
//!   [`StepOutput`] (forward-only and MeZO paths).
//! * [`GradSink`] — the consumer side of the stream: fused optimizer
//!   updates ([`crate::optim::FusedApply`]), collection ([`CollectSink`]),
//!   or the double-buffered pipeline ([`crate::optim::PipelinedApply`]).
//! * [`manifest`] — the artifact/parameter contract shared by all backends
//!   (for PJRT it is parsed from `manifest.json`; the native backend
//!   synthesizes an identical one).
//! * [`native`] — the default implementation: a pure-Rust decoder-only
//!   transformer with hand-written forward/backward ([`model`]), so the
//!   whole training loop builds, tests and benches offline.
//! * `crate::runtime` (behind the `pjrt` cargo feature) — the XLA/PJRT
//!   implementation executing AOT-compiled HLO artifacts; it adapts to the
//!   streaming contract with a post-execute drain.
//! * [`par`] — `std::thread` chunking used by the native hot paths and the
//!   optimizer update loops.
//!
//! Strategies, the trainer, the benches and the CLI all take
//! `&mut dyn ExecBackend`, so switching engines is a constructor choice
//! ([`build_backend`] / [`from_env`]), not a code change.
//!
//! ## Emit-order determinism
//!
//! Every backend must emit gradients in a **fixed, deterministic order**
//! for a given artifact, and tag each with its `slot` — the gradient's
//! index in the artifact's output list — so sinks never depend on arrival
//! order for *placement*.  The native backend emits in backward-walk
//! order: the head unit first, then transformer layers top-down, then the
//! embedding unit, with each unit's tensors in manifest parameter order
//! (adapter gradients follow their layer's base tensors).  This is a fixed
//! permutation of the artifact output order.  Because optimizer updates
//! are per-tensor (no update reads another trainable tensor), applying
//! updates in emit order yields **bit-identical** final parameters to the
//! old collect-then-update path — asserted in `tests/streaming.rs`.
//!
//! A sink may mutate `params` from [`GradSink::grad`], but only tensors
//! whose gradient has already been emitted in the current run; backends
//! guarantee they never read a parameter tensor again after emitting its
//! gradient.

pub mod kernels;
pub mod manifest;
pub mod model;
pub mod native;
pub mod par;
pub mod shard;

use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::optim::ScalerEvent;
use crate::tensor::paged::OffloadCounters;
use crate::tensor::{Tensor, TensorSet};
pub use crate::tensor::half::Precision;
pub use crate::tensor::paged::{Compression, OffloadCfg};
pub use kernels::KernelKind;
pub use manifest::{ArtifactInfo, Manifest, ModelCfg, ParamInfo, VariantInfo};
pub use native::{NativeBackend, PRESET_NAMES};

/// Activation-checkpointing policy for the backward-capable backends.
///
/// Under a recompute policy the forward pass retains only **layer-boundary
/// residual streams** (one `[B·T, D]` tensor per checkpointed layer) instead
/// of every layer's internal activation cache; the backward walk rebuilds
/// each layer's internals from its boundary just before that layer's
/// gradients are emitted (`model::recompute_layer`).  Recompute replays
/// the exact forward arithmetic (fixed-order reductions, no RNG), so
/// gradients — and therefore whole training runs — are bit-identical to the
/// cache-everything path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ActCkpt {
    /// Cache every layer's internals (no recompute).
    #[default]
    None,
    /// Keep a boundary every `k` layers (`k = 1` ⇒ boundary at every layer,
    /// internals always recomputed).  Non-boundary inputs are rebuilt by
    /// chaining the residual stream forward from the previous boundary.
    EveryK(usize),
    /// `every_k(⌈√L⌉)` — the classic O(√L) memory / one-extra-forward
    /// compromise (Chen et al., 2016).
    Sqrt,
}

impl ActCkpt {
    /// Parse `"none"`, `"sqrt"`, `"every_k(K)"` (also `"every_k=K"` or a
    /// bare integer `K`).
    pub fn parse(s: &str) -> Result<ActCkpt> {
        let t = s.trim().to_ascii_lowercase();
        match t.as_str() {
            "none" | "off" | "0" => return Ok(ActCkpt::None),
            "sqrt" => return Ok(ActCkpt::Sqrt),
            _ => {}
        }
        let k_str = t
            .strip_prefix("every_k(")
            .and_then(|r| r.strip_suffix(')'))
            .or_else(|| t.strip_prefix("every_k="))
            .unwrap_or(&t);
        let k: usize = k_str
            .parse()
            .map_err(|_| anyhow::anyhow!("bad act-ckpt policy {s:?} (none|sqrt|every_k(K))"))?;
        if k == 0 {
            bail!("act-ckpt every_k(0) is meaningless; use k >= 1 or `none`");
        }
        Ok(ActCkpt::EveryK(k))
    }

    pub fn name(&self) -> String {
        match self {
            ActCkpt::None => "none".to_string(),
            ActCkpt::EveryK(k) => format!("every_k({k})"),
            ActCkpt::Sqrt => "sqrt".to_string(),
        }
    }

    /// Boundary spacing for a model with `n_layers` blocks; `None` when the
    /// policy keeps full caches (no recompute).
    pub fn seg_len(&self, n_layers: usize) -> Option<usize> {
        match *self {
            ActCkpt::None => None,
            ActCkpt::EveryK(k) => Some(k.max(1)),
            ActCkpt::Sqrt => {
                let mut k = 1usize;
                while k * k < n_layers {
                    k += 1;
                }
                Some(k.max(1))
            }
        }
    }

    /// Is layer `i`'s input residual stream a stored checkpoint?
    pub fn is_boundary(&self, i: usize, n_layers: usize) -> bool {
        match self.seg_len(n_layers) {
            None => false,
            Some(k) => i % k == 0,
        }
    }
}

/// One training/eval batch, shaped `[B, S]` row-major.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub weights: Vec<f32>,
    pub b: usize,
    pub s: usize,
}

impl Batch {
    pub fn new(b: usize, s: usize) -> Self {
        Batch { tokens: vec![0; b * s], targets: vec![0; b * s], weights: vec![0.0; b * s], b, s }
    }

    pub fn validate(&self) -> Result<()> {
        let n = self.b * self.s;
        if self.tokens.len() != n || self.targets.len() != n || self.weights.len() != n {
            bail!("batch buffers disagree with [{}x{}]", self.b, self.s);
        }
        Ok(())
    }

    /// Host→device bytes of one batch upload, from the actual buffer
    /// element sizes (tokens/targets i32 + weights f32) — the single source
    /// both backends account with, so stats stay honest if dtypes diverge.
    pub fn h2d_bytes(&self) -> usize {
        self.tokens.len() * std::mem::size_of::<i32>()
            + self.targets.len() * std::mem::size_of::<i32>()
            + self.weights.len() * std::mem::size_of::<f32>()
    }
}

/// Result of one executed step (collected form; see [`StreamOutput`] for
/// the streamed form).
#[derive(Debug)]
pub struct StepOutput {
    pub loss: f32,
    /// Masked #correct (paired with the batch's weight sum for accuracy).
    pub ncorrect: f32,
    /// Gradients in artifact output order (empty for `fwd_*`).
    pub grads: Vec<Tensor>,
    /// Wallclock of the backend execute call.
    pub exec_time: Duration,
}

/// Result of one streamed step: the scalars only — gradients went to the
/// [`GradSink`] and were dropped as they were consumed.
#[derive(Debug, Clone, Copy)]
pub struct StreamOutput {
    pub loss: f32,
    /// Masked #correct (paired with the batch's weight sum for accuracy).
    pub ncorrect: f32,
    /// Wallclock of the backend execute call (forward + streamed backward).
    pub exec_time: Duration,
}

/// Consumer of a gradient stream (the strategy side of the seam).
///
/// The backend calls [`GradSink::grad`] once per gradient output, the
/// moment that gradient is final, then [`GradSink::finish`] once after the
/// last emission.  `slot` is the gradient's index in the artifact's output
/// list (or, for [`ExecBackend::run_group_streamed`], in the concatenated
/// unit gradient lists); `name` is the parameter name for sanity checks.
///
/// `params` is the same set the artifact ran with.  A sink may update it
/// in place (fused optimizer updates), but only tensors whose gradients
/// were already emitted in this run — the backend guarantees it no longer
/// reads those.
pub trait GradSink {
    /// Consume one gradient.  Ownership transfers to the sink; dropping it
    /// immediately is what shrinks peak gradient residency from the group
    /// sum to a single tensor.
    fn grad(
        &mut self,
        slot: usize,
        name: &str,
        grad: Tensor,
        params: &mut TensorSet,
    ) -> Result<()>;

    /// Gradient bytes the sink still retains after the last `grad` call
    /// returned (for peak-residency accounting).  Fused sinks return 0.
    fn resident_bytes(&self) -> u64 {
        0
    }

    /// Called once after the final emission of a run (lets pipelined sinks
    /// drain in-flight work and restore borrowed tensors).
    fn finish(&mut self, _params: &mut TensorSet) -> Result<()> {
        Ok(())
    }
}

/// A [`GradSink`] that collects the stream back into artifact output
/// order — the compatibility shim behind the provided [`ExecBackend::run`].
#[derive(Default)]
pub struct CollectSink {
    slots: Vec<Option<Tensor>>,
    bytes: u64,
}

impl CollectSink {
    /// The collected gradients, densely ordered by slot.
    pub fn into_grads(self) -> Result<Vec<Tensor>> {
        self.slots
            .into_iter()
            .enumerate()
            .map(|(i, t)| t.ok_or_else(|| anyhow::anyhow!("gradient slot {i} was never emitted")))
            .collect()
    }
}

impl GradSink for CollectSink {
    fn grad(
        &mut self,
        slot: usize,
        name: &str,
        grad: Tensor,
        _params: &mut TensorSet,
    ) -> Result<()> {
        if self.slots.len() <= slot {
            self.slots.resize_with(slot + 1, || None);
        }
        self.bytes += grad.bytes() as u64;
        if self.slots[slot].replace(grad).is_some() {
            bail!("gradient slot {slot} ({name}) emitted twice");
        }
        Ok(())
    }

    fn resident_bytes(&self) -> u64 {
        self.bytes
    }
}

/// Cumulative execution statistics (perf pass bookkeeping).  `h2d`/`d2h` and
/// the cache counters are real device traffic under PJRT and simulated
/// (same accounting rules) under the native backend, so bench columns stay
/// comparable.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub executions: u64,
    pub exec_secs: f64,
    pub compiles: u64,
    pub compile_secs: f64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    /// Parameter uploads skipped thanks to the device-buffer cache.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Peak bytes of parameter gradients resident at once (in-flight
    /// emission + whatever the sink retained).  Streamed fused updates hold
    /// this at ≈ one tensor; the collected path holds the whole group.
    /// Accumulates until [`ExecBackend::reset_run_peaks`] — the trainer
    /// resets it at run start so `RunRecord` peaks are per-run.
    pub peak_grad_resident_bytes: u64,
    /// Peak bytes of **activations retained across layer-walk steps**:
    /// cached layer internals (policy [`ActCkpt::None`]), boundary residual
    /// streams + recompute scratch (checkpointing policies), and the
    /// head-stage buffers.  The single layer being recomputed during
    /// backward is transient working memory — freed before the walk moves
    /// on, like the backward pass's own gradient temporaries — and is not
    /// part of this cache.  Reset per run like the grad peak.
    pub peak_act_resident_bytes: u64,
    /// Layer forward passes re-run during backward under a recompute
    /// policy (0 when the forward cached everything).
    pub recompute_layers: u64,
    /// Estimated flops spent on those recomputations (dense matmuls +
    /// attention forms; adapter extras excluded).
    pub recompute_flops: u64,
    /// Flops executed by the kernel layer (GEMM + attention inner loops),
    /// **measured** at the kernel entry points — not modeled from shapes.
    /// Divide by `kernel_nanos` for achieved GFLOP/s
    /// ([`RuntimeStats::kernel_gflops`]).
    pub kernel_flops: u64,
    /// Wall nanoseconds spent inside those kernels (sum over calls; under
    /// threading this is span time per call, not CPU time).
    pub kernel_nanos: u64,
    /// Host-paging page-in events (tensors admitted back into the arena).
    /// All `offload_*`/`prefetch_*` fields are zero when `--offload` is
    /// off; they mirror the paging tier's [`crate::optim::OffloadLedger`].
    pub offload_page_ins: u64,
    /// Host-paging page-out events (tensors evicted to the host pool).
    pub offload_page_outs: u64,
    /// Bytes paged host → device (full f32 arena size of admitted pages).
    pub offload_h2d_bytes: u64,
    /// Bytes paged device → host.
    pub offload_d2h_bytes: u64,
    /// Peak bytes of paged parameter *masters* resident in the arena at
    /// once — the **enforced** residency of the paper's Table 5 claim
    /// (active group + the transient walk unit), measured from real
    /// evictions/admissions rather than modeled.  Reset per run.
    pub peak_param_resident_bytes: u64,
    /// Peak bytes posted to the prefetch double buffer (in-flight or
    /// landed-but-unadmitted page-ins).  Reset per run.
    pub peak_prefetch_buffer_bytes: u64,
    /// Current / peak host-tier footprint of evicted pages (compressed
    /// bytes — f16 mode halves this).  `host_pool_bytes` is a gauge.
    pub host_pool_bytes: u64,
    pub peak_host_pool_bytes: u64,
    /// Page-ins served instantly because the prefetch had already landed.
    pub prefetch_hits: u64,
    /// Page-ins that blocked the walk (every sync-mode page-in is one).
    pub prefetch_misses: u64,
    /// Nanoseconds the walk spent stalled waiting for page-ins.
    pub prefetch_stall_nanos: u64,
    /// Gradients that arrived at an update sink with a NaN/Inf norm (their
    /// updates were skipped — the numerics safety net; see
    /// [`crate::optim::FusedApply`]).
    pub nonfinite_grad_tensors: u64,
    /// Whole steps dropped atomically because a gradient was non-finite
    /// (the f16 loss-scaler's skip-step path).
    pub nonfinite_grad_steps: u64,
    /// Loss-scale doublings / halvings performed by the dynamic scaler.
    pub loss_scale_growths: u64,
    pub loss_scale_backoffs: u64,
    /// Current loss scale (gauge; 0 = scaler never engaged, 1 = unscaled).
    pub loss_scale: f64,
}

impl RuntimeStats {
    /// Per-run view: additive counters since `start`; peak fields carry the
    /// current value, since a max cannot be subtracted (callers that need a
    /// clean per-run peak reset it first via
    /// [`ExecBackend::reset_run_peaks`], as the trainer does).
    pub fn since(&self, start: &RuntimeStats) -> RuntimeStats {
        RuntimeStats {
            executions: self.executions - start.executions,
            exec_secs: self.exec_secs - start.exec_secs,
            compiles: self.compiles - start.compiles,
            compile_secs: self.compile_secs - start.compile_secs,
            h2d_bytes: self.h2d_bytes - start.h2d_bytes,
            d2h_bytes: self.d2h_bytes - start.d2h_bytes,
            cache_hits: self.cache_hits - start.cache_hits,
            cache_misses: self.cache_misses - start.cache_misses,
            peak_grad_resident_bytes: self.peak_grad_resident_bytes,
            peak_act_resident_bytes: self.peak_act_resident_bytes,
            recompute_layers: self.recompute_layers - start.recompute_layers,
            recompute_flops: self.recompute_flops - start.recompute_flops,
            kernel_flops: self.kernel_flops - start.kernel_flops,
            kernel_nanos: self.kernel_nanos - start.kernel_nanos,
            offload_page_ins: self.offload_page_ins - start.offload_page_ins,
            offload_page_outs: self.offload_page_outs - start.offload_page_outs,
            offload_h2d_bytes: self.offload_h2d_bytes - start.offload_h2d_bytes,
            offload_d2h_bytes: self.offload_d2h_bytes - start.offload_d2h_bytes,
            peak_param_resident_bytes: self.peak_param_resident_bytes,
            peak_prefetch_buffer_bytes: self.peak_prefetch_buffer_bytes,
            host_pool_bytes: self.host_pool_bytes,
            peak_host_pool_bytes: self.peak_host_pool_bytes,
            prefetch_hits: self.prefetch_hits - start.prefetch_hits,
            prefetch_misses: self.prefetch_misses - start.prefetch_misses,
            prefetch_stall_nanos: self.prefetch_stall_nanos - start.prefetch_stall_nanos,
            nonfinite_grad_tensors: self.nonfinite_grad_tensors - start.nonfinite_grad_tensors,
            nonfinite_grad_steps: self.nonfinite_grad_steps - start.nonfinite_grad_steps,
            loss_scale_growths: self.loss_scale_growths - start.loss_scale_growths,
            loss_scale_backoffs: self.loss_scale_backoffs - start.loss_scale_backoffs,
            loss_scale: self.loss_scale,
        }
    }

    /// Achieved kernel-layer throughput in GFLOP/s (measured flops over
    /// measured span time; 0 when no kernel ran).
    pub fn kernel_gflops(&self) -> f64 {
        if self.kernel_nanos == 0 {
            0.0
        } else {
            self.kernel_flops as f64 / self.kernel_nanos as f64
        }
    }

    /// Fold a pager counter delta (before → after one execution or flush)
    /// into the cumulative stats.  Counts are additive deltas; gauges take
    /// the pager's current values.  Peaks fold only when `include_peaks` —
    /// executions fold them, while flush/repage (checkpoint bookkeeping
    /// that deliberately materializes the whole arena) do not, so the
    /// reported peak stays the *training-walk* residency.  (The pager's
    /// own peaks are reset with [`ExecBackend::reset_run_peaks`].)
    pub(crate) fn apply_offload(
        &mut self,
        before: &OffloadCounters,
        after: &OffloadCounters,
        include_peaks: bool,
    ) {
        self.offload_page_ins += after.page_ins.saturating_sub(before.page_ins);
        self.offload_page_outs += after.page_outs.saturating_sub(before.page_outs);
        self.offload_h2d_bytes += after.h2d_bytes.saturating_sub(before.h2d_bytes);
        self.offload_d2h_bytes += after.d2h_bytes.saturating_sub(before.d2h_bytes);
        self.prefetch_hits += after.prefetch_hits.saturating_sub(before.prefetch_hits);
        self.prefetch_misses += after.prefetch_misses.saturating_sub(before.prefetch_misses);
        self.prefetch_stall_nanos +=
            after.stall_nanos.saturating_sub(before.stall_nanos);
        if include_peaks {
            self.peak_param_resident_bytes =
                self.peak_param_resident_bytes.max(after.peak_param_resident_bytes);
            self.peak_prefetch_buffer_bytes =
                self.peak_prefetch_buffer_bytes.max(after.peak_prefetch_buffer_bytes);
        }
        self.host_pool_bytes = after.host_bytes;
        self.peak_host_pool_bytes = self.peak_host_pool_bytes.max(after.peak_host_bytes);
    }

    /// Fold one residency observation into the peak.
    pub(crate) fn note_grad_resident(&mut self, bytes: u64) {
        self.peak_grad_resident_bytes = self.peak_grad_resident_bytes.max(bytes);
    }

    /// Fold one activation-residency observation into the peak.
    pub(crate) fn note_act_resident(&mut self, bytes: u64) {
        self.peak_act_resident_bytes = self.peak_act_resident_bytes.max(bytes);
    }
}

/// An execution engine for the manifest's artifacts.
///
/// The primitive is [`ExecBackend::run_streamed`] — execute an artifact and
/// hand each gradient to a [`GradSink`] the moment it is final (see the
/// module docs for the emit-order determinism guarantee).  Implementations
/// also own the parameter upload cache keyed on `(TensorSet lineage,
/// version)` — the §Perf optimization that stops every step from
/// re-marshalling the (mostly frozen) model.
pub trait ExecBackend {
    /// Short engine id (`"native"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// Human-readable platform string.
    fn platform(&self) -> String;

    /// The artifact/parameter contract this backend serves.
    fn manifest(&self) -> &Manifest;

    /// Execute `artifact` with `params` (must match the artifact's input
    /// order prefix) and a batch, streaming each gradient into `sink` as
    /// soon as it is final.  `params` is `&mut` so sinks can fuse optimizer
    /// updates in place; the backend itself never mutates it.  Implementors
    /// must call `sink.finish(params)` after the last emission.
    fn run_streamed(
        &mut self,
        artifact: &str,
        params: &mut TensorSet,
        batch: &Batch,
        sink: &mut dyn GradSink,
    ) -> Result<StreamOutput>;

    /// Execute `artifact` and collect the gradient stream back into the
    /// classic `(loss, ncorrect, grads…)` output (forward-only and MeZO
    /// paths, tests).  Provided in terms of [`ExecBackend::run_streamed`].
    fn run(&mut self, artifact: &str, params: &mut TensorSet, batch: &Batch) -> Result<StepOutput> {
        let mut sink = CollectSink::default();
        let out = self.run_streamed(artifact, params, batch, &mut sink)?;
        Ok(StepOutput {
            loss: out.loss,
            ncorrect: out.ncorrect,
            grads: sink.into_grads()?,
            exec_time: out.exec_time,
        })
    }

    /// Execute the gradients of a *group* of base-model layer units in one
    /// logical step, streaming into `sink`.  Slots index the concatenation
    /// of the units' parameter lists in the order given by `units`.
    ///
    /// All gradients are taken at the *same* parameter point (Eq. (2)'s
    /// joint group update), even though the sink may update each unit's
    /// tensors as they stream.  The native backend honors this with a
    /// single multi-unit backward pass (one forward instead of one per
    /// unit); the default implementation falls back to collected per-unit
    /// artifact runs drained afterwards, which preserves the same
    /// parameter-point semantics at collected-path memory cost.
    fn run_group_streamed(
        &mut self,
        units: &[usize],
        params: &mut TensorSet,
        batch: &Batch,
        sink: &mut dyn GradSink,
    ) -> Result<StreamOutput> {
        let names: Vec<String> = {
            let vinfo = self.manifest().variant("base")?;
            units
                .iter()
                .flat_map(|&u| {
                    vinfo
                        .params
                        .iter()
                        .filter(|p| p.unit == u as i64)
                        .map(|p| p.name.clone())
                        .collect::<Vec<_>>()
                })
                .collect()
        };
        let mut exec_time = Duration::ZERO;
        let mut loss = 0.0f32;
        let mut ncorrect = 0.0f32;
        let mut grads: Vec<Tensor> = Vec::with_capacity(names.len());
        for (gi, &u) in units.iter().enumerate() {
            let out = self.run(&unit_artifact(u), params, batch)?;
            exec_time += out.exec_time;
            if gi == 0 {
                loss = out.loss;
                ncorrect = out.ncorrect;
            }
            grads.extend(out.grads);
        }
        if grads.len() != names.len() {
            bail!("group run produced {} grads for {} params", grads.len(), names.len());
        }
        // Honest accounting: this fallback materialized the whole group
        // before draining, so its residency peak is the collected sum.
        let collected: u64 = grads.iter().map(|g| g.bytes() as u64).sum();
        self.note_grad_residency(collected + sink.resident_bytes());
        for (slot, (name, g)) in names.iter().zip(grads).enumerate() {
            sink.grad(slot, name, g, params)?;
        }
        sink.finish(params)?;
        Ok(StreamOutput { loss, ncorrect, exec_time })
    }

    /// Record a gradient-residency observation (bytes held at once) into
    /// this backend's [`RuntimeStats`].  Backends with stats override this;
    /// the default is a no-op so stat-less test doubles stay trivial.
    fn note_grad_residency(&mut self, _bytes: u64) {}

    /// Select the activation-checkpointing policy for subsequent runs.
    /// Backends without a recompute path (PJRT artifacts are compiled with
    /// their caching baked in; test doubles) accept only [`ActCkpt::None`].
    fn set_act_ckpt(&mut self, policy: ActCkpt) -> Result<()> {
        if policy != ActCkpt::None {
            bail!(
                "backend {:?} does not support activation checkpointing (policy {})",
                self.name(),
                policy.name()
            );
        }
        Ok(())
    }

    /// The active activation-checkpointing policy.
    fn act_ckpt(&self) -> ActCkpt {
        ActCkpt::None
    }

    /// Select the kernel implementation for subsequent runs
    /// (`--kernels naive|blocked|simd`).  Backends without the native
    /// kernel layer (PJRT artifacts ship their own compiled kernels; test
    /// doubles) accept only the default [`KernelKind::Blocked`].
    fn set_kernels(&mut self, kind: KernelKind) -> Result<()> {
        if kind != KernelKind::default() {
            bail!(
                "backend {:?} has no selectable kernel layer (kind {})",
                self.name(),
                kind.name()
            );
        }
        Ok(())
    }

    /// Select the compute precision for subsequent runs
    /// (`--precision f32|bf16|f16`): forward activations, backward
    /// intermediates and pre-upcast gradients run at this width while
    /// parameter masters and optimizer state stay f32.  Backends without a
    /// reduced-precision path (PJRT artifacts bake their dtypes in at
    /// compile time; test doubles) accept only [`Precision::F32`].
    fn set_precision(&mut self, prec: Precision) -> Result<()> {
        if prec != Precision::F32 {
            bail!(
                "backend {:?} has no reduced-precision compute path (precision {})",
                self.name(),
                prec.name()
            );
        }
        Ok(())
    }

    /// The active compute precision.
    fn precision(&self) -> Precision {
        Precision::F32
    }

    /// Install the loss scale for subsequent grad runs (the f16 dynamic
    /// scaler's per-step value; meaningful only when
    /// [`Precision::needs_loss_scaling`]).  Backends that never scale may
    /// ignore it.
    fn set_loss_scale(&mut self, _scale: f32) {}

    /// The loss scale the next grad run's backward seed will carry.
    fn loss_scale(&self) -> f32 {
        1.0
    }

    /// Record non-finite-gradient events into this backend's
    /// [`RuntimeStats`] (`nonfinite_grad_tensors` / `nonfinite_grad_steps`).
    /// Strategies call it after each step with what their sink observed.
    fn note_numerics(&mut self, _nonfinite_grads: u64, _step_skipped: bool) {}

    /// Record the dynamic loss scaler's current scale and grow/backoff
    /// transition into [`RuntimeStats`].
    fn note_loss_scale(&mut self, _scale: f32, _event: ScalerEvent) {}

    /// Configure the host-memory paging tier (`--offload host`): inactive
    /// HiFT groups' parameter masters physically leave the arena into a
    /// host pool and return on demand during the walk (see
    /// [`crate::tensor::paged`]).  Backends without a paging tier (PJRT —
    /// device residency is the runtime's business; test doubles) accept
    /// only a disabled config.
    fn set_offload(&mut self, cfg: OffloadCfg) -> Result<()> {
        if cfg.enabled {
            bail!("backend {:?} has no host paging tier (offload {})", self.name(), cfg.name());
        }
        Ok(())
    }

    /// The active offload configuration.
    fn offload(&self) -> OffloadCfg {
        OffloadCfg::default()
    }

    /// Page every evicted master back into `params` (checkpoint saves and
    /// end-of-run hand-off need the full set materialized; a no-op when
    /// offload is off or the pager is attached to a different set).  The
    /// materialization spike is bookkeeping, not training residency, and is
    /// excluded from the reported peaks.
    fn flush_offload(&mut self, _params: &mut TensorSet) -> Result<()> {
        Ok(())
    }

    /// Undo a [`ExecBackend::flush_offload`]: page the managed masters back
    /// out to the host and reset the pager's peak gauges to the re-evicted
    /// level, so a mid-run checkpoint save neither leaves the whole model
    /// arena-resident nor pollutes the measured training peaks.  No-op
    /// without a paging tier.
    fn repage_offload(&mut self, _params: &mut TensorSet) -> Result<()> {
        Ok(())
    }

    /// Stage the scheduler's *next* group in the paging tier: async
    /// page-ins are posted now (their decompression overlaps the current
    /// step's compute) and the staged units survive the end-of-run
    /// eviction, so the next step starts with its active group already
    /// arena-resident — cross-step double-buffering, at the residency cost
    /// of one extra group ("one group + one prefetch buffer").  Replaces
    /// any previous staging set; coalesced with the walk's one-unit-ahead
    /// prefetch; no-op without a paging tier, in synchronous mode, or
    /// before the pager first attaches.
    fn prefetch_units(&mut self, _units: &[usize]) {}

    /// Reset per-run peak statistics (`peak_grad_resident_bytes`).  The
    /// trainer calls this at run start so each [`crate::coordinator::trainer::RunRecord`]
    /// reports its own peak rather than the lifetime maximum of a shared
    /// backend.
    fn reset_run_peaks(&mut self) {}

    /// Configure data-parallel sharded execution (`--workers`/
    /// `HIFT_WORKERS`): each run's batch splits across `n` worker replicas
    /// whose gradients are combined by a deterministic tree all-reduce at
    /// the emit seam — bit-identical to serial for any `n` (see
    /// [`shard`]).  Backends without a worker topology accept only `n <=
    /// 1`.
    fn set_workers(&mut self, n: usize) -> Result<()> {
        if n > 1 {
            bail!("backend {:?} has no data-parallel worker support (workers {n})", self.name());
        }
        Ok(())
    }

    /// The configured worker-replica count (1 = serial).
    fn workers(&self) -> usize {
        1
    }

    /// Initial parameters for `variant`.
    fn load_params(&self, variant: &str) -> Result<TensorSet>;

    /// Prepare a set of artifacts ahead of time (compile caches etc.).
    fn warmup(&mut self, _artifacts: &[&str]) -> Result<()> {
        Ok(())
    }

    /// Cumulative execution statistics.
    fn stats(&self) -> &RuntimeStats;
}

/// Grad-artifact name for one layer unit of the base model.
pub fn unit_artifact(u: usize) -> String {
    format!("grad_base_u{u}")
}

/// Construct a backend: an artifact directory selects PJRT (requires the
/// `pjrt` cargo feature), otherwise the native backend with the given
/// preset (default `tiny`).
pub fn build_backend(
    artifacts: Option<&str>,
    preset: Option<&str>,
    seed: u64,
) -> Result<Box<dyn ExecBackend>> {
    if let Some(dir) = artifacts {
        #[cfg(feature = "pjrt")]
        {
            return Ok(Box::new(crate::runtime::Runtime::load(dir)?));
        }
        #[cfg(not(feature = "pjrt"))]
        {
            bail!(
                "artifact dir {dir:?} requested but this build has no PJRT engine; \
                 rebuild with `--features pjrt` or drop the artifacts flag to use \
                 the native backend"
            );
        }
    }
    Ok(Box::new(NativeBackend::preset(preset.unwrap_or("tiny"), seed)?))
}

/// [`build_backend`] from the environment: `HIFT_ARTIFACTS` (PJRT),
/// `HIFT_PRESET` (native geometry, default `tiny`), `HIFT_SEED`,
/// `HIFT_ACT_CKPT` (activation-checkpoint policy: `none|sqrt|every_k(K)`),
/// `HIFT_PRECISION` (compute precision: `f32|bf16|f16`),
/// `HIFT_KERNELS` (kernel layer: `naive|blocked|simd`),
/// `HIFT_OFFLOAD`/`HIFT_OFFLOAD_COMPRESS`/`HIFT_PREFETCH` (host paging
/// tier: `host|none`, `f16|none`, `1|0`),
/// `HIFT_WORKERS` (data-parallel worker replicas, default 1).
pub fn from_env() -> Result<Box<dyn ExecBackend>> {
    // Empty values mean "unset" — `HIFT_ARTIFACTS= hift …` must fall back
    // to the native backend, not request PJRT with an empty dir.
    let artifacts = std::env::var("HIFT_ARTIFACTS").ok().filter(|s| !s.is_empty());
    let preset = std::env::var("HIFT_PRESET").ok().filter(|s| !s.is_empty());
    let seed = std::env::var("HIFT_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0);
    let mut be = build_backend(artifacts.as_deref(), preset.as_deref(), seed)?;
    if let Some(p) = std::env::var("HIFT_ACT_CKPT").ok().filter(|s| !s.is_empty()) {
        be.set_act_ckpt(ActCkpt::parse(&p)?)?;
    }
    if let Some(p) = std::env::var("HIFT_PRECISION").ok().filter(|s| !s.is_empty()) {
        be.set_precision(Precision::parse(&p)?)?;
    }
    if let Some(p) = std::env::var("HIFT_KERNELS").ok().filter(|s| !s.is_empty()) {
        be.set_kernels(KernelKind::parse(&p)?)?;
    }
    let offload = OffloadCfg::from_env()?;
    if offload.enabled {
        be.set_offload(offload)?;
    }
    if let Some(w) = std::env::var("HIFT_WORKERS").ok().filter(|s| !s.is_empty()) {
        let n: usize = w.parse().with_context(|| format!("bad HIFT_WORKERS {w:?}"))?;
        be.set_workers(n)?;
    }
    Ok(be)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_validation() {
        let b = Batch::new(2, 3);
        assert!(b.validate().is_ok());
        let mut bad = Batch::new(2, 3);
        bad.tokens.pop();
        assert!(bad.validate().is_err());
    }

    #[test]
    fn unit_artifact_names() {
        assert_eq!(unit_artifact(0), "grad_base_u0");
        assert_eq!(unit_artifact(13), "grad_base_u13");
    }

    #[test]
    fn build_backend_defaults_to_native_tiny() {
        let be = build_backend(None, None, 0).unwrap();
        assert_eq!(be.name(), "native");
        assert_eq!(be.manifest().preset, "tiny");
        let be = build_backend(None, Some("small"), 1).unwrap();
        assert_eq!(be.manifest().preset, "small");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn artifacts_without_pjrt_is_a_clear_error() {
        let err = build_backend(Some("artifacts/tiny"), None, 0).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn act_ckpt_parse_roundtrip() {
        assert_eq!(ActCkpt::parse("none").unwrap(), ActCkpt::None);
        assert_eq!(ActCkpt::parse("sqrt").unwrap(), ActCkpt::Sqrt);
        assert_eq!(ActCkpt::parse("every_k(3)").unwrap(), ActCkpt::EveryK(3));
        assert_eq!(ActCkpt::parse("every_k=2").unwrap(), ActCkpt::EveryK(2));
        assert_eq!(ActCkpt::parse("4").unwrap(), ActCkpt::EveryK(4));
        assert!(ActCkpt::parse("every_k(0)").is_err());
        assert!(ActCkpt::parse("bogus").is_err());
        for p in [ActCkpt::None, ActCkpt::Sqrt, ActCkpt::EveryK(2)] {
            assert_eq!(ActCkpt::parse(&p.name()).unwrap(), p);
        }
    }

    #[test]
    fn act_ckpt_boundaries() {
        assert_eq!(ActCkpt::None.seg_len(8), None);
        assert_eq!(ActCkpt::EveryK(2).seg_len(8), Some(2));
        assert_eq!(ActCkpt::Sqrt.seg_len(2), Some(2));
        assert_eq!(ActCkpt::Sqrt.seg_len(6), Some(3));
        assert_eq!(ActCkpt::Sqrt.seg_len(12), Some(4));
        assert!(ActCkpt::EveryK(2).is_boundary(0, 8));
        assert!(!ActCkpt::EveryK(2).is_boundary(1, 8));
        assert!(ActCkpt::EveryK(2).is_boundary(2, 8));
        assert!(!ActCkpt::None.is_boundary(0, 8));
    }
}
