//! Pluggable execution backends.
//!
//! HiFT is backend-independent: the coordinator only needs, per step, the
//! loss/metrics and the *active group's* gradients for a named artifact
//! (paper §1).  This module owns that seam:
//!
//! * [`ExecBackend`] — the trait every engine implements: run an artifact
//!   against a [`crate::tensor::TensorSet`] + [`Batch`] and hand back
//!   `(loss, ncorrect, grads…)`, plus parameter loading and upload-cache
//!   accounting ([`RuntimeStats`]).
//! * [`manifest`] — the artifact/parameter contract shared by all backends
//!   (for PJRT it is parsed from `manifest.json`; the native backend
//!   synthesizes an identical one).
//! * [`native`] — the default implementation: a pure-Rust decoder-only
//!   transformer with hand-written forward/backward ([`model`]), so the
//!   whole training loop builds, tests and benches offline.
//! * `crate::runtime` (behind the `pjrt` cargo feature) — the XLA/PJRT
//!   implementation executing AOT-compiled HLO artifacts.
//! * [`par`] — `std::thread` chunking used by the native hot paths and the
//!   optimizer update loops.
//!
//! Strategies, the trainer, the benches and the CLI all take
//! `&mut dyn ExecBackend`, so switching engines is a constructor choice
//! ([`build_backend`] / [`from_env`]), not a code change.

pub mod manifest;
pub mod model;
pub mod native;
pub mod par;

use std::time::Duration;

use anyhow::{bail, Result};

use crate::tensor::{Tensor, TensorSet};
pub use manifest::{ArtifactInfo, Manifest, ModelCfg, ParamInfo, VariantInfo};
pub use native::NativeBackend;

/// One training/eval batch, shaped `[B, S]` row-major.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub weights: Vec<f32>,
    pub b: usize,
    pub s: usize,
}

impl Batch {
    pub fn new(b: usize, s: usize) -> Self {
        Batch { tokens: vec![0; b * s], targets: vec![0; b * s], weights: vec![0.0; b * s], b, s }
    }

    pub fn validate(&self) -> Result<()> {
        let n = self.b * self.s;
        if self.tokens.len() != n || self.targets.len() != n || self.weights.len() != n {
            bail!("batch buffers disagree with [{}x{}]", self.b, self.s);
        }
        Ok(())
    }

    /// Host→device bytes of one batch upload, from the actual buffer
    /// element sizes (tokens/targets i32 + weights f32) — the single source
    /// both backends account with, so stats stay honest if dtypes diverge.
    pub fn h2d_bytes(&self) -> usize {
        self.tokens.len() * std::mem::size_of::<i32>()
            + self.targets.len() * std::mem::size_of::<i32>()
            + self.weights.len() * std::mem::size_of::<f32>()
    }
}

/// Result of one executed step.
#[derive(Debug)]
pub struct StepOutput {
    pub loss: f32,
    /// Masked #correct (paired with the batch's weight sum for accuracy).
    pub ncorrect: f32,
    /// Gradients in artifact output order (empty for `fwd_*`).
    pub grads: Vec<Tensor>,
    /// Wallclock of the backend execute call.
    pub exec_time: Duration,
}

/// Cumulative execution statistics (perf pass bookkeeping).  `h2d`/`d2h` and
/// the cache counters are real device traffic under PJRT and simulated
/// (same accounting rules) under the native backend, so bench columns stay
/// comparable.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub executions: u64,
    pub exec_secs: f64,
    pub compiles: u64,
    pub compile_secs: f64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    /// Parameter uploads skipped thanks to the device-buffer cache.
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// An execution engine for the manifest's artifacts.
///
/// Implementations own "run artifact → `(loss, ncorrect, grads…)`" plus the
/// parameter upload cache keyed on `(TensorSet lineage, version)` — the
/// §Perf optimization that stops every step from re-marshalling the
/// (mostly frozen) model.
pub trait ExecBackend {
    /// Short engine id (`"native"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// Human-readable platform string.
    fn platform(&self) -> String;

    /// The artifact/parameter contract this backend serves.
    fn manifest(&self) -> &Manifest;

    /// Execute `artifact` with `params` (must match the artifact's input
    /// order prefix) and a batch; returns `(loss, ncorrect, grads…)`.
    fn run(&mut self, artifact: &str, params: &TensorSet, batch: &Batch) -> Result<StepOutput>;

    /// Initial parameters for `variant`.
    fn load_params(&self, variant: &str) -> Result<TensorSet>;

    /// Prepare a set of artifacts ahead of time (compile caches etc.).
    fn warmup(&mut self, _artifacts: &[&str]) -> Result<()> {
        Ok(())
    }

    /// Cumulative execution statistics.
    fn stats(&self) -> &RuntimeStats;
}

/// Grad-artifact name for one layer unit of the base model.
pub fn unit_artifact(u: usize) -> String {
    format!("grad_base_u{u}")
}

/// Construct a backend: an artifact directory selects PJRT (requires the
/// `pjrt` cargo feature), otherwise the native backend with the given
/// preset (default `tiny`).
pub fn build_backend(
    artifacts: Option<&str>,
    preset: Option<&str>,
    seed: u64,
) -> Result<Box<dyn ExecBackend>> {
    if let Some(dir) = artifacts {
        #[cfg(feature = "pjrt")]
        {
            return Ok(Box::new(crate::runtime::Runtime::load(dir)?));
        }
        #[cfg(not(feature = "pjrt"))]
        {
            bail!(
                "artifact dir {dir:?} requested but this build has no PJRT engine; \
                 rebuild with `--features pjrt` or drop the artifacts flag to use \
                 the native backend"
            );
        }
    }
    Ok(Box::new(NativeBackend::preset(preset.unwrap_or("tiny"), seed)?))
}

/// [`build_backend`] from the environment: `HIFT_ARTIFACTS` (PJRT),
/// `HIFT_PRESET` (native geometry, default `tiny`), `HIFT_SEED`.
pub fn from_env() -> Result<Box<dyn ExecBackend>> {
    // Empty values mean "unset" — `HIFT_ARTIFACTS= hift …` must fall back
    // to the native backend, not request PJRT with an empty dir.
    let artifacts = std::env::var("HIFT_ARTIFACTS").ok().filter(|s| !s.is_empty());
    let preset = std::env::var("HIFT_PRESET").ok().filter(|s| !s.is_empty());
    let seed = std::env::var("HIFT_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0);
    build_backend(artifacts.as_deref(), preset.as_deref(), seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_validation() {
        let b = Batch::new(2, 3);
        assert!(b.validate().is_ok());
        let mut bad = Batch::new(2, 3);
        bad.tokens.pop();
        assert!(bad.validate().is_err());
    }

    #[test]
    fn unit_artifact_names() {
        assert_eq!(unit_artifact(0), "grad_base_u0");
        assert_eq!(unit_artifact(13), "grad_base_u13");
    }

    #[test]
    fn build_backend_defaults_to_native_tiny() {
        let be = build_backend(None, None, 0).unwrap();
        assert_eq!(be.name(), "native");
        assert_eq!(be.manifest().preset, "tiny");
        let be = build_backend(None, Some("small"), 1).unwrap();
        assert_eq!(be.manifest().preset, "small");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn artifacts_without_pjrt_is_a_clear_error() {
        let err = build_backend(Some("artifacts/tiny"), None, 0).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
