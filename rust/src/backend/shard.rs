//! Data-parallel sharded execution: the multi-worker topology and the
//! deterministic gradient all-reduce it feeds (`--workers` / `HIFT_WORKERS`).
//!
//! ## The canonical batch-row reduction
//!
//! Every parameter gradient (and the masked loss) is a reduction over the
//! batch-row dimension.  A data-parallel split changes *where* each row's
//! contribution is computed, and f32 addition is not associative — so the
//! only way N workers can be bit-identical to one is to fix the reduction
//! structure **independently of the worker count**.  This module owns that
//! contract:
//!
//! * every bt-dimension reduction site produces **one partial per batch
//!   row** (the within-row accumulation order is the kernel layer's usual
//!   fixed order), and
//! * partials are combined by [`tree_fold`] — a fixed, balanced pairwise
//!   tree over the *global* row index (separate mul + add, no FMA — the
//!   kernel layer's discipline).
//!
//! The plain single-threaded walk ([`super::model`]) folds its own rows'
//! partials with the very same tree; the sharded reducer folds the same
//! per-row partials collected from N workers.  Because the partial grain
//! (one batch row) and the fold shape depend only on the batch geometry,
//! **any worker count — including 1 — produces identical bits**, for every
//! gradient, the loss, and hence whole training trajectories.  Embedding
//! scatters (whose accumulation grain is the token occurrence, not the
//! row) are instead *replayed serially by the reducer* over the
//! concatenated row gradients, which reproduces the plain walk's exact
//! accumulation sequence.
//!
//! ## Topology
//!
//! [`run_sharded`] splits the batch into `min(workers, B)` contiguous row
//! ranges, clones one shared read-only parameter snapshot, and runs one
//! full `forward`/`backward` walk per shard on scoped worker threads (each
//! registered against the shared [`super::par::ThreadBudget`], so kernels
//! + workers never oversubscribe).  Workers stream per-row partials over
//! bounded channels in the walk's fixed emission order; the coordinator
//! rendezvouses one site at a time — reduce, then emit a *single* tensor
//! into the ordinary [`super::GradSink`] seam — so
//! `peak_grad_resident_bytes` stays at max-single-tensor, never N live
//! copies of a gradient.

use std::ops::Range;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};

use anyhow::{bail, Context, Result};

use super::manifest::ModelCfg;
use super::model::{self, BwdStats, GradSpec};
use super::par;
use super::{ActCkpt, Batch};
use crate::tensor::half::Precision;
use crate::tensor::{Tensor, TensorSet};

/// Bounded rendezvous capacity per worker: how many sites a fast worker
/// may run ahead of the reducer before its `send` blocks.  Small, so the
/// in-flight partial set stays a couple of tensors per worker.
const CHANNEL_CAP: usize = 2;

// ---------------------------------------------------------------------------
// The canonical reduction (shared by the plain walk and the reducer)
// ---------------------------------------------------------------------------

/// Fold per-batch-row partials with a fixed, balanced pairwise tree:
/// adjacent pairs are summed (separate loads, one add — no FMA), halving
/// the list until one buffer remains; an odd tail passes through a round
/// unchanged.  The tree shape depends only on the number of rows, so any
/// contiguous sharding of the rows reproduces the same bits.
pub fn tree_fold(mut parts: Vec<Vec<f32>>) -> Vec<f32> {
    assert!(!parts.is_empty(), "tree_fold of zero partials");
    while parts.len() > 1 {
        let mut next = Vec::with_capacity(parts.len().div_ceil(2));
        let mut it = parts.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                for (x, &y) in a.iter_mut().zip(b.iter()) {
                    *x += y;
                }
            }
            next.push(a);
        }
        parts = next;
    }
    parts.pop().unwrap()
}

/// [`tree_fold`] over per-row scalar triples `[w·nll, w, w·correct]` —
/// the masked-loss statistics.  Lane-wise, same tree.
pub fn tree_fold_stats(mut parts: Vec<[f64; 3]>) -> [f64; 3] {
    assert!(!parts.is_empty(), "tree_fold_stats of zero rows");
    while parts.len() > 1 {
        let mut next = Vec::with_capacity(parts.len().div_ceil(2));
        let mut it = parts.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                for (x, y) in a.iter_mut().zip(b.iter()) {
                    *x += y;
                }
            }
            next.push(a);
        }
        parts = next;
    }
    parts.pop().unwrap()
}

/// Per-batch-row partials of `a^T · b` where `a: [rows·rlen, m]` and
/// `b: [rows·rlen, n]` — one `[m, n]` partial GEMM per batch row (`rlen`
/// positions each).  `tree_fold` of the result is the canonical form of
/// the old single `matmul_at` over all `rows·rlen` positions.
pub fn matmul_at_rows(
    a: &[f32],
    b: &[f32],
    rows: usize,
    rlen: usize,
    m: usize,
    n: usize,
) -> Vec<Vec<f32>> {
    let mut parts = Vec::with_capacity(rows);
    for r in 0..rows {
        let mut p = vec![0.0f32; m * n];
        let ar = &a[r * rlen * m..][..rlen * m];
        let br = &b[r * rlen * n..][..rlen * n];
        par::matmul_at(ar, br, &mut p, rlen, m, n);
        parts.push(p);
    }
    parts
}

/// Per-batch-row column sums of `x: [rows·rlen, n]` (the canonical form
/// of bias gradients).
pub fn colsum_rows(x: &[f32], rows: usize, rlen: usize, n: usize) -> Vec<Vec<f32>> {
    let mut parts = Vec::with_capacity(rows);
    for r in 0..rows {
        let mut p = vec![0.0f32; n];
        for t in 0..rlen {
            let src = &x[(r * rlen + t) * n..][..n];
            for (pj, &sj) in p.iter_mut().zip(src.iter()) {
                *pj += sj;
            }
        }
        parts.push(p);
    }
    parts
}

/// The batch's total loss-mask weight, computed with the same per-row
/// accumulation + canonical fold the forward pass uses — so the global
/// denominator the coordinator hands each worker is bit-equal to the one
/// a plain walk over the whole batch would derive.
pub fn batch_denom(batch: &Batch) -> f64 {
    let mut rows = Vec::with_capacity(batch.b);
    for b in 0..batch.b {
        let mut w = 0.0f64;
        for tc in 0..batch.s {
            w += batch.weights[b * batch.s + tc] as f64;
        }
        rows.push([0.0, w, 0.0]);
    }
    tree_fold_stats(rows)[1]
}

// ---------------------------------------------------------------------------
// Batch sharding
// ---------------------------------------------------------------------------

/// Contiguous row ranges for `workers` shards of a `b`-row batch.  A batch
/// smaller than the worker count degrades to fewer active shards (never an
/// empty one); the split is balanced with the longer shards first.
pub fn split_rows(b: usize, workers: usize) -> Vec<Range<usize>> {
    let n = workers.clamp(1, b.max(1));
    let base = b / n;
    let extra = b % n;
    let mut out = Vec::with_capacity(n);
    let mut lo = 0;
    for w in 0..n {
        let len = base + usize::from(w < extra);
        out.push(lo..lo + len);
        lo += len;
    }
    debug_assert_eq!(lo, b);
    out
}

/// The sub-batch of rows `lo..hi` (same seq length, sliced buffers).
pub fn batch_rows(batch: &Batch, r: &Range<usize>) -> Batch {
    let s = batch.s;
    Batch {
        tokens: batch.tokens[r.start * s..r.end * s].to_vec(),
        targets: batch.targets[r.start * s..r.end * s].to_vec(),
        weights: batch.weights[r.start * s..r.end * s].to_vec(),
        b: r.len(),
        s,
    }
}

// ---------------------------------------------------------------------------
// The worker → reducer protocol
// ---------------------------------------------------------------------------

/// One message on a worker's reduce channel.  Workers send these in the
/// walk's fixed emission order, so the coordinator can rendezvous site by
/// site without buffering the stream.
pub enum GradMsg {
    /// Forward summary: per-row `[w·nll, w, w·correct]` triples for this
    /// shard's rows (always the first message).
    Fwd { rows: Vec<[f64; 3]> },
    /// One reduced-gradient site: per-batch-row partials for this shard's
    /// rows, in row order.
    Rows { name: String, shape: Vec<usize>, parts: Vec<Vec<f32>> },
    /// LoRA dW intermediates for layer `layer`: per-row partials of the
    /// full `dW_q`/`dW_v`, from which the reducer derives the four adapter
    /// factor gradients after folding (exactly as the plain walk does).
    LoraDw { layer: usize, dwq: Vec<Vec<f32>>, dwv: Vec<Vec<f32>> },
    /// Embedding-level activation gradient rows `[shard_rows·t, d]`: the
    /// reducer concatenates all shards' rows and replays the plain walk's
    /// serial scatters (token / position / prefix embeddings).
    EmbDx { dx: Vec<f32> },
}

/// What one worker reports back through its join handle.
struct WorkerDone {
    act_peak: u64,
    bwd: BwdStats,
}

/// Scalars + accounting the sharded execution hands back to the backend.
pub struct ShardSummary {
    pub loss: f32,
    pub ncorrect: f32,
    /// Gradients emitted into the sink (the backend cross-checks this
    /// against the artifact's slot count).
    pub emitted: usize,
    /// Sum of the workers' retained activation peaks (the shards' caches
    /// are resident concurrently) plus the reducer's in-flight partials.
    pub act_peak_bytes: u64,
    pub recompute_layers: u64,
    pub recompute_flops: u64,
}

// ---------------------------------------------------------------------------
// The driver
// ---------------------------------------------------------------------------

/// Execute one forward + streamed backward as `workers` data-parallel
/// shards over a shared read-only parameter snapshot, reducing per-row
/// gradient partials with the canonical tree and emitting each reduced
/// tensor through `emit` (the backend's ordinary quantize → unscale →
/// account → sink seam).  Bit-identical to the plain walk for any worker
/// count; see the module docs for why.
///
/// `emit` receives `(name, reduced gradient, params)` in the exact plain-
/// walk emission order.  `grads` is false for forward-only runs (eval,
/// MeZO), which still shard the forward and merge loss/ncorrect.
#[allow(clippy::too_many_arguments)]
pub fn run_sharded(
    cfg: &ModelCfg,
    variant: &str,
    params: &mut TensorSet,
    batch: &Batch,
    gspec: &GradSpec,
    policy: ActCkpt,
    prec: Precision,
    loss_scale: f32,
    workers: usize,
    grads: bool,
    emit: &mut dyn FnMut(&str, Tensor, &mut TensorSet) -> Result<()>,
) -> Result<ShardSummary> {
    batch.validate()?;
    let wsum = batch_denom(batch);
    if wsum <= 0.0 {
        // Mirror the plain forward's zero-mask bail (PR 5): a batch whose
        // loss mask selects nothing is a config bug, not loss 0.
        bail!(
            "batch [{}x{}] has zero total loss-mask weight: no position is supervised \
             (weighted loss would be 0/0)",
            batch.b,
            batch.s
        );
    }
    let denom = wsum as f32;
    let ranges = split_rows(batch.b, workers);
    let n = ranges.len();
    // One shared read-only snapshot for every worker (params do not scale
    // with N).  Cloned before any sink emission, so workers read the same
    // pre-update values the plain walk would — the sink may then update
    // the *real* set in place behind them without aliasing.
    let snapshot = params.clone();

    let mut txs: Vec<Option<SyncSender<GradMsg>>> = Vec::with_capacity(n);
    let mut rxs: Vec<Receiver<GradMsg>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = sync_channel(CHANNEL_CAP);
        txs.push(Some(tx));
        rxs.push(rx);
    }

    let (reduced, joined) = std::thread::scope(|scope| {
        let snapshot = &snapshot;
        let handles: Vec<_> = ranges
            .iter()
            .enumerate()
            .map(|(w, range)| {
                let tx = txs[w].take().expect("worker channel handed out twice");
                let sub = batch_rows(batch, range);
                // Charge the worker before it spawns (the pipelined
                // optimizer's discipline), so budget accounting is
                // deterministic: kernels inside the workers lease only
                // what the registered walks leave free.
                let slot = par::register_worker();
                scope.spawn(move || -> Result<WorkerDone> {
                    let _slot = slot;
                    let fwd =
                        model::forward_shard(cfg, variant, snapshot, &sub, policy, prec, denom)?;
                    tx.send(GradMsg::Fwd { rows: fwd.row_stats().to_vec() })
                        .map_err(|_| anyhow::anyhow!("gradient reducer hung up"))?;
                    let mut act_peak = fwd.act_resident_bytes();
                    let mut bwd = BwdStats::default();
                    if grads {
                        let mut ship = |m: GradMsg| -> Result<()> {
                            tx.send(m).map_err(|_| anyhow::anyhow!("gradient reducer hung up"))
                        };
                        bwd = model::backward_shard(
                            &fwd, cfg, variant, snapshot, &sub, gspec, &mut ship, loss_scale,
                        )?;
                        act_peak = act_peak.max(fwd.act_resident_bytes() + bwd.peak_scratch_bytes);
                    }
                    Ok(WorkerDone { act_peak, bwd })
                })
            })
            .collect();

        // The coordinator reduces on this thread while the workers walk.
        // On any reduce error the receivers drop, failing the workers'
        // sends, so joins below can never deadlock.
        let reduced = reduce(rxs, cfg, variant, snapshot, params, batch, gspec, grads, denom, emit);
        let joined: Vec<_> = handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|p| std::panic::resume_unwind(p)))
            .collect();
        (reduced, joined)
    });

    // Worker errors are the root cause of any reducer channel error —
    // surface them first.
    let mut act_peak = 0u64;
    let mut rlayers = 0u64;
    let mut rflops = 0u64;
    for done in joined {
        let done = done.context("sharded worker walk failed")?;
        act_peak += done.act_peak;
        rlayers += done.bwd.recompute_layers;
        rflops += done.bwd.recompute_flops;
    }
    let red = reduced?;
    Ok(ShardSummary {
        loss: red.loss,
        ncorrect: red.ncorrect,
        emitted: red.emitted,
        act_peak_bytes: act_peak + red.partials_peak,
        recompute_layers: rlayers,
        recompute_flops: rflops,
    })
}

struct Reduced {
    loss: f32,
    ncorrect: f32,
    emitted: usize,
    /// Peak bytes of per-row partials the reducer held in flight at once.
    partials_peak: u64,
}

/// The coordinator side: rendezvous each emission site across all worker
/// streams (fixed worker order), fold with the canonical tree, emit one
/// reduced tensor.  Consumes the receivers so that dropping them on error
/// unblocks any worker mid-`send`.
#[allow(clippy::too_many_arguments)]
fn reduce(
    rxs: Vec<Receiver<GradMsg>>,
    cfg: &ModelCfg,
    variant: &str,
    snapshot: &TensorSet,
    params: &mut TensorSet,
    batch: &Batch,
    spec: &GradSpec,
    grads: bool,
    denom: f32,
    emit: &mut dyn FnMut(&str, Tensor, &mut TensorSet) -> Result<()>,
) -> Result<Reduced> {
    // --- forward merge: global per-row stats, canonical fold -------------
    let mut row_stats: Vec<[f64; 3]> = Vec::with_capacity(batch.b);
    for rx in &rxs {
        match rx.recv() {
            Ok(GradMsg::Fwd { rows }) => row_stats.extend(rows),
            Ok(_) => bail!("worker stream began with a gradient message"),
            Err(_) => bail!("worker exited before its forward summary"),
        }
    }
    if row_stats.len() != batch.b {
        bail!("forward summaries cover {} of {} batch rows", row_stats.len(), batch.b);
    }
    let [loss_acc, _, ncorrect] = tree_fold_stats(row_stats);
    let loss = (loss_acc / denom as f64) as f32;
    let ncorrect = ncorrect as f32;
    let mut red = Reduced { loss, ncorrect, emitted: 0, partials_peak: 0 };
    if !grads {
        return Ok(red);
    }

    let lora_sc = (cfg.lora_alpha / cfg.lora_rank.max(1) as f64) as f32;
    let p_ = if variant == "prefix" { cfg.n_prefix } else { 0 };
    let (d, v_, s) = (cfg.d_model, cfg.vocab, batch.s);
    let t_ = s + p_;

    // --- gradient rendezvous loop ----------------------------------------
    loop {
        let first = match rxs[0].recv() {
            Ok(m) => m,
            Err(_) => break, // worker 0 closed: end of stream (or its error, surfaced by join)
        };
        match first {
            GradMsg::Rows { name, shape, mut parts } => {
                for rx in &rxs[1..] {
                    match rx.recv() {
                        Ok(GradMsg::Rows { name: n2, parts: p2, .. }) if n2 == name => {
                            parts.extend(p2)
                        }
                        Ok(_) => bail!("worker streams diverged at site {name:?}"),
                        Err(_) => bail!("worker exited mid-stream at site {name:?}"),
                    }
                }
                note_partials(&mut red, &parts);
                let g = Tensor::from_vec(tree_fold(parts), &shape);
                emit(&name, g, params)?;
                red.emitted += 1;
            }
            GradMsg::LoraDw { layer, mut dwq, mut dwv } => {
                for rx in &rxs[1..] {
                    match rx.recv() {
                        Ok(GradMsg::LoraDw { layer: l2, dwq: q2, dwv: v2 }) if l2 == layer => {
                            dwq.extend(q2);
                            dwv.extend(v2);
                        }
                        Ok(_) => bail!("worker streams diverged at layer {layer} LoRA site"),
                        Err(_) => bail!("worker exited mid-stream at layer {layer} LoRA site"),
                    }
                }
                note_partials(&mut red, &dwq);
                note_partials(&mut red, &dwv);
                // Fold the full dW intermediates, then derive the factor
                // gradients exactly as the plain walk does.  The factors
                // have not been emitted yet this run, so the live set
                // still holds their pre-update (snapshot) values.
                let dwq_full = tree_fold(dwq);
                let dwv_full = tree_fold(dwv);
                let r = cfg.lora_rank;
                let pfx = format!("l{layer}.");
                let aq = get(snapshot, &format!("{pfx}lora.aq"))?;
                let bq = get(snapshot, &format!("{pfx}lora.bq"))?;
                let av = get(snapshot, &format!("{pfx}lora.av"))?;
                let bv = get(snapshot, &format!("{pfx}lora.bv"))?;
                let mut daq = vec![0.0f32; d * r];
                par::matmul_bt(&dwq_full, &bq.data, &mut daq, d, d, r);
                daq.iter_mut().for_each(|z| *z *= lora_sc);
                let mut dbq = vec![0.0f32; r * d];
                par::matmul_at(&aq.data, &dwq_full, &mut dbq, d, r, d);
                dbq.iter_mut().for_each(|z| *z *= lora_sc);
                let mut dav = vec![0.0f32; d * r];
                par::matmul_bt(&dwv_full, &bv.data, &mut dav, d, d, r);
                dav.iter_mut().for_each(|z| *z *= lora_sc);
                let mut dbv = vec![0.0f32; r * d];
                par::matmul_at(&av.data, &dwv_full, &mut dbv, d, r, d);
                dbv.iter_mut().for_each(|z| *z *= lora_sc);
                emit(&format!("{pfx}lora.aq"), Tensor::from_vec(daq, &[d, r]), params)?;
                emit(&format!("{pfx}lora.bq"), Tensor::from_vec(dbq, &[r, d]), params)?;
                emit(&format!("{pfx}lora.av"), Tensor::from_vec(dav, &[d, r]), params)?;
                emit(&format!("{pfx}lora.bv"), Tensor::from_vec(dbv, &[r, d]), params)?;
                red.emitted += 4;
            }
            GradMsg::EmbDx { mut dx } => {
                for rx in &rxs[1..] {
                    match rx.recv() {
                        Ok(GradMsg::EmbDx { dx: d2 }) => dx.extend(d2),
                        Ok(_) => bail!("worker streams diverged at the embedding site"),
                        Err(_) => bail!("worker exited mid-stream at the embedding site"),
                    }
                }
                red.partials_peak = red.partials_peak.max(4 * dx.len() as u64);
                let want = batch.b * t_ * d;
                if dx.len() != want {
                    bail!("embedding row gradients cover {} of {want} values", dx.len());
                }
                // Serial scatter replay over the *global* rows — the exact
                // loops (and accumulation order) of the plain walk.
                emit_embeddings(
                    cfg, snapshot, params, batch, spec, &dx, p_, v_, d, &mut red, emit,
                )?;
            }
            GradMsg::Fwd { .. } => bail!("unexpected second forward summary"),
        }
    }
    Ok(red)
}

fn note_partials(red: &mut Reduced, parts: &[Vec<f32>]) {
    let bytes: u64 = parts.iter().map(|p| 4 * p.len() as u64).sum();
    red.partials_peak = red.partials_peak.max(bytes);
}

fn get<'a>(set: &'a TensorSet, name: &str) -> Result<&'a Tensor> {
    set.get(name).with_context(|| format!("parameter {name:?} missing from snapshot"))
}

/// Replay the plain walk's embedding scatters over the concatenated row
/// gradients `dx: [B·T, D]` — same loops, same (b, t) visit order, so the
/// accumulated f32 values are bit-identical to the serial path.
#[allow(clippy::too_many_arguments)]
fn emit_embeddings(
    cfg: &ModelCfg,
    snapshot: &TensorSet,
    params: &mut TensorSet,
    batch: &Batch,
    spec: &GradSpec,
    dx: &[f32],
    p_: usize,
    v_: usize,
    d: usize,
    red: &mut Reduced,
    emit: &mut dyn FnMut(&str, Tensor, &mut TensorSet) -> Result<()>,
) -> Result<()> {
    let (bsz, s) = (batch.b, batch.s);
    let t_ = s + p_;
    // Same gating as the plain walk's embedding section: workers ship
    // `EmbDx` iff one of these holds.
    if spec.emit(0) {
        let pos_shape = get(snapshot, "pos_emb")?.shape.clone();
        let mut dtok = vec![0.0f32; v_ * d];
        for b in 0..bsz {
            for tt in p_..t_ {
                let row = &dx[(b * t_ + tt) * d..][..d];
                let tc = tt - p_;
                let tok = batch.tokens[b * s + tc] as usize;
                for (dj, &rj) in dtok[tok * d..(tok + 1) * d].iter_mut().zip(row.iter()) {
                    *dj += rj;
                }
            }
        }
        emit("tok_emb", Tensor::from_vec(dtok, &[v_, d]), params)?;
        let mut dpos = vec![0.0f32; pos_shape.iter().product()];
        for b in 0..bsz {
            for tt in 0..t_ {
                let row = &dx[(b * t_ + tt) * d..][..d];
                let base = if tt < p_ { cfg.seq_len + tt } else { tt - p_ };
                for (dj, &rj) in dpos[base * d..(base + 1) * d].iter_mut().zip(row.iter()) {
                    *dj += rj;
                }
            }
        }
        emit("pos_emb", Tensor::from_vec(dpos, &pos_shape), params)?;
        red.emitted += 2;
    }
    if p_ > 0 && spec.adapters {
        let mut dpre = vec![0.0f32; p_ * d];
        for b in 0..bsz {
            for tt in 0..p_ {
                let row = &dx[(b * t_ + tt) * d..][..d];
                for (dj, &rj) in dpre[tt * d..(tt + 1) * d].iter_mut().zip(row.iter()) {
                    *dj += rj;
                }
            }
        }
        emit("prefix.emb", Tensor::from_vec(dpre, &[p_, d]), params)?;
        red.emitted += 1;
    }
    Ok(())
}
