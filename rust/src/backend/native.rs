//! The default execution backend: the pure-Rust reference transformer of
//! [`super::model`] wrapped in the [`ExecBackend`] interface.
//!
//! Instead of reading `artifacts/<preset>/manifest.json`, the backend
//! *synthesizes* a manifest with exactly the contract `aot.py` emits — the
//! same parameter names/units/offsets and the same artifact inventory
//! (`fwd_<variant>`, `grad_base_full`, `grad_base_u{i}`, `grad_base_bitfit`,
//! `grad_<variant>_adapter`) — so strategies, trainer, benches and the CLI
//! run unchanged with zero external dependencies or Python-generated files.
//!
//! Parameters are initialized deterministically from the backend seed with
//! the same scheme as `model.init_params` (fan-in-scaled normals for
//! weights, zeros for biases and LoRA B, ones for LN scales and IA³).

use std::collections::{BTreeMap, HashMap};

use anyhow::{bail, Context, Result};

use super::kernels::{self, KernelKind};
use super::manifest::{ArtifactInfo, Manifest, ModelCfg, ParamInfo, VariantInfo};
use super::model;
use super::shard;
use super::{unit_artifact, ActCkpt, Batch, ExecBackend, GradSink, RuntimeStats, StreamOutput};
use crate::optim::ScalerEvent;
use crate::rng::Pcg32;
use crate::tensor::half::Precision;
use crate::tensor::paged::{OffloadCfg, UnitPager};
use crate::tensor::{Tensor, TensorSet};

/// Model geometry presets, mirroring `PRESETS` in `python/compile/model.py`.
pub fn preset_cfg(name: &str) -> Option<ModelCfg> {
    let mk = |name: &str, vocab, d_model, n_layers, n_heads, d_ff, seq_len, batch, lora_rank,
              n_prefix| ModelCfg {
        name: name.to_string(),
        vocab,
        d_model,
        n_layers,
        n_heads,
        d_ff,
        seq_len,
        batch,
        lora_rank,
        lora_alpha: 8.0,
        n_prefix,
    };
    Some(match name {
        "tiny" => mk("tiny", 64, 32, 2, 2, 64, 16, 4, 2, 4),
        "small" => mk("small", 256, 128, 4, 4, 256, 64, 8, 4, 16),
        "base" => mk("base", 512, 256, 6, 8, 1024, 64, 8, 8, 16),
        "e2e" => mk("e2e", 4096, 512, 8, 8, 2048, 64, 8, 8, 16),
        "e2e100m" => mk("e2e100m", 32768, 768, 12, 12, 3072, 128, 4, 8, 16),
        _ => return None,
    })
}

/// Names of all presets [`preset_cfg`] accepts.
pub const PRESET_NAMES: [&str; 5] = ["tiny", "small", "base", "e2e", "e2e100m"];

struct Spec {
    name: String,
    shape: Vec<usize>,
    unit: i64,
    bitfit: bool,
}

fn spec(name: String, shape: &[usize], unit: i64, bitfit: bool) -> Spec {
    Spec { name, shape: shape.to_vec(), unit, bitfit }
}

/// Base-model parameter list (order == artifact input order, `model.py`).
fn base_specs(c: &ModelCfg) -> Vec<Spec> {
    let (d, f, v, s) = (c.d_model, c.d_ff, c.vocab, c.seq_len);
    let mut out = vec![
        spec("tok_emb".into(), &[v, d], 0, false),
        spec("pos_emb".into(), &[s + c.n_prefix, d], 0, false),
    ];
    for i in 0..c.n_layers {
        let u = (i + 1) as i64;
        let p = format!("l{i}.");
        out.push(spec(format!("{p}ln1.scale"), &[d], u, true));
        out.push(spec(format!("{p}ln1.bias"), &[d], u, true));
        out.push(spec(format!("{p}attn.wq"), &[d, d], u, false));
        out.push(spec(format!("{p}attn.bq"), &[d], u, true));
        out.push(spec(format!("{p}attn.wk"), &[d, d], u, false));
        out.push(spec(format!("{p}attn.bk"), &[d], u, true));
        out.push(spec(format!("{p}attn.wv"), &[d, d], u, false));
        out.push(spec(format!("{p}attn.bv"), &[d], u, true));
        out.push(spec(format!("{p}attn.wo"), &[d, d], u, false));
        out.push(spec(format!("{p}attn.bo"), &[d], u, true));
        out.push(spec(format!("{p}ln2.scale"), &[d], u, true));
        out.push(spec(format!("{p}ln2.bias"), &[d], u, true));
        out.push(spec(format!("{p}ffn.w1"), &[d, f], u, false));
        out.push(spec(format!("{p}ffn.b1"), &[f], u, true));
        out.push(spec(format!("{p}ffn.w2"), &[f, d], u, false));
        out.push(spec(format!("{p}ffn.b2"), &[d], u, true));
    }
    let u = (c.n_layers + 1) as i64;
    out.push(spec("ln_f.scale".into(), &[d], u, true));
    out.push(spec("ln_f.bias".into(), &[d], u, true));
    out.push(spec("head.w".into(), &[d, v], u, false));
    out.push(spec("head.b".into(), &[v], u, true));
    out
}

/// Adapter parameters for a PEFT variant (unit = -1).
fn adapter_specs(c: &ModelCfg, variant: &str) -> Vec<Spec> {
    let (d, f, r) = (c.d_model, c.d_ff, c.lora_rank);
    let mut out = Vec::new();
    match variant {
        "base" => {}
        "lora" => {
            for i in 0..c.n_layers {
                let p = format!("l{i}.lora.");
                out.push(spec(format!("{p}aq"), &[d, r], -1, false));
                out.push(spec(format!("{p}bq"), &[r, d], -1, false));
                out.push(spec(format!("{p}av"), &[d, r], -1, false));
                out.push(spec(format!("{p}bv"), &[r, d], -1, false));
            }
        }
        "ia3" => {
            for i in 0..c.n_layers {
                let p = format!("l{i}.ia3.");
                out.push(spec(format!("{p}lk"), &[d], -1, false));
                out.push(spec(format!("{p}lv"), &[d], -1, false));
                out.push(spec(format!("{p}lff"), &[f], -1, false));
            }
        }
        "prefix" => out.push(spec("prefix.emb".into(), &[c.n_prefix, d], -1, false)),
        other => unreachable!("unknown variant {other}"),
    }
    out
}

#[derive(Clone, Copy, PartialEq)]
enum Init {
    Normal,
    Zeros,
    Ones,
}

/// Init kind, derivable from the parameter name (same rules as `model.py`).
fn init_kind(name: &str) -> Init {
    let last = name.rsplit('.').next().unwrap_or(name);
    if name.contains("ia3.") || last == "scale" {
        Init::Ones
    } else if last == "bias" || last.starts_with('b') {
        // biases (bq/bk/bv/bo/b1/b2/head.b) and LoRA B matrices start at 0
        Init::Zeros
    } else {
        Init::Normal
    }
}

fn variant_info(c: &ModelCfg, variant: &str) -> VariantInfo {
    let base = base_specs(c);
    let n_base_params = base.len();
    let adapters = adapter_specs(c, variant);
    let mut params = Vec::with_capacity(n_base_params + adapters.len());
    let mut base_off = 0usize;
    for sp in &base {
        let size: usize = sp.shape.iter().product();
        params.push(ParamInfo {
            name: sp.name.clone(),
            shape: sp.shape.clone(),
            unit: sp.unit,
            bitfit: sp.bitfit,
            offset: base_off,
            size,
        });
        base_off += size * 4;
    }
    let mut ad_off = 0usize;
    for sp in &adapters {
        let size: usize = sp.shape.iter().product();
        params.push(ParamInfo {
            name: sp.name.clone(),
            shape: sp.shape.clone(),
            unit: sp.unit,
            bitfit: sp.bitfit,
            offset: ad_off,
            size,
        });
        ad_off += size * 4;
    }
    VariantInfo { params, n_base_params }
}

/// Build the full synthetic manifest for `cfg`.
fn synth_manifest(cfg: &ModelCfg, seed: u64) -> Manifest {
    let mut variants = BTreeMap::new();
    for v in ["base", "lora", "ia3", "prefix"] {
        variants.insert(v.to_string(), variant_info(cfg, v));
    }
    let n_units = cfg.n_units();
    let batch_inputs = ["tokens", "targets", "weights"];
    let mk_artifact = |name: String, variant: &str, grad_names: Vec<String>| {
        let vinfo = &variants[variant];
        let mut inputs: Vec<String> = vinfo.params.iter().map(|p| p.name.clone()).collect();
        inputs.extend(batch_inputs.iter().map(|s| s.to_string()));
        let mut outputs = vec!["loss".to_string(), "ncorrect".to_string()];
        outputs.extend(grad_names);
        ArtifactInfo { name: name.clone(), path: format!("<native>/{name}"), inputs, outputs }
    };
    let mut artifacts = Vec::new();
    for v in ["base", "lora", "ia3", "prefix"] {
        artifacts.push(mk_artifact(format!("fwd_{v}"), v, Vec::new()));
    }
    let base = &variants["base"];
    let all_base: Vec<String> =
        base.params.iter().filter(|p| p.unit >= 0).map(|p| p.name.clone()).collect();
    artifacts.push(mk_artifact("grad_base_full".into(), "base", all_base));
    for u in 0..n_units {
        let names: Vec<String> = base
            .params
            .iter()
            .filter(|p| p.unit == u as i64)
            .map(|p| p.name.clone())
            .collect();
        artifacts.push(mk_artifact(unit_artifact(u), "base", names));
    }
    let bitfit: Vec<String> =
        base.params.iter().filter(|p| p.bitfit).map(|p| p.name.clone()).collect();
    artifacts.push(mk_artifact("grad_base_bitfit".into(), "base", bitfit));
    for v in ["lora", "ia3", "prefix"] {
        let names: Vec<String> =
            variants[v].params.iter().filter(|p| p.unit == -1).map(|p| p.name.clone()).collect();
        artifacts.push(mk_artifact(format!("grad_{v}_adapter"), v, names));
    }
    Manifest {
        preset: cfg.name.clone(),
        kernels: "native".to_string(),
        seed,
        config: cfg.clone(),
        n_units,
        variants,
        artifacts,
    }
}

/// Native CPU reference backend.
pub struct NativeBackend {
    manifest: Manifest,
    seed: u64,
    /// Simulated device-buffer cache: name → last-seen `(lineage, version)`.
    /// Keeps [`RuntimeStats`] meaningful (h2d per *changed* tensor only), so
    /// bench columns compare across backends.
    uploaded: HashMap<String, (u64, u64)>,
    /// Activation-checkpointing policy for grad-producing runs (see
    /// [`ActCkpt`]): recompute-on-backward, bit-identical results.
    act_ckpt: ActCkpt,
    /// Host-memory paging tier (`--offload host`): inactive units' masters
    /// live in a host pool and return on demand during the walk.
    pager: Option<UnitPager>,
    offload: OffloadCfg,
    /// Compute precision (`--precision f32|bf16|f16`): forward activations,
    /// backward intermediates and pre-upcast gradients; masters stay f32.
    precision: Precision,
    /// Loss scale applied to the backward seed of grad runs (installed per
    /// step by the strategies' f16 scaler; 1.0 = off, bit-exact).
    loss_scale: f32,
    /// Data-parallel worker replicas per step (`--workers`/`HIFT_WORKERS`);
    /// 1 = the plain serial walk.  Gradients from the workers are combined
    /// by the deterministic tree all-reduce in [`shard`], so every count is
    /// bit-identical to serial.
    workers: usize,
    pub stats: RuntimeStats,
}

/// Initial worker count for a freshly built backend: `HIFT_WORKERS` when set
/// to a positive integer, else 1 (the plain serial walk).  Reading the env
/// here — not only in [`super::from_env`] — lets a CI job re-run the whole
/// identity suite under a multi-worker default without touching each test.
/// Sharding is bit-identical to serial, so the default only changes wall
/// clock; `set_workers` still overrides it per backend.
fn default_workers() -> usize {
    std::env::var("HIFT_WORKERS")
        .ok()
        .filter(|s| !s.is_empty())
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

impl NativeBackend {
    /// Build from an explicit geometry.
    pub fn new(cfg: ModelCfg, seed: u64) -> Result<Self> {
        if cfg.d_model == 0 || cfg.n_heads == 0 || cfg.d_model % cfg.n_heads != 0 {
            bail!("d_model {} must be a positive multiple of n_heads {}", cfg.d_model, cfg.n_heads);
        }
        if cfg.vocab == 0 || cfg.seq_len == 0 || cfg.batch == 0 || cfg.d_ff == 0 {
            bail!("degenerate model geometry: {cfg:?}");
        }
        Ok(NativeBackend {
            manifest: synth_manifest(&cfg, seed),
            seed,
            uploaded: HashMap::new(),
            act_ckpt: ActCkpt::None,
            pager: None,
            offload: OffloadCfg::default(),
            precision: Precision::F32,
            loss_scale: 1.0,
            workers: default_workers(),
            stats: RuntimeStats::default(),
        })
    }

    /// Build one of the named presets (`tiny`, `small`, `base`, …).
    pub fn preset(name: &str, seed: u64) -> Result<Self> {
        let cfg = preset_cfg(name)
            .with_context(|| format!("unknown preset {name:?} (have {PRESET_NAMES:?})"))?;
        Self::new(cfg, seed)
    }

    fn init_tensor(&self, idx: usize, name: &str, shape: &[usize]) -> Tensor {
        match init_kind(name) {
            Init::Zeros => Tensor::zeros(shape),
            Init::Ones => Tensor::ones(shape),
            Init::Normal => {
                let fan_in = if shape.len() > 1 { shape[0] } else { shape[shape.len() - 1] };
                let std = if name.contains("emb") {
                    0.02
                } else {
                    1.0 / (fan_in.max(1) as f32).sqrt()
                };
                let mut rng = Pcg32::new(self.seed, 1000 + idx as u64);
                Tensor::randn(shape, std, &mut rng)
            }
        }
    }

    /// Simulated parameter-upload cache (mirrors the PJRT device-buffer
    /// cache keyed by `(TensorSet lineage, version)`).
    fn account_uploads(&mut self, params: &TensorSet) {
        for i in 0..params.len() {
            let key = params.cache_key(i);
            let name = &params.names[i];
            if self.uploaded.get(name) == Some(&key) {
                self.stats.cache_hits += 1;
            } else {
                // An evicted master (host paging) still has a well-defined
                // upload size: the full f32 bytes the pager recorded at
                // attach.  Lossless paging never bumps the version, so the
                // device working copy stays cached across evictions; the
                // lossy f16 round trip does bump it, forcing a re-upload.
                let bytes = if params.tensors[i].numel() == 0 {
                    self.pager.as_ref().and_then(|p| p.full_bytes_of(i)).unwrap_or(0)
                } else {
                    params.tensors[i].bytes()
                };
                // Half-precision compute uploads half-width working copies
                // of the weights (the f32 masters stay host-side) — the
                // halved h2d term of the memory model.
                let bytes = if self.precision == Precision::F32 { bytes } else { bytes / 2 };
                self.uploaded.insert(name.clone(), key);
                self.stats.h2d_bytes += bytes as u64;
                self.stats.cache_misses += 1;
            }
        }
    }

    /// Shared streamed execution: one forward (under the configured
    /// activation-checkpoint policy), then the streamed backward for
    /// `gspec`, routing each gradient to `sink` through the name→slot map
    /// the caller derived from the artifact (or group).
    fn exec_streamed(
        &mut self,
        variant: &str,
        params: &mut TensorSet,
        batch: &Batch,
        gspec: &model::GradSpec,
        slots: &HashMap<String, usize>,
        sink: &mut dyn GradSink,
    ) -> Result<StreamOutput> {
        // Host paging: attach the pager to this parameter set (a fresh
        // lineage triggers the initial placement — every managed master
        // moves to the host pool) and pin the run's trainable units, whose
        // tensors fused sinks update in place mid-walk.
        let offload_before = match self.pager.as_mut() {
            Some(pg) => {
                if !pg.is_attached_to(params) {
                    // Only a fresh lineage pays for building the unit map
                    // (attach itself is a no-op when already attached).
                    pg.attach(params, unit_param_map(&self.manifest, variant)?)?;
                }
                pg.clear_pins();
                for (u, &want) in gspec.units.iter().enumerate() {
                    if want {
                        pg.pin_unit(u);
                    }
                }
                Some(pg.counters())
            }
            None => None,
        };
        self.account_uploads(params);
        self.stats.h2d_bytes += batch.h2d_bytes() as u64;

        let cfg = self.manifest.config.clone();
        // Forward-only runs (eval, MeZO) never backward, so nothing but the
        // head buffers needs retaining — use a maximally sparse policy
        // instead of caching every layer.
        let policy = if slots.is_empty() {
            ActCkpt::EveryK(cfg.n_layers.max(1))
        } else {
            self.act_ckpt
        };
        let t0 = std::time::Instant::now();
        // The kernel counters are process-global atomics, so one delta
        // snapshot brackets the whole step — including every concurrent
        // worker walk of the sharded topology — without losing increments.
        let kern0 = kernels::counters();
        let prec = self.precision;
        let loss_scale = self.loss_scale;
        let n_active = self.workers.min(batch.b.max(1));
        // Contracts (HIFT_CHECK): validate the emission sequence against the
        // manifest — every gradient once, units strictly head→embedding,
        // manifest order within a unit (see docs/CONTRACTS.md).
        let mut checker = if crate::contracts::enabled() && !slots.is_empty() {
            Some(crate::contracts::EmitChecker::new(self.manifest.variant(variant)?, slots)?)
        } else {
            None
        };
        let loss;
        let ncorrect;
        let mut act_peak;
        {
            let stats = &mut self.stats;
            let mut pager = self.pager.as_mut();
            let mut emitted = 0usize;
            let checker = &mut checker;
            let mut emit = |name: &str, mut g: Tensor, ps: &mut TensorSet| -> Result<()> {
                let slot = *slots
                    .get(name)
                    .with_context(|| format!("backward emitted unexpected gradient {name:?}"))?;
                if let Some(c) = checker.as_mut() {
                    c.observe(slot, name)?;
                }
                // The gradient leaves the device at the compute
                // precision (rounded here, half d2h bytes), then the
                // loss scale is divided back out in f32 — exact, the
                // scale is a power of two — so the sink clips and
                // updates on honest magnitudes ("grads are emitted
                // upcast to f32").  Non-finite values survive both
                // steps (Inf/2^k = Inf), so overflow detection at the
                // sink still fires.
                prec.quantize_slice(&mut g.data);
                if loss_scale != 1.0 {
                    g.scale(1.0 / loss_scale);
                }
                let bytes = if prec == Precision::F32 {
                    g.bytes() as u64
                } else {
                    g.bytes() as u64 / 2
                };
                stats.d2h_bytes += bytes;
                stats.note_grad_resident(g.bytes() as u64 + sink.resident_bytes());
                sink.grad(slot, name, g, ps)?;
                stats.note_grad_resident(sink.resident_bytes());
                emitted += 1;
                Ok(())
            };
            if n_active > 1 {
                // `set_workers`/`set_offload` enforce the exclusivity; the
                // pager mutates `params` mid-walk, which would race the
                // workers' shared read-only view of the snapshot.
                debug_assert!(
                    pager.is_none(),
                    "offload and workers>1 are mutually exclusive (enforced at configure time)"
                );
                let sum = shard::run_sharded(
                    &cfg,
                    variant,
                    params,
                    batch,
                    gspec,
                    policy,
                    prec,
                    loss_scale,
                    n_active,
                    !slots.is_empty(),
                    &mut emit,
                )?;
                if emitted != slots.len() {
                    bail!("streamed backward emitted {emitted} of {} gradients", slots.len());
                }
                stats.recompute_layers += sum.recompute_layers;
                stats.recompute_flops += sum.recompute_flops;
                act_peak = sum.act_peak_bytes;
                loss = sum.loss;
                ncorrect = sum.ncorrect;
            } else {
                let fwd = model::forward_ckpt(
                    &cfg,
                    variant,
                    params,
                    batch,
                    policy,
                    pager.as_deref_mut(),
                    prec,
                )?;
                act_peak = fwd.act_resident_bytes();
                if !slots.is_empty() {
                    let bw = model::backward_streamed(
                        &fwd,
                        &cfg,
                        variant,
                        params,
                        batch,
                        gspec,
                        &mut emit,
                        pager.as_deref_mut(),
                        loss_scale,
                    )?;
                    if emitted != slots.len() {
                        bail!("streamed backward emitted {emitted} of {} gradients", slots.len());
                    }
                    act_peak = act_peak.max(fwd.act_resident_bytes() + bw.peak_scratch_bytes);
                    stats.recompute_layers += bw.recompute_layers;
                    stats.recompute_flops += bw.recompute_flops;
                }
                loss = fwd.loss;
                ncorrect = fwd.ncorrect;
            }
        }
        self.stats.note_act_resident(act_peak);
        if let Some(c) = &checker {
            c.finalize().context("emission-order contract (HIFT_CHECK)")?;
        }
        sink.finish(params)?;
        // Page the just-finished group (and anything else resident) back
        // out — async under prefetch, so the store overlaps whatever the
        // caller does next — then fold this run's transfer accounting into
        // the backend stats.
        if let (Some(pg), Some(before)) = (self.pager.as_mut(), offload_before.as_ref()) {
            pg.end_run(params)?;
            let after = pg.counters();
            self.stats.apply_offload(before, &after, true);
        }
        let exec_time = t0.elapsed();
        self.stats.executions += 1;
        self.stats.exec_secs += exec_time.as_secs_f64();
        // Kernel counters are process-global; attribute this run's delta.
        let kern1 = kernels::counters();
        self.stats.kernel_flops += kern1.0 - kern0.0;
        self.stats.kernel_nanos += kern1.1 - kern0.1;
        Ok(StreamOutput { loss, ncorrect, exec_time })
    }

    /// Pool-side transfer-event counts `(stores, fetches)` of the paging
    /// tier, `None` when offload is off.  Lets tests regression-check that
    /// the accounting ledger agrees with what the pool actually did.
    pub fn offload_pool_events(&mut self) -> Result<Option<(u64, u64)>> {
        match self.pager.as_mut() {
            Some(pg) => Ok(Some(pg.pool_events()?)),
            None => Ok(None),
        }
    }

    /// The paging tier's cumulative counters (None when offload is off).
    pub fn offload_counters(&self) -> Option<crate::tensor::paged::OffloadCounters> {
        self.pager.as_ref().map(|p| p.counters())
    }

    /// Record the paging tier's steady-state [`PageEvent`] stream (the
    /// `plancheck` cross-validation seam).  No-op when offload is off.
    pub fn set_offload_tracing(&mut self, on: bool) {
        if let Some(pg) = self.pager.as_mut() {
            pg.set_tracing(on);
        }
    }

    /// Drain the recorded paging events (empty when offload or tracing is
    /// off).
    pub fn take_offload_trace(&mut self) -> Vec<crate::tensor::paged::PageEvent> {
        self.pager.as_mut().map(|pg| pg.take_trace()).unwrap_or_default()
    }
}

/// Unit → parameter-index map for `variant` (managed tensors only: every
/// base parameter belongs to exactly one unit; adapters, unit −1, stay
/// always-resident).
fn unit_param_map(manifest: &Manifest, variant: &str) -> Result<Vec<Vec<usize>>> {
    let vinfo = manifest.variant(variant)?;
    Ok((0..manifest.n_units).map(|u| vinfo.unit_indices(u)).collect())
}

impl ExecBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn platform(&self) -> String {
        format!("native-cpu({} threads)", super::par::max_threads())
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run_streamed(
        &mut self,
        artifact: &str,
        params: &mut TensorSet,
        batch: &Batch,
        sink: &mut dyn GradSink,
    ) -> Result<StreamOutput> {
        batch.validate()?;
        let info = self.manifest.artifact(artifact)?.clone();
        let n_inputs = info.inputs.len();
        if params.len() + 3 != n_inputs {
            bail!(
                "artifact {artifact} expects {} inputs, got {} params + 3 batch",
                n_inputs,
                params.len()
            );
        }
        // "fwd_<variant>" / "grad_<variant>[_suffix]" → variant name.
        let variant = artifact
            .strip_prefix("fwd_")
            .or_else(|| artifact.strip_prefix("grad_"))
            .map(|rest| rest.split('_').next().unwrap_or(rest))
            .with_context(|| format!("cannot infer variant from artifact {artifact:?}"))?
            .to_string();
        // Which gradients the artifact asks for: per-unit emit flags plus
        // the descent bound (adapters live in every layer, so they force a
        // full downward pass — but not the embedding-gradient scatter).
        let mut gspec = model::GradSpec {
            min_unit: usize::MAX,
            units: vec![false; self.manifest.n_units],
            adapters: false,
            dense: false,
        };
        {
            let vinfo = self.manifest.variant(&variant)?;
            for out_name in &info.outputs[2..] {
                let p = vinfo
                    .params
                    .iter()
                    .find(|p| &p.name == out_name)
                    .with_context(|| format!("grad output {out_name} not a {variant} param"))?;
                if p.unit < 0 {
                    gspec.adapters = true;
                    gspec.min_unit = 0;
                } else {
                    let u = p.unit as usize;
                    if u < gspec.units.len() {
                        gspec.units[u] = true;
                    }
                    gspec.min_unit = gspec.min_unit.min(u);
                    // A bias/LN-only request (BitFit) never needs the dense
                    // weight matmuls.
                    gspec.dense |= p.shape.len() > 1;
                }
            }
        }
        let slots: HashMap<String, usize> =
            info.outputs[2..].iter().enumerate().map(|(i, n)| (n.clone(), i)).collect();
        self.exec_streamed(&variant, params, batch, &gspec, &slots, sink)
    }

    fn run_group_streamed(
        &mut self,
        units: &[usize],
        params: &mut TensorSet,
        batch: &Batch,
        sink: &mut dyn GradSink,
    ) -> Result<StreamOutput> {
        batch.validate()?;
        let mut gspec = model::GradSpec {
            min_unit: usize::MAX,
            units: vec![false; self.manifest.n_units],
            adapters: false,
            dense: true,
        };
        let slots = {
            let vinfo = self.manifest.variant("base")?;
            if params.len() != vinfo.params.len() {
                bail!("group run expects {} base params, got {}", vinfo.params.len(), params.len());
            }
            let mut slots = HashMap::new();
            let mut slot = 0usize;
            for &u in units {
                if u >= self.manifest.n_units {
                    bail!("unit {u} out of range ({} units)", self.manifest.n_units);
                }
                if gspec.units[u] {
                    bail!("unit {u} listed twice in the group");
                }
                gspec.units[u] = true;
                gspec.min_unit = gspec.min_unit.min(u);
                for p in vinfo.params.iter().filter(|p| p.unit == u as i64) {
                    slots.insert(p.name.clone(), slot);
                    slot += 1;
                }
            }
            slots
        };
        self.exec_streamed("base", params, batch, &gspec, &slots, sink)
    }

    fn note_grad_residency(&mut self, bytes: u64) {
        self.stats.note_grad_resident(bytes);
    }

    fn set_act_ckpt(&mut self, policy: ActCkpt) -> Result<()> {
        self.act_ckpt = policy;
        Ok(())
    }

    fn act_ckpt(&self) -> ActCkpt {
        self.act_ckpt
    }

    fn set_kernels(&mut self, kind: KernelKind) -> Result<()> {
        if kind == KernelKind::Simd && !kernels::simd_available() {
            bail!(
                "kernel kind `simd` requires building with `--features simd` \
                 (falling back silently would misreport benchmarks)"
            );
        }
        // The kernel layer is selected process-globally (the model walk
        // calls free kernel functions, not backend methods); record the
        // choice in the manifest so run records carry it.
        kernels::set_kind(kind);
        self.manifest.kernels = format!("native+{}", kind.name());
        Ok(())
    }

    fn set_precision(&mut self, prec: Precision) -> Result<()> {
        self.precision = prec;
        if !prec.needs_loss_scaling() {
            self.loss_scale = 1.0;
        }
        Ok(())
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn set_loss_scale(&mut self, scale: f32) {
        // Only f16 backward runs scaled; in f32/bf16 the seed multiplier
        // stays the exact 1.0.
        self.loss_scale = if self.precision.needs_loss_scaling() { scale.max(1.0) } else { 1.0 };
        self.stats.loss_scale = self.loss_scale as f64;
    }

    fn loss_scale(&self) -> f32 {
        self.loss_scale
    }

    fn note_numerics(&mut self, nonfinite_grads: u64, step_skipped: bool) {
        self.stats.nonfinite_grad_tensors += nonfinite_grads;
        if step_skipped {
            self.stats.nonfinite_grad_steps += 1;
        }
    }

    fn note_loss_scale(&mut self, scale: f32, event: ScalerEvent) {
        self.stats.loss_scale = scale as f64;
        match event {
            ScalerEvent::Grew => self.stats.loss_scale_growths += 1,
            ScalerEvent::BackedOff => self.stats.loss_scale_backoffs += 1,
            ScalerEvent::None => {}
        }
    }

    fn set_workers(&mut self, n: usize) -> Result<()> {
        if n == 0 {
            bail!("workers must be >= 1 (1 = the plain serial walk)");
        }
        // The pager mutates the parameter set mid-walk (evict/fetch), which
        // cannot coexist with N workers reading a shared snapshot of it.
        if n > 1 && self.offload.enabled {
            bail!(
                "workers {n} is incompatible with --offload {}: the host pager \
                 mutates parameters mid-walk while worker replicas read them",
                self.offload.name()
            );
        }
        self.workers = n;
        Ok(())
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn set_offload(&mut self, cfg: OffloadCfg) -> Result<()> {
        if cfg.enabled && self.workers > 1 {
            bail!(
                "--offload {} is incompatible with workers {}: the host pager \
                 mutates parameters mid-walk while worker replicas read them",
                cfg.name(),
                self.workers
            );
        }
        // Replacing an attached pager discards its pool.  While evicted
        // masters live there the pool is their *only* copy, so switching
        // modes then would silently destroy parameters — refuse instead.
        // The trainer flushes at run end, which makes run boundaries safe
        // switch points (the bench harness relies on this).
        if let Some(pg) = &self.pager {
            if pg.holds_pages() {
                bail!(
                    "cannot reconfigure offload ({} -> {}): the host pool still holds \
                     evicted parameter masters; flush_offload the active set first",
                    self.offload.name(),
                    cfg.name()
                );
            }
        }
        self.offload = cfg;
        self.pager = if cfg.enabled { Some(UnitPager::new(cfg)) } else { None };
        Ok(())
    }

    fn offload(&self) -> OffloadCfg {
        self.offload
    }

    fn flush_offload(&mut self, params: &mut TensorSet) -> Result<()> {
        if let Some(pg) = self.pager.as_mut() {
            if pg.is_attached_to(params) {
                let before = pg.counters();
                pg.flush(params)?;
                let after = pg.counters();
                // Materialization for external readers is bookkeeping, not
                // training residency: count the transfers, skip the peaks.
                self.stats.apply_offload(&before, &after, false);
            }
        }
        Ok(())
    }

    fn repage_offload(&mut self, params: &mut TensorSet) -> Result<()> {
        if let Some(pg) = self.pager.as_mut() {
            if pg.is_attached_to(params) {
                let before = pg.counters();
                pg.end_run(params)?;
                let after = pg.counters();
                self.stats.apply_offload(&before, &after, false);
                // The flush/save window is over; peaks resume from the
                // re-evicted (≈ empty) arena, not the full-model spike.
                pg.reset_peaks();
            }
        }
        Ok(())
    }

    fn prefetch_units(&mut self, units: &[usize]) {
        if let Some(pg) = self.pager.as_mut() {
            // A new staging set replaces the previous one: the old "next
            // group" is the caller's active group now, pinned by its run.
            pg.clear_staged();
            for &u in units {
                pg.stage_unit(u);
            }
        }
    }

    fn reset_run_peaks(&mut self) {
        self.stats.peak_grad_resident_bytes = 0;
        self.stats.peak_act_resident_bytes = 0;
        self.stats.peak_param_resident_bytes = 0;
        self.stats.peak_prefetch_buffer_bytes = 0;
        self.stats.peak_host_pool_bytes = 0;
        // The loss-scale gauge is per-run too: an f16 run repopulates it on
        // its first step; a f32/bf16 run correctly reports "never engaged"
        // instead of a stale scale from a previous run on a shared backend.
        self.stats.loss_scale = 0.0;
        if let Some(pg) = self.pager.as_mut() {
            pg.reset_peaks();
        }
    }

    fn load_params(&self, variant: &str) -> Result<TensorSet> {
        let vinfo = self.manifest.variant(variant)?;
        let mut set = TensorSet::new();
        for (i, p) in vinfo.params.iter().enumerate() {
            set.push(p.name.clone(), self.init_tensor(i, &p.name, &p.shape));
        }
        Ok(set)
    }

    fn stats(&self) -> &RuntimeStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_build_and_expose_manifest() {
        let be = NativeBackend::preset("tiny", 0).unwrap();
        let m = be.manifest();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.n_units, m.config.n_layers + 2);
        assert_eq!(m.kernels, "native");
        // fwd ×4 + full + units + bitfit + adapters ×3
        assert_eq!(m.artifacts.len(), 4 + 1 + m.n_units + 1 + 3);
        for v in ["base", "lora", "ia3", "prefix"] {
            assert!(m.variant(v).is_ok(), "{v}");
            assert!(m.artifact(&format!("fwd_{v}")).is_ok());
        }
        assert!(NativeBackend::preset("nope", 0).is_err());
    }

    #[test]
    fn unit_partition_covers_all_base_params() {
        let be = NativeBackend::preset("tiny", 0).unwrap();
        let v = be.manifest().variant("base").unwrap();
        let total: usize = (0..be.manifest().n_units).map(|u| v.unit_indices(u).len()).sum();
        assert_eq!(total, v.params.len(), "every base param belongs to exactly one unit");
        assert!(v.adapter_indices().is_empty());
        let lora = be.manifest().variant("lora").unwrap();
        assert_eq!(lora.adapter_indices().len(), 4 * be.manifest().config.n_layers);
    }

    #[test]
    fn init_rules_match_python_scheme() {
        let be = NativeBackend::preset("tiny", 7).unwrap();
        let p = be.load_params("ia3").unwrap();
        assert!(p.get("l0.ln1.scale").unwrap().data.iter().all(|&x| x == 1.0));
        assert!(p.get("l0.ia3.lff").unwrap().data.iter().all(|&x| x == 1.0));
        assert!(p.get("l0.attn.bq").unwrap().data.iter().all(|&x| x == 0.0));
        assert!(p.get("head.b").unwrap().data.iter().all(|&x| x == 0.0));
        assert!(p.get("tok_emb").unwrap().l2_norm() > 0.0);
        let lora = be.load_params("lora").unwrap();
        assert!(lora.get("l0.lora.bq").unwrap().data.iter().all(|&x| x == 0.0), "LoRA B = 0");
        assert!(lora.get("l0.lora.aq").unwrap().l2_norm() > 0.0, "LoRA A random");
        // deterministic per seed
        let be2 = NativeBackend::preset("tiny", 7).unwrap();
        let q = be2.load_params("ia3").unwrap();
        assert_eq!(p.get("tok_emb").unwrap(), q.get("tok_emb").unwrap());
    }

    #[test]
    fn run_checks_param_arity() {
        let mut be = NativeBackend::preset("tiny", 0).unwrap();
        let mut params = be.load_params("base").unwrap();
        let batch = Batch::new(2, 8);
        assert!(be.run("fwd_lora", &mut params, &batch).is_err(), "base params ≠ lora inputs");
        assert!(be.run("nope", &mut params, &batch).is_err());
    }
}
