//! The native CPU reference model: a decoder-only pre-LN transformer LM with
//! hand-written forward *and* backward passes over [`Tensor`] buffers.
//!
//! It mirrors `python/compile/model.py` exactly — same parameter names, same
//! layer-unit partition, same PEFT variants (LoRA on W_q/W_v, IA³ rescaling
//! of K/V/FFN-hidden, prefix virtual tokens), same tanh-GELU and masked
//! mean-loss — so every strategy and artifact name the manifest describes
//! runs against it unchanged, with zero external dependencies.
//!
//! Backward is reverse-mode with explicit per-layer activation caches.  A
//! [`GradSpec`] says which units' gradients to emit: the downward pass is
//! truncated below the shallowest requested unit, and weight-gradient
//! matmuls are skipped for unrequested layers along the way — the native
//! analogue of the per-unit `jax.grad` artifacts, and the source of HiFT's
//! per-step speed win (§4.3: backprop never descends past the active
//! group, and never forms gradients outside it).
//!
//! The walk is *streamed* ([`backward_streamed`]): each requested gradient
//! is handed to an emission callback the moment it is final and dropped by
//! the consumer, so peak parameter-gradient residency is one tensor rather
//! than the requested set — the LOMO-style fusion point the GradSink seam
//! is built on.  [`backward`] is the collect-into-a-map wrapper.
//!
//! Hot loops (matmuls, attention, GELU, softmax) run through the
//! [`super::par`] thread-chunking helpers and the [`super::kernels`]
//! compute layer; all reductions are fixed-order, so results are
//! bit-identical across thread counts *and* across kernel schedules
//! (naive / blocked / blocked+SIMD).  Every reduction over the *batch-row*
//! dimension (parameter gradients, the masked loss) additionally follows
//! the canonical per-row-partials + fixed-tree-fold structure of
//! [`super::shard`], which is what makes N data-parallel workers
//! bit-identical to this serial walk — see that module's docs.  Under the blocked/simd kinds the
//! attention core runs the fused streaming-softmax path: the `[B*H, T*T]`
//! probability matrix is never materialized — forward consumes each
//! query row's O(T) score scratch immediately and backward recomputes
//! rows on the fly — so `LayerState` and the recompute scratch shrink
//! from O(T²) to O(T) per head while staying bit-identical to the
//! materializing naive reference.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::kernels;
use super::manifest::ModelCfg;
use super::par;
use super::shard::{self, GradMsg};
use super::{ActCkpt, Batch};
use crate::tensor::half::{PrecBuf, Precision};
use crate::tensor::paged::UnitPager;
use crate::tensor::{Tensor, TensorSet};

/// LayerNorm epsilon (matches `layernorm_ref` in the Python compile path).
const LN_EPS: f32 = 1e-5;

fn get<'a>(params: &'a TensorSet, name: &str) -> Result<&'a Tensor> {
    params.get(name).with_context(|| format!("parameter {name:?} missing from TensorSet"))
}

fn axpy(dst: &mut [f32], a: f32, src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        *d += a * s;
    }
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b.iter()) {
        acc += x * y;
    }
    acc
}

/// Causal-attention probabilities for one query row, written into
/// `srow[..=ti]`: score sweep with online row max, two-sweep softmax over
/// the O(T) row, then quantize-at-the-op rounding.  Shared verbatim by the
/// materialized forward (row of the cached probs matrix), the fused
/// forward (transient scratch row), and the fused backward's row
/// recompute — so all three observe identical bits.
fn attn_prob_row(
    qb: &[f32],
    kb: &[f32],
    srow: &mut [f32],
    ti: usize,
    dh: usize,
    scale: f32,
    prec: Precision,
) {
    let qrow = &qb[ti * dh..][..dh];
    let mut maxv = f32::NEG_INFINITY;
    for (j, sj) in srow.iter_mut().enumerate().take(ti + 1) {
        let sc = dot(qrow, &kb[j * dh..][..dh]) * scale;
        *sj = sc;
        maxv = maxv.max(sc);
    }
    let mut sum = 0.0f32;
    for sj in srow.iter_mut().take(ti + 1) {
        *sj = (*sj - maxv).exp();
        sum += *sj;
    }
    let inv = 1.0 / sum;
    for sj in srow.iter_mut().take(ti + 1) {
        *sj = prec.quantize(*sj * inv);
    }
}

/// Add `bias[j]` to every row of `x: [rows, cols]`.
fn add_bias(x: &mut [f32], bias: &[f32]) {
    let cols = bias.len();
    for row in x.chunks_mut(cols) {
        axpy(row, 1.0, bias);
    }
}

/// Per-row LayerNorm statistics cached for backward.
struct LnState {
    mean: Vec<f32>,
    inv: Vec<f32>,
}

fn ln_fwd(x: &[f32], scale: &[f32], bias: &[f32], d: usize) -> (Vec<f32>, LnState) {
    let rows = x.len() / d;
    let mut y = vec![0.0f32; x.len()];
    let mut mean = vec![0.0f32; rows];
    let mut inv = vec![0.0f32; rows];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        // hift-lint: allow(float-reduction): sequential per-row mean in slice order — one fixed schedule, bit-stable
        let mu = xr.iter().sum::<f32>() / d as f32;
        let mut var = 0.0f32;
        for &v in xr {
            var += (v - mu) * (v - mu);
        }
        var /= d as f32;
        let iv = 1.0 / (var + LN_EPS).sqrt();
        let yr = &mut y[r * d..(r + 1) * d];
        kernels::ln_norm_row(xr, yr, mu, iv, scale, bias);
        mean[r] = mu;
        inv[r] = iv;
    }
    (y, LnState { mean, inv })
}

/// Returns `(dx, dscale_parts, dbias_parts)` for `y = LN(x) * scale + bias`.
///
/// The scale/bias gradients come back as one partial per *batch* row
/// (`rlen` consecutive LN rows each) — the canonical reduction grain of
/// [`super::shard`].  Within a batch row the accumulation is the usual
/// fixed sweep; the caller folds the partials with the canonical tree (or
/// ships them to the shard reducer, which applies the same tree).
#[allow(clippy::type_complexity)]
fn ln_bwd(
    dy: &[f32],
    x: &[f32],
    st: &LnState,
    scale: &[f32],
    d: usize,
    rlen: usize,
) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let rows = x.len() / d;
    debug_assert_eq!(rows % rlen, 0);
    let mut dx = vec![0.0f32; x.len()];
    let mut dscale = vec![vec![0.0f32; d]; rows / rlen];
    let mut dbias = vec![vec![0.0f32; d]; rows / rlen];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let (mu, iv) = (st.mean[r], st.inv[r]);
        let dsc = &mut dscale[r / rlen];
        let dbi = &mut dbias[r / rlen];
        let mut g_mean = 0.0f32;
        let mut gx_mean = 0.0f32;
        for j in 0..d {
            let xhat = (xr[j] - mu) * iv;
            let g = dyr[j] * scale[j];
            dsc[j] += dyr[j] * xhat;
            dbi[j] += dyr[j];
            g_mean += g;
            gx_mean += g * xhat;
        }
        g_mean /= d as f32;
        gx_mean /= d as f32;
        let dxr = &mut dx[r * d..(r + 1) * d];
        for j in 0..d {
            let xhat = (xr[j] - mu) * iv;
            let g = dyr[j] * scale[j];
            dxr[j] = iv * (g - g_mean - xhat * gx_mean);
        }
    }
    (dx, dscale, dbias)
}

/// `[BT, D]` (b, t, head, dh) → head-major `[B*H, T*DH]`.
fn gather_heads(src: &[f32], b: usize, t: usize, h: usize, dh: usize) -> Vec<f32> {
    let d = h * dh;
    let mut out = vec![0.0f32; b * h * t * dh];
    for bi in 0..b {
        for hi in 0..h {
            for ti in 0..t {
                let s = &src[(bi * t + ti) * d + hi * dh..][..dh];
                let o = &mut out[((bi * h + hi) * t + ti) * dh..][..dh];
                o.copy_from_slice(s);
            }
        }
    }
    out
}

/// Head-major `[B*H, T*DH]` → `[BT, D]` (inverse of [`gather_heads`]).
fn scatter_heads(src: &[f32], b: usize, t: usize, h: usize, dh: usize) -> Vec<f32> {
    let d = h * dh;
    let mut out = vec![0.0f32; b * t * d];
    for bi in 0..b {
        for hi in 0..h {
            for ti in 0..t {
                let s = &src[((bi * h + hi) * t + ti) * dh..][..dh];
                let o = &mut out[(bi * t + ti) * d + hi * dh..][..dh];
                o.copy_from_slice(s);
            }
        }
    }
    out
}

/// Per-layer activation cache.  The large `[BT, *]` buffers are stored at
/// the compute precision's width ([`PrecBuf`]: plain f32 vectors in f32
/// mode, packed 16-bit codewords under `--precision bf16|f16` — the
/// physically halved retention the memory model's halved activation term
/// describes).  LayerNorm row statistics stay f32 (standard mixed-precision
/// practice; they are `O(BT)` against the buffers' `O(BT·D)`).
struct LayerState {
    x_in: PrecBuf,
    h1: PrecBuf,
    ln1: LnState,
    /// Effective W_q / W_v (LoRA-merged; plain copies otherwise).
    wq_eff: PrecBuf,
    wv_eff: PrecBuf,
    /// Post-IA³ q/k/v, flat `[BT, D]`.
    q: PrecBuf,
    k: PrecBuf,
    v: PrecBuf,
    /// Pre-IA³ k/v (empty unless the variant is ia3).
    k0: PrecBuf,
    v0: PrecBuf,
    /// Softmax attention probabilities, `[B*H, T*T]` (0 above the
    /// diagonal) — cached only under the naive kernel kind.  The fused
    /// streaming-softmax path leaves this empty and backward recomputes
    /// rows from `q`/`k` on the fly (the O(T²) → O(T) saving).
    probs: PrecBuf,
    /// Attention output before the out-projection, `[BT, D]`.
    attn: PrecBuf,
    x_mid: PrecBuf,
    h2: PrecBuf,
    ln2: LnState,
    /// Pre-GELU FFN activation, `[BT, F]`.
    a1: PrecBuf,
    mid0: PrecBuf,
    /// Post-IA³ FFN hidden (empty unless ia3).
    mid_ia3: PrecBuf,
}

impl LayerState {
    /// Bytes of activation buffers this cache retains (at their stored
    /// width: 4 bytes/elem for f32 buffers, 2 for half-precision ones).
    fn bytes(&self) -> usize {
        self.x_in.bytes()
            + self.h1.bytes()
            + self.wq_eff.bytes()
            + self.wv_eff.bytes()
            + self.q.bytes()
            + self.k.bytes()
            + self.v.bytes()
            + self.k0.bytes()
            + self.v0.bytes()
            + self.probs.bytes()
            + self.attn.bytes()
            + self.x_mid.bytes()
            + self.h2.bytes()
            + self.a1.bytes()
            + self.mid0.bytes()
            + self.mid_ia3.bytes()
            + 4 * (self.ln1.mean.len()
                + self.ln1.inv.len()
                + self.ln2.mean.len()
                + self.ln2.inv.len())
    }
}

/// Everything one forward pass produced (loss/metrics + backward caches).
pub struct FwdState {
    pub loss: f32,
    pub ncorrect: f32,
    /// Per-layer internal caches; `None` under a recompute policy (rebuilt
    /// from `boundaries` by `recompute_layer` during backward).
    layers: Vec<Option<LayerState>>,
    /// Stored boundary residual streams (a layer's input `x`, `[BT, D]`);
    /// `Some` at checkpoint layers under a recompute policy.  Policy
    /// [`ActCkpt::None`] keeps each layer's input inside its `LayerState`
    /// instead, so every entry is `None`.
    boundaries: Vec<Option<PrecBuf>>,
    x_fin: PrecBuf,
    hf: PrecBuf,
    lnf: LnState,
    /// Final hidden states for the real (non-prefix) positions, `[BS, D]` —
    /// empty when there are no prefix positions (`hf` is used directly).
    hf_s: PrecBuf,
    /// Output softmax probabilities, `[BS, V]`.
    probs_out: PrecBuf,
    denom: f32,
    /// Per-batch-row loss statistics `[Σw·nll, Σw, Σw·correct]` — the
    /// canonical reduction grain; the shard reducer concatenates workers'
    /// rows and folds them with the same tree the serial loss uses.
    row_stats: Vec<[f64; 3]>,
    n_pre: usize,
    /// Compute precision this forward ran at; backward replays it (same
    /// quantization points) so the whole step is one consistent regime.
    prec: Precision,
}

impl FwdState {
    /// Activation bytes this state retains between layer-walk steps: cached
    /// layer internals (policy `none`), boundary residual streams
    /// (recompute policies) and the head-stage buffers.  The single layer
    /// being recomputed during backward is transient working memory — freed
    /// before the walk moves on, like backward's own gradient temporaries —
    /// and is deliberately not part of this cache figure.
    pub fn act_resident_bytes(&self) -> u64 {
        let layers: usize = self.layers.iter().flatten().map(LayerState::bytes).sum();
        let bounds: usize = self.boundaries.iter().flatten().map(PrecBuf::bytes).sum();
        let head = self.x_fin.bytes()
            + self.hf.bytes()
            + self.hf_s.bytes()
            + self.probs_out.bytes()
            + 4 * (self.lnf.mean.len() + self.lnf.inv.len());
        (layers + bounds + head) as u64
    }

    /// The compute precision the forward ran at.
    pub fn precision(&self) -> Precision {
        self.prec
    }

    /// Output softmax probabilities, `[BS, V]` (decoded to f32; borrowed —
    /// free — in f32 mode).
    pub fn probs_out(&self) -> std::borrow::Cow<'_, [f32]> {
        self.probs_out.load()
    }

    /// Per-batch-row loss-statistic triples `[Σw·nll, Σw, Σw·correct]`.
    pub fn row_stats(&self) -> &[[f64; 3]] {
        &self.row_stats
    }
}

/// Gradients keyed by parameter name.  BTreeMap so every consumer that
/// walks the map (tests, batch sinks) sees one deterministic order —
/// see docs/CONTRACTS.md (D2).
pub type Grads = BTreeMap<String, Tensor>;

/// Which gradients a backward pass must produce.  Backward always
/// propagates `dx` down to `min_unit`, but weight-gradient matmuls, bias
/// column-sums and the (potentially huge) embedding scatter are only done
/// for requested units — the native analogue of per-unit `jax.grad`.
#[derive(Debug, Clone)]
pub struct GradSpec {
    /// Lowest layer unit whose `dx` must be formed (descent bound).
    pub min_unit: usize,
    /// Per-unit emit flags, indexed 0 (embeddings) ..= n_layers+1 (head).
    pub units: Vec<bool>,
    /// Emit adapter gradients (LoRA / IA³ / prefix).
    pub adapters: bool,
    /// Emit dense (≥2-D) weight gradients.  False for bias/LN-only
    /// artifacts (BitFit), which then skip every weight matmul.
    pub dense: bool,
}

impl GradSpec {
    /// Everything: all units, plus adapters when the variant has them.
    pub fn all(n_units: usize, adapters: bool) -> Self {
        GradSpec { min_unit: 0, units: vec![true; n_units], adapters, dense: true }
    }

    /// Exactly one layer unit of the base model.
    pub fn only_unit(n_units: usize, u: usize) -> Self {
        let mut units = vec![false; n_units];
        if u < n_units {
            units[u] = true;
        }
        GradSpec { min_unit: u, units, adapters: false, dense: true }
    }

    pub(crate) fn emit(&self, u: usize) -> bool {
        self.units.get(u).copied().unwrap_or(false)
    }
}

fn check_variant(variant: &str) -> Result<()> {
    match variant {
        "base" | "lora" | "ia3" | "prefix" => Ok(()),
        other => bail!("native backend: unknown variant {other:?}"),
    }
}

/// How a walk reaches the parameter set: exclusively (the plain path —
/// required by the pager, which swaps tensor storage in and out mid-walk,
/// and by fused sinks that update parameters in place at the emit seam),
/// or as a shared read-only snapshot (data-parallel shard workers, which
/// never page and never emit locally).
enum ParamsView<'a> {
    Excl { params: &'a mut TensorSet, pager: Option<&'a mut UnitPager> },
    Shared(&'a TensorSet),
}

impl ParamsView<'_> {
    fn view(&self) -> &TensorSet {
        match self {
            ParamsView::Excl { params, .. } => params,
            ParamsView::Shared(p) => p,
        }
    }

    /// The exclusive handle the emit seam needs.  Only the plain path
    /// emits locally, so this is unreachable on a shared snapshot.
    fn excl(&mut self) -> &mut TensorSet {
        match self {
            ParamsView::Excl { params, .. } => params,
            ParamsView::Shared(_) => unreachable!("shard workers never emit gradients locally"),
        }
    }

    fn ensure_unit(&mut self, u: usize) -> Result<()> {
        if let ParamsView::Excl { params, pager: Some(pg) } = self {
            pg.ensure_unit(params, u)?;
        }
        Ok(())
    }

    fn prefetch_unit(&mut self, u: usize) {
        if let ParamsView::Excl { pager: Some(pg), .. } = self {
            pg.prefetch_unit(u);
        }
    }

    fn release_unit(&mut self, u: usize) -> Result<()> {
        if let ParamsView::Excl { params, pager: Some(pg) } = self {
            pg.release_unit(params, u)?;
        }
        Ok(())
    }
}

/// Per-batch-row gradient-partial consumer for the sharded walk: the
/// worker hands each emission site's partials (and its special
/// LoRA/embedding messages) to this callback in the plain walk's exact
/// emission order; the reducer on the other end rendezvouses the streams.
pub type ShipFn<'a> = dyn FnMut(GradMsg) -> Result<()> + 'a;

/// Where a backward walk's parameter gradients go: folded to a single
/// tensor and emitted locally (the plain path), or shipped as per-batch-
/// row partials to the shard reducer (data-parallel workers).  Both arms
/// of every site share the same partial grain and the same canonical tree
/// fold, so the reducer reproduces the plain fold bit-for-bit.
enum GradOut<'a, 'b> {
    Fold(&'a mut EmitFn<'b>),
    Ship(&'a mut ShipFn<'b>),
}

impl GradOut<'_, '_> {
    /// One ordinary reduction site: fold-and-emit, or ship the partials.
    fn rows(
        &mut self,
        name: &str,
        shape: &[usize],
        parts: Vec<Vec<f32>>,
        ps: &mut ParamsView<'_>,
    ) -> Result<()> {
        match self {
            GradOut::Fold(emit) => {
                emit(name, Tensor::from_vec(shard::tree_fold(parts), shape), ps.excl())
            }
            GradOut::Ship(tx) => {
                tx(GradMsg::Rows { name: name.to_string(), shape: shape.to_vec(), parts })
            }
        }
    }
}

/// One transformer block's forward pass from its input residual stream.
/// Shared by the cache-building forward, the checkpoint-only forward and
/// the backward-time recompute (`recompute_layer`), so all three perform
/// the exact same arithmetic — the recompute path is bit-identical by
/// construction (quantization is deterministic, so this holds at every
/// precision).  Returns the layer's activation cache and its output
/// residual stream.
///
/// Under a half `prec`, every hot-loop product (projections, attention
/// probabilities and context, GELU, residual sums) is rounded to the
/// target precision the moment it is produced — downstream ops consume the
/// rounded values, exactly as if the matmuls had emitted bf16/f16 — and
/// the cache stores the rounded buffers at 16-bit width.  `Precision::F32`
/// makes every one of these hooks a structural no-op.
#[allow(clippy::too_many_arguments)]
fn layer_fwd(
    cfg: &ModelCfg,
    variant: &str,
    params: &TensorSet,
    i: usize,
    x_in: Vec<f32>,
    bsz: usize,
    t_: usize,
    prec: Precision,
) -> Result<(LayerState, Vec<f32>)> {
    let (d, heads, f_) = (cfg.d_model, cfg.n_heads, cfg.d_ff);
    let dh = d / heads;
    let bt = bsz * t_;
    let scale = 1.0 / (dh as f32).sqrt();
    let lora = variant == "lora";
    let ia3 = variant == "ia3";
    let lora_sc = (cfg.lora_alpha / cfg.lora_rank.max(1) as f64) as f32;
    let pfx = format!("l{i}.");

    let (mut h1, ln1) = ln_fwd(
        &x_in,
        &get(params, &format!("{pfx}ln1.scale"))?.data,
        &get(params, &format!("{pfx}ln1.bias"))?.data,
        d,
    );
    prec.quantize_slice(&mut h1);

    // effective projections (LoRA merges into W_q / W_v); under a half
    // precision these are the layer's cast working copies of the weights.
    let mut wq_eff = get(params, &format!("{pfx}attn.wq"))?.data.clone();
    let mut wv_eff = get(params, &format!("{pfx}attn.wv"))?.data.clone();
    if lora {
        let r = cfg.lora_rank;
        let aq = get(params, &format!("{pfx}lora.aq"))?;
        let bq = get(params, &format!("{pfx}lora.bq"))?;
        let av = get(params, &format!("{pfx}lora.av"))?;
        let bv = get(params, &format!("{pfx}lora.bv"))?;
        let mut delta = vec![0.0f32; d * d];
        par::matmul(&aq.data, &bq.data, &mut delta, d, r, d);
        axpy(&mut wq_eff, lora_sc, &delta);
        delta.iter_mut().for_each(|z| *z = 0.0);
        par::matmul(&av.data, &bv.data, &mut delta, d, r, d);
        axpy(&mut wv_eff, lora_sc, &delta);
    }
    prec.quantize_slice(&mut wq_eff);
    prec.quantize_slice(&mut wv_eff);

    let mut q = vec![0.0f32; bt * d];
    par::matmul(&h1, &wq_eff, &mut q, bt, d, d);
    add_bias(&mut q, &get(params, &format!("{pfx}attn.bq"))?.data);
    prec.quantize_slice(&mut q);
    let mut k = vec![0.0f32; bt * d];
    par::matmul(&h1, &get(params, &format!("{pfx}attn.wk"))?.data, &mut k, bt, d, d);
    add_bias(&mut k, &get(params, &format!("{pfx}attn.bk"))?.data);
    prec.quantize_slice(&mut k);
    let mut v = vec![0.0f32; bt * d];
    par::matmul(&h1, &wv_eff, &mut v, bt, d, d);
    add_bias(&mut v, &get(params, &format!("{pfx}attn.bv"))?.data);
    prec.quantize_slice(&mut v);

    let (mut k0, mut v0) = (Vec::new(), Vec::new());
    if ia3 {
        k0 = k.clone();
        v0 = v.clone();
        let lk = &get(params, &format!("{pfx}ia3.lk"))?.data;
        let lv = &get(params, &format!("{pfx}ia3.lv"))?.data;
        for row in k.chunks_mut(d) {
            for (kj, &lj) in row.iter_mut().zip(lk.iter()) {
                *kj *= lj;
            }
        }
        for row in v.chunks_mut(d) {
            for (vj, &lj) in row.iter_mut().zip(lv.iter()) {
                *vj *= lj;
            }
        }
        prec.quantize_slice(&mut k);
        prec.quantize_slice(&mut v);
    }

    // causal attention, head-major.  Two paths, bit-identical per element:
    //
    // * naive kernels materialize the full `[B*H, T*T]` probability matrix
    //   into the layer cache (the reference the fused path is compared
    //   against, and what backward reads when present);
    // * blocked/simd kernels run the fused streaming-softmax path — per
    //   query row the scores live in an O(T) scratch, the row max is
    //   tracked online during the score sweep, and the normalized row is
    //   consumed by the context accumulation immediately, so nothing
    //   quadratic in T is ever cached (backward recomputes rows on the
    //   fly).  The softmax stays a fixed-order two-sweep over the O(T)
    //   row rather than a rescale-as-you-go accumulation, because
    //   rescaling would reassociate the reduction and break bit-stability
    //   against the reference.
    //
    // Probabilities are rounded *before* the context accumulation
    // consumes them, so what backward reads (cached or recomputed) is
    // exactly what the forward multiplied against V — the
    // quantize-at-the-op contract.  (In f32 `quantize` is the identity
    // and the split loop performs the same per-element arithmetic in the
    // same order: bit-identical.)
    let fused = kernels::kind().fused_attention();
    let q_hm = gather_heads(&q, bsz, t_, heads, dh);
    let k_hm = gather_heads(&k, bsz, t_, heads, dh);
    let v_hm = gather_heads(&v, bsz, t_, heads, dh);
    let mut o_hm = vec![0.0f32; bsz * heads * t_ * dh];
    let mut probs = Vec::new();
    let attn_t0 = std::time::Instant::now();
    if fused {
        par::par_rows(&mut o_hm, t_ * dh, 2 * t_ * t_ * dh, |bh0, chunk| {
            let mut srow = vec![0.0f32; t_];
            for (bi, och) in chunk.chunks_mut(t_ * dh).enumerate() {
                let bh = bh0 + bi;
                let qb = &q_hm[bh * t_ * dh..][..t_ * dh];
                let kb = &k_hm[bh * t_ * dh..][..t_ * dh];
                let vb = &v_hm[bh * t_ * dh..][..t_ * dh];
                for ti in 0..t_ {
                    attn_prob_row(qb, kb, &mut srow, ti, dh, scale, prec);
                    let orow = &mut och[ti * dh..][..dh];
                    for (j, &pij) in srow.iter().enumerate().take(ti + 1) {
                        if pij != 0.0 {
                            axpy(orow, pij, &vb[j * dh..][..dh]);
                        }
                    }
                }
            }
        });
    } else {
        probs = vec![0.0f32; bsz * heads * t_ * t_];
        par::par_items2(&mut probs, t_ * t_, &mut o_hm, t_ * dh, |bh, pch, och| {
            let qb = &q_hm[bh * t_ * dh..][..t_ * dh];
            let kb = &k_hm[bh * t_ * dh..][..t_ * dh];
            let vb = &v_hm[bh * t_ * dh..][..t_ * dh];
            for ti in 0..t_ {
                let prow = &mut pch[ti * t_..][..t_];
                attn_prob_row(qb, kb, prow, ti, dh, scale, prec);
                let orow = &mut och[ti * dh..][..dh];
                for (j, &pij) in prow.iter().enumerate().take(ti + 1) {
                    if pij != 0.0 {
                        axpy(orow, pij, &vb[j * dh..][..dh]);
                    }
                }
            }
        });
    }
    // Scores + context accumulation ≈ 2·(2·dh)·T(T+1)/2 flops per head.
    kernels::note(
        (bsz * heads) as u64 * 2 * dh as u64 * (t_ * (t_ + 1)) as u64,
        attn_t0.elapsed().as_nanos() as u64,
    );
    let mut attn = scatter_heads(&o_hm, bsz, t_, heads, dh);
    prec.quantize_slice(&mut attn);

    let mut x_mid = vec![0.0f32; bt * d];
    par::matmul(&attn, &get(params, &format!("{pfx}attn.wo"))?.data, &mut x_mid, bt, d, d);
    add_bias(&mut x_mid, &get(params, &format!("{pfx}attn.bo"))?.data);
    axpy(&mut x_mid, 1.0, &x_in);
    prec.quantize_slice(&mut x_mid);

    let (mut h2, ln2) = ln_fwd(
        &x_mid,
        &get(params, &format!("{pfx}ln2.scale"))?.data,
        &get(params, &format!("{pfx}ln2.bias"))?.data,
        d,
    );
    prec.quantize_slice(&mut h2);
    let mut a1 = vec![0.0f32; bt * f_];
    par::matmul(&h2, &get(params, &format!("{pfx}ffn.w1"))?.data, &mut a1, bt, d, f_);
    add_bias(&mut a1, &get(params, &format!("{pfx}ffn.b1"))?.data);
    prec.quantize_slice(&mut a1);
    let mut mid0 = a1.clone();
    par::par_rows(&mut mid0, f_, 4 * f_, |_, chunk| {
        kernels::gelu_slice(chunk);
    });
    prec.quantize_slice(&mut mid0);
    let mut mid_ia3 = Vec::new();
    if ia3 {
        let lff = &get(params, &format!("{pfx}ia3.lff"))?.data;
        mid_ia3 = mid0.clone();
        for row in mid_ia3.chunks_mut(f_) {
            for (mj, &lj) in row.iter_mut().zip(lff.iter()) {
                *mj *= lj;
            }
        }
        prec.quantize_slice(&mut mid_ia3);
    }
    let mid_ref: &[f32] = if ia3 { &mid_ia3 } else { &mid0 };
    let mut x_out = vec![0.0f32; bt * d];
    par::matmul(mid_ref, &get(params, &format!("{pfx}ffn.w2"))?.data, &mut x_out, bt, f_, d);
    add_bias(&mut x_out, &get(params, &format!("{pfx}ffn.b2"))?.data);
    axpy(&mut x_out, 1.0, &x_mid);
    prec.quantize_slice(&mut x_out);

    Ok((
        LayerState {
            x_in: PrecBuf::store(prec, x_in),
            h1: PrecBuf::store(prec, h1),
            ln1,
            wq_eff: PrecBuf::store(prec, wq_eff),
            wv_eff: PrecBuf::store(prec, wv_eff),
            q: PrecBuf::store(prec, q),
            k: PrecBuf::store(prec, k),
            v: PrecBuf::store(prec, v),
            k0: PrecBuf::store(prec, k0),
            v0: PrecBuf::store(prec, v0),
            probs: PrecBuf::store(prec, probs),
            attn: PrecBuf::store(prec, attn),
            x_mid: PrecBuf::store(prec, x_mid),
            h2: PrecBuf::store(prec, h2),
            ln2,
            a1: PrecBuf::store(prec, a1),
            mid0: PrecBuf::store(prec, mid0),
            mid_ia3: PrecBuf::store(prec, mid_ia3),
        },
        x_out,
    ))
}

/// Rough flop estimate for one block's forward (the recompute cost unit):
/// dense projections + FFN matmuls + the two attention forms.  Adapter
/// extras (LoRA merge, IA³ rescales) are below the noise floor and ignored.
fn layer_flops(cfg: &ModelCfg, bsz: usize, t_: usize) -> u64 {
    let (d, f) = (cfg.d_model, cfg.d_ff);
    let bt = bsz * t_;
    (2 * bt * d * (4 * d + 2 * f) + 4 * bt * t_ * d) as u64
}

/// Run the model forward with full activation caching ([`ActCkpt::None`]),
/// no paging and f32 compute; see [`forward_ckpt`] for the
/// checkpointing/paged/reduced-precision variant.
pub fn forward(
    cfg: &ModelCfg,
    variant: &str,
    params: &mut TensorSet,
    batch: &Batch,
) -> Result<FwdState> {
    forward_ckpt(cfg, variant, params, batch, ActCkpt::None, None, Precision::F32)
}

/// Run the model forward under an activation-checkpointing `policy`;
/// returns loss, masked #correct and whatever caches the policy retains for
/// backward: every layer's internals under [`ActCkpt::None`], only
/// layer-boundary residual streams under a recompute policy (backward then
/// rebuilds each layer's internals via `recompute_layer`).  The loss and
/// all downstream gradients are bit-identical across policies — the same
/// `layer_fwd` runs either way.
///
/// With a `pager` (the `--offload host` tier), the walk admits each layer
/// unit's parameters just before computing it, prefetches the next unit
/// behind the compute, and evicts units it has passed — only pinned units
/// (the run's trainable group) stay resident.  Lossless paging restores the
/// exact bits, so results stay bit-identical to the resident walk.
///
/// `prec` selects the compute precision (`--precision f32|bf16|f16`):
/// under a half mode every block-level product is rounded to the target
/// format as it is produced and the retained caches store 16-bit words
/// (half the activation residency); the softmax/loss head stays f32, as is
/// standard for mixed-precision training.  [`Precision::F32`] is
/// bit-identical to the historical path — every hook is a no-op.
#[allow(clippy::too_many_arguments)]
pub fn forward_ckpt(
    cfg: &ModelCfg,
    variant: &str,
    params: &mut TensorSet,
    batch: &Batch,
    policy: ActCkpt,
    pager: Option<&mut UnitPager>,
    prec: Precision,
) -> Result<FwdState> {
    forward_impl(cfg, variant, &mut ParamsView::Excl { params, pager }, batch, policy, prec, None)
}

/// One data-parallel worker's forward over its batch shard, against a
/// shared read-only parameter snapshot (no pager — offload and sharding
/// are mutually exclusive).  `denom` is the *global* loss-mask weight sum
/// the coordinator derived for the whole batch: seeding backward with it
/// makes every per-row gradient contribution identical to the plain
/// walk's, so the reducer's tree fold needs no rescaling — and a shard
/// whose rows are all mask-zero contributes exact zeros instead of
/// tripping the plain path's 0/0 bail.
pub fn forward_shard(
    cfg: &ModelCfg,
    variant: &str,
    params: &TensorSet,
    batch: &Batch,
    policy: ActCkpt,
    prec: Precision,
    denom: f32,
) -> Result<FwdState> {
    forward_impl(cfg, variant, &mut ParamsView::Shared(params), batch, policy, prec, Some(denom))
}

#[allow(clippy::too_many_arguments)]
fn forward_impl(
    cfg: &ModelCfg,
    variant: &str,
    ps: &mut ParamsView<'_>,
    batch: &Batch,
    policy: ActCkpt,
    prec: Precision,
    denom_override: Option<f32>,
) -> Result<FwdState> {
    check_variant(variant)?;
    batch.validate()?;
    let (bsz, s) = (batch.b, batch.s);
    let (d, heads) = (cfg.d_model, cfg.n_heads);
    let v_ = cfg.vocab;
    if d == 0 || heads == 0 || d % heads != 0 {
        bail!("bad geometry: d_model={} n_heads={}", d, heads);
    }
    if s > cfg.seq_len {
        bail!("batch seq {} exceeds model seq_len {}", s, cfg.seq_len);
    }
    for &t in batch.tokens.iter().chain(batch.targets.iter()) {
        if t < 0 || t as usize >= v_ {
            bail!("token id {t} outside vocab {v_}");
        }
    }
    let p_ = if variant == "prefix" { cfg.n_prefix } else { 0 };
    let t_ = s + p_;
    let bt = bsz * t_;
    let bs = bsz * s;

    // --- embeddings ---------------------------------------------------
    ps.ensure_unit(0)?;
    ps.prefetch_unit(1);
    let tok_emb = get(ps.view(), "tok_emb")?;
    let pos_emb = get(ps.view(), "pos_emb")?;
    let mut x0 = vec![0.0f32; bt * d];
    for b in 0..bsz {
        for tt in 0..t_ {
            let row = &mut x0[(b * t_ + tt) * d..][..d];
            if tt < p_ {
                // Prefix rows live in the reserved pos_emb block at
                // seq_len..seq_len+n_prefix, independent of the batch's
                // runtime length (s may be < seq_len).
                let base = cfg.seq_len + tt;
                let pre = get(ps.view(), "prefix.emb")?;
                row.copy_from_slice(&pre.data[tt * d..(tt + 1) * d]);
                axpy(row, 1.0, &pos_emb.data[base * d..(base + 1) * d]);
            } else {
                let tc = tt - p_;
                let tok = batch.tokens[b * s + tc] as usize;
                row.copy_from_slice(&tok_emb.data[tok * d..(tok + 1) * d]);
                axpy(row, 1.0, &pos_emb.data[tc * d..(tc + 1) * d]);
            }
        }
    }

    ps.release_unit(0)?;
    prec.quantize_slice(&mut x0);

    // --- transformer blocks -------------------------------------------
    let seg = policy.seg_len(cfg.n_layers);
    let mut layers: Vec<Option<LayerState>> = Vec::with_capacity(cfg.n_layers);
    let mut boundaries: Vec<Option<PrecBuf>> = Vec::with_capacity(cfg.n_layers);
    let mut x = x0;
    for i in 0..cfg.n_layers {
        ps.ensure_unit(i + 1)?;
        // Double-buffer the next unit's page-in behind this layer's
        // compute (the head unit follows the last block).
        ps.prefetch_unit(if i + 2 <= cfg.n_layers { i + 2 } else { cfg.n_layers + 1 });
        let x_in = x;
        let (state, x_out) = layer_fwd(cfg, variant, ps.view(), i, x_in, bsz, t_, prec)?;
        ps.release_unit(i + 1)?;
        match seg {
            None => {
                layers.push(Some(state));
                boundaries.push(None);
            }
            Some(k) => {
                // Retain only the boundary residual streams; the layer's
                // internals are dropped here and rebuilt on backward.
                layers.push(None);
                boundaries.push(if i % k == 0 { Some(state.x_in) } else { None });
            }
        }
        x = x_out;
    }
    let x_fin = x;

    // --- head + masked loss -------------------------------------------
    // The head unit stays resident after the forward: a grad run's backward
    // reads it first (the caller's end-of-run sweep evicts it otherwise).
    ps.ensure_unit(cfg.n_layers + 1)?;
    let (mut hf, lnf) = ln_fwd(
        &x_fin,
        &get(ps.view(), "ln_f.scale")?.data,
        &get(ps.view(), "ln_f.bias")?.data,
        d,
    );
    prec.quantize_slice(&mut hf);
    let hf_s = if p_ == 0 {
        Vec::new() // hf already is [BS, D]; avoid duplicating it
    } else {
        let mut out = vec![0.0f32; bs * d];
        for b in 0..bsz {
            for tc in 0..s {
                let src = &hf[(b * t_ + p_ + tc) * d..][..d];
                out[(b * s + tc) * d..][..d].copy_from_slice(src);
            }
        }
        out
    };
    let hf_s_ref: &[f32] = if p_ == 0 { &hf } else { &hf_s };
    let mut logits = vec![0.0f32; bs * v_];
    par::matmul(hf_s_ref, &get(ps.view(), "head.w")?.data, &mut logits, bs, d, v_);
    add_bias(&mut logits, &get(ps.view(), "head.b")?.data);
    // The logits leave the half-precision region here: softmax and the
    // masked loss run in f32 (standard mixed-precision head handling).
    prec.quantize_slice(&mut logits);

    // In-place softmax; per-row (nll, correct) side-channel.
    let mut rowstats = vec![0.0f32; bs * 2];
    {
        let targets = &batch.targets;
        par::par_items2(&mut logits, v_, &mut rowstats, 2, |r, lrow, st| {
            let tgt = targets[r] as usize;
            let gold = lrow[tgt];
            let mut maxv = f32::NEG_INFINITY;
            let mut arg = 0usize;
            for (j, &z) in lrow.iter().enumerate() {
                if z > maxv {
                    maxv = z;
                    arg = j;
                }
            }
            let mut sum = 0.0f32;
            for z in lrow.iter_mut() {
                *z = (*z - maxv).exp();
                sum += *z;
            }
            let inv = 1.0 / sum;
            for z in lrow.iter_mut() {
                *z *= inv;
            }
            st[0] = sum.ln() + maxv - gold; // logsumexp - gold logit
            st[1] = (arg == tgt) as u8 as f32;
        });
    }
    // Per-batch-row statistics folded by the canonical tree (the grain +
    // fold the shard reducer applies to N workers' rows), so the loss is
    // invariant to the worker topology.
    let mut row_stats: Vec<[f64; 3]> = Vec::with_capacity(bsz);
    for b in 0..bsz {
        let mut t = [0.0f64; 3];
        for tc in 0..s {
            let r = b * s + tc;
            let w = batch.weights[r] as f64;
            t[0] += rowstats[r * 2] as f64 * w;
            t[1] += w;
            t[2] += rowstats[r * 2 + 1] as f64 * w;
        }
        row_stats.push(t);
    }
    let [loss_acc, wsum, ncorrect] = shard::tree_fold_stats(row_stats.clone());
    let denom = match denom_override {
        Some(global) => global,
        None => {
            if wsum <= 0.0 {
                // The old `wsum.max(1e-6)` fallback silently produced loss 0 /
                // all-zero gradients for a batch whose loss mask selects nothing —
                // a config bug that then reads as a perfectly converged model.
                // Bail like the PR 3 empty-batch eval fix.
                bail!(
                    "batch [{bsz}x{s}] has zero total loss-mask weight: no position is \
                     supervised (weighted loss would be 0/0)"
                );
            }
            wsum as f32
        }
    };
    Ok(FwdState {
        loss: (loss_acc / denom as f64) as f32,
        ncorrect: ncorrect as f32,
        layers,
        boundaries,
        x_fin: PrecBuf::store(prec, x_fin),
        hf: PrecBuf::store(prec, hf),
        lnf,
        hf_s: PrecBuf::store(prec, hf_s),
        probs_out: PrecBuf::store(prec, logits),
        denom,
        row_stats,
        n_pre: p_,
        prec,
    })
}

/// Gradient-emission callback for [`backward_streamed`]: `(parameter name,
/// gradient, params)`.  The `&mut TensorSet` handle lets fused sinks update
/// the parameter in place — by the time a gradient is emitted, the walk
/// never reads that tensor again.
pub type EmitFn<'a> = dyn FnMut(&str, Tensor, &mut TensorSet) -> Result<()> + 'a;

/// Reverse-mode gradients for the parameters `spec` requests, collected
/// into a map (compatibility wrapper over [`backward_streamed`]).
pub fn backward(
    st: &FwdState,
    cfg: &ModelCfg,
    variant: &str,
    params: &mut TensorSet,
    batch: &Batch,
    spec: &GradSpec,
) -> Result<Grads> {
    let mut grads: Grads = Grads::new();
    let mut emit = |name: &str, g: Tensor, _ps: &mut TensorSet| -> Result<()> {
        grads.insert(name.to_string(), g);
        Ok(())
    };
    backward_streamed(st, cfg, variant, params, batch, spec, &mut emit, None, 1.0)?;
    Ok(grads)
}

/// What a streamed backward spent on the recompute path (all zero when the
/// forward cached everything, i.e. policy [`ActCkpt::None`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct BwdStats {
    /// Layer forward passes re-run during this backward.
    pub recompute_layers: u64,
    /// Estimated flops spent on those recomputations.
    pub recompute_flops: u64,
    /// Peak bytes of chained boundary scratch held on top of the
    /// [`FwdState`] cache.
    pub peak_scratch_bytes: u64,
}

/// Rebuild layer `i`'s activation cache from the nearest stored boundary at
/// or below it, chaining the residual stream forward through `layer_fwd`
/// — the exact computation the original forward ran, so every recomputed
/// buffer (and every gradient formed from it) is bit-identical to the
/// cache-everything path.  Intermediate boundaries are parked in `scratch`,
/// which the descending backward walk consumes and frees layer by layer.
///
/// Safe under fused in-place parameter updates: the walk is at layer `i`,
/// so only parameters of layers `<= i` are read here, and none of their
/// gradients has been emitted yet — the streamed contract ("never read a
/// tensor after emitting its gradient") is preserved.
#[allow(clippy::too_many_arguments)]
fn recompute_layer(
    st: &FwdState,
    cfg: &ModelCfg,
    variant: &str,
    ps: &mut ParamsView<'_>,
    bsz: usize,
    t_: usize,
    i: usize,
    scratch: &mut [Option<PrecBuf>],
    scratch_bytes: &mut u64,
    stats: &mut BwdStats,
) -> Result<LayerState> {
    let prec = st.prec;
    // Nearest available boundary at or below layer i.
    let mut c = i;
    while scratch[c].is_none() && st.boundaries[c].is_none() {
        if c == 0 {
            bail!("activation checkpointing: no boundary stored at or below layer {i}");
        }
        c -= 1;
    }
    // Chain the residual stream from the boundary up to layer i, parking
    // each intermediate layer input in `scratch` for the walk's descent.
    // Scratch entries are stored at the compute precision's width (the
    // parked values are already representable, so the round trip is exact).
    for j in c..i {
        // Paged walk: the chained layers' parameters return transiently
        // (their gradients have not been emitted, so re-reading them is
        // within the streamed contract — and lossless paging restores the
        // exact bits the original forward read).
        ps.ensure_unit(j + 1)?;
        let (x_j, from_scratch) = match scratch[j].take() {
            Some(b) => (b.into_vec(), true),
            None => (st.boundaries[j].as_ref().unwrap().load().into_owned(), false),
        };
        let (stj, x_out) = layer_fwd(cfg, variant, ps.view(), j, x_j, bsz, t_, prec)?;
        ps.release_unit(j + 1)?;
        stats.recompute_layers += 1;
        stats.recompute_flops += layer_flops(cfg, bsz, t_);
        let LayerState { x_in, .. } = stj;
        if from_scratch {
            scratch[j] = Some(x_in); // return the borrowed boundary
        }
        if scratch[j + 1].is_none() && st.boundaries[j + 1].is_none() {
            let parked = PrecBuf::store(prec, x_out);
            *scratch_bytes += parked.bytes() as u64;
            scratch[j + 1] = Some(parked);
            stats.peak_scratch_bytes = stats.peak_scratch_bytes.max(*scratch_bytes);
        }
    }
    // Layer i's boundary moves into the rebuilt cache (it *is* the cache's
    // `x_in`), so it leaves the scratch accounting.
    let x_i = match scratch[i].take() {
        Some(b) => {
            *scratch_bytes -= b.bytes() as u64;
            b.into_vec()
        }
        None => st.boundaries[i].as_ref().unwrap().load().into_owned(),
    };
    let (state, _x_out) = layer_fwd(cfg, variant, ps.view(), i, x_i, bsz, t_, prec)?;
    stats.recompute_layers += 1;
    stats.recompute_flops += layer_flops(cfg, bsz, t_);
    Ok(state)
}

/// Streamed reverse-mode backward: `dx` propagates down to
/// `spec.min_unit`, and every requested gradient is handed to `emit` the
/// moment it is final, then dropped by the consumer — peak parameter-
/// gradient residency is one tensor, not the whole requested set.
///
/// Each layer runs in two phases.  Phase 1 propagates activation
/// gradients (`dq/dk/dv`, `da1`, …) and performs **every read of the
/// layer's parameters**.  Phase 2 then forms the weight/bias gradients one
/// at a time — in manifest parameter order within the unit — and emits
/// each immediately.  Because no parameter is read after its gradient is
/// emitted, a sink may fuse the optimizer update in place without
/// changing any downstream gradient; and because every gradient is
/// computed from the same cached activations and pre-update parameters as
/// the collected path, the emitted values are bit-identical to
/// [`backward`].
///
/// Emission order: head unit first, then layers top-down, then the
/// embedding unit; within a unit, manifest parameter order; a layer's
/// adapter gradients (LoRA/IA³) follow its base tensors; `prefix.emb`
/// comes last.  This is a fixed permutation of the artifact output order.
///
/// When `st` came from a checkpointing [`forward_ckpt`], each layer's
/// internal activations are rebuilt from its boundary residual stream by
/// `recompute_layer` just before that layer's gradients are emitted; the
/// returned [`BwdStats`] reports the recompute work and scratch residency
/// (all zero on the fully-cached path).
///
/// The walk replays the forward's compute precision (`st.precision()`):
/// under a half mode every propagated gradient buffer is rounded to the
/// target format as it is produced.  `loss_scale` multiplies the backward
/// seed (dynamic loss scaling for f16 — keep it `1.0` otherwise, which is
/// bit-exact); emitted gradients carry the scale, and the caller divides
/// it back out in f32 after emission (the native backend does, before the
/// sink sees the gradient).
#[allow(clippy::too_many_arguments)]
pub fn backward_streamed(
    st: &FwdState,
    cfg: &ModelCfg,
    variant: &str,
    params: &mut TensorSet,
    batch: &Batch,
    spec: &GradSpec,
    emit: &mut EmitFn<'_>,
    pager: Option<&mut UnitPager>,
    loss_scale: f32,
) -> Result<BwdStats> {
    let mut ps = ParamsView::Excl { params, pager };
    let mut out = GradOut::Fold(emit);
    backward_impl(st, cfg, variant, &mut ps, batch, spec, &mut out, loss_scale)
}

/// One data-parallel worker's streamed backward over its batch shard:
/// identical walk to [`backward_streamed`], but parameters are a shared
/// read-only snapshot and every emission site *ships* its per-batch-row
/// partials through `ship` (in the plain walk's exact emission order)
/// instead of folding and emitting locally — the shard reducer on the
/// other end folds the global row set with the same canonical tree.
#[allow(clippy::too_many_arguments)]
pub fn backward_shard(
    st: &FwdState,
    cfg: &ModelCfg,
    variant: &str,
    params: &TensorSet,
    batch: &Batch,
    spec: &GradSpec,
    ship: &mut ShipFn<'_>,
    loss_scale: f32,
) -> Result<BwdStats> {
    let mut ps = ParamsView::Shared(params);
    let mut out = GradOut::Ship(ship);
    backward_impl(st, cfg, variant, &mut ps, batch, spec, &mut out, loss_scale)
}

#[allow(clippy::too_many_arguments)]
fn backward_impl(
    st: &FwdState,
    cfg: &ModelCfg,
    variant: &str,
    ps: &mut ParamsView<'_>,
    batch: &Batch,
    spec: &GradSpec,
    out: &mut GradOut<'_, '_>,
    loss_scale: f32,
) -> Result<BwdStats> {
    check_variant(variant)?;
    let (bsz, s) = (batch.b, batch.s);
    let (d, heads, f_) = (cfg.d_model, cfg.n_heads, cfg.d_ff);
    let v_ = cfg.vocab;
    let dh = d / heads;
    let p_ = st.n_pre;
    let t_ = s + p_;
    let bt = bsz * t_;
    let bs = bsz * s;
    let scale = 1.0 / (dh as f32).sqrt();
    let lora = variant == "lora";
    let ia3 = variant == "ia3";
    let lora_sc = (cfg.lora_alpha / cfg.lora_rank.max(1) as f64) as f32;
    let head_unit = cfg.n_layers + 1;
    let prec = st.prec;

    // --- loss → logits -------------------------------------------------
    // The seed carries the loss scale: every downstream f16 intermediate
    // is shifted up by it, keeping small gradients above the subnormal
    // floor.  (`w * 1.0` is exact, so the f32 path is untouched.)
    let mut dlogits = st.probs_out.load().into_owned();
    for r in 0..bs {
        let w = batch.weights[r] * loss_scale / st.denom;
        let row = &mut dlogits[r * v_..(r + 1) * v_];
        row[batch.targets[r] as usize] -= 1.0;
        for z in row.iter_mut() {
            *z *= w;
        }
    }
    prec.quantize_slice(&mut dlogits);

    // --- head ----------------------------------------------------------
    // Propagate through the head *before* emitting its gradients: once a
    // gradient is emitted the sink may update that tensor in place, so all
    // reads of head.w / ln_f.scale must happen first.
    let mut dhf_s = vec![0.0f32; bs * d];
    {
        let head_w = get(ps.view(), "head.w")?;
        par::matmul_bt(&dlogits, &head_w.data, &mut dhf_s, bs, v_, d);
    }
    prec.quantize_slice(&mut dhf_s);
    let dhf = if p_ == 0 {
        dhf_s
    } else {
        let mut out = vec![0.0f32; bt * d];
        for b in 0..bsz {
            for tc in 0..s {
                out[(b * t_ + p_ + tc) * d..][..d]
                    .copy_from_slice(&dhf_s[(b * s + tc) * d..][..d]);
            }
        }
        out
    };
    let x_fin_l = st.x_fin.load();
    let (mut dx, dscale_f, dbias_f) = {
        let scale_f = get(ps.view(), "ln_f.scale")?;
        ln_bwd(&dhf, &x_fin_l, &st.lnf, &scale_f.data, d, t_)
    };
    drop(dhf);
    prec.quantize_slice(&mut dx);
    if spec.emit(head_unit) {
        out.rows("ln_f.scale", &[d], dscale_f, ps)?;
        out.rows("ln_f.bias", &[d], dbias_f, ps)?;
        if spec.dense {
            let hf_l = st.hf.load();
            let hfs_l = st.hf_s.load();
            let hf_s: &[f32] = if p_ == 0 { &hf_l } else { &hfs_l };
            out.rows("head.w", &[d, v_], shard::matmul_at_rows(hf_s, &dlogits, bsz, s, d, v_), ps)?;
        }
        out.rows("head.b", &[v_], shard::colsum_rows(&dlogits, bsz, s, v_), ps)?;
    }
    drop(dlogits);
    // The head's reads and emits are done; a pinned head (its grads were
    // emitted and updated in place) survives this release as a no-op.
    ps.release_unit(head_unit)?;

    // --- blocks, top-down ----------------------------------------------
    let mut bstats = BwdStats::default();
    let mut scratch: Vec<Option<PrecBuf>> = vec![None; cfg.n_layers];
    let mut scratch_bytes = 0u64;
    for i in (0..cfg.n_layers).rev() {
        if i + 1 < spec.min_unit {
            // Truncated backprop: nothing below this unit was requested.
            return Ok(bstats);
        }
        ps.ensure_unit(i + 1)?;
        if i > 0 {
            ps.prefetch_unit(i); // the next unit the descent will touch
        }
        let ls_owned;
        let ls: &LayerState = match st.layers[i].as_ref() {
            Some(cached) => cached,
            None => {
                ls_owned = recompute_layer(
                    st,
                    cfg,
                    variant,
                    ps,
                    bsz,
                    t_,
                    i,
                    &mut scratch,
                    &mut scratch_bytes,
                    &mut bstats,
                )?;
                &ls_owned
            }
        };
        let pfx = format!("l{i}.");
        let emit_unit = spec.emit(i + 1);
        let emit_w = emit_unit && spec.dense;
        // Decode the layer's caches once (borrowed — free — in f32 mode;
        // an owned 16→32-bit expansion under the half modes, transient
        // working memory like backward's own gradient temporaries).
        let a1_l = ls.a1.load();
        let mid0_l = ls.mid0.load();
        let mid_ia3_l = ls.mid_ia3.load();
        let x_in_l = ls.x_in.load();
        let x_mid_l = ls.x_mid.load();
        let h1_l = ls.h1.load();
        let h2_l = ls.h2.load();
        let q_l = ls.q.load();
        let k_l = ls.k.load();
        let v_l = ls.v.load();
        let k0_l = ls.k0.load();
        let v0_l = ls.v0.load();
        let probs_l = ls.probs.load();
        let attn_l = ls.attn.load();
        let wq_eff_l = ls.wq_eff.load();
        let wv_eff_l = ls.wv_eff.load();
        let mid_ref: &[f32] = if ia3 { &mid_ia3_l } else { &mid0_l };

        // ---- phase 1: propagate activation gradients.  Every read of
        // this layer's parameters happens here, before any of its
        // gradients is emitted (so fused sinks can update in place).
        let dx_in = dx;
        let mut dmid = vec![0.0f32; bt * f_];
        {
            let w2 = get(ps.view(), &format!("{pfx}ffn.w2"))?;
            par::matmul_bt(&dx_in, &w2.data, &mut dmid, bt, d, f_);
        }
        let mut dlff: Vec<Vec<f32>> = Vec::new();
        if ia3 {
            let lff = &get(ps.view(), &format!("{pfx}ia3.lff"))?.data;
            if spec.adapters {
                // Per-batch-row partials (canonical reduction grain).
                for b in 0..bsz {
                    let mut part = vec![0.0f32; f_];
                    for r in b * t_..(b + 1) * t_ {
                        for j in 0..f_ {
                            part[j] += dmid[r * f_ + j] * mid0_l[r * f_ + j];
                        }
                    }
                    dlff.push(part);
                }
            }
            for row in dmid.chunks_mut(f_) {
                for (mj, &lj) in row.iter_mut().zip(lff.iter()) {
                    *mj *= lj;
                }
            }
        }
        prec.quantize_slice(&mut dmid);
        // GELU'
        let mut da1 = dmid;
        {
            let a1: &[f32] = &a1_l;
            par::par_rows(&mut da1, f_, 4 * f_, |r0, chunk| {
                let base = r0 * f_;
                kernels::dgelu_slice(chunk, &a1[base..base + chunk.len()]);
            });
        }
        prec.quantize_slice(&mut da1);
        let mut dh2 = vec![0.0f32; bt * d];
        {
            let w1 = get(ps.view(), &format!("{pfx}ffn.w1"))?;
            par::matmul_bt(&da1, &w1.data, &mut dh2, bt, f_, d);
        }
        prec.quantize_slice(&mut dh2);
        let (dx_ln2, dsc2, dbi2) = {
            let sc2 = get(ps.view(), &format!("{pfx}ln2.scale"))?;
            ln_bwd(&dh2, &x_mid_l, &ls.ln2, &sc2.data, d, t_)
        };
        drop(dh2);
        // Keep the layer-top gradient alive only when phase 2 will consume
        // it (ffn.w2/b2); pass-through layers move it — no copy on the
        // truncated-backprop hot path.
        let (mut dx_mid, dx_top) =
            if emit_unit { (dx_in.clone(), dx_in) } else { (dx_in, Vec::new()) };
        axpy(&mut dx_mid, 1.0, &dx_ln2);
        drop(dx_ln2);
        prec.quantize_slice(&mut dx_mid);

        // attention out-projection input gradient
        let mut dattn = vec![0.0f32; bt * d];
        {
            let wo = get(ps.view(), &format!("{pfx}attn.wo"))?;
            par::matmul_bt(&dx_mid, &wo.data, &mut dattn, bt, d, d);
        }
        prec.quantize_slice(&mut dattn);

        // attention core
        let q_hm = gather_heads(&q_l, bsz, t_, heads, dh);
        let k_hm = gather_heads(&k_l, bsz, t_, heads, dh);
        let v_hm = gather_heads(&v_l, bsz, t_, heads, dh);
        let do_hm = gather_heads(&dattn, bsz, t_, heads, dh);
        drop(dattn);
        let mut dq_hm = vec![0.0f32; bsz * heads * t_ * dh];
        let mut dk_hm = vec![0.0f32; bsz * heads * t_ * dh];
        let mut dv_hm = vec![0.0f32; bsz * heads * t_ * dh];
        // A fused-attention forward cached no probs matrix; recompute each
        // query row's probabilities from q/k on the fly (O(T) scratch per
        // thread).  The recompute shares `attn_prob_row` with the forward,
        // so the values are bit-identical to what a materializing forward
        // would have cached.
        let probs_s: &[f32] = &probs_l;
        let fused_bwd = probs_s.is_empty();
        let attn_bwd_t0 = std::time::Instant::now();
        par::par_items3(
            &mut dq_hm,
            t_ * dh,
            &mut dk_hm,
            t_ * dh,
            &mut dv_hm,
            t_ * dh,
            |bh, dqc, dkc, dvc| {
                let qb = &q_hm[bh * t_ * dh..][..t_ * dh];
                let kb = &k_hm[bh * t_ * dh..][..t_ * dh];
                let vb = &v_hm[bh * t_ * dh..][..t_ * dh];
                let dob = &do_hm[bh * t_ * dh..][..t_ * dh];
                let pch: &[f32] = if fused_bwd { &[] } else { &probs_s[bh * t_ * t_..][..t_ * t_] };
                let mut srow = if fused_bwd { vec![0.0f32; t_] } else { Vec::new() };
                let mut dp = vec![0.0f32; t_];
                for ti in 0..t_ {
                    let dorow = &dob[ti * dh..][..dh];
                    let prow: &[f32] = if fused_bwd {
                        attn_prob_row(qb, kb, &mut srow, ti, dh, scale, prec);
                        &srow
                    } else {
                        &pch[ti * t_..][..t_]
                    };
                    let mut pdp = 0.0f32;
                    for j in 0..=ti {
                        let pij = prow[j];
                        if pij != 0.0 {
                            axpy(&mut dvc[j * dh..][..dh], pij, dorow);
                        }
                        let dpj = dot(dorow, &vb[j * dh..][..dh]);
                        dp[j] = dpj;
                        pdp += pij * dpj;
                    }
                    for j in 0..=ti {
                        let ds = prow[j] * (dp[j] - pdp) * scale;
                        if ds != 0.0 {
                            axpy(&mut dqc[ti * dh..][..dh], ds, &kb[j * dh..][..dh]);
                            axpy(&mut dkc[j * dh..][..dh], ds, &qb[ti * dh..][..dh]);
                        }
                    }
                }
            },
        );
        // dV + dP dots + dQ/dK rank-1 updates ≈ 8·dh flops per (ti, j)
        // pair, plus the 2·dh-flop row recompute on the fused path.
        kernels::note(
            (bsz * heads) as u64
                * (if fused_bwd { 5 } else { 4 }) * dh as u64
                * (t_ * (t_ + 1)) as u64,
            attn_bwd_t0.elapsed().as_nanos() as u64,
        );
        let mut dq = scatter_heads(&dq_hm, bsz, t_, heads, dh);
        let mut dk = scatter_heads(&dk_hm, bsz, t_, heads, dh);
        let mut dv = scatter_heads(&dv_hm, bsz, t_, heads, dh);
        prec.quantize_slice(&mut dq);

        // IA³ on k/v (gradients flow to the pre-scale activations)
        let (mut dlk, mut dlv): (Vec<Vec<f32>>, Vec<Vec<f32>>) = (Vec::new(), Vec::new());
        if ia3 {
            let lk = &get(ps.view(), &format!("{pfx}ia3.lk"))?.data;
            let lv = &get(ps.view(), &format!("{pfx}ia3.lv"))?.data;
            if spec.adapters {
                for b in 0..bsz {
                    let mut pk = vec![0.0f32; d];
                    let mut pv = vec![0.0f32; d];
                    for r in b * t_..(b + 1) * t_ {
                        for j in 0..d {
                            pk[j] += dk[r * d + j] * k0_l[r * d + j];
                            pv[j] += dv[r * d + j] * v0_l[r * d + j];
                        }
                    }
                    dlk.push(pk);
                    dlv.push(pv);
                }
            }
            for row in dk.chunks_mut(d) {
                for (kj, &lj) in row.iter_mut().zip(lk.iter()) {
                    *kj *= lj;
                }
            }
            for row in dv.chunks_mut(d) {
                for (vj, &lj) in row.iter_mut().zip(lv.iter()) {
                    *vj *= lj;
                }
            }
        }
        prec.quantize_slice(&mut dk);
        prec.quantize_slice(&mut dv);

        // LoRA factor gradients (chain rule through dW_q/dW_v).  The dW
        // intermediates are built as per-batch-row partials (canonical
        // grain).  On the plain path they are folded and chained into the
        // factor gradients here — before any emission, so the reads of
        // the LoRA factors precede their own updates.  Sharded workers
        // park the partials instead and ship them at the layer's LoRA
        // emission point; the reducer folds and runs the same chain rule
        // against the snapshot factors.
        let mut lora_grads: Vec<(String, Tensor)> = Vec::new();
        let mut lora_parts: Option<(Vec<Vec<f32>>, Vec<Vec<f32>>)> = None;
        if lora && spec.adapters {
            let dwq_parts = shard::matmul_at_rows(&h1_l, &dq, bsz, t_, d, d);
            let dwv_parts = shard::matmul_at_rows(&h1_l, &dv, bsz, t_, d, d);
            match out {
                GradOut::Ship(_) => lora_parts = Some((dwq_parts, dwv_parts)),
                GradOut::Fold(_) => {
                    let r = cfg.lora_rank;
                    let dwq_full = shard::tree_fold(dwq_parts);
                    let dwv_full = shard::tree_fold(dwv_parts);
                    let aq = get(ps.view(), &format!("{pfx}lora.aq"))?;
                    let bq = get(ps.view(), &format!("{pfx}lora.bq"))?;
                    let av = get(ps.view(), &format!("{pfx}lora.av"))?;
                    let bv = get(ps.view(), &format!("{pfx}lora.bv"))?;
                    let mut daq = vec![0.0f32; d * r];
                    par::matmul_bt(&dwq_full, &bq.data, &mut daq, d, d, r);
                    daq.iter_mut().for_each(|z| *z *= lora_sc);
                    let mut dbq = vec![0.0f32; r * d];
                    par::matmul_at(&aq.data, &dwq_full, &mut dbq, d, r, d);
                    dbq.iter_mut().for_each(|z| *z *= lora_sc);
                    let mut dav = vec![0.0f32; d * r];
                    par::matmul_bt(&dwv_full, &bv.data, &mut dav, d, d, r);
                    dav.iter_mut().for_each(|z| *z *= lora_sc);
                    let mut dbv = vec![0.0f32; r * d];
                    par::matmul_at(&av.data, &dwv_full, &mut dbv, d, r, d);
                    dbv.iter_mut().for_each(|z| *z *= lora_sc);
                    lora_grads.push((format!("{pfx}lora.aq"), Tensor::from_vec(daq, &[d, r])));
                    lora_grads.push((format!("{pfx}lora.bq"), Tensor::from_vec(dbq, &[r, d])));
                    lora_grads.push((format!("{pfx}lora.av"), Tensor::from_vec(dav, &[d, r])));
                    lora_grads.push((format!("{pfx}lora.bv"), Tensor::from_vec(dbv, &[r, d])));
                }
            }
        }

        // dh1 and the LN1 backward complete the layer's parameter reads.
        let mut dh1 = vec![0.0f32; bt * d];
        par::matmul_bt(&dq, &wq_eff_l, &mut dh1, bt, d, d);
        {
            let wk = get(ps.view(), &format!("{pfx}attn.wk"))?;
            par::matmul_bt(&dk, &wk.data, &mut dh1, bt, d, d);
        }
        par::matmul_bt(&dv, &wv_eff_l, &mut dh1, bt, d, d);
        prec.quantize_slice(&mut dh1);
        let (dx_ln1, dsc1, dbi1) = {
            let sc1 = get(ps.view(), &format!("{pfx}ln1.scale"))?;
            ln_bwd(&dh1, &x_in_l, &ls.ln1, &sc1.data, d, t_)
        };
        drop(dh1);

        // ---- phase 2: weight/bias gradients, one at a time, in manifest
        // parameter order, each emitted (and dropped by the sink) before
        // the next is materialized.
        if emit_unit {
            out.rows(&format!("{pfx}ln1.scale"), &[d], dsc1, ps)?;
            out.rows(&format!("{pfx}ln1.bias"), &[d], dbi1, ps)?;
        }
        if emit_w {
            let parts = shard::matmul_at_rows(&h1_l, &dq, bsz, t_, d, d);
            out.rows(&format!("{pfx}attn.wq"), &[d, d], parts, ps)?;
        }
        if emit_unit {
            out.rows(&format!("{pfx}attn.bq"), &[d], shard::colsum_rows(&dq, bsz, t_, d), ps)?;
        }
        if emit_w {
            let parts = shard::matmul_at_rows(&h1_l, &dk, bsz, t_, d, d);
            out.rows(&format!("{pfx}attn.wk"), &[d, d], parts, ps)?;
        }
        if emit_unit {
            out.rows(&format!("{pfx}attn.bk"), &[d], shard::colsum_rows(&dk, bsz, t_, d), ps)?;
        }
        if emit_w {
            let parts = shard::matmul_at_rows(&h1_l, &dv, bsz, t_, d, d);
            out.rows(&format!("{pfx}attn.wv"), &[d, d], parts, ps)?;
        }
        if emit_unit {
            out.rows(&format!("{pfx}attn.bv"), &[d], shard::colsum_rows(&dv, bsz, t_, d), ps)?;
        }
        if emit_w {
            let parts = shard::matmul_at_rows(&attn_l, &dx_mid, bsz, t_, d, d);
            out.rows(&format!("{pfx}attn.wo"), &[d, d], parts, ps)?;
        }
        if emit_unit {
            out.rows(&format!("{pfx}attn.bo"), &[d], shard::colsum_rows(&dx_mid, bsz, t_, d), ps)?;
            out.rows(&format!("{pfx}ln2.scale"), &[d], dsc2, ps)?;
            out.rows(&format!("{pfx}ln2.bias"), &[d], dbi2, ps)?;
        }
        if emit_w {
            let parts = shard::matmul_at_rows(&h2_l, &da1, bsz, t_, d, f_);
            out.rows(&format!("{pfx}ffn.w1"), &[d, f_], parts, ps)?;
        }
        if emit_unit {
            out.rows(&format!("{pfx}ffn.b1"), &[f_], shard::colsum_rows(&da1, bsz, t_, f_), ps)?;
        }
        drop(da1);
        if emit_w {
            let parts = shard::matmul_at_rows(mid_ref, &dx_top, bsz, t_, f_, d);
            out.rows(&format!("{pfx}ffn.w2"), &[f_, d], parts, ps)?;
        }
        if emit_unit {
            out.rows(&format!("{pfx}ffn.b2"), &[d], shard::colsum_rows(&dx_top, bsz, t_, d), ps)?;
        }
        drop(dx_top);
        // this layer's adapter gradients follow its base tensors
        match out {
            GradOut::Fold(emit) => {
                for (name, g) in lora_grads {
                    emit(&name, g, ps.excl())?;
                }
            }
            GradOut::Ship(tx) => {
                if let Some((dwq, dwv)) = lora_parts.take() {
                    tx(GradMsg::LoraDw { layer: i, dwq, dwv })?;
                }
            }
        }
        if ia3 && spec.adapters {
            out.rows(&format!("{pfx}ia3.lk"), &[d], dlk, ps)?;
            out.rows(&format!("{pfx}ia3.lv"), &[d], dlv, ps)?;
            out.rows(&format!("{pfx}ia3.lff"), &[f_], dlff, ps)?;
        }

        dx = dx_mid;
        axpy(&mut dx, 1.0, &dx_ln1);
        prec.quantize_slice(&mut dx);
        ps.release_unit(i + 1)?;
    }

    // --- embeddings (unit 0) + prefix adapter ---------------------------
    // One gradient at a time: the token-embedding scatter (potentially the
    // largest tensor in the model) is emitted and dropped before the
    // position-embedding gradient is materialized.  The scatter loops
    // visit (b, t) in the same order as the old fused loop, and the
    // prefix/content rows of pos_emb are disjoint, so the per-row
    // accumulation sequences — and hence the f32 results — are unchanged.
    let want_emb = spec.emit(0);
    let want_prefix = p_ > 0 && spec.adapters;
    let emit = match out {
        GradOut::Fold(emit) => emit,
        GradOut::Ship(tx) => {
            // The scatters' accumulation grain is the token *occurrence*,
            // not the batch row, so sharded workers ship their dx rows
            // once and the reducer replays these exact serial loops over
            // the concatenated global rows (bit-identical, and far
            // smaller than per-row `[V, D]` partials).
            if want_emb || want_prefix {
                tx(GradMsg::EmbDx { dx })?;
            }
            return Ok(bstats);
        }
    };
    if want_emb {
        let pos_shape = get(ps.view(), "pos_emb")?.shape.clone();
        let mut dtok = vec![0.0f32; v_ * d];
        for b in 0..bsz {
            for tt in p_..t_ {
                let row = &dx[(b * t_ + tt) * d..][..d];
                let tc = tt - p_;
                let tok = batch.tokens[b * s + tc] as usize;
                axpy(&mut dtok[tok * d..(tok + 1) * d], 1.0, row);
            }
        }
        emit("tok_emb", Tensor::from_vec(dtok, &[v_, d]), ps.excl())?;
        let mut dpos = vec![0.0f32; pos_shape.iter().product()];
        for b in 0..bsz {
            for tt in 0..t_ {
                let row = &dx[(b * t_ + tt) * d..][..d];
                if tt < p_ {
                    let base = cfg.seq_len + tt;
                    axpy(&mut dpos[base * d..(base + 1) * d], 1.0, row);
                } else {
                    let tc = tt - p_;
                    axpy(&mut dpos[tc * d..(tc + 1) * d], 1.0, row);
                }
            }
        }
        emit("pos_emb", Tensor::from_vec(dpos, &pos_shape), ps.excl())?;
    }
    if want_prefix {
        let mut dpre = vec![0.0f32; p_ * d];
        for b in 0..bsz {
            for tt in 0..p_ {
                let row = &dx[(b * t_ + tt) * d..][..d];
                axpy(&mut dpre[tt * d..(tt + 1) * d], 1.0, row);
            }
        }
        emit("prefix.emb", Tensor::from_vec(dpre, &[p_, d]), ps.excl())?;
    }
    Ok(bstats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 1,
            n_heads: 2,
            d_ff: 16,
            seq_len: 4,
            batch: 2,
            lora_rank: 2,
            lora_alpha: 8.0,
            n_prefix: 2,
        }
    }

    fn tiny_params(cfg: &ModelCfg) -> TensorSet {
        let mut rng = Pcg32::seeded(9);
        let d = cfg.d_model;
        let mut set = TensorSet::new();
        set.push("tok_emb", Tensor::randn(&[cfg.vocab, d], 0.1, &mut rng));
        set.push("pos_emb", Tensor::randn(&[cfg.seq_len + cfg.n_prefix, d], 0.1, &mut rng));
        for i in 0..cfg.n_layers {
            let p = format!("l{i}.");
            set.push(format!("{p}ln1.scale"), Tensor::ones(&[d]));
            set.push(format!("{p}ln1.bias"), Tensor::zeros(&[d]));
            set.push(format!("{p}attn.wq"), Tensor::randn(&[d, d], 0.3, &mut rng));
            set.push(format!("{p}attn.bq"), Tensor::zeros(&[d]));
            set.push(format!("{p}attn.wk"), Tensor::randn(&[d, d], 0.3, &mut rng));
            set.push(format!("{p}attn.bk"), Tensor::zeros(&[d]));
            set.push(format!("{p}attn.wv"), Tensor::randn(&[d, d], 0.3, &mut rng));
            set.push(format!("{p}attn.bv"), Tensor::zeros(&[d]));
            set.push(format!("{p}attn.wo"), Tensor::randn(&[d, d], 0.3, &mut rng));
            set.push(format!("{p}attn.bo"), Tensor::zeros(&[d]));
            set.push(format!("{p}ln2.scale"), Tensor::ones(&[d]));
            set.push(format!("{p}ln2.bias"), Tensor::zeros(&[d]));
            set.push(format!("{p}ffn.w1"), Tensor::randn(&[d, cfg.d_ff], 0.3, &mut rng));
            set.push(format!("{p}ffn.b1"), Tensor::zeros(&[cfg.d_ff]));
            set.push(format!("{p}ffn.w2"), Tensor::randn(&[cfg.d_ff, d], 0.3, &mut rng));
            set.push(format!("{p}ffn.b2"), Tensor::zeros(&[d]));
        }
        set.push("ln_f.scale", Tensor::ones(&[d]));
        set.push("ln_f.bias", Tensor::zeros(&[d]));
        set.push("head.w", Tensor::randn(&[d, cfg.vocab], 0.3, &mut rng));
        set.push("head.b", Tensor::zeros(&[cfg.vocab]));
        set
    }

    fn tiny_batch(cfg: &ModelCfg, seed: u64) -> Batch {
        let mut rng = Pcg32::seeded(seed);
        let mut b = Batch::new(cfg.batch, cfg.seq_len);
        for t in b.tokens.iter_mut() {
            *t = rng.below(cfg.vocab) as i32;
        }
        for t in b.targets.iter_mut() {
            *t = rng.below(cfg.vocab) as i32;
        }
        for w in b.weights.iter_mut() {
            *w = 1.0;
        }
        b
    }

    #[test]
    fn forward_is_deterministic_and_finite() {
        let cfg = tiny_cfg();
        let mut params = tiny_params(&cfg);
        let batch = tiny_batch(&cfg, 3);
        let a = forward(&cfg, "base", &mut params, &batch).unwrap();
        let b = forward(&cfg, "base", &mut params, &batch).unwrap();
        assert!(a.loss.is_finite() && a.loss > 0.0);
        assert_eq!(a.loss, b.loss);
        // random targets on a random net ⇒ near-uniform loss
        assert!((a.loss - (cfg.vocab as f32).ln()).abs() < 1.5, "loss {}", a.loss);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let cfg = tiny_cfg();
        let mut params = tiny_params(&cfg);
        let batch = tiny_batch(&cfg, 5);
        let st = forward(&cfg, "base", &mut params, &batch).unwrap();
        let probs = st.probs_out();
        for row in probs.chunks(cfg.vocab) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "softmax row sums to {sum}");
        }
    }

    #[test]
    fn backward_truncation_matches_full_backward() {
        let cfg = tiny_cfg();
        let n_units = cfg.n_units();
        let mut params = tiny_params(&cfg);
        let batch = tiny_batch(&cfg, 7);
        let st = forward(&cfg, "base", &mut params, &batch).unwrap();
        let full =
            backward(&st, &cfg, "base", &mut params, &batch, &GradSpec::all(n_units, false))
                .unwrap();
        let head_spec = GradSpec::only_unit(n_units, cfg.n_layers + 1);
        let head_only = backward(&st, &cfg, "base", &mut params, &batch, &head_spec).unwrap();
        assert!(head_only.contains_key("head.w"));
        assert!(!head_only.contains_key("l0.attn.wq"), "truncated below the head");
        assert!(!head_only.contains_key("tok_emb"));
        for (name, g) in &head_only {
            let fg = &full[name];
            assert_eq!(g.shape, fg.shape);
            for (a, b) in g.data.iter().zip(&fg.data) {
                assert_eq!(a, b, "{name}: truncated grad must be bit-identical");
            }
        }
        // A middle unit: emitted grads are bit-identical to the full pass
        // even though the layers above it skip their weight-grad work.
        let mid_spec = GradSpec::only_unit(n_units, 1);
        let mid = backward(&st, &cfg, "base", &mut params, &batch, &mid_spec).unwrap();
        assert!(mid.contains_key("l0.attn.wq"));
        assert!(!mid.contains_key("head.w"), "head not requested");
        for (name, g) in &mid {
            let fg = &full[name];
            for (a, b) in g.data.iter().zip(&fg.data) {
                assert_eq!(a, b, "{name}: gated grad must be bit-identical");
            }
        }
    }

    #[test]
    fn recompute_backward_is_bit_identical() {
        let mut cfg = tiny_cfg();
        cfg.n_layers = 3;
        let n_units = cfg.n_units();
        let mut params = tiny_params(&cfg);
        let batch = tiny_batch(&cfg, 13);
        let spec = GradSpec::all(n_units, false);
        let st = forward(&cfg, "base", &mut params, &batch).unwrap();
        let full = backward(&st, &cfg, "base", &mut params, &batch, &spec).unwrap();
        for policy in [ActCkpt::EveryK(1), ActCkpt::EveryK(2), ActCkpt::Sqrt] {
            let stc =
                forward_ckpt(&cfg, "base", &mut params, &batch, policy, None, Precision::F32)
                    .unwrap();
            assert_eq!(st.loss, stc.loss, "{policy:?}: loss must be bit-identical");
            assert!(
                stc.act_resident_bytes() < st.act_resident_bytes(),
                "{policy:?}: checkpointing must shrink the retained cache"
            );
            let g = backward(&stc, &cfg, "base", &mut params, &batch, &spec).unwrap();
            assert_eq!(g.len(), full.len(), "{policy:?}");
            for (name, grad) in &g {
                assert_eq!(
                    grad.data, full[name].data,
                    "{policy:?} {name}: recomputed grad must be bit-identical"
                );
            }
        }
    }

    #[test]
    fn zero_weight_batch_is_an_error() {
        // Regression (numerics sweep): the old `wsum.max(1e-6)` fallback
        // silently returned loss 0 / all-zero grads for a batch whose mask
        // supervises nothing — indistinguishable from a converged model.
        let cfg = tiny_cfg();
        let mut params = tiny_params(&cfg);
        let mut batch = tiny_batch(&cfg, 11);
        batch.weights.iter_mut().for_each(|w| *w = 0.0);
        let err = forward(&cfg, "base", &mut params, &batch).unwrap_err();
        assert!(
            err.to_string().contains("loss-mask weight"),
            "error must name the zero-weight mask: {err}"
        );
        // A partially-masked batch still works (the normal case).
        batch.weights[0] = 1.0;
        assert!(forward(&cfg, "base", &mut params, &batch).is_ok());
    }

    #[test]
    fn half_precision_forward_backward_drift_is_bounded() {
        let mut cfg = tiny_cfg();
        cfg.n_layers = 2;
        let n_units = cfg.n_units();
        let mut params = tiny_params(&cfg);
        let batch = tiny_batch(&cfg, 21);
        let spec = GradSpec::all(n_units, false);
        let st32 =
            forward_ckpt(&cfg, "base", &mut params, &batch, ActCkpt::None, None, Precision::F32)
                .unwrap();
        let g32 = backward(&st32, &cfg, "base", &mut params, &batch, &spec).unwrap();
        for prec in [Precision::Bf16, Precision::F16] {
            let sth =
                forward_ckpt(&cfg, "base", &mut params, &batch, ActCkpt::None, None, prec)
                    .unwrap();
            assert!(sth.loss.is_finite());
            let rel = (sth.loss - st32.loss).abs() / st32.loss.abs().max(1e-6);
            assert!(rel < 0.05, "{prec:?}: loss drift {rel} ({} vs {})", sth.loss, st32.loss);
            assert_ne!(sth.loss.to_bits(), st32.loss.to_bits(), "{prec:?} provably quantizes");
            assert!(
                sth.act_resident_bytes() < (st32.act_resident_bytes() * 6) / 10,
                "{prec:?}: half storage must cut retained activations ({} vs {})",
                sth.act_resident_bytes(),
                st32.act_resident_bytes()
            );
            let gh = backward(&sth, &cfg, "base", &mut params, &batch, &spec).unwrap();
            assert_eq!(gh.len(), g32.len());
            for (name, g) in &gh {
                assert!(g.data.iter().all(|x| x.is_finite()), "{prec:?} {name} non-finite");
                // grads track the f32 reference in relative L2
                let r = &g32[name];
                let mut num = 0.0f64;
                let mut den = 0.0f64;
                for (a, b) in g.data.iter().zip(&r.data) {
                    num += ((a - b) as f64).powi(2);
                    den += (*b as f64).powi(2);
                }
                let rel = num.sqrt() / den.sqrt().max(1e-12);
                assert!(rel < 0.35, "{prec:?} {name}: grad rel-L2 drift {rel}");
            }
        }
    }

    #[test]
    fn recompute_is_bit_identical_within_a_half_precision() {
        // Quantization is deterministic, so the ckpt/recompute walk must
        // reproduce the cached walk's gradients bit-for-bit at bf16 too.
        let mut cfg = tiny_cfg();
        cfg.n_layers = 3;
        let n_units = cfg.n_units();
        let mut params = tiny_params(&cfg);
        let batch = tiny_batch(&cfg, 23);
        let spec = GradSpec::all(n_units, false);
        let prec = Precision::Bf16;
        let st =
            forward_ckpt(&cfg, "base", &mut params, &batch, ActCkpt::None, None, prec).unwrap();
        let full = backward(&st, &cfg, "base", &mut params, &batch, &spec).unwrap();
        let stc =
            forward_ckpt(&cfg, "base", &mut params, &batch, ActCkpt::Sqrt, None, prec).unwrap();
        assert_eq!(st.loss, stc.loss, "bf16 ckpt loss must be bit-identical");
        let g = backward(&stc, &cfg, "base", &mut params, &batch, &spec).unwrap();
        for (name, grad) in &g {
            assert_eq!(grad.data, full[name].data, "bf16 recomputed grad {name}");
        }
    }

    #[test]
    fn loss_scale_is_divided_out_exactly_in_f32() {
        // Power-of-two scaling of the backward seed must cancel exactly
        // when divided back out (f32: every op is exact under *2^k).
        let cfg = tiny_cfg();
        let n_units = cfg.n_units();
        let mut params = tiny_params(&cfg);
        let batch = tiny_batch(&cfg, 31);
        let spec = GradSpec::all(n_units, false);
        let st = forward(&cfg, "base", &mut params, &batch).unwrap();
        let base = backward(&st, &cfg, "base", &mut params, &batch, &spec).unwrap();
        let mut scaled: Grads = Grads::new();
        {
            let mut emit = |name: &str, mut g: Tensor, _ps: &mut TensorSet| -> Result<()> {
                g.scale(1.0 / 1024.0);
                scaled.insert(name.to_string(), g);
                Ok(())
            };
            backward_streamed(
                &st, &cfg, "base", &mut params, &batch, &spec, &mut emit, None, 1024.0,
            )
            .unwrap();
        }
        for (name, g) in &scaled {
            let b = &base[name];
            for (x, y) in g.data.iter().zip(&b.data) {
                let rel = (x - y).abs() / y.abs().max(1e-12);
                assert!(rel < 1e-5, "{name}: scaled/unscaled grad mismatch {x} vs {y}");
            }
        }
    }
}
