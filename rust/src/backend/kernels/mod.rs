//! The native backend's compute-kernel layer: cache-blocked GEMM with
//! packed panels, optional `std::simd` micro-kernels (cargo feature
//! `simd`), and the SIMD-vectorized elementwise kernels (GELU, LayerNorm
//! normalize, AdamW update) the hot loops in [`super::par`],
//! [`super::model`] and [`crate::optim`] call into.
//!
//! ## The reduction-order guarantee
//!
//! Every kernel in this module computes each output element as a **single
//! fixed-order reduction**: partial products accumulate in ascending
//! reduction-index order into a zero-initialized f32 accumulator, which is
//! added to the output exactly once.  No fused multiply-add, no lane-split
//! reductions, no reassociation.  Because IEEE-754 `+ - * / sqrt` are
//! correctly rounded and `std::simd` lanes perform the same scalar
//! operations element-wise, the three GEMM schedules — [`KernelKind::Naive`]
//! (textbook triple loop, the retained reference), [`KernelKind::Blocked`]
//! (packed panels + register tiles) and [`KernelKind::Simd`] (the same
//! schedule with explicit 8-lane vectors) — produce **bit-identical** f32
//! results, and so do the scalar/SIMD flavors of every elementwise kernel.
//! That is what lets `--kernels` switch schedules without perturbing any
//! streaming/checkpoint/offload/precision identity test.
//!
//! The tile schedule (blocked path): output columns are processed in
//! strips of [`NC`]; per strip, the B operand is packed once into a
//! contiguous `[K, NC]` panel (transposed packing for the `a @ bᵀ` and
//! `aᵀ @ b` forms, so all three GEMM shapes reduce to one micro-kernel);
//! rows are processed in register blocks of [`MR`] with the reduction
//! dimension consumed in [`KC`]-deep passes so the active panel slice
//! stays L1-resident while `MR × NC` accumulators live in registers /
//! the stack.  Threading (via [`super::par::par_rows`]) only ever splits
//! **disjoint output rows**, which does not touch reduction order.
//!
//! Kernel selection is process-global (`HIFT_KERNELS` env or
//! [`set_kind`], surfaced as `--kernels naive|blocked|simd`); since all
//! kinds agree bit-for-bit in f32 this is a pure performance knob.  The
//! module also keeps process-global flop/nanosecond counters
//! ([`counters`]) that the native backend snapshots around each execution
//! into `RuntimeStats::kernel_flops`/`kernel_nanos` — measured GFLOP/s,
//! not modeled.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use anyhow::{bail, Result};

use super::par;

/// Column-strip width of the packed B panel.
const NC: usize = 128;
/// Row block (register tile height) of the micro-kernel.
const MR: usize = 8;
/// Reduction-depth of one packed-panel pass (keeps the active
/// `KC × NC` panel slice ≈ 32 KiB — L1-resident).
const KC: usize = 64;

// ---------------------------------------------------------------------------
// Kernel selection
// ---------------------------------------------------------------------------

/// Which GEMM/attention schedule the native backend runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelKind {
    /// Textbook triple-loop GEMM and materialized `[B*H, T*T]` attention
    /// probabilities — the retained reference the other kinds are
    /// bit-compared against.
    Naive,
    /// Cache-blocked GEMM (packed panels, register tiles) and the fused
    /// streaming-softmax attention path.  The default.
    #[default]
    Blocked,
    /// [`KernelKind::Blocked`] with explicit `std::simd` micro-kernels.
    /// Requires the `simd` cargo feature (nightly `portable_simd`);
    /// without it the scalar blocked micro-kernel runs instead.
    Simd,
}

impl KernelKind {
    /// Parse `"naive"`, `"blocked"`, `"simd"`.
    pub fn parse(s: &str) -> Result<KernelKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "blocked" => Ok(KernelKind::Blocked),
            "naive" => Ok(KernelKind::Naive),
            "simd" => Ok(KernelKind::Simd),
            other => bail!("bad kernel kind {other:?} (naive|blocked|simd)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Naive => "naive",
            KernelKind::Blocked => "blocked",
            KernelKind::Simd => "simd",
        }
    }

    /// Does this kind run the fused streaming-softmax attention path
    /// (never materializing the `[B*H, T*T]` probability matrix)?
    pub fn fused_attention(&self) -> bool {
        !matches!(self, KernelKind::Naive)
    }

    /// Should the micro-kernels use explicit SIMD?  True only for
    /// [`KernelKind::Simd`] in a build with the `simd` feature.
    fn simd(&self) -> bool {
        matches!(self, KernelKind::Simd) && simd_available()
    }
}

/// Was this binary built with the `simd` cargo feature (explicit
/// `std::simd` micro-kernels)?  Without it [`KernelKind::Simd`] falls back
/// to the scalar blocked micro-kernel — same schedule, same bits.
pub const fn simd_available() -> bool {
    cfg!(feature = "simd")
}

/// `u8::MAX` = "no override installed; use the env default".
static KIND_OVERRIDE: AtomicU8 = AtomicU8::new(u8::MAX);

fn env_default() -> KernelKind {
    static CACHE: OnceLock<KernelKind> = OnceLock::new();
    *CACHE.get_or_init(|| {
        std::env::var("HIFT_KERNELS")
            .ok()
            .and_then(|s| KernelKind::parse(&s).ok())
            .unwrap_or_default()
    })
}

/// The active kernel kind: the last [`set_kind`] override, else
/// `HIFT_KERNELS`, else [`KernelKind::Blocked`].
pub fn kind() -> KernelKind {
    match KIND_OVERRIDE.load(Ordering::Relaxed) {
        0 => KernelKind::Naive,
        1 => KernelKind::Blocked,
        2 => KernelKind::Simd,
        _ => env_default(),
    }
}

/// Install a process-global kernel-kind override (`--kernels`,
/// `ExecBackend::set_kernels`).  Safe to flip between runs: every kind is
/// bit-identical in f32, so concurrent readers can never observe a
/// numerically different model.
pub fn set_kind(k: KernelKind) {
    KIND_OVERRIDE.store(k as u8, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Measured kernel throughput
// ---------------------------------------------------------------------------

static KERNEL_FLOPS: AtomicU64 = AtomicU64::new(0);
static KERNEL_NANOS: AtomicU64 = AtomicU64::new(0);

/// Cumulative `(flops, nanoseconds)` spent inside kernel entry points
/// (GEMM and the attention cores) process-wide.  The native backend
/// snapshots deltas around each execution into
/// `RuntimeStats::kernel_flops` / `kernel_nanos`; `flops / nanos` is
/// GFLOP/s by construction.
pub fn counters() -> (u64, u64) {
    (KERNEL_FLOPS.load(Ordering::Relaxed), KERNEL_NANOS.load(Ordering::Relaxed))
}

/// Fold one kernel invocation into the process-wide counters.
pub(crate) fn note(flops: u64, nanos: u64) {
    KERNEL_FLOPS.fetch_add(flops, Ordering::Relaxed);
    KERNEL_NANOS.fetch_add(nanos, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// GEMM — three forms, one reduction discipline
// ---------------------------------------------------------------------------

/// `c += a @ b` (`a: [M,K]`, `b: [K,N]`, `c: [M,N]`, row-major) under an
/// explicit kernel kind.  [`super::par::matmul`] is the
/// current-global-kind wrapper.
pub fn matmul_with(kind: KernelKind, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "matmul: a");
    assert_eq!(b.len(), k * n, "matmul: b");
    assert_eq!(c.len(), m * n, "matmul: c");
    let t0 = Instant::now();
    let row_cost = 2 * k * n;
    match kind {
        KernelKind::Naive => par::par_rows(c, n, row_cost, |r0, cc| {
            for (ri, crow) in cc.chunks_mut(n).enumerate() {
                let arow = &a[(r0 + ri) * k..][..k];
                for (j, cj) in crow.iter_mut().enumerate() {
                    let mut acc = 0.0f32;
                    for (kk, &aik) in arow.iter().enumerate() {
                        acc += aik * b[kk * n + j];
                    }
                    *cj += acc;
                }
            }
        }),
        _ => {
            let simd = kind.simd();
            par::par_rows(c, n, row_cost, |r0, cc| {
                let rows = cc.len() / n;
                let arows = &a[r0 * k..][..rows * k];
                gemm_chunk_blocked(simd, arows, k, cc, n, rows, &|j0, bp, nc| {
                    for kk in 0..k {
                        bp[kk * nc..][..nc].copy_from_slice(&b[kk * n + j0..][..nc]);
                    }
                });
            });
        }
    }
    note((2 * m * k * n) as u64, t0.elapsed().as_nanos() as u64);
}

/// `c += aᵀ @ b` (`a: [M,K]`, `b: [M,N]`, `c: [K,N]` — the weight-grad
/// form `dW = Xᵀ dY`) under an explicit kernel kind.
pub fn matmul_at_with(kind: KernelKind, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "matmul_at: a");
    assert_eq!(b.len(), m * n, "matmul_at: b");
    assert_eq!(c.len(), k * n, "matmul_at: c");
    let t0 = Instant::now();
    let row_cost = 2 * m * n;
    match kind {
        KernelKind::Naive => par::par_rows(c, n, row_cost, |r0, cc| {
            for (ri, crow) in cc.chunks_mut(n).enumerate() {
                let kk = r0 + ri;
                for (j, cj) in crow.iter_mut().enumerate() {
                    let mut acc = 0.0f32;
                    for i in 0..m {
                        acc += a[i * k + kk] * b[i * n + j];
                    }
                    *cj += acc;
                }
            }
        }),
        _ => {
            let simd = kind.simd();
            par::par_rows(c, n, row_cost, |r0, cc| {
                let rows = cc.len() / n;
                // Pack this chunk's slice of aᵀ once: row r (output row
                // r0+r) holds a[., r0+r] contiguously over the reduction
                // index i — a pure copy, so reduction order is untouched.
                let mut at = vec![0.0f32; rows * m];
                for (r, atrow) in at.chunks_mut(m).enumerate() {
                    let col = r0 + r;
                    for (i, slot) in atrow.iter_mut().enumerate() {
                        *slot = a[i * k + col];
                    }
                }
                gemm_chunk_blocked(simd, &at, m, cc, n, rows, &|j0, bp, nc| {
                    for ii in 0..m {
                        bp[ii * nc..][..nc].copy_from_slice(&b[ii * n + j0..][..nc]);
                    }
                });
            });
        }
    }
    note((2 * m * k * n) as u64, t0.elapsed().as_nanos() as u64);
}

/// `c += a @ bᵀ` (`a: [M,K]`, `b: [N,K]`, `c: [M,N]` — the input-grad
/// form `dX = dY Wᵀ`) under an explicit kernel kind.
pub fn matmul_bt_with(kind: KernelKind, a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "matmul_bt: a");
    assert_eq!(b.len(), n * k, "matmul_bt: b");
    assert_eq!(c.len(), m * n, "matmul_bt: c");
    let t0 = Instant::now();
    let row_cost = 2 * k * n;
    match kind {
        KernelKind::Naive => par::par_rows(c, n, row_cost, |r0, cc| {
            for (ri, crow) in cc.chunks_mut(n).enumerate() {
                let arow = &a[(r0 + ri) * k..][..k];
                for (j, cj) in crow.iter_mut().enumerate() {
                    let brow = &b[j * k..][..k];
                    let mut acc = 0.0f32;
                    for (&x, &y) in arow.iter().zip(brow.iter()) {
                        acc += x * y;
                    }
                    *cj += acc;
                }
            }
        }),
        _ => {
            let simd = kind.simd();
            par::par_rows(c, n, row_cost, |r0, cc| {
                let rows = cc.len() / n;
                let arows = &a[r0 * k..][..rows * k];
                // Pack bᵀ panels: bp[kk][jj] = b[(j0+jj)*k + kk] — a pure
                // transpose copy.
                gemm_chunk_blocked(simd, arows, k, cc, n, rows, &|j0, bp, nc| {
                    for kk in 0..k {
                        let dst = &mut bp[kk * nc..][..nc];
                        for (jj, slot) in dst.iter_mut().enumerate() {
                            *slot = b[(j0 + jj) * k + kk];
                        }
                    }
                });
            });
        }
    }
    note((2 * m * k * n) as u64, t0.elapsed().as_nanos() as u64);
}

/// One thread-chunk of the blocked schedule: `rows` consecutive output
/// rows (`cc`, row stride `n`) with their reduction vectors stored
/// contiguously in `arows` (row stride `kr`).  `pack_b(j0, bp, nc)` fills
/// the packed `[kr, nc]` panel for the column strip at `j0`.
fn gemm_chunk_blocked(
    simd: bool,
    arows: &[f32],
    kr: usize,
    cc: &mut [f32],
    n: usize,
    rows: usize,
    pack_b: &(dyn Fn(usize, &mut [f32], usize) + Sync),
) {
    let mut bp = vec![0.0f32; kr * NC.min(n.max(1))];
    let mut j0 = 0;
    while j0 < n {
        let nc = NC.min(n - j0);
        pack_b(j0, &mut bp[..kr * nc], nc);
        let mut r0 = 0;
        while r0 < rows {
            let mr = MR.min(rows - r0);
            micro_kernel(simd, &arows[r0 * kr..][..mr * kr], kr, &bp[..kr * nc], nc, cc, n, j0, r0, mr);
            r0 += mr;
        }
        j0 += nc;
    }
}

/// `MR × NC` register-tile micro-kernel: accumulators are zero-initialized,
/// consume the packed panel in ascending-k [`KC`]-deep passes, and are
/// added to C exactly once — the reduction-order guarantee.
#[allow(clippy::too_many_arguments)]
fn micro_kernel(
    simd: bool,
    ablock: &[f32],
    kr: usize,
    bp: &[f32],
    nc: usize,
    cc: &mut [f32],
    n: usize,
    j0: usize,
    r0: usize,
    mr: usize,
) {
    let mut acc = [[0.0f32; NC]; MR];
    let mut k0 = 0;
    while k0 < kr {
        let kc = KC.min(kr - k0);
        for (ri, accr) in acc.iter_mut().enumerate().take(mr) {
            let ar = &ablock[ri * kr + k0..][..kc];
            axpy_strip(simd, ar, &bp[k0 * nc..][..kc * nc], nc, accr);
        }
        k0 += kc;
    }
    for (ri, accr) in acc.iter().enumerate().take(mr) {
        let crow = &mut cc[(r0 + ri) * n + j0..][..nc];
        for (cj, &aj) in crow.iter_mut().zip(accr[..nc].iter()) {
            *cj += aj;
        }
    }
}

/// `accr[j] += Σ_kk ar[kk] * panel[kk*nc + j]`, ascending `kk` — the
/// innermost loop of the blocked schedule.  The SIMD flavor vectorizes the
/// `j` lanes only; per lane it performs the same mul-then-add sequence as
/// the scalar loop, so both flavors are bit-identical.
fn axpy_strip(simd: bool, ar: &[f32], panel: &[f32], nc: usize, accr: &mut [f32; NC]) {
    #[cfg(feature = "simd")]
    if simd {
        axpy_strip_simd(ar, panel, nc, accr);
        return;
    }
    let _ = simd;
    for (kk, &av) in ar.iter().enumerate() {
        let brow = &panel[kk * nc..][..nc];
        for (aj, &bj) in accr[..nc].iter_mut().zip(brow.iter()) {
            *aj += av * bj;
        }
    }
}

#[cfg(feature = "simd")]
fn axpy_strip_simd(ar: &[f32], panel: &[f32], nc: usize, accr: &mut [f32; NC]) {
    use std::simd::f32x8;
    const L: usize = 8;
    let lanes = nc / L * L;
    for (kk, &av) in ar.iter().enumerate() {
        let avv = f32x8::splat(av);
        let brow = &panel[kk * nc..][..nc];
        let mut j = 0;
        while j < lanes {
            let mut acc = f32x8::from_slice(&accr[j..]);
            acc = acc + avv * f32x8::from_slice(&brow[j..]);
            acc.copy_to_slice(&mut accr[j..j + L]);
            j += L;
        }
        for jj in lanes..nc {
            accr[jj] += av * brow[jj];
        }
    }
}

// ---------------------------------------------------------------------------
// Elementwise kernels (GELU, LayerNorm normalize, AdamW update)
// ---------------------------------------------------------------------------
//
// Each has one scalar expression of record; the SIMD flavor performs the
// identical operation sequence per lane (tanh, which `std::simd` lacks,
// stays a per-lane scalar call), so scalar and SIMD builds agree
// bit-for-bit.

pub(crate) const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)
pub(crate) const GELU_A: f32 = 0.044_715;

/// Scalar tanh-GELU (the expression of record).
#[inline]
pub(crate) fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + GELU_A * x * x * x)).tanh())
}

/// Scalar tanh-GELU derivative (the expression of record).
#[inline]
pub(crate) fn dgelu(x: f32) -> f32 {
    let u = GELU_C * (x + GELU_A * x * x * x);
    let th = u.tanh();
    0.5 * (1.0 + th) + 0.5 * x * (1.0 - th * th) * GELU_C * (1.0 + 3.0 * GELU_A * x * x)
}

/// In-place GELU over a slice.  SIMD builds vectorize the polynomial /
/// combine arithmetic around a per-lane scalar tanh.
pub fn gelu_slice(xs: &mut [f32]) {
    #[cfg(feature = "simd")]
    {
        use std::simd::f32x8;
        const L: usize = 8;
        let half = f32x8::splat(0.5);
        let one = f32x8::splat(1.0);
        let gc = f32x8::splat(GELU_C);
        let ga = f32x8::splat(GELU_A);
        let mut chunks = xs.chunks_exact_mut(L);
        for ch in &mut chunks {
            let x = f32x8::from_slice(ch);
            // u = GELU_C * (x + ((GELU_A*x)*x)*x)  — same association as
            // the scalar `GELU_A * x * x * x`.
            let u = gc * (x + ((ga * x) * x) * x);
            let mut t = [0.0f32; L];
            u.copy_to_slice(&mut t);
            for v in t.iter_mut() {
                *v = v.tanh();
            }
            let th = f32x8::from_slice(&t);
            let y = (half * x) * (one + th);
            y.copy_to_slice(ch);
        }
        for x in chunks.into_remainder() {
            *x = gelu(*x);
        }
        return;
    }
    #[allow(unreachable_code)]
    for x in xs.iter_mut() {
        *x = gelu(*x);
    }
}

/// `dz[i] *= dgelu(a[i])` — the GELU backward scaling.
pub fn dgelu_slice(dz: &mut [f32], a: &[f32]) {
    debug_assert_eq!(dz.len(), a.len());
    for (z, &x) in dz.iter_mut().zip(a.iter()) {
        *z *= dgelu(x);
    }
}

/// LayerNorm normalize step for one row:
/// `y[j] = (x[j] - mean) * inv * scale[j] + bias[j]` (the row reductions
/// that produce `mean`/`inv` stay scalar in the caller — fixed order).
pub fn ln_norm_row(xr: &[f32], yr: &mut [f32], mean: f32, inv: f32, scale: &[f32], bias: &[f32]) {
    #[cfg(feature = "simd")]
    {
        use std::simd::f32x8;
        const L: usize = 8;
        let n = xr.len();
        let lanes = n / L * L;
        let mu = f32x8::splat(mean);
        let iv = f32x8::splat(inv);
        let mut j = 0;
        while j < lanes {
            let x = f32x8::from_slice(&xr[j..]);
            let sc = f32x8::from_slice(&scale[j..]);
            let bi = f32x8::from_slice(&bias[j..]);
            // ((x - mu) * iv) * sc + bi — same association as the scalar
            // expression of record.
            let y = ((x - mu) * iv) * sc + bi;
            y.copy_to_slice(&mut yr[j..j + L]);
            j += L;
        }
        for jj in lanes..n {
            yr[jj] = (xr[jj] - mean) * inv * scale[jj] + bias[jj];
        }
        return;
    }
    #[allow(unreachable_code)]
    for j in 0..xr.len() {
        yr[j] = (xr[j] - mean) * inv * scale[j] + bias[j];
    }
}

/// Fused AdamW update over one chunk (the optimizer hot loop):
///
/// ```text
/// m ← β₁·m + (1-β₁)·g          v ← β₂·v + (1-β₂)·g·g
/// p ← p − lr·( (m/bc₁) / (√(v/bc₂) + ε) + wd·p )
/// ```
///
/// Same expression order in both flavors; `std::simd` div/sqrt are
/// correctly rounded per lane, so scalar and SIMD agree bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn adamw_chunk(
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
    wd: f32,
    lr: f32,
) {
    debug_assert!(p.len() == m.len() && p.len() == v.len() && p.len() == g.len());
    #[cfg(feature = "simd")]
    {
        use std::simd::{f32x8, StdFloat};
        const L: usize = 8;
        let n = p.len();
        let lanes = n / L * L;
        let b1v = f32x8::splat(b1);
        let b1c = f32x8::splat(1.0 - b1);
        let b2v = f32x8::splat(b2);
        let b2c = f32x8::splat(1.0 - b2);
        let bc1v = f32x8::splat(bc1);
        let bc2v = f32x8::splat(bc2);
        let epsv = f32x8::splat(eps);
        let wdv = f32x8::splat(wd);
        let lrv = f32x8::splat(lr);
        let mut i = 0;
        while i < lanes {
            let gv = f32x8::from_slice(&g[i..]);
            let mv = b1v * f32x8::from_slice(&m[i..]) + b1c * gv;
            let vv = b2v * f32x8::from_slice(&v[i..]) + (b2c * gv) * gv;
            mv.copy_to_slice(&mut m[i..i + L]);
            vv.copy_to_slice(&mut v[i..i + L]);
            let mhat = mv / bc1v;
            let vhat = vv / bc2v;
            let pv = f32x8::from_slice(&p[i..]);
            let upd = pv - lrv * (mhat / (vhat.sqrt() + epsv) + wdv * pv);
            upd.copy_to_slice(&mut p[i..i + L]);
            i += L;
        }
        adamw_chunk_scalar(
            &mut p[lanes..],
            &mut m[lanes..],
            &mut v[lanes..],
            &g[lanes..],
            b1,
            b2,
            bc1,
            bc2,
            eps,
            wd,
            lr,
        );
        return;
    }
    #[allow(unreachable_code)]
    adamw_chunk_scalar(p, m, v, g, b1, b2, bc1, bc2, eps, wd, lr)
}

#[allow(clippy::too_many_arguments)]
fn adamw_chunk_scalar(
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    eps: f32,
    wd: f32,
    lr: f32,
) {
    for i in 0..p.len() {
        let gi = g[i];
        let m_new = b1 * m[i] + (1.0 - b1) * gi;
        let v_new = b2 * v[i] + (1.0 - b2) * gi * gi;
        m[i] = m_new;
        v[i] = v_new;
        let mhat = m_new / bc1;
        let vhat = v_new / bc2;
        p[i] -= lr * (mhat / (vhat.sqrt() + eps) + wd * p[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|i| ((i * 7 + 3) % 11) as f32 * scale - 0.4).collect()
    }

    const KINDS: [KernelKind; 3] = [KernelKind::Naive, KernelKind::Blocked, KernelKind::Simd];

    #[test]
    fn parse_and_names_roundtrip() {
        for k in KINDS {
            assert_eq!(KernelKind::parse(k.name()).unwrap(), k);
        }
        assert_eq!(KernelKind::parse("").unwrap(), KernelKind::Blocked);
        assert!(KernelKind::parse("fast").is_err());
        assert!(!KernelKind::Naive.fused_attention());
        assert!(KernelKind::Blocked.fused_attention());
    }

    /// The module's core contract: all three schedules are bit-identical,
    /// including on ragged shapes that exercise partial NC/MR/KC tiles and
    /// on non-zero (accumulating) C.
    #[test]
    fn gemm_kinds_are_bit_identical() {
        for &(m, k, n) in
            &[(1, 1, 1), (7, 5, 9), (8, 64, 128), (9, 65, 129), (33, 130, 127), (16, 3, 260)]
        {
            let a = seq(m * k, 0.13);
            let b_nn = seq(k * n, 0.07);
            let b_at = seq(m * n, 0.07);
            let b_bt = seq(n * k, 0.07);
            let c0 = seq(m * n, 0.01);
            let c0_at = seq(k * n, 0.01);

            let run = |kind: KernelKind| {
                let mut c1 = c0.clone();
                matmul_with(kind, &a, &b_nn, &mut c1, m, k, n);
                let mut c2 = c0_at.clone();
                matmul_at_with(kind, &a, &b_at, &mut c2, m, k, n);
                let mut c3 = c0.clone();
                matmul_bt_with(kind, &a, &b_bt, &mut c3, m, k, n);
                (c1, c2, c3)
            };
            let base = run(KernelKind::Naive);
            for kind in [KernelKind::Blocked, KernelKind::Simd] {
                let got = run(kind);
                for (which, (x, y)) in [
                    ("nn", (&base.0, &got.0)),
                    ("at", (&base.1, &got.1)),
                    ("bt", (&base.2, &got.2)),
                ] {
                    assert!(
                        x.iter().zip(y.iter()).all(|(u, w)| u.to_bits() == w.to_bits()),
                        "{which} {m}x{k}x{n}: naive vs {} not bit-identical",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_matches_reference_values() {
        // 2x2 sanity against hand computation: [[1,2],[3,4]] @ [[5,6],[7,8]].
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        for kind in KINDS {
            let mut c = vec![0.0f32; 4];
            matmul_with(kind, &a, &b, &mut c, 2, 2, 2);
            assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0], "{}", kind.name());
        }
    }

    #[test]
    fn counters_accumulate() {
        let (f0, _) = counters();
        let a = seq(16, 0.1);
        let b = seq(16, 0.1);
        let mut c = vec![0.0f32; 16];
        matmul_with(KernelKind::Blocked, &a, &b, &mut c, 4, 4, 4);
        let (f1, _) = counters();
        assert!(f1 - f0 >= 2 * 4 * 4 * 4, "flop counter must grow");
    }

    #[test]
    fn elementwise_kernels_match_scalar_expressions() {
        let xs0: Vec<f32> = (0..37).map(|i| (i as f32 - 18.0) * 0.3).collect();
        let mut xs = xs0.clone();
        gelu_slice(&mut xs);
        for (y, &x) in xs.iter().zip(xs0.iter()) {
            assert_eq!(y.to_bits(), gelu(x).to_bits());
        }
        let mut dz = vec![1.0f32; 37];
        dgelu_slice(&mut dz, &xs0);
        for (z, &x) in dz.iter().zip(xs0.iter()) {
            assert_eq!(z.to_bits(), dgelu(x).to_bits());
        }

        let xr = seq(21, 0.2);
        let scale = seq(21, 0.05);
        let bias = seq(21, 0.02);
        let mut yr = vec![0.0f32; 21];
        ln_norm_row(&xr, &mut yr, 0.1, 2.0, &scale, &bias);
        for j in 0..21 {
            let want = (xr[j] - 0.1) * 2.0 * scale[j] + bias[j];
            assert_eq!(yr[j].to_bits(), want.to_bits(), "ln row elem {j}");
        }
    }

    #[test]
    fn adamw_kernel_matches_scalar_reference() {
        let n = 29; // forces a SIMD tail
        let g = seq(n, 0.3);
        let (mut p1, mut m1, mut v1) = (seq(n, 0.5), vec![0.0f32; n], vec![0.0f32; n]);
        let (mut p2, mut m2, mut v2) = (p1.clone(), m1.clone(), v1.clone());
        adamw_chunk(&mut p1, &mut m1, &mut v1, &g, 0.9, 0.999, 0.1, 0.001999, 1e-8, 0.01, 0.1);
        adamw_chunk_scalar(&mut p2, &mut m2, &mut v2, &g, 0.9, 0.999, 0.1, 0.001999, 1e-8, 0.01, 0.1);
        for i in 0..n {
            assert_eq!(p1[i].to_bits(), p2[i].to_bits(), "p[{i}]");
            assert_eq!(m1[i].to_bits(), m2[i].to_bits(), "m[{i}]");
            assert_eq!(v1[i].to_bits(), v2[i].to_bits(), "v[{i}]");
        }
    }
}
