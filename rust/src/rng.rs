//! Deterministic RNG (PCG32) — MeZO's seeded perturbations, the RAN
//! strategy's fixed shuffle, synthetic-data generation and weight init all
//! flow through this so every run is reproducible from a u64 seed.
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014): small state, excellent statistical
//! quality, trivially seekable by re-seeding — the properties MeZO needs to
//! regenerate the *same* perturbation twice without storing it (the whole
//! point of the zeroth-order memory saving).

/// PCG32 generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with `(seed, stream)`; distinct streams are independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Single-argument convenience constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in `[0, n)` (Lemire's method, bias-free for our n << 2^32).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0 && n <= u32::MAX as usize);
        let n = n as u64;
        ((self.next_u32() as u64 * n) >> 32) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 > 1e-9 {
                let u2 = self.next_f32();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Fill `buf` with standard-normal samples.
    pub fn fill_normal(&mut self, buf: &mut [f32]) {
        for x in buf.iter_mut() {
            *x = self.normal();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Pick one element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u32> = (0..8).map(|_| 0).scan(Pcg32::seeded(7), |r, _| Some(r.next_u32())).collect();
        let b: Vec<u32> = (0..8).map(|_| 0).scan(Pcg32::seeded(7), |r, _| Some(r.next_u32())).collect();
        let c: Vec<u32> = (0..8).map(|_| 0).scan(Pcg32::seeded(8), |r, _| Some(r.next_u32())).collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Pcg32::seeded(1);
        let mean: f32 = (0..10_000).map(|_| r.next_f32()).sum::<f32>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(2);
        let xs: Vec<f32> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Pcg32::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let i = r.below(7);
            assert!(i < 7);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(4);
        let mut xs: Vec<usize> = (0..20).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
        assert_ne!(xs, (0..20).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn reseeding_reproduces_mezo_perturbation() {
        // MeZO contract: regenerate identical noise from the same seed.
        let mut a = vec![0f32; 64];
        let mut b = vec![0f32; 64];
        Pcg32::seeded(99).fill_normal(&mut a);
        Pcg32::seeded(99).fill_normal(&mut b);
        assert_eq!(a, b);
    }
}
