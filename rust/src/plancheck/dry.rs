//! Side-effect-free "dry" twins of the runtime state machines.
//!
//! `plancheck` derives a full step plan — paging events, parameter reads,
//! gradient emits — without touching a single float.  To do that it replays
//! the *decision logic* of the real components over shapes and byte counts:
//!
//! * [`DryPager`] mirrors `tensor::paged::UnitPager` bit-for-bit at the
//!   policy level (managed / resident / pinned / keep / requested flags,
//!   admit/evict/prefetch ordering) but holds no tensor data.
//! * [`generate_plan`] mirrors the streamed execution walk: the call order
//!   in `Hift::step` (schedule → stage next group → run), the forward walk
//!   in `model::forward_ckpt` (ensure/prefetch/release per unit, activation
//!   caching policy), and the backward walk in `model::backward_streamed`
//!   (head phase, recompute chains, manifest-order emits, descent
//!   truncation at `min_unit`).
//!
//! The generator also hosts the fault-injection knobs ([`Inject`]): each
//! knob makes the *generator* misbehave in a specific way so the
//! independent verifier in the parent module can prove it still catches
//! the corruption.  Injection never touches the verifier.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::backend::manifest::Manifest;
use crate::backend::ActCkpt;
use crate::coordinator::scheduler::{HiftScheduler, SchedulerCfg};
use crate::coordinator::LrSchedule;
use crate::tensor::paged::PageEvent;

use super::{Inject, LatticePoint, Plan, PlanStep, TraceOp};

/// Shapes-only view of one model variant: per-parameter byte counts plus
/// the unit layout the pager and emit checker operate on.
pub(crate) struct SymModel {
    pub n_layers: usize,
    pub n_units: usize,
    /// Parameter indices of each layer unit, in manifest order.
    pub unit_params: Vec<Vec<usize>>,
    /// f32 bytes of each parameter tensor.
    pub param_bytes: Vec<u64>,
    /// f32 bytes of each layer unit (sum over its parameters).
    pub unit_bytes: Vec<u64>,
}

impl SymModel {
    pub fn new(manifest: &Manifest) -> Result<SymModel> {
        let vinfo = manifest.variant("base")?;
        let n_units = manifest.n_units;
        if n_units < 3 {
            bail!("plancheck needs embeddings + >=1 block + head, got {n_units} units");
        }
        let unit_params: Vec<Vec<usize>> =
            (0..n_units).map(|u| vinfo.unit_indices(u)).collect();
        let param_bytes: Vec<u64> =
            vinfo.params.iter().map(|p| p.size as u64 * 4).collect();
        let unit_bytes = manifest.unit_param_bytes("base")?;
        Ok(SymModel { n_layers: n_units - 2, n_units, unit_params, param_bytes, unit_bytes })
    }
}

/// Symbolic twin of `UnitPager`.  Same flag lattice, same event ordering,
/// no data.  When `enabled` is false every method is a no-op — mirroring a
/// run with offload off (or `workers > 1`, where the backend refuses to
/// combine paging with sharded execution).
pub(crate) struct DryPager {
    enabled: bool,
    prefetch: bool,
    attached: bool,
    managed: Vec<bool>,
    resident: Vec<bool>,
    pinned: Vec<bool>,
    keep: Vec<bool>,
    requested: Vec<bool>,
    inject: Inject,
    /// One-shot injections (DropEvict) fire exactly once.
    fired: bool,
}

impl DryPager {
    pub fn new(point: &LatticePoint, inject: Inject) -> DryPager {
        // The real backend rejects offload × workers>1; a plan for such a
        // point is never generated (validate_point bails first), but the
        // guard keeps the twin honest if called directly.
        let enabled = point.offload.enabled && point.workers <= 1;
        DryPager {
            enabled,
            prefetch: point.offload.prefetch,
            attached: false,
            managed: Vec::new(),
            resident: Vec::new(),
            pinned: Vec::new(),
            keep: Vec::new(),
            requested: Vec::new(),
            inject,
            fired: false,
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Mirror of `UnitPager::attach`: unit-mapped tensors move to host
    /// (initial placement — not a steady-state event, so nothing is
    /// recorded), everything else stays resident.
    pub fn attach(&mut self, model: &SymModel) {
        if !self.enabled {
            return;
        }
        let n = model.param_bytes.len();
        self.managed = vec![false; n];
        self.resident = vec![true; n];
        self.pinned = vec![false; n];
        self.keep = vec![false; n];
        self.requested = vec![false; n];
        for idxs in &model.unit_params {
            for &i in idxs {
                self.managed[i] = true;
                self.resident[i] = false;
            }
        }
        self.attached = true;
    }

    pub fn pin_unit(&mut self, model: &SymModel, u: usize) {
        if !self.enabled || !self.attached {
            return;
        }
        for &i in &model.unit_params[u] {
            self.pinned[i] = true;
        }
    }

    pub fn clear_pins(&mut self) {
        if self.enabled {
            self.pinned.iter_mut().for_each(|p| *p = false);
        }
    }

    pub fn clear_staged(&mut self) {
        if self.enabled {
            self.keep.iter_mut().for_each(|k| *k = false);
        }
    }

    /// Mirror of `stage_unit`: prefetch mode only — pre-attach (step 1,
    /// before the first `run_group_streamed`) this is a silent no-op, which
    /// is exactly why the verifier treats the first step's staged set as
    /// empty.
    pub fn stage_unit(&mut self, model: &SymModel, u: usize, ops: &mut Vec<TraceOp>) {
        if !self.enabled || !self.attached || !self.prefetch {
            return;
        }
        for &i in &model.unit_params[u] {
            self.keep[i] = true;
        }
        self.prefetch_unit(model, u, ops);
    }

    pub fn prefetch_unit(&mut self, model: &SymModel, u: usize, ops: &mut Vec<TraceOp>) {
        if !self.enabled || !self.attached || !self.prefetch {
            return;
        }
        for &i in &model.unit_params[u] {
            if !self.resident[i] && !self.requested[i] {
                self.requested[i] = true;
                ops.push(TraceOp::Page(PageEvent::Prefetch { idx: i }));
            }
        }
    }

    pub fn ensure_unit(&mut self, model: &SymModel, u: usize, ops: &mut Vec<TraceOp>) {
        if !self.enabled || !self.attached {
            return;
        }
        for &i in &model.unit_params[u] {
            if !self.resident[i] {
                self.resident[i] = true;
                self.requested[i] = false;
                ops.push(TraceOp::Page(PageEvent::Admit { idx: i }));
            }
        }
    }

    pub fn release_unit(&mut self, model: &SymModel, u: usize, ops: &mut Vec<TraceOp>) {
        if !self.enabled || !self.attached {
            return;
        }
        for &i in &model.unit_params[u] {
            let pinned = self.pinned[i] && self.inject != Inject::EvictPinned;
            if self.resident[i] && !pinned && !self.keep[i] {
                self.evict(i, ops);
            }
        }
    }

    /// Mirror of `end_run`: drop pins, then page out everything managed
    /// that is not staged for the next group.  The [`TraceOp::EndRun`]
    /// marker records where the pins lift, so the verifier can tell these
    /// legitimate post-update evictions from a mid-walk evict of a pinned
    /// master.
    pub fn end_run(&mut self, _model: &SymModel, ops: &mut Vec<TraceOp>) {
        if !self.enabled || !self.attached {
            return;
        }
        ops.push(TraceOp::EndRun);
        self.clear_pins();
        // Global index order, exactly like the real `end_run` loop.
        for i in 0..self.resident.len() {
            if self.managed[i] && self.resident[i] && !self.keep[i] {
                self.evict(i, ops);
            }
        }
    }

    fn evict(&mut self, idx: usize, ops: &mut Vec<TraceOp>) {
        self.resident[idx] = false;
        if self.inject == Inject::DropEvict && !self.fired {
            // Corrupt plan: the page-out happened but the event vanished
            // from the trace.  The verifier must notice the ledger no
            // longer conserves bytes.
            self.fired = true;
            return;
        }
        ops.push(TraceOp::Page(PageEvent::Evict { idx }));
    }
}

/// Activation-cache bookkeeping of the forward walk: which layer inputs
/// were kept live (`layers`) vs parked at checkpoint boundaries
/// (`boundaries`) — determines the recompute chains the backward walk runs.
struct CacheState {
    layers: Vec<bool>,
    boundaries: Vec<bool>,
}

/// Derive the full static plan for one lattice point.
///
/// Replays `HiftScheduler` for the real unit schedule, then for each step
/// mirrors `Hift::step` + `NativeBackend::exec_streamed`: stage the *next*
/// group (peeked after `next()`, exactly like the strategy does), pin the
/// current group, run the forward/backward walk, page out at end-of-run.
pub(crate) fn generate_plan(
    manifest: &Manifest,
    point: &LatticePoint,
    n_steps: u64,
    inject: Inject,
) -> Result<Plan> {
    let model = SymModel::new(manifest)?;
    let mut sched = HiftScheduler::new(
        SchedulerCfg {
            m: point.m,
            strategy: point.strategy,
            schedule: LrSchedule::Const { lr: super::PLAN_LR },
        },
        model.n_units,
    );
    let mut pager = DryPager::new(point, inject);
    let mut steps = Vec::with_capacity(n_steps as usize);

    for _ in 0..n_steps {
        let plan = sched.next();
        let staged = sched.peek_next();
        let mut ops = Vec::new();

        // `Hift::step` calls `prefetch_units` (stage) before the group
        // runs; on the very first step the pager is not attached yet, so
        // staging silently does nothing — mirrored by the attach check
        // inside stage_unit.
        pager.clear_staged();
        for &u in &staged {
            pager.stage_unit(&model, u, &mut ops);
        }
        if !pager.attached {
            pager.attach(&model);
        }

        pager.clear_pins();
        for &u in &plan.units {
            pager.pin_unit(&model, u);
        }

        // Slot map: `run_group_streamed` numbers slots over the group's
        // parameters in group order.
        let mut slot_of: HashMap<usize, usize> = HashMap::new();
        for &u in &plan.units {
            for &i in &model.unit_params[u] {
                let slot = slot_of.len();
                slot_of.insert(i, slot);
            }
        }
        let min_unit = plan.units.iter().copied().min().unwrap_or(0);
        let emit: Vec<bool> =
            (0..model.n_units).map(|u| plan.units.contains(&u)).collect();

        let cache = walk_forward(&model, &mut pager, point.act_ckpt, &mut ops);
        walk_backward(&model, &mut pager, &emit, min_unit, cache, &slot_of, &mut ops);
        if inject == Inject::PrefetchPinned && pager.enabled() {
            // Corrupt plan: post an async fetch for a master that is
            // resident and pinned under the fused in-place update (the walk
            // just finished, so the group is exactly that).
            if let Some(&idx) = plan.units.first().and_then(|&u| model.unit_params[u].first()) {
                ops.push(TraceOp::Page(PageEvent::Prefetch { idx }));
            }
        }
        pager.end_run(&model, &mut ops);

        if inject == Inject::SwapEmits {
            let emits: Vec<usize> = ops
                .iter()
                .enumerate()
                .filter(|(_, op)| matches!(op, TraceOp::Emit { .. }))
                .map(|(i, _)| i)
                .take(2)
                .collect();
            if let [a, b] = emits[..] {
                ops.swap(a, b);
            }
        }

        steps.push(PlanStep {
            step: plan.step,
            units: plan.units,
            staged,
            lr: plan.lr,
            sweep_boundary: plan.sweep_boundary,
            ops,
        });
    }

    Ok(Plan {
        deferred: point.precision.needs_loss_scaling() || inject == Inject::HoardGrads,
        steps,
    })
}

/// Mirror of `model::forward_ckpt`'s unit walk: embeddings, each block with
/// next-unit prefetch, then the head — which *stays resident* for the
/// backward head phase (the real walk performs no ensure there).
fn walk_forward(
    model: &SymModel,
    pg: &mut DryPager,
    policy: ActCkpt,
    ops: &mut Vec<TraceOp>,
) -> CacheState {
    let l = model.n_layers;
    pg.ensure_unit(model, 0, ops);
    pg.prefetch_unit(model, 1, ops);
    ops.push(TraceOp::Read { unit: 0 });
    pg.release_unit(model, 0, ops);

    let seg = policy.seg_len(l);
    let mut layers = vec![false; l];
    let mut boundaries = vec![false; l];
    for i in 0..l {
        pg.ensure_unit(model, i + 1, ops);
        let next = if i + 2 <= l { i + 2 } else { l + 1 };
        pg.prefetch_unit(model, next, ops);
        ops.push(TraceOp::Read { unit: i + 1 });
        pg.release_unit(model, i + 1, ops);
        match seg {
            None => layers[i] = true,
            Some(k) => boundaries[i] = i % k == 0,
        }
    }
    pg.ensure_unit(model, l + 1, ops);
    CacheState { layers, boundaries }
}

/// Mirror of `model::backward_streamed`: head phase (reads the head the
/// forward left resident, emits in manifest order, releases), reverse block
/// walk with recompute chains and descent truncation at `min_unit`, then
/// the embedding emits — which perform no ensure: unit 0 is resident only
/// because the group pin held it through the walk.
fn walk_backward(
    model: &SymModel,
    pg: &mut DryPager,
    emit: &[bool],
    min_unit: usize,
    cache: CacheState,
    slot_of: &HashMap<usize, usize>,
    ops: &mut Vec<TraceOp>,
) {
    let l = model.n_layers;
    let head = l + 1;
    ops.push(TraceOp::Read { unit: head });
    if emit[head] {
        emit_unit(model, head, slot_of, ops);
    }
    pg.release_unit(model, head, ops);

    let mut scratch = vec![false; l];
    for i in (0..l).rev() {
        if i + 1 < min_unit {
            // Descent truncation: every unit below the group's floor is
            // frozen this step, so the real walk returns early.
            return;
        }
        pg.ensure_unit(model, i + 1, ops);
        if i > 0 {
            pg.prefetch_unit(model, i, ops);
        }
        if !cache.layers[i] && !scratch[i] {
            recompute_chain(model, pg, &cache, &mut scratch, i, ops);
        }
        scratch[i] = false; // the input is consumed by this layer's backward
        ops.push(TraceOp::Read { unit: i + 1 });
        if emit[i + 1] {
            emit_unit(model, i + 1, slot_of, ops);
        }
        pg.release_unit(model, i + 1, ops);
    }
    if emit[0] {
        emit_unit(model, 0, slot_of, ops);
    }
}

/// Mirror of `model::recompute_layer`: walk back to the nearest parked
/// activation (checkpoint boundary or scratch), then re-run the segment
/// forward, parking intermediate inputs in scratch for the layers below.
fn recompute_chain(
    model: &SymModel,
    pg: &mut DryPager,
    cache: &CacheState,
    scratch: &mut [bool],
    i: usize,
    ops: &mut Vec<TraceOp>,
) {
    let mut c = i;
    while c > 0 && !scratch[c] && !cache.boundaries[c] {
        c -= 1;
    }
    for j in c..i {
        pg.ensure_unit(model, j + 1, ops);
        ops.push(TraceOp::Read { unit: j + 1 });
        pg.release_unit(model, j + 1, ops);
        if !scratch[j + 1] && !cache.boundaries[j + 1] {
            scratch[j + 1] = true;
        }
    }
    // The final `layer_fwd(i)` runs under the outer loop's ensure; its
    // parameter read is the phase-1 read the caller records.
}

fn emit_unit(
    model: &SymModel,
    u: usize,
    slot_of: &HashMap<usize, usize>,
    ops: &mut Vec<TraceOp>,
) {
    for &i in &model.unit_params[u] {
        let slot = slot_of.get(&i).copied().unwrap_or(usize::MAX);
        ops.push(TraceOp::Emit { slot, idx: i });
    }
}
