//! `hift plancheck` — static schedule & memory-model verification over the
//! full configuration lattice.
//!
//! HiFT's memory claims (peak parameter residency = one group + staged
//! prefetch + the walk's transient units; gradient residency = the single
//! largest tensor) and its determinism guarantees are decidable from the
//! plan alone: the strategy × m × act-ckpt × offload × prefetch ×
//! precision × workers lattice is finite and the scheduler / pager / sink
//! state machines are deterministic.  This module derives the complete
//! step plan for every lattice point using only shapes and byte counts
//! ([`dry`]), then replays it through an *independent* verifier that
//! asserts, statically, every property the `contracts` checkers assert
//! dynamically:
//!
//! | rule              | invariant                                          | runtime twin                        |
//! |-------------------|----------------------------------------------------|-------------------------------------|
//! | `ledger-conserve` | page-in/out balance, nothing resident past end-run | `OffloadLedger::check_conservation` |
//! | `peak-bound`      | peak residency ≤ `memmodel` structural bound       | `tests/offload.rs` counter asserts  |
//! | `grad-peak`       | grad residency = max single tensor (or group sum under deferred f16) | `LedgerStats::note_grad_resident` |
//! | `evicted-read`    | no read/update of an evicted master                | `PagedStore::take` missing-page err |
//! | `pinned-evict`    | pinned-through-update units never paged out        | `UnitPager` pin flags               |
//! | `prefetch-overlap`| prefetch never overlaps a fused in-place update    | pager requested/pinned flags        |
//! | `emit-order`      | gradient emit order = manifest order, descending   | `contracts::EmitChecker`            |
//! | `sink-quiesce`    | optimizer sink drains every grad/state byte        | `OffloadLedger::check_sink_quiesced`|
//! | `resume-align`    | `fast_forward(t)` reproduces step t exactly        | resume tests                        |
//! | `exclusion`       | offload×workers / MeZO×offload rejected            | `set_workers`/`set_offload` bails   |
//!
//! The generator carries fault-injection knobs ([`Inject`]) that corrupt
//! the *plan*; the verifier shares no state with them, so an injected run
//! failing is positive proof the gate can catch a real regression.

pub mod dry;

use std::collections::BTreeMap;
use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::backend::manifest::Manifest;
use crate::backend::{ActCkpt, Precision};
use crate::contracts::EmitChecker;
use crate::coordinator::{HiftScheduler, LrSchedule, SchedulerCfg, UpdateStrategy};
use crate::memmodel::account::paged_param_bound_bytes;
use crate::optim::OffloadLedger;
use crate::ser::{self, Value};
use crate::tensor::paged::{Compression, OffloadCfg, PageEvent};

use dry::SymModel;

/// Fixed learning rate used for symbolic plans (resume-alignment compares
/// `lr` bit-for-bit, so generator and verifier must agree on the schedule).
pub(crate) const PLAN_LR: f32 = 0.1;

/// Cap on recorded violations per plan — injected faults can fire on every
/// release of every step; a handful is plenty of evidence.
const MAX_VIOLATIONS: usize = 64;

/// Fault-injection knobs.  Each corrupts the generated plan in one specific
/// way; the verifier must flag it (regression tests assert this).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Inject {
    #[default]
    None,
    /// Suppress the first page-out event (state changes, trace doesn't).
    DropEvict,
    /// Let `release_unit` evict tensors pinned through the update.
    EvictPinned,
    /// Post an async prefetch for a tensor pinned under the fused update.
    PrefetchPinned,
    /// Swap the first two gradient emits of every step.
    SwapEmits,
    /// Defer (hoard) gradients even when loss scaling is off.
    HoardGrads,
}

impl Inject {
    pub fn parse(s: &str) -> Result<Inject> {
        Ok(match s {
            "none" => Inject::None,
            "drop-evict" => Inject::DropEvict,
            "evict-pinned" => Inject::EvictPinned,
            "prefetch-pinned" => Inject::PrefetchPinned,
            "swap-emits" => Inject::SwapEmits,
            "hoard-grads" => Inject::HoardGrads,
            other => bail!(
                "unknown injection {other:?} (want none|drop-evict|evict-pinned|\
                 prefetch-pinned|swap-emits|hoard-grads)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Inject::None => "none",
            Inject::DropEvict => "drop-evict",
            Inject::EvictPinned => "evict-pinned",
            Inject::PrefetchPinned => "prefetch-pinned",
            Inject::SwapEmits => "swap-emits",
            Inject::HoardGrads => "hoard-grads",
        }
    }
}

/// Strategy family axis — MeZO rides along only for the mutual-exclusion
/// rule (its zeroth-order probes mutate parameters in place, which the
/// paging tier must never interleave with).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    Hift,
    Mezo,
}

/// One point of the configuration lattice.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatticePoint {
    pub family: Family,
    pub strategy: UpdateStrategy,
    pub m: usize,
    pub act_ckpt: ActCkpt,
    pub offload: OffloadCfg,
    pub precision: Precision,
    pub workers: usize,
}

impl LatticePoint {
    /// Stable human/machine-readable name (used as the JSON key).
    pub fn name(&self) -> String {
        format!(
            "{}|{}|m={}|ckpt={}|offload={}|prec={}|workers={}",
            match self.family {
                Family::Hift => "hift",
                Family::Mezo => "mezo",
            },
            self.strategy.name(),
            self.m,
            self.act_ckpt.name(),
            self.offload.name(),
            self.precision.name(),
            self.workers,
        )
    }

    /// Whether this point exercises the paging tier at all.
    pub fn paged(&self) -> bool {
        self.offload.enabled && self.workers <= 1
    }
}

/// One derived step: the scheduler's decision plus the ordered event trace
/// the streamed walk produces for it.
#[derive(Debug, Clone)]
pub struct PlanStep {
    pub step: u64,
    pub units: Vec<usize>,
    /// Units staged for the *next* step (peeked after `next()`, exactly as
    /// `Hift::step` does).  Empty on step 1: the pager attaches lazily
    /// inside the first group run, after staging was requested.
    pub staged: Vec<usize>,
    pub lr: f32,
    pub sweep_boundary: bool,
    pub ops: Vec<TraceOp>,
}

impl PlanStep {
    /// Just the paging events, in order — the stream
    /// `NativeBackend::take_offload_trace` must reproduce.
    pub fn page_events(&self) -> Vec<PageEvent> {
        self.ops
            .iter()
            .filter_map(|op| match op {
                TraceOp::Page(ev) => Some(*ev),
                _ => None,
            })
            .collect()
    }

    /// Just the gradient emits `(slot, param_idx)`, in order.
    pub fn emits(&self) -> Vec<(usize, usize)> {
        self.ops
            .iter()
            .filter_map(|op| match op {
                TraceOp::Emit { slot, idx } => Some((*slot, *idx)),
                _ => None,
            })
            .collect()
    }
}

/// One event of the derived trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceOp {
    /// A steady-state paging action (shared vocabulary with the real pager).
    Page(PageEvent),
    /// The compute walk reads unit `unit`'s parameters.
    Read { unit: usize },
    /// A gradient for parameter `idx` is handed to the update sink as `slot`.
    Emit { slot: usize, idx: usize },
    /// The pager's end-of-run point: pins lift here, so evictions after
    /// this marker are the legitimate post-update page-outs.
    EndRun,
}

/// A full static plan for one lattice point.
#[derive(Debug, Clone)]
pub struct Plan {
    /// Whether the sink defers grads to step end (f16 loss-scaling path).
    pub deferred: bool,
    pub steps: Vec<PlanStep>,
}

/// A verified-property failure.
#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: &'static str,
    pub step: u64,
    pub detail: String,
}

/// Byte-level facts the verifier proved for one plan.
#[derive(Debug, Clone, Default)]
pub struct PlanMetrics {
    pub peak_param_bytes: u64,
    pub bound_bytes: u64,
    pub peak_grad_bytes: u64,
    pub expected_grad_bytes: u64,
    pub page_ins: u64,
    pub page_outs: u64,
    pub prefetches: u64,
    pub emits: u64,
}

/// Outcome of verifying one plan: per-rule assertion counts + violations.
#[derive(Debug, Clone, Default)]
pub struct Verification {
    pub metrics: PlanMetrics,
    pub checks: BTreeMap<&'static str, u64>,
    pub violations: Vec<Violation>,
}

impl Verification {
    fn check(&mut self, rule: &'static str, step: u64, ok: bool, detail: impl FnOnce() -> String) {
        *self.checks.entry(rule).or_insert(0) += 1;
        if !ok && self.violations.len() < MAX_VIOLATIONS {
            self.violations.push(Violation { rule, step, detail: detail() });
        }
    }
}

/// Static mirror of the runtime mutual exclusions (`set_workers` /
/// `set_offload` bails, MeZO's in-place probe constraint) plus the
/// degenerate-value guards the CLI enforces at parse time.
pub fn validate_point(p: &LatticePoint) -> Result<()> {
    if p.workers == 0 {
        bail!("--workers must be >= 1 (1 = the plain serial walk)");
    }
    if p.m == 0 {
        bail!("-m must be >= 1 (one unit per step is the finest schedule)");
    }
    if p.offload.enabled && p.workers > 1 {
        bail!("offload x workers exclusion: the sharded walk bypasses the unit pager");
    }
    if p.family == Family::Mezo && p.offload.enabled {
        bail!("MeZO x offload exclusion: in-place perturbation probes cannot run over paged masters");
    }
    Ok(())
}

/// Derive the static plan for one lattice point (see [`dry`]).
pub fn generate_plan(
    manifest: &Manifest,
    point: &LatticePoint,
    n_steps: u64,
    inject: Inject,
) -> Result<Plan> {
    dry::generate_plan(manifest, point, n_steps, inject)
}

/// Replay `plan` through the independent verifier.  Shares no state with
/// the generator beyond the manifest: every rule below re-derives the
/// expected machine state from the event stream itself.
pub fn verify_plan(manifest: &Manifest, point: &LatticePoint, plan: &Plan) -> Result<Verification> {
    let model = SymModel::new(manifest)?;
    let vinfo = manifest.variant("base")?;
    let mut out = Verification::default();
    let paging = point.paged();
    let n = model.param_bytes.len();

    // --- replayed pager state -------------------------------------------
    let mut managed = vec![false; n];
    for idxs in &model.unit_params {
        for &i in idxs {
            managed[i] = true;
        }
    }
    // Managed tensors start on host (initial placement, not an event).
    let mut resident: Vec<bool> = managed.iter().map(|m| !m).collect();
    let mut requested = vec![false; n];
    let mut device_bytes: u64 = 0;
    let mut peak_param: u64 = 0;
    let mut ledger = OffloadLedger::default();

    // --- replayed update-sink state (FusedApply over AdamW) --------------
    let mut sink_ledger = OffloadLedger::default();
    let mut state_seen = vec![false; n];
    let mut peak_grad: u64 = 0;
    let mut expected_grad: u64 = 0;

    // --- structural residency bound over the *actual* schedule -----------
    let schedule: Vec<(Vec<usize>, Vec<usize>)> = plan
        .steps
        .iter()
        .enumerate()
        .map(|(t, s)| (s.units.clone(), staged_eff(point, t, s).to_vec()))
        .collect();
    let walk_slots = if point.act_ckpt.seg_len(model.n_layers).is_some() { 2 } else { 1 };
    let bound = if paging {
        paged_param_bound_bytes(&model.unit_bytes, &schedule, walk_slots)
    } else {
        0
    };

    for (t, step) in plan.steps.iter().enumerate() {
        let sn = step.step;
        let keep_units = staged_eff(point, t, step);
        let mut pinned = vec![false; n];
        for &u in &step.units {
            for &i in &model.unit_params[u] {
                pinned[i] = true;
            }
        }
        let mut keep = vec![false; n];
        for &u in keep_units {
            for &i in &model.unit_params[u] {
                keep[i] = true;
            }
        }

        // Slot table exactly as `run_group_streamed` builds it.
        let mut slots: HashMap<String, usize> = HashMap::new();
        for &u in &step.units {
            for &i in &model.unit_params[u] {
                let slot = slots.len();
                slots.insert(vinfo.params[i].name.clone(), slot);
            }
        }
        let mut checker = EmitChecker::new(vinfo, &slots)?;
        let mut deferred: Vec<usize> = Vec::new();
        let mut grad_resident: u64 = 0;

        for op in &step.ops {
            match *op {
                TraceOp::Page(PageEvent::Prefetch { idx }) => {
                    out.metrics.prefetches += 1;
                    out.check("prefetch-overlap", sn, paging && point.offload.prefetch, || {
                        format!("prefetch of {} posted with async prefetch disabled", pname(vinfo, idx))
                    });
                    // Prefetching a *non-resident* pinned tensor is how
                    // staging works; the hazard is a fetch posted while the
                    // device master is live — resident and, worst case,
                    // pinned under the fused in-place update.
                    out.check("prefetch-overlap", sn, !resident[idx], || {
                        if pinned[idx] {
                            format!(
                                "prefetch of {} overlaps the fused in-place update (resident and pinned)",
                                pname(vinfo, idx)
                            )
                        } else {
                            format!("prefetch of resident master {}", pname(vinfo, idx))
                        }
                    });
                    out.check("prefetch-overlap", sn, !requested[idx], || {
                        format!("duplicate prefetch request for {}", pname(vinfo, idx))
                    });
                    requested[idx] = true;
                }
                TraceOp::Page(PageEvent::Admit { idx }) => {
                    out.metrics.page_ins += 1;
                    if resident[idx] {
                        out.check("ledger-conserve", sn, false, || {
                            format!("{} paged in while already resident (double page-in)", pname(vinfo, idx))
                        });
                    } else {
                        resident[idx] = true;
                        requested[idx] = false;
                        device_bytes += model.param_bytes[idx];
                        peak_param = peak_param.max(device_bytes);
                        ledger.page_in(model.param_bytes[idx]);
                    }
                }
                TraceOp::Page(PageEvent::Evict { idx }) => {
                    out.metrics.page_outs += 1;
                    out.check("pinned-evict", sn, !pinned[idx], || {
                        format!("{} paged out while pinned through the update", pname(vinfo, idx))
                    });
                    if resident[idx] {
                        resident[idx] = false;
                        device_bytes = device_bytes.saturating_sub(model.param_bytes[idx]);
                        ledger.page_out(model.param_bytes[idx]);
                    } else {
                        out.check("ledger-conserve", sn, false, || {
                            format!("{} paged out while not resident (double page-out)", pname(vinfo, idx))
                        });
                    }
                }
                TraceOp::Read { unit } => {
                    if paging {
                        for &i in &model.unit_params[unit] {
                            out.check("evicted-read", sn, resident[i], || {
                                format!("unit {unit} read touches evicted master {}", pname(vinfo, i))
                            });
                        }
                    }
                }
                TraceOp::EndRun => {
                    pinned.iter_mut().for_each(|p| *p = false);
                }
                TraceOp::Emit { slot, idx } => {
                    out.metrics.emits += 1;
                    if paging {
                        out.check("evicted-read", sn, resident[idx], || {
                            format!("update of evicted master {}", pname(vinfo, idx))
                        });
                    }
                    if let Err(e) = checker.observe(slot, &vinfo.params[idx].name) {
                        out.check("emit-order", sn, false, || e.to_string());
                    }
                    let g = model.param_bytes[idx];
                    sink_ledger.grad_in(g);
                    grad_resident += g;
                    peak_grad = peak_grad.max(grad_resident);
                    if plan.deferred {
                        deferred.push(idx);
                    } else {
                        apply_update(&mut sink_ledger, idx, &mut state_seen, &model.param_bytes);
                        sink_ledger.grad_out(g);
                        grad_resident -= g;
                    }
                }
            }
        }

        // Step boundary: the sink finishes (draining any deferred grads),
        // the emit checker proves completeness, the pager's end-of-run
        // state must leave nothing resident except the staged next group.
        for idx in deferred.drain(..) {
            apply_update(&mut sink_ledger, idx, &mut state_seen, &model.param_bytes);
            sink_ledger.grad_out(model.param_bytes[idx]);
            grad_resident -= model.param_bytes[idx];
        }
        if let Err(e) = checker.finalize() {
            out.check("emit-order", sn, false, || e.to_string());
        }
        if let Err(e) = sink_ledger.check_sink_quiesced() {
            out.check("sink-quiesce", sn, false, || e.to_string());
        } else {
            out.check("sink-quiesce", sn, true, String::new);
        }
        if paging {
            for i in 0..n {
                out.check("ledger-conserve", sn, !(managed[i] && resident[i] && !keep[i]), || {
                    format!(
                        "{} still resident past end-of-step without being staged",
                        pname(vinfo, i)
                    )
                });
            }
            if let Err(e) = ledger.check_conservation() {
                out.check("ledger-conserve", sn, false, || e.to_string());
            }
        }

        // Per-step expected gradient residency.
        let step_param_bytes: Vec<u64> = step
            .units
            .iter()
            .flat_map(|&u| model.unit_params[u].iter().map(|&i| model.param_bytes[i]))
            .collect();
        expected_grad = expected_grad.max(if point.precision.needs_loss_scaling() {
            step_param_bytes.iter().sum()
        } else {
            step_param_bytes.iter().copied().max().unwrap_or(0)
        });
    }

    // --- whole-plan rules -------------------------------------------------
    if paging {
        out.check("peak-bound", 0, peak_param <= bound, || {
            format!("peak param residency {peak_param} exceeds structural bound {bound}")
        });
    }
    out.check("grad-peak", 0, peak_grad == expected_grad, || {
        format!("peak grad residency {peak_grad} != expected {expected_grad} (max single tensor, or group sum under deferred f16)")
    });

    // Resume alignment: a fresh scheduler fast-forwarded to t must plan
    // step t identically (checkpoint/resume takes exactly this path).
    let k = model.n_units.div_ceil(point.m.max(1));
    let samples =
        [0usize, k.saturating_sub(1), k, k + 1, 2 * k, plan.steps.len().saturating_sub(1)];
    let mut done: Vec<usize> = Vec::new();
    for &t in &samples {
        if t >= plan.steps.len() || done.contains(&t) {
            continue;
        }
        done.push(t);
        let mut sched = HiftScheduler::new(
            SchedulerCfg {
                m: point.m,
                strategy: point.strategy,
                schedule: LrSchedule::Const { lr: PLAN_LR },
            },
            model.n_units,
        );
        sched.fast_forward(t as u64);
        let replay = sched.next();
        let want = &plan.steps[t];
        let ok = replay.step == want.step
            && replay.units == want.units
            && replay.lr == want.lr
            && replay.sweep_boundary == want.sweep_boundary;
        out.check("resume-align", want.step, ok, || {
            format!(
                "fast_forward({t}) replans step {} as units {:?} lr {} boundary {} (plan had {:?} lr {} boundary {})",
                replay.step, replay.units, replay.lr, replay.sweep_boundary,
                want.units, want.lr, want.sweep_boundary
            )
        });
    }

    out.metrics.peak_param_bytes = peak_param;
    out.metrics.bound_bytes = bound;
    out.metrics.peak_grad_bytes = peak_grad;
    out.metrics.expected_grad_bytes = expected_grad;
    Ok(out)
}

/// Effective staged set for step `t`: empty on the first step (the pager
/// attaches lazily *after* staging was requested) and in sync offload mode
/// (`stage_unit` is prefetch-only); the plan's staged units otherwise.
fn staged_eff<'a>(point: &LatticePoint, t: usize, step: &'a PlanStep) -> &'a [usize] {
    if t == 0 || !point.paged() || !point.offload.prefetch {
        &[]
    } else {
        &step.staged
    }
}

/// Replay `FusedApply::apply_now`'s ledger traffic for one AdamW update:
/// page in the (m, v) moments — zero bytes before the tensor's first-ever
/// update — allocate any growth, page the post-update state back out.
fn apply_update(led: &mut OffloadLedger, idx: usize, state_seen: &mut [bool], param_bytes: &[u64]) {
    let post = 2 * param_bytes[idx]; // two f32 moments per f32 parameter
    let pre = if state_seen[idx] { post } else { 0 };
    led.page_in(pre);
    led.alloc_on_device(post - pre);
    led.page_out(post);
    state_seen[idx] = true;
}

fn pname(vinfo: &crate::backend::manifest::VariantInfo, idx: usize) -> String {
    vinfo.params.get(idx).map_or_else(|| format!("param#{idx}"), |p| p.name.clone())
}

/// Enumerate the full lattice for a model with `n_units` layer units.
/// MeZO points are included only on the offload-enabled slice — every one
/// must be *rejected* (the exclusion rule), never planned.
pub fn enumerate_lattice(n_units: usize) -> Vec<LatticePoint> {
    let strategies = [
        UpdateStrategy::Bottom2Up,
        UpdateStrategy::Top2Down,
        UpdateStrategy::Random { seed: 7 },
    ];
    let acts = [ActCkpt::None, ActCkpt::EveryK(1), ActCkpt::EveryK(2), ActCkpt::Sqrt];
    let offloads = [
        OffloadCfg { enabled: false, compress: Compression::Lossless, prefetch: false },
        OffloadCfg { enabled: true, compress: Compression::Lossless, prefetch: false },
        OffloadCfg { enabled: true, compress: Compression::Lossless, prefetch: true },
        OffloadCfg { enabled: true, compress: Compression::F16, prefetch: false },
        OffloadCfg { enabled: true, compress: Compression::F16, prefetch: true },
    ];
    let precisions = [Precision::F32, Precision::Bf16, Precision::F16];
    let mut points = Vec::new();
    for &strategy in &strategies {
        for m in 1..=n_units {
            for &act_ckpt in &acts {
                for &offload in &offloads {
                    for &precision in &precisions {
                        for workers in [1usize, 2] {
                            points.push(LatticePoint {
                                family: Family::Hift,
                                strategy,
                                m,
                                act_ckpt,
                                offload,
                                precision,
                                workers,
                            });
                        }
                    }
                }
            }
        }
    }
    for offload in offloads.into_iter().filter(|o| o.enabled) {
        points.push(LatticePoint {
            family: Family::Mezo,
            strategy: UpdateStrategy::Bottom2Up,
            m: 1,
            act_ckpt: ActCkpt::None,
            offload,
            precision: Precision::F32,
            workers: 1,
        });
    }
    points
}

/// Per-point outcome in the lattice report.
#[derive(Debug, Clone)]
pub enum PointStatus {
    /// Plan derived and every rule held.
    Verified,
    /// Statically rejected, as the exclusion rules demand.
    Rejected(String),
    /// At least one rule was violated.
    Failed,
}

#[derive(Debug, Clone)]
pub struct PointReport {
    pub point: LatticePoint,
    pub status: PointStatus,
    pub steps: u64,
    pub metrics: Option<PlanMetrics>,
    pub violations: Vec<Violation>,
}

/// Whole-lattice result: the machine-readable proof artifact's source.
#[derive(Debug)]
pub struct LatticeReport {
    pub preset: String,
    pub inject: Inject,
    pub points: Vec<PointReport>,
    pub checks: BTreeMap<&'static str, u64>,
    pub verified: usize,
    pub rejected: usize,
    pub failed: usize,
}

impl LatticeReport {
    pub fn ok(&self) -> bool {
        self.failed == 0
    }
}

/// Verify every lattice point.  `steps` overrides the per-point default of
/// two full sweeps plus two wraparound steps (`2k + 2`).
pub fn check_lattice(manifest: &Manifest, inject: Inject, steps: Option<u64>) -> Result<LatticeReport> {
    let mut report = LatticeReport {
        preset: manifest.preset.clone(),
        inject,
        points: Vec::new(),
        checks: BTreeMap::new(),
        verified: 0,
        rejected: 0,
        failed: 0,
    };
    for point in enumerate_lattice(manifest.n_units) {
        let expect_reject = (point.offload.enabled && point.workers > 1)
            || (point.family == Family::Mezo && point.offload.enabled);
        *report.checks.entry("exclusion").or_insert(0) += 1;
        let entry = match (validate_point(&point), expect_reject) {
            (Err(e), true) => {
                report.rejected += 1;
                PointReport {
                    point,
                    status: PointStatus::Rejected(e.to_string()),
                    steps: 0,
                    metrics: None,
                    violations: Vec::new(),
                }
            }
            (Err(e), false) => {
                report.failed += 1;
                PointReport {
                    point,
                    status: PointStatus::Failed,
                    steps: 0,
                    metrics: None,
                    violations: vec![Violation {
                        rule: "exclusion",
                        step: 0,
                        detail: format!("valid point rejected: {e}"),
                    }],
                }
            }
            (Ok(()), true) => {
                report.failed += 1;
                PointReport {
                    point,
                    status: PointStatus::Failed,
                    steps: 0,
                    metrics: None,
                    violations: vec![Violation {
                        rule: "exclusion",
                        step: 0,
                        detail: "mutually-exclusive point was not rejected".into(),
                    }],
                }
            }
            (Ok(()), false) => {
                let k = manifest.n_units.div_ceil(point.m) as u64;
                let n_steps = steps.unwrap_or(2 * k + 2);
                let plan = generate_plan(manifest, &point, n_steps, inject)?;
                let v = verify_plan(manifest, &point, &plan)?;
                for (rule, c) in &v.checks {
                    *report.checks.entry(rule).or_insert(0) += *c;
                }
                let status = if v.violations.is_empty() {
                    report.verified += 1;
                    PointStatus::Verified
                } else {
                    report.failed += 1;
                    PointStatus::Failed
                };
                PointReport {
                    point,
                    status,
                    steps: n_steps,
                    metrics: Some(v.metrics),
                    violations: v.violations,
                }
            }
        };
        report.points.push(entry);
    }
    Ok(report)
}

/// Render the report as the `plancheck.json` proof artifact (schema 1).
pub fn report_json(report: &LatticeReport) -> Value {
    let mut rules = ser::Obj::new();
    for (rule, checks) in &report.checks {
        let violations: u64 = report
            .points
            .iter()
            .flat_map(|p| &p.violations)
            .filter(|v| v.rule == *rule)
            .count() as u64;
        rules.insert(
            *rule,
            Value::obj(vec![
                ("checks", Value::Num(*checks as f64)),
                ("violations", Value::Num(violations as f64)),
            ]),
        );
    }
    let configs: Vec<Value> = report
        .points
        .iter()
        .map(|p| {
            let mut o = ser::Obj::new();
            o.insert("name", Value::Str(p.point.name()));
            o.insert(
                "status",
                Value::Str(
                    match &p.status {
                        PointStatus::Verified => "verified",
                        PointStatus::Rejected(_) => "rejected",
                        PointStatus::Failed => "failed",
                    }
                    .into(),
                ),
            );
            o.insert("steps", Value::Num(p.steps as f64));
            if let PointStatus::Rejected(why) = &p.status {
                o.insert("reason", Value::Str(why.clone()));
            }
            if let Some(m) = &p.metrics {
                o.insert(
                    "metrics",
                    Value::obj(vec![
                        ("peak_param_bytes", Value::Num(m.peak_param_bytes as f64)),
                        ("bound_bytes", Value::Num(m.bound_bytes as f64)),
                        ("peak_grad_bytes", Value::Num(m.peak_grad_bytes as f64)),
                        ("page_ins", Value::Num(m.page_ins as f64)),
                        ("page_outs", Value::Num(m.page_outs as f64)),
                        ("prefetches", Value::Num(m.prefetches as f64)),
                        ("emits", Value::Num(m.emits as f64)),
                    ]),
                );
            }
            if !p.violations.is_empty() {
                o.insert(
                    "violations",
                    Value::Arr(
                        p.violations
                            .iter()
                            .map(|v| {
                                Value::obj(vec![
                                    ("rule", Value::Str(v.rule.into())),
                                    ("step", Value::Num(v.step as f64)),
                                    ("detail", Value::Str(v.detail.clone())),
                                ])
                            })
                            .collect(),
                    ),
                );
            }
            Value::Obj(o)
        })
        .collect();
    Value::obj(vec![
        ("schema", Value::Str("plancheck/1".into())),
        ("preset", Value::Str(report.preset.clone())),
        ("inject", Value::Str(report.inject.name().into())),
        ("configs_total", Value::Num(report.points.len() as f64)),
        ("verified", Value::Num(report.verified as f64)),
        ("rejected_invalid", Value::Num(report.rejected as f64)),
        ("failed", Value::Num(report.failed as f64)),
        ("rules", Value::Obj(rules)),
        ("configs", Value::Arr(configs)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;

    fn manifest() -> Manifest {
        NativeBackend::preset("tiny", 42).expect("tiny preset").manifest().clone()
    }

    fn point(offload: OffloadCfg) -> LatticePoint {
        LatticePoint {
            family: Family::Hift,
            strategy: UpdateStrategy::Bottom2Up,
            m: 2,
            act_ckpt: ActCkpt::None,
            offload,
            precision: Precision::F32,
            workers: 1,
        }
    }

    fn host(prefetch: bool) -> OffloadCfg {
        OffloadCfg { enabled: true, compress: Compression::Lossless, prefetch }
    }

    #[test]
    fn clean_lattice_verifies_everywhere() {
        let m = manifest();
        let report = check_lattice(&m, Inject::None, None).unwrap();
        assert!(report.points.len() > 100, "lattice too small: {}", report.points.len());
        assert!(report.verified > 0 && report.rejected > 0);
        for p in &report.points {
            assert!(
                p.violations.is_empty(),
                "clean config {} violated: {:?}",
                p.point.name(),
                p.violations
            );
        }
        assert!(report.ok());
    }

    #[test]
    fn every_injection_is_caught() {
        let m = manifest();
        for inject in [
            Inject::DropEvict,
            Inject::EvictPinned,
            Inject::PrefetchPinned,
            Inject::SwapEmits,
            Inject::HoardGrads,
        ] {
            let report = check_lattice(&m, inject, Some(4)).unwrap();
            assert!(
                report.failed > 0,
                "injected fault {:?} slipped past the verifier",
                inject
            );
        }
    }

    #[test]
    fn injected_faults_name_the_right_rule() {
        let m = manifest();
        let cases = [
            (Inject::DropEvict, "ledger-conserve", host(false)),
            (Inject::EvictPinned, "pinned-evict", host(false)),
            (Inject::PrefetchPinned, "prefetch-overlap", host(true)),
            (Inject::SwapEmits, "emit-order", host(false)),
            (Inject::HoardGrads, "grad-peak", host(false)),
        ];
        for (inject, rule, offload) in cases {
            let p = point(offload);
            let plan = generate_plan(&m, &p, 4, inject).unwrap();
            let v = verify_plan(&m, &p, &plan).unwrap();
            assert!(
                v.violations.iter().any(|viol| viol.rule == rule),
                "{inject:?} should trip {rule}, got {:?}",
                v.violations
            );
        }
    }

    #[test]
    fn exclusions_are_enforced() {
        let mut p = point(host(false));
        p.workers = 2;
        assert!(validate_point(&p).unwrap_err().to_string().contains("offload x workers"));
        let mut p = point(host(false));
        p.family = Family::Mezo;
        assert!(validate_point(&p).unwrap_err().to_string().contains("MeZO"));
        let mut p = point(host(false));
        p.workers = 0;
        assert!(validate_point(&p).unwrap_err().to_string().contains("--workers"));
        let mut p = point(host(false));
        p.m = 0;
        assert!(validate_point(&p).unwrap_err().to_string().contains("-m"));
    }

    #[test]
    fn report_json_shape() {
        let m = manifest();
        let report = check_lattice(&m, Inject::None, Some(3)).unwrap();
        let v = report_json(&report);
        assert_eq!(v.get("schema").as_str(), Some("plancheck/1"));
        assert_eq!(
            v.get("configs_total").as_usize(),
            Some(report.points.len())
        );
        assert_eq!(v.get("failed").as_usize(), Some(0));
        let text = ser::emit(&v);
        let back = ser::parse(&text).unwrap();
        assert_eq!(back.get("verified").as_usize(), Some(report.verified));
    }

    #[test]
    fn plans_are_deterministic() {
        let m = manifest();
        let p = point(host(true));
        let a = generate_plan(&m, &p, 6, Inject::None).unwrap();
        let b = generate_plan(&m, &p, 6, Inject::None).unwrap();
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            assert_eq!(sa.units, sb.units);
            assert_eq!(sa.ops, sb.ops);
        }
    }

    #[test]
    fn grad_peak_is_max_single_tensor_when_streaming() {
        let m = manifest();
        let p = point(host(false));
        let plan = generate_plan(&m, &p, 6, Inject::None).unwrap();
        let v = verify_plan(&m, &p, &plan).unwrap();
        assert!(v.violations.is_empty(), "{:?}", v.violations);
        // tiny: largest tensor is head.w / tok_emb (vocab x d_model) = 64*32*4.
        assert_eq!(v.metrics.peak_grad_bytes, 64 * 32 * 4);
    }
}
