//! Stream quality gates + diversity accounting for the task forge (ISSUE 9).
//!
//! [`ForgeStream`] wraps any [`Task`] and adds the dataforge-style quality
//! layer (SNIPPETS.md §06-data-quality):
//!
//! - **Dedup gate** — every emitted row is fingerprinted (FNV-1a over its
//!   tokens + targets); a train batch whose rows are mostly already-seen is
//!   resampled from the underlying stream up to [`DedupCfg::max_retries`]
//!   times before being emitted anyway.  The gate is a pure function of the
//!   inner stream, so a wrapped stream is still deterministic per seed and a
//!   checkpoint-resume replay reproduces the identical gate decisions.
//! - **Diversity accounting** — n-gram novelty over emitted tokens, the
//!   label histogram at supervised positions (normalized entropy), and
//!   per-template coverage (from [`Task::coverage`], e.g. mixtures), all
//!   summarized as a [`StreamStats`] that `RunRecord` serializes per run.
//!
//! High-entropy generators never trip the gate, so wrapping is emission-
//! transparent for the historical presets: the wrapped stream yields
//! bit-identical batches to the raw task.
//!
//! Memory for the seen-sets is bounded by [`DedupCfg::max_entries`]; past
//! that the gate stops remembering new fingerprints (counters keep running).

use std::collections::{BTreeMap, HashSet};

use crate::backend::Batch;
use crate::ser::Value;

use super::Task;

/// Dedup-gate tuning.
#[derive(Debug, Clone, Copy)]
pub struct DedupCfg {
    /// n-gram width for the novelty statistic.
    pub ngram: usize,
    /// How many times a mostly-duplicate batch is resampled before emission.
    pub max_retries: u32,
    /// Fingerprint-set capacity bound (rows and n-grams each).
    pub max_entries: usize,
}

impl Default for DedupCfg {
    fn default() -> Self {
        DedupCfg { ngram: 4, max_retries: 3, max_entries: 1 << 20 }
    }
}

/// Diversity / dedup summary of one emitted train stream.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StreamStats {
    pub batches_emitted: u64,
    pub rows_emitted: u64,
    /// Emitted rows whose fingerprint had been seen before.
    pub dup_rows: u64,
    /// Batches the dedup gate rejected and redrew.
    pub resampled_batches: u64,
    pub ngrams_total: u64,
    pub ngrams_distinct: u64,
    /// Normalized label entropy at supervised positions, in `[0, 1]`.
    pub label_entropy: f64,
    /// Per-template batch counts (single entry for plain families).
    pub coverage: Vec<(String, u64)>,
}

impl StreamStats {
    /// Fraction of emitted token n-grams never seen before, in `(0, 1]`.
    pub fn ngram_distinct_ratio(&self) -> f64 {
        if self.ngrams_total == 0 {
            0.0
        } else {
            self.ngrams_distinct as f64 / self.ngrams_total as f64
        }
    }

    /// Normalized entropy of the per-template coverage histogram: 1.0 for a
    /// single-template stream or a perfectly balanced mixture, → 0 as one
    /// template dominates.
    pub fn coverage_balance(&self) -> f64 {
        if self.coverage.len() <= 1 {
            return 1.0;
        }
        let mut total = 0u64;
        for &(_, n) in &self.coverage {
            total += n;
        }
        if total == 0 {
            return 0.0;
        }
        let mut h = 0.0f64;
        for &(_, n) in &self.coverage {
            if n > 0 {
                let p = n as f64 / total as f64;
                h -= p * p.ln();
            }
        }
        h / (self.coverage.len() as f64).ln()
    }

    /// Scalar diversity score in `[0, 1]`: label entropy and template
    /// coverage, equally weighted (the two axes the forge can steer).
    pub fn diversity_score(&self) -> f64 {
        0.5 * self.label_entropy + 0.5 * self.coverage_balance()
    }

    /// Serialize for the `RunRecord` / scoreboard JSON.
    pub fn to_json(&self) -> Value {
        let coverage: Vec<Value> = self
            .coverage
            .iter()
            .map(|(name, n)| {
                Value::obj(vec![("template", name.as_str().into()), ("batches", (*n).into())])
            })
            .collect();
        Value::obj(vec![
            ("batches_emitted", self.batches_emitted.into()),
            ("rows_emitted", self.rows_emitted.into()),
            ("dup_rows", self.dup_rows.into()),
            ("resampled_batches", self.resampled_batches.into()),
            ("ngrams_total", self.ngrams_total.into()),
            ("ngrams_distinct", self.ngrams_distinct.into()),
            ("ngram_distinct_ratio", self.ngram_distinct_ratio().into()),
            ("label_entropy", self.label_entropy.into()),
            ("coverage_balance", self.coverage_balance().into()),
            ("diversity_score", self.diversity_score().into()),
            ("coverage", Value::Arr(coverage)),
        ])
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0100_0000_01b3;

fn fnv1a_i32s(mut h: u64, xs: &[i32]) -> u64 {
    for &x in xs {
        for byte in x.to_le_bytes() {
            h = (h ^ byte as u64).wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// A [`Task`] wrapped with the dedup gate and diversity accounting.
pub struct ForgeStream {
    inner: Box<dyn Task>,
    cfg: DedupCfg,
    rows_seen: HashSet<u64>,
    ngrams_seen: HashSet<u64>,
    /// Target-token histogram at supervised positions (BTreeMap: the lint
    /// contract bans hash-order iteration in `data/`).
    labels: BTreeMap<i32, u64>,
    batches_emitted: u64,
    rows_emitted: u64,
    dup_rows: u64,
    resampled_batches: u64,
    ngrams_total: u64,
    ngrams_distinct: u64,
}

impl ForgeStream {
    pub fn new(inner: Box<dyn Task>, cfg: DedupCfg) -> Self {
        ForgeStream {
            inner,
            cfg,
            rows_seen: HashSet::new(),
            ngrams_seen: HashSet::new(),
            labels: BTreeMap::new(),
            batches_emitted: 0,
            rows_emitted: 0,
            dup_rows: 0,
            resampled_batches: 0,
            ngrams_total: 0,
            ngrams_distinct: 0,
        }
    }

    fn row_fingerprint(batch: &Batch, row: usize) -> u64 {
        let s = batch.s;
        let h = fnv1a_i32s(FNV_OFFSET, &batch.tokens[row * s..(row + 1) * s]);
        fnv1a_i32s(h, &batch.targets[row * s..(row + 1) * s])
    }

    /// Rows of `batch` whose fingerprint is already in the seen-set.
    fn dup_rows_in(&self, batch: &Batch) -> usize {
        let mut dups = 0;
        for row in 0..batch.b {
            if self.rows_seen.contains(&Self::row_fingerprint(batch, row)) {
                dups += 1;
            }
        }
        dups
    }

    /// Fold an accepted batch into the fingerprint sets and statistics.
    fn admit(&mut self, batch: &Batch) {
        let s = batch.s;
        for row in 0..batch.b {
            self.rows_emitted += 1;
            let fp = Self::row_fingerprint(batch, row);
            if self.rows_seen.contains(&fp) {
                self.dup_rows += 1;
            } else if self.rows_seen.len() < self.cfg.max_entries {
                self.rows_seen.insert(fp);
            }
            let toks = &batch.tokens[row * s..(row + 1) * s];
            for window in toks.windows(self.cfg.ngram.clamp(1, s)) {
                self.ngrams_total += 1;
                let g = fnv1a_i32s(FNV_OFFSET, window);
                if !self.ngrams_seen.contains(&g) {
                    self.ngrams_distinct += 1;
                    if self.ngrams_seen.len() < self.cfg.max_entries {
                        self.ngrams_seen.insert(g);
                    }
                }
            }
            for col in 0..s {
                if batch.weights[row * s + col] > 0.0 {
                    *self.labels.entry(batch.targets[row * s + col]).or_insert(0) += 1;
                }
            }
        }
        self.batches_emitted += 1;
    }

    /// Snapshot the stream's diversity / dedup statistics.
    pub fn stats(&self) -> StreamStats {
        let mut total = 0u64;
        for &n in self.labels.values() {
            total += n;
        }
        let mut h = 0.0f64;
        if total > 0 {
            for &n in self.labels.values() {
                if n > 0 {
                    let p = n as f64 / total as f64;
                    h -= p * p.ln();
                }
            }
        }
        let label_entropy =
            if self.labels.len() <= 1 { 0.0 } else { h / (self.labels.len() as f64).ln() };
        let coverage = self
            .inner
            .coverage()
            .unwrap_or_else(|| vec![(self.inner.name().to_string(), self.batches_emitted)]);
        StreamStats {
            batches_emitted: self.batches_emitted,
            rows_emitted: self.rows_emitted,
            dup_rows: self.dup_rows,
            resampled_batches: self.resampled_batches,
            ngrams_total: self.ngrams_total,
            ngrams_distinct: self.ngrams_distinct,
            label_entropy,
            coverage,
        }
    }
}

impl Task for ForgeStream {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn train_batch(&mut self) -> Batch {
        let mut batch = self.inner.train_batch();
        let mut tries = 0u32;
        // Resample while more than half the rows are already-seen; always
        // emit after max_retries so degenerate streams still make progress.
        while tries < self.cfg.max_retries && 2 * self.dup_rows_in(&batch) > batch.b {
            self.resampled_batches += 1;
            batch = self.inner.train_batch();
            tries += 1;
        }
        self.admit(&batch);
        batch
    }

    fn eval_batches(&self) -> &[Batch] {
        self.inner.eval_batches()
    }

    fn coverage(&self) -> Option<Vec<(String, u64)>> {
        self.inner.coverage()
    }

    fn stream_stats(&self) -> Option<StreamStats> {
        Some(self.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{build_task, MotifClass, TaskGeom};

    fn geom() -> TaskGeom {
        TaskGeom::new(64, 4, 16)
    }

    /// A degenerate stream: the same batch forever.
    struct ConstTask {
        batch: Batch,
        eval: Vec<Batch>,
    }

    impl ConstTask {
        fn new() -> Self {
            let mut t = MotifClass::new(geom(), 2, 0.0, 1);
            let batch = t.train_batch();
            ConstTask { eval: vec![batch.clone()], batch }
        }
    }

    impl Task for ConstTask {
        fn name(&self) -> &str {
            "const"
        }

        fn train_batch(&mut self) -> Batch {
            self.batch.clone()
        }

        fn eval_batches(&self) -> &[Batch] {
            &self.eval
        }
    }

    #[test]
    fn dedup_gate_fires_on_a_degenerate_stream() {
        let mut fs = ForgeStream::new(Box::new(ConstTask::new()), DedupCfg::default());
        for _ in 0..5 {
            let _ = fs.train_batch();
        }
        let st = fs.stats();
        assert_eq!(st.batches_emitted, 5);
        assert!(st.dup_rows > 0, "constant stream re-emits seen rows");
        // Every batch after the first is fully duplicate → max_retries redraws each.
        assert_eq!(st.resampled_batches, 4 * u64::from(DedupCfg::default().max_retries));
        assert!(st.ngram_distinct_ratio() < 0.25, "got {}", st.ngram_distinct_ratio());
    }

    #[test]
    fn gate_is_transparent_for_high_entropy_streams() {
        let mut raw = MotifClass::new(geom(), 4, 0.0, 9);
        let mut fs =
            ForgeStream::new(Box::new(MotifClass::new(geom(), 4, 0.0, 9)), DedupCfg::default());
        for _ in 0..10 {
            let a = raw.train_batch();
            let b = fs.train_batch();
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.targets, b.targets);
            assert_eq!(a.weights, b.weights);
        }
        let st = fs.stats();
        assert_eq!(st.resampled_batches, 0);
        assert_eq!(st.batches_emitted, 10);
        assert_eq!(st.rows_emitted, 40);
    }

    #[test]
    fn stats_are_deterministic_per_seed() {
        let mut a =
            ForgeStream::new(Box::new(MotifClass::new(geom(), 4, 0.0, 9)), DedupCfg::default());
        let mut b =
            ForgeStream::new(Box::new(MotifClass::new(geom(), 4, 0.0, 9)), DedupCfg::default());
        for _ in 0..8 {
            let _ = a.train_batch();
            let _ = b.train_batch();
        }
        assert_eq!(a.stats(), b.stats());
    }

    #[test]
    fn label_entropy_is_normalized() {
        // motif2: two classes drawn uniformly → entropy near 1.
        let mut fs =
            ForgeStream::new(Box::new(MotifClass::new(geom(), 2, 0.0, 3)), DedupCfg::default());
        for _ in 0..50 {
            let _ = fs.train_batch();
        }
        let st = fs.stats();
        assert!(st.label_entropy > 0.5 && st.label_entropy <= 1.0, "got {}", st.label_entropy);
        assert!(st.diversity_score() > 0.0 && st.diversity_score() <= 1.0);
        assert_eq!(st.coverage_balance(), 1.0, "single-template stream");
    }

    #[test]
    fn stats_serialize_with_all_fields() {
        let mut fs =
            ForgeStream::new(build_task("motif4", geom(), 7).unwrap(), DedupCfg::default());
        let _ = fs.train_batch();
        let json = crate::ser::emit_pretty(&fs.stats().to_json());
        for key in [
            "batches_emitted",
            "dup_rows",
            "resampled_batches",
            "ngram_distinct_ratio",
            "label_entropy",
            "coverage_balance",
            "diversity_score",
            "coverage",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
    }
}
