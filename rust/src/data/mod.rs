//! Synthetic tasks standing in for the paper's datasets (DESIGN.md §2).
//!
//! The paper's quality claims are *relative* — HiFT vs FPFT vs PEFT on the
//! same task — so deterministic planted-signal generators give a clean
//! accuracy axis at laptop scale while exercising the identical training
//! code path.  Mapping:
//!
//! | paper dataset family | stand-in | task type |
//! |---|---|---|
//! | SST-2/5, TREC, MNLI… (Tables 1–2) | [`MotifClass`] | sequence classification |
//! | E2E NLG (Table 3) | [`CopyTask`] / [`SortTask`] | seq2seq generation |
//! | ViGGO/SQL/GSM8K (Table 4) | [`ModSumTask`] | compositional "reasoning" |
//! | Alpaca instruction FT (Fig. 2) | [`InstructTask`] | multi-task with task-id prefix |
//! | LM pre-training corpora (Fig. 3) | [`MarkovLm`] | language modelling |
//!
//! The presets generalize into the parameterized template families of the
//! task forge ([`templates`]): `motif<N>`, `markovlm<N>`, `modsum<N>`, plus
//! the new [`templates::BracketTask`] / [`templates::KvRecallTask`] /
//! [`templates::ReverseTask`] families and `mix:` mixtures.  Every stream
//! built through [`build_task`] runs behind the [`quality::ForgeStream`]
//! dedup gate and records per-stream diversity stats (see `docs/TASKS.md`).
//!
//! Every task emits [`Batch`]es: `tokens` (input), `targets` (gold,
//! position-aligned) and `weights` (loss mask — 1 only where the task
//! defines supervision).

use anyhow::Result;

use crate::backend::Batch;
use crate::rng::Pcg32;

pub mod quality;
pub mod templates;

/// A supervised task: a train-batch sampler plus a fixed eval set.
pub trait Task {
    fn name(&self) -> &str;

    /// Sample a fresh training batch (deterministic in the task's RNG).
    fn train_batch(&mut self) -> Batch;

    /// The held-out evaluation set (fixed at construction).
    fn eval_batches(&self) -> &[Batch];

    /// Per-template batch counts for multi-template streams (mixtures,
    /// instruct); `None` for plain single-template tasks.
    fn coverage(&self) -> Option<Vec<(String, u64)>> {
        None
    }

    /// Diversity / dedup statistics of the emitted train stream; `Some` only
    /// for forge-wrapped streams ([`quality::ForgeStream`]).
    fn stream_stats(&self) -> Option<quality::StreamStats> {
        None
    }

    /// Sum of loss-mask weights in a batch (accuracy denominator).
    fn weight_sum(batch: &Batch) -> f64
    where
        Self: Sized,
    {
        batch.weights.iter().map(|&w| w as f64).sum()
    }
}

/// Geometry every generator needs: vocab and batch shape from the manifest.
#[derive(Debug, Clone, Copy)]
pub struct TaskGeom {
    pub vocab: usize,
    pub b: usize,
    pub s: usize,
}

impl TaskGeom {
    pub fn new(vocab: usize, b: usize, s: usize) -> Self {
        assert!(vocab >= 16, "tasks reserve the first 16 tokens for control symbols");
        TaskGeom { vocab, b, s }
    }
}

// Reserved control tokens (always < 16 < vocab).
pub const PAD: i32 = 0;
pub const SEP: i32 = 1;
/// Classification answers use tokens 2..2+n_classes.
pub const CLS_BASE: i32 = 2;

// ---------------------------------------------------------------------------
// MotifClass — planted-motif sequence classification
// ---------------------------------------------------------------------------

/// Classification with a planted motif: class c's motif (a fixed trigram) is
/// embedded at a random position in noise tokens; the model must emit the
/// class token at the final position.  Difficulty rises with `n_classes`
/// and `noise` (probability of corrupting one motif token).
pub struct MotifClass {
    geom: TaskGeom,
    n_classes: usize,
    motifs: Vec<[i32; 3]>,
    noise: f32,
    rng: Pcg32,
    eval: Vec<Batch>,
    name: String,
}

impl MotifClass {
    pub fn new(geom: TaskGeom, n_classes: usize, noise: f32, seed: u64) -> Self {
        assert!(n_classes >= 2 && (CLS_BASE as usize + n_classes) < geom.vocab);
        let mut rng = Pcg32::new(seed, 101);
        let lo = 16 + n_classes; // motif alphabet sits above control+class tokens
        let motifs: Vec<[i32; 3]> = (0..n_classes)
            .map(|_| {
                [
                    (lo + rng.below(geom.vocab - lo)) as i32,
                    (lo + rng.below(geom.vocab - lo)) as i32,
                    (lo + rng.below(geom.vocab - lo)) as i32,
                ]
            })
            .collect();
        let mut t = MotifClass {
            geom,
            n_classes,
            motifs,
            noise,
            rng,
            eval: Vec::new(),
            name: format!("motif{n_classes}"),
        };
        t.eval = (0..4).map(|_| t.gen_batch()).collect();
        t
    }

    fn gen_batch(&mut self) -> Batch {
        let TaskGeom { vocab, b, s } = self.geom;
        let mut batch = Batch::new(b, s);
        let lo = 16 + self.n_classes;
        for row in 0..b {
            let class = self.rng.below(self.n_classes);
            let motif = self.motifs[class];
            // noise background
            for col in 0..s {
                batch.tokens[row * s + col] = (lo + self.rng.below(vocab - lo)) as i32;
            }
            // plant the motif away from the answer slot
            let pos = self.rng.below(s.saturating_sub(4).max(1));
            for (j, &m) in motif.iter().enumerate() {
                let tok = if self.rng.next_f32() < self.noise {
                    (lo + self.rng.below(vocab - lo)) as i32
                } else {
                    m
                };
                batch.tokens[row * s + pos + j] = tok;
            }
            // last position: SEP input, class-token target, weight 1
            batch.tokens[row * s + s - 1] = SEP;
            batch.targets[row * s + s - 1] = CLS_BASE + class as i32;
            batch.weights[row * s + s - 1] = 1.0;
        }
        batch
    }
}

impl Task for MotifClass {
    fn name(&self) -> &str {
        &self.name
    }

    fn train_batch(&mut self) -> Batch {
        self.gen_batch()
    }

    fn eval_batches(&self) -> &[Batch] {
        &self.eval
    }
}

// ---------------------------------------------------------------------------
// MarkovLm — language modelling on a seeded order-2 Markov "corpus"
// ---------------------------------------------------------------------------

/// LM objective over sequences drawn from a random (but fixed) order-2
/// Markov chain — a tiny corpus with real statistical structure, so loss
/// falls smoothly as the model learns the transition table (the Figure-3
/// stability workload).
pub struct MarkovLm {
    geom: TaskGeom,
    /// `transitions[a][b]` = preferred successors of bigram (a, b)
    succ: Vec<i32>,
    branch: usize,
    rng: Pcg32,
    eval: Vec<Batch>,
    name: String,
}

impl MarkovLm {
    pub fn new(geom: TaskGeom, branch: usize, seed: u64) -> Self {
        let v = geom.vocab;
        let mut rng = Pcg32::new(seed, 202);
        // For each (a, b) pick `branch` allowed successors.
        let mut succ = vec![0i32; v * v * branch];
        for i in 0..v * v {
            for j in 0..branch {
                succ[i * branch + j] = (16 + rng.below(v - 16)) as i32;
            }
        }
        let mut t = MarkovLm { geom, succ, branch, rng, eval: Vec::new(), name: "markovlm".into() };
        t.eval = (0..4).map(|_| t.gen_batch()).collect();
        t
    }

    fn next_tok(&mut self, a: i32, b: i32) -> i32 {
        let idx = (a as usize * self.geom.vocab + b as usize) * self.branch;
        let j = self.rng.below(self.branch);
        self.succ[idx + j]
    }

    fn gen_batch(&mut self) -> Batch {
        let TaskGeom { vocab, b, s } = self.geom;
        let mut batch = Batch::new(b, s);
        for row in 0..b {
            let mut a = (16 + self.rng.below(vocab - 16)) as i32;
            let mut bb = (16 + self.rng.below(vocab - 16)) as i32;
            let mut seq = Vec::with_capacity(s + 1);
            seq.push(a);
            seq.push(bb);
            for _ in 2..=s {
                let c = self.next_tok(a, bb);
                seq.push(c);
                a = bb;
                bb = c;
            }
            for col in 0..s {
                batch.tokens[row * s + col] = seq[col];
                batch.targets[row * s + col] = seq[col + 1];
                // first position is unpredictable; start supervision at 1
                batch.weights[row * s + col] = if col == 0 { 0.0 } else { 1.0 };
            }
        }
        batch
    }
}

impl Task for MarkovLm {
    fn name(&self) -> &str {
        &self.name
    }

    fn train_batch(&mut self) -> Batch {
        self.gen_batch()
    }

    fn eval_batches(&self) -> &[Batch] {
        &self.eval
    }
}

// ---------------------------------------------------------------------------
// CopyTask / SortTask — seq2seq generation
// ---------------------------------------------------------------------------

/// `x₁…x_L SEP` → the model must reproduce `x₁…x_L` (E2E-NLG stand-in:
/// faithful surface realization of given content).
pub struct CopyTask {
    geom: TaskGeom,
    src_len: usize,
    rng: Pcg32,
    eval: Vec<Batch>,
    sorted: bool,
    name: String,
}

impl CopyTask {
    pub fn new(geom: TaskGeom, sorted: bool, seed: u64) -> Self {
        let src_len = (geom.s - 2) / 2;
        let mut t = CopyTask {
            geom,
            src_len,
            rng: Pcg32::new(seed, 303),
            eval: Vec::new(),
            sorted,
            name: if sorted { "sort" } else { "copy" }.into(),
        };
        t.eval = (0..4).map(|_| t.gen_batch()).collect();
        t
    }

    fn gen_batch(&mut self) -> Batch {
        let TaskGeom { vocab, b, s } = self.geom;
        let l = self.src_len;
        let mut batch = Batch::new(b, s);
        for row in 0..b {
            let mut src: Vec<i32> =
                (0..l).map(|_| (16 + self.rng.below(vocab - 16)) as i32).collect();
            let mut out = src.clone();
            if self.sorted {
                out.sort_unstable();
            }
            // layout: src … SEP out … (padding)
            for (col, &tok) in src.iter().enumerate() {
                batch.tokens[row * s + col] = tok;
            }
            batch.tokens[row * s + l] = SEP;
            for (j, &tok) in out.iter().enumerate() {
                let col = l + 1 + j;
                batch.tokens[row * s + col] = tok;
                // next-token supervision: predict out[j] at position col-1
                batch.targets[row * s + col - 1] = tok;
                batch.weights[row * s + col - 1] = 1.0;
            }
            let _ = &mut src;
        }
        batch
    }
}

impl Task for CopyTask {
    fn name(&self) -> &str {
        &self.name
    }

    fn train_batch(&mut self) -> Batch {
        self.gen_batch()
    }

    fn eval_batches(&self) -> &[Batch] {
        &self.eval
    }
}

/// Sorted-copy variant (harder: requires global order reasoning).
pub type SortTask = CopyTask;

// ---------------------------------------------------------------------------
// ModSumTask — compositional "reasoning" (GSM8K stand-in)
// ---------------------------------------------------------------------------

/// `a₁ a₂ … a_L SEP` → answer token `(Σ aᵢ) mod base`.  Requires combining
/// *all* input positions, which linear probes and low-capacity adapters
/// visibly fail at — the Table-4 "hard task" axis.
pub struct ModSumTask {
    geom: TaskGeom,
    n_terms: usize,
    base: usize,
    rng: Pcg32,
    eval: Vec<Batch>,
    name: String,
}

impl ModSumTask {
    pub fn new(geom: TaskGeom, n_terms: usize, base: usize, seed: u64) -> Self {
        assert!(16 + base <= geom.vocab);
        assert!(n_terms + 2 <= geom.s);
        let mut t = ModSumTask {
            geom,
            n_terms,
            base,
            rng: Pcg32::new(seed, 404),
            eval: Vec::new(),
            name: format!("modsum{n_terms}"),
        };
        t.eval = (0..4).map(|_| t.gen_batch()).collect();
        t
    }

    fn gen_batch(&mut self) -> Batch {
        let TaskGeom { b, s, .. } = self.geom;
        let mut batch = Batch::new(b, s);
        for row in 0..b {
            let mut sum = 0usize;
            for j in 0..self.n_terms {
                let digit = self.rng.below(self.base);
                sum += digit;
                batch.tokens[row * s + j] = (16 + digit) as i32;
            }
            batch.tokens[row * s + self.n_terms] = SEP;
            // pad rest with PAD; supervise only at the SEP position
            let col = self.n_terms;
            batch.targets[row * s + col] = (16 + (sum % self.base)) as i32;
            batch.weights[row * s + col] = 1.0;
            for j in self.n_terms + 1..s {
                batch.tokens[row * s + j] = PAD;
            }
        }
        batch
    }
}

impl Task for ModSumTask {
    fn name(&self) -> &str {
        &self.name
    }

    fn train_batch(&mut self) -> Batch {
        self.gen_batch()
    }

    fn eval_batches(&self) -> &[Batch] {
        &self.eval
    }
}

// ---------------------------------------------------------------------------
// InstructTask — multi-task with a task-id prefix (instruction-FT stand-in)
// ---------------------------------------------------------------------------

/// A mixture of sub-tasks, each announced by a distinct "instruction" token
/// at position 0 — the model must dispatch on it (Alpaca/MT-bench proxy;
/// quality = held-out masked accuracy per category, Figure 2 / Table 7).
pub struct InstructTask {
    subs: Vec<Box<dyn Task>>,
    /// Train batches emitted per sub-task (template-coverage statistic).
    emits: Vec<u64>,
    rng: Pcg32,
    eval: Vec<Batch>,
    name: String,
}

impl InstructTask {
    pub fn new(geom: TaskGeom, seed: u64) -> Self {
        let subs: Vec<Box<dyn Task>> = vec![
            Box::new(MotifClass::new(geom, 4, 0.0, seed ^ 1)),
            Box::new(CopyTask::new(geom, false, seed ^ 2)),
            Box::new(ModSumTask::new(geom, 4.min(geom.s - 2), 8, seed ^ 3)),
        ];
        let emits = vec![0u64; subs.len()];
        let mut t = InstructTask {
            subs,
            emits,
            rng: Pcg32::new(seed, 505),
            eval: Vec::new(),
            name: "instruct".into(),
        };
        t.eval = (0..6).map(|i| t.tagged_batch(i % t.subs.len())).collect();
        t
    }

    pub fn n_categories(&self) -> usize {
        self.subs.len()
    }

    fn tagged_batch(&mut self, which: usize) -> Batch {
        let mut b = self.subs[which].train_batch();
        // instruction token: 8 + sub-task id, stamped at position 0
        for row in 0..b.b {
            b.tokens[row * b.s] = 8 + which as i32;
            b.weights[row * b.s] = 0.0;
        }
        b
    }

    /// Eval batches for one category only (per-category scores, Table 7).
    pub fn eval_category(&self, which: usize) -> Vec<Batch> {
        self.eval
            .iter()
            .enumerate()
            .filter(|(i, _)| i % self.subs.len() == which)
            .map(|(_, b)| b.clone())
            .collect()
    }
}

impl Task for InstructTask {
    fn name(&self) -> &str {
        &self.name
    }

    fn train_batch(&mut self) -> Batch {
        let which = self.rng.below(self.subs.len());
        self.emits[which] += 1;
        self.tagged_batch(which)
    }

    fn eval_batches(&self) -> &[Batch] {
        &self.eval
    }

    fn coverage(&self) -> Option<Vec<(String, u64)>> {
        Some(
            self.subs
                .iter()
                .zip(&self.emits)
                .map(|(sub, &n)| (sub.name().to_string(), n))
                .collect(),
        )
    }
}

/// Build a task by name — the CLI/bench entry point.  Accepts every
/// [`TASK_NAMES`] entry plus the parameterized template grammar of
/// [`templates::TemplateSpec::parse`]; unknown names are a proper `Err`
/// listing the known families.  The stream comes wrapped in the
/// [`quality::ForgeStream`] dedup/diversity layer.
pub fn build_task(name: &str, geom: TaskGeom, seed: u64) -> Result<Box<dyn Task>> {
    let spec = templates::TemplateSpec::parse(name)?;
    let inner = spec.build(geom, seed)?;
    Ok(Box::new(quality::ForgeStream::new(inner, quality::DedupCfg::default())))
}

/// Historical task names `build_task` accepts (the forge grammar accepts
/// more — see [`templates::TemplateSpec::parse`]).
pub const TASK_NAMES: [&str; 14] = [
    "motif2", "motif4", "motif8", "motif16", "markovlm", "markovlm4", "copy", "sort", "modsum",
    "modsum6", "instruct", "bracket", "kvrecall", "reverse",
];

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> TaskGeom {
        TaskGeom::new(64, 4, 16)
    }

    fn check_batch_well_formed(b: &Batch, vocab: usize) {
        assert!(b.validate().is_ok());
        assert!(b.tokens.iter().all(|&t| (0..vocab as i32).contains(&t)), "tokens in vocab");
        assert!(b.targets.iter().all(|&t| (0..vocab as i32).contains(&t)));
        assert!(b.weights.iter().all(|&w| w == 0.0 || w == 1.0));
        assert!(b.weights.iter().any(|&w| w > 0.0), "some supervision");
    }

    #[test]
    fn all_tasks_emit_well_formed_batches() {
        for name in TASK_NAMES {
            let mut t = build_task(name, geom(), 7).unwrap();
            for _ in 0..3 {
                check_batch_well_formed(&t.train_batch(), 64);
            }
            assert!(!t.eval_batches().is_empty(), "{name} has eval data");
            for e in t.eval_batches() {
                check_batch_well_formed(e, 64);
            }
        }
    }

    #[test]
    fn unknown_task_name_is_a_listed_error() {
        let err = build_task("nope", geom(), 7).err().expect("unknown name must be Err");
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown task"), "{msg}");
        assert!(msg.contains("motif4"), "error lists known families: {msg}");
        assert!(msg.contains("mix:"), "error mentions the mixture grammar: {msg}");
    }

    #[test]
    fn tasks_are_deterministic_per_seed() {
        for name in ["motif4", "copy", "modsum", "markovlm"] {
            let mut a = build_task(name, geom(), 9).unwrap();
            let mut b = build_task(name, geom(), 9).unwrap();
            let (x, y) = (a.train_batch(), b.train_batch());
            assert_eq!(x.tokens, y.tokens, "{name}");
            assert_eq!(x.targets, y.targets);
        }
    }

    #[test]
    fn motif_class_answer_is_class_token() {
        let mut t = MotifClass::new(geom(), 4, 0.0, 3);
        let b = t.train_batch();
        for row in 0..b.b {
            let tgt = b.targets[row * b.s + b.s - 1];
            assert!((CLS_BASE..CLS_BASE + 4).contains(&tgt));
            assert_eq!(b.weights[row * b.s + b.s - 1], 1.0);
        }
    }

    #[test]
    fn copy_targets_align_with_source() {
        let mut t = CopyTask::new(geom(), false, 5);
        let b = t.train_batch();
        let l = (16 - 2) / 2;
        for row in 0..b.b {
            for j in 0..l {
                let src = b.tokens[row * b.s + j];
                let tgt = b.targets[row * b.s + l + j];
                assert_eq!(src, tgt, "copy semantics at j={j}");
            }
        }
    }

    #[test]
    fn sort_targets_are_sorted() {
        let mut t = CopyTask::new(geom(), true, 5);
        let b = t.train_batch();
        let l = (16 - 2) / 2;
        for row in 0..b.b {
            let outs: Vec<i32> = (0..l).map(|j| b.targets[row * b.s + l + j]).collect();
            let mut sorted = outs.clone();
            sorted.sort_unstable();
            assert_eq!(outs, sorted);
        }
    }

    #[test]
    fn modsum_answer_is_correct() {
        let mut t = ModSumTask::new(geom(), 4, 8, 5);
        let b = t.train_batch();
        for row in 0..b.b {
            let sum: i32 = (0..4).map(|j| b.tokens[row * b.s + j] - 16).sum();
            let tgt = b.targets[row * b.s + 4];
            assert_eq!(tgt, 16 + sum % 8);
        }
    }

    #[test]
    fn markov_lm_targets_are_next_tokens() {
        let mut t = MarkovLm::new(geom(), 2, 5);
        let b = t.train_batch();
        for row in 0..b.b {
            for col in 0..b.s - 1 {
                assert_eq!(b.targets[row * b.s + col], b.tokens[row * b.s + col + 1]);
            }
        }
    }

    #[test]
    fn instruct_task_stamps_category_token() {
        let mut t = InstructTask::new(geom(), 5);
        let b = t.train_batch();
        for row in 0..b.b {
            assert!((8..8 + t.n_categories() as i32).contains(&b.tokens[row * b.s]));
        }
        assert_eq!(t.eval_category(0).len() + t.eval_category(1).len() + t.eval_category(2).len(),
                   t.eval_batches().len());
    }
}
