//! Task forge: parameterized, seeded task templates (ISSUE 9 tentpole).
//!
//! The five hand-rolled presets in [`crate::data`] generalize into template
//! *families*: a [`TemplateSpec`] names a family plus its parameters, parses
//! from the same strings the CLI always accepted (`motif4`, `modsum6`, …) and
//! from new parameterized forms (`motif32`, `markovlm3`, `bracket4`,
//! `kvrecall6`, `reverse3`, `mix:motif4+copy`), and builds a `Box<dyn Task>`
//! whose stream is deterministic in `(template, geometry, seed)`.
//!
//! New families beyond the original five:
//!
//! | family | stand-in | task type |
//! |---|---|---|
//! | [`BracketTask`] | CoLA-style acceptability | balanced-bracket classification |
//! | [`KvRecallTask`] | closed-book QA / retrieval | key-value recall after `SEP` |
//! | [`ReverseTask`] | structured rewriting | reverse payload, ignore distractors |
//! | [`MixtureTask`] | multi-domain corpora | uniform mixture of plain families |
//!
//! Every template built through [`TemplateSpec::build`] (and therefore through
//! [`crate::data::build_task`]) is wrapped in a
//! [`crate::data::quality::ForgeStream`], which adds the dedup gate and the
//! per-stream diversity statistics recorded in `RunRecord`.

use anyhow::{bail, Result};

use super::{
    CopyTask, InstructTask, MarkovLm, ModSumTask, MotifClass, Task, TaskGeom, CLS_BASE, SEP,
};
use crate::backend::Batch;
use crate::rng::Pcg32;

/// The family × parameter space the forge knows how to instantiate.
#[derive(Debug, Clone, PartialEq)]
pub enum TemplateKind {
    /// Planted-motif classification (`motifN`).
    Motif { n_classes: usize, noise: f32 },
    /// Order-2 Markov language modelling (`markovlm`, `markovlmN`).
    Markov { branch: usize },
    /// Copy / sorted-copy seq2seq (`copy`, `sort`).
    Copy { sorted: bool },
    /// Modular-sum reasoning (`modsum`, `modsumN`).
    ModSum { n_terms: usize, base: usize },
    /// Instruction-prefixed multi-task mixture (`instruct`).
    Instruct,
    /// Balanced-bracket acceptability classification (`bracket`, `bracketN`).
    Bracket { pairs: usize },
    /// Key-value recall (`kvrecall`, `kvrecallN`).
    KvRecall { n_pairs: usize },
    /// Sequence reversal with planted distractors (`reverse`, `reverseN`).
    Reverse { distractors: usize },
    /// Uniform mixture over plain families (`mix:a+b+…`).
    Mixture { parts: Vec<TemplateSpec> },
}

/// A named, parameterized task template; `parse` then `build`.
#[derive(Debug, Clone, PartialEq)]
pub struct TemplateSpec {
    /// The canonical name the spec was parsed from (used for mixture labels).
    pub name: String,
    pub kind: TemplateKind,
}

/// Noise level the historical presets used: `motif8` → 0.05, `motif16` → 0.1.
fn motif_noise(n_classes: usize) -> f32 {
    if n_classes >= 16 {
        0.1
    } else if n_classes >= 8 {
        0.05
    } else {
        0.0
    }
}

impl TemplateSpec {
    /// Parse a template name.  Accepts every historical `TASK_NAMES` entry
    /// unchanged plus the parameterized forms documented in `docs/TASKS.md`.
    pub fn parse(name: &str) -> Result<TemplateSpec> {
        let kind = Self::parse_kind(name)?;
        Ok(TemplateSpec { name: name.to_string(), kind })
    }

    fn parse_kind(name: &str) -> Result<TemplateKind> {
        if let Some(rest) = name.strip_prefix("mix:") {
            let mut parts = Vec::new();
            for part in rest.split('+') {
                if part.is_empty() {
                    bail!("empty component in mixture template {name:?}");
                }
                let spec = TemplateSpec::parse(part)?;
                if matches!(spec.kind, TemplateKind::Mixture { .. }) {
                    bail!("mixture components must be plain families, got {part:?} in {name:?}");
                }
                parts.push(spec);
            }
            if parts.len() < 2 {
                bail!("mixture template {name:?} needs at least two '+'-separated families");
            }
            return Ok(TemplateKind::Mixture { parts });
        }
        // Bare family names with their historical default parameters.
        match name {
            "copy" => return Ok(TemplateKind::Copy { sorted: false }),
            "sort" => return Ok(TemplateKind::Copy { sorted: true }),
            "instruct" => return Ok(TemplateKind::Instruct),
            "markovlm" => return Ok(TemplateKind::Markov { branch: 2 }),
            "modsum" => return Ok(TemplateKind::ModSum { n_terms: 4, base: 8 }),
            "bracket" => return Ok(TemplateKind::Bracket { pairs: 2 }),
            "kvrecall" => return Ok(TemplateKind::KvRecall { n_pairs: 4 }),
            "reverse" => return Ok(TemplateKind::Reverse { distractors: 2 }),
            _ => {}
        }
        // Parameterized forms: family prefix + decimal parameter.
        for (prefix, lo, hi) in [
            ("motif", 2, 62),
            ("markovlm", 1, 64),
            ("modsum", 1, 48),
            ("bracket", 1, 8),
            ("kvrecall", 1, 8),
            ("reverse", 0, 64),
        ] {
            let Some(digits) = name.strip_prefix(prefix) else { continue };
            let Ok(n) = digits.parse::<usize>() else {
                bail!("bad parameter {digits:?} in template {name:?} (want {prefix}<N>)");
            };
            if !(lo..=hi).contains(&n) {
                bail!("parameter {n} out of range [{lo}, {hi}] for template family {prefix:?}");
            }
            return Ok(match prefix {
                "motif" => TemplateKind::Motif { n_classes: n, noise: motif_noise(n) },
                "markovlm" => TemplateKind::Markov { branch: n },
                "modsum" => {
                    // Historical presets: modsum → (4, 8), modsum6 → (6, 10).
                    TemplateKind::ModSum { n_terms: n, base: if n <= 4 { 8 } else { 10 } }
                }
                "bracket" => TemplateKind::Bracket { pairs: n },
                "kvrecall" => TemplateKind::KvRecall { n_pairs: n },
                _ => TemplateKind::Reverse { distractors: n },
            });
        }
        bail!(
            "unknown task {name:?}; known families: {:?}, parameterized forms \
             motif<N>/markovlm<N>/modsum<N>/bracket<N>/kvrecall<N>/reverse<N>, \
             and mixtures like mix:motif4+copy",
            crate::data::TASK_NAMES
        )
    }

    /// Instantiate the template for a geometry and seed, validating that the
    /// parameters fit (`Err`, not panic, so the CLI can surface it).
    pub fn build(&self, geom: TaskGeom, seed: u64) -> Result<Box<dyn Task>> {
        let v = geom.vocab;
        let s = geom.s;
        Ok(match &self.kind {
            TemplateKind::Motif { n_classes, noise } => {
                let n = *n_classes;
                if CLS_BASE as usize + n >= v {
                    bail!("motif{n}: needs vocab > {} for the class tokens, got {v}", 2 + n);
                }
                if 16 + n >= v {
                    bail!("motif{n}: needs vocab > {} for the motif alphabet, got {v}", 16 + n);
                }
                Box::new(MotifClass::new(geom, n, *noise, seed))
            }
            TemplateKind::Markov { branch } => Box::new(MarkovLm::new(geom, *branch, seed)),
            TemplateKind::Copy { sorted } => {
                if s < 4 {
                    bail!("copy/sort: needs seq_len >= 4, got {s}");
                }
                Box::new(CopyTask::new(geom, *sorted, seed))
            }
            TemplateKind::ModSum { n_terms, base } => {
                if *n_terms + 2 > s {
                    bail!("modsum{n_terms}: needs seq_len >= {}, got {s}", n_terms + 2);
                }
                if 16 + *base > v {
                    bail!("modsum{n_terms}: needs vocab >= {}, got {v}", 16 + base);
                }
                Box::new(ModSumTask::new(geom, *n_terms, *base, seed))
            }
            TemplateKind::Instruct => Box::new(InstructTask::new(geom, seed)),
            TemplateKind::Bracket { pairs } => Box::new(BracketTask::new(geom, *pairs, seed)?),
            TemplateKind::KvRecall { n_pairs } => Box::new(KvRecallTask::new(geom, *n_pairs, seed)?),
            TemplateKind::Reverse { distractors } => {
                Box::new(ReverseTask::new(geom, *distractors, seed)?)
            }
            TemplateKind::Mixture { parts } => {
                let mut subs: Vec<Box<dyn Task>> = Vec::with_capacity(parts.len());
                for (i, p) in parts.iter().enumerate() {
                    // Decorrelate component streams the way InstructTask does.
                    subs.push(p.build(geom, seed ^ ((i as u64 + 1) << 8))?);
                }
                Box::new(MixtureTask::new(self.name.clone(), subs, seed))
            }
        })
    }
}

/// The default family set the `evalmatrix` scoreboard runs every strategy
/// against: all five historical families plus the three new ones and one
/// mixture (ISSUE 9 acceptance requires ≥ 8).
pub const MATRIX_FAMILIES: [&str; 11] = [
    "motif4",
    "motif8",
    "markovlm",
    "copy",
    "sort",
    "modsum",
    "instruct",
    "bracket",
    "kvrecall",
    "reverse",
    "mix:motif4+copy+modsum",
];

// ---------------------------------------------------------------------------
// BracketTask — balanced-bracket acceptability classification
// ---------------------------------------------------------------------------

/// Token id of the opening bracket of pair type `t` (pairs live at 16+2t /
/// 17+2t, above the control/class/instruction ranges).
fn bracket_open(t: usize) -> i32 {
    (16 + 2 * t) as i32
}

fn bracket_close(t: usize) -> i32 {
    (17 + 2 * t) as i32
}

/// Whether `body` is a balanced bracket sequence over `pairs` pair types
/// (openers at `16+2t`, closers at `17+2t`).  Non-bracket tokens make the
/// sequence unbalanced.  Exposed so tests can recheck emitted labels.
pub fn is_balanced(body: &[i32], pairs: usize) -> bool {
    let hi = (16 + 2 * pairs) as i32;
    let mut stack: Vec<i32> = Vec::new();
    for &tok in body {
        if !(16..hi).contains(&tok) {
            return false;
        }
        if (tok - 16) % 2 == 0 {
            stack.push(tok);
        } else if stack.pop() != Some(tok - 1) {
            return false;
        }
    }
    stack.is_empty()
}

/// Binary acceptability: is the bracket string balanced?  Class 0 = balanced,
/// class 1 = corrupted.  Answer at the final position, MotifClass-style (SEP
/// input, class-token target, weight 1).
pub struct BracketTask {
    geom: TaskGeom,
    pairs: usize,
    /// Even-length bracket body occupying columns `0..body`.
    body: usize,
    rng: Pcg32,
    eval: Vec<Batch>,
    name: String,
}

impl BracketTask {
    pub fn new(geom: TaskGeom, pairs: usize, seed: u64) -> Result<Self> {
        if 16 + 2 * pairs > geom.vocab {
            bail!("bracket{pairs}: needs vocab >= {}, got {}", 16 + 2 * pairs, geom.vocab);
        }
        let body = (geom.s.saturating_sub(2)) & !1;
        if body < 2 {
            bail!("bracket{pairs}: needs seq_len >= 4, got {}", geom.s);
        }
        let mut t = BracketTask {
            geom,
            pairs,
            body,
            rng: Pcg32::new(seed, 707),
            eval: Vec::new(),
            name: format!("bracket{pairs}"),
        };
        t.eval = (0..4).map(|_| t.gen_batch()).collect();
        Ok(t)
    }

    /// Stack-walk generator: always emits a balanced string of length `body`.
    fn gen_balanced(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.body);
        let mut stack: Vec<usize> = Vec::new();
        while out.len() < self.body {
            let remaining = self.body - out.len();
            let must_close = stack.len() == remaining;
            let must_open = stack.is_empty();
            if must_open || (!must_close && self.rng.below(2) == 0) {
                let t = self.rng.below(self.pairs);
                stack.push(t);
                out.push(bracket_open(t));
            } else {
                let t = stack.pop().unwrap_or(0);
                out.push(bracket_close(t));
            }
        }
        out
    }

    fn gen_batch(&mut self) -> Batch {
        let TaskGeom { b, s, .. } = self.geom;
        let mut batch = Batch::new(b, s);
        for row in 0..b {
            let balanced = self.rng.below(2) == 0;
            let mut body = self.gen_balanced();
            if !balanced {
                // Corrupt one position with a random bracket token; if the
                // result is (rarely) still balanced, force a leading closer.
                let i = self.rng.below(self.body);
                let t = self.rng.below(self.pairs);
                body[i] = if self.rng.below(2) == 0 { bracket_open(t) } else { bracket_close(t) };
                if is_balanced(&body, self.pairs) {
                    body[0] = bracket_close(0);
                }
            }
            for (col, &tok) in body.iter().enumerate() {
                batch.tokens[row * s + col] = tok;
            }
            batch.tokens[row * s + s - 1] = SEP;
            batch.targets[row * s + s - 1] = CLS_BASE + i32::from(!balanced);
            batch.weights[row * s + s - 1] = 1.0;
        }
        batch
    }
}

impl Task for BracketTask {
    fn name(&self) -> &str {
        &self.name
    }

    fn train_batch(&mut self) -> Batch {
        self.gen_batch()
    }

    fn eval_batches(&self) -> &[Batch] {
        &self.eval
    }
}

// ---------------------------------------------------------------------------
// KvRecallTask — key-value recall after SEP
// ---------------------------------------------------------------------------

/// `k₁ v₁ … k_n v_n SEP k_q` → the model must emit `v_q` at the query
/// position.  Keys come from a small fixed alphabet (16..24) and are distinct
/// within a row; values come from the open vocab (24..V).
pub struct KvRecallTask {
    geom: TaskGeom,
    n_pairs: usize,
    rng: Pcg32,
    eval: Vec<Batch>,
    name: String,
}

/// Key alphabet: 8 tokens starting at 16.
const KV_KEYS: usize = 8;
const KV_VAL_LO: usize = 16 + KV_KEYS;

impl KvRecallTask {
    pub fn new(geom: TaskGeom, n_pairs: usize, seed: u64) -> Result<Self> {
        if !(1..=KV_KEYS).contains(&n_pairs) {
            bail!("kvrecall{n_pairs}: pair count must be in 1..={KV_KEYS}");
        }
        if 2 * n_pairs + 2 > geom.s {
            bail!("kvrecall{n_pairs}: needs seq_len >= {}, got {}", 2 * n_pairs + 2, geom.s);
        }
        if geom.vocab <= KV_VAL_LO {
            bail!("kvrecall{n_pairs}: needs vocab > {KV_VAL_LO}, got {}", geom.vocab);
        }
        let mut t = KvRecallTask {
            geom,
            n_pairs,
            rng: Pcg32::new(seed, 808),
            eval: Vec::new(),
            name: format!("kvrecall{n_pairs}"),
        };
        t.eval = (0..4).map(|_| t.gen_batch()).collect();
        Ok(t)
    }

    fn gen_batch(&mut self) -> Batch {
        let TaskGeom { vocab, b, s } = self.geom;
        let n = self.n_pairs;
        let mut batch = Batch::new(b, s);
        for row in 0..b {
            let mut keys: Vec<usize> = (0..KV_KEYS).collect();
            self.rng.shuffle(&mut keys);
            let mut vals = vec![0i32; n];
            for (j, val) in vals.iter_mut().enumerate() {
                let k = (16 + keys[j]) as i32;
                *val = (KV_VAL_LO + self.rng.below(vocab - KV_VAL_LO)) as i32;
                batch.tokens[row * s + 2 * j] = k;
                batch.tokens[row * s + 2 * j + 1] = *val;
            }
            batch.tokens[row * s + 2 * n] = SEP;
            let q = self.rng.below(n);
            let col = 2 * n + 1;
            batch.tokens[row * s + col] = (16 + keys[q]) as i32;
            batch.targets[row * s + col] = vals[q];
            batch.weights[row * s + col] = 1.0;
        }
        batch
    }
}

impl Task for KvRecallTask {
    fn name(&self) -> &str {
        &self.name
    }

    fn train_batch(&mut self) -> Batch {
        self.gen_batch()
    }

    fn eval_batches(&self) -> &[Batch] {
        &self.eval
    }
}

// ---------------------------------------------------------------------------
// ReverseTask — sequence reversal with planted distractors
// ---------------------------------------------------------------------------

/// The input half holds a payload interleaved with `distractors` tokens from
/// a reserved alphabet (16..24); after `SEP` the model must emit the payload
/// *reversed*, skipping the distractors (CopyTask-style next-token
/// supervision).
pub struct ReverseTask {
    geom: TaskGeom,
    /// Input-half length (payload + distractor slots).
    src_len: usize,
    distractors: usize,
    rng: Pcg32,
    eval: Vec<Batch>,
    name: String,
}

const REV_DISTRACT: usize = 8;
const REV_PAYLOAD_LO: usize = 16 + REV_DISTRACT;

impl ReverseTask {
    pub fn new(geom: TaskGeom, distractors: usize, seed: u64) -> Result<Self> {
        let src_len = (geom.s.saturating_sub(2)) / 2;
        if distractors + 1 > src_len {
            bail!(
                "reverse{distractors}: {distractors} distractors leave no payload in an \
                 input half of {src_len} (seq_len {})",
                geom.s
            );
        }
        if geom.vocab <= REV_PAYLOAD_LO {
            bail!("reverse{distractors}: needs vocab > {REV_PAYLOAD_LO}, got {}", geom.vocab);
        }
        let mut t = ReverseTask {
            geom,
            src_len,
            distractors,
            rng: Pcg32::new(seed, 909),
            eval: Vec::new(),
            name: format!("reverse{distractors}"),
        };
        t.eval = (0..4).map(|_| t.gen_batch()).collect();
        Ok(t)
    }

    fn gen_batch(&mut self) -> Batch {
        let TaskGeom { vocab, b, s } = self.geom;
        let l = self.src_len;
        let mut batch = Batch::new(b, s);
        for row in 0..b {
            let mut slots: Vec<usize> = (0..l).collect();
            self.rng.shuffle(&mut slots);
            let mut is_distractor = vec![false; l];
            for &sl in &slots[..self.distractors] {
                is_distractor[sl] = true;
            }
            let mut payload: Vec<i32> = Vec::with_capacity(l - self.distractors);
            for (col, &d) in is_distractor.iter().enumerate() {
                let tok = if d {
                    (16 + self.rng.below(REV_DISTRACT)) as i32
                } else {
                    let t = (REV_PAYLOAD_LO + self.rng.below(vocab - REV_PAYLOAD_LO)) as i32;
                    payload.push(t);
                    t
                };
                batch.tokens[row * s + col] = tok;
            }
            batch.tokens[row * s + l] = SEP;
            let p = payload.len();
            for j in 0..p {
                let tok = payload[p - 1 - j];
                let col = l + 1 + j;
                batch.tokens[row * s + col] = tok;
                batch.targets[row * s + col - 1] = tok;
                batch.weights[row * s + col - 1] = 1.0;
            }
        }
        batch
    }
}

impl Task for ReverseTask {
    fn name(&self) -> &str {
        &self.name
    }

    fn train_batch(&mut self) -> Batch {
        self.gen_batch()
    }

    fn eval_batches(&self) -> &[Batch] {
        &self.eval
    }
}

// ---------------------------------------------------------------------------
// MixtureTask — uniform mixture over plain families
// ---------------------------------------------------------------------------

/// Multi-task stream: each train batch comes from one component, chosen
/// uniformly by the mixture's own RNG stream; the eval set is the
/// concatenation of the components' eval sets.  Tracks per-component emit
/// counts so the forge can report template coverage.
pub struct MixtureTask {
    subs: Vec<Box<dyn Task>>,
    emits: Vec<u64>,
    rng: Pcg32,
    eval: Vec<Batch>,
    name: String,
}

impl MixtureTask {
    pub fn new(name: String, subs: Vec<Box<dyn Task>>, seed: u64) -> Self {
        let mut eval = Vec::new();
        for sub in &subs {
            eval.extend(sub.eval_batches().iter().cloned());
        }
        let emits = vec![0u64; subs.len()];
        MixtureTask { subs, emits, rng: Pcg32::new(seed, 606), eval, name }
    }
}

impl Task for MixtureTask {
    fn name(&self) -> &str {
        &self.name
    }

    fn train_batch(&mut self) -> Batch {
        let which = self.rng.below(self.subs.len());
        self.emits[which] += 1;
        self.subs[which].train_batch()
    }

    fn eval_batches(&self) -> &[Batch] {
        &self.eval
    }

    fn coverage(&self) -> Option<Vec<(String, u64)>> {
        Some(
            self.subs
                .iter()
                .zip(&self.emits)
                .map(|(sub, &n)| (sub.name().to_string(), n))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> TaskGeom {
        TaskGeom::new(64, 4, 16)
    }

    #[test]
    fn parse_preserves_historical_presets() {
        let cases = [
            ("motif2", TemplateKind::Motif { n_classes: 2, noise: 0.0 }),
            ("motif4", TemplateKind::Motif { n_classes: 4, noise: 0.0 }),
            ("motif8", TemplateKind::Motif { n_classes: 8, noise: 0.05 }),
            ("motif16", TemplateKind::Motif { n_classes: 16, noise: 0.1 }),
            ("markovlm", TemplateKind::Markov { branch: 2 }),
            ("markovlm4", TemplateKind::Markov { branch: 4 }),
            ("copy", TemplateKind::Copy { sorted: false }),
            ("sort", TemplateKind::Copy { sorted: true }),
            ("modsum", TemplateKind::ModSum { n_terms: 4, base: 8 }),
            ("modsum6", TemplateKind::ModSum { n_terms: 6, base: 10 }),
            ("instruct", TemplateKind::Instruct),
        ];
        for (name, want) in cases {
            assert_eq!(TemplateSpec::parse(name).unwrap().kind, want, "{name}");
        }
    }

    #[test]
    fn parse_new_families_and_mixtures() {
        assert_eq!(
            TemplateSpec::parse("bracket").unwrap().kind,
            TemplateKind::Bracket { pairs: 2 }
        );
        assert_eq!(
            TemplateSpec::parse("kvrecall6").unwrap().kind,
            TemplateKind::KvRecall { n_pairs: 6 }
        );
        assert_eq!(
            TemplateSpec::parse("reverse3").unwrap().kind,
            TemplateKind::Reverse { distractors: 3 }
        );
        let mix = TemplateSpec::parse("mix:motif4+copy").unwrap();
        match mix.kind {
            TemplateKind::Mixture { ref parts } => {
                assert_eq!(parts.len(), 2);
                assert_eq!(parts[0].name, "motif4");
            }
            other => panic!("expected mixture, got {other:?}"),
        }
    }

    #[test]
    fn parse_rejects_malformed_names() {
        for bad in ["", "motif", "motif1", "motifx", "bracket9", "mix:", "mix:motif4",
            "mix:motif4+", "mix:motif4+mix:copy+sort", "kvrecall0", "nope"]
        {
            assert!(TemplateSpec::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn build_rejects_impossible_geometry() {
        // 14 kv pairs can never fit in seq_len 16 — and can't even parse (cap 8).
        assert!(TemplateSpec::parse("kvrecall14").is_err());
        // 7 distractors leave no payload in an input half of 7.
        let spec = TemplateSpec::parse("reverse7").unwrap();
        assert!(spec.build(geom(), 3).is_err());
        // motif with more classes than the vocab can host.
        let spec = TemplateSpec::parse("motif60").unwrap();
        assert!(spec.build(geom(), 3).is_err());
    }

    #[test]
    fn bracket_labels_match_balance() {
        let mut t = BracketTask::new(geom(), 2, 11).unwrap();
        let mut saw = [false; 2];
        for _ in 0..8 {
            let b = t.train_batch();
            for row in 0..b.b {
                let body: Vec<i32> = (0..t.body).map(|c| b.tokens[row * b.s + c]).collect();
                let class = (b.targets[row * b.s + b.s - 1] - CLS_BASE) as usize;
                assert_eq!(is_balanced(&body, 2), class == 0);
                saw[class] = true;
            }
        }
        assert!(saw[0] && saw[1], "both classes appear");
    }

    #[test]
    fn kvrecall_answer_is_the_queried_value() {
        let n = 4;
        let mut t = KvRecallTask::new(geom(), n, 11).unwrap();
        let b = t.train_batch();
        for row in 0..b.b {
            let base = row * b.s;
            assert_eq!(b.tokens[base + 2 * n], SEP);
            let query = b.tokens[base + 2 * n + 1];
            let answer = b.targets[base + 2 * n + 1];
            assert_eq!(b.weights[base + 2 * n + 1], 1.0);
            let mut found = 0;
            for j in 0..n {
                if b.tokens[base + 2 * j] == query {
                    assert_eq!(b.tokens[base + 2 * j + 1], answer, "value of the queried key");
                    found += 1;
                }
            }
            assert_eq!(found, 1, "keys are distinct and the query names one of them");
        }
    }

    #[test]
    fn reverse_targets_are_reversed_payload() {
        let d = 2;
        let mut t = ReverseTask::new(geom(), d, 11).unwrap();
        let b = t.train_batch();
        let l = (16 - 2) / 2;
        for row in 0..b.b {
            let base = row * b.s;
            assert_eq!(b.tokens[base + l], SEP);
            let payload: Vec<i32> = (0..l)
                .map(|c| b.tokens[base + c])
                .filter(|&tok| tok >= REV_PAYLOAD_LO as i32)
                .collect();
            assert_eq!(payload.len(), l - d);
            let out: Vec<i32> = (l..l + payload.len()).map(|c| b.targets[base + c]).collect();
            let mut rev = payload.clone();
            rev.reverse();
            assert_eq!(out, rev, "supervised output is the reversed payload");
        }
    }

    #[test]
    fn mixture_tracks_component_coverage() {
        let spec = TemplateSpec::parse("mix:motif4+copy+modsum").unwrap();
        let mut t = spec.build(geom(), 5).unwrap();
        for _ in 0..30 {
            let _ = t.train_batch();
        }
        let cov = t.coverage().expect("mixture reports coverage");
        assert_eq!(cov.len(), 3);
        let mut total = 0u64;
        for &(_, n) in &cov {
            assert!(n > 0, "every component drawn at least once in 30 batches");
            total += n;
        }
        assert_eq!(total, 30);
    }

    #[test]
    fn matrix_families_all_parse_and_build() {
        assert!(MATRIX_FAMILIES.len() >= 8);
        for name in MATRIX_FAMILIES {
            let spec = TemplateSpec::parse(name).unwrap();
            let mut t = spec.build(geom(), 7).unwrap();
            let b = t.train_batch();
            assert!(b.validate().is_ok(), "{name}");
            assert!(!t.eval_batches().is_empty(), "{name}");
        }
    }
}
