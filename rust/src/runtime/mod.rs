//! PJRT runtime (the `pjrt` cargo feature): load AOT artifacts, compile
//! once, execute from the L3 loop — one implementation of
//! [`ExecBackend`].
//!
//! Pattern follows `/opt/xla-example/load_hlo`: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  Executables are compiled lazily and
//! cached for the life of the process (one compile per artifact, ever).
//!
//! Interchange is HLO *text*; all artifacts were lowered with
//! `return_tuple=True`, so each execution returns a single tuple literal
//! that we decompose into `(loss, ncorrect, grads…)`.
//!
//! The default build ships the vendored API-stub `xla` crate (so this
//! module stays type-checked offline); point `rust/vendor/xla` at a real
//! PJRT binding to actually execute artifacts.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::backend::{Batch, ExecBackend, GradSink, Manifest, RuntimeStats, StepOutput, StreamOutput};
use crate::tensor::{Tensor, TensorSet};

/// Device-resident copy of one parameter tensor, valid for a specific
/// `(TensorSet lineage, version)` — the §Perf optimization that stops every
/// step from re-uploading the (mostly frozen) model.
struct CachedBuf {
    key: (u64, u64),
    buf: xla::PjRtBuffer,
}

/// PJRT-backed execution engine for one artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// name -> cached device buffer (keyed by TensorSet lineage+version).
    param_bufs: HashMap<String, CachedBuf>,
    pub stats: RuntimeStats,
}

impl Runtime {
    /// Load `artifacts/<preset>` (manifest + lazily-compiled HLO).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("PjRtClient::cpu")?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            exes: HashMap::new(),
            param_bufs: HashMap::new(),
            stats: RuntimeStats::default(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact's executable.
    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let info = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&info.path);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.stats.compiles += 1;
        self.stats.compile_secs += t0.elapsed().as_secs_f64();
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Pre-compile a set of artifacts (amortize startup, e.g. all HiFT units).
    pub fn warmup(&mut self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    /// Execute `artifact` with `params` (must match the artifact's input
    /// order prefix) and a batch; decompose `(loss, ncorrect, grads…)`.
    pub fn run(&mut self, artifact: &str, params: &TensorSet, batch: &Batch) -> Result<StepOutput> {
        batch.validate()?;
        self.ensure_compiled(artifact)?;
        let info = self.manifest.artifact(artifact)?;
        let n_inputs = info.inputs.len();
        if params.len() + 3 != n_inputs {
            bail!(
                "artifact {artifact} expects {} inputs, got {} params + 3 batch",
                n_inputs,
                params.len()
            );
        }
        let n_grads = info.outputs.len().saturating_sub(2);
        let grad_shapes: Vec<Vec<usize>> = info.outputs[2..]
            .iter()
            .map(|out_name| {
                params
                    .get(out_name)
                    .map(|t| t.shape.clone())
                    .with_context(|| format!("grad output {out_name} not among params"))
            })
            .collect::<Result<Vec<_>>>()?;

        // Marshal inputs.  Parameters go through the device-buffer cache:
        // a tensor is re-uploaded only when its (lineage, version) changed —
        // under HiFT that's one layer group per step, so h2d traffic is
        // O(group) instead of O(model) (EXPERIMENTS.md §Perf).
        for (i, t) in params.tensors.iter().enumerate() {
            let key = params.cache_key(i);
            let name = &params.names[i];
            let hit = self.param_bufs.get(name).map(|c| c.key == key).unwrap_or(false);
            if hit {
                self.stats.cache_hits += 1;
            } else {
                let buf = self.client.buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)?;
                self.param_bufs.insert(name.clone(), CachedBuf { key, buf });
                self.stats.h2d_bytes += t.bytes() as u64;
                self.stats.cache_misses += 1;
            }
        }
        let bdims = [batch.b, batch.s];
        let tok_buf = self.client.buffer_from_host_buffer::<i32>(&batch.tokens, &bdims, None)?;
        let tgt_buf = self.client.buffer_from_host_buffer::<i32>(&batch.targets, &bdims, None)?;
        let w_buf = self.client.buffer_from_host_buffer::<f32>(&batch.weights, &bdims, None)?;
        self.stats.h2d_bytes += batch.h2d_bytes() as u64;

        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(n_inputs);
        for name in &params.names {
            args.push(&self.param_bufs[name].buf);
        }
        args.push(&tok_buf);
        args.push(&tgt_buf);
        args.push(&w_buf);

        let exe = self
            .exes
            .get(artifact)
            .with_context(|| format!("artifact {artifact} not compiled before execution"))?;
        let t0 = Instant::now();
        let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0].to_literal_sync()?;
        let exec_time = t0.elapsed();
        self.stats.executions += 1;
        self.stats.exec_secs += exec_time.as_secs_f64();

        let mut parts = result.to_tuple()?;
        if parts.len() != info.outputs.len() {
            bail!("artifact {artifact}: expected {} outputs, got {}", info.outputs.len(), parts.len());
        }
        let loss: f32 = parts[0].to_vec::<f32>()?[0];
        let ncorrect: f32 = parts[1].to_vec::<f32>()?[0];
        let mut grads = Vec::with_capacity(n_grads);
        for (i, lit) in parts.drain(..).enumerate().skip(2) {
            let shape = &grad_shapes[i - 2];
            let data = lit.to_vec::<f32>()?;
            self.stats.d2h_bytes += (data.len() * 4) as u64;
            grads.push(Tensor::from_vec(data, shape));
        }
        Ok(StepOutput { loss, ncorrect, grads, exec_time })
    }

    /// Load the initial parameters for `variant` from the .bin files.
    pub fn load_params(&self, variant: &str) -> Result<TensorSet> {
        let vinfo = self.manifest.variant(variant)?;
        let base_bytes = std::fs::read(self.dir.join("params.bin"))
            .with_context(|| "reading params.bin")?;
        let adapter_bytes = if variant != "base" {
            std::fs::read(self.dir.join(format!("adapters_{variant}.bin")))
                .with_context(|| format!("reading adapters_{variant}.bin"))?
        } else {
            Vec::new()
        };
        let mut set = TensorSet::new();
        for (i, p) in vinfo.params.iter().enumerate() {
            let bytes: &[u8] = if i < vinfo.n_base_params { &base_bytes } else { &adapter_bytes };
            set.push(p.name.clone(), Tensor::from_le_bytes(&bytes[p.offset..], &p.shape));
        }
        Ok(set)
    }

    /// Grad-artifact name for one layer unit of the base model.
    pub fn unit_artifact(u: usize) -> String {
        crate::backend::unit_artifact(u)
    }
}

impl ExecBackend for Runtime {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self) -> String {
        Runtime::platform(self)
    }

    fn manifest(&self) -> &Manifest {
        Runtime::manifest(self)
    }

    /// PJRT adapts to the streaming seam with a post-execute drain: the
    /// artifact's tuple output is decomposed as usual, then each gradient
    /// is fed to the sink in artifact output order.  Unlike the native
    /// backend, the whole tuple is materialized first, so the residency
    /// peak recorded here is the collected size — honest accounting for a
    /// backend whose execution model cannot interleave.
    fn run_streamed(
        &mut self,
        artifact: &str,
        params: &mut TensorSet,
        batch: &Batch,
        sink: &mut dyn GradSink,
    ) -> Result<StreamOutput> {
        let out = Runtime::run(self, artifact, params, batch)?;
        let names: Vec<String> = self.manifest.artifact(artifact)?.outputs[2..].to_vec();
        let resident: u64 = out.grads.iter().map(|g| g.bytes() as u64).sum();
        self.stats.peak_grad_resident_bytes =
            self.stats.peak_grad_resident_bytes.max(resident + sink.resident_bytes());
        for (slot, (name, g)) in names.iter().zip(out.grads).enumerate() {
            sink.grad(slot, name, g, params)?;
        }
        sink.finish(params)?;
        Ok(StreamOutput { loss: out.loss, ncorrect: out.ncorrect, exec_time: out.exec_time })
    }

    fn run(&mut self, artifact: &str, params: &mut TensorSet, batch: &Batch) -> Result<StepOutput> {
        Runtime::run(self, artifact, params, batch)
    }

    fn note_grad_residency(&mut self, bytes: u64) {
        self.stats.peak_grad_resident_bytes = self.stats.peak_grad_resident_bytes.max(bytes);
    }

    fn reset_run_peaks(&mut self) {
        self.stats.peak_grad_resident_bytes = 0;
    }

    fn load_params(&self, variant: &str) -> Result<TensorSet> {
        Runtime::load_params(self, variant)
    }

    fn warmup(&mut self, artifacts: &[&str]) -> Result<()> {
        Runtime::warmup(self, artifacts)
    }

    fn stats(&self) -> &RuntimeStats {
        &self.stats
    }
}
