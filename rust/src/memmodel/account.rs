//! Device-memory accounting: the analytic model behind Tables 5 & 8–12 and
//! Figure 6.
//!
//! Four components, following the paper's Appendix B / ZeRO decomposition:
//!
//! * **#Para** — model weights resident for the forward pass.  fp32: `4N`;
//!   mixed: `6N` (fp32 master + fp16 working copy — why the paper observes
//!   mixed precision *costing* memory on big models, §G.2); MixedHi (the
//!   paper's HiFT-adapted mixed precision): `2N + 4·T` — only the active
//!   group's fp32 master is on device.
//! * **#Gra** — `4·T` where `T` = trainable parameters this step (full
//!   model under FPFT, the *peak group* under HiFT, adapters under PEFT).
//! * **#Sta** — optimizer state over the trainable set, computed per
//!   tensor so Adafactor's factored `(rows+cols)` state is exact.
//! * **Residual** — activations + buffers, modelled with the standard
//!   transformer activation formula (Korthikanti et al., 2022):
//!   `L·(34·b·s·d + 5·b·h·s²)` fp16-bytes per layer, ×2 for fp32, with two
//!   *calibrated* global factors documented in EXPERIMENTS.md:
//!   `MIXED_ACT_FACTOR = 0.75` (paper-measured mixed/fp32 residual ratio,
//!   range 0.71–0.86) and `HIFT_RETENTION = 0.75` (paper-measured
//!   HiFT/FPFT residual ratio, range 0.67–0.85 — HiFT truncates the
//!   autograd graph below the active group).  Under an
//!   activation-checkpointing policy ([`account_ckpt`]) the layer term is
//!   replaced by the structural `act_ckpt` model — stored boundary
//!   residual streams + segment scratch + one recomputing layer — instead
//!   of the flat calibrated factor.
//!
//! #Para/#Gra/#Sta/#PGS are exact arithmetic (validated against every row
//! of Tables 8–12 in `rust/tests/memmodel_paper.rs`); Residual/Total are a
//! model and validated in band.

use super::arch::{Arch, PShape};
use crate::backend::ActCkpt;
use crate::optim::OptimKind;
use crate::tensor::half::Precision;

pub const MIB: f64 = 1024.0 * 1024.0;
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Calibrated residual-state factors (see module docs).
pub const MIXED_ACT_FACTOR: f64 = 0.75;
pub const HIFT_RETENTION: f64 = 0.75;
/// Additional residual shrink under the §G.2 adapted mixed precision
/// (paper-measured MixedHi/mixed residual ratios 0.66–0.85, excl. GPT-Neo).
pub const MIXEDHI_ACT_EXTRA: f64 = 0.72;

/// Precision regime (#Dtype column of Tables 8–12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    Fp32,
    Mixed,
    /// The paper's HiFT-adapted mixed precision (§G.2): per-step fp32
    /// master weights only for the active group.
    MixedHi,
}

impl Dtype {
    pub fn name(&self) -> &'static str {
        match self {
            Dtype::Fp32 => "fp32",
            Dtype::Mixed => "mixed",
            Dtype::MixedHi => "MixedHi",
        }
    }
}

/// Fine-tuning method (#FType column).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    Fpft,
    Hift { m: usize },
    /// PEFT with `adapter_params` trainable parameters added on top of the
    /// frozen model (LoRA r=8, IA3, prefix… — Table 5).
    Peft { adapter_params: usize },
}

/// Workload geometry.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub batch: usize,
    pub seq: usize,
}

/// One row of a memory table (all bytes).
#[derive(Debug, Clone, Copy)]
pub struct MemRow {
    /// Peak per-step trainable parameter count.
    pub trainable: usize,
    pub para: f64,
    pub gra: f64,
    /// Peak gradient residency under *streamed* execution (the GradSink
    /// seam): backward fuses each tensor's update and drops its gradient
    /// immediately, so only the largest single trainable tensor is ever
    /// resident — vs `gra`'s full collected set.  For PEFT the adapter set
    /// is small and unstructured here, so the collected bound is used.
    pub gra_streamed: f64,
    pub sta: f64,
    /// para + gra + sta.
    pub pgs: f64,
    pub residual: f64,
    /// The activation (layer) part of `residual` — the `act_ckpt` term.
    /// Under [`ActCkpt::None`] this is the flat calibrated
    /// `layers × per-layer × retention` model; under a recompute policy it
    /// is boundary residual streams + segment scratch + one layer's
    /// transient working set, replacing the calibrated factor.
    pub act_ckpt: f64,
    pub total: f64,
}

impl MemRow {
    pub fn para_mib(&self) -> f64 {
        self.para / MIB
    }
    pub fn gra_mib(&self) -> f64 {
        self.gra / MIB
    }
    pub fn gra_streamed_mib(&self) -> f64 {
        self.gra_streamed / MIB
    }
    pub fn sta_mib(&self) -> f64 {
        self.sta / MIB
    }
    pub fn pgs_gib(&self) -> f64 {
        self.pgs / GIB
    }
    pub fn residual_gib(&self) -> f64 {
        self.residual / GIB
    }
    pub fn act_ckpt_gib(&self) -> f64 {
        self.act_ckpt / GIB
    }
    pub fn total_gib(&self) -> f64 {
        self.total / GIB
    }
}

/// Optimizer-state bytes for a set of tensors (exact, per tensor).
fn state_bytes(shapes: &[&[usize]], opt: OptimKind) -> f64 {
    let mut total = 0f64;
    for shape in shapes {
        let n: usize = shape.iter().product();
        total += match opt {
            OptimKind::AdamW => 8.0 * n as f64,
            OptimKind::Sgdm | OptimKind::Adagrad => 4.0 * n as f64,
            OptimKind::Sgd => 0.0,
            OptimKind::Adafactor => match shape.last() {
                // Factored moments for matrices: one row vector + one
                // column vector per tensor.
                Some(&cols) if shape.len() >= 2 && cols > 0 => {
                    let rows = n / cols;
                    4.0 * (rows + cols) as f64
                }
                _ => 4.0 * n as f64,
            },
        };
    }
    total
}


/// Activation ("residual state") model in bytes.  Returns
/// `(total residual, activation layer part)` — the latter is the
/// `act_ckpt` term surfaced in [`MemRow`].
///
/// `fused` models the fused streaming-softmax attention path: the
/// `5·b·h·s·s_kv` probability slice of the per-layer footprint is never
/// materialized (forward streams per-row, backward recomputes rows), so
/// it drops out of both the retained-graph and the recompute-scratch
/// branches.  The public `account*` entry points keep `fused = false` —
/// the calibrated paper-table model — and [`fused_attn_savings`] exposes
/// the delta as its own structural term.
fn residual_bytes(
    arch: &Arch,
    w: Workload,
    dtype: Dtype,
    method: Method,
    policy: ActCkpt,
    fused: bool,
) -> (f64, f64) {
    let (b, s, d, h, l) = (
        w.batch as f64,
        w.seq as f64,
        arch.d_model as f64,
        arch.n_heads as f64,
        arch.n_layers as f64,
    );
    // fp16 bytes per layer (Korthikanti et al.); ×2 at fp32.  Models with
    // alternating local attention (GPT-Neo) pay the quadratic term on only
    // half their layers, with the other half capped at the window.
    let s_kv = match arch.local_attn_window() {
        Some(w) => (s + s.min(w as f64)) / 2.0,
        None => s,
    };
    let probs_fp16 = 5.0 * b * h * s * s_kv;
    let per_layer_fp16 = 34.0 * b * s * d + if fused { 0.0 } else { probs_fp16 };
    let extras = 4.0 * b * s * (arch.vocab as f64).min(8.0 * d) + 12.0 * b * s * d;
    let act_factor = match dtype {
        Dtype::Fp32 => 1.0,
        Dtype::Mixed => MIXED_ACT_FACTOR,
        Dtype::MixedHi => MIXED_ACT_FACTOR * MIXEDHI_ACT_EXTRA,
    };
    let act = match policy.seg_len(arch.n_layers) {
        None => {
            let retention = match method {
                Method::Hift { .. } => HIFT_RETENTION,
                // PEFT keeps the full graph alive (adapters hang off every
                // layer) and adds the adapter forward burden (paper §4.2).
                Method::Peft { .. } => 1.05,
                Method::Fpft => 1.0,
            };
            2.0 * per_layer_fp16 * l * act_factor * retention
        }
        Some(k) => {
            // Recompute-on-backward replaces the flat calibrated factor:
            // stored boundary residual streams (⌈L/k⌉) + chained segment
            // scratch (≤ k) + one layer's full working set while it is
            // being recomputed.
            let n_bound = (arch.n_layers.div_ceil(k) + k.min(arch.n_layers)) as f64;
            let boundary_fp32 = 4.0 * b * s * d;
            (n_bound * boundary_fp32 + 2.0 * per_layer_fp16) * act_factor
        }
    };
    (act + extras, act)
}

/// [`account`] under an activation-checkpointing policy: the residual's
/// activation term switches from the flat calibrated model to the
/// boundary + recompute-scratch model (the `act_ckpt` column of the
/// Table 5 / Figure 6 exhibits).
pub fn account_ckpt(
    arch: &Arch,
    opt: OptimKind,
    dtype: Dtype,
    method: Method,
    w: Workload,
    policy: ActCkpt,
) -> MemRow {
    let n = arch.total_params() as f64;
    let params = arch.params();

    // Trainable set (peak per step) as tensor shapes; `largest` is the
    // biggest single trainable tensor (streamed gradient residency).
    let (trainable, sta, largest): (usize, f64, usize) = match method {
        Method::Fpft => {
            let shapes: Vec<&[usize]> = params.iter().map(|p| p.shape.as_slice()).collect();
            let largest = params.iter().map(|p| p.numel()).max().unwrap_or(0);
            (arch.total_params(), state_bytes(&shapes, opt), largest)
        }
        Method::Hift { m } => {
            // Peak group = contiguous unit chunk with most parameters.
            let n_units = arch.n_units();
            let mut best = (0usize, 0usize); // (start unit, params)
            for start in (0..n_units).step_by(m) {
                let end = (start + m).min(n_units);
                let count: usize = params
                    .iter()
                    .filter(|p| p.unit >= start && p.unit < end)
                    .map(|p| p.numel())
                    .sum();
                if count > best.1 {
                    best = (start, count);
                }
            }
            let shapes: Vec<&[usize]> = params
                .iter()
                .filter(|p| p.unit >= best.0 && p.unit < best.0 + m)
                .map(|p| p.shape.as_slice())
                .collect();
            let largest = params
                .iter()
                .filter(|p| p.unit >= best.0 && p.unit < best.0 + m)
                .map(|p| p.numel())
                .max()
                .unwrap_or(0);
            (best.1, state_bytes(&shapes, opt), largest)
        }
        Method::Peft { adapter_params } => {
            // Adapters are overwhelmingly small matrices; model state on the
            // dense bound (exact enough at this magnitude).
            let sta = match opt {
                OptimKind::AdamW => 8.0 * adapter_params as f64,
                OptimKind::Sgdm | OptimKind::Adagrad => 4.0 * adapter_params as f64,
                OptimKind::Sgd => 0.0,
                OptimKind::Adafactor => 0.1 * 4.0 * adapter_params as f64,
            };
            // No per-tensor structure for adapters here: use the collected
            // bound (they are tiny either way).
            (adapter_params, sta, adapter_params)
        }
    };

    let para = match (dtype, method) {
        (Dtype::Fp32, _) => 4.0 * n,
        (Dtype::Mixed, Method::Peft { adapter_params }) => {
            // frozen base needs no fp32 master; adapters do.
            2.0 * n + 6.0 * adapter_params as f64
        }
        (Dtype::Mixed, _) => 6.0 * n,
        (Dtype::MixedHi, _) => 2.0 * n + 4.0 * trainable as f64,
    };
    let extra_para = match method {
        // PEFT adds the adapter weights themselves to the forward.
        Method::Peft { adapter_params } if dtype == Dtype::Fp32 => 4.0 * adapter_params as f64,
        _ => 0.0,
    };
    let para = para + extra_para;
    let gra = 4.0 * trainable as f64;
    let gra_streamed = 4.0 * largest as f64;
    let pgs = para + gra + sta;
    let (residual, act_ckpt) = residual_bytes(arch, w, dtype, method, policy, false);
    let total = pgs + residual;
    MemRow { trainable, para, gra, gra_streamed, sta, pgs, residual, act_ckpt, total }
}

/// Compute one memory-table row (no activation checkpointing).
pub fn account(arch: &Arch, opt: OptimKind, dtype: Dtype, method: Method, w: Workload) -> MemRow {
    account_ckpt(arch, opt, dtype, method, w, ActCkpt::None)
}

/// Activation-storage multiplier of a *native compute precision*
/// (`--precision f32|bf16|f16`): the retained activation buffers are
/// physically half-width under the half modes (`tensor/half.rs::PrecBuf`),
/// so the activation term — and the recompute scratch it includes under a
/// checkpointing policy — halves.
pub fn precision_act_factor(prec: Precision) -> f64 {
    prec.act_bytes_per_elem() as f64 / 4.0
}

/// [`account_ckpt`] under a native compute precision: the activation part
/// of the residual term (`act_ckpt`, which under a recompute policy is
/// boundaries + segment scratch + one working layer) scales by
/// [`precision_act_factor`].  The `extras` slice of the residual — the
/// softmax/loss head, which the native backend keeps in f32 as is standard
/// for mixed precision — and the #Para/#Gra/#Sta terms are untouched:
/// parameter *masters*, gradients-as-updated and optimizer state stay f32
/// (the `Dtype` axis continues to model the paper's own mixed-precision
/// weight-copy regimes; this knob is orthogonal to it).
pub fn account_prec(
    arch: &Arch,
    opt: OptimKind,
    dtype: Dtype,
    method: Method,
    w: Workload,
    policy: ActCkpt,
    prec: Precision,
) -> MemRow {
    let mut r = account_ckpt(arch, opt, dtype, method, w, policy);
    let f = precision_act_factor(prec);
    if f != 1.0 {
        let scaled = r.act_ckpt * f;
        r.residual += scaled - r.act_ckpt;
        r.act_ckpt = scaled;
        r.total = r.pgs + r.residual;
    }
    r
}

/// Additional device bytes of the data-parallel worker topology
/// (`--workers n`): zero at `n <= 1`, and a *constant* (n-independent)
/// overhead once the topology is on, because batch-split parallelism
/// replicates almost nothing:
///
/// * **one parameter snapshot** — all workers share a single read-only
///   clone of the parameter set (4 bytes/elem) while the sink updates the
///   live one behind them; this term does not scale with `n`.
/// * **reducer partials** — the coordinator holds one emission site's
///   per-batch-row partials while folding (`B ×` the largest weight
///   tensor, whichever of the per-row-partial sites or the `[B·T, D]`
///   embedding-row gradient is bigger).  The partial grain is the batch
///   row, so this too is independent of `n`.
///
/// Activations do **not** scale ×n either: each of the `n` active workers
/// walks `B/n` batch rows, so the workers' retained graphs *sum* to the
/// serial batch's activation bytes.  #Gra/#Sta are untouched — the
/// reduce-then-emit seam hands the sink one gradient at a time
/// (`gra_streamed` stays max-single-tensor) and optimizer state never
/// replicates.  Params/grads/state staying N-independent while only
/// snapshot + partials are added is the HiFT asymmetry at multi-core.
pub fn workers_overhead(arch: &Arch, w: Workload, workers: usize) -> f64 {
    if workers <= 1 {
        return 0.0;
    }
    let snapshot = 4.0 * arch.total_params() as f64;
    // Largest per-row-partial site: the biggest non-embedding tensor (the
    // head projection or a layer weight).  Embedding gradients travel as
    // `[B·T, D]` activation rows instead of per-row `[V, D]` partials.
    let largest_site =
        arch.params().iter().filter(|p| p.unit > 0).map(PShape::numel).max().unwrap_or(0);
    let emb_rows = w.batch * w.seq * arch.d_model;
    let partials = 4.0 * w.batch as f64 * largest_site as f64;
    let partials = partials.max(4.0 * emb_rows as f64);
    snapshot + partials
}

/// [`account_prec`] under data-parallel sharded execution: the
/// [`workers_overhead`] term folds into the residual (it is working
/// memory, not params/grads/state — those are exactly serial).
#[allow(clippy::too_many_arguments)]
pub fn account_workers(
    arch: &Arch,
    opt: OptimKind,
    dtype: Dtype,
    method: Method,
    w: Workload,
    policy: ActCkpt,
    prec: Precision,
    workers: usize,
) -> MemRow {
    let mut r = account_prec(arch, opt, dtype, method, w, policy, prec);
    let extra = workers_overhead(arch, w, workers);
    r.residual += extra;
    r.total += extra;
    r
}

/// The Appendix-B closed form: ζ_hift/ζ_fpft = (k+3)/(4k) for AdamW @ fp32
/// over params+grads+states with *uniform* layer sizes.
pub fn appendix_b_ratio(k: usize) -> f64 {
    (k as f64 + 3.0) / (4.0 * k as f64)
}

/// Exact bytes of the native backend's materialized attention-probability
/// caches: `L·B·H·T²` elements at the compute precision's activation
/// width.  This is precisely what the fused streaming-softmax kernel path
/// stops retaining, so under [`ActCkpt::None`] the measured
/// `peak_act_resident_bytes` of a naive-kernel run minus a fused run must
/// equal this value *exactly* (asserted in `tests/kernels.rs`).
pub fn native_probs_bytes(
    n_layers: usize,
    batch: usize,
    heads: usize,
    t: usize,
    prec: Precision,
) -> u64 {
    (n_layers * batch * heads * t * t) as u64 * prec.act_bytes_per_elem() as u64
}

/// Analytic residual-memory saving (bytes) of the fused streaming-softmax
/// attention path: the calibrated residual model with the `5·b·h·s·s_kv`
/// probability slice materialized minus the same model with it fused away.
/// Grows quadratically in sequence length, which is the point of the
/// technique.
pub fn fused_attn_savings(
    arch: &Arch,
    w: Workload,
    dtype: Dtype,
    method: Method,
    policy: ActCkpt,
) -> f64 {
    let (materialized, _) = residual_bytes(arch, w, dtype, method, policy, false);
    let (fused, _) = residual_bytes(arch, w, dtype, method, policy, true);
    materialized - fused
}

// ---------------------------------------------------------------------------
// Host paging tier bounds (enforced, not just modeled)
// ---------------------------------------------------------------------------
//
// With `--offload host` the runtime *measures* these quantities instead of
// inferring them: the pager's OffloadLedger (the same counter object the
// optimizer-state paging uses — one source of truth, there is no separate
// modeled-offload path anymore) only credits arena residency when a page is
// physically admitted.  `tests/offload.rs` asserts the measured peaks stay
// within these structural bounds on the native presets; at paper scale the
// bounds below are what the `bench offload` exhibit prints.

/// Enforced device-residency bound for parameter masters under host paging:
/// the active group (pinned through its update) plus `slots` transient
/// walk/prefetch unit buffers, in f32 bytes.  The plain walk holds one
/// non-group unit at a time and the double buffer adds one more in flight
/// (`slots = 2`); under an activation-checkpointing policy the backward
/// recompute chain transiently co-holds a second walk unit, so combine
/// `--act-ckpt` with `--offload` at `slots = 3`.
pub fn paged_param_bound(arch: &Arch, m: usize, slots: usize) -> f64 {
    let group = arch.peak_group_params(m);
    let unit = arch.unit_sizes().into_iter().max().unwrap_or(0);
    4.0 * (group + slots * unit) as f64
}

/// Schedule-aware byte-level form of [`paged_param_bound`]: the enforced
/// residency bound computed from the *actual* per-step groups a scheduler
/// plans rather than the contiguous index chunks `peak_group_params`
/// assumes (Top2Down/Random groups are chunks of a permuted unit order, so
/// the chunked formula does not bound them).
///
/// `schedule` is one `(group, staged)` pair of unit-index lists per step —
/// `staged` empty in sync mode (staged units become arena-resident once the
/// walk ensures them and survive the end-of-run eviction, so a prefetch-mode
/// step co-holds the next group too).  `walk_slots` is the number of
/// transient non-group walk units co-held at the peak: 1 for the plain
/// walk, 2 under an activation-checkpointing policy (the outer backward
/// unit plus one unit of the recompute chain).  This is the bound
/// `plancheck` proves every lattice point's simulated peak stays under, and
/// `tests/offload.rs` asserts the measured peaks against the same shape.
pub fn paged_param_bound_bytes(
    unit_bytes: &[u64],
    schedule: &[(Vec<usize>, Vec<usize>)],
    walk_slots: usize,
) -> u64 {
    let sum = |units: &[usize]| units.iter().map(|&u| unit_bytes.get(u).copied().unwrap_or(0));
    let max_unit = unit_bytes.iter().copied().max().unwrap_or(0);
    let per_step = schedule.iter().map(|(group, staged)| {
        // A unit both active and staged is one residency, not two.
        let staged_extra: u64 =
            sum(staged).zip(staged).filter(|(_, u)| !group.contains(u)).map(|(b, _)| b).sum();
        sum(group).sum::<u64>() + staged_extra
    });
    per_step.max().unwrap_or(0) + walk_slots as u64 * max_unit
}

/// Host-tier footprint bound of the paged masters: everything but the
/// resident group, at the pool's storage width (2 bytes/elem for the f16
/// lossy mode, 4 otherwise).
pub fn paged_host_bound(arch: &Arch, m: usize, f16: bool) -> f64 {
    let parked = arch.total_params().saturating_sub(arch.peak_group_params(m));
    (if f16 { 2.0 } else { 4.0 }) * parked as f64
}

/// Savings of HiFT over FPFT in total memory (%).
pub fn savings_pct(arch: &Arch, opt: OptimKind, dtype: Dtype, w: Workload, m: usize) -> f64 {
    let base_dtype = if dtype == Dtype::MixedHi { Dtype::Mixed } else { dtype };
    let f = account(arch, opt, base_dtype, Method::Fpft, w);
    let h = account(arch, opt, dtype, Method::Hift { m }, w);
    (1.0 - h.total / f.total) * 100.0
}

#[cfg(test)]
mod tests {
    use super::super::arch::by_name;
    use super::*;
    use crate::proptest::{prop_assert, run};

    const W512: Workload = Workload { batch: 8, seq: 512 };

    #[test]
    fn fused_attn_savings_are_positive_and_quadratic_in_seq() {
        let arch = by_name("roberta-base").unwrap();
        let w = |seq| Workload { batch: 8, seq };
        let m = Method::Hift { m: 1 };
        let s1 = fused_attn_savings(&arch, w(128), Dtype::Fp32, m, ActCkpt::None);
        let s2 = fused_attn_savings(&arch, w(256), Dtype::Fp32, m, ActCkpt::None);
        assert!(s1 > 0.0, "fused attention must save memory, got {s1}");
        // Doubling seq quadruples the probs term but the per-layer base
        // only doubles — the saving must grow superlinearly.
        assert!(s2 > 3.0 * s1, "probs term is quadratic in seq: {s1} -> {s2}");
        // The public account() stays on the calibrated materialized model.
        let row = account(&arch, OptimKind::AdamW, Dtype::Fp32, m, w(128));
        let fused_row_residual = row.residual - s1;
        assert!(fused_row_residual > 0.0);
    }

    #[test]
    fn native_probs_bytes_is_the_exact_cache_size() {
        // tiny preset: 2 layers, 2 heads; batch 4, t 16 -> 2*4*2*16*16 el.
        assert_eq!(native_probs_bytes(2, 4, 2, 16, Precision::F32), 4096 * 4);
        assert_eq!(native_probs_bytes(2, 4, 2, 16, Precision::Bf16), 4096 * 2);
    }

    #[test]
    fn roberta_base_adamw_fp32_matches_table8_pgs() {
        let a = by_name("roberta-base").unwrap();
        let f = account(&a, OptimKind::AdamW, Dtype::Fp32, Method::Fpft, W512);
        // Paper: #Para 475.49, #Gra 475.49, #Sta 950.98 MiB, #PGS 1.86 GiB.
        assert!((f.para_mib() - 475.49).abs() < 3.0, "para {:.2}", f.para_mib());
        assert!((f.gra_mib() - 475.49).abs() < 3.0);
        assert!((f.sta_mib() - 950.98).abs() < 6.0);
        assert!((f.pgs_gib() - 1.86).abs() < 0.02, "pgs {:.3}", f.pgs_gib());

        let h = account(&a, OptimKind::AdamW, Dtype::Fp32, Method::Hift { m: 1 }, W512);
        // Paper HiFT: #Gra 148.77, #Sta 297.54 MiB, #PGS 0.90 GiB.
        assert!((h.gra_mib() - 148.77).abs() < 2.0, "gra {:.2}", h.gra_mib());
        assert!((h.sta_mib() - 297.54).abs() < 4.0);
        assert!((h.pgs_gib() - 0.90).abs() < 0.02, "pgs {:.3}", h.pgs_gib());
    }

    #[test]
    fn mixed_precision_para_is_6_bytes_per_param() {
        let a = by_name("roberta-base").unwrap();
        let f = account(&a, OptimKind::AdamW, Dtype::Mixed, Method::Fpft, W512);
        // Paper: 713.25 MiB.
        assert!((f.para_mib() - 713.25).abs() < 5.0, "para {:.2}", f.para_mib());
    }

    #[test]
    fn mixedhi_para_matches_table8() {
        let a = by_name("roberta-base").unwrap();
        let h = account(&a, OptimKind::AdamW, Dtype::MixedHi, Method::Hift { m: 1 }, W512);
        // Paper: 386.52 MiB = 2 bytes × 124.65M + 4 bytes × 39.0M.
        assert!((h.para_mib() - 386.52).abs() < 4.0, "para {:.2}", h.para_mib());
    }

    #[test]
    fn adafactor_state_is_tiny_and_matches_table8() {
        let a = by_name("roberta-base").unwrap();
        let f = account(&a, OptimKind::Adafactor, Dtype::Fp32, Method::Fpft, W512);
        // Paper: 0.98 MiB (FPFT), 0.19 MiB (HiFT peak group).
        assert!(f.sta_mib() < 1.6, "adafactor FPFT state {:.2} MiB", f.sta_mib());
        let h = account(&a, OptimKind::Adafactor, Dtype::Fp32, Method::Hift { m: 1 }, W512);
        assert!((h.sta_mib() - 0.19).abs() < 0.12, "adafactor HiFT state {:.2}", h.sta_mib());
    }

    #[test]
    fn sgd_state_is_zero_sgdm_equals_grads() {
        let a = by_name("roberta-large").unwrap();
        let s = account(&a, OptimKind::Sgd, Dtype::Fp32, Method::Fpft, W512);
        assert_eq!(s.sta, 0.0);
        let m = account(&a, OptimKind::Sgdm, Dtype::Fp32, Method::Fpft, W512);
        assert!((m.sta - m.gra).abs() < 1.0, "SGDM state == gradient bytes");
    }

    #[test]
    fn llama7b_fp32_adamw_totals_in_band() {
        // Paper Table 12 (b=6, s=512): FPFT #PGS 100.41 GiB, HiFT 27.36 GiB.
        let a = by_name("llama-7b").unwrap();
        let w = Workload { batch: 6, seq: 512 };
        let f = account(&a, OptimKind::AdamW, Dtype::Fp32, Method::Fpft, w);
        assert!((f.pgs_gib() - 100.41).abs() < 1.0, "fpft pgs {:.2}", f.pgs_gib());
        let h = account(&a, OptimKind::AdamW, Dtype::Fp32, Method::Hift { m: 1 }, w);
        assert!((h.pgs_gib() - 27.36).abs() < 0.6, "hift pgs {:.2}", h.pgs_gib());
    }

    #[test]
    fn headline_7b_fits_24g_with_mixedhi_batch1() {
        // Abstract: "HiFT supports FPFT of 7B models on 24G devices".
        // Paper §G.2: ~16.87 GiB at batch 1.
        let a = by_name("llama-7b").unwrap();
        let w = Workload { batch: 1, seq: 512 };
        let h = account(&a, OptimKind::AdamW, Dtype::MixedHi, Method::Hift { m: 1 }, w);
        assert!(h.total_gib() < 24.0, "total {:.2} GiB must fit 24G", h.total_gib());
        assert!((h.total_gib() - 16.87).abs() < 3.0, "total {:.2} vs paper 16.87", h.total_gib());
    }

    #[test]
    fn streamed_grad_term_is_one_tensor_not_the_set() {
        let a = by_name("roberta-base").unwrap();
        let f = account(&a, OptimKind::AdamW, Dtype::Fp32, Method::Fpft, W512);
        let largest = a.params().iter().map(|p| p.numel()).max().unwrap();
        assert_eq!(f.gra_streamed, 4.0 * largest as f64, "FPFT streamed = largest tensor");
        assert!(f.gra_streamed < f.gra, "streamed residency ≪ collected set");

        let h = account(&a, OptimKind::AdamW, Dtype::Fp32, Method::Hift { m: 2 }, W512);
        assert!(h.gra_streamed <= h.gra, "HiFT streamed bounded by the group");
        assert!(h.gra_streamed <= f.gra_streamed, "group's largest ≤ model's largest");
        assert!(h.gra_streamed > 0.0);
    }

    #[test]
    fn act_ckpt_shrinks_residual_and_is_monotone() {
        let a = by_name("llama-7b").unwrap();
        let w = Workload { batch: 6, seq: 512 };
        let hift = Method::Hift { m: 1 };
        let none = account(&a, OptimKind::AdamW, Dtype::Fp32, hift, w);
        let ek2 = account_ckpt(&a, OptimKind::AdamW, Dtype::Fp32, hift, w, ActCkpt::EveryK(2));
        let sq = account_ckpt(&a, OptimKind::AdamW, Dtype::Fp32, hift, w, ActCkpt::Sqrt);
        assert!(
            none.act_ckpt > ek2.act_ckpt && ek2.act_ckpt > sq.act_ckpt,
            "act term must be monotone: none {:.2} ≥ every_k(2) {:.2} ≥ sqrt {:.2} GiB",
            none.act_ckpt_gib(),
            ek2.act_ckpt_gib(),
            sq.act_ckpt_gib()
        );
        assert_eq!(none.pgs, sq.pgs, "checkpointing only changes the residual term");
        assert!(sq.total < none.total);
        assert!(
            none.act_ckpt / sq.act_ckpt > 4.0,
            "recompute slashes the activation term at 7B scale: {:.2} vs {:.2} GiB",
            none.act_ckpt_gib(),
            sq.act_ckpt_gib()
        );
    }

    #[test]
    fn compute_precision_halves_the_activation_term_only() {
        let a = by_name("llama-7b").unwrap();
        let w = Workload { batch: 6, seq: 512 };
        let hift = Method::Hift { m: 1 };
        for policy in [ActCkpt::None, ActCkpt::Sqrt] {
            let f32_row =
                account_prec(&a, OptimKind::AdamW, Dtype::Fp32, hift, w, policy, Precision::F32);
            let ref_row = account_ckpt(&a, OptimKind::AdamW, Dtype::Fp32, hift, w, policy);
            assert_eq!(f32_row.act_ckpt, ref_row.act_ckpt, "f32 knob is the identity");
            assert_eq!(f32_row.total, ref_row.total);
            for prec in [Precision::Bf16, Precision::F16] {
                let h = account_prec(&a, OptimKind::AdamW, Dtype::Fp32, hift, w, policy, prec);
                assert!(
                    (h.act_ckpt - 0.5 * ref_row.act_ckpt).abs() < 1.0,
                    "{prec:?}: activation term must halve ({:.2} vs {:.2} GiB)",
                    h.act_ckpt_gib(),
                    ref_row.act_ckpt_gib()
                );
                assert_eq!(h.pgs, ref_row.pgs, "masters/grads/state stay f32");
                assert!(h.residual < ref_row.residual && h.total < ref_row.total);
                // extras (the f32 loss head) are preserved, so the
                // residual shrinks by exactly the activation half.
                let extras = ref_row.residual - ref_row.act_ckpt;
                assert!((h.residual - (h.act_ckpt + extras)).abs() < 1.0);
            }
        }
        assert_eq!(precision_act_factor(Precision::F32), 1.0);
        assert_eq!(precision_act_factor(Precision::Bf16), 0.5);
        assert_eq!(precision_act_factor(Precision::F16), 0.5);
    }

    #[test]
    fn hift_always_cheaper_than_fpft() {
        for arch in super::super::arch::zoo() {
            for opt in OptimKind::ALL {
                for dt in [Dtype::Fp32, Dtype::Mixed] {
                    let f = account(&arch, opt, dt, Method::Fpft, W512);
                    let h = account(&arch, opt, dt, Method::Hift { m: 1 }, W512);
                    assert!(
                        h.total < f.total,
                        "{} {opt:?} {dt:?}: hift {:.2} >= fpft {:.2}",
                        arch.name,
                        h.total_gib(),
                        f.total_gib()
                    );
                }
            }
        }
    }

    #[test]
    fn savings_bands_match_paper_ranges() {
        // Paper §4.2 mixed-precision savings bands (MixedHi vs mixed FPFT):
        // RoBERTa-base 44.82–53.69%, RoBERTa-large 48.04–56.60%,
        // GPT-2-large 48.20–54.27%, GPT-Neo 28.99–50.69%, LLaMA 65.31–76.65%.
        let cases = [
            ("roberta-base", 35.0, 65.0),
            ("roberta-large", 38.0, 68.0),
            ("gpt2-large", 38.0, 66.0),
            ("gpt-neo-2.7b", 20.0, 75.0), // paper band 28.99-50.69 rests on its anomalous
            // MixedHi residual measurement (larger than mixed, Table 11); our
            // structural model cannot reproduce that inversion.
            ("llama-7b", 50.0, 85.0),
        ];
        for (name, lo, hi) in cases {
            let a = by_name(name).unwrap();
            let w = if name == "llama-7b" { Workload { batch: 6, seq: 512 } } else { W512 };
            let s = savings_pct(&a, OptimKind::AdamW, Dtype::MixedHi, w, 1);
            assert!((lo..=hi).contains(&s), "{name}: savings {s:.1}% outside [{lo},{hi}]");
        }
    }

    #[test]
    fn paged_bounds_are_structurally_sane() {
        for arch in super::super::arch::zoo() {
            let total = 4.0 * arch.total_params() as f64;
            // m=1, one transfer slot: the tightest bound (the sync-paging
            // regime) must beat keeping every master resident.  The margin
            // shrinks for embedding-dominated models (RoBERTa's peak unit
            // is ~31% of the model), so strict inequality is the claim.
            let tight = paged_param_bound(&arch, 1, 1);
            assert!(tight > 0.0, "{}", arch.name);
            if arch.n_units() > 6 {
                assert!(
                    tight < total,
                    "{}: bound {:.2} GiB must beat all-resident {:.2} GiB",
                    arch.name,
                    tight / GIB,
                    total / GIB
                );
            }
            // Deep decoders are where paging pays: the bound collapses.
            if arch.name == "llama-7b" {
                assert!(tight < 0.1 * total, "llama-7b: {:.3} of resident", tight / total);
            }
            // More slots / bigger groups only grow the bound; the whole
            // model as one group (plus no slots) is exactly all-resident.
            assert!(paged_param_bound(&arch, 1, 2) > tight, "{}", arch.name);
            assert!(paged_param_bound(&arch, 2, 1) >= tight, "{}", arch.name);
            assert_eq!(paged_param_bound(&arch, arch.n_units(), 0), total, "{}", arch.name);

            for m in [1usize, 2, 4] {
                let host_f32 = paged_host_bound(&arch, m, false);
                let host_f16 = paged_host_bound(&arch, m, true);
                assert!((host_f16 - host_f32 / 2.0).abs() < 1.0, "f16 halves the host tier");
                assert!(host_f32 <= total, "host tier holds at most the non-group remainder");
            }
            // m = all units: nothing is parked.
            assert_eq!(paged_host_bound(&arch, arch.n_units(), false), 0.0, "{}", arch.name);
        }
    }

    #[test]
    fn workers_overhead_is_flat_in_n_and_leaves_pgs_alone() {
        let a = by_name("roberta-base").unwrap();
        assert_eq!(workers_overhead(&a, W512, 0), 0.0);
        assert_eq!(workers_overhead(&a, W512, 1), 0.0, "serial pays nothing");
        let o2 = workers_overhead(&a, W512, 2);
        assert!(o2 > 0.0, "the topology costs a snapshot + partials");
        // Batch-split: the overhead is a step function of the topology
        // being on, not a ×N activation blow-up.
        assert_eq!(o2, workers_overhead(&a, W512, 8));
        // One snapshot is the floor.
        assert!(o2 >= 4.0 * a.total_params() as f64);

        let m = Method::Hift { m: 1 };
        let serial =
            account_prec(&a, OptimKind::AdamW, Dtype::Fp32, m, W512, ActCkpt::None, Precision::F32);
        let par = account_workers(
            &a,
            OptimKind::AdamW,
            Dtype::Fp32,
            m,
            W512,
            ActCkpt::None,
            Precision::F32,
            4,
        );
        // Params/grads/state are exactly serial — the HiFT asymmetry.
        assert_eq!(par.pgs, serial.pgs);
        assert_eq!(par.gra_streamed, serial.gra_streamed);
        assert_eq!(par.sta, serial.sta);
        assert_eq!(par.residual, serial.residual + o2);
        assert_eq!(par.total, serial.total + o2);
    }

    #[test]
    fn prop_appendix_b_identity_on_uniform_model() {
        // For a hypothetical model with k equal groups, the PGS ratio must
        // equal (k+3)/4k exactly (AdamW @ fp32).
        run(50, |g| {
            let k = g.usize_in(1, 64);
            let unit = 1_000_000f64; // params per group
            let n = k as f64 * unit;
            let fpft = 4.0 * n + 4.0 * n + 8.0 * n; // para+gra+sta
            let hift = 4.0 * n + 4.0 * unit + 8.0 * unit;
            let ratio = hift / fpft;
            prop_assert(
                (ratio - appendix_b_ratio(k)).abs() < 1e-12,
                format!("k={k}: {ratio} vs {}", appendix_b_ratio(k)),
            )
        });
    }

    #[test]
    fn prop_hift_memory_monotone_in_m() {
        run(40, |g| {
            let arch = by_name("roberta-base").unwrap();
            let m1 = g.usize_in(1, 14);
            let m2 = g.usize_in(m1, 14);
            let a1 = account(&arch, OptimKind::AdamW, Dtype::Fp32, Method::Hift { m: m1 }, W512);
            let a2 = account(&arch, OptimKind::AdamW, Dtype::Fp32, Method::Hift { m: m2 }, W512);
            prop_assert(a1.pgs <= a2.pgs + 1.0, format!("m={m1} vs m={m2}"))
        });
    }

    #[test]
    fn peft_memory_between_hift_and_fpft_at_scale() {
        // Table 5, LLaMA-7B: HiFT 40.11 < prefix 40.69 < LoRA 43.24 < FPFT OOM.
        // (Table 5's HiFT rows use the §G.2 adapted mixed precision.)
        let a = by_name("llama-7b").unwrap();
        let w = Workload { batch: 8, seq: 512 };
        let hift = account(&a, OptimKind::AdamW, Dtype::MixedHi, Method::Hift { m: 1 }, w);
        let lora = account(&a, OptimKind::AdamW, Dtype::Mixed, Method::Peft { adapter_params: 4_194_304 }, w);
        let fpft = account(&a, OptimKind::AdamW, Dtype::Mixed, Method::Fpft, w);
        assert!(hift.total < lora.total, "hift {:.1} < lora {:.1}", hift.total_gib(), lora.total_gib());
        assert!(lora.total < fpft.total);
        assert!(fpft.total_gib() > 80.0, "FPFT 7B mixed must blow an A100 (paper: OOM)");
    }
}
