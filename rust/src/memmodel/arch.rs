//! Layer inventories for the paper's model zoo.
//!
//! Every architecture is expanded to its full named-parameter list with
//! shapes and layer-unit assignments (unit 0 = embeddings, 1..=L = blocks,
//! L+1 = head — the paper's §F layering).  The accounting in
//! [`super::account`] is then exact arithmetic over these shapes, which is
//! how the model reproduces the #Trainable/#Para/#Gra/#Sta columns of
//! Tables 8–12 to the megabyte:
//!
//! * RoBERTa-base peak unit = embeddings = **39.00 M** (Table 8)
//! * RoBERTa-large peak unit = embeddings = **52.00 M** (Table 9)
//! * GPT-2-large peak unit = embeddings = **65.64 M** (Table 10)
//! * GPT-Neo-2.7B peak unit = embeddings = **133.9 M** (Table 11)
//! * LLaMA-7B peak unit = one *block* = **202.38 M** (Table 12)
//! * LLaMA-13B peak fraction = **2.44 %** (Figure 6e)

/// One parameter tensor of an architecture.
#[derive(Debug, Clone)]
pub struct PShape {
    pub name: String,
    pub shape: Vec<usize>,
    /// 0 = embeddings, 1..=L = blocks, L+1 = head.
    pub unit: usize,
}

impl PShape {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn new(name: impl Into<String>, shape: &[usize], unit: usize) -> Self {
        PShape { name: name.into(), shape: shape.to_vec(), unit }
    }
}

/// Transformer family (drives which parameters a block carries).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// Encoder, learned abs. positions + token-type, LN w/ bias, dense head
    /// with pooler (RoBERTa + classification head).
    BertEncoder,
    /// Decoder, learned positions, LN w/ bias, *tied* LM head (GPT-2 /
    /// GPT-Neo).
    Gpt2Decoder,
    /// Decoder, RoPE (no position table), RMSNorm (no bias), gated SwiGLU
    /// FFN, *untied* LM head (LLaMA).
    LlamaDecoder,
    /// Decoder, learned positions, LN w/ bias, untied head (OPT).
    OptDecoder,
}

/// Architecture hyperparameters.
#[derive(Debug, Clone)]
pub struct Arch {
    pub name: String,
    pub family: Family,
    pub vocab: usize,
    pub max_pos: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
}

impl Arch {
    /// Number of layer units (embeddings + blocks + head).
    pub fn n_units(&self) -> usize {
        self.n_layers + 2
    }

    /// Expand to the full parameter inventory.
    pub fn params(&self) -> Vec<PShape> {
        let d = self.d_model;
        let f = self.d_ff;
        let mut out = Vec::new();
        // --- unit 0: embeddings ---
        out.push(PShape::new("tok_emb", &[self.vocab, d], 0));
        match self.family {
            Family::BertEncoder => {
                out.push(PShape::new("pos_emb", &[self.max_pos, d], 0));
                out.push(PShape::new("type_emb", &[1, d], 0));
                out.push(PShape::new("emb_ln.scale", &[d], 0));
                out.push(PShape::new("emb_ln.bias", &[d], 0));
            }
            Family::Gpt2Decoder | Family::OptDecoder => {
                out.push(PShape::new("pos_emb", &[self.max_pos, d], 0));
            }
            Family::LlamaDecoder => {} // RoPE: no table
        }
        // --- units 1..=L: blocks ---
        for i in 0..self.n_layers {
            let u = i + 1;
            let p = format!("l{i}.");
            match self.family {
                Family::LlamaDecoder => {
                    // RMSNorm (scale only), no attention/ffn biases, SwiGLU.
                    out.push(PShape::new(p.clone() + "attn_norm", &[d], u));
                    for w in ["wq", "wk", "wv", "wo"] {
                        out.push(PShape::new(format!("{p}attn.{w}"), &[d, d], u));
                    }
                    out.push(PShape::new(p.clone() + "ffn_norm", &[d], u));
                    out.push(PShape::new(p.clone() + "ffn.w_gate", &[d, f], u));
                    out.push(PShape::new(p.clone() + "ffn.w_up", &[d, f], u));
                    out.push(PShape::new(p.clone() + "ffn.w_down", &[f, d], u));
                }
                _ => {
                    // LN(+bias), attention and FFN biases (BERT/GPT-2/
                    // GPT-Neo/OPT all carry them).
                    out.push(PShape::new(p.clone() + "ln1.scale", &[d], u));
                    out.push(PShape::new(p.clone() + "ln1.bias", &[d], u));
                    for w in ["wq", "wk", "wv", "wo"] {
                        out.push(PShape::new(format!("{p}attn.{w}"), &[d, d], u));
                        out.push(PShape::new(format!("{p}attn.b_{w}"), &[d], u));
                    }
                    out.push(PShape::new(p.clone() + "ln2.scale", &[d], u));
                    out.push(PShape::new(p.clone() + "ln2.bias", &[d], u));
                    out.push(PShape::new(p.clone() + "ffn.w1", &[d, f], u));
                    out.push(PShape::new(p.clone() + "ffn.b1", &[f], u));
                    out.push(PShape::new(p.clone() + "ffn.w2", &[f, d], u));
                    out.push(PShape::new(p.clone() + "ffn.b2", &[d], u));
                }
            }
        }
        // --- unit L+1: head ---
        let u = self.n_layers + 1;
        match self.family {
            Family::BertEncoder => {
                // RoBERTa classification head (CoLA: 2 labels).
                out.push(PShape::new("head.dense", &[d, d], u));
                out.push(PShape::new("head.dense_b", &[d], u));
                out.push(PShape::new("head.out", &[d, 2], u));
                out.push(PShape::new("head.out_b", &[2], u));
            }
            Family::Gpt2Decoder => {
                // Tied LM head: only the final LN is new.
                out.push(PShape::new("ln_f.scale", &[d], u));
                out.push(PShape::new("ln_f.bias", &[d], u));
            }
            Family::LlamaDecoder => {
                out.push(PShape::new("norm_f", &[d], u));
                out.push(PShape::new("lm_head", &[d, self.vocab], u));
            }
            Family::OptDecoder => {
                out.push(PShape::new("ln_f.scale", &[d], u));
                out.push(PShape::new("ln_f.bias", &[d], u));
                out.push(PShape::new("lm_head", &[d, self.vocab], u));
            }
        }
        out
    }

    /// Total parameter count.
    pub fn total_params(&self) -> usize {
        self.params().iter().map(PShape::numel).sum()
    }

    /// Parameter count per layer unit.
    pub fn unit_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.n_units()];
        for p in self.params() {
            sizes[p.unit] += p.numel();
        }
        sizes
    }

    /// GPT-Neo alternates global and local (window-256) attention layers;
    /// the activation model halves the quadratic term on the local half.
    pub fn local_attn_window(&self) -> Option<usize> {
        if self.name.starts_with("gpt-neo") {
            Some(256)
        } else {
            None
        }
    }

    /// Largest group's parameter count for groups of `m` contiguous units —
    /// the paper's per-step "#Trainable Parameters" (Tables 8–12) at m=1.
    pub fn peak_group_params(&self, m: usize) -> usize {
        self.unit_sizes().chunks(m).map(|c| c.iter().sum::<usize>()).max().unwrap_or(0)
    }
}

/// The paper's model zoo (+ OPT sizes for the Figure-6e curve).
pub fn zoo() -> Vec<Arch> {
    vec![
        Arch { name: "roberta-base".into(), family: Family::BertEncoder, vocab: 50265, max_pos: 514, d_model: 768, n_layers: 12, n_heads: 12, d_ff: 3072 },
        Arch { name: "roberta-large".into(), family: Family::BertEncoder, vocab: 50265, max_pos: 514, d_model: 1024, n_layers: 24, n_heads: 16, d_ff: 4096 },
        Arch { name: "gpt2-large".into(), family: Family::Gpt2Decoder, vocab: 50257, max_pos: 1024, d_model: 1280, n_layers: 36, n_heads: 20, d_ff: 5120 },
        Arch { name: "gpt-neo-2.7b".into(), family: Family::Gpt2Decoder, vocab: 50257, max_pos: 2048, d_model: 2560, n_layers: 32, n_heads: 20, d_ff: 10240 },
        Arch { name: "llama-7b".into(), family: Family::LlamaDecoder, vocab: 32000, max_pos: 4096, d_model: 4096, n_layers: 32, n_heads: 32, d_ff: 11008 },
        Arch { name: "llama-13b".into(), family: Family::LlamaDecoder, vocab: 32000, max_pos: 4096, d_model: 5120, n_layers: 40, n_heads: 40, d_ff: 13824 },
        Arch { name: "opt-13b".into(), family: Family::OptDecoder, vocab: 50272, max_pos: 2050, d_model: 5120, n_layers: 40, n_heads: 40, d_ff: 20480 },
        Arch { name: "opt-125m".into(), family: Family::OptDecoder, vocab: 50272, max_pos: 2050, d_model: 768, n_layers: 12, n_heads: 12, d_ff: 3072 },
        Arch { name: "opt-1.3b".into(), family: Family::OptDecoder, vocab: 50272, max_pos: 2050, d_model: 2048, n_layers: 24, n_heads: 32, d_ff: 8192 },
    ]
}

/// Lookup by name.
pub fn by_name(name: &str) -> Option<Arch> {
    zoo().into_iter().find(|a| a.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn millions(n: usize) -> f64 {
        n as f64 / 1e6
    }

    /// Paper Tables 8–12: total and peak-unit (HiFT m=1) parameter counts.
    #[test]
    fn totals_and_peaks_match_paper() {
        let cases = [
            // (name, paper total M, paper peak-unit M, tolerance M)
            ("roberta-base", 124.65, 39.00, 0.7),
            ("roberta-large", 355.36, 52.00, 1.6),
            ("gpt2-large", 774.03, 65.64, 1.6),
            ("gpt-neo-2.7b", 2651.31, 133.9, 14.0),
            ("llama-7b", 6738.42, 202.38, 1.0),
        ];
        for (name, total_m, peak_m, tol) in cases {
            let a = by_name(name).unwrap();
            let total = millions(a.total_params());
            let peak = millions(a.peak_group_params(1));
            assert!((total - total_m).abs() < tol, "{name}: total {total:.2}M vs paper {total_m}M");
            assert!((peak - peak_m).abs() < tol, "{name}: peak unit {peak:.2}M vs paper {peak_m}M");
        }
    }

    /// Figure 6(e): LLaMA-13B peak trainable fraction = 2.44 %.
    #[test]
    fn llama13b_peak_fraction_matches_fig6e() {
        let a = by_name("llama-13b").unwrap();
        let frac = a.peak_group_params(1) as f64 / a.total_params() as f64 * 100.0;
        assert!((frac - 2.44).abs() < 0.1, "peak fraction {frac:.2}% vs paper 2.44%");
    }

    /// Abstract claim: ~89.18% average reduction in trainable params.
    #[test]
    fn average_trainable_reduction_matches_abstract() {
        let names =
            ["roberta-base", "roberta-large", "gpt2-large", "gpt-neo-2.7b", "llama-7b", "opt-13b"];
        let mean_reduction: f64 = names
            .iter()
            .map(|n| {
                let a = by_name(n).unwrap();
                1.0 - a.peak_group_params(1) as f64 / a.total_params() as f64
            })
            .sum::<f64>()
            / names.len() as f64;
        assert!(
            (mean_reduction * 100.0 - 89.18).abs() < 3.0,
            "mean reduction {:.2}% vs paper 89.18%",
            mean_reduction * 100.0
        );
    }

    #[test]
    fn peak_fraction_decreases_with_model_size() {
        // Figure 6(e)'s trend across decoder sizes.
        let names = ["opt-125m", "opt-1.3b", "llama-7b", "llama-13b"];
        let fracs: Vec<f64> = names
            .iter()
            .map(|n| {
                let a = by_name(n).unwrap();
                a.peak_group_params(1) as f64 / a.total_params() as f64
            })
            .collect();
        for w in fracs.windows(2) {
            assert!(w[1] < w[0], "fraction must fall with size: {fracs:?}");
        }
    }

    #[test]
    fn unit_sizes_partition_total() {
        for a in zoo() {
            assert_eq!(a.unit_sizes().iter().sum::<usize>(), a.total_params(), "{}", a.name);
            assert_eq!(a.unit_sizes().len(), a.n_units());
        }
    }

    #[test]
    fn grouping_m_reduces_k_and_raises_peak() {
        let a = by_name("roberta-base").unwrap();
        let p1 = a.peak_group_params(1);
        let p4 = a.peak_group_params(4);
        let pall = a.peak_group_params(a.n_units());
        assert!(p1 <= p4 && p4 <= pall);
        assert_eq!(pall, a.total_params());
    }

    #[test]
    fn llama_peak_unit_is_a_block_not_embeddings() {
        let a = by_name("llama-7b").unwrap();
        let sizes = a.unit_sizes();
        let peak_unit = (0..sizes.len()).max_by_key(|&i| sizes[i]).unwrap();
        assert!(peak_unit >= 1 && peak_unit <= a.n_layers, "LLaMA's widest unit is a block");
    }
}
