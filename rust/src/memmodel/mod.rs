//! Analytic device-memory model: layer inventories for the paper's model
//! zoo ([`arch`]) + the accounting that regenerates Tables 5 & 8–12 and
//! Figure 6 ([`account`]).

pub mod account;
pub mod arch;

pub use account::{
    account, account_ckpt, account_prec, account_workers, appendix_b_ratio, fused_attn_savings,
    native_probs_bytes, paged_host_bound, paged_param_bound, precision_act_factor, savings_pct,
    workers_overhead, Dtype, MemRow, Method, Workload, GIB, MIB,
};
pub use arch::{by_name, zoo, Arch, Family, PShape};
