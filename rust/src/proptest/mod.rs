//! A minimal property-testing harness (the vendor set has no `proptest`).
//!
//! Supports: seeded generators, configurable case counts, and greedy
//! shrinking for integers and integer vectors.  Used by the coordinator and
//! memmodel tests to check invariants (queue rotation is a permutation, the
//! Appendix-B memory identity holds for all k, …).
//!
//! ```ignore
//! run(100, |g| {
//!     let n = g.usize_in(1, 64);
//!     let m = g.usize_in(1, n);
//!     let k = (n + m - 1) / m;
//!     prop_assert(k * m >= n, "groups must cover all layers")
//! });
//! ```

use crate::rng::Pcg32;

/// Failure raised by `prop_assert`.
#[derive(Debug)]
pub struct PropFailure {
    pub message: String,
}

/// Assert inside a property; returns Err to trigger shrinking/reporting.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> Result<(), PropFailure> {
    if cond {
        Ok(())
    } else {
        Err(PropFailure { message: msg.into() })
    }
}

/// Per-case generator: draws values and records them for reporting.
pub struct Gen {
    rng: Pcg32,
    pub draws: Vec<(String, String)>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Pcg32::seeded(seed), draws: Vec::new() }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = lo + self.rng.below(hi - lo + 1);
        self.draws.push(("usize".into(), v.to_string()));
        v
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        let v = lo + (self.rng.next_u64() % span) as i64;
        self.draws.push(("i64".into(), v.to_string()));
        v
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let v = lo + self.rng.next_f32() * (hi - lo);
        self.draws.push(("f32".into(), v.to_string()));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_u32() & 1 == 1;
        self.draws.push(("bool".into(), v.to_string()));
        v
    }

    pub fn vec_usize(&mut self, max_len: usize, lo: usize, hi: usize) -> Vec<usize> {
        let len = self.rng.below(max_len + 1);
        let v: Vec<usize> = (0..len).map(|_| lo + self.rng.below(hi - lo + 1)).collect();
        self.draws.push(("vec".into(), format!("{v:?}")));
        v
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choice(xs)
    }
}

/// Run `cases` random cases of `prop`; panics with the failing seed + draws.
///
/// Shrinking strategy: on failure, retry nearby seeds whose draws are
/// lexicographically smaller (seed-level shrinking — simple but effective
/// for the small integer domains we test).
pub fn run<F>(cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), PropFailure>,
{
    run_seeded(0xBADC0FFE, cases, prop)
}

/// `run` with an explicit base seed (regression pinning).
pub fn run_seeded<F>(base_seed: u64, cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), PropFailure>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen::new(seed);
        if let Err(f) = prop(&mut g) {
            // Greedy shrink: look for a smaller failing case among
            // derived seeds, preferring fewer/smaller draws.
            let mut best = (seed, g.draws.clone(), f.message.clone());
            for attempt in 0..200u64 {
                let s2 = seed.wrapping_add(attempt.wrapping_mul(0x2545F4914F6CDD1D));
                let mut g2 = Gen::new(s2);
                if let Err(f2) = prop(&mut g2) {
                    if draws_size(&g2.draws) < draws_size(&best.1) {
                        best = (s2, g2.draws.clone(), f2.message.clone());
                    }
                }
            }
            panic!(
                "property failed (case {case}, seed {:#x}): {}\n  draws: {:?}",
                best.0, best.2, best.1
            );
        }
    }
}

fn draws_size(draws: &[(String, String)]) -> u64 {
    draws
        .iter()
        .map(|(_, v)| v.parse::<i64>().map(|x| x.unsigned_abs()).unwrap_or(v.len() as u64))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        run(50, |g| {
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            prop_assert(a + b >= a, "no overflow in range")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        run(50, |g| {
            let a = g.usize_in(0, 100);
            prop_assert(a < 90, "a must be < 90 (intentionally falsifiable)")
        });
    }

    #[test]
    fn generators_respect_bounds() {
        run(100, |g| {
            let x = g.i64_in(-5, 5);
            let f = g.f32_in(0.0, 1.0);
            prop_assert((-5..=5).contains(&x) && (0.0..=1.0).contains(&f), "bounds")
        });
    }

    #[test]
    fn vec_generator_bounds() {
        run(50, |g| {
            let v = g.vec_usize(10, 2, 4);
            prop_assert(v.len() <= 10 && v.iter().all(|&x| (2..=4).contains(&x)), "vec bounds")
        });
    }
}
