//! `hift` binary entrypoint — delegates to the CLI module.
fn main() -> anyhow::Result<()> {
    hift::cli::main_entry()
}
