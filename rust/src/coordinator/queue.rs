//! The rotating layer queue of Algorithm 1.
//!
//! `Q` stores unit identifiers in strategy order.  Each step the scheduler
//! pops the next `m` (step c, `QueueGetAndRemove`) and pushes them back at
//! the tail (step d, `QueueAddTail`), so after a full sweep the queue is
//! back in its initial order — groups are *stable* across sweeps.

use std::collections::VecDeque;

/// FIFO of layer-unit ids with the Algorithm-1 rotation ops.
#[derive(Debug, Clone)]
pub struct LayerQueue {
    q: VecDeque<usize>,
}

impl LayerQueue {
    /// Initialize from a strategy order (the `UpdateStrategy(Q, S)` line).
    pub fn new(order: &[usize]) -> Self {
        LayerQueue { q: order.iter().copied().collect() }
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Step c: remove and return up to `m` ids from the head.
    pub fn get_and_remove(&mut self, m: usize) -> Vec<usize> {
        let take = m.min(self.q.len());
        self.q.drain(..take).collect()
    }

    /// Step d: append ids at the tail (to be revisited next sweep).
    pub fn add_tail(&mut self, ids: &[usize]) {
        self.q.extend(ids.iter().copied());
    }

    /// Convenience: pop-rotate in one call.
    pub fn rotate(&mut self, m: usize) -> Vec<usize> {
        let ids = self.get_and_remove(m);
        self.add_tail(&ids);
        ids
    }

    /// Current contents, head first (diagnostics/tests).
    pub fn snapshot(&self) -> Vec<usize> {
        self.q.iter().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{prop_assert, run};

    #[test]
    fn rotation_cycles_through_all() {
        let mut q = LayerQueue::new(&[0, 1, 2, 3, 4]);
        assert_eq!(q.rotate(2), vec![0, 1]);
        assert_eq!(q.rotate(2), vec![2, 3]);
        assert_eq!(q.rotate(2), vec![4, 0]); // m ∤ n wraps
        assert_eq!(q.len(), 5);
    }

    #[test]
    fn full_sweep_restores_order_when_m_divides() {
        let order = vec![3, 1, 4, 0, 2, 5];
        let mut q = LayerQueue::new(&order);
        for _ in 0..3 {
            q.rotate(2);
        }
        assert_eq!(q.snapshot(), order, "after k rotations the queue is unchanged");
    }

    #[test]
    fn get_and_remove_clamps_to_len() {
        let mut q = LayerQueue::new(&[7, 8]);
        assert_eq!(q.get_and_remove(5), vec![7, 8]);
        assert!(q.is_empty());
        q.add_tail(&[7, 8]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn prop_rotation_preserves_multiset_and_length() {
        run(200, |g| {
            let n = g.usize_in(1, 40);
            let m = g.usize_in(1, 40);
            let steps = g.usize_in(0, 50);
            let order: Vec<usize> = (0..n).collect();
            let mut q = LayerQueue::new(&order);
            for _ in 0..steps {
                let ids = q.rotate(m);
                prop_assert(ids.len() == m.min(n), "pop size")?;
            }
            let mut snap = q.snapshot();
            snap.sort_unstable();
            prop_assert(snap == order, "queue must stay a permutation of the units")?;
            Ok(())
        });
    }

    #[test]
    fn prop_every_unit_visited_once_per_sweep() {
        run(200, |g| {
            let n = g.usize_in(1, 32);
            let m = g.usize_in(1, n);
            let k = n.div_ceil(m);
            let mut q = LayerQueue::new(&(0..n).collect::<Vec<_>>());
            let mut seen = vec![0usize; n];
            let mut popped = 0;
            // one paper-sweep = pops until every unit appeared once
            while popped < n {
                let take = m.min(n - popped);
                for id in q.rotate(take) {
                    seen[id] += 1;
                }
                popped += take;
            }
            prop_assert(seen.iter().all(|&c| c == 1), format!("sweep visits each once; k={k}"))?;
            Ok(())
        });
    }
}
