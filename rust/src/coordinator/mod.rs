//! The HiFT coordinator — Algorithm 1 of the paper, in Rust.
//!
//! HiFT divides the model's layer units into `k = ⌈n/m⌉` groups and updates
//! exactly one group per training step, rotating through a queue whose
//! initial order is fixed by the update strategy (bottom2up / top2down /
//! random).  The learning rate advances *once per full sweep* (delayed LR),
//! keeping the update amplitude of every group consistent.
//!
//! Module layout mirrors the algorithm:
//! * [`strategy`] — S ∈ {B2U, T2D, RAN} (the `UpdateStrategy(Q, S)` line)
//! * [`queue`] — the rotating layer queue (steps c, d)
//! * [`grouping`] — n layers → k groups of m (the `group` operation)
//! * [`lr`] — schedules + the delayed `IsAllLayerUpdate` advancement
//! * [`scheduler`] — the per-step group selection state machine
//! * [`trainer`] — drives any [`crate::strategies::FineTuneStrategy`]
//!   (HiFT or a baseline) over data with eval + metrics

pub mod grouping;
pub mod lr;
pub mod queue;
pub mod scheduler;
pub mod strategy;
pub mod trainer;

pub use grouping::Grouping;
pub use lr::{DelayedLr, LrSchedule};
pub use queue::LayerQueue;
pub use scheduler::{HiftScheduler, SchedulerCfg};
pub use strategy::UpdateStrategy;
pub use trainer::{RunRecord, TrainCfg, Trainer};
