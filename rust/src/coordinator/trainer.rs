//! Strategy-agnostic training driver: loops batches from a [`Task`] through
//! any [`FineTuneStrategy`], tracks loss/accuracy/throughput, runs periodic
//! held-out evaluation, and emits a JSON [`RunRecord`] — the unit of
//! evidence every bench harness builds its tables from.
//!
//! [`train_ckpt`] adds the crash-safe checkpoint loop: periodic
//! [`checkpoint::save_replace`] of params + optimizer state + schedule
//! position, and resume via [`CkptOpts::start_step`] (fast-forwarding the
//! strategy's schedules and replaying the task's deterministic batch
//! stream, so a resumed run is bit-identical to an uninterrupted one).

use anyhow::{bail, Result};

use crate::backend::{Batch, ExecBackend, RuntimeStats};
use crate::data::Task;
use crate::metrics::{Accuracy, Series, Throughput};
use crate::ser::Value;
use crate::strategies::FineTuneStrategy;
use crate::tensor::{checkpoint, TensorSet};

/// Driver configuration.
#[derive(Debug, Clone, Copy)]
pub struct TrainCfg {
    pub steps: u64,
    /// 0 = eval only at the end.
    pub eval_every: u64,
    /// 0 = no progress logging.
    pub log_every: u64,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg { steps: 100, eval_every: 0, log_every: 0 }
    }
}

/// Held-out evaluation result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResult {
    pub acc: f64,
    pub loss: f64,
}

/// Evaluate `params` on fixed batches with a forward artifact.
///
/// Eval loss is weighted by each batch's loss-mask weight sum (each batch's
/// loss is already a weighted mean over its own mask): a plain per-batch
/// mean would bias the aggregate whenever batches carry uneven masking.
pub fn evaluate(
    be: &mut dyn ExecBackend,
    fwd_artifact: &str,
    params: &mut TensorSet,
    batches: &[Batch],
) -> Result<EvalResult> {
    if batches.is_empty() {
        // The 0/1e-9 division below would otherwise silently report
        // acc = NaN, loss = 0.0 for an empty eval set.
        bail!("evaluate: no eval batches given for {fwd_artifact}");
    }
    let mut acc = Accuracy::default();
    let mut loss_sum = 0.0f64;
    let mut weight_total = 0.0f64;
    for b in batches {
        let out = be.run(fwd_artifact, params, b)?;
        let wsum: f64 = b.weights.iter().map(|&w| w as f64).sum();
        acc.add(out.ncorrect as f64, wsum);
        loss_sum += out.loss as f64 * wsum;
        weight_total += wsum;
    }
    Ok(EvalResult { acc: acc.value(), loss: loss_sum / weight_total.max(1e-9) })
}

/// Everything one training run produced.
#[derive(Debug)]
pub struct RunRecord {
    pub strategy: String,
    pub task: String,
    pub losses: Series,
    /// (step, eval accuracy, eval loss) checkpoints.
    pub evals: Vec<(u64, f64, f64)>,
    pub final_eval: EvalResult,
    pub train_acc: f64,
    pub steps: u64,
    pub wall_secs: f64,
    pub steps_per_sec: f64,
    pub exec_secs: f64,
    /// Compute precision the run executed at (`"f32"|"bf16"|"f16"`).
    pub precision: String,
    /// Data-parallel worker replicas the backend ran each step with
    /// (1 = serial; N > 1 is bit-identical to serial by construction).
    pub workers: usize,
    pub peak_trainable_params: usize,
    pub optimizer_state_bytes: usize,
    /// Paging ledger summary (HiFT only): (h2d, d2h, max_inflight, peak_device).
    pub paging: Option<(u64, u64, u64, u64)>,
    /// Peak gradient residency observed by the strategy's fused-update
    /// ledger (streamed HiFT: ≈ the largest single tensor); `None` when the
    /// strategy has no ledger.
    pub peak_grad_resident_bytes: Option<u64>,
    /// Backend execution statistics for this run (additive counters are
    /// per-run deltas; peak fields are end-of-run values) — the upload-
    /// cache hit rates the bench tables report.
    pub backend: RuntimeStats,
    /// Diversity / dedup statistics of the task's emitted train stream;
    /// `Some` whenever the task came from the forge ([`crate::data::build_task`]).
    pub diversity: Option<crate::data::quality::StreamStats>,
}

impl RunRecord {
    pub fn to_json(&self) -> Value {
        let mut pairs: Vec<(&str, Value)> = vec![
            ("strategy", self.strategy.as_str().into()),
            ("task", self.task.as_str().into()),
            ("steps", (self.steps as usize).into()),
            ("final_eval_acc", self.final_eval.acc.into()),
            ("final_eval_loss", self.final_eval.loss.into()),
            ("train_acc", self.train_acc.into()),
            ("final_train_loss", self.losses.tail_mean(10).into()),
            ("wall_secs", self.wall_secs.into()),
            ("steps_per_sec", self.steps_per_sec.into()),
            ("exec_secs", self.exec_secs.into()),
            ("precision", self.precision.as_str().into()),
            ("workers", self.workers.into()),
            ("peak_trainable_params", self.peak_trainable_params.into()),
            ("optimizer_state_bytes", self.optimizer_state_bytes.into()),
            (
                "loss_curve",
                Value::Arr(
                    self.losses
                        .downsample(64)
                        .into_iter()
                        .map(|(i, v)| Value::Arr(vec![(i as f64).into(), v.into()]))
                        .collect(),
                ),
            ),
            (
                "evals",
                Value::Arr(
                    self.evals
                        .iter()
                        .map(|(s, a, l)| Value::Arr(vec![(*s as f64).into(), (*a).into(), (*l).into()]))
                        .collect(),
                ),
            ),
        ];
        if let Some((h2d, d2h, inflight, peak)) = self.paging {
            let mut paging = vec![
                ("h2d_bytes", Value::from(h2d as usize)),
                ("d2h_bytes", (d2h as usize).into()),
                ("max_inflight_bytes", (inflight as usize).into()),
                ("peak_device_state_bytes", (peak as usize).into()),
            ];
            if let Some(g) = self.peak_grad_resident_bytes {
                paging.push(("peak_grad_resident_bytes", (g as usize).into()));
            }
            pairs.push(("paging", Value::obj(paging)));
        }
        let b = &self.backend;
        let lookups = b.cache_hits + b.cache_misses;
        let hit_rate =
            if lookups > 0 { b.cache_hits as f64 / lookups as f64 } else { 0.0 };
        pairs.push((
            "backend",
            Value::obj(vec![
                ("executions", (b.executions as usize).into()),
                ("exec_secs", b.exec_secs.into()),
                ("compiles", (b.compiles as usize).into()),
                ("h2d_bytes", (b.h2d_bytes as usize).into()),
                ("d2h_bytes", (b.d2h_bytes as usize).into()),
                ("cache_hits", (b.cache_hits as usize).into()),
                ("cache_misses", (b.cache_misses as usize).into()),
                ("cache_hit_rate", hit_rate.into()),
                ("peak_grad_resident_bytes", (b.peak_grad_resident_bytes as usize).into()),
                ("peak_act_resident_bytes", (b.peak_act_resident_bytes as usize).into()),
                ("recompute_layers", (b.recompute_layers as usize).into()),
                ("recompute_flops", (b.recompute_flops as usize).into()),
                ("kernel_flops", (b.kernel_flops as usize).into()),
                ("kernel_gflops", b.kernel_gflops().into()),
            ]),
        ));
        // Numerics block (absent when nothing noteworthy happened):
        // non-finite-gradient events and the f16 dynamic loss scaler's
        // trajectory.
        let scaler_active = b.loss_scale != 0.0 && b.loss_scale != 1.0;
        if b.nonfinite_grad_tensors + b.nonfinite_grad_steps > 0
            || b.loss_scale_growths + b.loss_scale_backoffs > 0
            || scaler_active
        {
            pairs.push((
                "numerics",
                Value::obj(vec![
                    ("nonfinite_grad_tensors", (b.nonfinite_grad_tensors as usize).into()),
                    ("nonfinite_grad_steps", (b.nonfinite_grad_steps as usize).into()),
                    ("loss_scale_growths", (b.loss_scale_growths as usize).into()),
                    ("loss_scale_backoffs", (b.loss_scale_backoffs as usize).into()),
                    ("loss_scale", b.loss_scale.into()),
                ]),
            ));
        }
        // Host paging tier (all-zero when --offload is off): measured
        // transfers, enforced residency peaks, prefetch effectiveness.
        if let Some(d) = &self.diversity {
            pairs.push(("diversity", d.to_json()));
        }
        if b.offload_page_ins + b.offload_page_outs > 0 {
            pairs.push((
                "offload",
                Value::obj(vec![
                    ("page_ins", (b.offload_page_ins as usize).into()),
                    ("page_outs", (b.offload_page_outs as usize).into()),
                    ("h2d_bytes", (b.offload_h2d_bytes as usize).into()),
                    ("d2h_bytes", (b.offload_d2h_bytes as usize).into()),
                    ("peak_param_resident_bytes", (b.peak_param_resident_bytes as usize).into()),
                    (
                        "peak_prefetch_buffer_bytes",
                        (b.peak_prefetch_buffer_bytes as usize).into(),
                    ),
                    ("peak_host_pool_bytes", (b.peak_host_pool_bytes as usize).into()),
                    ("prefetch_hits", (b.prefetch_hits as usize).into()),
                    ("prefetch_misses", (b.prefetch_misses as usize).into()),
                    ("prefetch_stall_ms", (b.prefetch_stall_nanos as f64 / 1e6).into()),
                ]),
            ));
        }
        Value::obj(pairs)
    }
}

/// Checkpoint/resume options for [`train_ckpt`].
#[derive(Debug, Clone, Default)]
pub struct CkptOpts {
    /// Where to write checkpoints (`None` = never save).  Saves go through
    /// [`checkpoint::save_replace`], so a crash mid-save never leaves a
    /// torn checkpoint behind.
    pub save_dir: Option<std::path::PathBuf>,
    /// Save every N steps (0 = only at the end of the run, when
    /// `save_dir` is set).
    pub save_every: u64,
    /// Resume: steps already completed by the checkpointed run.  The
    /// trainer fast-forwards the strategy's schedules and replays the
    /// task's deterministic batch stream, so the continuation consumes
    /// exactly the batches an uninterrupted run would.
    pub start_step: u64,
    /// Sweep index recorded in the checkpoint, cross-checked against the
    /// fast-forwarded schedule — a mismatch means the run configuration
    /// (m / order / schedule) changed, which would desync the delayed-LR
    /// alignment §3.1 exists to protect.
    pub expect_sweep: Option<u64>,
}

/// Run `strategy` on `task` for `cfg.steps` steps.
///
/// `params` must have been loaded for `strategy.variant()`
/// (see [`ExecBackend::load_params`]).
pub fn train(
    be: &mut dyn ExecBackend,
    strategy: &mut dyn FineTuneStrategy,
    params: &mut TensorSet,
    task: &mut dyn Task,
    cfg: TrainCfg,
) -> Result<RunRecord> {
    train_ckpt(be, strategy, params, task, cfg, &CkptOpts::default())
}

/// [`train`] with the crash-safe checkpoint loop (periodic save of params +
/// optimizer state + schedule position) and resume-from-step support.
pub fn train_ckpt(
    be: &mut dyn ExecBackend,
    strategy: &mut dyn FineTuneStrategy,
    params: &mut TensorSet,
    task: &mut dyn Task,
    cfg: TrainCfg,
    ckpt: &CkptOpts,
) -> Result<RunRecord> {
    let fwd = strategy.fwd_artifact();
    // Peaks are reset per run so RunRecord reports this run's residency,
    // not the lifetime maximum of a shared bench backend.
    be.reset_run_peaks();
    let stats_start = be.stats().clone();
    let mut losses = Series::new("train_loss");
    let mut train_acc = Accuracy::default();
    let mut evals = Vec::new();
    let mut exec_secs = 0.0f64;

    if ckpt.start_step > cfg.steps {
        bail!("resume step {} is beyond the requested {} steps", ckpt.start_step, cfg.steps);
    }
    if ckpt.start_step > 0 {
        strategy.fast_forward(ckpt.start_step);
        if let Some(sweep) = ckpt.expect_sweep {
            if strategy.sweeps_done() != sweep {
                bail!(
                    "checkpoint records sweep {sweep} at step {} but the replayed schedule \
                     lands on sweep {} — was the strategy configuration (m/order/schedule) \
                     changed between save and resume?",
                    ckpt.start_step,
                    strategy.sweeps_done()
                );
            }
        }
        // Replay the deterministic batch stream so the resumed run sees the
        // same batches an uninterrupted run would.
        for _ in 0..ckpt.start_step {
            let _ = task.train_batch();
        }
    }

    let mut thr = Throughput::new();
    for step in (ckpt.start_step + 1)..=cfg.steps {
        let batch = task.train_batch();
        let stats = strategy.step(be, params, &batch)?;
        losses.push(stats.loss as f64);
        train_acc.add(stats.ncorrect as f64, stats.weight_sum as f64);
        exec_secs += stats.exec_time.as_secs_f64();
        thr.step();

        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            eprintln!(
                "[{}] step {step}/{} loss={:.4} lr={:.2e} trainable={} ({:.2} steps/s)",
                strategy.name(),
                cfg.steps,
                losses.tail_mean(cfg.log_every as usize),
                stats.lr,
                stats.trainable_params,
                thr.steps_per_sec(),
            );
        }
        if cfg.eval_every > 0 && step % cfg.eval_every == 0 {
            let ev = evaluate(be, &fwd, params, task.eval_batches())?;
            evals.push((step, ev.acc, ev.loss));
            if cfg.log_every > 0 {
                eprintln!("[{}]   eval@{step}: acc={:.4} loss={:.4}", strategy.name(), ev.acc, ev.loss);
            }
        }
        if let Some(dir) = &ckpt.save_dir {
            let at_interval = ckpt.save_every > 0 && step % ckpt.save_every == 0;
            if at_interval || step == cfg.steps {
                // Host-paged masters must be back in the arena before the
                // checkpoint serializes the set (no-op when offload is off).
                be.flush_offload(params)?;
                let meta = checkpoint::CkptMeta {
                    step,
                    sweep: Some(strategy.sweeps_done()),
                    strategy: strategy.name().to_string(),
                    task: task.name().to_string(),
                    precision: Some(be.precision().name().to_string()),
                };
                checkpoint::save_replace(dir, params, &meta, &strategy.export_opt_state())?;
                // …and back out afterwards, so a mid-run save neither
                // leaves the whole model resident nor pollutes the
                // measured training peaks (the final hand-off flush after
                // the loop re-materializes everything for the caller).
                be.repage_offload(params)?;
                if cfg.log_every > 0 {
                    eprintln!("[{}]   ckpt@{step}: saved to {}", strategy.name(), dir.display());
                }
            }
        }
    }

    let final_eval = evaluate(be, &fwd, params, task.eval_batches())?;
    // Snapshot the run's backend stats *before* the hand-off flush: paging
    // everything back in necessarily makes the whole model arena-resident,
    // and that bookkeeping spike is not part of the training loop whose
    // peaks the RunRecord reports.
    let backend_stats = be.stats().since(&stats_start);
    // Hand the caller a fully materialized parameter set: anything the
    // paging tier still holds on the host returns to the arena here.
    be.flush_offload(params)?;
    let wall = thr.elapsed_secs();
    let executed = cfg.steps - ckpt.start_step;
    Ok(RunRecord {
        strategy: strategy.name().to_string(),
        task: task.name().to_string(),
        losses,
        evals,
        final_eval,
        train_acc: train_acc.value(),
        steps: cfg.steps,
        wall_secs: wall,
        steps_per_sec: if wall > 0.0 { executed as f64 / wall } else { 0.0 },
        exec_secs,
        precision: be.precision().name().to_string(),
        workers: be.workers(),
        peak_trainable_params: strategy.peak_trainable_params(),
        optimizer_state_bytes: strategy.optimizer_state_bytes(),
        paging: strategy
            .ledger()
            .map(|l| (l.h2d_bytes, l.d2h_bytes, l.max_inflight_bytes, l.peak_device_bytes)),
        peak_grad_resident_bytes: strategy.ledger().map(|l| l.peak_grad_resident_bytes),
        backend: backend_stats,
        diversity: task.stream_stats(),
    })
}

/// Alias kept for the public API surface described in DESIGN.md.
pub struct Trainer;

impl Trainer {
    /// See [`train`].
    pub fn run(
        be: &mut dyn ExecBackend,
        strategy: &mut dyn FineTuneStrategy,
        params: &mut TensorSet,
        task: &mut dyn Task,
        cfg: TrainCfg,
    ) -> Result<RunRecord> {
        train(be, strategy, params, task, cfg)
    }
}
