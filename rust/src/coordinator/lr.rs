//! Learning-rate schedules with HiFT's **delayed update** (§3.1).
//!
//! Standard training advances the LR every optimizer step.  Under HiFT that
//! would give different groups different LRs within one sweep — the
//! inconsistent-amplitude problem the paper calls out.  [`DelayedLr`]
//! therefore advances the underlying schedule only when *all* layers have
//! been updated once (`IsAllLayerUpdate(t, n, m)` in Algorithm 1): every
//! group in a sweep sees the identical LR.

/// The underlying schedule, indexed by *sweep* (delayed) or *step* (FPFT).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    Const { lr: f32 },
    /// Linear warmup then linear decay to zero over `total` indices.
    Linear { lr: f32, warmup: usize, total: usize },
    /// Linear warmup then cosine decay to `min_lr`.
    Cosine { lr: f32, warmup: usize, total: usize, min_lr: f32 },
}

impl LrSchedule {
    /// LR at schedule index `i` (a sweep under HiFT, a step under FPFT).
    pub fn at(&self, i: usize) -> f32 {
        match *self {
            LrSchedule::Const { lr } => lr,
            LrSchedule::Linear { lr, warmup, total } => {
                if warmup > 0 && i < warmup {
                    return lr * (i + 1) as f32 / warmup as f32;
                }
                let total = total.max(warmup + 1);
                let frac = (total - i.min(total)) as f32 / (total - warmup) as f32;
                lr * frac.clamp(0.0, 1.0)
            }
            LrSchedule::Cosine { lr, warmup, total, min_lr } => {
                if warmup > 0 && i < warmup {
                    return lr * (i + 1) as f32 / warmup as f32;
                }
                let total = total.max(warmup + 1);
                let p = ((i - warmup.min(i)) as f32 / (total - warmup) as f32).clamp(0.0, 1.0);
                min_lr + 0.5 * (lr - min_lr) * (1.0 + (std::f32::consts::PI * p).cos())
            }
        }
    }
}

/// Algorithm 1's `IsAllLayerUpdate`: true at steps that complete a sweep.
///
/// With n units in groups of m there are `k = ⌈n/m⌉` steps per sweep; step
/// indices are 1-based as in the paper.
pub fn is_all_layer_update(t: u64, n: usize, m: usize) -> bool {
    let k = n.div_ceil(m) as u64;
    t % k == 0
}

/// The delayed-LR state machine: `lr()` is constant within a sweep and the
/// schedule index advances only at sweep boundaries.
#[derive(Debug, Clone)]
pub struct DelayedLr {
    schedule: LrSchedule,
    k: usize,
    step: u64,
    sweep: usize,
}

impl DelayedLr {
    pub fn new(schedule: LrSchedule, k: usize) -> Self {
        assert!(k >= 1);
        DelayedLr { schedule, k, step: 0, sweep: 0 }
    }

    /// The LR for the *next* training step.
    pub fn lr(&self) -> f32 {
        self.schedule.at(self.sweep)
    }

    /// Record a completed step; advances the sweep at boundaries.
    /// Returns true if a sweep just completed.
    pub fn tick(&mut self) -> bool {
        self.step += 1;
        if self.step % self.k as u64 == 0 {
            self.sweep += 1;
            true
        } else {
            false
        }
    }

    /// Jump the state machine to `steps` completed ticks (checkpoint
    /// resume): lands on exactly the state `steps` calls to
    /// [`DelayedLr::tick`] produce, so a resumed run continues the
    /// sweep-aligned schedule instead of restarting it (§3.1).
    pub fn fast_forward(&mut self, steps: u64) {
        self.step = steps;
        self.sweep = (steps / self.k as u64) as usize;
    }

    pub fn sweep(&self) -> usize {
        self.sweep
    }

    pub fn step(&self) -> u64 {
        self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{prop_assert, run};

    #[test]
    fn const_schedule_is_flat() {
        let s = LrSchedule::Const { lr: 0.1 };
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(999), 0.1);
    }

    #[test]
    fn linear_warms_then_decays() {
        let s = LrSchedule::Linear { lr: 1.0, warmup: 10, total: 110 };
        assert!((s.at(0) - 0.1).abs() < 1e-6);
        assert!((s.at(9) - 1.0).abs() < 1e-6);
        assert!(s.at(50) < 1.0 && s.at(50) > 0.0);
        assert_eq!(s.at(110), 0.0);
    }

    #[test]
    fn cosine_hits_endpoints() {
        let s = LrSchedule::Cosine { lr: 1.0, warmup: 0, total: 100, min_lr: 0.1 };
        assert!((s.at(0) - 1.0).abs() < 1e-6);
        assert!((s.at(100) - 0.1).abs() < 1e-6);
        assert!(s.at(50) > 0.1 && s.at(50) < 1.0);
    }

    #[test]
    fn is_all_layer_update_matches_k() {
        // n=5, m=2 -> k=3: sweep completes at t = 3, 6, 9 …
        assert!(!is_all_layer_update(1, 5, 2));
        assert!(!is_all_layer_update(2, 5, 2));
        assert!(is_all_layer_update(3, 5, 2));
        assert!(is_all_layer_update(6, 5, 2));
    }

    #[test]
    fn delayed_lr_constant_within_sweep() {
        let mut d = DelayedLr::new(LrSchedule::Linear { lr: 1.0, warmup: 0, total: 10 }, 4);
        let lr0 = d.lr();
        for i in 0..4 {
            assert_eq!(d.lr(), lr0, "same LR for all {} steps of the sweep", 4);
            let boundary = d.tick();
            assert_eq!(boundary, i == 3);
        }
        assert!(d.lr() < lr0, "LR advances only after the sweep");
        assert_eq!(d.sweep(), 1);
    }

    #[test]
    fn prop_delayed_lr_changes_exactly_once_per_k_steps() {
        run(100, |g| {
            let k = g.usize_in(1, 20);
            let sweeps = g.usize_in(1, 10);
            let mut d = DelayedLr::new(LrSchedule::Linear { lr: 1.0, warmup: 0, total: 1000 }, k);
            let mut changes = 0;
            let mut prev = d.lr();
            for _ in 0..k * sweeps {
                d.tick();
                if (d.lr() - prev).abs() > 0.0 {
                    changes += 1;
                    prev = d.lr();
                }
            }
            prop_assert(changes == sweeps, format!("k={k}: {changes} changes != {sweeps} sweeps"))?;
            Ok(())
        });
    }

    #[test]
    fn prop_schedules_are_bounded_and_nonnegative() {
        run(200, |g| {
            let lr = g.f32_in(1e-6, 1.0);
            let warmup = g.usize_in(0, 50);
            let total = warmup + g.usize_in(1, 200);
            let i = g.usize_in(0, 400);
            for s in [
                LrSchedule::Const { lr },
                LrSchedule::Linear { lr, warmup, total },
                LrSchedule::Cosine { lr, warmup, total, min_lr: 0.0 },
            ] {
                let v = s.at(i);
                prop_assert(v >= 0.0 && v <= lr + 1e-6, format!("{s:?} at {i} -> {v}"))?;
            }
            Ok(())
        });
    }
}
