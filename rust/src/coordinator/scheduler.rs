//! The per-step group-selection state machine (Algorithm 1's loop body,
//! minus the actual forward/backward which [`crate::strategies::hift`]
//! dispatches to the runtime).
//!
//! Pure (no PJRT dependency) so its invariants are property-testable:
//! * each sweep visits every unit exactly once, in strategy order;
//! * groups are identical from sweep to sweep (`m ∤ n` handled with the
//!   paper's short final group, not a drifting window);
//! * the LR is constant within a sweep and advances at sweep boundaries.

use super::grouping::Grouping;
use super::lr::{DelayedLr, LrSchedule};
use super::queue::LayerQueue;
use super::strategy::UpdateStrategy;

/// Scheduler configuration (the HiFT-specific hyperparameters).
#[derive(Debug, Clone, Copy)]
pub struct SchedulerCfg {
    /// Layers per group (paper's m).
    pub m: usize,
    pub strategy: UpdateStrategy,
    pub schedule: LrSchedule,
}

/// One planned training step: which units to train and at what LR.
#[derive(Debug, Clone, PartialEq)]
pub struct PlannedStep {
    /// 1-based step index (paper's t).
    pub step: u64,
    /// Sweep index (how many full passes completed before this step).
    pub sweep: usize,
    /// Layer units whose parameters are trainable this step.
    pub units: Vec<usize>,
    /// The (delayed) learning rate for this step.
    pub lr: f32,
    /// True if this step completes a sweep (LR advances after it).
    pub sweep_boundary: bool,
}

/// HiFT's group scheduler.
#[derive(Debug, Clone)]
pub struct HiftScheduler {
    queue: LayerQueue,
    lr: DelayedLr,
    n_units: usize,
    m: usize,
    k: usize,
    pos_in_sweep: usize,
    step: u64,
}

impl HiftScheduler {
    pub fn new(cfg: SchedulerCfg, n_units: usize) -> Self {
        assert!(n_units >= 1 && cfg.m >= 1);
        let order = cfg.strategy.order(n_units);
        let k = Grouping::k_formula(n_units, cfg.m);
        HiftScheduler {
            queue: LayerQueue::new(&order),
            lr: DelayedLr::new(cfg.schedule, k),
            n_units,
            m: cfg.m,
            k,
            pos_in_sweep: 0,
            step: 0,
        }
    }

    /// Number of groups (steps per sweep).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Completed-sweep index (the delayed-LR schedule position).
    pub fn sweep(&self) -> usize {
        self.lr.sweep()
    }

    /// Fast-forward a **freshly built** scheduler as if `steps_done` steps
    /// had already been planned (checkpoint resume).  The rotating queue
    /// returns to its initial order after every full sweep, so only the
    /// within-sweep remainder is replayed; the delayed-LR counters jump
    /// directly.  The next [`HiftScheduler::next`] then plans exactly the
    /// step an uninterrupted run would have planned.
    pub fn fast_forward(&mut self, steps_done: u64) {
        self.step = steps_done;
        self.lr.fast_forward(steps_done);
        self.pos_in_sweep = 0;
        let within = (steps_done % self.k as u64) as usize;
        for _ in 0..within {
            let take = self.m.min(self.n_units - self.pos_in_sweep);
            let _ = self.queue.rotate(take);
            self.pos_in_sweep += take;
        }
    }

    /// The units the next [`HiftScheduler::next`] call will pop, without
    /// committing anything — the hint the paging tier uses to stage the
    /// next group's page-ins in its double buffer behind the current
    /// step's compute.
    pub fn peek_next(&self) -> Vec<usize> {
        let take = self.m.min(self.n_units - self.pos_in_sweep);
        self.queue.snapshot().into_iter().take(take).collect()
    }

    /// Plan and commit the next step.
    pub fn next(&mut self) -> PlannedStep {
        self.step += 1;
        // Clamp the pop at the sweep end so groups stay fixed when m ∤ n
        // (the paper's short final group).
        let take = self.m.min(self.n_units - self.pos_in_sweep);
        let units = self.queue.rotate(take);
        let lr = self.lr.lr();
        let sweep = self.lr.sweep();
        self.pos_in_sweep += take;
        let boundary = self.pos_in_sweep >= self.n_units;
        if boundary {
            self.pos_in_sweep = 0;
        }
        let advanced = self.lr.tick();
        debug_assert_eq!(advanced, boundary, "DelayedLr and sweep position must agree");
        PlannedStep { step: self.step, sweep, units, lr, sweep_boundary: boundary }
    }

    pub fn total_steps(&self) -> u64 {
        self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{prop_assert, run};

    fn cfg(m: usize, lr: f32) -> SchedulerCfg {
        SchedulerCfg {
            m,
            strategy: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Linear { lr, warmup: 0, total: 100 },
        }
    }

    #[test]
    fn m1_visits_units_in_order() {
        let mut s = HiftScheduler::new(cfg(1, 1.0), 4);
        let units: Vec<Vec<usize>> = (0..8).map(|_| s.next().units).collect();
        assert_eq!(units[..4], [vec![0], vec![1], vec![2], vec![3]]);
        assert_eq!(units[4..], [vec![0], vec![1], vec![2], vec![3]], "second sweep identical");
    }

    #[test]
    fn short_final_group_is_stable_across_sweeps() {
        // n=5, m=2 -> groups [0,1], [2,3], [4] every sweep.
        let mut s = HiftScheduler::new(cfg(2, 1.0), 5);
        for sweep in 0..3 {
            assert_eq!(s.next().units, vec![0, 1], "sweep {sweep}");
            assert_eq!(s.next().units, vec![2, 3], "sweep {sweep}");
            let last = s.next();
            assert_eq!(last.units, vec![4], "sweep {sweep}");
            assert!(last.sweep_boundary);
        }
    }

    #[test]
    fn lr_constant_within_sweep_advances_after() {
        let mut s = HiftScheduler::new(cfg(1, 1.0), 3);
        let first: Vec<f32> = (0..3).map(|_| s.next().lr).collect();
        assert!(first.windows(2).all(|w| w[0] == w[1]), "sweep-constant LR");
        let next_lr = s.next().lr;
        assert!(next_lr < first[0], "delayed LR decays after sweep");
    }

    #[test]
    fn t2d_reverses_visit_order() {
        let mut s = HiftScheduler::new(
            SchedulerCfg { m: 1, strategy: UpdateStrategy::Top2Down, schedule: LrSchedule::Const { lr: 0.1 } },
            3,
        );
        assert_eq!(s.next().units, vec![2]);
        assert_eq!(s.next().units, vec![1]);
        assert_eq!(s.next().units, vec![0]);
    }

    #[test]
    fn prop_sweep_visits_each_unit_exactly_once() {
        run(200, |g| {
            let n = g.usize_in(1, 24);
            let m = g.usize_in(1, 24);
            let seed = g.i64_in(0, 1 << 30) as u64;
            let strat = *g.choose(&[
                UpdateStrategy::Bottom2Up,
                UpdateStrategy::Top2Down,
                UpdateStrategy::Random { seed },
            ]);
            let mut s = HiftScheduler::new(
                SchedulerCfg { m, strategy: strat, schedule: LrSchedule::Const { lr: 0.1 } },
                n,
            );
            let k = s.k();
            for sweep in 0..3 {
                let mut seen = vec![0usize; n];
                let mut boundaries = 0;
                for _ in 0..k {
                    let p = s.next();
                    prop_assert(p.sweep == sweep, "sweep counter")?;
                    for u in &p.units {
                        seen[*u] += 1;
                    }
                    boundaries += p.sweep_boundary as usize;
                }
                prop_assert(seen.iter().all(|&c| c == 1), format!("n={n} m={m} {strat:?}"))?;
                prop_assert(boundaries == 1, "exactly one boundary per sweep")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_fast_forward_matches_stepped_schedule() {
        // A scheduler fast-forwarded to t must plan exactly the steps a
        // scheduler stepped t times would plan next — groups, LR and sweep
        // counters all (the resume invariant).
        run(100, |g| {
            let n = g.usize_in(1, 16);
            let m = g.usize_in(1, 16);
            let t = g.usize_in(0, 60) as u64;
            let mut stepped = HiftScheduler::new(cfg(m, 1.0), n);
            for _ in 0..t {
                stepped.next();
            }
            let mut jumped = HiftScheduler::new(cfg(m, 1.0), n);
            jumped.fast_forward(t);
            prop_assert(jumped.sweep() == stepped.sweep(), format!("sweep at t={t}"))?;
            for i in 0..(2 * jumped.k()) {
                let a = stepped.next();
                let b = jumped.next();
                prop_assert(a == b, format!("n={n} m={m} t={t}: step {i} diverged"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_peek_matches_next() {
        run(100, |g| {
            let n = g.usize_in(1, 16);
            let m = g.usize_in(1, 16);
            let mut s = HiftScheduler::new(cfg(m, 1.0), n);
            for i in 0..3 * s.k() {
                let peeked = s.peek_next();
                let planned = s.next();
                prop_assert(
                    peeked == planned.units,
                    format!("n={n} m={m} step {i}: peek {peeked:?} != next {:?}", planned.units),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_group_pattern_identical_across_sweeps() {
        run(100, |g| {
            let n = g.usize_in(1, 20);
            let m = g.usize_in(1, 20);
            let mut s = HiftScheduler::new(cfg(m, 1.0), n);
            let k = s.k();
            let sweep1: Vec<Vec<usize>> = (0..k).map(|_| s.next().units).collect();
            let sweep2: Vec<Vec<usize>> = (0..k).map(|_| s.next().units).collect();
            prop_assert(sweep1 == sweep2, format!("groups drift: n={n} m={m}"))?;
            Ok(())
        });
    }
}
