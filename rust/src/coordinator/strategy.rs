//! Update strategies (paper Figure 1): the *order* in which layer units are
//! visited.  The paper's finding (§4.6, Figure 4-left) is that this order
//! does not affect final quality; `bench_fig4` reproduces that.

use crate::rng::Pcg32;

/// S ∈ {bottom2up, top2down, random} (Algorithm 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdateStrategy {
    /// Embedding layer first, head last (paper default).
    Bottom2Up,
    /// Head first, embedding last.
    Top2Down,
    /// One seeded shuffle *before* training; the order then stays fixed for
    /// the whole run ("avoids the instability caused by constant changes in
    /// the update order", §3.1).
    Random { seed: u64 },
}

impl UpdateStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            UpdateStrategy::Bottom2Up => "bottom2up",
            UpdateStrategy::Top2Down => "top2down",
            UpdateStrategy::Random { .. } => "random",
        }
    }

    pub fn parse(s: &str, seed: u64) -> Option<UpdateStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "b2u" | "bottom2up" => Some(UpdateStrategy::Bottom2Up),
            "t2d" | "top2down" => Some(UpdateStrategy::Top2Down),
            "ran" | "random" => Some(UpdateStrategy::Random { seed }),
            _ => None,
        }
    }

    /// The initial unit visit order for a model with `n_units` layer units
    /// (unit 0 = embeddings … unit n-1 = head, matching the manifest).
    pub fn order(&self, n_units: usize) -> Vec<usize> {
        let mut ids: Vec<usize> = (0..n_units).collect();
        match self {
            UpdateStrategy::Bottom2Up => {}
            UpdateStrategy::Top2Down => ids.reverse(),
            UpdateStrategy::Random { seed } => Pcg32::seeded(*seed).shuffle(&mut ids),
        }
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{prop_assert, run};

    #[test]
    fn b2u_and_t2d_are_reverses() {
        let b = UpdateStrategy::Bottom2Up.order(6);
        let mut t = UpdateStrategy::Top2Down.order(6);
        t.reverse();
        assert_eq!(b, t);
        assert_eq!(b, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn random_is_fixed_per_seed() {
        let a = UpdateStrategy::Random { seed: 3 }.order(10);
        let b = UpdateStrategy::Random { seed: 3 }.order(10);
        let c = UpdateStrategy::Random { seed: 4 }.order(10);
        assert_eq!(a, b, "same seed = same order (stability requirement §3.1)");
        assert_ne!(a, c);
    }

    #[test]
    fn prop_every_order_is_a_permutation() {
        run(100, |g| {
            let n = g.usize_in(1, 64);
            let seed = g.i64_in(0, 1 << 40) as u64;
            for s in [
                UpdateStrategy::Bottom2Up,
                UpdateStrategy::Top2Down,
                UpdateStrategy::Random { seed },
            ] {
                let mut o = s.order(n);
                o.sort_unstable();
                prop_assert(o == (0..n).collect::<Vec<_>>(), format!("{s:?} not a permutation"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn parse_aliases() {
        assert_eq!(UpdateStrategy::parse("B2U", 0), Some(UpdateStrategy::Bottom2Up));
        assert_eq!(UpdateStrategy::parse("top2down", 0), Some(UpdateStrategy::Top2Down));
        assert!(matches!(UpdateStrategy::parse("ran", 7), Some(UpdateStrategy::Random { seed: 7 })));
        assert_eq!(UpdateStrategy::parse("zigzag", 0), None);
    }
}
