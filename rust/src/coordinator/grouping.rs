//! Grouping: n layer units → k groups of m (paper §3 Notation).
//!
//! `k = n/m` when m | n, else `⌊n/m⌋ + 1` with a short final group.  The
//! paper's §4.7 (Figure 4-right) shows quality is insensitive to m;
//! `bench_fig4` reproduces that, and the memory model consumes `k` for the
//! Appendix-B identity ζ_hift = (k+3)/k · ζ₁.

/// Static partition of strategy-ordered units into contiguous groups.
#[derive(Debug, Clone, PartialEq)]
pub struct Grouping {
    pub n_units: usize,
    pub m: usize,
    /// Unit ids per group, in update order.
    pub groups: Vec<Vec<usize>>,
}

impl Grouping {
    /// Partition `order` (a strategy-ordered unit permutation) into groups
    /// of `m`.
    pub fn new(order: &[usize], m: usize) -> Self {
        assert!(m >= 1, "m must be >= 1");
        let groups: Vec<Vec<usize>> = order.chunks(m).map(|c| c.to_vec()).collect();
        Grouping { n_units: order.len(), m, groups }
    }

    /// Number of groups k.
    pub fn k(&self) -> usize {
        self.groups.len()
    }

    /// The paper's k formula — must agree with the actual partition.
    pub fn k_formula(n: usize, m: usize) -> usize {
        if n % m == 0 {
            n / m
        } else {
            n / m + 1
        }
    }

    /// Which group contains unit `u`.
    pub fn group_of(&self, u: usize) -> Option<usize> {
        self.groups.iter().position(|g| g.contains(&u))
    }

    /// Largest group size (drives peak per-step trainable parameters).
    pub fn max_group_len(&self) -> usize {
        self.groups.iter().map(Vec::len).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{prop_assert, run};

    #[test]
    fn divisible_grouping() {
        let g = Grouping::new(&[0, 1, 2, 3, 4, 5], 2);
        assert_eq!(g.k(), 3);
        assert_eq!(g.groups, vec![vec![0, 1], vec![2, 3], vec![4, 5]]);
    }

    #[test]
    fn non_divisible_has_short_tail() {
        let g = Grouping::new(&[0, 1, 2, 3, 4], 2);
        assert_eq!(g.k(), 3);
        assert_eq!(g.groups[2], vec![4]);
        assert_eq!(g.max_group_len(), 2);
    }

    #[test]
    fn group_of_lookup() {
        let g = Grouping::new(&[5, 3, 1, 0], 3);
        assert_eq!(g.group_of(3), Some(0));
        assert_eq!(g.group_of(0), Some(1));
        assert_eq!(g.group_of(9), None);
    }

    #[test]
    fn prop_k_matches_paper_formula() {
        run(300, |g| {
            let n = g.usize_in(1, 100);
            let m = g.usize_in(1, 100);
            let order: Vec<usize> = (0..n).collect();
            let grouping = Grouping::new(&order, m);
            prop_assert(
                grouping.k() == Grouping::k_formula(n, m),
                format!("k mismatch n={n} m={m}"),
            )?;
            // groups partition the units
            let mut all: Vec<usize> = grouping.groups.concat();
            all.sort_unstable();
            prop_assert(all == order, "groups must partition units")?;
            Ok(())
        });
    }

    #[test]
    fn m_one_gives_one_unit_per_group() {
        let g = Grouping::new(&[0, 1, 2], 1);
        assert_eq!(g.k(), 3);
        assert!(g.groups.iter().all(|gr| gr.len() == 1));
    }

    #[test]
    fn m_geq_n_gives_fpft_like_single_group() {
        let g = Grouping::new(&[0, 1, 2], 8);
        assert_eq!(g.k(), 1, "m >= n degenerates to one group = FPFT schedule");
    }
}
