//! Flat f32 tensors — the parameter/gradient containers the optimizers and
//! the MeZO perturbation path operate on.
//!
//! Parameters live in Rust (`Vec<f32>`), are marshalled to PJRT literals per
//! step, and updated in place by the optimizers.  The math here (axpy-style
//! loops) is the L3 hot path profiled in EXPERIMENTS.md §Perf.

pub mod checkpoint;
pub mod half;
pub mod paged;

use crate::rng::Pcg32;

/// A dense f32 tensor: contiguous data + shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Tensor { data: vec![1.0; shape.iter().product()], shape: shape.to_vec() }
    }

    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor { data, shape: shape.to_vec() }
    }

    /// Normal(0, std) init, deterministic per (seed).
    pub fn randn(shape: &[usize], std: f32, rng: &mut Pcg32) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data);
        for x in &mut t.data {
            *x *= std;
        }
        t
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Bytes at f32.
    pub fn bytes(&self) -> usize {
        self.numel() * 4
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    pub fn abs_max(&self) -> f32 {
        // hift-lint: allow(float-reduction): max of absolute values is order-insensitive
        self.data.iter().fold(0f32, |m, x| m.max(x.abs()))
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        (self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len() as f64) as f32
    }

    /// `self += alpha * other` (shape-checked).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Read a tensor slice out of a little-endian f32 byte buffer.
    pub fn from_le_bytes(bytes: &[u8], shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert!(bytes.len() >= n * 4, "buffer too small: {} < {}", bytes.len(), n * 4);
        let data = bytes[..n * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Tensor { data, shape: shape.to_vec() }
    }

    /// Serialize as little-endian f32 bytes.
    pub fn to_le_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.data.len() * 4);
        for x in &self.data {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }
}

/// A named, ordered set of tensors (the model's flat parameter list).
///
/// Mutation through [`TensorSet::tensor_mut`] bumps a per-tensor version
/// counter; the PJRT runtime uses `(set id, index, version)` to keep
/// device-resident copies of *unchanged* tensors across steps — the reason
/// HiFT's frozen-majority steps avoid re-uploading the whole model
/// (EXPERIMENTS.md §Perf).  Mutating `tensors` directly is allowed but
/// bypasses the cache (the runtime would keep serving the stale device
/// copy), so all optimizer paths go through `tensor_mut`.
#[derive(Debug, Default)]
pub struct TensorSet {
    pub names: Vec<String>,
    pub tensors: Vec<Tensor>,
    versions: Vec<u64>,
    id: u64,
}

/// Global TensorSet id source (distinguishes cache lineages).
static NEXT_SET_ID: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

impl Clone for TensorSet {
    /// Clones get a fresh cache lineage: the same `(id, version)` pair must
    /// never refer to two different tensor contents.
    fn clone(&self) -> Self {
        TensorSet {
            names: self.names.clone(),
            tensors: self.tensors.clone(),
            versions: self.versions.clone(),
            id: NEXT_SET_ID.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
        }
    }
}

impl TensorSet {
    pub fn new() -> Self {
        TensorSet {
            names: Vec::new(),
            tensors: Vec::new(),
            versions: Vec::new(),
            id: NEXT_SET_ID.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
        }
    }

    pub fn push(&mut self, name: impl Into<String>, t: Tensor) {
        self.names.push(name.into());
        self.tensors.push(t);
        self.versions.push(0);
    }

    /// Mutable access that invalidates the runtime's device-buffer cache
    /// entry for tensor `i`.
    pub fn tensor_mut(&mut self, i: usize) -> &mut Tensor {
        self.versions[i] += 1;
        &mut self.tensors[i]
    }

    /// Device-buffer cache key for tensor `i`: (set lineage id, version).
    pub fn cache_key(&self, i: usize) -> (u64, u64) {
        (self.id, self.versions[i])
    }

    /// This set's cache-lineage id (unique per clone).  The host paging
    /// tier keys its pool on it: evicted pages belong to one lineage, and a
    /// fresh parameter set (new `load_params`, checkpoint resume) resets
    /// the pool rather than aliasing a dead set's pages.
    pub fn lineage(&self) -> u64 {
        self.id
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index_of(name).map(|i| &self.tensors[i])
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(Tensor::numel).sum()
    }

    pub fn total_bytes(&self) -> usize {
        self.total_params() * 4
    }

    pub fn l2_norm(&self) -> f32 {
        let ss: f64 = self
            .tensors
            .iter()
            .map(|t| t.data.iter().map(|x| (*x as f64).powi(2)).sum::<f64>())
            .sum();
        ss.sqrt() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_ones_shapes() {
        let z = Tensor::zeros(&[2, 3]);
        assert_eq!(z.numel(), 6);
        assert_eq!(z.bytes(), 24);
        assert_eq!(Tensor::ones(&[4]).data, vec![1.0; 4]);
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_shape() {
        Tensor::from_vec(vec![1.0, 2.0], &[3]);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::ones(&[3]);
        let b = Tensor::from_vec(vec![1.0, 2.0, 3.0], &[3]);
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![3.0, 5.0, 7.0]);
        a.scale(0.5);
        assert_eq!(a.data, vec![1.5, 2.5, 3.5]);
    }

    #[test]
    fn le_bytes_roundtrip() {
        let t = Tensor::from_vec(vec![1.5, -2.25, 1e-7, 3e8], &[2, 2]);
        let b = t.to_le_bytes();
        assert_eq!(Tensor::from_le_bytes(&b, &[2, 2]), t);
    }

    #[test]
    fn norms() {
        let t = Tensor::from_vec(vec![3.0, 4.0], &[2]);
        assert!((t.l2_norm() - 5.0).abs() < 1e-6);
        assert_eq!(t.abs_max(), 4.0);
        assert!((t.mean() - 3.5).abs() < 1e-6);
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Pcg32::seeded(5);
        let mut r2 = Pcg32::seeded(5);
        assert_eq!(Tensor::randn(&[8], 0.1, &mut r1), Tensor::randn(&[8], 0.1, &mut r2));
    }

    #[test]
    fn tensorset_lookup() {
        let mut s = TensorSet::new();
        s.push("a", Tensor::zeros(&[2]));
        s.push("b", Tensor::ones(&[3]));
        assert_eq!(s.index_of("b"), Some(1));
        assert_eq!(s.total_params(), 5);
        assert_eq!(s.total_bytes(), 20);
        assert!(s.get("c").is_none());
    }

    #[test]
    fn finite_detection() {
        let mut t = Tensor::ones(&[2]);
        assert!(t.is_finite());
        t.data[1] = f32::NAN;
        assert!(!t.is_finite());
    }
}
