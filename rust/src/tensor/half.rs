//! Shared half-precision support: the IEEE binary16 (f16) and bfloat16
//! codecs, the [`Precision`] compute-mode selector, and [`PrecBuf`] — a
//! precision-tagged activation buffer that genuinely stores half-width bits
//! when a reduced-precision mode is active.
//!
//! The f16 codec started life inside the host-paging tier
//! (`tensor/paged.rs`, `--offload-compress f16`) and was promoted here when
//! the compute path gained `--precision bf16|f16`: both consumers now share
//! one round-to-nearest-even implementation, so paged storage and compute
//! quantization can never drift apart.
//!
//! ## Non-finite and out-of-range behavior (defined, deterministic)
//!
//! * NaN (any payload) → the **canonical quiet NaN** of the target format
//!   (f16 `0x7e00`, bf16 `0x7fc0`), sign preserved.  Payloads are *not*
//!   carried across the round trip — two encodes of different NaNs yield
//!   the same bits, so paged/requantized runs stay deterministic.
//! * ±Inf → ±Inf.
//! * |x| > max finite target value → ±Inf (overflow rounds to infinity,
//!   matching IEEE round-to-nearest).  For bf16 this happens through the
//!   ordinary mantissa-carry path; f16 checks the exponent explicitly.
//! * |x| below the smallest subnormal → ±0 (sign preserved).
//!
//! Every decoded value is exactly representable in f32, so a second
//! round trip is a fixed point (idempotency is what lets a parked page or a
//! requantized activation sit through arbitrarily many round trips without
//! further drift) — asserted in the tests below for normals, subnormals,
//! and the non-finite edges.

use std::borrow::Cow;

use anyhow::{bail, Result};

// ---------------------------------------------------------------------------
// f16 codec
// ---------------------------------------------------------------------------

/// f32 → IEEE-754 binary16 bits, round-to-nearest-even (ties-to-even):
/// NaN → canonical quiet NaN (sign kept), overflow → ±inf, graceful
/// subnormals, underflow → signed zero.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // inf stays inf; every NaN collapses to the canonical quiet NaN so
        // the round trip is deterministic and idempotent.
        return sign | if man != 0 { 0x7e00 } else { 0x7c00 };
    }
    let e16 = exp - 127 + 15;
    if e16 >= 0x1f {
        return sign | 0x7c00; // overflow → inf
    }
    if e16 <= 0 {
        if e16 < -10 {
            return sign; // underflow → signed zero
        }
        // subnormal: shift the (implicit-1) 24-bit mantissa into place
        let man = man | 0x0080_0000;
        let shift = (14 - e16) as u32; // 14..=24
        let half = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded =
            if rem > halfway || (rem == halfway && (half & 1) == 1) { half + 1 } else { half };
        return sign | rounded as u16;
    }
    let half = ((e16 as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    // Mantissa overflow carries into the exponent, which is the correct
    // rounding there too (… 0x7bff + 1 = 0x7c00 = inf).
    let rounded = if rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1) { half + 1 } else { half };
    sign | rounded as u16
}

/// IEEE-754 binary16 bits → f32 (exact — every f16 value is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign
        } else {
            // subnormal: normalize into f32's implicit-1 form
            let mut e32: i32 = 127 - 15 + 1;
            let mut m = man << 13;
            while m & 0x0080_0000 == 0 {
                m <<= 1;
                e32 -= 1;
            }
            sign | ((e32 as u32) << 23) | (m & 0x007f_ffff)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

// ---------------------------------------------------------------------------
// bf16 codec
// ---------------------------------------------------------------------------

/// f32 → bfloat16 bits, round-to-nearest-even.  bf16 keeps f32's exponent
/// range, so there is no overflow-to-inf short of rounding f32::MAX's
/// mantissa upward (which correctly carries into ±inf); NaN collapses to
/// the canonical quiet NaN `0x7fc0` (sign kept).
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16 & 0x8000) | 0x7fc0;
    }
    let upper = bits >> 16;
    let lower = bits & 0xffff;
    let rounded =
        if lower > 0x8000 || (lower == 0x8000 && (upper & 1) == 1) { upper + 1 } else { upper };
    rounded as u16
}

/// bfloat16 bits → f32 (exact: bf16 is f32's top half).
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

// ---------------------------------------------------------------------------
// Precision — the compute-mode selector
// ---------------------------------------------------------------------------

/// Compute precision of the native backend's forward activations, backward
/// intermediates and emitted (pre-upcast) gradients.  Parameter masters and
/// optimizer state stay f32 regardless (mixed precision with full-precision
/// master state, the QFT/ChunkFT recipe the paper's §G.2 builds on).
///
/// `F32` is the default and is **bit-identical** to the historical
/// f32-everywhere path: every quantization hook is a structural no-op.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full f32 compute (bit-identical to pre-precision-mode builds).
    #[default]
    F32,
    /// bfloat16 compute: f32's exponent range, 8-bit mantissa.  Runs
    /// unscaled — overflow is as (un)likely as in f32.
    Bf16,
    /// IEEE binary16 compute: 11-bit mantissa but max finite value 65504,
    /// so backward runs under dynamic loss scaling
    /// ([`crate::optim::LossScaler`]) with skip-step on overflow.
    F16,
}

impl Precision {
    /// Parse `"f32"`, `"bf16"`, `"f16"` (plus common aliases).
    pub fn parse(s: &str) -> Result<Precision> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "f32" | "fp32" | "float32" | "full" => Ok(Precision::F32),
            "bf16" | "bfloat16" => Ok(Precision::Bf16),
            "f16" | "fp16" | "half" | "float16" => Ok(Precision::F16),
            other => bail!("bad precision {other:?} (f32|bf16|f16)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            Precision::F16 => "f16",
        }
    }

    /// Storage bytes per activation element in this precision.
    pub fn act_bytes_per_elem(&self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::Bf16 | Precision::F16 => 2,
        }
    }

    /// Does backward need dynamic loss scaling in this precision?  Only
    /// f16: its max finite value (65504) is small enough that honest
    /// gradients overflow; bf16 shares f32's exponent range.
    pub fn needs_loss_scaling(&self) -> bool {
        *self == Precision::F16
    }

    /// Round one value to this precision's representable set (identity for
    /// [`Precision::F32`]).
    #[inline]
    pub fn quantize(&self, x: f32) -> f32 {
        match self {
            Precision::F32 => x,
            Precision::Bf16 => bf16_bits_to_f32(f32_to_bf16_bits(x)),
            Precision::F16 => f16_bits_to_f32(f32_to_f16_bits(x)),
        }
    }

    /// Round a buffer in place.  [`Precision::F32`] returns without
    /// touching the slice at all, so the default path stays bit-identical
    /// by construction.
    pub fn quantize_slice(&self, data: &mut [f32]) {
        match self {
            Precision::F32 => {}
            Precision::Bf16 => {
                for x in data.iter_mut() {
                    *x = bf16_bits_to_f32(f32_to_bf16_bits(*x));
                }
            }
            Precision::F16 => {
                for x in data.iter_mut() {
                    *x = f16_bits_to_f32(f32_to_f16_bits(*x));
                }
            }
        }
    }

    /// Encode one value to this precision's 16-bit storage form.  Only
    /// meaningful for the half modes ([`PrecBuf`] never calls it for f32).
    #[inline]
    fn encode(&self, x: f32) -> u16 {
        match self {
            Precision::F32 => unreachable!("f32 buffers are stored as f32"),
            Precision::Bf16 => f32_to_bf16_bits(x),
            Precision::F16 => f32_to_f16_bits(x),
        }
    }

    /// Decode one 16-bit stored value back to f32.
    #[inline]
    fn decode(&self, h: u16) -> f32 {
        match self {
            Precision::F32 => unreachable!("f32 buffers are stored as f32"),
            Precision::Bf16 => bf16_bits_to_f32(h),
            Precision::F16 => f16_bits_to_f32(h),
        }
    }

    /// Validate that a checkpoint written at `saved` precision (`None` for
    /// pre-precision checkpoints, which were necessarily f32) may resume
    /// under `current`.  A mismatch is rejected: the loss surface the run
    /// was descending, the activation drift profile and the loss-scaler
    /// state are all precision-specific, so silently switching would
    /// corrupt the "resume is bit-identical" contract.
    pub fn check_resume(saved: Option<&str>, current: Precision) -> Result<()> {
        let saved_p = match saved {
            Some(s) => Precision::parse(s)?,
            None => Precision::F32,
        };
        if saved_p != current {
            bail!(
                "checkpoint was written at --precision {} but this run uses --precision {}; \
                 resume with the matching precision (or start a fresh run)",
                saved_p.name(),
                current.name()
            );
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// PrecBuf — a precision-tagged activation buffer
// ---------------------------------------------------------------------------

/// An activation buffer stored at the compute precision's width: plain
/// `Vec<f32>` under [`Precision::F32`] (zero-cost, bit-identical), packed
/// 16-bit codewords under the half modes — the storage that genuinely
/// halves retained-activation residency (`FwdState::act_resident_bytes`,
/// `peak_act_resident_bytes`), not just an accounting fiction.
///
/// [`PrecBuf::store`] rounds through the codec; storing values that are
/// already representable (the model quantizes in place right after each
/// op, then stores) is exact, so load-after-store returns precisely the
/// values compute saw.
#[derive(Debug, Clone)]
pub enum PrecBuf {
    F32(Vec<f32>),
    Half { prec: Precision, bits: Vec<u16> },
}

impl PrecBuf {
    /// Wrap (f32) or encode (half modes) `data` at `prec`.
    pub fn store(prec: Precision, data: Vec<f32>) -> PrecBuf {
        match prec {
            Precision::F32 => PrecBuf::F32(data),
            p => PrecBuf::Half { prec: p, bits: data.iter().map(|&x| p.encode(x)).collect() },
        }
    }

    /// An empty f32 buffer (placeholder for variant-dependent caches).
    pub fn empty() -> PrecBuf {
        PrecBuf::F32(Vec::new())
    }

    /// Decode to f32 for compute: borrowed (free) for f32 buffers, an owned
    /// decode for half buffers.
    pub fn load(&self) -> Cow<'_, [f32]> {
        match self {
            PrecBuf::F32(v) => Cow::Borrowed(v.as_slice()),
            PrecBuf::Half { prec, bits } => {
                Cow::Owned(bits.iter().map(|&h| prec.decode(h)).collect())
            }
        }
    }

    /// Decode into an owned `Vec<f32>` (moves the f32 case out for free).
    pub fn into_vec(self) -> Vec<f32> {
        match self {
            PrecBuf::F32(v) => v,
            PrecBuf::Half { prec, bits } => bits.into_iter().map(|h| prec.decode(h)).collect(),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            PrecBuf::F32(v) => v.len(),
            PrecBuf::Half { bits, .. } => bits.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Physical storage bytes (4 per element for f32, 2 for half modes).
    pub fn bytes(&self) -> usize {
        match self {
            PrecBuf::F32(v) => v.len() * 4,
            PrecBuf::Half { bits, .. } => bits.len() * 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_roundtrip_is_idempotent_and_exact_on_representables() {
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 65504.0, -65504.0, 2.0f32.powi(-14), 0.099976] {
            let once = f16_bits_to_f32(f32_to_f16_bits(x));
            let twice = f16_bits_to_f32(f32_to_f16_bits(once));
            assert_eq!(once.to_bits(), twice.to_bits(), "roundtrip must be idempotent for {x}");
        }
        // exactly-representable values survive untouched
        for &x in &[1.0f32, 0.25, -3.5, 1024.0, 2.0f32.powi(-24)] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(x)), x, "{x} is f16-exact");
        }
    }

    #[test]
    fn f16_handles_specials_and_rounding() {
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f32_to_f16_bits(1e9), 0x7c00, "overflow → inf");
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00, "just past max_f16 rounds to inf");
        assert_eq!(f32_to_f16_bits(1e-9), 0, "underflow → 0");
        assert_eq!(f32_to_f16_bits(-1e-9), 0x8000, "underflow keeps the sign");
        // ties-to-even: 1 + 2^-11 is exactly halfway between 1.0 and the
        // next f16 (1 + 2^-10) → rounds to the even mantissa (0x3c00).
        let tie = 1.0f32 + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16_bits(tie), 0x3c00, "tie rounds to even");
        // error of a random-ish value is within half an ulp (2^-11 rel.)
        let x = 0.123456789f32;
        let r = f16_bits_to_f32(f32_to_f16_bits(x));
        assert!((r - x).abs() / x < 1e-3, "{x} → {r}");
    }

    #[test]
    fn f16_nan_is_canonical_and_deterministic() {
        // Two NaNs with different payloads must encode to the same bits —
        // the round trip defines ONE representative per sign.
        let nan_a = f32::from_bits(0x7fc0_0001);
        let nan_b = f32::from_bits(0x7f80_0001);
        assert_eq!(f32_to_f16_bits(nan_a), 0x7e00);
        assert_eq!(f32_to_f16_bits(nan_b), 0x7e00);
        let neg_nan = f32::from_bits(0xffc1_2345);
        assert_eq!(f32_to_f16_bits(neg_nan), 0xfe00, "sign survives canonicalization");
        // idempotent: decode(encode(NaN)) re-encodes to the same bits
        let once = f16_bits_to_f32(0x7e00);
        assert!(once.is_nan());
        assert_eq!(f32_to_f16_bits(once), 0x7e00);
    }

    #[test]
    fn f16_infinities_roundtrip_exactly() {
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        // overflow-to-inf is sticky: the decoded inf re-encodes as inf
        let over = f16_bits_to_f32(f32_to_f16_bits(1e30));
        assert_eq!(over, f32::INFINITY);
        assert_eq!(f32_to_f16_bits(over), 0x7c00);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1e30)), f32::NEG_INFINITY);
    }

    #[test]
    fn bf16_roundtrip_and_specials() {
        // bf16-exact values survive untouched (any f32 with a 7-bit mantissa)
        for &x in &[0.0f32, -0.0, 1.0, -2.0, 0.5, 1.5, 3.0e38, 1e-38] {
            let r = bf16_bits_to_f32(f32_to_bf16_bits(x));
            let rr = bf16_bits_to_f32(f32_to_bf16_bits(r));
            assert_eq!(r.to_bits(), rr.to_bits(), "idempotent for {x}");
        }
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(1.0)), 1.0);
        assert_eq!(f32_to_bf16_bits(f32::INFINITY), 0x7f80);
        assert_eq!(f32_to_bf16_bits(f32::NEG_INFINITY), 0xff80);
        // NaN → canonical 0x7fc0 (sign kept), regardless of payload
        assert_eq!(f32_to_bf16_bits(f32::NAN) & 0x7fff, 0x7fc0);
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0xff80_0001)), 0xffc0);
        assert!(bf16_bits_to_f32(0x7fc0).is_nan());
        // f32::MAX's mantissa rounds up → carries into inf (defined overflow)
        assert_eq!(f32_to_bf16_bits(f32::MAX), 0x7f80);
        assert_eq!(f32_to_bf16_bits(-f32::MAX), 0xff80);
        // ties-to-even on the 16th bit: 1 + 2^-8 is halfway between
        // 1.0 (0x3f80) and the next bf16 (0x3f81) → even wins (0x3f80).
        assert_eq!(f32_to_bf16_bits(1.0 + 2.0f32.powi(-8)), 0x3f80);
        // relative error bound ~2^-8
        let x = 0.123456789f32;
        let r = bf16_bits_to_f32(f32_to_bf16_bits(x));
        assert!((r - x).abs() / x < 4e-3, "{x} → {r}");
    }

    #[test]
    fn precision_parse_and_props() {
        assert_eq!(Precision::parse("f32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("FP32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("bf16").unwrap(), Precision::Bf16);
        assert_eq!(Precision::parse("half").unwrap(), Precision::F16);
        assert!(Precision::parse("f8").is_err());
        for p in [Precision::F32, Precision::Bf16, Precision::F16] {
            assert_eq!(Precision::parse(p.name()).unwrap(), p);
        }
        assert_eq!(Precision::F32.act_bytes_per_elem(), 4);
        assert_eq!(Precision::Bf16.act_bytes_per_elem(), 2);
        assert!(Precision::F16.needs_loss_scaling());
        assert!(!Precision::Bf16.needs_loss_scaling());
    }

    #[test]
    fn quantize_slice_is_a_true_noop_for_f32() {
        let orig = vec![0.1f32, f32::NAN, 1e30, -0.0];
        let mut v = orig.clone();
        Precision::F32.quantize_slice(&mut v);
        for (a, b) in v.iter().zip(&orig) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 mode must not rewrite any bit");
        }
    }

    #[test]
    fn precbuf_storage_width_and_roundtrip() {
        let data = vec![0.5f32, -1.25, 3.0, 0.099976];
        let b32 = PrecBuf::store(Precision::F32, data.clone());
        assert_eq!(b32.bytes(), 16);
        assert_eq!(b32.load().as_ref(), data.as_slice(), "f32 load is verbatim");

        let b16 = PrecBuf::store(Precision::F16, data.clone());
        assert_eq!(b16.bytes(), 8, "half storage is physically half");
        assert_eq!(b16.len(), 4);
        let dec = b16.load();
        assert_eq!(dec[0], 0.5, "f16-exact values survive");
        // store(quantized) is exact: quantize first, then store+load
        let mut q = data.clone();
        Precision::F16.quantize_slice(&mut q);
        let b = PrecBuf::store(Precision::F16, q.clone());
        assert_eq!(b.load().as_ref(), q.as_slice(), "load-after-store of representables is exact");
        assert_eq!(b.into_vec(), q);
        assert!(PrecBuf::empty().is_empty());
    }

    #[test]
    fn resume_precision_check() {
        use Precision::*;
        assert!(Precision::check_resume(None, F32).is_ok(), "legacy checkpoints are f32");
        assert!(Precision::check_resume(Some("f32"), F32).is_ok());
        assert!(Precision::check_resume(Some("bf16"), Bf16).is_ok());
        assert!(Precision::check_resume(None, F16).is_err());
        assert!(Precision::check_resume(Some("f16"), F32).is_err());
        let err = Precision::check_resume(Some("f32"), Bf16).unwrap_err().to_string();
        assert!(err.contains("precision"), "{err}");
        assert!(Precision::check_resume(Some("garbage"), F32).is_err());
    }
}
