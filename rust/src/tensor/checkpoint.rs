//! Checkpointing: save/restore a [`TensorSet`] + run metadata + optimizer
//! state so long HiFT runs survive a crash and resume **bit-identically**.
//!
//! What must persist for exact resume: the parameters, the optimizer's
//! per-tensor moments (`opt.bin`; AdamW's m/v and step counts, momentum
//! buffers, Adafactor factors), and the schedule position — Algorithm 1's
//! step counter plus the delayed-LR **sweep** index (§3.1), both in
//! [`CkptMeta`], so a resumed run continues the sweep-aligned LR schedule
//! instead of restarting it.
//!
//! Format: `<dir>/ckpt.json` (names, shapes, offsets, metadata, schema 2) +
//! `<dir>/params.bin` (+ `<dir>/opt.bin` when optimizer state exists), all
//! concatenated little-endian f32 in manifest order — the same layout
//! `aot.py` emits, so a checkpoint is loadable anywhere an artifact bundle
//! is.  Schema-1 checkpoints (params only) still load.
//!
//! [`load`] is strict: out-of-range offsets, overflowing or non-integer
//! shapes, overlapping regions and duplicate tensor names are all rejected
//! with an error — corrupt metadata must never panic or alias buffers.
//! [`save_replace`] writes into a temp dir and swaps it into place, so a
//! crash mid-save leaves either the previous checkpoint or none, never a
//! torn one.

use std::collections::HashSet;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Tensor, TensorSet};
use crate::ser::{emit_pretty, parse, Value};

/// Checkpoint metadata persisted alongside the weights.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CkptMeta {
    /// Training steps completed (Algorithm 1's `t`).
    pub step: u64,
    /// Delayed-LR schedule index (sweeps completed) at save time; resume
    /// cross-checks it against the replayed scheduler so the sweep-aligned
    /// LR schedule continues correctly (§3.1).  `None` for schema-1
    /// checkpoints, which predate the field — resume then skips the
    /// cross-check instead of falsely rejecting the checkpoint.
    pub sweep: Option<u64>,
    pub strategy: String,
    pub task: String,
    /// Compute precision the run trained at (`"f32"|"bf16"|"f16"`).
    /// Resume rejects a precision mismatch
    /// ([`crate::tensor::half::Precision::check_resume`]); `None` for
    /// checkpoints predating the field, which were necessarily f32.
    pub precision: Option<String>,
}

/// A loaded checkpoint.
#[derive(Debug)]
pub struct Ckpt {
    pub params: TensorSet,
    pub meta: CkptMeta,
    /// Optimizer state tensors keyed `"{param idx}.{field}"`
    /// (see `Optimizer::export_state`); empty when the checkpoint carries
    /// none (schema 1, or a stateless optimizer).
    pub opt_state: Vec<(String, Tensor)>,
}

fn tensor_section<'a>(
    tensors: impl Iterator<Item = (&'a str, &'a Tensor)>,
) -> (Vec<u8>, Value, usize) {
    let mut bin = Vec::new();
    let mut entries = Vec::new();
    let mut offset = 0usize;
    for (name, t) in tensors {
        bin.extend_from_slice(&t.to_le_bytes());
        entries.push(Value::obj(vec![
            ("name", name.into()),
            ("shape", Value::Arr(t.shape.iter().map(|&d| d.into()).collect())),
            ("offset", offset.into()),
        ]));
        offset += t.bytes();
    }
    (bin, Value::Arr(entries), offset)
}

/// Write `params` + metadata (+ optimizer state, if any) to `dir` (created
/// if missing).  Prefer [`save_replace`] for periodic in-place saves.
pub fn save(
    dir: impl AsRef<Path>,
    params: &TensorSet,
    meta: &CkptMeta,
    opt_state: &[(String, Tensor)],
) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let (bin, tensors, total) =
        tensor_section(params.names.iter().map(String::as_str).zip(&params.tensors));
    std::fs::write(dir.join("params.bin"), &bin)?;
    let mut pairs: Vec<(&str, Value)> = vec![
        ("schema", 2usize.into()),
        ("step", (meta.step as usize).into()),
        ("strategy", meta.strategy.as_str().into()),
        ("task", meta.task.as_str().into()),
        ("total_bytes", total.into()),
        ("tensors", tensors),
    ];
    if let Some(sweep) = meta.sweep {
        pairs.push(("sweep", (sweep as usize).into()));
    }
    if let Some(prec) = &meta.precision {
        pairs.push(("precision", prec.as_str().into()));
    }
    if !opt_state.is_empty() {
        let (obin, otensors, ototal) =
            tensor_section(opt_state.iter().map(|(n, t)| (n.as_str(), t)));
        std::fs::write(dir.join("opt.bin"), &obin)?;
        pairs.push(("opt_total_bytes", ototal.into()));
        pairs.push(("opt_tensors", otensors));
    }
    std::fs::write(dir.join("ckpt.json"), emit_pretty(&Value::obj(pairs)))?;
    Ok(())
}

/// Crash-safe overwrite: write the whole checkpoint into a fresh sibling
/// temp dir, then swap it into place with a rename.  A crash mid-save
/// leaves either the previous checkpoint or no checkpoint — and a torn
/// directory from a crash mid-swap is rejected by [`load`]'s validation
/// rather than silently resuming from garbage.
pub fn save_replace(
    dir: impl AsRef<Path>,
    params: &TensorSet,
    meta: &CkptMeta,
    opt_state: &[(String, Tensor)],
) -> Result<()> {
    let dir = dir.as_ref();
    // Build the temp dir as a true *sibling* via parent + file_name — naive
    // string-appending would turn a trailing-slash path ("runs/ckpt/") into
    // a temp dir *inside* the target, which the swap below would destroy.
    let Some(name) = dir.file_name() else {
        bail!("checkpoint path {} has no final component to save into", dir.display());
    };
    let mut tmp_name = name.to_os_string();
    tmp_name.push(format!(".tmp.{}", std::process::id()));
    let tmp = dir.parent().unwrap_or_else(|| Path::new("")).join(tmp_name);
    if tmp.exists() {
        std::fs::remove_dir_all(&tmp)?;
    }
    save(&tmp, params, meta, opt_state)?;
    if dir.exists() {
        std::fs::remove_dir_all(dir)
            .with_context(|| format!("clearing previous checkpoint at {}", dir.display()))?;
    }
    std::fs::rename(&tmp, dir)
        .with_context(|| format!("installing checkpoint at {}", dir.display()))?;
    Ok(())
}

/// Strict non-negative-integer read (the permissive `as usize` cast would
/// silently fold corrupt negative/fractional numbers to valid offsets).
fn strict_usize(v: &Value, what: &str) -> Result<usize> {
    let n = v.as_f64().with_context(|| format!("{what}: not a number"))?;
    if !n.is_finite() || n < 0.0 || n.fract() != 0.0 || n > 9.007_199_254_740_992e15 {
        bail!("{what}: {n} is not a valid size/offset");
    }
    Ok(n as usize)
}

/// Parse + validate one serialized tensor section.  Every entry must name a
/// unique tensor whose `[offset, offset + numel*4)` region lies inside
/// `bin` and overlaps no other entry.
fn read_tensors(section: &Value, bin: &[u8], what: &str) -> Result<Vec<(String, Tensor)>> {
    let arr = section.as_arr().with_context(|| format!("{what}: tensor list missing"))?;
    let mut out = Vec::with_capacity(arr.len());
    let mut regions: Vec<(usize, usize)> = Vec::with_capacity(arr.len());
    let mut names: HashSet<String> = HashSet::with_capacity(arr.len());
    for (i, t) in arr.iter().enumerate() {
        let name = t.get("name").as_str().with_context(|| format!("{what}[{i}]: name"))?;
        if !names.insert(name.to_string()) {
            bail!("{what}: duplicate tensor name {name:?}");
        }
        let shape_v =
            t.get("shape").as_arr().with_context(|| format!("{what} {name:?}: shape"))?;
        let mut shape = Vec::with_capacity(shape_v.len());
        for d in shape_v {
            shape.push(strict_usize(d, &format!("{what} {name:?}: shape entry"))?);
        }
        let numel = shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .with_context(|| format!("{what} {name:?}: shape product overflows"))?;
        let bytes = numel
            .checked_mul(4)
            .with_context(|| format!("{what} {name:?}: byte size overflows"))?;
        let offset = strict_usize(t.get("offset"), &format!("{what} {name:?}: offset"))?;
        let end = offset
            .checked_add(bytes)
            .with_context(|| format!("{what} {name:?}: region end overflows"))?;
        if end > bin.len() {
            bail!(
                "{what} {name:?}: region {offset}..{end} exceeds the {} bytes on disk",
                bin.len()
            );
        }
        regions.push((offset, end));
        out.push((name.to_string(), Tensor::from_le_bytes(&bin[offset..end], &shape)));
    }
    regions.sort_unstable();
    for w in regions.windows(2) {
        if w[0].1 > w[1].0 {
            bail!(
                "{what}: tensor regions overlap ({}..{} vs {}..{})",
                w[0].0,
                w[0].1,
                w[1].0,
                w[1].1
            );
        }
    }
    Ok(out)
}

/// Load a checkpoint written by [`save`] / [`save_replace`].
pub fn load(dir: impl AsRef<Path>) -> Result<Ckpt> {
    let dir = dir.as_ref();
    let meta_text = std::fs::read_to_string(dir.join("ckpt.json"))
        .with_context(|| format!("reading {}/ckpt.json", dir.display()))?;
    let v = parse(&meta_text).context("ckpt.json parse")?;
    let schema = v.get("schema").as_usize();
    if schema != Some(1) && schema != Some(2) {
        bail!("unsupported checkpoint schema {schema:?}");
    }
    let bin = std::fs::read(dir.join("params.bin"))
        .with_context(|| format!("reading {}/params.bin", dir.display()))?;
    if Some(bin.len()) != v.get("total_bytes").as_usize() {
        bail!("params.bin size {} != recorded {:?}", bin.len(), v.get("total_bytes"));
    }
    let mut set = TensorSet::new();
    for (name, t) in read_tensors(v.get("tensors"), &bin, "params")? {
        set.push(name, t);
    }
    let opt_state = match v.get("opt_tensors") {
        Value::Null => Vec::new(),
        section => {
            let obin = std::fs::read(dir.join("opt.bin"))
                .with_context(|| format!("reading {}/opt.bin", dir.display()))?;
            if Some(obin.len()) != v.get("opt_total_bytes").as_usize() {
                bail!("opt.bin size {} != recorded {:?}", obin.len(), v.get("opt_total_bytes"));
            }
            read_tensors(section, &obin, "optimizer state")?
        }
    };
    Ok(Ckpt {
        params: set,
        meta: CkptMeta {
            step: v.get("step").as_i64().unwrap_or(0) as u64,
            // Absent in schema-1 checkpoints: None, not a fake 0.
            sweep: v.get("sweep").as_i64().map(|s| s as u64),
            strategy: v.get("strategy").as_str().unwrap_or("").to_string(),
            task: v.get("task").as_str().unwrap_or("").to_string(),
            // Absent in pre-precision checkpoints: None (≡ f32 at resume).
            precision: v.get("precision").as_str().map(str::to_string),
        },
        opt_state,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn sample_set() -> TensorSet {
        let mut rng = Pcg32::seeded(3);
        let mut s = TensorSet::new();
        s.push("a.w", Tensor::randn(&[4, 3], 0.5, &mut rng));
        s.push("a.b", Tensor::randn(&[3], 0.5, &mut rng));
        s.push("head", Tensor::randn(&[3, 7], 0.5, &mut rng));
        s
    }

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("hift_ckpt_{tag}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let dir = tmpdir("rt");
        let set = sample_set();
        let meta = CkptMeta {
            step: 123,
            sweep: Some(30),
            strategy: "hift".into(),
            task: "motif4".into(),
            precision: Some("bf16".into()),
        };
        let opt = vec![
            ("0.m".to_string(), Tensor::ones(&[12])),
            ("0.v".to_string(), Tensor::zeros(&[12])),
            ("0.t".to_string(), Tensor::from_vec(vec![4.0], &[1])),
        ];
        save(&dir, &set, &meta, &opt).unwrap();
        let ck = load(&dir).unwrap();
        assert_eq!(ck.meta, meta);
        assert_eq!(ck.params.names, set.names);
        assert_eq!(ck.params.tensors, set.tensors);
        assert_eq!(ck.opt_state, opt);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_replace_overwrites_atomically() {
        let dir = tmpdir("swap");
        let set = sample_set();
        save_replace(&dir, &set, &CkptMeta { step: 1, ..Default::default() }, &[]).unwrap();
        save_replace(&dir, &set, &CkptMeta { step: 2, ..Default::default() }, &[]).unwrap();
        let ck = load(&dir).unwrap();
        assert_eq!(ck.meta.step, 2);
        assert!(ck.opt_state.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_replace_tolerates_trailing_slash() {
        // Regression: the temp dir must be a sibling even when the target
        // path carries a trailing slash (shell tab-completion), or the swap
        // would delete its own freshly written checkpoint.
        let dir = tmpdir("slash");
        let _ = std::fs::remove_dir_all(&dir);
        let set = sample_set();
        let with_slash = format!("{}/", dir.display());
        save_replace(&with_slash, &set, &CkptMeta { step: 7, ..Default::default() }, &[]).unwrap();
        assert_eq!(load(&dir).unwrap().meta.step, 7);
        save_replace(&with_slash, &set, &CkptMeta { step: 8, ..Default::default() }, &[]).unwrap();
        assert_eq!(load(&dir).unwrap().meta.step, 8);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_bin_is_rejected() {
        let dir = tmpdir("t");
        save(&dir, &sample_set(), &CkptMeta::default(), &[]).unwrap();
        let bin = std::fs::read(dir.join("params.bin")).unwrap();
        std::fs::write(dir.join("params.bin"), &bin[..bin.len() - 4]).unwrap();
        assert!(load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_clean_error() {
        assert!(load("/nonexistent/hift/ckpt").is_err());
    }
}
