//! Checkpointing: save/restore a [`TensorSet`] (+ run metadata) so long
//! HiFT runs can resume — parameters are the only state that must survive
//! (optimizer moments rebuild within one sweep; the paper's Algorithm 1
//! carries no cross-sweep schedule state beyond the step counter, which we
//! persist in the metadata).
//!
//! Format: `<dir>/ckpt.json` (names, shapes, step, extra metadata) +
//! `<dir>/params.bin` (concatenated little-endian f32, manifest order) —
//! the same layout `aot.py` emits, so a checkpoint is loadable anywhere an
//! artifact bundle is.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Tensor, TensorSet};
use crate::ser::{emit_pretty, parse, Value};

/// Checkpoint metadata persisted alongside the weights.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CkptMeta {
    pub step: u64,
    pub strategy: String,
    pub task: String,
}

/// Write `params` + metadata to `dir` (created if missing).
pub fn save(dir: impl AsRef<Path>, params: &TensorSet, meta: &CkptMeta) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let mut bin = Vec::with_capacity(params.total_bytes());
    let mut tensors = Vec::new();
    let mut offset = 0usize;
    for (name, t) in params.names.iter().zip(&params.tensors) {
        bin.extend_from_slice(&t.to_le_bytes());
        tensors.push(Value::obj(vec![
            ("name", name.as_str().into()),
            ("shape", Value::Arr(t.shape.iter().map(|&d| d.into()).collect())),
            ("offset", offset.into()),
        ]));
        offset += t.bytes();
    }
    std::fs::write(dir.join("params.bin"), &bin)?;
    let json = Value::obj(vec![
        ("schema", 1usize.into()),
        ("step", (meta.step as usize).into()),
        ("strategy", meta.strategy.as_str().into()),
        ("task", meta.task.as_str().into()),
        ("total_bytes", offset.into()),
        ("tensors", Value::Arr(tensors)),
    ]);
    std::fs::write(dir.join("ckpt.json"), emit_pretty(&json))?;
    Ok(())
}

/// Load a checkpoint written by [`save`].
pub fn load(dir: impl AsRef<Path>) -> Result<(TensorSet, CkptMeta)> {
    let dir = dir.as_ref();
    let meta_text = std::fs::read_to_string(dir.join("ckpt.json"))
        .with_context(|| format!("reading {}/ckpt.json", dir.display()))?;
    let v = parse(&meta_text).context("ckpt.json parse")?;
    if v.get("schema").as_usize() != Some(1) {
        bail!("unsupported checkpoint schema");
    }
    let bin = std::fs::read(dir.join("params.bin"))?;
    if Some(bin.len()) != v.get("total_bytes").as_usize() {
        bail!("params.bin size {} != recorded {:?}", bin.len(), v.get("total_bytes"));
    }
    let mut set = TensorSet::new();
    for t in v.get("tensors").as_arr().context("tensors")? {
        let name = t.get("name").as_str().context("name")?;
        let shape: Vec<usize> =
            t.get("shape").as_arr().context("shape")?.iter().filter_map(|d| d.as_usize()).collect();
        let offset = t.get("offset").as_usize().context("offset")?;
        set.push(name, Tensor::from_le_bytes(&bin[offset..], &shape));
    }
    Ok((
        set,
        CkptMeta {
            step: v.get("step").as_i64().unwrap_or(0) as u64,
            strategy: v.get("strategy").as_str().unwrap_or("").to_string(),
            task: v.get("task").as_str().unwrap_or("").to_string(),
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn sample_set() -> TensorSet {
        let mut rng = Pcg32::seeded(3);
        let mut s = TensorSet::new();
        s.push("a.w", Tensor::randn(&[4, 3], 0.5, &mut rng));
        s.push("a.b", Tensor::randn(&[3], 0.5, &mut rng));
        s.push("head", Tensor::randn(&[3, 7], 0.5, &mut rng));
        s
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let dir = std::env::temp_dir().join(format!("hift_ckpt_{}", std::process::id()));
        let set = sample_set();
        let meta = CkptMeta { step: 123, strategy: "hift".into(), task: "motif4".into() };
        save(&dir, &set, &meta).unwrap();
        let (loaded, meta2) = load(&dir).unwrap();
        assert_eq!(meta2, meta);
        assert_eq!(loaded.names, set.names);
        assert_eq!(loaded.tensors, set.tensors);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_bin_is_rejected() {
        let dir = std::env::temp_dir().join(format!("hift_ckpt_t_{}", std::process::id()));
        save(&dir, &sample_set(), &CkptMeta::default()).unwrap();
        let bin = std::fs::read(dir.join("params.bin")).unwrap();
        std::fs::write(dir.join("params.bin"), &bin[..bin.len() - 4]).unwrap();
        assert!(load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_clean_error() {
        assert!(load("/nonexistent/hift/ckpt").is_err());
    }
}
