//! The host-memory paging tier: inactive HiFT groups' parameter masters
//! physically leave the device arena and live in a [`HostPool`] until the
//! layer walk demands them back (paper §3, Table 5: only the *active*
//! block's state is device-resident; everything else parks on the host).
//!
//! Three layers:
//!
//! * [`HostPool`] — the host-side store that **owns** evicted tensor data,
//!   either verbatim f32 ([`Compression::Lossless`], paged runs are
//!   bit-identical to resident runs) or f16-compressed
//!   ([`Compression::F16`], QFT-style lossy mode: half the host footprint,
//!   bounded drift — round-to-nearest-even, idempotent after the first
//!   round trip).
//! * [`PagedStore`] — the transfer engine over the pool.  With prefetch
//!   enabled it runs the pool on a **background worker thread** and
//!   double-buffers: `request` posts an async page-in (decompression
//!   happens on the worker while the main thread computes), `store` posts
//!   an async page-out, and `take` collects a page — instantly when the
//!   prefetch already landed, blocking (a measured *stall*) when it did
//!   not.  With prefetch off every transfer is synchronous and every
//!   page-in is a stall, which is exactly the baseline the `bench_offload`
//!   exhibit measures against.
//! * [`UnitPager`] — the layer-unit-granular policy driver the native
//!   backend threads through its forward/backward walks: `ensure_unit`
//!   admits a unit's parameters before the walk reads them,
//!   `prefetch_unit` posts the walk's one-unit-ahead page-in,
//!   `release_unit` evicts a unit the walk has passed, and pinned units —
//!   the active group whose gradients the run emits and whose tensors
//!   fused sinks update in place — stay resident until `end_run` pages
//!   the finished group out (overlapping the next step's compute in
//!   prefetch mode).  `stage_unit` (fed by
//!   [`crate::coordinator::scheduler::HiftScheduler::peek_next`] through
//!   `ExecBackend::prefetch_units`) additionally keeps the scheduler's
//!   *next* group resident across `end_run`, so each step starts with its
//!   active group already in the arena — cross-step double-buffering.
//!
//! Accounting runs through a [`crate::optim::OffloadLedger`] — the same
//! single source of truth the optimizer-state paging uses — so measured
//! peaks (`peak_param_resident_bytes`) are *enforced* arena residency, not
//! a model: `device_resident` rises only when a page is admitted and falls
//! the moment it is evicted.  The initial placement at [`UnitPager::attach`]
//! (the whole model moves to the host before the first step) is setup, not
//! steady-state traffic, and is deliberately not counted as paging events;
//! the pool's own event counters therefore exceed the ledger's page-outs by
//! exactly one store per managed tensor (asserted in the tests).

use std::collections::{HashMap, HashSet};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::TensorSet;
use crate::optim::OffloadLedger;

// The f16 codec now lives in the shared `tensor/half.rs` (the compute path
// — `--precision bf16|f16` — uses the same round-to-nearest-even
// implementation, so paged storage and compute quantization cannot drift
// apart).  Re-exported here for the paging tier's historical callers.
pub use super::half::{f16_bits_to_f32, f32_to_f16_bits};

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Host-side storage format for evicted pages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Compression {
    /// Verbatim f32 — paged runs are bit-identical to resident runs.
    #[default]
    Lossless,
    /// Round-to-nearest-even f16 — half the host bytes, bounded drift
    /// (idempotent after the first round trip, so values do not keep
    /// degrading while a group sits parked).
    F16,
}

impl Compression {
    pub fn parse(s: &str) -> Result<Compression> {
        match s.trim().to_ascii_lowercase().as_str() {
            "" | "none" | "lossless" | "f32" => Ok(Compression::Lossless),
            "f16" | "half" => Ok(Compression::F16),
            other => bail!("bad offload compression {other:?} (none|f16)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Compression::Lossless => "lossless",
            Compression::F16 => "f16",
        }
    }

    /// Host bytes for `numel` elements in this format.
    pub fn bytes(&self, numel: usize) -> usize {
        match self {
            Compression::Lossless => numel * 4,
            Compression::F16 => numel * 2,
        }
    }
}

/// Offload configuration (CLI `--offload host|none`, `--offload-compress`,
/// `--prefetch`; env `HIFT_OFFLOAD`, `HIFT_OFFLOAD_COMPRESS`,
/// `HIFT_PREFETCH`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OffloadCfg {
    /// Host paging on (`--offload host`)?  Off = everything stays resident.
    pub enabled: bool,
    pub compress: Compression,
    /// Double-buffered async transfers (default).  Off = synchronous paging
    /// (every page-in stalls the walk) — the `bench_offload` baseline.
    pub prefetch: bool,
}

impl Default for OffloadCfg {
    fn default() -> Self {
        OffloadCfg { enabled: false, compress: Compression::Lossless, prefetch: true }
    }
}

impl OffloadCfg {
    /// Lossless host paging with prefetch — the `--offload host` default.
    pub fn host() -> Self {
        OffloadCfg { enabled: true, ..Default::default() }
    }

    /// Parse the CLI flag values on top of `self` (None = keep).
    pub fn with_flags(
        mut self,
        offload: Option<&str>,
        compress: Option<&str>,
        prefetch: Option<&str>,
    ) -> Result<Self> {
        if let Some(mode) = offload {
            self.enabled = match mode.trim().to_ascii_lowercase().as_str() {
                "none" | "off" | "0" => false,
                "host" | "cpu" | "1" => true,
                other => bail!("bad --offload {other:?} (host|none)"),
            };
        }
        if let Some(c) = compress {
            self.compress = Compression::parse(c)?;
        }
        if let Some(p) = prefetch {
            self.prefetch = match p.trim().to_ascii_lowercase().as_str() {
                "0" | "off" | "false" => false,
                "1" | "on" | "true" => true,
                other => bail!("bad --prefetch {other:?} (1|0)"),
            };
        }
        Ok(self)
    }

    /// From `HIFT_OFFLOAD` / `HIFT_OFFLOAD_COMPRESS` / `HIFT_PREFETCH`
    /// (empty values mean unset).
    pub fn from_env() -> Result<Self> {
        let var = |k: &str| std::env::var(k).ok().filter(|s| !s.is_empty());
        OffloadCfg::default().with_flags(
            var("HIFT_OFFLOAD").as_deref(),
            var("HIFT_OFFLOAD_COMPRESS").as_deref(),
            var("HIFT_PREFETCH").as_deref(),
        )
    }

    pub fn name(&self) -> String {
        if !self.enabled {
            return "none".to_string();
        }
        format!(
            "host({}, {})",
            self.compress.name(),
            if self.prefetch { "prefetch" } else { "sync" }
        )
    }
}

// ---------------------------------------------------------------------------
// HostPool — the store that owns evicted pages
// ---------------------------------------------------------------------------

enum HostPage {
    F32(Vec<f32>),
    F16(Vec<u16>),
}

/// Host-side page store.  Owns every evicted tensor's data: on eviction the
/// arena slot is genuinely emptied (lossless pages move their buffer here;
/// f16 pages are converted element-by-element and the f32 buffer is freed),
/// and admission hands the data back — so arena residency is a physical
/// fact, not a flag.
pub struct HostPool {
    compress: Compression,
    pages: HashMap<usize, HostPage>,
    stores: u64,
    fetches: u64,
}

impl HostPool {
    pub fn new(compress: Compression) -> Self {
        HostPool { compress, pages: HashMap::new(), stores: 0, fetches: 0 }
    }

    /// Page `data` out into the pool (compressing if configured).
    pub fn store(&mut self, idx: usize, data: Vec<f32>) {
        let page = match self.compress {
            Compression::Lossless => HostPage::F32(data),
            Compression::F16 => {
                HostPage::F16(data.iter().map(|&x| f32_to_f16_bits(x)).collect())
            }
        };
        self.pages.insert(idx, page);
        self.stores += 1;
    }

    /// Page `idx` back in (decompressing if needed); `None` if not stored.
    pub fn fetch(&mut self, idx: usize) -> Option<Vec<f32>> {
        let page = self.pages.remove(&idx)?;
        self.fetches += 1;
        Some(match page {
            HostPage::F32(v) => v,
            HostPage::F16(v) => v.into_iter().map(f16_bits_to_f32).collect(),
        })
    }

    /// `(stores, fetches)` processed — the pool-side event counts the
    /// ledger regression test compares against.
    pub fn events(&self) -> (u64, u64) {
        (self.stores, self.fetches)
    }

    pub fn len(&self) -> usize {
        self.pages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pages.is_empty()
    }
}

// ---------------------------------------------------------------------------
// PagedStore — sync or double-buffered async transfers over a HostPool
// ---------------------------------------------------------------------------

enum Job {
    Store { idx: usize, data: Vec<f32> },
    Fetch { idx: usize },
    Report,
    Stop,
}

enum Done {
    Fetched { idx: usize, data: Option<Vec<f32>> },
    Report { stores: u64, fetches: u64 },
}

enum Inner {
    Sync(HostPool),
    Async {
        jobs: Sender<Job>,
        done: Receiver<Done>,
        worker: Option<JoinHandle<()>>,
        /// Prefetched pages that landed but were not yet admitted.
        ready: HashMap<usize, Vec<f32>>,
        /// Fetches posted but not yet landed.
        inflight: HashSet<usize>,
    },
}

/// Transfer engine over a [`HostPool`]: synchronous, or double-buffered on
/// a background worker thread (compression/decompression overlap compute).
pub struct PagedStore {
    inner: Inner,
}

impl PagedStore {
    pub fn new(compress: Compression, prefetch: bool) -> Self {
        if !prefetch {
            return PagedStore { inner: Inner::Sync(HostPool::new(compress)) };
        }
        let (jobs, job_rx) = channel::<Job>();
        let (done_tx, done) = channel::<Done>();
        // hift-lint: allow(budget-lease): IO-bound prefetch worker, blocked on the job channel while compute runs — a budget slot would permanently steal a compute thread
        let worker = std::thread::spawn(move || {
            let mut pool = HostPool::new(compress);
            while let Ok(job) = job_rx.recv() {
                match job {
                    Job::Store { idx, data } => pool.store(idx, data),
                    Job::Fetch { idx } => {
                        let data = pool.fetch(idx);
                        if done_tx.send(Done::Fetched { idx, data }).is_err() {
                            return;
                        }
                    }
                    Job::Report => {
                        let (stores, fetches) = pool.events();
                        if done_tx.send(Done::Report { stores, fetches }).is_err() {
                            return;
                        }
                    }
                    Job::Stop => return,
                }
            }
        });
        PagedStore {
            inner: Inner::Async {
                jobs,
                done,
                worker: Some(worker),
                ready: HashMap::new(),
                inflight: HashSet::new(),
            },
        }
    }

    /// Page `data` out (async when prefetching: the compression happens on
    /// the worker, overlapping whatever the main thread does next).
    pub fn store(&mut self, idx: usize, data: Vec<f32>) -> Result<()> {
        match &mut self.inner {
            Inner::Sync(pool) => {
                pool.store(idx, data);
                Ok(())
            }
            Inner::Async { jobs, .. } => jobs
                .send(Job::Store { idx, data })
                .map_err(|_| anyhow!("offload worker died during page-out")),
        }
    }

    /// Hint that `idx` will be needed soon.  Returns true when an async
    /// fetch was actually posted (false in sync mode / already buffered).
    pub fn request(&mut self, idx: usize) -> bool {
        match &mut self.inner {
            Inner::Sync(_) => false,
            Inner::Async { jobs, ready, inflight, .. } => {
                if ready.contains_key(&idx) || inflight.contains(&idx) {
                    return false;
                }
                if jobs.send(Job::Fetch { idx }).is_ok() {
                    inflight.insert(idx);
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Collect page `idx`.  Returns `(data, prefetch_hit)` — `prefetch_hit`
    /// is true when the page had already landed in the double buffer and no
    /// blocking was needed.
    pub fn take(&mut self, idx: usize) -> Result<(Vec<f32>, bool)> {
        match &mut self.inner {
            Inner::Sync(pool) => {
                let data =
                    pool.fetch(idx).ok_or_else(|| anyhow!("page {idx} missing from host pool"))?;
                Ok((data, false))
            }
            Inner::Async { jobs, done, ready, inflight, .. } => {
                if let Some(data) = ready.remove(&idx) {
                    return Ok((data, true));
                }
                if !inflight.contains(&idx) {
                    jobs.send(Job::Fetch { idx })
                        .map_err(|_| anyhow!("offload worker died during page-in"))?;
                    inflight.insert(idx);
                }
                loop {
                    match done.recv().map_err(|_| anyhow!("offload worker died"))? {
                        Done::Fetched { idx: got, data } => {
                            inflight.remove(&got);
                            let data = data
                                .ok_or_else(|| anyhow!("page {got} missing from host pool"))?;
                            if got == idx {
                                return Ok((data, false));
                            }
                            ready.insert(got, data);
                        }
                        Done::Report { .. } => bail!("offload worker answered out of order"),
                    }
                }
            }
        }
    }

    /// Pool-side `(stores, fetches)` event counts (drains the worker queue
    /// first in async mode, so the numbers are settled).
    pub fn events(&mut self) -> Result<(u64, u64)> {
        match &mut self.inner {
            Inner::Sync(pool) => Ok(pool.events()),
            Inner::Async { jobs, done, ready, inflight, .. } => {
                jobs.send(Job::Report).map_err(|_| anyhow!("offload worker died"))?;
                loop {
                    match done.recv().map_err(|_| anyhow!("offload worker died"))? {
                        Done::Fetched { idx, data } => {
                            inflight.remove(&idx);
                            ready.insert(
                                idx,
                                data.ok_or_else(|| anyhow!("page {idx} missing"))?,
                            );
                        }
                        Done::Report { stores, fetches } => return Ok((stores, fetches)),
                    }
                }
            }
        }
    }
}

impl Drop for PagedStore {
    fn drop(&mut self) {
        if let Inner::Async { jobs, worker, .. } = &mut self.inner {
            let _ = jobs.send(Job::Stop);
            if let Some(w) = worker.take() {
                let _ = w.join();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// UnitPager — layer-unit policy over a TensorSet
// ---------------------------------------------------------------------------

/// One steady-state paging action, at parameter-tensor granularity — the
/// shared event vocabulary of the real pager trace
/// ([`UnitPager::set_tracing`]) and the static plans `plancheck` derives.
/// The initial placement at [`UnitPager::attach`] is setup, not paging, and
/// is not an event (matching the ledger, which skips it too).  Whether a
/// posted page-in *lands* before the walk blocks on it (hit vs miss) is
/// timing, not schedule, so it is deliberately not part of an event's
/// identity — the sequence below is fully deterministic for a given
/// configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageEvent {
    /// Parameter `idx` admitted to the arena (one ledger page-in).
    Admit { idx: usize },
    /// Parameter `idx` evicted to the host pool (one ledger page-out).
    Evict { idx: usize },
    /// Async page-in posted for `idx` (prefetch mode only; no arena
    /// residency change until the matching `Admit`).
    Prefetch { idx: usize },
}

/// A snapshot of the pager's accounting, used by the backend to fold deltas
/// into its [`crate::backend::RuntimeStats`].
#[derive(Debug, Clone, Copy, Default)]
pub struct OffloadCounters {
    pub page_ins: u64,
    pub page_outs: u64,
    /// Host→device page-in traffic (full f32 bytes admitted to the arena).
    pub h2d_bytes: u64,
    /// Device→host page-out traffic.
    pub d2h_bytes: u64,
    /// Managed parameter bytes currently resident in the arena.
    pub param_resident_bytes: u64,
    /// Peak of `param_resident_bytes` — the *enforced* device residency of
    /// parameter masters (active group + the transient walk unit).
    pub peak_param_resident_bytes: u64,
    /// Peak bytes posted to the double buffer (prefetches in flight or
    /// landed-but-unadmitted), full f32 size.
    pub peak_prefetch_buffer_bytes: u64,
    /// Current / peak host-tier footprint (compressed bytes).
    pub host_bytes: u64,
    pub peak_host_bytes: u64,
    /// Page-ins served instantly from the double buffer.
    pub prefetch_hits: u64,
    /// Page-ins that had to block (every sync-mode page-in is one).
    pub prefetch_misses: u64,
    /// Nanoseconds the walk spent blocked waiting for page-ins.
    pub stall_nanos: u64,
}

/// The unit-granular pager the native backend drives through its walks.
///
/// Attached to one [`TensorSet`] lineage at a time; a new lineage (fresh
/// `load_params`, checkpoint resume) resets the pool — evicted pages of a
/// dead set die with it.
pub struct UnitPager {
    cfg: OffloadCfg,
    store: PagedStore,
    ledger: OffloadLedger,
    /// Parameter indices per layer unit (managed tensors only).
    unit_params: Vec<Vec<usize>>,
    /// Per parameter index: does the pager manage it?  (Adapters, unit −1,
    /// are tiny and stay always-resident.)
    managed: Vec<bool>,
    /// Full f32 bytes per parameter index (the arena-side size).
    full_bytes: Vec<usize>,
    resident: Vec<bool>,
    pinned: Vec<bool>,
    /// Staged units (the scheduler's *next* group): their page-ins are
    /// posted ahead of time and they survive [`UnitPager::end_run`], so the
    /// following step starts with its active group already resident —
    /// cross-step double-buffering.  Prefetch mode only.
    keep: Vec<bool>,
    /// Prefetches posted and not yet admitted (for buffer accounting).
    requested: Vec<bool>,
    lineage: Option<u64>,
    buffer_bytes: u64,
    peak_buffer_bytes: u64,
    host_bytes: u64,
    peak_host_bytes: u64,
    prefetch_hits: u64,
    prefetch_misses: u64,
    stall_nanos: u64,
    /// Steady-state event trace, recorded only while tracing is on
    /// (`plancheck` cross-validation; off by default — zero steady cost).
    trace: Option<Vec<PageEvent>>,
}

impl UnitPager {
    pub fn new(cfg: OffloadCfg) -> Self {
        UnitPager {
            cfg,
            store: PagedStore::new(cfg.compress, cfg.prefetch),
            ledger: OffloadLedger::new(),
            unit_params: Vec::new(),
            managed: Vec::new(),
            full_bytes: Vec::new(),
            resident: Vec::new(),
            pinned: Vec::new(),
            keep: Vec::new(),
            requested: Vec::new(),
            lineage: None,
            buffer_bytes: 0,
            peak_buffer_bytes: 0,
            host_bytes: 0,
            peak_host_bytes: 0,
            prefetch_hits: 0,
            prefetch_misses: 0,
            stall_nanos: 0,
            trace: None,
        }
    }

    pub fn cfg(&self) -> OffloadCfg {
        self.cfg
    }

    /// Start/stop recording the steady-state [`PageEvent`] stream.  Turning
    /// tracing on clears any previous recording.
    pub fn set_tracing(&mut self, on: bool) {
        self.trace = if on { Some(Vec::new()) } else { None };
    }

    /// Drain the recorded events (empty when tracing is off).
    pub fn take_trace(&mut self) -> Vec<PageEvent> {
        self.trace.as_mut().map(std::mem::take).unwrap_or_default()
    }

    fn note(&mut self, ev: PageEvent) {
        if let Some(t) = self.trace.as_mut() {
            t.push(ev);
        }
    }

    /// Is the pager attached to this parameter set's lineage?
    pub fn is_attached_to(&self, set: &TensorSet) -> bool {
        self.lineage == Some(set.lineage())
    }

    /// Attach to `set` with the given unit → parameter-index map.  A no-op
    /// when already attached to this lineage; otherwise the pool is rebuilt
    /// and every managed tensor is moved to the host — the **initial
    /// placement**, which is setup rather than steady-state paging and is
    /// not counted as ledger events (the pool's store count therefore leads
    /// the ledger's page-outs by one per managed tensor).
    pub fn attach(&mut self, set: &mut TensorSet, unit_params: Vec<Vec<usize>>) -> Result<()> {
        if self.is_attached_to(set) {
            return Ok(());
        }
        let n = set.len();
        self.store = PagedStore::new(self.cfg.compress, self.cfg.prefetch);
        self.ledger = OffloadLedger::new();
        self.managed = vec![false; n];
        self.full_bytes = (0..n).map(|i| set.tensors[i].bytes()).collect();
        self.resident = vec![true; n];
        self.pinned = vec![false; n];
        self.keep = vec![false; n];
        self.requested = vec![false; n];
        self.buffer_bytes = 0;
        self.peak_buffer_bytes = 0;
        self.host_bytes = 0;
        self.peak_host_bytes = 0;
        for unit in &unit_params {
            for &idx in unit {
                if idx >= n {
                    bail!("pager unit map names parameter {idx} of a {n}-tensor set");
                }
                self.managed[idx] = true;
            }
        }
        self.unit_params = unit_params;
        self.lineage = Some(set.lineage());
        // Initial placement: every managed master moves to the host.
        for idx in 0..n {
            if self.managed[idx] {
                let data = std::mem::take(&mut set.tensors[idx].data);
                let numel = data.len();
                self.host_bytes += self.cfg.compress.bytes(numel) as u64;
                self.store.store(idx, data)?;
                self.resident[idx] = false;
            }
        }
        self.peak_host_bytes = self.peak_host_bytes.max(self.host_bytes);
        Ok(())
    }

    /// Pin a unit for the current run: its tensors stay resident through
    /// `release_unit` (fused sinks update them in place) until `end_run`.
    pub fn pin_unit(&mut self, u: usize) {
        let Some(idxs) = self.unit_params.get(u).cloned() else {
            return;
        };
        for i in idxs {
            self.pinned[i] = true;
        }
    }

    pub fn clear_pins(&mut self) {
        self.pinned.iter_mut().for_each(|p| *p = false);
    }

    /// Admit unit `u`'s parameters into the arena (blocking on any page
    /// still in flight — a measured stall).
    pub fn ensure_unit(&mut self, set: &mut TensorSet, u: usize) -> Result<()> {
        let Some(idxs) = self.unit_params.get(u).cloned() else {
            return Ok(());
        };
        for idx in idxs {
            self.admit(set, idx)?;
        }
        Ok(())
    }

    /// Post async page-ins for unit `u` (no-op in sync mode / if resident).
    pub fn prefetch_unit(&mut self, u: usize) {
        let Some(idxs) = self.unit_params.get(u).cloned() else {
            return;
        };
        for idx in idxs {
            if !self.resident[idx] && !self.requested[idx] && self.store.request(idx) {
                self.requested[idx] = true;
                self.buffer_bytes += self.full_bytes[idx] as u64;
                self.peak_buffer_bytes = self.peak_buffer_bytes.max(self.buffer_bytes);
                self.note(PageEvent::Prefetch { idx });
            }
        }
    }

    /// Stage unit `u` for the *next* run: post its page-ins now (their
    /// decompression overlaps the current run's compute) and mark it to
    /// survive [`UnitPager::end_run`], so the next step's active group is
    /// already arena-resident when it starts — the cross-step half of the
    /// double buffer.  Prefetch mode only: synchronous paging keeps the
    /// tight one-group residency baseline.
    pub fn stage_unit(&mut self, u: usize) {
        if !self.cfg.prefetch {
            return;
        }
        let Some(idxs) = self.unit_params.get(u).cloned() else {
            return;
        };
        for &idx in &idxs {
            self.keep[idx] = true;
        }
        self.prefetch_unit(u);
    }

    /// Drop all staging marks (the previous "next group" is now the active
    /// one; its pins take over).
    pub fn clear_staged(&mut self) {
        self.keep.iter_mut().for_each(|k| *k = false);
    }

    /// Evict unit `u` unless pinned or staged (the walk has moved past it).
    pub fn release_unit(&mut self, set: &mut TensorSet, u: usize) -> Result<()> {
        let Some(idxs) = self.unit_params.get(u).cloned() else {
            return Ok(());
        };
        for idx in idxs {
            if self.resident[idx] && !self.pinned[idx] && !self.keep[idx] {
                self.evict(set, idx)?;
            }
        }
        Ok(())
    }

    /// End of a run: page out everything still resident except staged
    /// units (the just-finished group included — in prefetch mode the
    /// store is async, overlapping the next step's compute) and drop the
    /// pins.  Staged units stay resident for the next step.
    pub fn end_run(&mut self, set: &mut TensorSet) -> Result<()> {
        self.clear_pins();
        for idx in 0..self.resident.len() {
            if self.managed[idx] && self.resident[idx] && !self.keep[idx] {
                self.evict(set, idx)?;
            }
        }
        // Contracts (HIFT_CHECK): conservation only — staged units stay
        // resident across runs by design, so no quiescence requirement.
        if crate::contracts::enabled() {
            self.ledger.check_conservation()?;
        }
        Ok(())
    }

    /// Page everything back in (checkpoint save, end of training — callers
    /// outside the backend walk need the full set materialized).
    pub fn flush(&mut self, set: &mut TensorSet) -> Result<()> {
        for idx in 0..self.resident.len() {
            if self.managed[idx] && !self.resident[idx] {
                self.admit(set, idx)?;
            }
        }
        Ok(())
    }

    fn admit(&mut self, set: &mut TensorSet, idx: usize) -> Result<()> {
        if self.resident[idx] {
            return Ok(());
        }
        let t0 = Instant::now();
        let (data, hit) = self.store.take(idx)?;
        if hit {
            self.prefetch_hits += 1;
        } else {
            self.prefetch_misses += 1;
            self.stall_nanos += t0.elapsed().as_nanos() as u64;
        }
        let expect: usize = set.tensors[idx].shape.iter().product();
        if data.len() != expect {
            bail!(
                "host pool returned {} elements for tensor {:?} (shape wants {expect})",
                data.len(),
                set.names[idx]
            );
        }
        if self.cfg.compress == Compression::F16 {
            // Lossy round trip: the master's bits changed, so the device
            // working copy must refresh (version bump → upload-cache miss).
            set.tensor_mut(idx).data = data;
        } else {
            // Bit-identical content: restore without invalidating caches.
            set.tensors[idx].data = data;
        }
        self.resident[idx] = true;
        self.host_bytes -= self.cfg.compress.bytes(expect) as u64;
        self.ledger.page_in(self.full_bytes[idx] as u64);
        if self.requested[idx] {
            self.requested[idx] = false;
            self.buffer_bytes -= self.full_bytes[idx] as u64;
        }
        self.note(PageEvent::Admit { idx });
        Ok(())
    }

    fn evict(&mut self, set: &mut TensorSet, idx: usize) -> Result<()> {
        let data = std::mem::take(&mut set.tensors[idx].data);
        let numel = data.len();
        self.ledger.page_out(self.full_bytes[idx] as u64);
        self.host_bytes += self.cfg.compress.bytes(numel) as u64;
        self.peak_host_bytes = self.peak_host_bytes.max(self.host_bytes);
        self.store.store(idx, data)?;
        self.resident[idx] = false;
        self.note(PageEvent::Evict { idx });
        Ok(())
    }

    /// Full f32 bytes of parameter `idx` as recorded at attach (used by the
    /// backend's upload accounting while the tensor is evicted).
    pub fn full_bytes_of(&self, idx: usize) -> Option<usize> {
        self.full_bytes.get(idx).copied()
    }

    /// Does the pool currently hold any evicted master?  While true, the
    /// pager is the *only* owner of that data — dropping it would destroy
    /// parameters, so reconfiguration must flush first.
    pub fn holds_pages(&self) -> bool {
        self.managed.iter().zip(&self.resident).any(|(m, r)| *m && !*r)
    }

    /// The accounting ledger (single source of truth for transfers).
    pub fn ledger(&self) -> &OffloadLedger {
        &self.ledger
    }

    /// Pool-side event counts (see [`PagedStore::events`]).
    pub fn pool_events(&mut self) -> Result<(u64, u64)> {
        self.store.events()
    }

    /// Reset peak gauges to current levels (per-run peak reporting).
    pub fn reset_peaks(&mut self) {
        self.ledger.peak_device_bytes = self.ledger.device_resident();
        self.peak_buffer_bytes = self.buffer_bytes;
        self.peak_host_bytes = self.host_bytes;
    }

    pub fn counters(&self) -> OffloadCounters {
        OffloadCounters {
            page_ins: self.ledger.page_ins,
            page_outs: self.ledger.page_outs,
            h2d_bytes: self.ledger.h2d_bytes,
            d2h_bytes: self.ledger.d2h_bytes,
            param_resident_bytes: self.ledger.device_resident(),
            peak_param_resident_bytes: self.ledger.peak_device_bytes,
            peak_prefetch_buffer_bytes: self.peak_buffer_bytes,
            host_bytes: self.host_bytes,
            peak_host_bytes: self.peak_host_bytes,
            prefetch_hits: self.prefetch_hits,
            prefetch_misses: self.prefetch_misses,
            stall_nanos: self.stall_nanos,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    // The codec's own edge tests (NaN canonicalization, overflow→inf,
    // ties-to-even, idempotency) live with the codec in `tensor/half.rs`.

    #[test]
    fn host_pool_roundtrips_lossless_and_compresses_f16() {
        let data = vec![0.1f32, -2.5, 3.25, 1e-3];
        let mut pool = HostPool::new(Compression::Lossless);
        pool.store(0, data.clone());
        assert_eq!(pool.fetch(0).unwrap(), data, "lossless is bit-identical");
        assert!(pool.fetch(0).is_none(), "fetch removes the page");

        let mut pool = HostPool::new(Compression::F16);
        pool.store(1, data.clone());
        let back = pool.fetch(1).unwrap();
        assert_eq!(back[2], 3.25, "f16-exact value survives");
        for (a, b) in back.iter().zip(&data) {
            assert!((a - b).abs() <= b.abs() * 1e-3 + 1e-6, "{b} → {a}");
        }
        assert_eq!(pool.events(), (2, 2));
    }

    #[test]
    fn paged_store_async_matches_sync() {
        let data: Vec<f32> = (0..257).map(|i| i as f32 * 0.37 - 40.0).collect();
        for prefetch in [false, true] {
            let mut st = PagedStore::new(Compression::Lossless, prefetch);
            st.store(3, data.clone()).unwrap();
            st.store(5, vec![1.0; 8]).unwrap();
            if prefetch {
                assert!(st.request(3), "fetch posted");
                assert!(!st.request(3), "double-request coalesced");
            }
            let (got, _) = st.take(3).unwrap();
            assert_eq!(got, data, "prefetch={prefetch}");
            let (got5, hit5) = st.take(5).unwrap();
            assert_eq!(got5, vec![1.0; 8]);
            assert!(!hit5, "unrequested take is a miss");
            assert_eq!(st.events().unwrap(), (2, 2), "prefetch={prefetch}");
            assert!(st.take(3).is_err(), "page gone after take");
        }
    }

    fn toy_set() -> (TensorSet, Vec<Vec<usize>>) {
        let mut set = TensorSet::new();
        set.push("emb", Tensor::from_vec((0..8).map(|i| i as f32).collect(), &[8]));
        set.push("l0.w", Tensor::from_vec(vec![0.5; 6], &[2, 3]));
        set.push("head", Tensor::from_vec(vec![-1.0; 4], &[4]));
        set.push("adapter", Tensor::from_vec(vec![9.0; 2], &[2]));
        // three units; the adapter is unmanaged
        (set, vec![vec![0], vec![1], vec![2]])
    }

    #[test]
    fn pager_evicts_admits_and_enforces_residency() {
        for prefetch in [false, true] {
            let cfg = OffloadCfg { enabled: true, compress: Compression::Lossless, prefetch };
            let mut pg = UnitPager::new(cfg);
            let (mut set, units) = toy_set();
            let orig: Vec<Vec<f32>> = set.tensors.iter().map(|t| t.data.clone()).collect();
            let managed_bytes: u64 = (orig[0].len() + orig[1].len() + orig[2].len()) as u64 * 4;
            pg.attach(&mut set, units.clone()).unwrap();
            // initial placement: managed tensors left the arena, no events
            assert_eq!(set.tensors[0].data.len(), 0, "emb evicted");
            assert_eq!(set.tensors[3].data, vec![9.0; 2], "adapter untouched");
            assert_eq!(pg.counters().page_outs, 0, "initial placement is not an event");
            assert_eq!(pg.counters().host_bytes, managed_bytes);

            // walk: unit 0 in, out; unit 1 pinned in
            pg.prefetch_unit(1);
            pg.ensure_unit(&mut set, 0).unwrap();
            assert_eq!(set.tensors[0].data, orig[0], "admitted bit-identical");
            pg.pin_unit(1);
            pg.ensure_unit(&mut set, 1).unwrap();
            pg.release_unit(&mut set, 0).unwrap();
            pg.release_unit(&mut set, 1).unwrap();
            assert_eq!(set.tensors[1].data, orig[1], "pinned unit survives release");
            assert_eq!(set.tensors[0].data.len(), 0, "unpinned unit evicted");
            let c = pg.counters();
            assert_eq!(c.param_resident_bytes, 24, "only l0.w (6 f32) resident");
            assert!(c.peak_param_resident_bytes >= 24 + 32, "emb+w were co-resident");

            pg.end_run(&mut set).unwrap();
            assert_eq!(pg.counters().param_resident_bytes, 0, "end_run evicts the group");
            pg.flush(&mut set).unwrap();
            for (i, t) in set.tensors.iter().enumerate() {
                assert_eq!(t.data, orig[i], "flush restores tensor {i} bit-identically");
            }
            // ledger ↔ pool single-source-of-truth: pool stores lead the
            // ledger's page-outs by exactly the initial placement.
            let (stores, fetches) = pg.pool_events().unwrap();
            let c = pg.counters();
            assert_eq!(stores, c.page_outs + 3, "stores = page-outs + initial placement");
            assert_eq!(fetches, c.page_ins, "every fetch is a ledger page-in");
            assert_eq!(c.host_bytes, 0, "pool drained after flush");
        }
    }

    #[test]
    fn staged_units_survive_end_run() {
        // Prefetch mode: staging marks the next step's group to outlive
        // end_run (cross-step double-buffering)…
        let mut pg = UnitPager::new(OffloadCfg::host());
        let (mut set, units) = toy_set();
        let orig = set.tensors[2].data.clone();
        pg.attach(&mut set, units.clone()).unwrap();
        pg.stage_unit(2);
        pg.ensure_unit(&mut set, 0).unwrap();
        pg.ensure_unit(&mut set, 2).unwrap();
        pg.end_run(&mut set).unwrap();
        assert_eq!(set.tensors[2].data, orig, "staged unit stays resident across end_run");
        assert_eq!(set.tensors[0].data.len(), 0, "unstaged unit is evicted");
        // …a new staging set replaces the old one…
        pg.clear_staged();
        pg.stage_unit(1);
        pg.end_run(&mut set).unwrap();
        assert_eq!(set.tensors[2].data.len(), 0, "unstaged-now unit is evicted");
        // …and synchronous mode ignores staging (tight residency baseline).
        let mut pg =
            UnitPager::new(OffloadCfg { enabled: true, prefetch: false, ..OffloadCfg::host() });
        let (mut set, units) = toy_set();
        pg.attach(&mut set, units).unwrap();
        pg.stage_unit(2);
        pg.ensure_unit(&mut set, 2).unwrap();
        pg.end_run(&mut set).unwrap();
        assert_eq!(set.tensors[2].data.len(), 0, "sync mode evicts staged units too");
    }

    #[test]
    fn pager_f16_mode_is_lossy_but_stable() {
        let cfg =
            OffloadCfg { enabled: true, compress: Compression::F16, prefetch: false };
        let mut pg = UnitPager::new(cfg);
        let (mut set, units) = toy_set();
        set.tensors[0].data = vec![0.1; 8]; // not f16-exact
        let v0 = set.cache_key(0);
        pg.attach(&mut set, units).unwrap();
        assert_eq!(pg.counters().host_bytes, 18 * 2, "f16 halves the host bytes");
        pg.ensure_unit(&mut set, 0).unwrap();
        let once = set.tensors[0].data.clone();
        assert_ne!(once, vec![0.1; 8], "f16 round trip is lossy");
        assert!((once[0] - 0.1).abs() < 1e-3);
        assert_ne!(set.cache_key(0), v0, "lossy admit must invalidate the upload cache");
        // parked again: the second round trip changes nothing (idempotent)
        pg.release_unit(&mut set, 0).unwrap();
        pg.ensure_unit(&mut set, 0).unwrap();
        assert_eq!(set.tensors[0].data, once, "second round trip is a fixed point");
    }

    #[test]
    fn offload_cfg_parses_flags() {
        let c = OffloadCfg::default();
        assert!(!c.enabled && c.prefetch);
        let c = c.with_flags(Some("host"), Some("f16"), Some("0")).unwrap();
        assert!(c.enabled && !c.prefetch);
        assert_eq!(c.compress, Compression::F16);
        assert_eq!(c.name(), "host(f16, sync)");
        assert_eq!(OffloadCfg::host().name(), "host(lossless, prefetch)");
        assert!(OffloadCfg::default().with_flags(Some("gpu"), None, None).is_err());
        assert!(OffloadCfg::default().with_flags(None, Some("f8"), None).is_err());
        assert!(OffloadCfg::default().with_flags(None, None, Some("maybe")).is_err());
    }
}
