//! Adafactor (Shazeer & Stern, 2018): sublinear-memory adaptivity.
//!
//! For matrices the second moment is factored into a row vector `R` and a
//! column vector `C` (state = r+c floats instead of r·c); vectors keep a
//! full accumulator.  This is why the paper's #Sta column collapses to
//! ~0.2–0.3 MB under Adafactor even for LLaMA-7B (Table 12) — and why
//! HiFT+Adafactor has near-zero paging traffic.
//!
//! Implemented per the paper's recommended defaults: β₂ schedule
//! `1 − t^−0.8`, update RMS-clipped at `d = 1.0`, relative step size off
//! (we use the external LR so the delayed-LR schedule stays in charge).

use super::{OptimCfg, OptimKind, Optimizer};
use crate::backend::par;
use crate::tensor::Tensor;

enum Factored {
    /// Matrices (and higher rank, folded to 2-D over the last axis):
    /// row/col second-moment factors.
    Matrix { r: Vec<f32>, c: Vec<f32>, rows: usize, cols: usize },
    /// Vectors/scalars: dense accumulator.
    Vector(Vec<f32>),
}

struct State {
    f: Factored,
    t: u64,
}

pub struct Adafactor {
    cfg: OptimCfg,
    states: Vec<Option<State>>,
}

impl Adafactor {
    pub fn new(cfg: OptimCfg, n_params: usize) -> Self {
        Adafactor { cfg, states: (0..n_params).map(|_| None).collect() }
    }

    fn fold_2d(shape: &[usize]) -> Option<(usize, usize)> {
        if shape.len() < 2 {
            return None;
        }
        let cols = *shape.last()?;
        let rows = shape.iter().rev().skip(1).product();
        Some((rows, cols))
    }
}

impl Optimizer for Adafactor {
    fn update(&mut self, idx: usize, param: &mut Tensor, grad: &Tensor, lr: f32) {
        assert_eq!(param.shape, grad.shape);
        let eps = 1e-30f32;
        let d_clip = 1.0f32;
        let st = self.states[idx].get_or_insert_with(|| State {
            f: match Self::fold_2d(&param.shape) {
                Some((rows, cols)) => {
                    Factored::Matrix { r: vec![0.0; rows], c: vec![0.0; cols], rows, cols }
                }
                None => Factored::Vector(vec![0.0; param.numel()]),
            },
            t: 0,
        });
        st.t += 1;
        let beta2 = 1.0 - (st.t as f32).powf(-0.8);
        let wd = self.cfg.weight_decay;

        // Build the adaptive update into `upd`, then RMS-clip and apply.
        let n = param.numel();
        let mut upd = vec![0.0f32; n];
        match &mut st.f {
            Factored::Matrix { r, c, rows, cols } => {
                let (rows, cols) = (*rows, *cols);
                // Update row/col factors with the mean of g² along the
                // other axis (exponential moving average).
                for i in 0..rows {
                    let mut s = 0.0f32;
                    for j in 0..cols {
                        let g = grad.data[i * cols + j];
                        s += g * g + eps;
                    }
                    r[i] = beta2 * r[i] + (1.0 - beta2) * (s / cols as f32);
                }
                for j in 0..cols {
                    let mut s = 0.0f32;
                    for i in 0..rows {
                        let g = grad.data[i * cols + j];
                        s += g * g + eps;
                    }
                    c[j] = beta2 * c[j] + (1.0 - beta2) * (s / rows as f32);
                }
                // hift-lint: allow(float-reduction): sequential factored-moment mean over per-param state — single fixed schedule
                let r_mean = r.iter().sum::<f32>() / rows as f32 + eps;
                for i in 0..rows {
                    for j in 0..cols {
                        let v = r[i] * c[j] / r_mean;
                        upd[i * cols + j] = grad.data[i * cols + j] / (v.sqrt() + 1e-8);
                    }
                }
            }
            Factored::Vector(v) => {
                for i in 0..n {
                    let g = grad.data[i];
                    v[i] = beta2 * v[i] + (1.0 - beta2) * (g * g + eps);
                    upd[i] = g / (v[i].sqrt() + 1e-8);
                }
            }
        }
        // RMS clipping: scale so rms(update) <= d.
        // hift-lint: allow(float-reduction): sequential RMS over the per-tensor update, never crosses threads
        let rms = (upd.iter().map(|x| x * x).sum::<f32>() / n as f32).sqrt();
        let denom = (rms / d_clip).max(1.0);
        par::par_apply2(&mut param.data, &upd, |p, u| {
            *p -= lr * (u / denom + wd * *p);
        });
    }

    fn state_bytes(&self, idx: usize) -> usize {
        self.states[idx].as_ref().map_or(0, |s| match &s.f {
            Factored::Matrix { r, c, .. } => (r.len() + c.len()) * 4,
            Factored::Vector(v) => v.len() * 4,
        })
    }

    fn total_state_bytes(&self) -> usize {
        (0..self.states.len()).map(|i| self.state_bytes(i)).sum()
    }

    fn kind(&self) -> OptimKind {
        OptimKind::Adafactor
    }

    fn export_state(&self) -> Vec<(String, Tensor)> {
        let mut out = Vec::new();
        for (i, slot) in self.states.iter().enumerate() {
            if let Some(s) = slot {
                match &s.f {
                    Factored::Matrix { r, c, .. } => {
                        out.push((format!("{i}.r"), Tensor::from_vec(r.clone(), &[r.len()])));
                        out.push((format!("{i}.c"), Tensor::from_vec(c.clone(), &[c.len()])));
                    }
                    Factored::Vector(v) => {
                        out.push((format!("{i}.acc"), Tensor::from_vec(v.clone(), &[v.len()])));
                    }
                }
                out.push((format!("{i}.t"), Tensor::from_vec(vec![s.t as f32], &[1])));
            }
        }
        out
    }

    fn import_state(
        &mut self,
        state: &[(String, Tensor)],
        params: &crate::tensor::TensorSet,
    ) -> anyhow::Result<()> {
        #[derive(Default)]
        struct Partial {
            r: Option<Vec<f32>>,
            c: Option<Vec<f32>>,
            acc: Option<Vec<f32>>,
            t: u64,
        }
        let mut parts: Vec<Partial> = (0..self.states.len()).map(|_| Partial::default()).collect();
        for (name, t) in state {
            let (idx, field) = super::state_key(name)?;
            if idx >= parts.len() || idx >= params.len() {
                anyhow::bail!("Adafactor state {name:?}: index out of range");
            }
            let p = &mut parts[idx];
            match field {
                "r" => p.r = Some(t.data.clone()),
                "c" => p.c = Some(t.data.clone()),
                "acc" => p.acc = Some(t.data.clone()),
                "t" => p.t = t.data.first().copied().unwrap_or(0.0) as u64,
                other => anyhow::bail!("unknown Adafactor state field {other:?}"),
            }
        }
        for (i, p) in parts.into_iter().enumerate() {
            let shape = &params.tensors[i].shape;
            self.states[i] = match (p.r, p.c, p.acc) {
                (None, None, None) => None,
                (Some(r), Some(c), None) => {
                    // Factored state must match the folded 2-D geometry of
                    // the parameter it belongs to.
                    let Some((rows, cols)) = Self::fold_2d(shape) else {
                        anyhow::bail!("Adafactor state for tensor {i}: factored state for a vector");
                    };
                    if r.len() != rows || c.len() != cols {
                        anyhow::bail!(
                            "Adafactor state for tensor {i}: factors {}x{} vs parameter {rows}x{cols}",
                            r.len(),
                            c.len()
                        );
                    }
                    Some(State { f: Factored::Matrix { r, c, rows, cols }, t: p.t })
                }
                (None, None, Some(acc)) => {
                    let numel = params.tensors[i].numel();
                    if Self::fold_2d(shape).is_some() || acc.len() != numel {
                        anyhow::bail!(
                            "Adafactor state for tensor {i}: dense accumulator of {} elements \
                             vs parameter {numel}",
                            acc.len()
                        );
                    }
                    Some(State { f: Factored::Vector(acc), t: p.t })
                }
                _ => anyhow::bail!("Adafactor state for tensor {i} mixes factored and dense"),
            };
            if let Some(s) = &self.states[i] {
                if s.t == 0 {
                    anyhow::bail!("Adafactor state for tensor {i} is missing its step count");
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_state_is_sublinear() {
        let mut opt = Adafactor::new(OptimCfg::new(OptimKind::Adafactor), 1);
        let mut p = Tensor::zeros(&[64, 32]);
        let g = Tensor::ones(&[64, 32]);
        opt.update(0, &mut p, &g, 0.01);
        assert_eq!(opt.state_bytes(0), (64 + 32) * 4);
        assert!(opt.state_bytes(0) < p.bytes() / 5, "factored ≪ dense");
    }

    #[test]
    fn vector_state_is_dense() {
        let mut opt = Adafactor::new(OptimCfg::new(OptimKind::Adafactor), 1);
        let mut p = Tensor::zeros(&[10]);
        let g = Tensor::ones(&[10]);
        opt.update(0, &mut p, &g, 0.01);
        assert_eq!(opt.state_bytes(0), 40);
    }

    #[test]
    fn update_rms_is_clipped() {
        let mut opt = Adafactor::new(OptimCfg::new(OptimKind::Adafactor), 1);
        let mut p = Tensor::zeros(&[4, 4]);
        let g = Tensor::from_vec(vec![1000.0; 16], &[4, 4]);
        opt.update(0, &mut p, &g, 0.1);
        let rms = (p.data.iter().map(|x| x * x).sum::<f32>() / 16.0).sqrt();
        assert!(rms <= 0.1 + 1e-4, "rms(Δ) ≤ lr·d, got {rms}");
    }

    #[test]
    fn higher_rank_folds_to_2d() {
        let mut opt = Adafactor::new(OptimCfg::new(OptimKind::Adafactor), 1);
        let mut p = Tensor::zeros(&[2, 3, 4]);
        let g = Tensor::ones(&[2, 3, 4]);
        opt.update(0, &mut p, &g, 0.01);
        assert_eq!(opt.state_bytes(0), (6 + 4) * 4);
    }
}
