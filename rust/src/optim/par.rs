//! Double-buffered update pipeline over the streamed-gradient seam: the
//! optimizer update of gradient *i* runs on a worker thread while the
//! backward chunk producing gradient *i+1* executes on the main thread.
//!
//! Determinism: a single worker applies jobs FIFO, and each update sees
//! exactly the `(param, grad, optimizer state)` it would see under the
//! serial [`super::FusedApply`] — the parameter tensor is checked out of
//! the `TensorSet` at dispatch and checked back in before the next
//! dispatch, and the backend guarantees it never reads a tensor again
//! after emitting its gradient.  Results are bit-identical to the serial
//! sink; only wall-clock changes.
//!
//! Ledger accounting happens on the main thread at completion time, in
//! dispatch order, so the event trace is identical to the serial sink's.
//!
//! Under data-parallel sharded execution (`--workers`, see
//! [`crate::backend::shard`]) nothing here changes: the reducer combines
//! the workers' per-row partials into one tensor per site *before* the
//! emit seam, so this sink still sees exactly one gradient per parameter,
//! in the same fixed order, with the same bits as a serial walk.  The
//! pipelined worker and the shard workers both register against the shared
//! [`crate::backend::par::ThreadBudget`], so kernels never oversubscribe.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use super::{clip_grad, OffloadLedger, Optimizer};
use crate::backend::GradSink;
use crate::tensor::{Tensor, TensorSet};

enum Job {
    Apply { idx: usize, param: Tensor, grad: Tensor, lr: f32, clip: f32 },
    Finish,
}

enum Done {
    Applied {
        idx: usize,
        param: Tensor,
        grad_bytes: u64,
        pre_state: u64,
        post_state: u64,
        elems: usize,
        /// The gradient's norm came back NaN/Inf — the update was skipped
        /// (same per-tensor safety net as the serial [`super::FusedApply`]).
        nonfinite: bool,
    },
    Optimizer(Box<dyn Optimizer>),
}

/// A [`GradSink`] that overlaps optimizer updates with the backward walk.
///
/// The optimizer moves into the worker thread for the duration of the run;
/// call [`PipelinedApply::into_optimizer`] after the backend has invoked
/// [`GradSink::finish`] to get it back.
pub struct PipelinedApply<'a> {
    jobs: Sender<Job>,
    done: Receiver<Done>,
    worker: Option<JoinHandle<()>>,
    ledger: Option<&'a mut OffloadLedger>,
    slot_param: Vec<usize>,
    grad_clip: f32,
    lr: f32,
    /// Parameter index of the job currently in flight (its tensor is
    /// checked out of the set).
    pending: Option<usize>,
    pending_grad_bytes: u64,
    /// Total parameter elements updated so far.
    pub updated_elems: usize,
    /// Gradients whose norm came back NaN/Inf (their updates were skipped
    /// on the worker — the per-tensor safety net; the pipelined sink does
    /// not support the f16 skip-step protocol, which needs the serial
    /// [`super::FusedApply`] in [`super::NonFinitePolicy::SkipStep`] mode).
    pub nonfinite_grads: usize,
    optimizer_back: Option<Box<dyn Optimizer>>,
}

impl<'a> PipelinedApply<'a> {
    pub fn new(
        optimizer: Box<dyn Optimizer>,
        ledger: Option<&'a mut OffloadLedger>,
        slot_param: Vec<usize>,
        grad_clip: f32,
        lr: f32,
    ) -> Self {
        let (jobs, job_rx) = channel::<Job>();
        let (done_tx, done) = channel::<Done>();
        // Charge the worker against the shared thread budget *before* it
        // spawns (deterministic accounting), and release when it exits —
        // while an update overlaps the backward walk, the par helpers on
        // both sides see one fewer slot instead of each assuming they own
        // the whole `HIFT_THREADS` cap.
        let budget_slot = crate::backend::par::register_worker();
        let worker = std::thread::spawn(move || {
            let _budget_slot = budget_slot;
            let mut opt = optimizer;
            while let Ok(job) = job_rx.recv() {
                match job {
                    Job::Apply { idx, mut param, mut grad, lr, clip } => {
                        let norm = clip_grad(&mut grad, clip);
                        let nonfinite = !norm.is_finite();
                        let grad_bytes = grad.bytes() as u64;
                        let pre_state = opt.state_bytes(idx) as u64;
                        let elems = if nonfinite { 0 } else { param.numel() };
                        if !nonfinite {
                            // A NaN/Inf gradient never reaches the
                            // optimizer: its moments would absorb the
                            // poison and every later step would inherit it.
                            opt.update(idx, &mut param, &grad, lr);
                        }
                        let post_state = opt.state_bytes(idx) as u64;
                        let done = Done::Applied {
                            idx,
                            param,
                            grad_bytes,
                            pre_state,
                            post_state,
                            elems,
                            nonfinite,
                        };
                        if done_tx.send(done).is_err() {
                            return;
                        }
                    }
                    Job::Finish => {
                        let _ = done_tx.send(Done::Optimizer(opt));
                        return;
                    }
                }
            }
        });
        PipelinedApply {
            jobs,
            done,
            worker: Some(worker),
            ledger,
            slot_param,
            grad_clip,
            lr,
            pending: None,
            pending_grad_bytes: 0,
            updated_elems: 0,
            nonfinite_grads: 0,
            optimizer_back: None,
        }
    }

    /// Wait for the in-flight update (if any), check its tensor back in and
    /// account the paging events — in dispatch order, like the serial sink.
    fn drain_pending(&mut self, params: &mut TensorSet) -> Result<()> {
        let Some(expect) = self.pending.take() else {
            return Ok(());
        };
        let done = self.done.recv().map_err(|_| anyhow!("update worker died"))?;
        let Done::Applied { idx, param, grad_bytes, pre_state, post_state, elems, nonfinite } =
            done
        else {
            bail!("update worker returned out-of-order result");
        };
        if idx != expect {
            bail!("update worker completed tensor {idx}, expected {expect}");
        }
        // Checking the tensor back in bumps its version, so the backend's
        // upload cache refreshes it — same as a tensor_mut update.
        *params.tensor_mut(idx) = param;
        self.updated_elems += elems;
        if nonfinite {
            self.nonfinite_grads += 1;
        }
        if let Some(l) = self.ledger.as_deref_mut() {
            if !nonfinite {
                // A skipped update never touched the optimizer state, so
                // no state transfer happened to account.
                l.page_in(pre_state);
                l.alloc_on_device(post_state.saturating_sub(pre_state));
                l.page_out(post_state);
            }
            l.grad_out(grad_bytes);
        }
        self.pending_grad_bytes = 0;
        Ok(())
    }

    /// Recover the optimizer once the run is finished.
    pub fn into_optimizer(mut self) -> Result<Box<dyn Optimizer>> {
        let opt = self
            .optimizer_back
            .take()
            .context("pipeline was not finished (backend must call GradSink::finish)")?;
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        Ok(opt)
    }
}

impl GradSink for PipelinedApply<'_> {
    fn grad(
        &mut self,
        slot: usize,
        name: &str,
        grad: Tensor,
        params: &mut TensorSet,
    ) -> Result<()> {
        let Some(&idx) = self.slot_param.get(slot) else {
            bail!("gradient slot {slot} ({name}) outside the update plan");
        };
        if params.names[idx] != name {
            bail!(
                "gradient slot {slot} maps to parameter {:?} but the backend emitted {name:?}",
                params.names[idx]
            );
        }
        self.drain_pending(params)?;
        // Check the tensor out and dispatch; the backend guarantees it will
        // not read an emitted tensor again, so the hole is unobservable.
        let taken = std::mem::replace(params.tensor_mut(idx), Tensor::from_vec(Vec::new(), &[0]));
        let grad_bytes = grad.bytes() as u64;
        if let Some(l) = self.ledger.as_deref_mut() {
            l.grad_in(grad_bytes);
        }
        self.pending_grad_bytes = grad_bytes;
        self.jobs
            .send(Job::Apply { idx, param: taken, grad, lr: self.lr, clip: self.grad_clip })
            .map_err(|_| anyhow!("update worker died"))?;
        self.pending = Some(idx);
        Ok(())
    }

    fn resident_bytes(&self) -> u64 {
        self.pending_grad_bytes
    }

    fn finish(&mut self, params: &mut TensorSet) -> Result<()> {
        self.drain_pending(params)?;
        self.jobs.send(Job::Finish).map_err(|_| anyhow!("update worker died"))?;
        match self.done.recv().map_err(|_| anyhow!("update worker died"))? {
            Done::Optimizer(opt) => {
                self.optimizer_back = Some(opt);
            }
            Done::Applied { .. } => bail!("update worker returned out-of-order result"),
        }
        // Contracts (HIFT_CHECK): with the pipeline drained, the sink seam
        // must be quiesced exactly like the serial FusedApply.
        if crate::contracts::enabled() {
            if let Some(l) = self.ledger.as_deref() {
                l.check_sink_quiesced()?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{build, FusedApply, OptimCfg, OptimKind};

    fn toy_params() -> TensorSet {
        let mut set = TensorSet::new();
        set.push("a", Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[4]));
        set.push("b", Tensor::from_vec(vec![-1.0, 0.5], &[2]));
        set.push("c", Tensor::from_vec(vec![0.1, 0.2, 0.3], &[3]));
        set
    }

    fn toy_grads() -> Vec<Tensor> {
        vec![
            Tensor::from_vec(vec![0.4, -0.2, 0.1, 2.0], &[4]),
            Tensor::from_vec(vec![1.5, -0.5], &[2]),
            Tensor::from_vec(vec![0.0, 0.1, -0.1], &[3]),
        ]
    }

    #[test]
    fn pipelined_is_bit_identical_to_serial_fused() {
        let cfg = OptimCfg::new(OptimKind::AdamW);
        let names = ["a", "b", "c"];

        let mut p_serial = toy_params();
        let mut opt_serial = build(cfg, 3);
        let mut led_serial = OffloadLedger::new();
        {
            let slots = [0usize, 1, 2];
            let mut sink = FusedApply::new(
                &mut *opt_serial,
                Some(&mut led_serial),
                &slots,
                cfg.grad_clip,
                0.02,
            );
            for (i, g) in toy_grads().into_iter().enumerate() {
                sink.grad(i, names[i], g, &mut p_serial).unwrap();
            }
        }

        let mut p_pipe = toy_params();
        let mut led_pipe = OffloadLedger::new();
        let mut sink = PipelinedApply::new(
            build(cfg, 3),
            Some(&mut led_pipe),
            vec![0, 1, 2],
            cfg.grad_clip,
            0.02,
        );
        for (i, g) in toy_grads().into_iter().enumerate() {
            sink.grad(i, names[i], g, &mut p_pipe).unwrap();
        }
        sink.finish(&mut p_pipe).unwrap();
        let updated = sink.updated_elems;
        let opt_back = sink.into_optimizer().unwrap();

        assert_eq!(updated, 9);
        for (x, y) in p_pipe.tensors.iter().zip(&p_serial.tensors) {
            assert_eq!(x.data, y.data, "pipelined update must be bit-identical");
        }
        assert_eq!(opt_back.total_state_bytes(), opt_serial.total_state_bytes());
        assert_eq!(led_pipe.h2d_bytes, led_serial.h2d_bytes);
        assert_eq!(led_pipe.d2h_bytes, led_serial.d2h_bytes);
        assert_eq!(led_pipe.peak_device_bytes, led_serial.peak_device_bytes);
        assert_eq!(led_pipe.peak_grad_resident_bytes, led_serial.peak_grad_resident_bytes);
        assert_eq!((led_pipe.page_ins, led_pipe.page_outs), (led_serial.page_ins, led_serial.page_outs));
    }

    #[test]
    fn pipelined_skips_nonfinite_grads() {
        let mut p = toy_params();
        let before = p.tensors[0].data.clone();
        let mut sink = PipelinedApply::new(
            build(OptimCfg::new(OptimKind::AdamW), 3),
            None,
            vec![0, 1, 2],
            1.0,
            0.1,
        );
        sink.grad(0, "a", Tensor::from_vec(vec![f32::NAN, 0.0, 0.0, 0.0], &[4]), &mut p)
            .unwrap();
        sink.grad(1, "b", Tensor::from_vec(vec![1.0, -1.0], &[2]), &mut p).unwrap();
        sink.finish(&mut p).unwrap();
        let (nf, updated) = (sink.nonfinite_grads, sink.updated_elems);
        let opt = sink.into_optimizer().unwrap();
        assert_eq!(nf, 1, "NaN gradient detected");
        assert_eq!(updated, 2, "only the healthy tensor's elements counted");
        assert_eq!(p.tensors[0].data, before, "poisoned tensor untouched");
        assert_ne!(p.tensors[1].data, vec![-1.0, 0.5], "healthy tensor updated");
        assert_eq!(opt.state_bytes(0), 0, "no moments allocated for the skipped tensor");
    }

    #[test]
    fn worker_threads_are_charged_to_the_shared_budget() {
        // Regression test for thread oversubscription: each live worker must
        // hold a slot in the process-wide thread budget so concurrent
        // `par::*` calls (e.g. the backward walk) see a reduced cap instead
        // of all sides assuming they own `HIFT_THREADS` cores.  Three live
        // sinks ⇒ at least three charged slots, regardless of what other
        // tests in this process are doing concurrently.
        let mut sinks: Vec<PipelinedApply> = (0..3)
            .map(|_| {
                PipelinedApply::new(
                    build(OptimCfg::new(OptimKind::Sgd), 3),
                    None,
                    vec![0, 1, 2],
                    0.0,
                    0.1,
                )
            })
            .collect();
        assert!(
            crate::backend::par::budget_in_flight() >= 3,
            "3 live workers must hold >= 3 budget slots, saw {}",
            crate::backend::par::budget_in_flight()
        );
        let mut p = toy_params();
        for sink in &mut sinks {
            sink.finish(&mut p).unwrap();
        }
        for sink in sinks {
            sink.into_optimizer().unwrap();
        }
    }

    #[test]
    fn into_optimizer_requires_finish() {
        let mut p = toy_params();
        let mut sink = PipelinedApply::new(
            build(OptimCfg::new(OptimKind::Sgd), 3),
            None,
            vec![0, 1, 2],
            0.0,
            0.1,
        );
        sink.grad(0, "a", Tensor::from_vec(vec![1.0, 1.0, 1.0, 1.0], &[4]), &mut p).unwrap();
        // finish not called: the optimizer is still in the worker.
        assert!(sink.into_optimizer().is_err());
    }
}
