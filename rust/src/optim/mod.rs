//! Optimizers over Rust-owned f32 parameter buffers, plus the host↔device
//! paging ledger that realizes the paper's "optimizer states live on CPU,
//! only the active group's states visit the GPU" discipline (Algorithm 1
//! steps i/k).
//!
//! HiFT is *optimizer-independent* (paper §1): the coordinator only sees the
//! [`Optimizer`] trait.  All five optimizers the paper evaluates are here —
//! AdamW, SGD, SGD-with-momentum, Adagrad, Adafactor — each with its
//! distinctive state footprint, which is what Tables 8–12 account for:
//!
//! | optimizer | state per param (f32) | #Sta multiplier |
//! |---|---|---|
//! | AdamW     | m + v                 | 2× |
//! | SGDM      | momentum              | 1× |
//! | SGD       | —                     | 0× |
//! | Adagrad   | accumulator           | 1× |
//! | Adafactor | row + col factors     | ~(r+c)/(r·c) ≪ 1× for matrices |
//!
//! Updates are applied *per parameter tensor* so the scheduler can page in
//! exactly the active group's state; the update loops are the L3 hot path
//! (profiled in EXPERIMENTS.md §Perf).
//!
//! The **fused-update layer** sits on top: [`FusedApply`] is a
//! [`crate::backend::GradSink`] that clips, pages state, updates and drops
//! each gradient the moment the backward walk emits it (LOMO-style fusion,
//! Lv et al. 2023), and [`PipelinedApply`] double-buffers it — the
//! optimizer update of gradient *i* runs on a worker thread while the
//! backward chunk producing gradient *i+1* executes, in fixed order, so
//! results stay bit-identical to the serial sink.

mod adafactor;
mod adagrad;
mod adamw;
mod apply;
mod par;
mod scaler;
mod sgd;

pub use adafactor::Adafactor;
pub use adagrad::Adagrad;
pub use adamw::AdamW;
pub use apply::{FusedApply, NonFinitePolicy};
pub use par::PipelinedApply;
pub use scaler::{LossScaler, ScalerEvent};
pub use sgd::{Sgd, Sgdm};

use anyhow::Result;

use crate::tensor::{Tensor, TensorSet};

/// Which optimizer (paper Appendix C "Optimizers").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OptimKind {
    AdamW,
    Sgd,
    Sgdm,
    Adagrad,
    Adafactor,
}

impl OptimKind {
    pub const ALL: [OptimKind; 5] =
        [OptimKind::AdamW, OptimKind::Sgdm, OptimKind::Sgd, OptimKind::Adafactor, OptimKind::Adagrad];

    pub fn name(&self) -> &'static str {
        match self {
            OptimKind::AdamW => "AdamW",
            OptimKind::Sgd => "SGD",
            OptimKind::Sgdm => "SGDM",
            OptimKind::Adagrad => "Adagrad",
            OptimKind::Adafactor => "Adafactor",
        }
    }

    pub fn parse(s: &str) -> Option<OptimKind> {
        match s.to_ascii_lowercase().as_str() {
            "adamw" | "adam" => Some(OptimKind::AdamW),
            "sgd" => Some(OptimKind::Sgd),
            "sgdm" => Some(OptimKind::Sgdm),
            "adagrad" => Some(OptimKind::Adagrad),
            "adafactor" => Some(OptimKind::Adafactor),
            _ => None,
        }
    }

    /// Optimizer-state f32 words per parameter *element* (matrices may be
    /// cheaper for Adafactor; this is the dense upper bound used by the
    /// closed-form memory identity).
    pub fn state_multiplier(&self) -> f64 {
        match self {
            OptimKind::AdamW => 2.0,
            OptimKind::Sgdm | OptimKind::Adagrad => 1.0,
            OptimKind::Sgd => 0.0,
            OptimKind::Adafactor => 0.0, // sublinear; exact bytes come from state_bytes()
        }
    }
}

/// Hyperparameters shared by all optimizers (unused fields ignored).
#[derive(Debug, Clone, Copy)]
pub struct OptimCfg {
    pub kind: OptimKind,
    pub weight_decay: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub momentum: f32,
    /// Gradient clipping by global-norm per tensor (0 = off).
    pub grad_clip: f32,
}

impl OptimCfg {
    pub fn new(kind: OptimKind) -> Self {
        OptimCfg {
            kind,
            weight_decay: 0.0,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            momentum: 0.9,
            grad_clip: 1.0,
        }
    }
}

/// The coordinator-facing optimizer interface.
///
/// `idx` identifies the parameter tensor (stable across the run) so state is
/// tracked per tensor — the granularity at which HiFT pages state between
/// host and device.  `Send` so the [`PipelinedApply`] double-buffer can run
/// updates on a worker thread; every implementation is plain owned data.
pub trait Optimizer: Send {
    /// Apply one update for parameter tensor `idx` in place.
    fn update(&mut self, idx: usize, param: &mut Tensor, grad: &Tensor, lr: f32);

    /// Bytes of optimizer state currently held for tensor `idx`.
    fn state_bytes(&self, idx: usize) -> usize;

    /// Total state bytes across all tensors.
    fn total_state_bytes(&self) -> usize;

    fn kind(&self) -> OptimKind;

    /// Snapshot every lazily-allocated per-tensor state buffer as named
    /// tensors, keyed `"{idx}.{field}"` (e.g. `"3.m"`, `"3.v"`, `"3.t"`),
    /// so checkpoints can persist optimizer moments and a resumed run is
    /// bit-identical to an uninterrupted one.  Stateless optimizers return
    /// an empty list.
    fn export_state(&self) -> Vec<(String, Tensor)> {
        Vec::new()
    }

    /// Restore a snapshot produced by [`Optimizer::export_state`] on an
    /// optimizer of the same kind and parameter-tensor count.  `params` is
    /// the parameter set the optimizer will run against: every imported
    /// buffer is validated against the corresponding tensor's geometry, so
    /// a size-mismatched checkpoint fails here with context instead of
    /// panicking inside the first fused update.
    fn import_state(&mut self, state: &[(String, Tensor)], params: &TensorSet) -> Result<()> {
        let _ = params;
        if state.is_empty() {
            Ok(())
        } else {
            anyhow::bail!(
                "{:?} optimizer carries no state, but {} entries were given",
                self.kind(),
                state.len()
            )
        }
    }
}

/// Split a `"{idx}.{field}"` optimizer-state key (the naming contract of
/// [`Optimizer::export_state`]).
pub(crate) fn state_key(name: &str) -> Result<(usize, &str)> {
    let (idx, field) = name
        .split_once('.')
        .ok_or_else(|| anyhow::anyhow!("bad optimizer state key {name:?}"))?;
    let idx = idx
        .parse()
        .map_err(|_| anyhow::anyhow!("bad optimizer state key {name:?} (index not a number)"))?;
    Ok((idx, field))
}

/// Construct an optimizer for `n_params` parameter tensors.
pub fn build(cfg: OptimCfg, n_params: usize) -> Box<dyn Optimizer> {
    match cfg.kind {
        OptimKind::AdamW => Box::new(AdamW::new(cfg, n_params)),
        OptimKind::Sgd => Box::new(Sgd::new(cfg)),
        OptimKind::Sgdm => Box::new(Sgdm::new(cfg, n_params)),
        OptimKind::Adagrad => Box::new(Adagrad::new(cfg, n_params)),
        OptimKind::Adafactor => Box::new(Adafactor::new(cfg, n_params)),
    }
}

/// Clip a gradient tensor to `max_norm` (no-op if 0); returns the pre-clip
/// norm.
///
/// A NaN/Inf gradient is left **untouched** and signalled through the
/// returned non-finite norm: scaling by `max_norm / inf` would zero the
/// finite entries and turn the Inf entries into NaN, silently feeding a
/// corrupt-but-plausible update into the optimizer.  Callers (the
/// [`FusedApply`]/[`PipelinedApply`] sinks) check `norm.is_finite()` and
/// skip the update instead — the safety net the f16 loss scaler's
/// skip-step path is built on.
pub fn clip_grad(grad: &mut Tensor, max_norm: f32) -> f32 {
    let norm = grad.l2_norm();
    if !norm.is_finite() {
        return norm;
    }
    if max_norm > 0.0 && norm > max_norm {
        grad.scale(max_norm / (norm + 1e-12));
    }
    norm
}

// ---------------------------------------------------------------------------
// Host↔device paging ledger (Algorithm 1 steps i and k)
// ---------------------------------------------------------------------------

/// The paging accounting backend — one source of truth for both paging
/// paths:
///
/// * **optimizer state** (here, via [`FusedApply`]/[`PipelinedApply`]):
///   each tensor's state pages in, updates, and pages out around its fused
///   update — Algorithm 1 steps i/k at tensor granularity;
/// * **parameter masters** ([`crate::tensor::paged::UnitPager`] owns its
///   own instance): with `--offload host` these are *real* transfers into
///   a host pool, and `device_resident`/`peak_device_bytes` become the
///   enforced arena residency (`tests/offload.rs` regression-checks that
///   ledger counts equal the pool's observed transfer events).
///
/// The paper's peak-communication claim (§4.3: "#Sta values in Tables 8–12")
/// is checked against `max_inflight_bytes`; the memory claim against
/// `peak_device_bytes`.
#[derive(Debug, Clone, Default)]
pub struct OffloadLedger {
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    device_resident: u64,
    pub peak_device_bytes: u64,
    /// Largest single page-in (the per-step communication peak).
    pub max_inflight_bytes: u64,
    pub page_ins: u64,
    pub page_outs: u64,
    grad_resident: u64,
    /// Peak bytes of parameter gradients held by the update sink at once.
    /// Streamed fused updates keep this at ≈ one tensor; the old collected
    /// path held the whole group.
    pub peak_grad_resident_bytes: u64,
    /// Conservation totals (see [`OffloadLedger::check_conservation`]):
    /// lifetime bytes allocated on device, and lifetime gradient in/out.
    alloc_bytes: u64,
    grad_in_bytes: u64,
    grad_out_bytes: u64,
}

impl OffloadLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Move `bytes` of optimizer state host → device (Algorithm 1 step i).
    /// Zero-byte "transfers" (a group's first visit, before any state is
    /// allocated; stateless SGD) are no-ops, not paging events.
    pub fn page_in(&mut self, bytes: u64) {
        if bytes == 0 {
            return;
        }
        self.h2d_bytes += bytes;
        self.device_resident += bytes;
        self.peak_device_bytes = self.peak_device_bytes.max(self.device_resident);
        self.max_inflight_bytes = self.max_inflight_bytes.max(bytes);
        self.page_ins += 1;
    }

    /// Account state newly *allocated* on device (first visit of a group:
    /// moments are created there, not copied from host).
    pub fn alloc_on_device(&mut self, bytes: u64) {
        self.device_resident += bytes;
        self.peak_device_bytes = self.peak_device_bytes.max(self.device_resident);
        self.alloc_bytes += bytes;
    }

    /// Move `bytes` back device → host (Algorithm 1 step k).  Zero-byte
    /// transfers are no-ops (see [`OffloadLedger::page_in`]).
    pub fn page_out(&mut self, bytes: u64) {
        if bytes == 0 {
            return;
        }
        debug_assert!(bytes <= self.device_resident, "paging out more than resident");
        self.d2h_bytes += bytes;
        self.device_resident = self.device_resident.saturating_sub(bytes);
        self.page_outs += 1;
    }

    pub fn device_resident(&self) -> u64 {
        self.device_resident
    }

    /// A gradient arrived at the update sink.
    pub fn grad_in(&mut self, bytes: u64) {
        self.grad_resident += bytes;
        self.peak_grad_resident_bytes = self.peak_grad_resident_bytes.max(self.grad_resident);
        self.grad_in_bytes += bytes;
    }

    /// A gradient was consumed (updated into the parameters) and dropped.
    pub fn grad_out(&mut self, bytes: u64) {
        self.grad_resident = self.grad_resident.saturating_sub(bytes);
        self.grad_out_bytes += bytes;
    }

    pub fn grad_resident(&self) -> u64 {
        self.grad_resident
    }

    /// Byte-conservation invariant (the runtime half of the ledger
    /// contract, see docs/CONTRACTS.md): everything that ever landed on the
    /// device (paged in or allocated there) either left again or is still
    /// resident, and likewise for sink-held gradients.  The saturating
    /// subtractions in [`OffloadLedger::page_out`] / `grad_out` make any
    /// over-release show up here as an inequality instead of a wrap.
    ///
    /// Always compiled (it is cheap and unit-testable); call sites on the
    /// hot paths are gated by [`crate::contracts::enabled`].
    pub fn check_conservation(&self) -> anyhow::Result<()> {
        let landed = self.h2d_bytes as u128 + self.alloc_bytes as u128;
        let accounted = self.d2h_bytes as u128 + self.device_resident as u128;
        anyhow::ensure!(
            landed == accounted,
            "OffloadLedger conservation breach: h2d {} + alloc {} != d2h {} + resident {}",
            self.h2d_bytes,
            self.alloc_bytes,
            self.d2h_bytes,
            self.device_resident
        );
        anyhow::ensure!(
            self.grad_in_bytes as u128 == self.grad_out_bytes as u128 + self.grad_resident as u128,
            "OffloadLedger gradient conservation breach: in {} != out {} + resident {}",
            self.grad_in_bytes,
            self.grad_out_bytes,
            self.grad_resident
        );
        Ok(())
    }

    /// Conservation plus full quiescence: nothing still resident at a
    /// sink's end-of-step seam (every tensor's state paged back out, every
    /// gradient consumed).
    pub fn check_sink_quiesced(&self) -> anyhow::Result<()> {
        self.check_conservation()?;
        anyhow::ensure!(
            self.grad_resident == 0,
            "update sink finished with {} gradient bytes still resident",
            self.grad_resident
        );
        anyhow::ensure!(
            self.device_resident == 0,
            "update sink finished with {} state bytes still on device",
            self.device_resident
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    /// Every optimizer must descend a convex quadratic: f(x) = ||x - c||².
    fn converges(kind: OptimKind, lr: f32) -> f32 {
        let mut cfg = OptimCfg::new(kind);
        cfg.weight_decay = 0.0;
        let mut opt = build(cfg, 1);
        let target = Tensor::from_vec(vec![1.0, -2.0, 3.0, 0.5], &[4]);
        let mut rng = Pcg32::seeded(11);
        let mut x = Tensor::randn(&[4], 1.0, &mut rng);
        for _ in 0..400 {
            let mut g = x.clone();
            g.axpy(-1.0, &target); // grad = x - c
            g.scale(2.0);
            opt.update(0, &mut x, &g, lr);
        }
        let mut d = x;
        d.axpy(-1.0, &target);
        d.l2_norm()
    }

    #[test]
    fn all_optimizers_converge_on_quadratic() {
        assert!(converges(OptimKind::AdamW, 0.05) < 0.05, "adamw");
        assert!(converges(OptimKind::Sgd, 0.05) < 0.05, "sgd");
        assert!(converges(OptimKind::Sgdm, 0.02) < 0.05, "sgdm");
        assert!(converges(OptimKind::Adagrad, 0.5) < 0.05, "adagrad");
        assert!(converges(OptimKind::Adafactor, 0.05) < 0.2, "adafactor");
    }

    #[test]
    fn state_multipliers_match_lazy_state() {
        let t = Tensor::zeros(&[16, 8]);
        let g = Tensor::ones(&[16, 8]);
        for kind in OptimKind::ALL {
            let mut opt = build(OptimCfg::new(kind), 2);
            assert_eq!(opt.state_bytes(0), 0, "{kind:?} state is lazy");
            let mut p = t.clone();
            opt.update(0, &mut p, &g, 0.01);
            let expect = (kind.state_multiplier() * t.bytes() as f64) as usize;
            match kind {
                OptimKind::Adafactor => {
                    // row + col factors: (16 + 8) * 4 bytes ≪ dense 128*4
                    assert_eq!(opt.state_bytes(0), (16 + 8) * 4);
                }
                _ => assert_eq!(opt.state_bytes(0), expect, "{kind:?}"),
            }
            assert_eq!(opt.total_state_bytes(), opt.state_bytes(0));
        }
    }

    #[test]
    fn zero_byte_transfers_are_not_paging_events() {
        // Regression: Hift used to call page_in(0) on a group's first visit
        // (state not yet allocated), inflating the event counts with no-op
        // transfers.
        let mut l = OffloadLedger::new();
        l.page_in(0);
        l.page_out(0);
        assert_eq!((l.page_ins, l.page_outs), (0, 0), "zero-byte transfer is not an event");
        assert_eq!(l.h2d_bytes, 0);
        assert_eq!(l.d2h_bytes, 0);
        assert_eq!(l.max_inflight_bytes, 0);
        l.page_in(64);
        l.page_out(64);
        assert_eq!((l.page_ins, l.page_outs), (1, 1), "real transfers still count");
    }

    #[test]
    fn ledger_tracks_grad_residency() {
        let mut l = OffloadLedger::new();
        l.grad_in(100);
        l.grad_out(100);
        l.grad_in(40);
        l.grad_in(40);
        assert_eq!(l.grad_resident(), 80);
        assert_eq!(l.peak_grad_resident_bytes, 100);
        l.grad_out(40);
        l.grad_out(40);
        assert_eq!(l.grad_resident(), 0);
    }

    #[test]
    fn ledger_tracks_peak_and_inflight() {
        let mut l = OffloadLedger::new();
        l.page_in(100);
        l.page_in(50);
        assert_eq!(l.device_resident(), 150);
        assert_eq!(l.peak_device_bytes, 150);
        l.page_out(150);
        assert_eq!(l.device_resident(), 0);
        l.page_in(80);
        assert_eq!(l.peak_device_bytes, 150, "peak remembered");
        assert_eq!(l.max_inflight_bytes, 100);
        assert_eq!((l.page_ins, l.page_outs), (3, 1));
    }

    #[test]
    fn clip_grad_caps_norm() {
        let mut g = Tensor::from_vec(vec![3.0, 4.0], &[2]);
        let pre = clip_grad(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        assert!((g.l2_norm() - 1.0).abs() < 1e-5);
        let mut g2 = Tensor::from_vec(vec![0.3, 0.4], &[2]);
        clip_grad(&mut g2, 1.0);
        assert!((g2.l2_norm() - 0.5).abs() < 1e-6, "below threshold untouched");
    }

    #[test]
    fn clip_grad_leaves_nonfinite_grads_untouched() {
        // Regression: clipping used to scale by max/inf = 0, turning an
        // Inf gradient into a mix of zeros and NaNs that the optimizer
        // would then absorb as a plausible update.
        let mut g = Tensor::from_vec(vec![1.0, f32::INFINITY, -2.0], &[3]);
        let norm = clip_grad(&mut g, 1.0);
        assert!(!norm.is_finite(), "non-finite norm must be surfaced");
        assert_eq!(g.data[0], 1.0, "finite entries untouched");
        assert_eq!(g.data[1], f32::INFINITY, "Inf preserved, not laundered to NaN");
        let mut g = Tensor::from_vec(vec![f32::NAN, 0.5], &[2]);
        let norm = clip_grad(&mut g, 1.0);
        assert!(norm.is_nan());
        assert_eq!(g.data[1], 0.5);
    }

    #[test]
    fn ledger_conservation_checks() {
        // Balanced traffic: page in 100, alloc 28, page everything out.
        let mut l = OffloadLedger::new();
        l.page_in(100);
        l.alloc_on_device(28);
        l.page_out(128);
        l.grad_in(64);
        l.grad_out(64);
        l.check_conservation().unwrap();
        l.check_sink_quiesced().unwrap();

        // Residency is fine for conservation but fails quiescence.
        let mut l = OffloadLedger::new();
        l.page_in(100);
        l.check_conservation().unwrap();
        let err = l.check_sink_quiesced().unwrap_err();
        assert!(err.to_string().contains("still on device"), "{err}");

        // A gradient over-release saturates instead of wrapping, and the
        // conservation equation exposes it.
        let mut l = OffloadLedger::new();
        l.grad_in(10);
        l.grad_out(25);
        assert!(l.check_conservation().is_err(), "gradient over-release must not balance");
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in OptimKind::ALL {
            assert_eq!(OptimKind::parse(k.name()), Some(k));
        }
        assert_eq!(OptimKind::parse("nope"), None);
    }

    #[test]
    fn state_export_import_roundtrip_is_bit_identical() {
        let g = Tensor::from_vec(vec![0.5, -1.0, 2.0, 0.25], &[2, 2]);
        let mut pset = TensorSet::new();
        pset.push("p0", Tensor::ones(&[2, 2]));
        pset.push("p1", Tensor::ones(&[3]));
        for kind in OptimKind::ALL {
            let cfg = OptimCfg::new(kind);
            let mut a = build(cfg, 2);
            let mut pa = Tensor::ones(&[2, 2]);
            for _ in 0..3 {
                a.update(0, &mut pa, &g, 0.05);
            }
            // A fresh optimizer with imported state must continue exactly
            // where the original left off.
            let mut b = build(cfg, 2);
            b.import_state(&a.export_state(), &pset).unwrap();
            assert_eq!(a.total_state_bytes(), b.total_state_bytes(), "{kind:?}: state size");
            let mut pb = pa.clone();
            a.update(0, &mut pa, &g, 0.05);
            b.update(0, &mut pb, &g, 0.05);
            assert_eq!(pa.data, pb.data, "{kind:?}: resumed update must be bit-identical");
        }
    }

    #[test]
    fn import_rejects_garbage_state() {
        let mut pset = TensorSet::new();
        pset.push("p0", Tensor::ones(&[1]));
        let mut opt = build(OptimCfg::new(OptimKind::AdamW), 1);
        assert!(opt.import_state(&[("nokey".to_string(), Tensor::zeros(&[1]))], &pset).is_err());
        assert!(opt.import_state(&[("9.m".to_string(), Tensor::zeros(&[1]))], &pset).is_err());
        assert!(
            opt.import_state(&[("0.m".to_string(), Tensor::zeros(&[1]))], &pset).is_err(),
            "m without v/t is incomplete"
        );
        // Size-mismatched moments must fail at import, not panic at the
        // first update (a resumed run with the wrong preset's opt.bin).
        let wrong_size = vec![
            ("0.m".to_string(), Tensor::zeros(&[2])),
            ("0.v".to_string(), Tensor::zeros(&[2])),
            ("0.t".to_string(), Tensor::from_vec(vec![1.0], &[1])),
        ];
        assert!(opt.import_state(&wrong_size, &pset).is_err(), "2-elem moments vs 1-elem param");
        let mut sgdm = build(OptimCfg::new(OptimKind::Sgdm), 1);
        assert!(
            sgdm.import_state(&[("0.u".to_string(), Tensor::zeros(&[3]))], &pset).is_err(),
            "momentum length must match the parameter"
        );
        let mut sgd = build(OptimCfg::new(OptimKind::Sgd), 1);
        assert!(sgd.import_state(&[("0.m".to_string(), Tensor::zeros(&[1]))], &pset).is_err());
        assert!(sgd.import_state(&[], &pset).is_ok());
    }
}
