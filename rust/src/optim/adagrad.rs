//! Adagrad (Duchi et al., 2010): per-element accumulated squared gradients.
//!
//! State: one accumulator per element (ζ₂ = ζ₁; "Adagrad" rows of
//! Tables 8–12).

use super::{OptimCfg, OptimKind, Optimizer};
use crate::backend::par;
use crate::tensor::Tensor;

pub struct Adagrad {
    cfg: OptimCfg,
    states: Vec<Option<Vec<f32>>>,
}

impl Adagrad {
    pub fn new(cfg: OptimCfg, n_params: usize) -> Self {
        Adagrad { cfg, states: (0..n_params).map(|_| None).collect() }
    }
}

impl Optimizer for Adagrad {
    fn update(&mut self, idx: usize, param: &mut Tensor, grad: &Tensor, lr: f32) {
        assert_eq!(param.shape, grad.shape);
        let eps = self.cfg.eps.max(1e-10);
        let wd = self.cfg.weight_decay;
        let acc = self.states[idx].get_or_insert_with(|| vec![0.0; param.numel()]);
        par::par_apply3(&mut param.data, acc, &grad.data, |p, a, g| {
            let g = g + wd * *p;
            *a += g * g;
            *p -= lr * g / (a.sqrt() + eps);
        });
    }

    fn state_bytes(&self, idx: usize) -> usize {
        self.states[idx].as_ref().map_or(0, |b| b.len() * 4)
    }

    fn total_state_bytes(&self) -> usize {
        (0..self.states.len()).map(|i| self.state_bytes(i)).sum()
    }

    fn kind(&self) -> OptimKind {
        OptimKind::Adagrad
    }

    fn export_state(&self) -> Vec<(String, Tensor)> {
        self.states
            .iter()
            .enumerate()
            .filter_map(|(i, s)| {
                s.as_ref()
                    .map(|b| (format!("{i}.acc"), Tensor::from_vec(b.clone(), &[b.len()])))
            })
            .collect()
    }

    fn import_state(
        &mut self,
        state: &[(String, Tensor)],
        params: &crate::tensor::TensorSet,
    ) -> anyhow::Result<()> {
        for slot in self.states.iter_mut() {
            *slot = None;
        }
        for (name, t) in state {
            let (idx, field) = super::state_key(name)?;
            if field != "acc" {
                anyhow::bail!("unknown Adagrad state field {field:?}");
            }
            if idx >= self.states.len() || idx >= params.len() {
                anyhow::bail!("Adagrad state {name:?}: index out of range");
            }
            let numel = params.tensors[idx].numel();
            if t.data.len() != numel {
                anyhow::bail!(
                    "Adagrad state {name:?} has {} elements, parameter has {numel}",
                    t.data.len()
                );
            }
            self.states[idx] = Some(t.data.clone());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_lr_sized() {
        let mut opt = Adagrad::new(OptimCfg::new(OptimKind::Adagrad), 1);
        let mut p = Tensor::zeros(&[1]);
        let g = Tensor::from_vec(vec![7.0], &[1]);
        opt.update(0, &mut p, &g, 0.1);
        // step = lr * g / sqrt(g²) = lr
        assert!((p.data[0] + 0.1).abs() < 1e-5);
    }

    #[test]
    fn steps_shrink_over_time() {
        let mut opt = Adagrad::new(OptimCfg::new(OptimKind::Adagrad), 1);
        let mut p = Tensor::zeros(&[1]);
        let g = Tensor::ones(&[1]);
        opt.update(0, &mut p, &g, 0.1);
        let d1 = p.data[0].abs();
        let before = p.data[0];
        opt.update(0, &mut p, &g, 0.1);
        let d2 = (p.data[0] - before).abs();
        assert!(d2 < d1, "adagrad step sizes must be non-increasing");
    }
}
