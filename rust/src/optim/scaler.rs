//! Dynamic loss scaling for f16 backward passes — the grow/backoff state
//! machine (Micikevicius et al., 2018) that keeps small gradients above
//! f16's subnormal floor without letting large ones overflow.
//!
//! Protocol per step (the strategies drive it):
//!
//! 1. `scale()` is installed on the backend (`ExecBackend::set_loss_scale`);
//!    the backward seed is multiplied by it, so every f16 intermediate and
//!    emitted gradient is shifted up by `scale`.
//! 2. The backend divides each gradient by `scale` (exact — the scale is
//!    always a power of two) before handing it to the sink, so clipping and
//!    the optimizer see honest magnitudes.
//! 3. The sink ([`super::FusedApply`] in skip-step mode) detects any
//!    NaN/Inf gradient and drops the whole step atomically.
//! 4. `note_step(overflow)` advances the machine: an overflow halves the
//!    scale and resets the good-step counter; `growth_interval` consecutive
//!    good steps double it.
//!
//! Scales are clamped to powers of two in `[min_scale, max_scale]`, so
//! scale/unscale round trips are bit-exact on every normal f32 value.

/// What [`LossScaler::note_step`] did to the scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalerEvent {
    /// Scale unchanged.
    None,
    /// `growth_interval` good steps elapsed — scale doubled.
    Grew,
    /// Overflow — scale halved (and the step was skipped by the sink).
    BackedOff,
}

use crate::backend::ExecBackend;

/// The grow/backoff loss-scale state machine.
#[derive(Debug, Clone)]
pub struct LossScaler {
    scale: f32,
    growth_interval: u32,
    good_steps: u32,
    min_scale: f32,
    max_scale: f32,
    /// Times the scale doubled.
    pub growths: u64,
    /// Times the scale halved on overflow.
    pub backoffs: u64,
    /// Steps dropped because a gradient came back non-finite.
    pub skipped_steps: u64,
}

impl LossScaler {
    /// `init` should be a power of two; `growth_interval` is the number of
    /// consecutive overflow-free steps before the scale doubles.
    pub fn new(init: f32, growth_interval: u32) -> Self {
        LossScaler {
            scale: init,
            growth_interval: growth_interval.max(1),
            good_steps: 0,
            min_scale: 1.0,
            max_scale: 16_777_216.0, // 2^24
            growths: 0,
            backoffs: 0,
            skipped_steps: 0,
        }
    }

    /// The default machine for f16 runs: init 2^12 with a short growth
    /// interval — reference-scale runs are tens-to-hundreds of steps, so a
    /// production-style 2000-step interval would never fire.  (PyTorch's
    /// GradScaler defaults to 2^16 / 2000; the dynamics are identical,
    /// only the time constants are scaled to this codebase's runs.)
    pub fn default_f16() -> Self {
        LossScaler::new(4096.0, 200)
    }

    /// The scale to seed the next backward with (always a power of two).
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Advance the machine after a step: `overflow` = the sink saw a
    /// non-finite gradient and dropped the step.
    pub fn note_step(&mut self, overflow: bool) -> ScalerEvent {
        if overflow {
            self.skipped_steps += 1;
            self.good_steps = 0;
            let next = (self.scale * 0.5).max(self.min_scale);
            if next < self.scale {
                self.scale = next;
                self.backoffs += 1;
                return ScalerEvent::BackedOff;
            }
            return ScalerEvent::None; // already at the floor
        }
        self.good_steps += 1;
        if self.good_steps >= self.growth_interval {
            self.good_steps = 0;
            let next = (self.scale * 2.0).min(self.max_scale);
            if next > self.scale {
                self.scale = next;
                self.growths += 1;
                return ScalerEvent::Grew;
            }
        }
        ScalerEvent::None
    }

    /// Pre-step half of the scaler protocol, shared by every gradient
    /// strategy: lazily engage a scaler in `slot` iff the backend's
    /// precision needs loss scaling, install this step's scale, and report
    /// whether scaling is active (the sink must then run in
    /// [`super::NonFinitePolicy::SkipStep`]).
    pub fn prepare_step(slot: &mut Option<LossScaler>, be: &mut dyn ExecBackend) -> bool {
        if be.precision().needs_loss_scaling() && slot.is_none() {
            *slot = Some(LossScaler::default_f16());
        }
        match slot {
            Some(sc) => {
                be.set_loss_scale(sc.scale());
                true
            }
            None => false,
        }
    }

    /// Post-step half: fold what the sink observed into the backend's
    /// [`crate::backend::RuntimeStats`] and advance the state machine.
    pub fn finish_step(
        slot: &mut Option<LossScaler>,
        be: &mut dyn ExecBackend,
        nonfinite_grads: usize,
        step_skipped: bool,
    ) {
        if nonfinite_grads > 0 || step_skipped {
            be.note_numerics(nonfinite_grads as u64, step_skipped);
        }
        if let Some(sc) = slot {
            let event = sc.note_step(step_skipped);
            be.note_loss_scale(sc.scale(), event);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_after_interval_of_good_steps() {
        let mut s = LossScaler::new(1024.0, 4);
        for _ in 0..3 {
            assert_eq!(s.note_step(false), ScalerEvent::None);
        }
        assert_eq!(s.note_step(false), ScalerEvent::Grew);
        assert_eq!(s.scale(), 2048.0);
        assert_eq!(s.growths, 1);
        // counter restarts: three more good steps don't grow again yet
        for _ in 0..3 {
            assert_eq!(s.note_step(false), ScalerEvent::None);
        }
        assert_eq!(s.note_step(false), ScalerEvent::Grew);
        assert_eq!(s.scale(), 4096.0);
    }

    #[test]
    fn overflow_halves_and_resets_the_good_counter() {
        let mut s = LossScaler::new(1024.0, 4);
        s.note_step(false);
        s.note_step(false);
        s.note_step(false);
        assert_eq!(s.note_step(true), ScalerEvent::BackedOff);
        assert_eq!(s.scale(), 512.0);
        assert_eq!((s.backoffs, s.skipped_steps), (1, 1));
        // the 3 pre-overflow good steps were forgotten
        for _ in 0..3 {
            assert_eq!(s.note_step(false), ScalerEvent::None);
        }
        assert_eq!(s.note_step(false), ScalerEvent::Grew);
    }

    #[test]
    fn scale_clamps_at_floor_and_ceiling() {
        let mut s = LossScaler::new(2.0, 1);
        assert_eq!(s.note_step(true), ScalerEvent::BackedOff);
        assert_eq!(s.scale(), 1.0);
        assert_eq!(s.note_step(true), ScalerEvent::None, "floor: no further halving");
        assert_eq!(s.scale(), 1.0);
        assert_eq!(s.skipped_steps, 2, "skips still counted at the floor");

        let mut s = LossScaler::new(16_777_216.0, 1);
        assert_eq!(s.note_step(false), ScalerEvent::None, "ceiling: no growth past max");
        assert_eq!(s.scale(), 16_777_216.0);
    }

    #[test]
    fn scales_stay_powers_of_two() {
        let mut s = LossScaler::default_f16();
        for i in 0..500 {
            s.note_step(i % 7 == 0);
            let sc = s.scale();
            assert!(sc >= 1.0 && sc.log2().fract() == 0.0, "scale {sc} not a power of two");
        }
    }
}
