//! SGD (Robbins & Monro, 1951) and SGD-with-momentum (Qian, 1999).
//!
//! SGD is the zero-state optimizer: the paper notes HiFT's peak CPU↔GPU
//! communication is *zero* under SGD (§4.3) — the ledger test in the
//! scheduler asserts exactly that.  SGDM carries one momentum buffer
//! (ζ₂ = ζ₁ in the Appendix-B accounting, Tables 8–12 "SGDM" rows).

use super::{OptimCfg, OptimKind, Optimizer};
use crate::backend::par;
use crate::tensor::Tensor;

/// Plain SGD: `p -= lr * (g + wd * p)`. No state at all.
pub struct Sgd {
    cfg: OptimCfg,
}

impl Sgd {
    pub fn new(cfg: OptimCfg) -> Self {
        Sgd { cfg }
    }
}

impl Optimizer for Sgd {
    fn update(&mut self, _idx: usize, param: &mut Tensor, grad: &Tensor, lr: f32) {
        assert_eq!(param.shape, grad.shape);
        let wd = self.cfg.weight_decay;
        par::par_apply2(&mut param.data, &grad.data, |p, g| {
            *p -= lr * (g + wd * *p);
        });
    }

    fn state_bytes(&self, _idx: usize) -> usize {
        0
    }

    fn total_state_bytes(&self) -> usize {
        0
    }

    fn kind(&self) -> OptimKind {
        OptimKind::Sgd
    }
}

/// SGD with (heavy-ball) momentum: `u = μu + g; p -= lr * u`.
pub struct Sgdm {
    cfg: OptimCfg,
    states: Vec<Option<Vec<f32>>>,
}

impl Sgdm {
    pub fn new(cfg: OptimCfg, n_params: usize) -> Self {
        Sgdm { cfg, states: (0..n_params).map(|_| None).collect() }
    }
}

impl Optimizer for Sgdm {
    fn update(&mut self, idx: usize, param: &mut Tensor, grad: &Tensor, lr: f32) {
        assert_eq!(param.shape, grad.shape);
        let mu = self.cfg.momentum;
        let wd = self.cfg.weight_decay;
        let buf = self.states[idx].get_or_insert_with(|| vec![0.0; param.numel()]);
        par::par_apply3(&mut param.data, buf, &grad.data, |p, b, g| {
            let u = mu * *b + (g + wd * *p);
            *b = u;
            *p -= lr * u;
        });
    }

    fn state_bytes(&self, idx: usize) -> usize {
        self.states[idx].as_ref().map_or(0, |b| b.len() * 4)
    }

    fn total_state_bytes(&self) -> usize {
        (0..self.states.len()).map(|i| self.state_bytes(i)).sum()
    }

    fn kind(&self) -> OptimKind {
        OptimKind::Sgdm
    }

    fn export_state(&self) -> Vec<(String, Tensor)> {
        self.states
            .iter()
            .enumerate()
            .filter_map(|(i, s)| {
                s.as_ref()
                    .map(|b| (format!("{i}.u"), Tensor::from_vec(b.clone(), &[b.len()])))
            })
            .collect()
    }

    fn import_state(
        &mut self,
        state: &[(String, Tensor)],
        params: &crate::tensor::TensorSet,
    ) -> anyhow::Result<()> {
        for slot in self.states.iter_mut() {
            *slot = None;
        }
        for (name, t) in state {
            let (idx, field) = super::state_key(name)?;
            if field != "u" {
                anyhow::bail!("unknown SGDM state field {field:?}");
            }
            if idx >= self.states.len() || idx >= params.len() {
                anyhow::bail!("SGDM state {name:?}: index out of range");
            }
            let numel = params.tensors[idx].numel();
            if t.data.len() != numel {
                anyhow::bail!(
                    "SGDM state {name:?} has {} elements, parameter has {numel}",
                    t.data.len()
                );
            }
            self.states[idx] = Some(t.data.clone());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_step_is_exact() {
        let mut opt = Sgd::new(OptimCfg::new(OptimKind::Sgd));
        let mut p = Tensor::from_vec(vec![1.0, 2.0], &[2]);
        let g = Tensor::from_vec(vec![0.5, -0.5], &[2]);
        opt.update(0, &mut p, &g, 0.1);
        assert_eq!(p.data, vec![0.95, 2.05]);
        assert_eq!(opt.total_state_bytes(), 0, "SGD carries no state — zero paging");
    }

    #[test]
    fn sgdm_accumulates_momentum() {
        let mut opt = Sgdm::new(OptimCfg::new(OptimKind::Sgdm), 1);
        let mut p = Tensor::zeros(&[1]);
        let g = Tensor::ones(&[1]);
        opt.update(0, &mut p, &g, 1.0); // u=1, p=-1
        opt.update(0, &mut p, &g, 1.0); // u=1.9, p=-2.9
        assert!((p.data[0] + 2.9).abs() < 1e-6, "got {}", p.data[0]);
        assert_eq!(opt.state_bytes(0), 4);
    }
}
