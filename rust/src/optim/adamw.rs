//! AdamW (Loshchilov & Hutter, 2017) — decoupled weight decay.
//!
//! State: first (`m`) and second (`v`) moment per element = 2× parameter
//! bytes, the worst case the paper's memory analysis centres on
//! (Appendix B: ζ₂ = 2ζ₁).  Bias correction uses a *per-tensor* step count:
//! under HiFT each tensor is updated once per sweep, so its own `t` — not
//! the global step — is the mathematically right correction.

use super::{OptimCfg, OptimKind, Optimizer};
use crate::backend::{kernels, par};
use crate::tensor::Tensor;

struct State {
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

/// AdamW with lazily-allocated per-tensor state.
pub struct AdamW {
    cfg: OptimCfg,
    states: Vec<Option<State>>,
}

impl AdamW {
    pub fn new(cfg: OptimCfg, n_params: usize) -> Self {
        AdamW { cfg, states: (0..n_params).map(|_| None).collect() }
    }
}

impl Optimizer for AdamW {
    fn update(&mut self, idx: usize, param: &mut Tensor, grad: &Tensor, lr: f32) {
        assert_eq!(param.shape, grad.shape, "param/grad shape mismatch");
        let slot = &mut self.states[idx];
        let st = slot.get_or_insert_with(|| State {
            m: vec![0.0; param.numel()],
            v: vec![0.0; param.numel()],
            t: 0,
        });
        st.t += 1;
        let (b1, b2, eps, wd) = (self.cfg.beta1, self.cfg.beta2, self.cfg.eps, self.cfg.weight_decay);
        let bc1 = 1.0 - b1.powi(st.t as i32);
        let bc2 = 1.0 - b2.powi(st.t as i32);
        // Single fused loop over the tensor — the L3 hot path, chunked
        // across threads for large tensors and vectorized per chunk
        // (element-independent and per-element expression order fixed, so
        // the result is identical at any thread count and with SIMD on
        // or off).
        let State { m, v, .. } = st;
        par::par_chunks4(&mut param.data, m, v, &grad.data, |pc, mc, vc, gc| {
            kernels::adamw_chunk(pc, mc, vc, gc, b1, b2, bc1, bc2, eps, wd, lr);
        });
    }

    fn state_bytes(&self, idx: usize) -> usize {
        self.states[idx].as_ref().map_or(0, |s| (s.m.len() + s.v.len()) * 4)
    }

    fn total_state_bytes(&self) -> usize {
        (0..self.states.len()).map(|i| self.state_bytes(i)).sum()
    }

    fn kind(&self) -> OptimKind {
        OptimKind::AdamW
    }

    fn export_state(&self) -> Vec<(String, Tensor)> {
        let mut out = Vec::new();
        for (i, slot) in self.states.iter().enumerate() {
            if let Some(s) = slot {
                out.push((format!("{i}.m"), Tensor::from_vec(s.m.clone(), &[s.m.len()])));
                out.push((format!("{i}.v"), Tensor::from_vec(s.v.clone(), &[s.v.len()])));
                // Per-tensor step count for bias correction (exact as f32
                // up to 2^24 updates of one tensor).
                out.push((format!("{i}.t"), Tensor::from_vec(vec![s.t as f32], &[1])));
            }
        }
        out
    }

    fn import_state(
        &mut self,
        state: &[(String, Tensor)],
        params: &crate::tensor::TensorSet,
    ) -> anyhow::Result<()> {
        for slot in self.states.iter_mut() {
            *slot = None;
        }
        for (name, t) in state {
            let (idx, field) = super::state_key(name)?;
            if idx >= self.states.len() || idx >= params.len() {
                anyhow::bail!("AdamW state {name:?}: index out of range");
            }
            let st = self.states[idx].get_or_insert_with(|| State {
                m: Vec::new(),
                v: Vec::new(),
                t: 0,
            });
            match field {
                "m" => st.m = t.data.clone(),
                "v" => st.v = t.data.clone(),
                "t" => st.t = t.data.first().copied().unwrap_or(0.0) as u64,
                other => anyhow::bail!("unknown AdamW state field {other:?}"),
            }
        }
        for (i, slot) in self.states.iter().enumerate() {
            if let Some(s) = slot {
                let numel = params.tensors[i].numel();
                if s.m.len() != numel || s.v.len() != numel || s.t == 0 {
                    anyhow::bail!(
                        "AdamW state for tensor {i} is incomplete or size-mismatched \
                         (m {} / v {} vs {numel} parameter elements)",
                        s.m.len(),
                        s.v.len()
                    );
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_moves_by_lr() {
        // With bias correction, |Δ| of step 1 ≈ lr regardless of grad scale.
        let mut opt = AdamW::new(OptimCfg::new(OptimKind::AdamW), 1);
        let mut p = Tensor::zeros(&[1]);
        let g = Tensor::from_vec(vec![1234.0], &[1]);
        opt.update(0, &mut p, &g, 0.1);
        assert!((p.data[0] + 0.1).abs() < 1e-4, "step-1 magnitude ≈ lr, got {}", p.data[0]);
    }

    #[test]
    fn weight_decay_is_decoupled() {
        let mut cfg = OptimCfg::new(OptimKind::AdamW);
        cfg.weight_decay = 0.5;
        let mut opt = AdamW::new(cfg, 1);
        let mut p = Tensor::from_vec(vec![1.0], &[1]);
        let g = Tensor::zeros(&[1]);
        opt.update(0, &mut p, &g, 0.1);
        // pure decay: p -= lr * wd * p  -> 1 - 0.05
        assert!((p.data[0] - 0.95).abs() < 1e-6, "got {}", p.data[0]);
    }

    #[test]
    fn per_tensor_step_counts_are_independent() {
        let mut opt = AdamW::new(OptimCfg::new(OptimKind::AdamW), 2);
        let mut a = Tensor::zeros(&[1]);
        let mut b = Tensor::zeros(&[1]);
        let g = Tensor::ones(&[1]);
        for _ in 0..5 {
            opt.update(0, &mut a, &g, 0.01);
        }
        opt.update(1, &mut b, &g, 0.01);
        // tensor 1's bias correction is that of t=1, so its step ≈ lr.
        assert!((b.data[0] + 0.01).abs() < 1e-5);
        assert_eq!(opt.state_bytes(0), 8);
        assert_eq!(opt.state_bytes(1), 8);
    }
}
