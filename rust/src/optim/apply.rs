//! The fused-update layer: gradient → clip → ledger page-in → optimizer
//! update → ledger page-out, applied per tensor as gradients stream out of
//! the backward walk ([`crate::backend::ExecBackend::run_streamed`]).
//!
//! This is Algorithm 1 steps i/g'/k fused into the emission point: the
//! gradient is dropped the moment the update lands, so peak parameter-
//! gradient residency is the single tensor in flight instead of the whole
//! group's `Vec<Tensor>` — and the per-tensor sequence (clip, page-in,
//! update, page-out) is exactly the one the old collected loop ran, so the
//! resulting parameters and ledger are bit-identical to it.
//!
//! ## Non-finite gradients
//!
//! Low-precision compute is exactly where NaN/Inf gradients appear, so the
//! sink is the numerics safety net.  Every incoming gradient's norm is
//! checked (free — [`clip_grad`] computes it anyway) and a non-finite one
//! is **never** fed to the optimizer.  Two policies:
//!
//! * [`NonFinitePolicy::SkipTensor`] (default) — drop just the offending
//!   tensor's update; everything else in the step still applies.  The
//!   always-on guard for f32/bf16 runs.
//! * [`NonFinitePolicy::SkipStep`] — the f16 loss-scaler contract: updates
//!   are *deferred* until [`GradSink::finish`]; if any gradient in the run
//!   came back non-finite the whole step is dropped, leaving parameters
//!   AND optimizer state bit-identical to pre-step (AdamW's per-tensor `t`
//!   included), so the scaler can halve its scale and retry.  The deferral
//!   trades the streamed one-tensor gradient residency for the collected
//!   group sum — the price of an atomic skip, paid only in f16 mode and
//!   honestly reported through [`GradSink::resident_bytes`].
//!   Applying at `finish` is bit-identical to applying at emission when no
//!   overflow occurs: updates are per-tensor and the backward walk never
//!   reads a parameter again after emitting its gradient.

use anyhow::{bail, Result};

use super::{clip_grad, OffloadLedger, Optimizer};
use crate::backend::GradSink;
use crate::tensor::{Tensor, TensorSet};

/// What to do when a gradient arrives with a NaN/Inf norm.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NonFinitePolicy {
    /// Skip only that tensor's update (default safety net).
    #[default]
    SkipTensor,
    /// Defer all updates to `finish`; drop the entire step if any gradient
    /// is non-finite (atomic skip-step for the f16 loss scaler).
    SkipStep,
}

/// A [`GradSink`] that applies the optimizer update the moment a gradient
/// arrives and drops it immediately (or, under
/// [`NonFinitePolicy::SkipStep`], at `finish` once the whole step is known
/// to be finite).
pub struct FusedApply<'a> {
    optimizer: &'a mut dyn Optimizer,
    ledger: Option<&'a mut OffloadLedger>,
    /// Gradient slot → parameter index in the running `TensorSet`.
    slot_param: &'a [usize],
    grad_clip: f32,
    lr: f32,
    policy: NonFinitePolicy,
    /// Clipped updates awaiting `finish` (SkipStep mode only), in emit
    /// order.
    deferred: Vec<(usize, Tensor)>,
    /// Any gradient in this run came back non-finite.
    overflow: bool,
    /// Total parameter elements updated so far (the per-step trainable
    /// count the strategies report).
    pub updated_elems: usize,
    /// Gradients consumed so far.
    pub grads_seen: usize,
    /// Gradients whose norm came back NaN/Inf (their updates were skipped).
    pub nonfinite_grads: usize,
    /// True once `finish` dropped the whole step (SkipStep + overflow).
    pub step_skipped: bool,
}

impl<'a> FusedApply<'a> {
    pub fn new(
        optimizer: &'a mut dyn Optimizer,
        ledger: Option<&'a mut OffloadLedger>,
        slot_param: &'a [usize],
        grad_clip: f32,
        lr: f32,
    ) -> Self {
        FusedApply {
            optimizer,
            ledger,
            slot_param,
            grad_clip,
            lr,
            policy: NonFinitePolicy::SkipTensor,
            deferred: Vec::new(),
            overflow: false,
            updated_elems: 0,
            grads_seen: 0,
            nonfinite_grads: 0,
            step_skipped: false,
        }
    }

    /// Select the non-finite policy (builder style).
    pub fn non_finite(mut self, policy: NonFinitePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Clip → page state in → update → page state out, for one tensor.
    fn apply_now(&mut self, idx: usize, grad: Tensor, params: &mut TensorSet) {
        let grad_bytes = grad.bytes() as u64;
        let pre = self.optimizer.state_bytes(idx) as u64;
        if let Some(l) = self.ledger.as_deref_mut() {
            l.page_in(pre);
        }
        let p = params.tensor_mut(idx);
        self.updated_elems += p.numel();
        self.optimizer.update(idx, p, &grad, self.lr);
        let post = self.optimizer.state_bytes(idx) as u64;
        if let Some(l) = self.ledger.as_deref_mut() {
            l.alloc_on_device(post.saturating_sub(pre));
            l.page_out(post);
            l.grad_out(grad_bytes);
        }
        // `grad` dropped here — "Clear gradients" (Algorithm 1 step g)
    }
}

impl GradSink for FusedApply<'_> {
    fn grad(
        &mut self,
        slot: usize,
        name: &str,
        mut grad: Tensor,
        params: &mut TensorSet,
    ) -> Result<()> {
        let Some(&idx) = self.slot_param.get(slot) else {
            bail!("gradient slot {slot} ({name}) outside the update plan");
        };
        if params.names[idx] != name {
            bail!(
                "gradient slot {slot} maps to parameter {:?} but the backend emitted {name:?}",
                params.names[idx]
            );
        }
        let norm = clip_grad(&mut grad, self.grad_clip);
        self.grads_seen += 1;
        let grad_bytes = grad.bytes() as u64;
        if let Some(l) = self.ledger.as_deref_mut() {
            l.grad_in(grad_bytes);
        }
        if !norm.is_finite() {
            // Never feed a NaN/Inf gradient to the optimizer: its moments
            // would absorb the poison and every later step would inherit it.
            self.nonfinite_grads += 1;
            self.overflow = true;
            if let Some(l) = self.ledger.as_deref_mut() {
                l.grad_out(grad_bytes);
            }
            return Ok(());
        }
        match self.policy {
            NonFinitePolicy::SkipTensor => self.apply_now(idx, grad, params),
            NonFinitePolicy::SkipStep => {
                if self.overflow {
                    // Step already doomed: don't accumulate further grads.
                    if let Some(l) = self.ledger.as_deref_mut() {
                        l.grad_out(grad_bytes);
                    }
                } else {
                    self.deferred.push((idx, grad));
                }
            }
        }
        Ok(())
    }

    fn resident_bytes(&self) -> u64 {
        self.deferred.iter().map(|(_, g)| g.bytes() as u64).sum()
    }

    fn finish(&mut self, params: &mut TensorSet) -> Result<()> {
        if self.policy == NonFinitePolicy::SkipStep {
            let deferred = std::mem::take(&mut self.deferred);
            if self.overflow {
                // Atomic skip: nothing was applied, so params and optimizer
                // state are bit-identical to pre-step by construction.
                self.step_skipped = true;
                for (_, g) in &deferred {
                    if let Some(l) = self.ledger.as_deref_mut() {
                        l.grad_out(g.bytes() as u64);
                    }
                }
            } else {
                for (idx, grad) in deferred {
                    self.apply_now(idx, grad, params);
                }
            }
        }
        // Contracts (HIFT_CHECK): the end-of-step seam must be quiesced —
        // every gradient consumed, every paged state back out, bytes
        // conserved (see docs/CONTRACTS.md).
        if crate::contracts::enabled() {
            if let Some(l) = self.ledger.as_deref() {
                l.check_sink_quiesced()?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{build, OptimCfg, OptimKind};

    fn toy_params() -> TensorSet {
        let mut set = TensorSet::new();
        set.push("a", Tensor::from_vec(vec![1.0, 2.0], &[2]));
        set.push("b", Tensor::from_vec(vec![3.0, 4.0, 5.0], &[3]));
        set
    }

    #[test]
    fn fused_apply_matches_collected_update() {
        let cfg = OptimCfg::new(OptimKind::AdamW);
        let ga = Tensor::from_vec(vec![0.5, -0.5], &[2]);
        let gb = Tensor::from_vec(vec![1.0, 0.0, -1.0], &[3]);

        // Collected reference: clip then update, per tensor.
        let mut p_ref = toy_params();
        let mut opt_ref = build(cfg, 2);
        for (i, g) in [ga.clone(), gb.clone()].into_iter().enumerate() {
            let mut g = g;
            clip_grad(&mut g, cfg.grad_clip);
            opt_ref.update(i, p_ref.tensor_mut(i), &g, 0.01);
        }

        // Fused sink fed in emit order.
        let mut p = toy_params();
        let mut opt = build(cfg, 2);
        let mut ledger = OffloadLedger::new();
        let slots = [0usize, 1];
        let mut sink =
            FusedApply::new(&mut *opt, Some(&mut ledger), &slots, cfg.grad_clip, 0.01);
        sink.grad(0, "a", ga, &mut p).unwrap();
        sink.grad(1, "b", gb, &mut p).unwrap();
        assert_eq!(sink.updated_elems, 5);
        assert_eq!(sink.grads_seen, 2);
        assert_eq!(sink.nonfinite_grads, 0);

        for (x, y) in p.tensors.iter().zip(&p_ref.tensors) {
            assert_eq!(x.data, y.data, "fused update must equal collected update");
        }
        // One gradient resident at a time.
        assert_eq!(ledger.peak_grad_resident_bytes, 12, "largest single tensor (3 f32)");
        assert_eq!(ledger.grad_resident(), 0);
    }

    #[test]
    fn fused_apply_rejects_mismatched_names() {
        let mut p = toy_params();
        let mut opt = build(OptimCfg::new(OptimKind::Sgd), 2);
        let slots = [0usize, 1];
        let mut sink = FusedApply::new(&mut *opt, None, &slots, 0.0, 0.01);
        let g = Tensor::from_vec(vec![0.0, 0.0], &[2]);
        assert!(sink.grad(0, "b", g.clone(), &mut p).is_err(), "name/slot mismatch");
        assert!(sink.grad(7, "a", g, &mut p).is_err(), "slot outside plan");
    }

    #[test]
    fn nonfinite_grad_skips_only_that_tensor_by_default() {
        let cfg = OptimCfg::new(OptimKind::AdamW);
        let mut p = toy_params();
        let before_a = p.tensors[0].data.clone();
        let mut opt = build(cfg, 2);
        let slots = [0usize, 1];
        let (nonfinite, skipped, updated) = {
            let mut sink = FusedApply::new(&mut *opt, None, &slots, cfg.grad_clip, 0.01);
            sink.grad(0, "a", Tensor::from_vec(vec![f32::NAN, 1.0], &[2]), &mut p).unwrap();
            sink.grad(1, "b", Tensor::from_vec(vec![1.0, 0.0, -1.0], &[3]), &mut p).unwrap();
            sink.finish(&mut p).unwrap();
            (sink.nonfinite_grads, sink.step_skipped, sink.updated_elems)
        };
        assert_eq!(nonfinite, 1);
        assert!(!skipped, "SkipTensor never drops the step");
        assert_eq!(updated, 3, "only b's elements counted");
        assert_eq!(p.tensors[0].data, before_a, "poisoned tensor untouched");
        assert_ne!(p.tensors[1].data, vec![3.0, 4.0, 5.0], "healthy tensor still updated");
        assert_eq!(opt.state_bytes(0), 0, "no moments were allocated for the skipped tensor");
    }

    #[test]
    fn skip_step_is_atomic_for_params_and_optimizer_state() {
        let cfg = OptimCfg::new(OptimKind::AdamW);
        let mut p = toy_params();
        let mut opt = build(cfg, 2);
        // One healthy step first, so optimizer state (m/v/t) is non-trivial.
        {
            let slots = [0usize, 1];
            let mut sink = FusedApply::new(&mut *opt, None, &slots, cfg.grad_clip, 0.01)
                .non_finite(NonFinitePolicy::SkipStep);
            sink.grad(0, "a", Tensor::from_vec(vec![0.5, -0.5], &[2]), &mut p).unwrap();
            sink.grad(1, "b", Tensor::from_vec(vec![1.0, 0.0, -1.0], &[3]), &mut p).unwrap();
            sink.finish(&mut p).unwrap();
            assert!(!sink.step_skipped);
            assert_eq!(sink.updated_elems, 5, "finite deferred step applies fully");
        }
        let params_snapshot: Vec<Vec<f32>> = p.tensors.iter().map(|t| t.data.clone()).collect();
        let state_snapshot = opt.export_state();

        // Overflow step: tensor a's grad is fine, b's is Inf.  The whole
        // step must vanish — a's applied-then-rolled-back would show up as
        // a param or `t` counter drift.
        let mut ledger = OffloadLedger::new();
        {
            let slots = [0usize, 1];
            let mut sink =
                FusedApply::new(&mut *opt, Some(&mut ledger), &slots, cfg.grad_clip, 0.01)
                    .non_finite(NonFinitePolicy::SkipStep);
            sink.grad(0, "a", Tensor::from_vec(vec![0.1, 0.2], &[2]), &mut p).unwrap();
            sink.grad(1, "b", Tensor::from_vec(vec![f32::INFINITY, 0.0, 1.0], &[3]), &mut p)
                .unwrap();
            sink.finish(&mut p).unwrap();
            assert!(sink.step_skipped);
            assert_eq!(sink.nonfinite_grads, 1);
            assert_eq!(sink.updated_elems, 0, "nothing applied on a skipped step");
        }
        for (t, snap) in p.tensors.iter().zip(&params_snapshot) {
            assert_eq!(&t.data, snap, "params must be bit-identical to pre-step");
        }
        let state_after = opt.export_state();
        assert_eq!(state_after.len(), state_snapshot.len());
        for ((ka, ta), (kb, tb)) in state_after.iter().zip(&state_snapshot) {
            assert_eq!(ka, kb);
            assert_eq!(ta.data, tb.data, "optimizer state {ka} must be bit-identical");
        }
        assert_eq!(ledger.grad_resident(), 0, "deferred grads fully drained");
    }

    #[test]
    fn deferred_apply_is_bit_identical_to_immediate() {
        let cfg = OptimCfg::new(OptimKind::AdamW);
        let ga = Tensor::from_vec(vec![0.5, -0.5], &[2]);
        let gb = Tensor::from_vec(vec![1.0, 0.0, -1.0], &[3]);

        let mut p_now = toy_params();
        let mut opt_now = build(cfg, 2);
        {
            let slots = [0usize, 1];
            let mut sink = FusedApply::new(&mut *opt_now, None, &slots, cfg.grad_clip, 0.01);
            sink.grad(0, "a", ga.clone(), &mut p_now).unwrap();
            sink.grad(1, "b", gb.clone(), &mut p_now).unwrap();
            sink.finish(&mut p_now).unwrap();
        }

        let mut p_def = toy_params();
        let mut opt_def = build(cfg, 2);
        let mut ledger = OffloadLedger::new();
        {
            let slots = [0usize, 1];
            let mut sink =
                FusedApply::new(&mut *opt_def, Some(&mut ledger), &slots, cfg.grad_clip, 0.01)
                    .non_finite(NonFinitePolicy::SkipStep);
            sink.grad(0, "a", ga, &mut p_def).unwrap();
            sink.grad(1, "b", gb, &mut p_def).unwrap();
            // Deferred mode holds the collected sum until finish.
            assert_eq!(sink.resident_bytes(), 8 + 12);
            sink.finish(&mut p_def).unwrap();
        }
        for (x, y) in p_def.tensors.iter().zip(&p_now.tensors) {
            assert_eq!(x.data, y.data, "deferred apply must equal immediate apply");
        }
        assert_eq!(
            ledger.peak_grad_resident_bytes,
            8 + 12,
            "skip-step honestly reports the collected residency"
        );
        assert_eq!(ledger.grad_resident(), 0);
    }
}
