//! The fused-update layer: gradient → clip → ledger page-in → optimizer
//! update → ledger page-out, applied per tensor as gradients stream out of
//! the backward walk ([`crate::backend::ExecBackend::run_streamed`]).
//!
//! This is Algorithm 1 steps i/g'/k fused into the emission point: the
//! gradient is dropped the moment the update lands, so peak parameter-
//! gradient residency is the single tensor in flight instead of the whole
//! group's `Vec<Tensor>` — and the per-tensor sequence (clip, page-in,
//! update, page-out) is exactly the one the old collected loop ran, so the
//! resulting parameters and ledger are bit-identical to it.

use anyhow::{bail, Result};

use super::{clip_grad, OffloadLedger, Optimizer};
use crate::backend::GradSink;
use crate::tensor::{Tensor, TensorSet};

/// A [`GradSink`] that applies the optimizer update the moment a gradient
/// arrives and drops it immediately.
pub struct FusedApply<'a> {
    optimizer: &'a mut dyn Optimizer,
    ledger: Option<&'a mut OffloadLedger>,
    /// Gradient slot → parameter index in the running `TensorSet`.
    slot_param: &'a [usize],
    grad_clip: f32,
    lr: f32,
    /// Total parameter elements updated so far (the per-step trainable
    /// count the strategies report).
    pub updated_elems: usize,
    /// Gradients consumed so far.
    pub grads_seen: usize,
}

impl<'a> FusedApply<'a> {
    pub fn new(
        optimizer: &'a mut dyn Optimizer,
        ledger: Option<&'a mut OffloadLedger>,
        slot_param: &'a [usize],
        grad_clip: f32,
        lr: f32,
    ) -> Self {
        FusedApply { optimizer, ledger, slot_param, grad_clip, lr, updated_elems: 0, grads_seen: 0 }
    }
}

impl GradSink for FusedApply<'_> {
    fn grad(
        &mut self,
        slot: usize,
        name: &str,
        mut grad: Tensor,
        params: &mut TensorSet,
    ) -> Result<()> {
        let Some(&idx) = self.slot_param.get(slot) else {
            bail!("gradient slot {slot} ({name}) outside the update plan");
        };
        if params.names[idx] != name {
            bail!(
                "gradient slot {slot} maps to parameter {:?} but the backend emitted {name:?}",
                params.names[idx]
            );
        }
        clip_grad(&mut grad, self.grad_clip);
        let grad_bytes = grad.bytes() as u64;
        if let Some(l) = self.ledger.as_deref_mut() {
            l.grad_in(grad_bytes);
        }
        let pre = self.optimizer.state_bytes(idx) as u64;
        if let Some(l) = self.ledger.as_deref_mut() {
            l.page_in(pre);
        }
        let p = params.tensor_mut(idx);
        self.updated_elems += p.numel();
        self.optimizer.update(idx, p, &grad, self.lr);
        let post = self.optimizer.state_bytes(idx) as u64;
        if let Some(l) = self.ledger.as_deref_mut() {
            l.alloc_on_device(post.saturating_sub(pre));
            l.page_out(post);
            l.grad_out(grad_bytes);
        }
        self.grads_seen += 1;
        Ok(())
        // `grad` dropped here — "Clear gradients" (Algorithm 1 step g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{build, OptimCfg, OptimKind};

    fn toy_params() -> TensorSet {
        let mut set = TensorSet::new();
        set.push("a", Tensor::from_vec(vec![1.0, 2.0], &[2]));
        set.push("b", Tensor::from_vec(vec![3.0, 4.0, 5.0], &[3]));
        set
    }

    #[test]
    fn fused_apply_matches_collected_update() {
        let cfg = OptimCfg::new(OptimKind::AdamW);
        let ga = Tensor::from_vec(vec![0.5, -0.5], &[2]);
        let gb = Tensor::from_vec(vec![1.0, 0.0, -1.0], &[3]);

        // Collected reference: clip then update, per tensor.
        let mut p_ref = toy_params();
        let mut opt_ref = build(cfg, 2);
        for (i, g) in [ga.clone(), gb.clone()].into_iter().enumerate() {
            let mut g = g;
            clip_grad(&mut g, cfg.grad_clip);
            opt_ref.update(i, p_ref.tensor_mut(i), &g, 0.01);
        }

        // Fused sink fed in emit order.
        let mut p = toy_params();
        let mut opt = build(cfg, 2);
        let mut ledger = OffloadLedger::new();
        let slots = [0usize, 1];
        let mut sink =
            FusedApply::new(&mut *opt, Some(&mut ledger), &slots, cfg.grad_clip, 0.01);
        sink.grad(0, "a", ga, &mut p).unwrap();
        sink.grad(1, "b", gb, &mut p).unwrap();
        assert_eq!(sink.updated_elems, 5);
        assert_eq!(sink.grads_seen, 2);

        for (x, y) in p.tensors.iter().zip(&p_ref.tensors) {
            assert_eq!(x.data, y.data, "fused update must equal collected update");
        }
        // One gradient resident at a time.
        assert_eq!(ledger.peak_grad_resident_bytes, 12, "largest single tensor (3 f32)");
        assert_eq!(ledger.grad_resident(), 0);
    }

    #[test]
    fn fused_apply_rejects_mismatched_names() {
        let mut p = toy_params();
        let mut opt = build(OptimCfg::new(OptimKind::Sgd), 2);
        let slots = [0usize, 1];
        let mut sink = FusedApply::new(&mut *opt, None, &slots, 0.0, 0.01);
        let g = Tensor::from_vec(vec![0.0, 0.0], &[2]);
        assert!(sink.grad(0, "b", g.clone(), &mut p).is_err(), "name/slot mismatch");
        assert!(sink.grad(7, "a", g, &mut p).is_err(), "slot outside plan");
    }
}
