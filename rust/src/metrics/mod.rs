//! Training/eval metrics: loss curves, accuracy, throughput, and simple
//! wallclock histograms — everything the bench harnesses print.

use std::time::Instant;

/// Running scalar series with summary statistics (loss curves, step times).
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub name: String,
    pub values: Vec<f64>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Series { name: name.into(), values: Vec::new() }
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn last(&self) -> Option<f64> {
        self.values.last().copied()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.values.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.values.iter().map(|v| (v - m).powi(2)).sum::<f64>() / self.values.len() as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        // hift-lint: allow(float-reduction): min is order-insensitive (associative, commutative)
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        // hift-lint: allow(float-reduction): max is order-insensitive (associative, commutative)
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Mean of the last `n` values (tail-smoothed loss).
    pub fn tail_mean(&self, n: usize) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let k = n.min(self.values.len());
        self.values[self.values.len() - k..].iter().sum::<f64>() / k as f64
    }

    /// Least-squares slope over the sample index — negative = converging.
    pub fn slope(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let nf = n as f64;
        let mean_x = (nf - 1.0) / 2.0;
        let mean_y = self.mean();
        let mut num = 0.0;
        let mut den = 0.0;
        for (i, v) in self.values.iter().enumerate() {
            let dx = i as f64 - mean_x;
            num += dx * (v - mean_y);
            den += dx * dx;
        }
        num / den
    }

    /// Downsample to at most `n` points (for printed loss curves).
    pub fn downsample(&self, n: usize) -> Vec<(usize, f64)> {
        if self.values.is_empty() || n == 0 {
            return vec![];
        }
        let stride = (self.values.len() + n - 1) / n;
        self.values
            .iter()
            .enumerate()
            .step_by(stride.max(1))
            .map(|(i, &v)| (i, v))
            .collect()
    }
}

/// Accuracy accumulator (masked-token accuracy from (ncorrect, weight-sum)).
#[derive(Debug, Clone, Copy, Default)]
pub struct Accuracy {
    pub correct: f64,
    pub total: f64,
}

impl Accuracy {
    pub fn add(&mut self, correct: f64, total: f64) {
        self.correct += correct;
        self.total += total;
    }

    pub fn value(&self) -> f64 {
        if self.total == 0.0 {
            return 0.0;
        }
        self.correct / self.total
    }
}

/// Steps/second + wall time tracker.
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    pub steps: usize,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput { start: Instant::now(), steps: 0 }
    }

    pub fn step(&mut self) {
        self.steps += 1;
    }

    pub fn steps_per_sec(&self) -> f64 {
        let dt = self.start.elapsed().as_secs_f64();
        if dt == 0.0 {
            return 0.0;
        }
        self.steps as f64 / dt
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Fixed-bucket duration histogram (microseconds; powers of two).
#[derive(Debug, Clone, Default)]
pub struct DurationHist {
    counts: [u64; 32],
    pub n: u64,
    pub total_us: u64,
}

impl DurationHist {
    pub fn record_us(&mut self, us: u64) {
        let bucket = (64 - us.max(1).leading_zeros() as usize).min(31);
        self.counts[bucket] += 1;
        self.n += 1;
        self.total_us += us;
    }

    pub fn mean_us(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.total_us as f64 / self.n as f64
    }

    /// Approximate quantile from bucket upper bounds.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.n == 0 {
            return 0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut seen = 0;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << i;
            }
        }
        1u64 << 31
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_stats() {
        let mut s = Series::new("loss");
        for v in [4.0, 3.0, 2.0, 1.0] {
            s.push(v);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.tail_mean(2), 1.5);
        assert!(s.slope() < 0.0, "decreasing series has negative slope");
    }

    #[test]
    fn series_downsample_bounds() {
        let mut s = Series::new("x");
        for i in 0..100 {
            s.push(i as f64);
        }
        let d = s.downsample(10);
        assert!(d.len() <= 11);
        assert_eq!(d[0], (0, 0.0));
    }

    #[test]
    fn accuracy_accumulates() {
        let mut a = Accuracy::default();
        a.add(3.0, 4.0);
        a.add(1.0, 4.0);
        assert_eq!(a.value(), 0.5);
        assert_eq!(Accuracy::default().value(), 0.0);
    }

    #[test]
    fn hist_quantiles_monotone() {
        let mut h = DurationHist::default();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            h.record_us(us);
        }
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn empty_series_is_nan_mean() {
        assert!(Series::new("e").mean().is_nan());
    }
}
