//! Minimal JSON: parser + emitter (the offline vendor set has no serde).
//!
//! Covers everything the repo needs — `manifest.json` from the AOT step,
//! run records, bench outputs.  Object key order is preserved (insertion
//! order) so emitted records diff cleanly.

mod json;

pub use json::{emit, emit_pretty, parse, JsonError, Obj, Value};
