//! A small, strict JSON implementation.
//!
//! Parsing is recursive-descent over bytes with line/column error reporting;
//! numbers are f64 (sufficient for manifests — offsets < 2^53).  Emission
//! round-trips: `parse(emit(v)) == v`.

use std::collections::BTreeMap;


/// A JSON value. Objects keep insertion order via a parallel key vector.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Obj),
}

/// Insertion-ordered string→value map.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Obj {
    keys: Vec<String>,
    map: BTreeMap<String, Value>,
}

impl Obj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, k: impl Into<String>, v: Value) {
        let k = k.into();
        if !self.map.contains_key(&k) {
            self.keys.push(k.clone());
        }
        self.map.insert(k, v);
    }

    pub fn get(&self, k: &str) -> Option<&Value> {
        self.map.get(k)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.keys.iter()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Value)> {
        self.keys.iter().map(move |k| (k, &self.map[k]))
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }
}

impl Value {
    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        let mut o = Obj::new();
        for (k, v) in pairs {
            o.insert(k, v);
        }
        Value::Obj(o)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&Obj> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `value["a"]["b"]` style access; returns Null on any miss.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Array index access; Null on miss.
    pub fn at(&self, i: usize) -> &Value {
        static NULL: Value = Value::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse error with 1-based line/column.
#[derive(Debug)]
pub struct JsonError {
    pub line: usize,
    pub col: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        let (mut line, mut col) = (1usize, 1usize);
        for &c in &self.b[..self.pos.min(self.b.len())] {
            if c == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Err(JsonError { line, col, msg: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            self.err(format!("expected literal '{s}'"))
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = match std::str::from_utf8(&self.b[start..self.pos]) {
            Ok(s) => s,
            Err(_) => return self.err("non-utf8 bytes in number"),
        };
        match s.parse::<f64>() {
            Ok(n) => Ok(Value::Num(n)),
            Err(_) => self.err(format!("bad number '{s}'")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or(()).or_else(|_| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).map(Ok).unwrap_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) => out.push(c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect_byte(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect_byte(b'{')?;
        let mut obj = Obj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(obj)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Value, JsonError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn fmt_num(n: f64) -> String {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

fn emit_into(v: &Value, out: &mut String, indent: Option<usize>, level: usize) {
    let (nl, pad, pad1) = match indent {
        Some(w) => ("\n", " ".repeat(w * level), " ".repeat(w * (level + 1))),
        None => ("", String::new(), String::new()),
    };
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => out.push_str(&fmt_num(*n)),
        Value::Str(s) => escape(s, out),
        Value::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(nl);
                out.push_str(&pad1);
                emit_into(e, out, indent, level + 1);
            }
            out.push_str(nl);
            out.push_str(&pad);
            out.push(']');
        }
        Value::Obj(o) => {
            if o.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, e)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(nl);
                out.push_str(&pad1);
                escape(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                emit_into(e, out, indent, level + 1);
            }
            out.push_str(nl);
            out.push_str(&pad);
            out.push('}');
        }
    }
}

/// Compact emission.
pub fn emit(v: &Value) -> String {
    let mut s = String::new();
    emit_into(v, &mut s, None, 0);
    s
}

/// Pretty emission (2-space indent).
pub fn emit_pretty(v: &Value) -> String {
    let mut s = String::new();
    emit_into(v, &mut s, Some(2), 0);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b"), &Value::Bool(false));
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"k":[1,2.5,null,true,"s\"q"],"o":{"n":-7}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&emit(&v)).unwrap(), v);
        assert_eq!(parse(&emit_pretty(&v)).unwrap(), v);
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().keys().cloned().collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn error_has_position() {
        let e = parse("{\n  \"a\": oops}").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.msg.contains("unexpected"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn accessors_default_to_null() {
        let v = parse("{}").unwrap();
        assert_eq!(v.get("missing").get("deeper"), &Value::Null);
        assert_eq!(v.at(3), &Value::Null);
    }

    #[test]
    fn integer_emission_is_exact() {
        assert_eq!(emit(&Value::Num(1048576.0)), "1048576");
        assert_eq!(emit(&Value::Num(0.5)), "0.5");
    }
}
