//! Mutation-style negative tests for the runtime contract checkers
//! (ISSUE 10 satellite): take the *correct* event stream a real HiFT step
//! produces — derived from the static plan, the same stream
//! `tests/plancheck.rs` proves the backend emits — mutate it the way a
//! buggy backend would, and assert each checker kills the mutant with the
//! message docs/CONTRACTS.md promises.
//!
//! The checkers themselves compile unconditionally (only their hot-path
//! call sites are feature-gated), so these tests run in the default build.

use std::collections::HashMap;

use hift::backend::{
    ActCkpt, Compression, ExecBackend, NativeBackend, OffloadCfg, Precision, VariantInfo,
};
use hift::contracts::EmitChecker;
use hift::coordinator::UpdateStrategy;
use hift::optim::OffloadLedger;
use hift::plancheck::{generate_plan, Family, Inject, LatticePoint};

/// One whole-network group (m = n_units on the tiny preset), so a single
/// step's emission stream covers every unit boundary the checker guards.
fn whole_net_point() -> LatticePoint {
    LatticePoint {
        family: Family::Hift,
        strategy: UpdateStrategy::Bottom2Up,
        m: 4,
        act_ckpt: ActCkpt::None,
        offload: OffloadCfg { enabled: false, compress: Compression::Lossless, prefetch: false },
        precision: Precision::F32,
        workers: 1,
    }
}

/// The correct `(slot, name)` stream for one tiny whole-network step, plus
/// the slot map and variant it was built against.
fn tiny_seam() -> (VariantInfo, HashMap<String, usize>, Vec<(usize, String)>) {
    let be = NativeBackend::preset("tiny", 42).unwrap();
    let manifest = be.manifest().clone();
    let vinfo = manifest.variant("base").unwrap().clone();
    let plan = generate_plan(&manifest, &whole_net_point(), 1, Inject::None).unwrap();
    let step = &plan.steps[0];
    let slot_param: Vec<usize> =
        step.units.iter().flat_map(|&u| vinfo.unit_indices(u)).collect();
    let slots: HashMap<String, usize> =
        slot_param.iter().enumerate().map(|(s, &p)| (vinfo.params[p].name.clone(), s)).collect();
    let emits: Vec<(usize, String)> = step
        .emits()
        .iter()
        .map(|&(slot, idx)| (slot, vinfo.params[idx].name.clone()))
        .collect();
    (vinfo, slots, emits)
}

/// Replay a (possibly mutated) stream; `Ok` only if every observation and
/// the coverage finalize pass.
fn replay(
    vinfo: &VariantInfo,
    slots: &HashMap<String, usize>,
    emits: &[(usize, String)],
) -> hift::Result<()> {
    let mut checker = EmitChecker::new(vinfo, slots)?;
    for (slot, name) in emits {
        checker.observe(*slot, name)?;
    }
    checker.finalize()
}

#[test]
fn unmutated_stream_is_accepted() {
    let (vinfo, slots, emits) = tiny_seam();
    assert!(emits.len() > 4, "tiny preset should stream many gradients");
    replay(&vinfo, &slots, &emits).expect("the plan-derived stream is the correct one");
}

/// Every adjacent transposition of the correct stream — the minimal
/// out-of-order-emit mutants — must be rejected, and the kill messages must
/// include each ordering rule at least once.
#[test]
fn every_adjacent_transposition_is_killed() {
    let (vinfo, slots, emits) = tiny_seam();
    let mut messages = Vec::new();
    for i in 0..emits.len() - 1 {
        let mut mutant = emits.clone();
        mutant.swap(i, i + 1);
        match replay(&vinfo, &slots, &mutant) {
            Ok(()) => panic!("swapping emits {i} and {} must not pass", i + 1),
            Err(err) => messages.push(err.to_string()),
        }
    }
    assert!(
        messages.iter().any(|m| m.contains("out of manifest order")),
        "no within-unit jump among the mutants: {messages:?}"
    );
    assert!(
        messages.iter().any(|m| m.contains("mid-block")),
        "no mid-block unit entry among the mutants: {messages:?}"
    );
}

#[test]
fn ascending_unit_order_is_killed() {
    let (vinfo, slots, emits) = tiny_seam();
    // The embedding unit's first slot (slot 0 — units concatenate in
    // ascending order in the slot map), then the head's first slot: a
    // strictly ascending walk, the mirror image of the contract.
    let mut checker = EmitChecker::new(&vinfo, &slots).unwrap();
    let (emb_slot, emb_name) =
        emits.iter().find(|(s, _)| *s == 0).expect("slot 0 is in the stream");
    checker.observe(*emb_slot, emb_name).unwrap();
    let (head_slot, head_name) = &emits[0];
    let err = checker.observe(*head_slot, head_name).unwrap_err();
    assert!(err.to_string().contains("not strictly descending"), "{err}");
}

#[test]
fn duplicated_and_dropped_emits_are_killed() {
    let (vinfo, slots, emits) = tiny_seam();
    // Duplicate the first emission.
    let mut doubled = emits.clone();
    doubled.insert(1, emits[0].clone());
    let err = replay(&vinfo, &slots, &doubled).unwrap_err();
    assert!(err.to_string().contains("emitted twice"), "{err}");
    // Drop the last: coverage must notice at finalize.
    let mut dropped = emits.clone();
    dropped.pop();
    let err = replay(&vinfo, &slots, &dropped).unwrap_err();
    assert!(err.to_string().contains("never emitted"), "{err}");
}

/// Over-releasing gradients — the grad-side double page-out — must show up
/// as a conservation inequality, not wrap silently.
#[test]
fn gradient_over_release_breaks_conservation() {
    let mut ledger = OffloadLedger::new();
    ledger.grad_in(64);
    ledger.grad_out(64);
    ledger.check_conservation().expect("balanced in/out conserves");
    ledger.grad_out(64); // the mutant: a second release of the same bytes
    let err = ledger.check_conservation().unwrap_err();
    assert!(err.to_string().contains("gradient conservation breach"), "{err}");
}

/// Paging out device state twice trips the resident-bytes guard (the
/// device-side double page-out); debug builds stop it at the call site.
#[test]
#[cfg(debug_assertions)]
fn double_page_out_is_caught_at_the_call_site() {
    let panic = std::panic::catch_unwind(|| {
        let mut ledger = OffloadLedger::new();
        ledger.page_in(128);
        ledger.page_out(128);
        ledger.page_out(128);
    })
    .expect_err("the second page-out must not be accepted");
    let msg = panic
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("paging out more than resident"), "{msg}");
}

#[test]
fn sink_quiesce_catches_hoarded_grads_and_unpaged_state() {
    let mut hoarder = OffloadLedger::new();
    hoarder.grad_in(32);
    hoarder.check_conservation().expect("a resident gradient still conserves");
    let err = hoarder.check_sink_quiesced().unwrap_err();
    assert!(err.to_string().contains("still resident"), "{err}");

    let mut lingerer = OffloadLedger::new();
    lingerer.page_in(128);
    let err = lingerer.check_sink_quiesced().unwrap_err();
    assert!(err.to_string().contains("still on device"), "{err}");
}
