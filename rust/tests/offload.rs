//! Host paging tier acceptance (ISSUE 4):
//!
//! * a lossless host-paged run is **bit-identical** to the fully-resident
//!   run — single grad executions on every native preset, and whole HiFT
//!   training runs (losses, final params, final eval) on tiny;
//! * prefetch-on ≡ prefetch-off (the double buffer changes wall-clock,
//!   never results);
//! * the f16-compressed host store is lossy but stays within a stated
//!   drift bound, and the lossy path is actually exercised;
//! * measured `peak_param_resident_bytes` is **enforced**: ≤ the active
//!   group plus one transient walk unit (sync mode), far below keeping
//!   every master resident;
//! * the accounting ledger and the pool agree (one source of truth):
//!   pool stores = ledger page-outs + the initial placement, pool fetches
//!   = ledger page-ins, and `RuntimeStats` mirrors the pager exactly;
//! * checkpoints written mid-offload are complete (masters paged back in
//!   before serialization) and match the resident run;
//! * MeZO — which mutates parameters outside the backend walk — refuses
//!   to run with offload instead of silently dropping perturbations.

use hift::backend::{
    unit_artifact, Batch, Compression, ExecBackend, NativeBackend, OffloadCfg, PRESET_NAMES,
};
use hift::coordinator::lr::LrSchedule;
use hift::coordinator::strategy::UpdateStrategy;
use hift::coordinator::trainer::{self, CkptOpts, RunRecord, TrainCfg};
use hift::data::{build_task, TaskGeom};
use hift::optim::{OptimCfg, OptimKind};
use hift::rng::Pcg32;
use hift::strategies::{FineTuneStrategy, Hift, HiftCfg, StrategySpec};
use hift::tensor::TensorSet;

const HOST_SYNC: OffloadCfg =
    OffloadCfg { enabled: true, compress: Compression::Lossless, prefetch: false };
const HOST_PREFETCH: OffloadCfg =
    OffloadCfg { enabled: true, compress: Compression::Lossless, prefetch: true };
const HOST_F16: OffloadCfg =
    OffloadCfg { enabled: true, compress: Compression::F16, prefetch: true };

fn geom(be: &dyn ExecBackend) -> TaskGeom {
    let c = &be.manifest().config;
    TaskGeom::new(c.vocab, c.batch, c.seq_len)
}

fn small_batch(vocab: usize, s: usize, seed: u64) -> Batch {
    let mut rng = Pcg32::seeded(seed);
    let mut b = Batch::new(1, s);
    for t in b.tokens.iter_mut() {
        *t = rng.below(vocab) as i32;
    }
    for t in b.targets.iter_mut() {
        *t = rng.below(vocab) as i32;
    }
    for w in b.weights.iter_mut() {
        *w = 1.0;
    }
    b
}

/// Train HiFT for `steps` on tiny with the given offload mode; returns the
/// run record and the final (flushed) parameters.
fn train_tiny_hift(offload: Option<OffloadCfg>, m: usize, steps: u64) -> (RunRecord, TensorSet) {
    let mut be = NativeBackend::preset("tiny", 0).unwrap();
    if let Some(cfg) = offload {
        be.set_offload(cfg).unwrap();
    }
    let manifest = be.manifest().clone();
    let mut hift = Hift::pipelined(
        HiftCfg {
            m,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Linear { lr: 4e-3, warmup: 0, total: 16 },
            optim: OptimCfg::new(OptimKind::AdamW),
        },
        &manifest,
        false,
    )
    .unwrap();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), 21).unwrap();
    let rec = trainer::train(
        &mut be,
        &mut hift,
        &mut params,
        task.as_mut(),
        TrainCfg { steps, eval_every: 0, log_every: 0 },
    )
    .unwrap();
    (rec, params)
}

#[test]
fn lossless_paged_grad_run_is_bit_identical_on_all_presets() {
    for preset in PRESET_NAMES {
        let batch;
        let reference = {
            let mut be = NativeBackend::preset(preset, 2).unwrap();
            let cfg = be.manifest().config.clone();
            batch = small_batch(cfg.vocab, cfg.seq_len.min(4), 13);
            let mut params = be.load_params("base").unwrap();
            be.run(&unit_artifact(1), &mut params, &batch).unwrap()
        };
        for offload in [HOST_SYNC, HOST_PREFETCH] {
            let mut be = NativeBackend::preset(preset, 2).unwrap();
            be.set_offload(offload).unwrap();
            let mut params = be.load_params("base").unwrap();
            let got = be.run(&unit_artifact(1), &mut params, &batch).unwrap();
            assert_eq!(reference.loss, got.loss, "{preset}/{}: loss", offload.name());
            assert_eq!(reference.grads.len(), got.grads.len());
            for (i, (a, g)) in reference.grads.iter().zip(&got.grads).enumerate() {
                assert_eq!(
                    a.data, g.data,
                    "{preset}/{}: grad slot {i} must be bit-identical",
                    offload.name()
                );
            }
            assert!(
                be.stats().offload_page_ins > 0,
                "{preset}/{}: the paging tier must actually page",
                offload.name()
            );
        }
    }
}

#[test]
fn paged_hift_training_is_bit_identical_and_prefetch_equals_sync() {
    let steps = 12u64;
    let (rec_ref, p_ref) = train_tiny_hift(None, 2, steps);
    for offload in [HOST_SYNC, HOST_PREFETCH] {
        let (rec, p) = train_tiny_hift(Some(offload), 2, steps);
        assert_eq!(
            rec.losses.values, rec_ref.losses.values,
            "{}: paged loss curve must equal resident",
            offload.name()
        );
        assert_eq!(rec.final_eval, rec_ref.final_eval, "{}", offload.name());
        for ((name, a), b) in p.names.iter().zip(&p.tensors).zip(&p_ref.tensors) {
            assert_eq!(
                a.data, b.data,
                "{}/{name}: flushed paged params must equal resident",
                offload.name()
            );
        }
        assert!(rec.backend.offload_page_ins > 0, "{}: paging exercised", offload.name());
    }
}

#[test]
fn enforced_param_residency_stays_within_group_plus_walk_unit() {
    // m=2 on tiny: the active group spans two units, and the bound
    // group + one transient walk unit is strictly below keeping all four
    // units resident — so this assertion only passes if eviction is real.
    // (This is the plain-walk bound: an activation-checkpointing policy
    // would add one more transient unit during recompute chains — see
    // `memmodel::paged_param_bound`'s slots parameter.)
    let m = 2usize;
    let (rec, _) = train_tiny_hift(Some(HOST_SYNC), m, 8);
    let be = NativeBackend::preset("tiny", 0).unwrap();
    let vinfo = be.manifest().variant("base").unwrap();
    let unit_bytes = be.manifest().unit_param_bytes("base").unwrap();
    let max_unit = unit_bytes.iter().copied().max().unwrap();
    let group = unit_bytes.chunks(m).map(|c| c.iter().sum::<u64>()).max().unwrap();
    let total: u64 = unit_bytes.iter().sum();
    assert!(group + max_unit < total, "bound must be distinguishable from all-resident");

    let peak = rec.backend.peak_param_resident_bytes;
    assert!(peak > 0, "peak must be measured, not zero");
    assert!(
        peak <= group + max_unit,
        "sync paging: peak {peak} must be ≤ group {group} + walk unit {max_unit}"
    );
    // Optimizer state pages per tensor through the fused sink: its enforced
    // device peak is one tensor's AdamW moments (2 × f32), far below the
    // group's state — together, para+opt peaks fit "one group + one
    // prefetch buffer" with room to spare.
    let max_tensor_bytes =
        vinfo.params.iter().map(|p| p.size as u64 * 4).max().unwrap();
    let (_, _, _, opt_peak) = rec.paging.expect("hift has a paging ledger");
    assert!(opt_peak <= 2 * max_tensor_bytes, "opt peak {opt_peak} ≤ one tensor's moments");
    assert!(
        peak + opt_peak <= group + max_unit + 2 * max_tensor_bytes,
        "enforced total ≤ one group + one prefetch buffer worth of slack"
    );

    // Prefetch mode stages the *next* group through `end_run` (cross-step
    // double-buffering), so its arena bound is the current group + the
    // staged next group + one walk unit — "one group + one prefetch
    // buffer".  At m=1 on tiny that is still strictly below all-resident
    // (m=2 would be degenerate: two groups = the whole model).
    let group1 = max_unit; // m=1: the peak group is the largest unit
    let (rec_pf, _) = train_tiny_hift(Some(HOST_PREFETCH), 1, 8);
    let pf_peak = rec_pf.backend.peak_param_resident_bytes;
    assert!(
        pf_peak <= 2 * group1 + max_unit,
        "prefetch: peak {pf_peak} ≤ group + staged group + walk unit"
    );
    assert!(pf_peak < total, "prefetch residency must still beat all-resident");
    assert!(
        rec_pf.backend.peak_prefetch_buffer_bytes <= group1 + max_unit,
        "double buffer holds at most the staged group + one walk unit in flight"
    );
}

#[test]
fn f16_host_store_is_lossy_but_within_drift_bound() {
    let steps = 12u64;
    let (rec_ref, p_ref) = train_tiny_hift(None, 1, steps);
    let (rec, p) = train_tiny_hift(Some(HOST_F16), 1, steps);
    // the lossy path must actually be exercised…
    let mut any_diff = false;
    for (a, b) in p.tensors.iter().zip(&p_ref.tensors) {
        if a.data != b.data {
            any_diff = true;
        }
    }
    assert!(any_diff, "f16 paging must not be a silent no-op");
    // …but stays within a stated drift band: losses finite and close,
    // parameters close in relative L2.
    for (l, r) in rec.losses.values.iter().zip(&rec_ref.losses.values) {
        assert!(l.is_finite(), "f16 run must stay finite");
        assert!((l - r).abs() < 0.1, "per-step loss drift bounded: {l} vs {r}");
    }
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (a, b) in p.tensors.iter().zip(&p_ref.tensors) {
        for (x, y) in a.data.iter().zip(&b.data) {
            num += ((x - y) as f64).powi(2);
            den += (*y as f64).powi(2);
        }
    }
    let rel = (num / den.max(1e-12)).sqrt();
    assert!(rel < 0.05, "f16 parameter drift {rel} must stay within 5% relative L2");
    // and the host tier really is half-size
    let (rec_lossless, _) = train_tiny_hift(Some(HOST_SYNC), 1, 2);
    assert!(
        rec.backend.peak_host_pool_bytes <= rec_lossless.backend.peak_host_pool_bytes / 2 + 64,
        "f16 host pool {} must be ≈ half the lossless pool {}",
        rec.backend.peak_host_pool_bytes,
        rec_lossless.backend.peak_host_pool_bytes
    );
}

#[test]
fn ledger_counts_equal_pool_transfer_events() {
    for offload in [HOST_SYNC, HOST_PREFETCH] {
        let mut be = NativeBackend::preset("tiny", 0).unwrap();
        be.set_offload(offload).unwrap();
        let manifest = be.manifest().clone();
        let n_managed = manifest.variant("base").unwrap().params.len() as u64;
        let mut hift = Hift::pipelined(
            HiftCfg {
                m: 1,
                order: UpdateStrategy::Bottom2Up,
                schedule: LrSchedule::Const { lr: 2e-3 },
                optim: OptimCfg::new(OptimKind::AdamW),
            },
            &manifest,
            false,
        )
        .unwrap();
        let mut params = be.load_params("base").unwrap();
        let mut task = build_task("motif4", geom(&be), 7).unwrap();
        for _ in 0..6 {
            let b = task.train_batch();
            hift.step(&mut be, &mut params, &b).unwrap();
        }
        be.flush_offload(&mut params).unwrap();
        let counters = be.offload_counters().expect("pager active");
        let (stores, fetches) = be.offload_pool_events().unwrap().expect("pager active");
        // One source of truth: the ledger *is* the pool's accounting —
        // stores lead page-outs by exactly the initial placement.
        assert_eq!(stores, counters.page_outs + n_managed, "{}", offload.name());
        assert_eq!(fetches, counters.page_ins, "{}", offload.name());
        // RuntimeStats mirrors the pager's ledger, event for event.
        let stats = be.stats();
        assert_eq!(stats.offload_page_ins, counters.page_ins);
        assert_eq!(stats.offload_page_outs, counters.page_outs);
        assert_eq!(stats.offload_h2d_bytes, counters.h2d_bytes);
        assert_eq!(stats.offload_d2h_bytes, counters.d2h_bytes);
        // The base variant has no adapters: after a flush every managed
        // byte is back in the arena, so resident bytes equal the whole set.
        assert_eq!(
            counters.param_resident_bytes,
            params.total_bytes() as u64,
            "flush restored everything"
        );
    }
}

#[test]
fn checkpoint_written_under_offload_is_complete_and_matches_resident() {
    use hift::tensor::checkpoint;
    let dir = std::env::temp_dir().join(format!("hift_offload_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let steps = 6u64;
    let (_, p_ref) = train_tiny_hift(None, 1, steps);

    let mut be = NativeBackend::preset("tiny", 0).unwrap();
    be.set_offload(HOST_SYNC).unwrap();
    let manifest = be.manifest().clone();
    let mut hift = Hift::pipelined(
        HiftCfg {
            m: 1,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Linear { lr: 4e-3, warmup: 0, total: 16 },
            optim: OptimCfg::new(OptimKind::AdamW),
        },
        &manifest,
        false,
    )
    .unwrap();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), 21).unwrap();
    trainer::train_ckpt(
        &mut be,
        &mut hift,
        &mut params,
        task.as_mut(),
        TrainCfg { steps, eval_every: 0, log_every: 0 },
        &CkptOpts { save_dir: Some(dir.clone()), save_every: 0, ..Default::default() },
    )
    .unwrap();

    let ck = checkpoint::load(&dir).unwrap();
    assert_eq!(ck.meta.step, steps);
    for (i, t) in ck.params.tensors.iter().enumerate() {
        let expect: usize = t.shape.iter().product();
        assert_eq!(
            t.numel(),
            expect,
            "checkpointed tensor {:?} must be fully materialized",
            ck.params.names[i]
        );
        assert_eq!(
            t.data, p_ref.tensors[i].data,
            "checkpointed tensor {:?} must match the resident run",
            ck.params.names[i]
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mezo_refuses_offload_instead_of_corrupting() {
    let mut be = NativeBackend::preset("tiny", 0).unwrap();
    be.set_offload(OffloadCfg::host()).unwrap();
    let manifest = be.manifest().clone();
    let mut spec = StrategySpec::new("mezo", OptimKind::Sgd, 3e-4, 4);
    spec.seed = 1;
    let mut mezo = spec.build(&manifest).unwrap();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), 3).unwrap();
    let b = task.train_batch();
    let err = mezo.step(&mut be, &mut params, &b).unwrap_err();
    assert!(err.to_string().contains("offload"), "{err}");
}
