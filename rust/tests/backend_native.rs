//! Native-backend correctness: finite-difference verification of the
//! hand-written backward pass (acceptance: rel. err < 1e-3 on a tiny
//! model), the HiFT ↔ FPFT-per-group equivalence across the backend seam,
//! and the offload-ledger memory claim (HiFT's peak device optimizer state
//! is a small fraction of FPFT's resident state).

use hift::backend::{Batch, ExecBackend, ModelCfg, NativeBackend};
use hift::coordinator::lr::LrSchedule;
use hift::coordinator::strategy::UpdateStrategy;
use hift::coordinator::trainer::{self, TrainCfg};
use hift::data::{build_task, TaskGeom};
use hift::optim::{self, OptimCfg, OptimKind, Optimizer};
use hift::rng::Pcg32;
use hift::strategies::{FineTuneStrategy, Hift, HiftCfg, SubsetTune};
use hift::tensor::{Tensor, TensorSet};

fn fd_cfg() -> ModelCfg {
    ModelCfg {
        name: "fd".into(),
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        seq_len: 8,
        batch: 2,
        lora_rank: 2,
        lora_alpha: 8.0,
        n_prefix: 2,
    }
}

fn dense_batch(cfg: &ModelCfg, seed: u64) -> Batch {
    let mut rng = Pcg32::seeded(seed);
    let mut b = Batch::new(cfg.batch, cfg.seq_len);
    for t in b.tokens.iter_mut() {
        *t = rng.below(cfg.vocab) as i32;
    }
    for t in b.targets.iter_mut() {
        *t = rng.below(cfg.vocab) as i32;
    }
    for w in b.weights.iter_mut() {
        *w = 1.0;
    }
    b
}

/// Jitter every tensor so no parameter sits at a symmetric point (zeros /
/// exact ones) where some gradients would vanish structurally.
fn jitter(params: &mut TensorSet, seed: u64) {
    for i in 0..params.len() {
        let mut rng = Pcg32::new(seed, 7000 + i as u64);
        let t = params.tensor_mut(i);
        for x in t.data.iter_mut() {
            *x += 0.05 * rng.normal();
        }
    }
}

fn loss_at(be: &mut NativeBackend, variant: &str, mut params: TensorSet, batch: &Batch) -> f64 {
    be.run(&format!("fwd_{variant}"), &mut params, batch).unwrap().loss as f64
}

fn perturbed(params: &TensorSet, idx: usize, z: &Tensor, eps: f32) -> TensorSet {
    let mut p = params.clone();
    p.tensor_mut(idx).axpy(eps, z);
    p
}

/// Directional derivative along the normalized analytic gradient of one
/// tensor, with Richardson extrapolation to kill the O(ε²) term.
fn directional_fd(
    be: &mut NativeBackend,
    variant: &str,
    params: &TensorSet,
    batch: &Batch,
    idx: usize,
    z: &Tensor,
    eps: f32,
) -> f64 {
    let fd = |be: &mut NativeBackend, e: f32| -> f64 {
        let lp = loss_at(be, variant, perturbed(params, idx, z, e), batch);
        let lm = loss_at(be, variant, perturbed(params, idx, z, -e), batch);
        (lp - lm) / (2.0 * e as f64)
    };
    let d1 = fd(be, eps);
    let d2 = fd(be, 0.5 * eps);
    (4.0 * d2 - d1) / 3.0
}

/// Finite-difference check of every requested gradient of `artifact`.
/// Tensors with grad norm ≥ 0.1 must match to rel. err < 1e-3; the
/// largest-norm tensor is additionally always checked (rel. err < 1e-2)
/// so no variant can silently skip everything.
fn fd_check(variant: &str, artifact: &str, min_strict_checks: usize) {
    let mut be = NativeBackend::new(fd_cfg(), 21).unwrap();
    let mut params = be.load_params(variant).unwrap();
    jitter(&mut params, 4242);
    let batch = dense_batch(&be.manifest().config.clone(), 17);

    let info = be.manifest().artifact(artifact).unwrap().clone();
    let out = be.run(artifact, &mut params, &batch).unwrap();
    assert_eq!(out.grads.len(), info.outputs.len() - 2);

    // Per-tensor step size holding the loss excursion ε·‖g‖ ≈ 0.02 roughly
    // constant: steep directions get small steps (bounds the curvature
    // term), flat ones get large steps (keeps the f32 signal-to-noise up).
    let eps_for = |norm: f32| (0.02 / norm).clamp(0.005, 0.2);
    let mut strict = 0usize;
    let mut best: Option<(usize, f32)> = None; // (grad index, norm)
    for (gi, g) in out.grads.iter().enumerate() {
        let norm = g.l2_norm();
        if best.map(|(_, n)| norm > n).unwrap_or(true) {
            best = Some((gi, norm));
        }
        if norm < 0.1 {
            continue;
        }
        let name = &info.outputs[2 + gi];
        let idx = params.index_of(name).unwrap();
        let mut z = g.clone();
        z.scale(1.0 / norm);
        let fd = directional_fd(&mut be, variant, &params, &batch, idx, &z, eps_for(norm));
        let rel = (fd - norm as f64).abs() / norm as f64;
        assert!(
            rel < 1e-3,
            "{variant}/{name}: fd {fd:.6} vs analytic {norm:.6} (rel {rel:.2e})"
        );
        strict += 1;
    }
    assert!(
        strict >= min_strict_checks,
        "{variant}: only {strict} tensors above the strict-check threshold"
    );
    // Belt and braces: the dominant gradient always matches.
    let (gi, norm) = best.expect("artifact emits gradients");
    assert!(norm > 1e-5, "{variant}: all gradients vanish?");
    let name = &info.outputs[2 + gi];
    let idx = params.index_of(name).unwrap();
    let mut z = out.grads[gi].clone();
    z.scale(1.0 / norm);
    let fd = directional_fd(&mut be, variant, &params, &batch, idx, &z, eps_for(norm));
    let rel = (fd - norm as f64).abs() / norm as f64;
    assert!(rel < 1e-2, "{variant}/{name} (largest): fd {fd} vs {norm} (rel {rel:.2e})");
}

#[test]
fn native_gradients_match_finite_differences_base() {
    fd_check("base", "grad_base_full", 5);
}

#[test]
fn native_gradients_match_finite_differences_lora() {
    fd_check("lora", "grad_lora_adapter", 1);
}

#[test]
fn native_gradients_match_finite_differences_ia3() {
    fd_check("ia3", "grad_ia3_adapter", 0);
}

#[test]
fn native_gradients_match_finite_differences_prefix() {
    fd_check("prefix", "grad_prefix_adapter", 1);
}

/// The backend-seam equivalence the ISSUE asks for: one full HiFT sweep
/// (m=1) must land on exactly the parameters produced by "FPFT-per-group"
/// — compute the *full* gradient each step but update only that step's
/// unit with the same optimizer state and LR.
#[test]
fn hift_sweep_equals_fpft_per_group() {
    let mut be = NativeBackend::preset("tiny", 0).unwrap();
    let manifest = be.manifest().clone();
    let n_units = manifest.n_units;
    let c = &manifest.config;
    let lr = 3e-3f32;
    let ocfg = OptimCfg::new(OptimKind::AdamW);

    let mut task =
        build_task("motif4", TaskGeom::new(c.vocab, c.batch, c.seq_len), 5).unwrap();
    let batches: Vec<Batch> = (0..n_units).map(|_| task.train_batch()).collect();

    // HiFT m=1, bottom2up: one sweep = one update of every unit.
    let mut hift = Hift::new(
        HiftCfg {
            m: 1,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr },
            optim: ocfg,
        },
        &manifest,
    )
    .unwrap();
    let mut p_h = be.load_params("base").unwrap();
    for b in &batches {
        hift.step(&mut be, &mut p_h, b).unwrap();
    }

    // FPFT-per-group reference: full gradients, masked update.
    let vinfo = manifest.variant("base").unwrap();
    let mut p_f = be.load_params("base").unwrap();
    let mut opt = optim::build(ocfg, vinfo.params.len());
    for (step, b) in batches.iter().enumerate() {
        let out = be.run("grad_base_full", &mut p_f, b).unwrap();
        for &pi in &vinfo.unit_indices(step) {
            let mut g = out.grads[pi].clone();
            optim::clip_grad(&mut g, ocfg.grad_clip);
            opt.update(pi, p_f.tensor_mut(pi), &g, lr);
        }
    }

    for ((name, th), tf) in
        p_h.names.iter().zip(&p_h.tensors).zip(&p_f.tensors)
    {
        let mut d = th.clone();
        d.axpy(-1.0, tf);
        assert!(
            d.abs_max() < 1e-6,
            "{name}: hift(m=1 sweep) and fpft-per-group diverge by {}",
            d.abs_max()
        );
    }
}

/// Ledger memory claim: under AdamW, HiFT's peak *device-resident*
/// optimizer state is bounded by one group (≈1/n_units of the model) while
/// FPFT keeps the full state resident.
#[test]
fn hift_peak_device_state_is_fraction_of_fpft() {
    let mut be = NativeBackend::preset("tiny", 0).unwrap();
    let manifest = be.manifest().clone();
    let n_units = manifest.n_units;
    let vinfo = manifest.variant("base").unwrap();
    let c = &manifest.config;
    let geom = TaskGeom::new(c.vocab, c.batch, c.seq_len);
    let steps = n_units as u64; // one full sweep

    let mut hift = Hift::new(
        HiftCfg {
            m: 1,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr: 1e-3 },
            optim: OptimCfg::new(OptimKind::AdamW),
        },
        &manifest,
    )
    .unwrap();
    let mut p_h = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom, 3).unwrap();
    let rec_h = trainer::train(&mut be, &mut hift, &mut p_h, task.as_mut(),
        TrainCfg { steps, eval_every: 0, log_every: 0 }).unwrap();
    let (_, _, _, peak) = rec_h.paging.unwrap();

    let mut fpft = SubsetTune::fpft(
        &manifest,
        OptimCfg::new(OptimKind::AdamW),
        LrSchedule::Const { lr: 1e-3 },
    )
    .unwrap();
    let mut p_f = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom, 3).unwrap();
    let rec_f = trainer::train(&mut be, &mut fpft, &mut p_f, task.as_mut(),
        TrainCfg { steps: 2, eval_every: 0, log_every: 0 }).unwrap();

    // FPFT: AdamW m+v for every element, fully resident.
    let total_elems: usize = vinfo.params.iter().map(|p| p.size).sum();
    let fpft_resident = rec_f.optimizer_state_bytes as u64;
    assert_eq!(fpft_resident, 8 * total_elems as u64, "AdamW = 2 f32 words / element");

    // HiFT: the device never holds more than the active group's state —
    // with per-tensor paging, at most one tensor's m+v at a time.
    let max_unit_elems: usize = (0..n_units)
        .map(|u| vinfo.unit_indices(u).iter().map(|&i| vinfo.params[i].size).sum())
        .max()
        .unwrap();
    let max_tensor_elems: usize = vinfo.params.iter().map(|p| p.size).max().unwrap();
    assert_eq!(peak, 8 * max_tensor_elems as u64, "peak = one tensor's m+v");
    assert!(peak <= 8 * max_unit_elems as u64, "peak bounded by the active group");
    // The headline ratio: ~1/n_units of FPFT's resident state (×2 slack for
    // uneven unit sizes).
    let ratio = peak as f64 / fpft_resident as f64;
    assert!(
        ratio <= 2.0 / n_units as f64,
        "peak/{fpft_resident} = {ratio:.3} should be ≲ 1/{n_units}"
    );
}
