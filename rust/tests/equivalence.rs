//! Schedule-equivalence and cross-strategy invariants over the real stack
//! (native CPU backend — runs offline).
//!
//! The strongest correctness statement for the coordinator: with a single
//! group (m ≥ n_units) HiFT's step IS standard FPFT — same gradients, same
//! optimizer sequence, same delayed-LR index — so the two trajectories must
//! coincide numerically.  Plus variant-parity checks for the PEFT models.

use hift::backend::{ExecBackend, NativeBackend};
use hift::coordinator::lr::LrSchedule;
use hift::coordinator::strategy::UpdateStrategy;
use hift::coordinator::trainer::{self, TrainCfg};
use hift::data::{build_task, TaskGeom};
use hift::optim::{OptimCfg, OptimKind};
use hift::strategies::{Hift, HiftCfg, SubsetTune};

fn backend() -> NativeBackend {
    NativeBackend::preset("tiny", 0).expect("tiny preset")
}

fn geom(be: &dyn ExecBackend) -> TaskGeom {
    let c = &be.manifest().config;
    TaskGeom::new(c.vocab, c.batch, c.seq_len)
}

#[test]
fn hift_single_group_equals_fpft_trajectory() {
    let mut be = backend();
    let n_units = be.manifest().n_units;
    let sched = LrSchedule::Const { lr: 3e-3 };
    let ocfg = OptimCfg::new(OptimKind::AdamW);
    let steps = 10u64;

    // FPFT trajectory.
    let mut fpft = SubsetTune::fpft(be.manifest(), ocfg, sched).unwrap();
    let mut p_f = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), 3).unwrap();
    let rec_f = trainer::train(&mut be, &mut fpft, &mut p_f, task.as_mut(),
        TrainCfg { steps, eval_every: 0, log_every: 0 }).unwrap();

    // HiFT with m = n_units (one group = everything; k = 1 so the delayed
    // LR advances every step, exactly like FPFT).
    let mut hift = Hift::new(
        HiftCfg { m: n_units, order: UpdateStrategy::Bottom2Up, schedule: sched, optim: ocfg },
        be.manifest(),
    )
    .unwrap();
    let mut p_h = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), 3).unwrap();
    let rec_h = trainer::train(&mut be, &mut hift, &mut p_h, task.as_mut(),
        TrainCfg { steps, eval_every: 0, log_every: 0 }).unwrap();

    for (i, (lf, lh)) in rec_f.losses.values.iter().zip(&rec_h.losses.values).enumerate() {
        assert!(
            (lf - lh).abs() < 1e-4 * (1.0 + lf.abs()),
            "step {i}: fpft {lf} vs hift(m=all) {lh}"
        );
    }
    // Final parameters must coincide too.
    for (tf, th) in p_f.tensors.iter().zip(&p_h.tensors) {
        let mut d = tf.clone();
        d.axpy(-1.0, th);
        assert!(d.abs_max() < 1e-4, "final params diverge by {}", d.abs_max());
    }
}

#[test]
fn update_order_converges_for_all_strategies() {
    // Fig 4-left at test scale: all three orders reach a similar loss.
    let mut be = backend();
    let mut finals = Vec::new();
    for order in [
        UpdateStrategy::Bottom2Up,
        UpdateStrategy::Top2Down,
        UpdateStrategy::Random { seed: 5 },
    ] {
        let mut hift = Hift::new(
            HiftCfg {
                m: 1,
                order,
                schedule: LrSchedule::Const { lr: 4e-3 },
                optim: OptimCfg::new(OptimKind::AdamW),
            },
            be.manifest(),
        )
        .unwrap();
        let mut params = be.load_params("base").unwrap();
        let mut task = build_task("motif4", geom(&be), 9).unwrap();
        let rec = trainer::train(&mut be, &mut hift, &mut params, task.as_mut(),
            TrainCfg { steps: 48, eval_every: 0, log_every: 0 }).unwrap();
        let tail = rec.losses.tail_mean(8);
        assert!(tail < rec.losses.values[0], "{order:?} did not descend");
        finals.push(tail);
    }
    let max = finals.iter().cloned().fold(f64::MIN, f64::max);
    let min = finals.iter().cloned().fold(f64::MAX, f64::min);
    assert!(max - min < 1.2, "orders diverge wildly: {finals:?}");
}

#[test]
fn every_optimizer_descends_under_hift() {
    let mut be = backend();
    for (kind, lr) in [
        (OptimKind::AdamW, 4e-3f32),
        (OptimKind::Sgd, 3e-2),
        (OptimKind::Sgdm, 8e-3),
        (OptimKind::Adagrad, 2e-2),
        (OptimKind::Adafactor, 2e-2),
    ] {
        let mut hift = Hift::new(
            HiftCfg {
                m: 1,
                order: UpdateStrategy::Bottom2Up,
                schedule: LrSchedule::Const { lr },
                optim: OptimCfg::new(kind),
            },
            be.manifest(),
        )
        .unwrap();
        let mut params = be.load_params("base").unwrap();
        let mut task = build_task("markovlm", geom(&be), 13).unwrap();
        let rec = trainer::train(&mut be, &mut hift, &mut params, task.as_mut(),
            TrainCfg { steps: 32, eval_every: 0, log_every: 0 }).unwrap();
        assert!(
            rec.losses.tail_mean(8) < rec.losses.values[..4].iter().sum::<f64>() / 4.0,
            "{kind:?}: no descent ({:?} -> {:?})",
            rec.losses.values[0],
            rec.losses.tail_mean(8)
        );
        assert!(params.tensors.iter().all(|t| t.is_finite()), "{kind:?} params finite");
    }
}

#[test]
fn delayed_lr_is_constant_within_sweep_on_real_run() {
    use hift::strategies::FineTuneStrategy;
    let mut be = backend();
    let mut hift = Hift::new(
        HiftCfg {
            m: 1,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Linear { lr: 1e-3, warmup: 0, total: 10 },
            optim: OptimCfg::new(OptimKind::Sgd),
        },
        be.manifest(),
    )
    .unwrap();
    let k = hift.k();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif2", geom(&be), 1).unwrap();
    let mut lrs = Vec::new();
    for _ in 0..2 * k {
        let b = task.train_batch();
        let stats = hift.step(&mut be, &mut params, &b).unwrap();
        lrs.push(stats.lr);
    }
    let first_sweep: Vec<f32> = lrs[..k].to_vec();
    assert!(first_sweep.windows(2).all(|w| w[0] == w[1]), "sweep-constant LR: {lrs:?}");
    assert!(lrs[k] < lrs[0], "LR advanced after sweep: {lrs:?}");
}

#[test]
fn mezo_preserves_params_when_lr_zero() {
    // The ±ε walk must restore parameters exactly (up to f32 rounding).
    use hift::strategies::{FineTuneStrategy, Mezo};
    let mut be = backend();
    let mut mezo = Mezo::new(
        be.manifest(),
        OptimCfg::new(OptimKind::Sgd),
        LrSchedule::Const { lr: 0.0 },
        7,
    )
    .unwrap();
    let mut params = be.load_params("base").unwrap();
    let before = params.clone();
    let mut task = build_task("motif2", geom(&be), 2).unwrap();
    let b = task.train_batch();
    mezo.step(&mut be, &mut params, &b).unwrap();
    for (a, b_) in before.tensors.iter().zip(&params.tensors) {
        let mut d = a.clone();
        d.axpy(-1.0, b_);
        assert!(d.abs_max() < 1e-5, "lr=0 MeZO must restore params, drift {}", d.abs_max());
    }
}
