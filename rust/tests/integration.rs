//! Integration tests over the full training stack, driven end-to-end
//! through the [`ExecBackend`] seam on the native CPU backend — no
//! artifacts, no Python, no network.  (With `--features pjrt` plus `make
//! artifacts` the same coordinator code runs against PJRT; these tests
//! deliberately depend only on the trait.)

use hift::backend::{unit_artifact, ExecBackend, NativeBackend};
use hift::coordinator::lr::LrSchedule;
use hift::coordinator::strategy::UpdateStrategy;
use hift::coordinator::trainer::{self, TrainCfg};
use hift::data::{build_task, TaskGeom};
use hift::optim::{OptimCfg, OptimKind};
use hift::strategies::{FineTuneStrategy, Hift, HiftCfg, StrategySpec, SubsetTune};

fn backend() -> NativeBackend {
    NativeBackend::preset("tiny", 0).expect("tiny preset")
}

fn geom(be: &dyn ExecBackend) -> TaskGeom {
    let c = &be.manifest().config;
    TaskGeom::new(c.vocab, c.batch, c.seq_len)
}

#[test]
fn manifest_and_params_load() {
    let be = backend();
    let m = be.manifest();
    assert_eq!(m.preset, "tiny");
    assert_eq!(m.n_units, m.config.n_layers + 2);
    let params = be.load_params("base").unwrap();
    assert_eq!(params.len(), m.variant("base").unwrap().params.len());
    assert!(params.l2_norm() > 0.0, "init is not all zeros");
    for v in ["lora", "ia3", "prefix"] {
        let p = be.load_params(v).unwrap();
        assert!(p.len() > params.len(), "{v} adds adapter tensors");
    }
}

#[test]
fn forward_artifact_executes_and_is_deterministic() {
    let mut be = backend();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), 7).unwrap();
    let batch = task.train_batch();
    let a = be.run("fwd_base", &mut params, &batch).unwrap();
    let b = be.run("fwd_base", &mut params, &batch).unwrap();
    assert!(a.loss.is_finite() && a.loss > 0.0);
    assert_eq!(a.loss, b.loss, "same params+batch ⇒ identical loss");
    assert!(a.grads.is_empty());
    // untrained model ≈ uniform: loss ≈ ln(vocab)
    let uniform = (be.manifest().config.vocab as f32).ln();
    assert!((a.loss - uniform).abs() < 1.5, "loss {} vs ln(V)={}", a.loss, uniform);
}

#[test]
fn unit_grads_are_slices_of_full_grad() {
    // The HiFT foundation at the artifact level: per-unit grad artifacts
    // produce exactly the corresponding slices of grad_base_full.
    let mut be = backend();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("markovlm", geom(&be), 3).unwrap();
    let batch = task.train_batch();
    let full = be.run("grad_base_full", &mut params, &batch).unwrap();
    let vinfo = be.manifest().variant("base").unwrap().clone();
    let n_units = be.manifest().n_units;
    for u in 0..n_units {
        let out = be.run(&unit_artifact(u), &mut params, &batch).unwrap();
        assert!((out.loss - full.loss).abs() < 1e-5);
        let idxs = vinfo.unit_indices(u);
        assert_eq!(out.grads.len(), idxs.len());
        for (g, &i) in out.grads.iter().zip(&idxs) {
            let fg = &full.grads[i];
            assert_eq!(g.shape, fg.shape);
            let mut diff = g.clone();
            diff.axpy(-1.0, fg);
            assert!(
                diff.abs_max() < 1e-4 * (1.0 + fg.abs_max()),
                "unit {u} param {} grad mismatch: {} vs full",
                vinfo.params[i].name,
                diff.abs_max()
            );
        }
    }
}

#[test]
fn bitfit_grads_are_slices_of_full_grad() {
    // BitFit's bias/LN-only artifact skips every dense weight matmul
    // (GradSpec::dense = false) — the emitted gradients must still be
    // bit-identical to the corresponding slices of grad_base_full.
    let mut be = backend();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("markovlm", geom(&be), 3).unwrap();
    let batch = task.train_batch();
    let full = be.run("grad_base_full", &mut params, &batch).unwrap();
    let out = be.run("grad_base_bitfit", &mut params, &batch).unwrap();
    let vinfo = be.manifest().variant("base").unwrap().clone();
    let idxs = vinfo.bitfit_indices();
    assert_eq!(out.grads.len(), idxs.len());
    for (g, &i) in out.grads.iter().zip(&idxs) {
        assert_eq!(g.shape.len(), 1, "bitfit trains only 1-D params");
        let mut diff = g.clone();
        diff.axpy(-1.0, &full.grads[i]);
        assert!(diff.abs_max() < 1e-6, "{} bitfit grad mismatch", vinfo.params[i].name);
    }
}

#[test]
fn hift_training_reduces_loss_and_pages_state() {
    let mut be = backend();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), 11).unwrap();
    let mut hift = Hift::new(
        HiftCfg {
            m: 1,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr: 5e-3 },
            optim: OptimCfg::new(OptimKind::AdamW),
        },
        be.manifest(),
    )
    .unwrap();
    let k = hift.k() as u64;
    let rec = trainer::train(&mut be, &mut hift, &mut params, &mut *task, TrainCfg {
        steps: 6 * k,
        eval_every: 0,
        log_every: 0,
    })
    .unwrap();
    let first = rec.losses.values[..k as usize].iter().sum::<f64>() / k as f64;
    let last = rec.losses.tail_mean(k as usize);
    assert!(last < first, "loss must fall: {first:.3} -> {last:.3}");
    // Paging: AdamW state for the active group only; inflight < total state.
    let (h2d, d2h, inflight, peak) = rec.paging.unwrap();
    assert!(h2d > 0 && d2h > 0);
    assert!(inflight > 0);
    let total_state = rec.optimizer_state_bytes as u64;
    assert!(peak < total_state, "peak device state {peak} must be < total {total_state}");
    // Peak trainable ≪ all params (the headline claim, tiny-scale).
    assert!(rec.peak_trainable_params < params.total_params());
}

#[test]
fn hift_sgd_has_zero_state_paging() {
    // §4.3: "When using SGD, the peak communication parameter is zero."
    let mut be = backend();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif2", geom(&be), 5).unwrap();
    let mut hift = Hift::new(
        HiftCfg {
            m: 1,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr: 1e-2 },
            optim: OptimCfg::new(OptimKind::Sgd),
        },
        be.manifest(),
    )
    .unwrap();
    let rec = trainer::train(&mut be, &mut hift, &mut params, &mut *task,
        TrainCfg { steps: 8, eval_every: 0, log_every: 0 }).unwrap();
    let (h2d, _, inflight, peak) = rec.paging.unwrap();
    assert_eq!(h2d, 0, "SGD pages nothing");
    assert_eq!(inflight, 0);
    assert_eq!(peak, 0);
}

#[test]
fn fpft_baseline_trains() {
    let mut be = backend();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), 11).unwrap();
    let mut fpft = SubsetTune::fpft(
        be.manifest(),
        OptimCfg::new(OptimKind::AdamW),
        LrSchedule::Const { lr: 5e-3 },
    )
    .unwrap();
    let rec = trainer::train(&mut be, &mut fpft, &mut params, &mut *task,
        TrainCfg { steps: 24, eval_every: 0, log_every: 0 }).unwrap();
    assert!(rec.losses.tail_mean(6) < rec.losses.values[0]);
    assert_eq!(rec.peak_trainable_params, params.total_params(), "FPFT trains everything");
}

#[test]
fn every_strategy_builds_and_steps() {
    let mut be = backend();
    let mut task = build_task("motif2", geom(&be), 2).unwrap();
    for name in hift::strategies::STRATEGY_NAMES {
        let spec = StrategySpec::new(name, OptimKind::AdamW, 1e-3, 10);
        let mut strat = spec.build(be.manifest()).unwrap();
        let mut params = be.load_params(strat.variant()).unwrap();
        let before = params.l2_norm();
        let batch = task.train_batch();
        let stats = strat.step(&mut be, &mut params, &batch).unwrap();
        assert!(stats.loss.is_finite(), "{name} loss finite");
        assert!(stats.trainable_params > 0, "{name} trains something");
        assert!(params.tensors.iter().all(|t| t.is_finite()), "{name} params finite");
        assert_ne!(params.l2_norm(), before, "{name} changed parameters");
    }
}

#[test]
fn peft_trains_fewer_params_than_hift_peak() {
    // Sanity on the Table-5 axis: adapter sets ≪ one HiFT group ≪ full.
    let mut be = backend();
    let mut task = build_task("motif2", geom(&be), 2).unwrap();
    let batch = task.train_batch();
    let mut sizes = std::collections::HashMap::new();
    for name in ["lora", "ia3", "hift", "fpft"] {
        let spec = StrategySpec::new(name, OptimKind::AdamW, 1e-3, 10);
        let mut strat = spec.build(be.manifest()).unwrap();
        let mut params = be.load_params(strat.variant()).unwrap();
        strat.step(&mut be, &mut params, &batch).unwrap();
        sizes.insert(name, strat.peak_trainable_params());
    }
    assert!(sizes["lora"] < sizes["hift"]);
    assert!(sizes["ia3"] < sizes["hift"]);
    assert!(sizes["hift"] < sizes["fpft"]);
}

#[test]
fn evaluation_accuracy_is_in_unit_interval() {
    let mut be = backend();
    let mut params = be.load_params("base").unwrap();
    let task = build_task("motif4", geom(&be), 7).unwrap();
    let ev = trainer::evaluate(&mut be, "fwd_base", &mut params, task.eval_batches()).unwrap();
    assert!((0.0..=1.0).contains(&ev.acc));
    assert!(ev.loss.is_finite());
}

#[test]
fn eval_loss_is_weighted_by_batch_mask_sums() {
    // Two batches with very different mask sizes: the aggregate eval loss
    // must be the weight-sum-weighted mean, not the plain per-batch mean.
    let mut be = backend();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("markovlm", geom(&be), 9).unwrap();
    let heavy = task.train_batch(); // dense LM supervision
    let mut light = task.train_batch();
    // keep exactly one supervised position in the light batch
    let keep = light.weights.iter().position(|&w| w > 0.0).unwrap();
    for (i, w) in light.weights.iter_mut().enumerate() {
        if i != keep {
            *w = 0.0;
        }
    }
    let lh = be.run("fwd_base", &mut params, &heavy).unwrap().loss as f64;
    let ll = be.run("fwd_base", &mut params, &light).unwrap().loss as f64;
    let wh: f64 = heavy.weights.iter().map(|&w| w as f64).sum();
    let wl: f64 = light.weights.iter().map(|&w| w as f64).sum();
    let expect = (lh * wh + ll * wl) / (wh + wl);
    let ev = trainer::evaluate(&mut be, "fwd_base", &mut params, &[heavy, light]).unwrap();
    assert!(
        (ev.loss - expect).abs() < 1e-5,
        "weighted eval loss: got {} want {} (plain mean would be {})",
        ev.loss,
        expect,
        0.5 * (lh + ll)
    );
}
