//! Integration tests over the real three-layer stack: HLO artifacts
//! (Pallas kernels inside) loaded and executed through PJRT, driven by the
//! Rust coordinator.  Requires `make artifacts` (preset `tiny`).

use hift::coordinator::lr::LrSchedule;
use hift::coordinator::trainer::{self, TrainCfg};
use hift::coordinator::strategy::UpdateStrategy;
use hift::data::{build_task, TaskGeom};
use hift::optim::{OptimCfg, OptimKind};
use hift::runtime::Runtime;
use hift::strategies::{FineTuneStrategy, Hift, HiftCfg, StrategySpec, SubsetTune};

fn artifacts_dir() -> std::path::PathBuf {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    root.join("artifacts").join("tiny")
}

fn runtime() -> Runtime {
    Runtime::load(artifacts_dir()).expect("run `make artifacts` first")
}

fn geom(rt: &Runtime) -> TaskGeom {
    let c = &rt.manifest().config;
    TaskGeom::new(c.vocab, c.batch, c.seq_len)
}

#[test]
fn manifest_and_params_load() {
    let rt = runtime();
    let m = rt.manifest();
    assert_eq!(m.preset, "tiny");
    assert_eq!(m.n_units, m.config.n_layers + 2);
    let params = rt.load_params("base").unwrap();
    assert_eq!(params.len(), m.variant("base").unwrap().params.len());
    assert!(params.l2_norm() > 0.0, "params.bin is not all zeros");
    for v in ["lora", "ia3", "prefix"] {
        let p = rt.load_params(v).unwrap();
        assert!(p.len() > params.len(), "{v} adds adapter tensors");
    }
}

#[test]
fn forward_artifact_executes_and_is_deterministic() {
    let mut rt = runtime();
    let params = rt.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&rt), 7).unwrap();
    let batch = task.train_batch();
    let a = rt.run("fwd_base", &params, &batch).unwrap();
    let b = rt.run("fwd_base", &params, &batch).unwrap();
    assert!(a.loss.is_finite() && a.loss > 0.0);
    assert_eq!(a.loss, b.loss, "same params+batch ⇒ identical loss");
    assert!(a.grads.is_empty());
    // untrained model ≈ uniform: loss ≈ ln(vocab)
    let uniform = (rt.manifest().config.vocab as f32).ln();
    assert!((a.loss - uniform).abs() < 1.5, "loss {} vs ln(V)={}", a.loss, uniform);
}

#[test]
fn unit_grads_are_slices_of_full_grad() {
    // The HiFT foundation at the artifact level: per-unit grad artifacts
    // produce exactly the corresponding slices of grad_base_full.
    let mut rt = runtime();
    let params = rt.load_params("base").unwrap();
    let mut task = build_task("markovlm", geom(&rt), 3).unwrap();
    let batch = task.train_batch();
    let full = rt.run("grad_base_full", &params, &batch).unwrap();
    let vinfo = rt.manifest().variant("base").unwrap().clone();
    for u in 0..rt.manifest().n_units {
        let out = rt.run(&Runtime::unit_artifact(u), &params, &batch).unwrap();
        assert!((out.loss - full.loss).abs() < 1e-5);
        let idxs = vinfo.unit_indices(u);
        assert_eq!(out.grads.len(), idxs.len());
        for (g, &i) in out.grads.iter().zip(&idxs) {
            let fg = &full.grads[i];
            assert_eq!(g.shape, fg.shape);
            let mut diff = g.clone();
            diff.axpy(-1.0, fg);
            assert!(
                diff.abs_max() < 1e-4 * (1.0 + fg.abs_max()),
                "unit {u} param {} grad mismatch: {} vs full",
                vinfo.params[i].name,
                diff.abs_max()
            );
        }
    }
}

#[test]
fn hift_training_reduces_loss_and_pages_state() {
    let mut rt = runtime();
    let mut params = rt.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&rt), 11).unwrap();
    let mut hift = Hift::new(
        HiftCfg {
            m: 1,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr: 5e-3 },
            optim: OptimCfg::new(OptimKind::AdamW),
        },
        rt.manifest(),
    )
    .unwrap();
    let k = hift.k() as u64;
    let rec = trainer::train(&mut rt, &mut hift, &mut params, &mut *task, TrainCfg {
        steps: 6 * k,
        eval_every: 0,
        log_every: 0,
    })
    .unwrap();
    let first = rec.losses.values[..k as usize].iter().sum::<f64>() / k as f64;
    let last = rec.losses.tail_mean(k as usize);
    assert!(last < first, "loss must fall: {first:.3} -> {last:.3}");
    // Paging: AdamW state for the active group only; inflight < total state.
    let (h2d, d2h, inflight, peak) = rec.paging.unwrap();
    assert!(h2d > 0 && d2h > 0);
    assert!(inflight > 0);
    let total_state = rec.optimizer_state_bytes as u64;
    assert!(peak < total_state, "peak device state {peak} must be < total {total_state}");
    // Peak trainable ≪ all params (the headline claim, tiny-scale).
    assert!(rec.peak_trainable_params < params.total_params());
}

#[test]
fn hift_sgd_has_zero_state_paging() {
    // §4.3: "When using SGD, the peak communication parameter is zero."
    let mut rt = runtime();
    let mut params = rt.load_params("base").unwrap();
    let mut task = build_task("motif2", geom(&rt), 5).unwrap();
    let mut hift = Hift::new(
        HiftCfg {
            m: 1,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr: 1e-2 },
            optim: OptimCfg::new(OptimKind::Sgd),
        },
        rt.manifest(),
    )
    .unwrap();
    let rec = trainer::train(&mut rt, &mut hift, &mut params, &mut *task,
        TrainCfg { steps: 8, eval_every: 0, log_every: 0 }).unwrap();
    let (h2d, _, inflight, peak) = rec.paging.unwrap();
    assert_eq!(h2d, 0, "SGD pages nothing");
    assert_eq!(inflight, 0);
    assert_eq!(peak, 0);
}

#[test]
fn fpft_baseline_trains() {
    let mut rt = runtime();
    let mut params = rt.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&rt), 11).unwrap();
    let mut fpft = SubsetTune::fpft(
        rt.manifest(),
        OptimCfg::new(OptimKind::AdamW),
        LrSchedule::Const { lr: 5e-3 },
    )
    .unwrap();
    let rec = trainer::train(&mut rt, &mut fpft, &mut params, &mut *task,
        TrainCfg { steps: 24, eval_every: 0, log_every: 0 }).unwrap();
    assert!(rec.losses.tail_mean(6) < rec.losses.values[0]);
    assert_eq!(rec.peak_trainable_params, params.total_params(), "FPFT trains everything");
}

#[test]
fn every_strategy_builds_and_steps() {
    let mut rt = runtime();
    let mut task = build_task("motif2", geom(&rt), 2).unwrap();
    for name in hift::strategies::STRATEGY_NAMES {
        let spec = StrategySpec::new(name, OptimKind::AdamW, 1e-3, 10);
        let mut strat = spec.build(rt.manifest()).unwrap();
        let mut params = rt.load_params(strat.variant()).unwrap();
        let before = params.l2_norm();
        let batch = task.train_batch();
        let stats = strat.step(&mut rt, &mut params, &batch).unwrap();
        assert!(stats.loss.is_finite(), "{name} loss finite");
        assert!(stats.trainable_params > 0, "{name} trains something");
        assert!(params.tensors.iter().all(|t| t.is_finite()), "{name} params finite");
        assert_ne!(params.l2_norm(), before, "{name} changed parameters");
    }
}

#[test]
fn peft_trains_fewer_params_than_hift_peak() {
    // Sanity on the Table-5 axis: adapter sets ≪ one HiFT group ≪ full.
    let mut rt = runtime();
    let mut task = build_task("motif2", geom(&rt), 2).unwrap();
    let batch = task.train_batch();
    let mut sizes = std::collections::HashMap::new();
    for name in ["lora", "ia3", "hift", "fpft"] {
        let spec = StrategySpec::new(name, OptimKind::AdamW, 1e-3, 10);
        let mut strat = spec.build(rt.manifest()).unwrap();
        let mut params = rt.load_params(strat.variant()).unwrap();
        strat.step(&mut rt, &mut params, &batch).unwrap();
        sizes.insert(name, strat.peak_trainable_params());
    }
    assert!(sizes["lora"] < sizes["hift"]);
    assert!(sizes["ia3"] < sizes["hift"]);
    assert!(sizes["hift"] < sizes["fpft"]);
}

#[test]
fn evaluation_accuracy_is_in_unit_interval() {
    let mut rt = runtime();
    let params = rt.load_params("base").unwrap();
    let task = build_task("motif4", geom(&rt), 7).unwrap();
    let ev = trainer::evaluate(&mut rt, "fwd_base", &params, task.eval_batches()).unwrap();
    assert!((0.0..=1.0).contains(&ev.acc));
    assert!(ev.loss.is_finite());
}
