//! Kernel-layer acceptance (ISSUE 6):
//!
//! * blocked and SIMD GEMM are **bit-identical** to the naive reference in
//!   f32 across randomized ragged shapes (edge tiles, reduction depths not
//!   divisible by the panel size, nonzero accumulation into C) — the
//!   reduction-order guarantee;
//! * the fused streaming-softmax attention path produces bit-identical
//!   losses *and* gradients to the materialized-probs path, forward and
//!   backward, in f32 **and** under bf16/f16 (the fused path replays the
//!   exact quantize points, so it exceeds the drift-band requirement with
//!   exact equality);
//! * the fused path's measured `peak_act_resident_bytes` saving equals the
//!   analytic `L·B·H·T²` probs term exactly under `ActCkpt::None`;
//! * the shared thread budget is observable and never over-grants.

use std::sync::Mutex;

use hift::backend::kernels::{self, KernelKind};
use hift::backend::par::ThreadBudget;
use hift::backend::{ActCkpt, Batch, ExecBackend, NativeBackend, Precision};
use hift::memmodel::native_probs_bytes;
use hift::proptest::{prop_assert, run_seeded};
use hift::rng::Pcg32;

/// Serializes tests that flip the process-global kernel kind.  Tests using
/// the explicit `*_with(kind, ...)` entry points don't need it, and other
/// test *files* run as separate processes on the default kind.
static KIND_LOCK: Mutex<()> = Mutex::new(());

fn kind_lock() -> std::sync::MutexGuard<'static, ()> {
    // A poisoned lock just means another kind test's assert fired; the
    // guarded state (the global kind) is reset at the top of every section.
    KIND_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn filled(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
}

fn small_batch(vocab: usize, b: usize, s: usize, seed: u64) -> Batch {
    let mut rng = Pcg32::seeded(seed);
    let mut batch = Batch::new(b, s);
    for t in batch.tokens.iter_mut() {
        *t = rng.below(vocab) as i32;
    }
    for t in batch.targets.iter_mut() {
        *t = rng.below(vocab) as i32;
    }
    for w in batch.weights.iter_mut() {
        *w = 1.0;
    }
    batch
}

#[test]
fn prop_gemm_kinds_bit_identical_on_ragged_shapes() {
    // Randomized shapes deliberately straddling the tile boundaries
    // (NC=128, MR=8, KC=64): every kind must produce the same bits for all
    // three GEMM forms, including accumulation into a nonzero C.
    run_seeded(0x6E41, 40, |g| {
        let m = g.usize_in(1, 40);
        let k = g.usize_in(1, 150);
        let n = g.usize_in(1, 300);
        let mut rng = Pcg32::seeded((m * 1_000_003 + k * 1009 + n) as u64);
        let a = filled(&mut rng, m * k); // shared [M,K] operand
        let kinds = [KernelKind::Naive, KernelKind::Blocked, KernelKind::Simd];
        // (form, b operand, c length): nn is a@b, at is aᵀ@b (dW = Xᵀ dY),
        // bt is a@bᵀ (dX = dY Wᵀ) — each with its own operand shapes.
        let b_nn = filled(&mut rng, k * n);
        let b_at = filled(&mut rng, m * n);
        let b_bt = filled(&mut rng, n * k);
        let forms: [(&str, &[f32], usize); 3] =
            [("nn", &b_nn, m * n), ("at", &b_at, k * n), ("bt", &b_bt, m * n)];
        for (form, bb, clen) in forms {
            let c0 = filled(&mut rng, clen); // nonzero accumulator
            let mut refbits: Option<Vec<u32>> = None;
            for kind in kinds {
                let mut c = c0.clone();
                match form {
                    "nn" => kernels::matmul_with(kind, &a, bb, &mut c, m, k, n),
                    "at" => kernels::matmul_at_with(kind, &a, bb, &mut c, m, k, n),
                    _ => kernels::matmul_bt_with(kind, &a, bb, &mut c, m, k, n),
                }
                let bits: Vec<u32> = c.iter().map(|x| x.to_bits()).collect();
                match &refbits {
                    None => refbits = Some(bits),
                    Some(r) => {
                        prop_assert(
                            r == &bits,
                            format!(
                                "{form} {m}x{k}x{n}: {} diverges bitwise from naive",
                                kind.name()
                            ),
                        )?;
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fused_attention_matches_naive_bitwise_f32() {
    // End-to-end through the model: the fused streaming-softmax path
    // (forward + backward row recompute) vs the materialized [T,T] probs
    // cache, at randomized ragged sequence lengths.  Losses and every
    // gradient must agree to the bit.
    let _g = kind_lock();
    let cfg = NativeBackend::preset("tiny", 3).unwrap().manifest().config.clone();
    run_seeded(0xA77E, 8, |g| {
        let s = g.usize_in(2, cfg.seq_len);
        let b = g.usize_in(1, 2);
        // run_seeded takes Fn, so each case builds its own backend (tiny —
        // cheap) instead of mutably sharing one across cases.
        let mut be = NativeBackend::preset("tiny", 3).unwrap();
        let mut params = be.load_params("base").unwrap();
        let batch = small_batch(cfg.vocab, b, s, (s * 31 + b) as u64);
        kernels::set_kind(KernelKind::Naive);
        let naive = be.run("grad_base_full", &mut params, &batch).unwrap();
        kernels::set_kind(KernelKind::Blocked);
        let fused = be.run("grad_base_full", &mut params, &batch).unwrap();
        prop_assert(
            naive.loss == fused.loss,
            format!("s={s} b={b}: loss {} != fused {}", naive.loss, fused.loss),
        )?;
        for (i, (gn, gf)) in naive.grads.iter().zip(&fused.grads).enumerate() {
            prop_assert(
                gn.data == gf.data,
                format!("s={s} b={b}: grad {i} differs between naive and fused"),
            )?;
        }
        Ok(())
    });
    kernels::set_kind(KernelKind::default());
}

#[test]
fn fused_attention_is_bit_identical_under_half_precision() {
    // The fused path quantizes each prob row at exactly the same point the
    // materialized path quantizes the cached matrix, so even bf16/f16 runs
    // are bit-identical between kinds — stronger than the drift band the
    // acceptance criteria ask for.
    let _g = kind_lock();
    for prec in [Precision::Bf16, Precision::F16] {
        let mut be = NativeBackend::preset("tiny", 5).unwrap();
        be.set_precision(prec).unwrap();
        let cfg = be.manifest().config.clone();
        let mut params = be.load_params("base").unwrap();
        let batch = small_batch(cfg.vocab, 2, cfg.seq_len, 11);
        kernels::set_kind(KernelKind::Naive);
        let naive = be.run("grad_base_full", &mut params, &batch).unwrap();
        kernels::set_kind(KernelKind::Blocked);
        let fused = be.run("grad_base_full", &mut params, &batch).unwrap();
        assert_eq!(naive.loss, fused.loss, "{}: loss drifted", prec.name());
        for (gn, gf) in naive.grads.iter().zip(&fused.grads) {
            assert_eq!(gn.data, gf.data, "{}: gradient drifted", prec.name());
        }
    }
    kernels::set_kind(KernelKind::default());
}

#[test]
fn simd_kind_matches_blocked_end_to_end_or_is_rejected() {
    let _g = kind_lock();
    let mut be = NativeBackend::preset("tiny", 7).unwrap();
    if !kernels::simd_available() {
        // Without the cargo feature, selecting simd must fail loudly
        // instead of silently falling back.
        assert!(be.set_kernels(KernelKind::Simd).is_err());
        kernels::set_kind(KernelKind::default());
        return;
    }
    let cfg = be.manifest().config.clone();
    let mut params = be.load_params("base").unwrap();
    let batch = small_batch(cfg.vocab, 2, cfg.seq_len, 13);
    kernels::set_kind(KernelKind::Blocked);
    let blocked = be.run("grad_base_full", &mut params, &batch).unwrap();
    kernels::set_kind(KernelKind::Simd);
    let simd = be.run("grad_base_full", &mut params, &batch).unwrap();
    assert_eq!(blocked.loss, simd.loss, "simd loss differs from blocked");
    for (gb, gs) in blocked.grads.iter().zip(&simd.grads) {
        assert_eq!(gb.data, gs.data, "simd gradient differs from blocked");
    }
    kernels::set_kind(KernelKind::default());
}

#[test]
fn fused_attention_saving_equals_the_probs_term_exactly() {
    // Under ActCkpt::None the forward caches every layer's internals and
    // backward adds no recompute scratch, so the only byte difference
    // between kernel kinds is the [B*H, T*T] probs cache — the measured
    // peak delta must equal the analytic term to the byte.
    let _g = kind_lock();
    let cfg = NativeBackend::preset("tiny", 9).unwrap().manifest().config.clone();
    let (b, s) = (2usize, cfg.seq_len);
    let batch = small_batch(cfg.vocab, b, s, 17);
    let mut peaks = Vec::new();
    for kind in [KernelKind::Naive, KernelKind::Blocked] {
        // A fresh backend per kind keeps the peaks independent.
        let mut be = NativeBackend::preset("tiny", 9).unwrap();
        be.set_act_ckpt(ActCkpt::None).unwrap();
        kernels::set_kind(kind);
        let mut params = be.load_params("base").unwrap();
        be.reset_run_peaks();
        let _ = be.run("grad_base_full", &mut params, &batch).unwrap();
        peaks.push(be.stats().peak_act_resident_bytes);
    }
    kernels::set_kind(KernelKind::default());
    let expected = native_probs_bytes(cfg.n_layers, b, cfg.n_heads, s, Precision::F32);
    assert!(peaks[0] > peaks[1], "fused path must retain fewer bytes: {peaks:?}");
    assert_eq!(
        peaks[0] - peaks[1],
        expected,
        "measured saving must equal the analytic L*B*H*T^2 term ({peaks:?})"
    );
}

#[test]
fn kernel_counters_flow_into_runtime_stats() {
    let _g = kind_lock();
    kernels::set_kind(KernelKind::default());
    let mut be = NativeBackend::preset("tiny", 21).unwrap();
    let cfg = be.manifest().config.clone();
    let mut params = be.load_params("base").unwrap();
    let batch = small_batch(cfg.vocab, 1, cfg.seq_len, 19);
    let _ = be.run("grad_base_full", &mut params, &batch).unwrap();
    let st = be.stats();
    assert!(st.kernel_flops > 0, "a grad run must execute kernel flops");
    assert!(st.kernel_nanos > 0, "kernel time must be measured");
    assert!(st.kernel_gflops() > 0.0);
}

#[test]
fn thread_budget_is_observable_and_never_over_grants() {
    // The process budget is shared with other tests in this binary, so
    // only invariants (not exact values) are asserted on the global; the
    // mechanics are pinned on a local instance.
    assert!(hift::backend::par::max_threads() >= 1);
    let local = ThreadBudget::new(3);
    let l1 = local.lease(8);
    let l2 = local.lease(8);
    assert!(l1.granted() + l2.granted() <= 1 + local.cap(), "over-granted");
    assert!(l1.granted() >= 1 && l2.granted() >= 1, "caller thread always runs");
    drop(l1);
    drop(l2);
    assert_eq!(local.in_flight(), 0, "leases must release on drop");
}

#[test]
fn manifest_records_explicit_kernel_choice() {
    let _g = kind_lock();
    let mut be = NativeBackend::preset("tiny", 1).unwrap();
    assert_eq!(be.manifest().kernels, "native", "default stays unchanged");
    be.set_kernels(KernelKind::Naive).unwrap();
    assert_eq!(be.manifest().kernels, "native+naive");
    be.set_kernels(KernelKind::Blocked).unwrap();
    assert_eq!(be.manifest().kernels, "native+blocked");
    kernels::set_kind(KernelKind::default());
}
