//! Data-parallel sharded execution (ISSUE 7 acceptance):
//!
//! * N-worker runs are **bit-identical** to the serial walk — loss,
//!   ncorrect, and every streamed gradient — on every native preset and
//!   every model variant (base units, full FPFT, LoRA, IA3, prefix);
//! * identity holds under bf16/f16 compute with an active loss scale
//!   (the quantize/descale seam sits after the reducer, exactly where
//!   the serial path has it);
//! * whole training runs land on bit-identical parameters, loss curves
//!   and final evals, and the `RunRecord` surfaces the worker count;
//! * measured kernel flop totals are exactly equal between serial and
//!   sharded runs (the counters are process-global atomics — concurrent
//!   worker walks must not lose increments);
//! * a batch smaller than N degrades to fewer active shards — B=1 with
//!   N=4 is still bit-identical, and `trainer::evaluate` agrees exactly;
//! * `peak_grad_resident_bytes` stays at max-single-tensor under N>1
//!   (reduce-then-emit: never N live copies of a gradient);
//! * `--workers` and `--offload` are mutually exclusive in both orders,
//!   and staged prefetch page-ins post once per group transition, never
//!   once per worker;
//! * the shard helpers (`split_rows`, `batch_denom`, `tree_fold`) hold
//!   their documented contracts;
//! * the task forge's stream statistics (ISSUE 9) are bit-identical
//!   across worker counts — the batch stream and its dedup/diversity
//!   accounting live above the sharding seam.

use hift::backend::shard::{batch_denom, split_rows, tree_fold, tree_fold_stats};
use hift::backend::{
    par, unit_artifact, Batch, Compression, ExecBackend, GradSink, NativeBackend, OffloadCfg,
    Precision, PRESET_NAMES,
};
use hift::coordinator::lr::LrSchedule;
use hift::coordinator::scheduler::{HiftScheduler, SchedulerCfg};
use hift::coordinator::strategy::UpdateStrategy;
use hift::coordinator::trainer::{self, TrainCfg};
use hift::data::{build_task, TaskGeom};
use hift::optim::{OptimCfg, OptimKind};
use hift::rng::Pcg32;
use hift::strategies::{FineTuneStrategy, Hift, HiftCfg};
use hift::tensor::{Tensor, TensorSet};

fn backend() -> NativeBackend {
    NativeBackend::preset("tiny", 0).expect("tiny preset")
}

fn geom(be: &dyn ExecBackend) -> TaskGeom {
    let c = &be.manifest().config;
    TaskGeom::new(c.vocab, c.batch, c.seq_len)
}

/// A sink that records `(slot, name, grad)` without applying anything.
#[derive(Default)]
struct Recorder {
    grads: Vec<(usize, String, Tensor)>,
}

impl GradSink for Recorder {
    fn grad(
        &mut self,
        slot: usize,
        name: &str,
        grad: Tensor,
        _params: &mut TensorSet,
    ) -> anyhow::Result<()> {
        self.grads.push((slot, name.to_string(), grad));
        Ok(())
    }

    fn resident_bytes(&self) -> u64 {
        self.grads.iter().map(|(_, _, g)| g.bytes() as u64).sum()
    }
}

/// A `b`-row batch with a non-uniform loss mask (0 / 0.5 / 1.0 weights),
/// so the weighted-mean denominator path is actually exercised.
fn rows_batch(vocab: usize, b: usize, s: usize, seed: u64) -> Batch {
    let mut rng = Pcg32::seeded(seed);
    let mut bt = Batch::new(b, s);
    for t in bt.tokens.iter_mut() {
        *t = rng.below(vocab) as i32;
    }
    for t in bt.targets.iter_mut() {
        *t = rng.below(vocab) as i32;
    }
    for (i, w) in bt.weights.iter_mut().enumerate() {
        *w = match i % 4 {
            0 => 0.0,
            1 => 0.5,
            _ => 1.0,
        };
    }
    bt
}

/// Run `artifact` streamed at the given worker count and record every
/// gradient; workers are reset to 1 before returning.
fn run_recorded(
    be: &mut NativeBackend,
    artifact: &str,
    params: &mut TensorSet,
    batch: &Batch,
    workers: usize,
) -> (f32, f32, Vec<(usize, String, Tensor)>) {
    be.set_workers(workers).unwrap();
    let mut rec = Recorder::default();
    let out = be.run_streamed(artifact, params, batch, &mut rec).unwrap();
    be.set_workers(1).unwrap();
    (out.loss, out.ncorrect, rec.grads)
}

fn assert_same_grads(
    what: &str,
    serial: &[(usize, String, Tensor)],
    sharded: &[(usize, String, Tensor)],
) {
    assert_eq!(serial.len(), sharded.len(), "{what}: grad count");
    for ((s_slot, s_name, s_g), (n_slot, n_name, n_g)) in serial.iter().zip(sharded) {
        assert_eq!(s_slot, n_slot, "{what}: emission order");
        assert_eq!(s_name, n_name, "{what}: emission order");
        assert_eq!(s_g.shape, n_g.shape, "{what}/{s_name}: shape");
        assert_eq!(s_g.data, n_g.data, "{what}: {s_name} must be bit-identical");
    }
}

#[test]
fn sharded_equals_serial_on_all_presets_and_variants() {
    for preset in PRESET_NAMES {
        let mut be = NativeBackend::preset(preset, 1).unwrap();
        let cfg = be.manifest().config.clone();
        let n_units = be.manifest().n_units;
        let small = matches!(*preset, "tiny" | "small");
        // Every variant's artifact on the small presets; one mid-stack
        // unit on the big ones keeps debug-build runtime tractable.
        let cases: Vec<(&str, String)> = if small {
            vec![
                ("base", "grad_base_full".to_string()),
                ("base", unit_artifact(0)),
                ("base", unit_artifact(n_units - 1)),
                ("lora", "grad_lora_adapter".to_string()),
                ("ia3", "grad_ia3_adapter".to_string()),
                ("prefix", "grad_prefix_adapter".to_string()),
            ]
        } else {
            vec![("base", unit_artifact(1))]
        };
        let b = if small { 4 } else { 2 };
        let worker_counts: &[usize] = if small { &[2, 3, 4] } else { &[2] };
        let batch = rows_batch(cfg.vocab, b, cfg.seq_len.min(4), 31);
        for (variant, art) in &cases {
            let mut params = be.load_params(variant).unwrap();
            let (loss1, nc1, grads1) = run_recorded(&mut be, art, &mut params, &batch, 1);
            for &n in worker_counts {
                let (loss_n, nc_n, grads_n) =
                    run_recorded(&mut be, art, &mut params, &batch, n);
                assert_eq!(loss1, loss_n, "{preset}/{art}/workers={n}: loss");
                assert_eq!(nc1, nc_n, "{preset}/{art}/workers={n}: ncorrect");
                assert_same_grads(&format!("{preset}/{art}/workers={n}"), &grads1, &grads_n);
            }
        }
    }
}

#[test]
fn sharded_is_bit_identical_under_half_precision() {
    for (prec, scale) in [(Precision::Bf16, 1.0f32), (Precision::F16, 1024.0)] {
        let mut be = backend();
        let cfg = be.manifest().config.clone();
        be.set_precision(prec).unwrap();
        be.set_loss_scale(scale);
        let batch = rows_batch(cfg.vocab, 4, cfg.seq_len.min(4), 47);
        let mut params = be.load_params("base").unwrap();
        let (loss1, nc1, grads1) =
            run_recorded(&mut be, "grad_base_full", &mut params, &batch, 1);
        for n in [2usize, 4] {
            let (loss_n, nc_n, grads_n) =
                run_recorded(&mut be, "grad_base_full", &mut params, &batch, n);
            assert_eq!(loss1, loss_n, "{}/workers={n}: loss", prec.name());
            assert_eq!(nc1, nc_n, "{}/workers={n}: ncorrect", prec.name());
            assert_same_grads(&format!("{}/workers={n}", prec.name()), &grads1, &grads_n);
        }
    }
}

fn train_tiny_hift(workers: usize, steps: u64) -> (trainer::RunRecord, TensorSet) {
    let mut be = backend();
    be.set_workers(workers).unwrap();
    let manifest = be.manifest().clone();
    let mut hift = Hift::pipelined(
        HiftCfg {
            m: 2,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr: 3e-3 },
            optim: OptimCfg::new(OptimKind::AdamW),
        },
        &manifest,
        false,
    )
    .unwrap();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("markovlm", geom(&be), 13).unwrap();
    let rec = trainer::train(
        &mut be,
        &mut hift,
        &mut params,
        task.as_mut(),
        TrainCfg { steps, eval_every: 0, log_every: 0 },
    )
    .unwrap();
    (rec, params)
}

#[test]
fn sharded_training_lands_on_identical_params() {
    let steps = 8u64;
    let (rec1, p1) = train_tiny_hift(1, steps);
    let (rec2, p2) = train_tiny_hift(2, steps);
    assert_eq!(rec1.losses.values, rec2.losses.values, "loss curves must be bit-identical");
    assert_eq!(rec1.final_eval, rec2.final_eval, "final eval must be bit-identical");
    for ((name, a), b) in p2.names.iter().zip(&p2.tensors).zip(&p1.tensors) {
        assert_eq!(a.data, b.data, "{name}: sharded training must equal serial");
    }
    assert_eq!(rec1.workers, 1);
    assert_eq!(rec2.workers, 2, "RunRecord must surface the worker count");
    let json = hift::ser::emit_pretty(&rec2.to_json());
    assert!(json.contains("workers"), "RunRecord JSON must surface workers");
}

#[test]
fn forge_stream_stats_are_identical_across_worker_counts() {
    let steps = 6u64;
    let (rec1, _) = train_tiny_hift(1, steps);
    let (rec2, _) = train_tiny_hift(2, steps);
    assert_eq!(
        rec1.diversity, rec2.diversity,
        "dedup/diversity accounting must not depend on the worker count"
    );
    let d = rec1.diversity.expect("forge-built tasks record stream stats");
    assert_eq!(d.batches_emitted, steps, "one emitted batch per step");
    assert!(d.ngrams_total > 0);
}

#[test]
fn kernel_flop_totals_match_serial_exactly() {
    let mut be = backend();
    let cfg = be.manifest().config.clone();
    let batch = rows_batch(cfg.vocab, 4, cfg.seq_len.min(4), 59);
    let mut params = be.load_params("base").unwrap();
    let mut deltas = Vec::new();
    for n in [1usize, 2, 4] {
        be.set_workers(n).unwrap();
        let f0 = be.stats().kernel_flops;
        let t0 = be.stats().kernel_nanos;
        let mut rec = Recorder::default();
        be.run_streamed("grad_base_full", &mut params, &batch, &mut rec).unwrap();
        assert!(be.stats().kernel_nanos > t0, "workers={n}: kernel span time must accrue");
        deltas.push(be.stats().kernel_flops - f0);
    }
    be.set_workers(1).unwrap();
    assert!(deltas[0] > 0, "the serial walk must count kernel flops");
    assert_eq!(
        deltas[0], deltas[1],
        "workers=2: measured flop total must equal serial exactly (same math, \
         different schedule; concurrent notes must not be lost)"
    );
    assert_eq!(deltas[0], deltas[2], "workers=4: measured flop total must equal serial");
}

#[test]
fn small_batch_degrades_to_fewer_shards() {
    // B=1 under N=4: one active shard, three idle workers, identical bits.
    let mut be = backend();
    let cfg = be.manifest().config.clone();
    let batch = rows_batch(cfg.vocab, 1, cfg.seq_len.min(8), 67);
    let mut params = be.load_params("base").unwrap();
    let (loss1, nc1, grads1) = run_recorded(&mut be, "grad_base_full", &mut params, &batch, 1);
    assert!(loss1.is_finite(), "B=1 serial loss must be finite");
    let (loss4, nc4, grads4) = run_recorded(&mut be, "grad_base_full", &mut params, &batch, 4);
    assert_eq!(loss1, loss4, "B=1, N=4: loss");
    assert_eq!(nc1, nc4, "B=1, N=4: ncorrect");
    assert_same_grads("B=1, N=4", &grads1, &grads4);

    // B=3 under N=4: three active shards of one row each.
    let batch3 = rows_batch(cfg.vocab, 3, cfg.seq_len.min(8), 71);
    let (l1, n1, g1) = run_recorded(&mut be, "grad_base_full", &mut params, &batch3, 1);
    let (l4, n4, g4) = run_recorded(&mut be, "grad_base_full", &mut params, &batch3, 4);
    assert_eq!(l1, l4, "B=3, N=4: loss");
    assert_eq!(n1, n4, "B=3, N=4: ncorrect");
    assert_same_grads("B=3, N=4", &g1, &g4);

    // trainer::evaluate over single-row batches agrees exactly too.
    let evals: Vec<Batch> =
        (0..3).map(|i| rows_batch(cfg.vocab, 1, cfg.seq_len.min(8), 80 + i)).collect();
    let e1 = trainer::evaluate(&mut be, "fwd_base", &mut params, &evals).unwrap();
    be.set_workers(4).unwrap();
    let e4 = trainer::evaluate(&mut be, "fwd_base", &mut params, &evals).unwrap();
    be.set_workers(1).unwrap();
    assert_eq!(e1, e4, "evaluate must be bit-identical under workers=4");
}

#[test]
fn peak_grad_residency_is_unchanged_under_workers() {
    let ocfg = OptimCfg::new(OptimKind::AdamW);
    let mut peaks = Vec::new();
    for workers in [1usize, 2] {
        let mut be = backend();
        be.set_workers(workers).unwrap();
        let manifest = be.manifest().clone();
        let vinfo = manifest.variant("base").unwrap();
        let max_tensor_bytes = vinfo.params.iter().map(|p| p.size * 4).max().unwrap() as u64;
        let mut hift = Hift::pipelined(
            HiftCfg {
                m: 2,
                order: UpdateStrategy::Bottom2Up,
                schedule: LrSchedule::Const { lr: 1e-3 },
                optim: ocfg,
            },
            &manifest,
            false,
        )
        .unwrap();
        let mut params = be.load_params("base").unwrap();
        let mut task = build_task("motif4", geom(&be), 3).unwrap();
        for _ in 0..manifest.n_units {
            let b = task.train_batch();
            hift.step(&mut be, &mut params, &b).unwrap();
        }
        assert_eq!(
            be.stats().peak_grad_resident_bytes,
            max_tensor_bytes,
            "workers={workers}: the emit seam sees one folded tensor at a time"
        );
        peaks.push(be.stats().peak_grad_resident_bytes);
    }
    assert_eq!(peaks[0], peaks[1], "grad residency must not grow with N");
}

#[test]
fn worker_threads_release_the_shared_budget() {
    let mut be = backend();
    let cfg = be.manifest().config.clone();
    let batch = rows_batch(cfg.vocab, 4, cfg.seq_len.min(4), 91);
    let mut params = be.load_params("base").unwrap();
    let _ = run_recorded(&mut be, "grad_base_full", &mut params, &batch, 4);
    // The budget is process-global and other tests in this binary may hold
    // transient leases concurrently, so this is a leak detector, not an
    // instantaneous probe: a leaked worker slot would pin the counter > 0
    // forever, while honest contention drains within the polling window.
    let mut in_flight = par::budget_in_flight();
    for _ in 0..2000 {
        if in_flight == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
        in_flight = par::budget_in_flight();
    }
    assert_eq!(in_flight, 0, "worker slots/leases must be released after the step");
}

#[test]
fn offload_and_workers_are_mutually_exclusive_in_both_orders() {
    let paged = OffloadCfg { enabled: true, compress: Compression::Lossless, prefetch: true };

    // workers first, then offload.
    let mut be = backend();
    be.set_workers(2).unwrap();
    let err = be.set_offload(paged).unwrap_err();
    assert!(err.to_string().contains("workers"), "{err}");
    // Dropping back to serial unblocks the pager.
    be.set_workers(1).unwrap();
    be.set_offload(paged).unwrap();

    // offload first, then workers.  (A fresh backend inherits `HIFT_WORKERS`,
    // so force the serial walk before engaging the pager.)
    let mut be2 = backend();
    be2.set_workers(1).unwrap();
    be2.set_offload(paged).unwrap();
    let err = be2.set_workers(2).unwrap_err();
    assert!(err.to_string().contains("offload"), "{err}");
    assert_eq!(be2.workers(), 1, "a rejected setting must not stick");
    // workers=1 (the serial walk) stays legal under the pager.
    be2.set_workers(1).unwrap();

    // workers=0 is never a topology.
    let err = backend().set_workers(0).unwrap_err();
    assert!(err.to_string().contains(">= 1"), "{err}");
}

#[test]
fn peek_next_is_idempotent_and_staged_page_ins_post_once() {
    // peek_next commits nothing: repeated peeks agree with each other and
    // with the units `next` then pops — across whole sweeps, including the
    // short final group when m ∤ n.
    let mut s = HiftScheduler::new(
        SchedulerCfg {
            m: 2,
            strategy: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr: 1e-3 },
        },
        5,
    );
    for step in 0..3 * s.k() {
        let peek_a = s.peek_next();
        let peek_b = s.peek_next();
        assert_eq!(peek_a, peek_b, "step {step}: peek must not advance the queue");
        let planned = s.next();
        assert_eq!(peek_a, planned.units, "step {step}: peek must match next");
    }

    // The staging hint drives the pager's double buffer exactly once per
    // group transition.  Worker topologies can't multiply the posts: the
    // pager only runs under the serial walk (workers=1 — the combination
    // with workers>1 is rejected at configure time), so two identical
    // paged runs must report identical page-in counts.
    let paged = OffloadCfg { enabled: true, compress: Compression::Lossless, prefetch: true };
    let run_paged = || -> (u64, trainer::RunRecord) {
        let mut be = backend();
        be.set_workers(1).unwrap();
        be.set_offload(paged).unwrap();
        let manifest = be.manifest().clone();
        let mut hift = Hift::pipelined(
            HiftCfg {
                m: 1,
                order: UpdateStrategy::Bottom2Up,
                schedule: LrSchedule::Const { lr: 2e-3 },
                optim: OptimCfg::new(OptimKind::AdamW),
            },
            &manifest,
            false,
        )
        .unwrap();
        let mut params = be.load_params("base").unwrap();
        let mut task = build_task("motif4", geom(&be), 27).unwrap();
        let rec = trainer::train(
            &mut be,
            &mut hift,
            &mut params,
            task.as_mut(),
            TrainCfg { steps: 8, eval_every: 0, log_every: 0 },
        )
        .unwrap();
        (be.stats().offload_page_ins, rec)
    };
    let (ins_a, rec_a) = run_paged();
    let (ins_b, rec_b) = run_paged();
    assert!(ins_a > 0, "the paged run must page groups in");
    assert_eq!(ins_a, ins_b, "staged page-ins must post once per transition, deterministically");
    assert_eq!(rec_a.losses.values, rec_b.losses.values);
}

#[test]
fn split_rows_contract() {
    // Degenerate: fewer rows than workers ⇒ fewer active shards.
    assert_eq!(split_rows(1, 4), vec![0..1]);
    assert_eq!(split_rows(3, 4), vec![0..1, 1..2, 2..3]);
    // Balanced with extras first.
    assert_eq!(split_rows(8, 3), vec![0..3, 3..6, 6..8]);
    assert_eq!(split_rows(4, 2), vec![0..2, 2..4]);
    // Serial and clamp edges.
    assert_eq!(split_rows(5, 1), vec![0..5]);
    assert_eq!(split_rows(4, 0), vec![0..4], "workers clamp up to 1");
    // Exhaustive cover: disjoint, ordered, total.
    for b in 1..12usize {
        for w in 1..6usize {
            let ranges = split_rows(b, w);
            assert_eq!(ranges.len(), w.min(b), "b={b} w={w}: active shard count");
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next, "b={b} w={w}: contiguous cover");
                assert!(r.end > r.start, "b={b} w={w}: no empty shard");
                next = r.end;
            }
            assert_eq!(next, b, "b={b} w={w}: every row assigned");
            let sizes: Vec<usize> = ranges.iter().map(|r| r.end - r.start).collect();
            assert!(
                sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1,
                "b={b} w={w}: balanced split, extras first: {sizes:?}"
            );
        }
    }
}

#[test]
fn tree_fold_and_batch_denom_contracts() {
    // A single partial passes through untouched.
    assert_eq!(tree_fold(vec![vec![1.5f32, -2.0]]), vec![1.5, -2.0]);
    // The fold is the fixed balanced pairwise tree: ((a+b)+(c+d)), odd
    // tails pass through a round — NOT a left fold.
    let parts: Vec<Vec<f32>> = vec![vec![0.1f32], vec![0.2], vec![0.3], vec![0.4], vec![0.5]];
    let want = (((0.1f32 + 0.2) + (0.3 + 0.4)) + 0.5).to_bits();
    assert_eq!(tree_fold(parts)[0].to_bits(), want, "fold shape must be the balanced tree");
    // Same tree for the f64 stats lanes.
    let stats = tree_fold_stats(vec![[1.0, 2.0, 0.0], [3.0, 4.0, 1.0], [5.0, 6.0, 1.0]]);
    assert_eq!(stats, [(1.0 + 3.0) + 5.0, (2.0 + 4.0) + 6.0, 2.0]);

    // batch_denom is the forward walk's weight sum, bit-for-bit.
    let batch = rows_batch(64, 4, 8, 101);
    let denom = batch_denom(&batch);
    assert!(denom > 0.0, "masked batch still has supervised positions");
    let per_row: Vec<[f64; 3]> = (0..batch.b)
        .map(|r| {
            let w: f64 = batch.weights[r * batch.s..(r + 1) * batch.s]
                .iter()
                .map(|&x| f64::from(x))
                .sum();
            [0.0, w, 0.0]
        })
        .collect();
    assert_eq!(denom, tree_fold_stats(per_row)[1], "denom folds per-row sums with the tree");
}
