//! Row-by-row validation of the analytic memory model against the paper's
//! published Tables 8–12 (#Para / #Gra / #Sta / #PGS are exact accounting;
//! Residual/Total are modelled and checked in band).
//!
//! Paper numbers are MiB for #Para/#Gra/#Sta and GiB for #PGS — the tables
//! label them "MB"/"GB" but the arithmetic (124.65M × 4B = 475.49) only
//! works in binary units.

use hift::memmodel::{account, by_name, Dtype, Method, Workload, GIB, MIB};
use hift::optim::OptimKind;

struct Row {
    model: &'static str,
    batch: usize,
    opt: OptimKind,
    dtype: Dtype,
    hift: bool,
    para_mib: f64,
    gra_mib: f64,
    sta_mib: f64,
    pgs_gib: f64,
    residual_gib: f64,
}

fn check(r: &Row) {
    let a = by_name(r.model).unwrap();
    let method = if r.hift { Method::Hift { m: 1 } } else { Method::Fpft };
    let w = Workload { batch: r.batch, seq: 512 };
    let got = account(&a, r.opt, r.dtype, method, w);
    let name = format!("{} {:?} {:?} hift={}", r.model, r.opt, r.dtype, r.hift);
    // Exact accounting: 1.5% tolerance (architecture minutiae like
    // token-type embeddings / tied biases).
    let tol = |x: f64| (x * 0.015).max(2.0);
    assert!(
        (got.para / MIB - r.para_mib).abs() < tol(r.para_mib),
        "{name}: #Para {:.2} vs paper {:.2}",
        got.para / MIB,
        r.para_mib
    );
    assert!(
        (got.gra / MIB - r.gra_mib).abs() < tol(r.gra_mib),
        "{name}: #Gra {:.2} vs paper {:.2}",
        got.gra / MIB,
        r.gra_mib
    );
    assert!(
        (got.sta / MIB - r.sta_mib).abs() < tol(r.sta_mib).max(1.0),
        "{name}: #Sta {:.2} vs paper {:.2}",
        got.sta / MIB,
        r.sta_mib
    );
    assert!(
        (got.pgs / GIB - r.pgs_gib).abs() < (r.pgs_gib * 0.02).max(0.03),
        "{name}: #PGS {:.2} vs paper {:.2}",
        got.pgs / GIB,
        r.pgs_gib
    );
    // Modelled residual: ±50% band (the paper measures allocator peaks —
    // fragmentation, caching, GPT-Neo's local-attention layers — that a
    // closed-form model cannot capture; per-row deltas are tabulated in
    // EXPERIMENTS.md §Memory).
    assert!(
        (got.residual / GIB - r.residual_gib).abs() < r.residual_gib * 0.5 + 0.3,
        "{name}: residual {:.2} vs paper {:.2} (modelled, band ±50%)",
        got.residual / GIB,
        r.residual_gib
    );
}

#[test]
fn table8_roberta_base_adamw() {
    // fp32 FPFT / HiFT rows.
    check(&Row { model: "roberta-base", batch: 8, opt: OptimKind::AdamW, dtype: Dtype::Fp32,
        hift: false, para_mib: 475.49, gra_mib: 475.49, sta_mib: 950.98, pgs_gib: 1.86,
        residual_gib: 5.02 });
    check(&Row { model: "roberta-base", batch: 8, opt: OptimKind::AdamW, dtype: Dtype::Fp32,
        hift: true, para_mib: 475.49, gra_mib: 148.77, sta_mib: 297.54, pgs_gib: 0.90,
        residual_gib: 3.61 });
    // mixed
    check(&Row { model: "roberta-base", batch: 8, opt: OptimKind::AdamW, dtype: Dtype::Mixed,
        hift: false, para_mib: 713.25, gra_mib: 475.49, sta_mib: 950.98, pgs_gib: 2.09,
        residual_gib: 3.58 });
    // MixedHi
    check(&Row { model: "roberta-base", batch: 8, opt: OptimKind::AdamW, dtype: Dtype::MixedHi,
        hift: true, para_mib: 386.52, gra_mib: 148.77, sta_mib: 297.54, pgs_gib: 0.81,
        residual_gib: 1.81 });
}

#[test]
fn table8_roberta_base_other_optimizers() {
    check(&Row { model: "roberta-base", batch: 8, opt: OptimKind::Sgdm, dtype: Dtype::Fp32,
        hift: false, para_mib: 475.49, gra_mib: 475.49, sta_mib: 475.49, pgs_gib: 1.39,
        residual_gib: 5.00 });
    check(&Row { model: "roberta-base", batch: 8, opt: OptimKind::Sgd, dtype: Dtype::Fp32,
        hift: true, para_mib: 475.49, gra_mib: 148.77, sta_mib: 0.0, pgs_gib: 0.61,
        residual_gib: 3.91 });
    check(&Row { model: "roberta-base", batch: 8, opt: OptimKind::Adagrad, dtype: Dtype::Fp32,
        hift: false, para_mib: 475.49, gra_mib: 475.49, sta_mib: 475.49, pgs_gib: 1.39,
        residual_gib: 5.00 });
    // Adafactor: factored state, sub-MiB.
    let a = by_name("roberta-base").unwrap();
    let f = account(&a, OptimKind::Adafactor, Dtype::Fp32, Method::Fpft,
                    Workload { batch: 8, seq: 512 });
    assert!(f.sta / MIB < 1.6, "paper: 0.98 MiB; got {:.2}", f.sta / MIB);
}

#[test]
fn table9_roberta_large() {
    check(&Row { model: "roberta-large", batch: 8, opt: OptimKind::AdamW, dtype: Dtype::Fp32,
        hift: false, para_mib: 1355.60, gra_mib: 1355.60, sta_mib: 2711.20, pgs_gib: 5.30,
        residual_gib: 13.08 });
    check(&Row { model: "roberta-large", batch: 8, opt: OptimKind::AdamW, dtype: Dtype::Fp32,
        hift: true, para_mib: 1355.60, gra_mib: 198.38, sta_mib: 396.73, pgs_gib: 1.90,
        residual_gib: 9.97 });
    check(&Row { model: "roberta-large", batch: 8, opt: OptimKind::AdamW, dtype: Dtype::MixedHi,
        hift: true, para_mib: 876.18, gra_mib: 198.38, sta_mib: 396.73, pgs_gib: 1.44,
        residual_gib: 5.18 });
}

#[test]
fn table10_gpt2_large() {
    check(&Row { model: "gpt2-large", batch: 8, opt: OptimKind::AdamW, dtype: Dtype::Fp32,
        hift: false, para_mib: 2952.69, gra_mib: 2952.69, sta_mib: 5905.39, pgs_gib: 11.53,
        residual_gib: 37.26 });
    check(&Row { model: "gpt2-large", batch: 8, opt: OptimKind::AdamW, dtype: Dtype::Fp32,
        hift: true, para_mib: 2952.69, gra_mib: 250.40, sta_mib: 500.79, pgs_gib: 3.62,
        residual_gib: 31.73 });
}

#[test]
fn table11_gpt_neo() {
    check(&Row { model: "gpt-neo-2.7b", batch: 8, opt: OptimKind::AdamW, dtype: Dtype::Fp32,
        hift: false, para_mib: 10113.95, gra_mib: 10113.95, sta_mib: 20227.89, pgs_gib: 39.51,
        residual_gib: 22.69 });
    check(&Row { model: "gpt-neo-2.7b", batch: 8, opt: OptimKind::AdamW, dtype: Dtype::Fp32,
        hift: true, para_mib: 10113.95, gra_mib: 510.79, sta_mib: 1021.58, pgs_gib: 11.37,
        residual_gib: 16.96 });
}

#[test]
fn table12_llama_7b() {
    check(&Row { model: "llama-7b", batch: 6, opt: OptimKind::AdamW, dtype: Dtype::Fp32,
        hift: false, para_mib: 25705.04, gra_mib: 25705.04, sta_mib: 51410.08, pgs_gib: 100.41,
        residual_gib: 41.7 });
    check(&Row { model: "llama-7b", batch: 6, opt: OptimKind::AdamW, dtype: Dtype::Fp32,
        hift: true, para_mib: 25705.04, gra_mib: 772.03, sta_mib: 1544.06, pgs_gib: 27.36,
        residual_gib: 28.04 });
    check(&Row { model: "llama-7b", batch: 6, opt: OptimKind::AdamW, dtype: Dtype::MixedHi,
        hift: true, para_mib: 13624.53, gra_mib: 772.03, sta_mib: 1544.06, pgs_gib: 15.57,
        residual_gib: 18.40 });
    check(&Row { model: "llama-7b", batch: 6, opt: OptimKind::Adafactor, dtype: Dtype::Fp32,
        hift: true, para_mib: 25705.04, gra_mib: 772.03, sta_mib: 0.33, pgs_gib: 25.86,
        residual_gib: 29.55 });
}

#[test]
fn hift_sgd_zero_communication_claim() {
    // §4.3: "When using SGD, the peak communication parameter is zero."
    for model in ["roberta-base", "roberta-large", "llama-7b"] {
        let a = by_name(model).unwrap();
        let r = account(&a, OptimKind::Sgd, Dtype::Fp32, Method::Hift { m: 1 },
                        Workload { batch: 8, seq: 512 });
        assert_eq!(r.sta, 0.0, "{model}");
    }
}

#[test]
fn adafactor_communication_peaks_match_4_3() {
    // §4.3: peak communication 0.19 MB (RoBERTa-base), 0.21 MB (large),
    // 0.33 MB (LLaMA-7B) under Adafactor — the HiFT #Sta column.
    for (model, mib) in [("roberta-base", 0.19), ("roberta-large", 0.21), ("llama-7b", 0.33)] {
        let a = by_name(model).unwrap();
        let r = account(&a, OptimKind::Adafactor, Dtype::Fp32, Method::Hift { m: 1 },
                        Workload { batch: 8, seq: 512 });
        assert!(
            (r.sta / MIB - mib).abs() < 0.08,
            "{model}: {:.3} MiB vs paper {mib}",
            r.sta / MIB
        );
    }
}
