//! Task forge + seeded-determinism regression suite (ISSUE 9 acceptance):
//!
//! * the template grammar accepts every historical task name plus the
//!   parameterized forms and mixtures, and every stream emits well-formed
//!   batches;
//! * same seed → bit-identical batch streams and bit-identical dedup/
//!   diversity statistics; different seeds diverge;
//! * a replayed stream (checkpoint-resume fast-forward) consumes exactly
//!   the batches an uninterrupted run would, at the task level and
//!   through `trainer::train_ckpt`, and lands on identical stream stats;
//! * stream statistics are independent of compute precision (the forge
//!   sits above the backend);
//! * `InstructTask::eval_category` partitions the eval set: per-category
//!   shapes/tags are right, the union is the full eval set with no
//!   overlap, and the partition is stable per seed;
//! * `RunRecord` JSON carries the per-stream diversity block.

use hift::backend::{Batch, ExecBackend, NativeBackend, Precision};
use hift::bench::default_spec;
use hift::coordinator::trainer::{self, CkptOpts, TrainCfg};
use hift::data::templates::MATRIX_FAMILIES;
use hift::data::{build_task, InstructTask, Task, TaskGeom, TASK_NAMES};

fn backend() -> NativeBackend {
    NativeBackend::preset("tiny", 0).expect("tiny preset")
}

fn geom(be: &dyn ExecBackend) -> TaskGeom {
    let c = &be.manifest().config;
    TaskGeom::new(c.vocab, c.batch, c.seq_len)
}

fn tiny_geom() -> TaskGeom {
    TaskGeom::new(64, 4, 16)
}

fn check_batch_well_formed(b: &Batch, vocab: usize) {
    assert!(b.validate().is_ok());
    assert!(b.tokens.iter().all(|&t| (0..vocab as i32).contains(&t)), "tokens in vocab");
    assert!(b.targets.iter().all(|&t| (0..vocab as i32).contains(&t)));
    assert!(b.weights.iter().all(|&w| w == 0.0 || w == 1.0));
    assert!(b.weights.iter().any(|&w| w > 0.0), "some supervision");
}

fn assert_batches_eq(what: &str, a: &Batch, b: &Batch) {
    assert_eq!(a.tokens, b.tokens, "{what}: tokens");
    assert_eq!(a.targets, b.targets, "{what}: targets");
    assert_eq!(a.weights, b.weights, "{what}: weights");
}

/// One hift training run on the tiny preset; `start_step > 0` exercises the
/// checkpoint-resume replay path (fresh strategy, fast-forwarded stream).
fn train_run(task_name: &str, steps: u64, precision: &str, start_step: u64) -> trainer::RunRecord {
    let mut be = backend();
    be.set_precision(Precision::parse(precision).unwrap()).unwrap();
    let mut spec = default_spec("hift", steps);
    spec.seed = 1;
    let mut strategy = spec.build(be.manifest()).unwrap();
    let mut params = be.load_params(strategy.variant()).unwrap();
    let mut task = build_task(task_name, geom(&be), 13).unwrap();
    trainer::train_ckpt(
        &mut be,
        strategy.as_mut(),
        &mut params,
        task.as_mut(),
        TrainCfg { steps, eval_every: 0, log_every: 0 },
        &CkptOpts { start_step, ..Default::default() },
    )
    .unwrap()
}

#[test]
fn forge_grammar_covers_presets_parameterized_forms_and_mixtures() {
    let extra = ["motif32", "markovlm3", "modsum5", "bracket4", "kvrecall6", "reverse3",
        "mix:bracket+kvrecall"];
    for name in TASK_NAMES.iter().copied().chain(extra) {
        let mut t = build_task(name, tiny_geom(), 7).unwrap();
        for _ in 0..3 {
            check_batch_well_formed(&t.train_batch(), 64);
        }
        assert!(!t.eval_batches().is_empty(), "{name} has eval data");
        for e in t.eval_batches() {
            check_batch_well_formed(e, 64);
        }
    }
}

#[test]
fn unknown_and_unbuildable_names_are_errors() {
    for bad in ["nope", "motif", "mix:", "bracket99"] {
        assert!(build_task(bad, tiny_geom(), 7).is_err(), "{bad:?}");
    }
    // Parses but cannot fit the geometry: Err, not panic.
    assert!(build_task("motif60", tiny_geom(), 7).is_err());
    assert!(build_task("reverse7", tiny_geom(), 7).is_err());
}

#[test]
fn streams_are_bit_identical_per_seed() {
    for name in MATRIX_FAMILIES {
        let mut a = build_task(name, tiny_geom(), 17).unwrap();
        let mut b = build_task(name, tiny_geom(), 17).unwrap();
        for i in 0..5 {
            assert_batches_eq(&format!("{name} batch {i}"), &a.train_batch(), &b.train_batch());
        }
        assert_eq!(a.stream_stats(), b.stream_stats(), "{name}: stream stats");
        for (x, y) in a.eval_batches().iter().zip(b.eval_batches()) {
            assert_batches_eq(&format!("{name} eval"), x, y);
        }
    }
}

#[test]
fn different_seeds_diverge() {
    for name in ["markovlm", "kvrecall", "bracket"] {
        let mut a = build_task(name, tiny_geom(), 1).unwrap();
        let mut b = build_task(name, tiny_geom(), 2).unwrap();
        assert_ne!(a.train_batch().tokens, b.train_batch().tokens, "{name}");
    }
}

#[test]
fn replayed_stream_matches_uninterrupted() {
    for name in ["kvrecall", "bracket", "reverse", "mix:motif4+copy+modsum"] {
        let mut full = build_task(name, tiny_geom(), 7).unwrap();
        let reference: Vec<Batch> = (0..10).map(|_| full.train_batch()).collect();
        // The trainer's resume path replays the first `start_step` batches
        // on a fresh task and discards them; the continuation must line up.
        let mut resumed = build_task(name, tiny_geom(), 7).unwrap();
        for _ in 0..3 {
            let _ = resumed.train_batch();
        }
        for (i, want) in reference.iter().enumerate().skip(3) {
            assert_batches_eq(&format!("{name} batch {i}"), &resumed.train_batch(), want);
        }
        assert_eq!(full.stream_stats(), resumed.stream_stats(), "{name}: stats after replay");
    }
}

#[test]
fn resume_replay_preserves_stream_stats_through_the_trainer() {
    let full = train_run("markovlm", 8, "f32", 0);
    let resumed = train_run("markovlm", 8, "f32", 5);
    let d_full = full.diversity.as_ref().expect("forge stream records stats");
    assert_eq!(Some(d_full), resumed.diversity.as_ref(), "replayed stream sees the same batches");
    assert_eq!(d_full.batches_emitted, 8);
    assert_eq!(d_full.rows_emitted, 32, "4 rows per tiny batch");
}

#[test]
fn stream_stats_are_identical_across_precisions() {
    let f32_run = train_run("motif4", 6, "f32", 0);
    for prec in ["bf16", "f16"] {
        let half = train_run("motif4", 6, prec, 0);
        assert_eq!(
            f32_run.diversity, half.diversity,
            "the forge sits above the backend; {prec} must not perturb the stream"
        );
    }
}

#[test]
fn runrecord_json_carries_the_diversity_block() {
    let rec = train_run("mix:motif4+copy+modsum", 6, "f32", 0);
    let d = rec.diversity.as_ref().expect("diversity recorded");
    assert_eq!(d.batches_emitted, 6);
    assert!((0.0..=1.0).contains(&d.label_entropy));
    assert!(d.diversity_score() > 0.0 && d.diversity_score() <= 1.0);
    let cov_total: u64 = d.coverage.iter().map(|&(_, n)| n).sum();
    assert_eq!(cov_total, 6, "mixture coverage accounts for every emitted batch");
    let json = hift::ser::emit_pretty(&rec.to_json());
    for key in ["diversity", "ngram_distinct_ratio", "label_entropy", "coverage_balance"] {
        assert!(json.contains(key), "missing {key}");
    }
}

#[test]
fn instruct_eval_category_shapes_and_tags() {
    let g = tiny_geom();
    let t = InstructTask::new(g, 5);
    assert_eq!(t.n_categories(), 3);
    for which in 0..t.n_categories() {
        let cat = t.eval_category(which);
        assert!(!cat.is_empty(), "category {which} has eval batches");
        for b in &cat {
            assert_eq!((b.b, b.s), (g.b, g.s), "category {which} batch shape");
            for row in 0..b.b {
                assert_eq!(b.tokens[row * b.s], 8 + which as i32, "instruction tag");
                assert_eq!(b.weights[row * b.s], 0.0, "tag position is unsupervised");
            }
        }
    }
}

#[test]
fn instruct_eval_categories_partition_the_eval_set() {
    let t = InstructTask::new(tiny_geom(), 5);
    let n = t.n_categories();
    let cats: Vec<Vec<Batch>> = (0..n).map(|w| t.eval_category(w)).collect();
    let total: usize = cats.iter().map(Vec::len).sum();
    assert_eq!(total, t.eval_batches().len(), "union covers the full eval set");
    // eval_category(w) selects by index stride, so batch i belongs to
    // category i % n and to no other (checked by content, not index).
    for (i, b) in t.eval_batches().iter().enumerate() {
        for (w, cat) in cats.iter().enumerate() {
            let hits = cat
                .iter()
                .filter(|c| c.tokens == b.tokens && c.targets == b.targets && c.weights == b.weights)
                .count();
            assert_eq!(hits, usize::from(w == i % n), "eval batch {i} vs category {w}");
        }
    }
}

#[test]
fn instruct_eval_categories_are_stable_per_seed() {
    let a = InstructTask::new(tiny_geom(), 5);
    let b = InstructTask::new(tiny_geom(), 5);
    for which in 0..a.n_categories() {
        let (ca, cb) = (a.eval_category(which), b.eval_category(which));
        assert_eq!(ca.len(), cb.len());
        for (x, y) in ca.iter().zip(&cb) {
            assert_batches_eq(&format!("category {which}"), x, y);
        }
    }
}

#[test]
fn instruct_coverage_tracks_per_category_emission() {
    let mut t = build_task("instruct", tiny_geom(), 9).unwrap();
    for _ in 0..30 {
        let _ = t.train_batch();
    }
    let stats = t.stream_stats().expect("forge stats");
    assert_eq!(stats.coverage.len(), 3, "one entry per sub-task");
    let total: u64 = stats.coverage.iter().map(|&(_, n)| n).sum();
    assert_eq!(total, 30);
    assert!(stats.coverage_balance() > 0.0 && stats.coverage_balance() <= 1.0);
}

#[test]
fn diversity_scores_are_bounded_across_families() {
    for name in MATRIX_FAMILIES {
        let mut t = build_task(name, tiny_geom(), 3).unwrap();
        for _ in 0..8 {
            let _ = t.train_batch();
        }
        let st = t.stream_stats().expect("forge stats");
        assert_eq!(st.batches_emitted, 8, "{name}");
        assert!(st.rows_emitted >= 32, "{name}: gate may resample but always emits");
        assert!((0.0..=1.0).contains(&st.label_entropy), "{name}");
        assert!((0.0..=1.0).contains(&st.diversity_score()), "{name}");
        assert!(st.ngram_distinct_ratio() > 0.0 && st.ngram_distinct_ratio() <= 1.0, "{name}");
    }
}
