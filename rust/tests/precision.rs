//! Mixed-precision compute path (ISSUE 5 acceptance):
//!
//! * `--precision f32` is **bit-identical** to the default path: same loss
//!   curve, same final parameters, same eval — the quantization hooks are
//!   structural no-ops;
//! * bf16/f16 runs train inside the documented drift band on the tiny
//!   preset (final-params rel-L2, eval-accuracy delta) while measurably
//!   halving retained-activation residency — and provably quantize (bits
//!   differ from f32);
//! * the f16 dynamic loss scaler engages (scale installed on the backend,
//!   surfaced in `RuntimeStats`), and skip-step on a synthetic overflow
//!   leaves params + optimizer state bit-identical to pre-step (covered at
//!   the sink layer in `optim::apply` tests; exercised end-to-end here);
//! * a checkpoint records its precision and resume rejects a mismatch;
//!   kill+resume under bf16 stays bit-identical (no scaler state to lose);
//! * lossless offload and activation checkpointing compose with a half
//!   precision without changing its results.

use hift::backend::{ActCkpt, ExecBackend, NativeBackend, OffloadCfg, Precision};
use hift::coordinator::lr::LrSchedule;
use hift::coordinator::strategy::UpdateStrategy;
use hift::coordinator::trainer::{self, CkptOpts, TrainCfg};
use hift::data::{build_task, TaskGeom};
use hift::optim::{OptimCfg, OptimKind};
use hift::strategies::{FineTuneStrategy, Hift, HiftCfg};
use hift::tensor::{checkpoint, TensorSet};

fn backend() -> NativeBackend {
    NativeBackend::preset("tiny", 0).expect("tiny preset")
}

fn geom(be: &dyn ExecBackend) -> TaskGeom {
    let c = &be.manifest().config;
    TaskGeom::new(c.vocab, c.batch, c.seq_len)
}

fn hift_cfg(total: usize) -> HiftCfg {
    HiftCfg {
        m: 1,
        order: UpdateStrategy::Bottom2Up,
        schedule: LrSchedule::Linear { lr: 4e-3, warmup: 0, total },
        optim: OptimCfg::new(OptimKind::AdamW),
    }
}

/// Train HiFT for `steps` at `prec`; returns (record, final params).
fn run_at(
    prec: Precision,
    steps: u64,
    seed: u64,
) -> (trainer::RunRecord, TensorSet) {
    let mut be = backend();
    be.set_precision(prec).unwrap();
    let manifest = be.manifest().clone();
    let mut strat = Hift::pipelined(hift_cfg(steps as usize), &manifest, false).unwrap();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), seed).unwrap();
    let rec = trainer::train(
        &mut be,
        &mut strat,
        &mut params,
        task.as_mut(),
        TrainCfg { steps, eval_every: 0, log_every: 0 },
    )
    .unwrap();
    (rec, params)
}

fn rel_l2(a: &TensorSet, b: &TensorSet) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (ta, tb) in a.tensors.iter().zip(&b.tensors) {
        for (x, y) in ta.data.iter().zip(&tb.data) {
            num += ((x - y) as f64).powi(2);
            den += (*y as f64).powi(2);
        }
    }
    num.sqrt() / den.sqrt().max(1e-12)
}

#[test]
fn explicit_f32_is_bit_identical_to_default() {
    let steps = 8u64;
    // Default path (never calls set_precision at all).
    let mut be = backend();
    let manifest = be.manifest().clone();
    let mut strat = Hift::pipelined(hift_cfg(steps as usize), &manifest, false).unwrap();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), 3).unwrap();
    let base = trainer::train(
        &mut be,
        &mut strat,
        &mut params,
        task.as_mut(),
        TrainCfg { steps, eval_every: 0, log_every: 0 },
    )
    .unwrap();

    let (rec, p32) = run_at(Precision::F32, steps, 3);
    assert_eq!(rec.losses.values, base.losses.values, "f32 loss curve must be bit-identical");
    assert_eq!(rec.final_eval, base.final_eval);
    assert_eq!(rec.precision, "f32");
    for ((name, a), b) in p32.names.iter().zip(&p32.tensors).zip(&params.tensors) {
        assert_eq!(a.data, b.data, "{name}: --precision f32 must not change a single bit");
    }
}

#[test]
fn half_precision_trains_within_the_drift_band() {
    let steps = 40u64;
    let (rec32, p32) = run_at(Precision::F32, steps, 5);
    for prec in [Precision::Bf16, Precision::F16] {
        let (rec, p) = run_at(prec, steps, 5);
        assert_eq!(rec.precision, prec.name());
        // Finite, converging training.
        for &l in &rec.losses.values {
            assert!(l.is_finite(), "{prec:?}: loss went non-finite");
        }
        assert!(
            rec.losses.tail_mean(8) < rec.losses.values[0],
            "{prec:?}: training must reduce the loss"
        );
        // Provably quantized (not silently running the f32 path)…
        assert_ne!(
            rec.losses.values[0].to_bits(),
            rec32.losses.values[0].to_bits(),
            "{prec:?}: first loss identical to f32 — quantization not engaged?"
        );
        // …but inside the documented drift band.
        let drift = rel_l2(&p, &p32);
        assert!(
            drift > 0.0 && drift < 0.15,
            "{prec:?}: final-params rel-L2 drift {drift} outside (0, 0.15)"
        );
        let dacc = (rec.final_eval.acc - rec32.final_eval.acc).abs();
        assert!(dacc < 0.3, "{prec:?}: eval accuracy drifted by {dacc}");
        // Measured activation residency is physically ~halved (LN row
        // stats and the f32 loss head keep it a little above 0.5×).
        let (h, f) = (rec.backend.peak_act_resident_bytes, rec32.backend.peak_act_resident_bytes);
        assert!(
            h * 10 <= f * 7 && h * 10 >= f * 4,
            "{prec:?}: peak act bytes {h} not in the halved band of f32's {f}"
        );
        // Half-width parameter uploads: h2d traffic drops too.
        assert!(
            rec.backend.h2d_bytes < rec32.backend.h2d_bytes,
            "{prec:?}: h2d {} should be below f32's {}",
            rec.backend.h2d_bytes,
            rec32.backend.h2d_bytes
        );
    }
}

#[test]
fn f16_engages_the_dynamic_loss_scaler() {
    let (rec, _) = run_at(Precision::F16, 12, 7);
    // The scaler installed a scale (gauge lands in RuntimeStats)…
    assert!(
        rec.backend.loss_scale > 1.0,
        "f16 run must train under an installed loss scale (got {})",
        rec.backend.loss_scale
    );
    // …and bf16/f32 never do.
    let (rec32, _) = run_at(Precision::F32, 12, 7);
    assert_eq!(rec32.backend.loss_scale, 0.0, "f32 never touches the scaler");
    let (recb, _) = run_at(Precision::Bf16, 12, 7);
    assert_eq!(recb.backend.loss_scale, 0.0, "bf16 runs unscaled by design");
}

#[test]
fn half_precision_composes_with_act_ckpt_and_lossless_offload() {
    let steps = 10u64;
    let run = |offload: bool, ckpt: ActCkpt| {
        let mut be = backend();
        be.set_precision(Precision::Bf16).unwrap();
        be.set_act_ckpt(ckpt).unwrap();
        if offload {
            be.set_offload(OffloadCfg::host()).unwrap();
        }
        let manifest = be.manifest().clone();
        let mut strat = Hift::pipelined(hift_cfg(steps as usize), &manifest, false).unwrap();
        let mut params = be.load_params("base").unwrap();
        let mut task = build_task("motif4", geom(&be), 13).unwrap();
        trainer::train(
            &mut be,
            &mut strat,
            &mut params,
            task.as_mut(),
            TrainCfg { steps, eval_every: 0, log_every: 0 },
        )
        .unwrap()
    };
    let plain = run(false, ActCkpt::None);
    // Recompute replays the same deterministic quantization → identical.
    let ck = run(false, ActCkpt::Sqrt);
    assert_eq!(plain.losses.values, ck.losses.values, "bf16 + act-ckpt must be bit-identical");
    // Lossless paging restores exact bits → identical under bf16 too.
    let off = run(true, ActCkpt::None);
    assert_eq!(plain.losses.values, off.losses.values, "bf16 + offload must be bit-identical");
    assert_eq!(plain.final_eval, off.final_eval);
}

#[test]
fn checkpoint_records_precision_and_resume_rejects_mismatch() {
    let dir = std::env::temp_dir().join(format!("hift_prec_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let steps = 6u64;
    let mut be = backend();
    be.set_precision(Precision::Bf16).unwrap();
    let manifest = be.manifest().clone();
    let mut strat = Hift::pipelined(hift_cfg(steps as usize), &manifest, false).unwrap();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), 17).unwrap();
    trainer::train_ckpt(
        &mut be,
        &mut strat,
        &mut params,
        task.as_mut(),
        TrainCfg { steps, eval_every: 0, log_every: 0 },
        &CkptOpts { save_dir: Some(dir.clone()), save_every: 0, ..Default::default() },
    )
    .unwrap();

    let ck = checkpoint::load(&dir).unwrap();
    assert_eq!(ck.meta.precision.as_deref(), Some("bf16"), "precision persisted in meta");
    // The guard the CLI resume path runs:
    assert!(Precision::check_resume(ck.meta.precision.as_deref(), Precision::Bf16).is_ok());
    let err =
        Precision::check_resume(ck.meta.precision.as_deref(), Precision::F16).unwrap_err();
    assert!(err.to_string().contains("precision"), "{err}");
    assert!(Precision::check_resume(ck.meta.precision.as_deref(), Precision::F32).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bf16_kill_and_resume_is_bit_identical() {
    let dir = std::env::temp_dir().join(format!("hift_prec_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let steps = 10u64;
    let kill_at = 6u64;
    let train_cfg = TrainCfg { steps, eval_every: 0, log_every: 0 };

    // Uninterrupted bf16 reference.
    let mut be = backend();
    be.set_precision(Precision::Bf16).unwrap();
    let manifest = be.manifest().clone();
    let mut h = Hift::pipelined(hift_cfg(8), &manifest, false).unwrap();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), 19).unwrap();
    let full = trainer::train(&mut be, &mut h, &mut params, task.as_mut(), train_cfg).unwrap();

    // Interrupted at kill_at, then resumed purely from disk.
    let mut be1 = backend();
    be1.set_precision(Precision::Bf16).unwrap();
    let mut h1 = Hift::pipelined(hift_cfg(8), &manifest, false).unwrap();
    let mut p1 = be1.load_params("base").unwrap();
    let mut t1 = build_task("motif4", geom(&be1), 19).unwrap();
    trainer::train_ckpt(
        &mut be1,
        &mut h1,
        &mut p1,
        t1.as_mut(),
        TrainCfg { steps: kill_at, eval_every: 0, log_every: 0 },
        &CkptOpts { save_dir: Some(dir.clone()), save_every: 0, ..Default::default() },
    )
    .unwrap();

    let ck = checkpoint::load(&dir).unwrap();
    assert_eq!(ck.meta.precision.as_deref(), Some("bf16"));
    let mut be2 = backend();
    be2.set_precision(Precision::Bf16).unwrap();
    let mut h2 = Hift::pipelined(hift_cfg(8), &manifest, false).unwrap();
    let mut p2 = ck.params;
    h2.import_opt_state(&ck.opt_state, &p2).unwrap();
    let mut t2 = build_task("motif4", geom(&be2), 19).unwrap();
    let resumed = trainer::train_ckpt(
        &mut be2,
        &mut h2,
        &mut p2,
        t2.as_mut(),
        train_cfg,
        &CkptOpts { start_step: ck.meta.step, expect_sweep: ck.meta.sweep, ..Default::default() },
    )
    .unwrap();

    assert_eq!(resumed.losses.values[..], full.losses.values[kill_at as usize..]);
    for ((name, a), b) in p2.names.iter().zip(&p2.tensors).zip(&params.tensors) {
        assert_eq!(a.data, b.data, "{name}: bf16 resume must be bit-identical");
    }
    assert_eq!(resumed.final_eval, full.final_eval);
    std::fs::remove_dir_all(&dir).ok();
}
