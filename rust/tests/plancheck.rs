//! Static-plan ↔ real-backend cross-validation (ISSUE 10 acceptance).
//!
//! For a sampled set of verified lattice points, the statically derived
//! plan from `hift::plancheck` is replayed through the *real*
//! `NativeBackend` with paging-event tracing on, and the measured streams
//! must match the symbolic ones **event for event**:
//!
//! * `NativeBackend::take_offload_trace()` (every Prefetch / Admit / Evict
//!   the pager actually performed, in order) == the plan step's
//!   `page_events()`;
//! * the `(slot, name)` sequence a recording sink observes == the plan
//!   step's `emits()` mapped through the manifest;
//! * the update-sink ledger's measured `peak_grad_resident_bytes` == the
//!   verifier's proven `peak_grad_bytes`, and the pager's measured
//!   `peak_param_resident_bytes` == the proven `peak_param_bytes` (which
//!   the verifier already bounded by the memmodel's structural bound).
//!
//! Run in CI with `--features contracts` under `HIFT_CHECK=1` so the
//! runtime checkers (emission order, ledger conservation) are armed on the
//! same steps the static verifier signed off.

use hift::backend::{
    ActCkpt, Batch, Compression, ExecBackend, NativeBackend, OffloadCfg, Precision,
};
use hift::coordinator::{HiftScheduler, LrSchedule, SchedulerCfg, UpdateStrategy};
use hift::optim::{self, FusedApply, NonFinitePolicy, OffloadLedger, OptimCfg, OptimKind};
use hift::plancheck::{generate_plan, verify_plan, Family, Inject, LatticePoint};
use hift::rng::Pcg32;
use hift::tensor::{Tensor, TensorSet};

const NO_OFFLOAD: OffloadCfg =
    OffloadCfg { enabled: false, compress: Compression::Lossless, prefetch: false };
const HOST_SYNC: OffloadCfg =
    OffloadCfg { enabled: true, compress: Compression::Lossless, prefetch: false };
const HOST_PREFETCH: OffloadCfg =
    OffloadCfg { enabled: true, compress: Compression::Lossless, prefetch: true };
const HOST_F16_SYNC: OffloadCfg =
    OffloadCfg { enabled: true, compress: Compression::F16, prefetch: false };
const HOST_F16_PREFETCH: OffloadCfg =
    OffloadCfg { enabled: true, compress: Compression::F16, prefetch: true };

/// Deterministic one-sequence batch (same idiom as `tests/offload.rs`).
fn small_batch(vocab: usize, s: usize, seed: u64) -> Batch {
    let mut rng = Pcg32::seeded(seed);
    let mut b = Batch::new(1, s);
    for t in &mut b.tokens {
        *t = rng.below(vocab) as i32;
    }
    for t in &mut b.targets {
        *t = rng.below(vocab) as i32;
    }
    for w in &mut b.weights {
        *w = 1.0;
    }
    b
}

fn point(
    strategy: UpdateStrategy,
    m: usize,
    act_ckpt: ActCkpt,
    offload: OffloadCfg,
    precision: Precision,
    workers: usize,
) -> LatticePoint {
    LatticePoint { family: Family::Hift, strategy, m, act_ckpt, offload, precision, workers }
}

/// The sampled lattice points the acceptance criteria call for (≥ 8):
/// every strategy, sync + prefetch + f16-compressed paging, every
/// activation-checkpoint policy, the single-group edge (m = n_units), the
/// deferred f16 sink, and the no-offload sharded walk (emit-only trace).
fn sampled_points() -> Vec<LatticePoint> {
    use UpdateStrategy::{Bottom2Up, Random, Top2Down};
    vec![
        point(Bottom2Up, 1, ActCkpt::None, HOST_SYNC, Precision::F32, 1),
        point(Bottom2Up, 2, ActCkpt::None, HOST_PREFETCH, Precision::F32, 1),
        point(Top2Down, 1, ActCkpt::Sqrt, HOST_F16_PREFETCH, Precision::F32, 1),
        point(Random { seed: 7 }, 3, ActCkpt::EveryK(1), HOST_F16_SYNC, Precision::F32, 1),
        point(Bottom2Up, 2, ActCkpt::EveryK(2), HOST_PREFETCH, Precision::Bf16, 1),
        point(Top2Down, 2, ActCkpt::Sqrt, HOST_SYNC, Precision::F16, 1),
        point(Random { seed: 3 }, 4, ActCkpt::None, HOST_PREFETCH, Precision::F32, 1),
        point(Bottom2Up, 3, ActCkpt::EveryK(1), HOST_F16_PREFETCH, Precision::F32, 1),
        point(Bottom2Up, 2, ActCkpt::None, NO_OFFLOAD, Precision::F32, 2),
    ]
}

/// A pass-through sink that records the `(slot, name)` emission sequence
/// the backend's streamed backward actually produced, then forwards each
/// gradient to the real `FusedApply`.
struct RecordingSink<'a> {
    inner: FusedApply<'a>,
    emits: Vec<(usize, String)>,
}

impl hift::backend::GradSink for RecordingSink<'_> {
    fn grad(
        &mut self,
        slot: usize,
        name: &str,
        grad: Tensor,
        params: &mut TensorSet,
    ) -> hift::Result<()> {
        self.emits.push((slot, name.to_string()));
        self.inner.grad(slot, name, grad, params)
    }

    fn resident_bytes(&self) -> u64 {
        self.inner.resident_bytes()
    }

    fn finish(&mut self, params: &mut TensorSet) -> hift::Result<()> {
        self.inner.finish(params)
    }
}

/// Generate + statically verify the plan for `pt`, then drive the real
/// backend through the same schedule and compare the measured event
/// streams step by step.
fn cross_validate(pt: &LatticePoint) {
    let label = pt.name();

    // --- the static side --------------------------------------------------
    let mut be = NativeBackend::preset("tiny", 42).unwrap();
    let manifest = be.manifest().clone();
    let k = manifest.n_units.div_ceil(pt.m) as u64;
    let n_steps = 2 * k + 2; // two full sweeps + a boundary crossing
    let plan = generate_plan(&manifest, pt, n_steps, Inject::None).unwrap();
    let verdict = verify_plan(&manifest, pt, &plan).unwrap();
    assert!(
        verdict.violations.is_empty(),
        "[{label}] static verifier rejected the clean plan: {:?}",
        verdict.violations
    );

    // --- the real side ----------------------------------------------------
    if pt.offload.enabled {
        be.set_offload(pt.offload).unwrap();
    }
    if pt.workers > 1 {
        be.set_workers(pt.workers).unwrap();
    }
    be.set_act_ckpt(pt.act_ckpt).unwrap();
    be.set_offload_tracing(true);
    let mut params = be.load_params("base").unwrap();
    let vinfo = manifest.variant("base").unwrap();
    let unit_params: Vec<Vec<usize>> =
        (0..manifest.n_units).map(|u| vinfo.unit_indices(u)).collect();
    let mut sched = HiftScheduler::new(
        SchedulerCfg {
            m: pt.m,
            strategy: pt.strategy,
            schedule: LrSchedule::Const { lr: 0.1 }, // == plancheck's PLAN_LR
        },
        manifest.n_units,
    );
    let mut opt = optim::build(OptimCfg::new(OptimKind::AdamW), vinfo.params.len());
    let mut ledger = OffloadLedger::new();
    let batch = small_batch(manifest.config.vocab, manifest.config.seq_len, 9);
    // Events are precision-invariant (compute width changes float values,
    // never the walk), so the backend runs at its default f32 width; the
    // sink policy is the one thing precision selects, mirrored here.
    let policy = if plan.deferred {
        NonFinitePolicy::SkipStep
    } else {
        NonFinitePolicy::SkipTensor
    };

    for (t, planned) in plan.steps.iter().enumerate() {
        let real = sched.next();
        assert_eq!(real.units, planned.units, "[{label}] step {t}: schedule diverged");
        assert_eq!(real.lr, planned.lr, "[{label}] step {t}: lr diverged");
        assert_eq!(
            real.sweep_boundary, planned.sweep_boundary,
            "[{label}] step {t}: sweep boundary diverged"
        );
        be.prefetch_units(&sched.peek_next());

        let slot_param: Vec<usize> =
            planned.units.iter().flat_map(|&u| unit_params[u].iter().copied()).collect();
        let mut sink = RecordingSink {
            inner: FusedApply::new(&mut *opt, Some(&mut ledger), &slot_param, 1.0, planned.lr)
                .non_finite(policy),
            emits: Vec::new(),
        };
        be.run_group_streamed(&planned.units, &mut params, &batch, &mut sink).unwrap();

        // Paging: every Prefetch / Admit / Evict, in order.
        let measured = be.take_offload_trace();
        assert_eq!(
            measured,
            planned.page_events(),
            "[{label}] step {t}: measured paging trace diverged from the static plan"
        );

        // Emits: the (slot, param) sequence, in order, names included.
        let expect: Vec<(usize, String)> = planned
            .emits()
            .iter()
            .map(|&(slot, idx)| (slot, vinfo.params[idx].name.clone()))
            .collect();
        assert_eq!(
            sink.emits, expect,
            "[{label}] step {t}: measured emit sequence diverged from the static plan"
        );
    }

    // Byte-level peaks: the verifier's proven numbers are the measured ones.
    assert_eq!(
        ledger.peak_grad_resident_bytes, verdict.metrics.peak_grad_bytes,
        "[{label}] measured peak gradient residency != statically proven peak"
    );
    if pt.paged() {
        let counters = be.offload_counters().expect("offload on, counters exist");
        assert_eq!(
            counters.peak_param_resident_bytes, verdict.metrics.peak_param_bytes,
            "[{label}] measured peak parameter residency != statically proven peak"
        );
        assert!(
            counters.peak_param_resident_bytes <= verdict.metrics.bound_bytes,
            "[{label}] measured residency {} above the memmodel bound {}",
            counters.peak_param_resident_bytes,
            verdict.metrics.bound_bytes
        );
    }
}

#[test]
fn sampled_plans_replay_exactly_on_the_real_backend() {
    let pts = sampled_points();
    assert!(pts.len() >= 8, "acceptance criteria want >= 8 sampled configs");
    for pt in &pts {
        cross_validate(pt);
    }
}

/// The no-offload sharded point must produce a *silent* pager: no trace at
/// all, while the emit order still matches the serial plan (the reduce
/// rendezvous emits in the exact plain-walk order).
#[test]
fn sharded_walk_has_no_paging_and_serial_emit_order() {
    let pt = point(
        UpdateStrategy::Bottom2Up,
        2,
        ActCkpt::None,
        NO_OFFLOAD,
        Precision::F32,
        2,
    );
    let be = NativeBackend::preset("tiny", 42).unwrap();
    let manifest = be.manifest().clone();
    let plan = generate_plan(&manifest, &pt, 4, Inject::None).unwrap();
    for step in &plan.steps {
        assert!(step.page_events().is_empty(), "unpaged plan must contain no page events");
        assert!(!step.emits().is_empty(), "every step emits its group's gradients");
    }
}
