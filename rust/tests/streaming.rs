//! Streamed-gradient seam equivalence (ISSUE 2 acceptance):
//!
//! * streamed and collected execution produce bit-identical gradients on
//!   every native preset;
//! * fused-update FPFT and HiFT (m=1 and m>1) land on parameters
//!   bit-identical to the pre-refactor collect-then-update path (encoded
//!   here as the reference loops);
//! * the double-buffered pipeline is bit-identical to the serial sink;
//! * `peak_grad_resident_bytes` under streamed HiFT is one tensor — the
//!   largest in the group — while the collected path holds the whole set.

use hift::backend::{
    unit_artifact, Batch, ExecBackend, GradSink, NativeBackend, PRESET_NAMES,
};
use hift::coordinator::lr::LrSchedule;
use hift::coordinator::scheduler::{HiftScheduler, SchedulerCfg};
use hift::coordinator::strategy::UpdateStrategy;
use hift::data::{build_task, TaskGeom};
use hift::optim::{self, OptimCfg, OptimKind};
use hift::rng::Pcg32;
use hift::strategies::{FineTuneStrategy, Hift, HiftCfg, SubsetTune};
use hift::tensor::{Tensor, TensorSet};

fn backend() -> NativeBackend {
    NativeBackend::preset("tiny", 0).expect("tiny preset")
}

fn geom(be: &dyn ExecBackend) -> TaskGeom {
    let c = &be.manifest().config;
    TaskGeom::new(c.vocab, c.batch, c.seq_len)
}

/// A sink that records `(slot, name, grad)` without applying anything.
#[derive(Default)]
struct Recorder {
    grads: Vec<(usize, String, Tensor)>,
}

impl GradSink for Recorder {
    fn grad(
        &mut self,
        slot: usize,
        name: &str,
        grad: Tensor,
        _params: &mut TensorSet,
    ) -> anyhow::Result<()> {
        self.grads.push((slot, name.to_string(), grad));
        Ok(())
    }

    fn resident_bytes(&self) -> u64 {
        self.grads.iter().map(|(_, _, g)| g.bytes() as u64).sum()
    }
}

fn small_batch(vocab: usize, s: usize, seed: u64) -> Batch {
    let mut rng = Pcg32::seeded(seed);
    let mut b = Batch::new(1, s);
    for t in b.tokens.iter_mut() {
        *t = rng.below(vocab) as i32;
    }
    for t in b.targets.iter_mut() {
        *t = rng.below(vocab) as i32;
    }
    for w in b.weights.iter_mut() {
        *w = 1.0;
    }
    b
}

#[test]
fn streamed_equals_collected_grads_on_all_presets() {
    for preset in PRESET_NAMES {
        let mut be = NativeBackend::preset(preset, 1).unwrap();
        let cfg = be.manifest().config.clone();
        let n_units = be.manifest().n_units;
        let mut params = be.load_params("base").unwrap();
        // A 1×4 batch keeps the larger presets tractable in debug test
        // builds while exercising the full layer stack.
        let batch = small_batch(cfg.vocab, cfg.seq_len.min(4), 7);
        // FPFT's artifact plus every HiFT unit artifact on the small
        // presets; a middle unit and the head unit on the big ones.
        let artifacts: Vec<String> = if matches!(preset, "tiny" | "small") {
            let mut a = vec!["grad_base_full".to_string()];
            a.extend((0..n_units).map(unit_artifact));
            a
        } else {
            vec![unit_artifact(1), unit_artifact(n_units - 1)]
        };
        for art in &artifacts {
            let collected = be.run(art, &mut params, &batch).unwrap();
            let mut rec = Recorder::default();
            let streamed = be.run_streamed(art, &mut params, &batch, &mut rec).unwrap();
            assert_eq!(collected.loss, streamed.loss, "{preset}/{art}: loss");
            assert_eq!(collected.ncorrect, streamed.ncorrect, "{preset}/{art}: ncorrect");
            assert_eq!(rec.grads.len(), collected.grads.len(), "{preset}/{art}: grad count");
            let mut by_slot = rec.grads;
            by_slot.sort_by_key(|(slot, _, _)| *slot);
            for ((slot, name, g), cg) in by_slot.iter().zip(&collected.grads) {
                assert_eq!(g.shape, cg.shape, "{preset}/{art}/{name}");
                assert_eq!(
                    g.data, cg.data,
                    "{preset}/{art}: slot {slot} ({name}) must be bit-identical"
                );
            }
        }
    }
}

/// The pre-refactor FPFT path: collect the full gradient vector, then
/// clip + update tensor-by-tensor in artifact output order.
#[test]
fn fused_fpft_matches_collected_reference() {
    let lr = 3e-3f32;
    let ocfg = OptimCfg::new(OptimKind::AdamW);
    let steps = 6usize;

    let mut be = backend();
    let mut task = build_task("motif4", geom(&be), 11).unwrap();
    let batches: Vec<Batch> = (0..steps).map(|_| task.train_batch()).collect();

    // Streamed + fused (the new SubsetTune path).
    let mut fpft =
        SubsetTune::fpft(be.manifest(), ocfg, LrSchedule::Const { lr }).unwrap();
    let mut p_s = be.load_params("base").unwrap();
    for b in &batches {
        fpft.step(&mut be, &mut p_s, b).unwrap();
    }

    // Collected reference (pre-refactor semantics).
    let n_params = be.manifest().variant("base").unwrap().params.len();
    let mut p_c = be.load_params("base").unwrap();
    let mut opt = optim::build(ocfg, n_params);
    for b in &batches {
        let out = be.run("grad_base_full", &mut p_c, b).unwrap();
        for (idx, mut g) in out.grads.into_iter().enumerate() {
            optim::clip_grad(&mut g, ocfg.grad_clip);
            opt.update(idx, p_c.tensor_mut(idx), &g, lr);
        }
    }

    for ((name, ts), tc) in p_s.names.iter().zip(&p_s.tensors).zip(&p_c.tensors) {
        assert_eq!(ts.data, tc.data, "{name}: streamed FPFT must equal collected path");
    }
}

/// The pre-refactor HiFT path: per step, run every unit artifact of the
/// group collecting all gradients, then clip + update jointly.
fn hift_collected_reference(
    be: &mut NativeBackend,
    m: usize,
    lr: f32,
    ocfg: OptimCfg,
    batches: &[Batch],
) -> TensorSet {
    let manifest = be.manifest().clone();
    let vinfo = manifest.variant("base").unwrap();
    let mut scheduler = HiftScheduler::new(
        SchedulerCfg {
            m,
            strategy: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr },
        },
        manifest.n_units,
    );
    let mut params = be.load_params("base").unwrap();
    let mut opt = optim::build(ocfg, vinfo.params.len());
    for b in batches {
        let plan = scheduler.next();
        let mut grads: Vec<(usize, Tensor)> = Vec::new();
        for &u in &plan.units {
            let out = be.run(&unit_artifact(u), &mut params, b).unwrap();
            for (slot, g) in vinfo.unit_indices(u).into_iter().zip(out.grads) {
                grads.push((slot, g));
            }
        }
        for (idx, mut g) in grads {
            optim::clip_grad(&mut g, ocfg.grad_clip);
            opt.update(idx, params.tensor_mut(idx), &g, plan.lr);
        }
    }
    params
}

fn run_streamed_hift(
    be: &mut NativeBackend,
    m: usize,
    lr: f32,
    ocfg: OptimCfg,
    batches: &[Batch],
    pipeline: bool,
) -> TensorSet {
    let manifest = be.manifest().clone();
    let cfg = HiftCfg {
        m,
        order: UpdateStrategy::Bottom2Up,
        schedule: LrSchedule::Const { lr },
        optim: ocfg,
    };
    let mut hift = Hift::pipelined(cfg, &manifest, pipeline).unwrap();
    let mut params = be.load_params("base").unwrap();
    for b in batches {
        hift.step(&mut *be, &mut params, b).unwrap();
    }
    params
}

#[test]
fn streamed_hift_matches_collected_reference_m1_and_m2() {
    let lr = 3e-3f32;
    let ocfg = OptimCfg::new(OptimKind::AdamW);
    for m in [1usize, 2] {
        let mut be = backend();
        let n_units = be.manifest().n_units;
        let mut task = build_task("motif4", geom(&be), 5).unwrap();
        // Two full sweeps so every group updates twice.
        let k = n_units.div_ceil(m);
        let batches: Vec<Batch> = (0..2 * k).map(|_| task.train_batch()).collect();

        let p_ref = hift_collected_reference(&mut be, m, lr, ocfg, &batches);
        let p_str = run_streamed_hift(&mut be, m, lr, ocfg, &batches, false);
        for ((name, a), b) in p_str.names.iter().zip(&p_str.tensors).zip(&p_ref.tensors) {
            assert_eq!(
                a.data, b.data,
                "m={m} {name}: streamed HiFT must equal the collected path"
            );
        }
    }
}

#[test]
fn pipelined_hift_matches_serial_streamed() {
    let lr = 4e-3f32;
    let ocfg = OptimCfg::new(OptimKind::AdamW);
    let mut be = backend();
    let n_units = be.manifest().n_units;
    let mut task = build_task("markovlm", geom(&be), 9).unwrap();
    let batches: Vec<Batch> = (0..2 * n_units).map(|_| task.train_batch()).collect();

    let p_serial = run_streamed_hift(&mut be, 2, lr, ocfg, &batches, false);
    let p_pipe = run_streamed_hift(&mut be, 2, lr, ocfg, &batches, true);
    for ((name, a), b) in p_pipe.names.iter().zip(&p_pipe.tensors).zip(&p_serial.tensors) {
        assert_eq!(a.data, b.data, "{name}: pipelined updates must be bit-identical");
    }
}

#[test]
fn hift_group_runs_one_execution_per_step() {
    // m>1 used to cost one forward per unit; the grouped streamed run is a
    // single execution per step.
    let mut be = backend();
    let manifest = be.manifest().clone();
    let mut hift = Hift::pipelined(
        HiftCfg {
            m: 2,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr: 1e-3 },
            optim: OptimCfg::new(OptimKind::AdamW),
        },
        &manifest,
        false,
    )
    .unwrap();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif2", geom(&be), 3).unwrap();
    let steps = 4u64;
    for _ in 0..steps {
        let b = task.train_batch();
        hift.step(&mut be, &mut params, &b).unwrap();
    }
    assert_eq!(be.stats().executions, steps, "one grouped execution per step");
}

#[test]
fn streamed_hift_peak_grad_residency_is_one_tensor() {
    let mut be = backend();
    let manifest = be.manifest().clone();
    let vinfo = manifest.variant("base").unwrap();
    let n_units = manifest.n_units;
    let max_tensor_bytes = vinfo.params.iter().map(|p| p.size * 4).max().unwrap() as u64;
    let group_sum_bytes: u64 = {
        // Largest group (m=2, fixed chunks) by total gradient bytes.
        (0..n_units)
            .step_by(2)
            .map(|start| {
                vinfo
                    .params
                    .iter()
                    .filter(|p| p.unit >= start as i64 && p.unit < start as i64 + 2)
                    .map(|p| (p.size * 4) as u64)
                    .sum()
            })
            .max()
            .unwrap()
    };
    assert!(group_sum_bytes > max_tensor_bytes, "group must span several tensors");

    let mut task = build_task("motif4", geom(&be), 3).unwrap();
    let batches: Vec<Batch> = (0..n_units).map(|_| task.train_batch()).collect();
    let _ = run_streamed_hift(&mut be, 2, 1e-3, OptimCfg::new(OptimKind::AdamW), &batches, false);
    assert_eq!(
        be.stats().peak_grad_resident_bytes,
        max_tensor_bytes,
        "streamed HiFT holds at most the group's largest single tensor"
    );

    // The collected path (pre-refactor semantics) holds the whole group.
    let mut be2 = backend();
    let _ = hift_collected_reference(
        &mut be2,
        2,
        1e-3,
        OptimCfg::new(OptimKind::AdamW),
        &batches,
    );
    assert!(
        be2.stats().peak_grad_resident_bytes >= group_sum_bytes / 2,
        "collected path accumulates whole units ({} < {})",
        be2.stats().peak_grad_resident_bytes,
        group_sum_bytes / 2,
    );
    assert!(
        be2.stats().peak_grad_resident_bytes > be.stats().peak_grad_resident_bytes,
        "collected residency must exceed streamed residency"
    );
}

#[test]
fn run_record_surfaces_backend_stats_and_grad_peak() {
    use hift::coordinator::trainer::{self, TrainCfg};
    let mut be = backend();
    let manifest = be.manifest().clone();
    let mut hift = Hift::pipelined(
        HiftCfg {
            m: 1,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr: 2e-3 },
            optim: OptimCfg::new(OptimKind::AdamW),
        },
        &manifest,
        false,
    )
    .unwrap();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), 7).unwrap();
    let rec = trainer::train(
        &mut be,
        &mut hift,
        &mut params,
        task.as_mut(),
        TrainCfg { steps: 4, eval_every: 0, log_every: 0 },
    )
    .unwrap();
    assert!(rec.backend.executions > 4, "train steps + eval forwards");
    assert!(rec.backend.cache_hits + rec.backend.cache_misses > 0);
    assert!(rec.backend.h2d_bytes > 0 && rec.backend.d2h_bytes > 0);
    assert!(rec.backend.peak_grad_resident_bytes > 0);
    let ledger_peak = rec.peak_grad_resident_bytes.expect("hift has a ledger");
    assert_eq!(
        ledger_peak, rec.backend.peak_grad_resident_bytes,
        "fused sink holds exactly what the backend streams"
    );
    let json = hift::ser::emit_pretty(&rec.to_json());
    for key in ["cache_hits", "cache_misses", "peak_grad_resident_bytes", "executions"] {
        assert!(json.contains(key), "RunRecord JSON must surface {key}");
    }
}
